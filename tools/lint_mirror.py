#!/usr/bin/env python3
"""Line-exact Python mirror of the Rust `lumina lint` engines.

Ports `rust/src/analysis/` (lexer, pylex, waiver, scan, extract,
mirror, report) plus the `util::json` pretty printer, so CI can
cross-check the two implementations: both scan the same trees and
must emit byte-identical findings JSON. Any divergence is itself a
mirror bug.

Stdlib only. Usage mirrors `lumina lint`:

    python3 tools/lint_mirror.py [--mirror] [--root DIR] [--out F]
        [--format text|json] [--deny-warnings]
        [--manifest production|fixture] [--v1]

`--v1` emits the legacy report layout (no `engine` key, version 1)
to compare against goldens generated before the mirror engine
landed.
"""

import os
import sys
from collections import namedtuple

# --------------------------------------------------------------- lexer
# Port of rust/src/analysis/lexer.rs. Tokens carry 1-based lines and
# 1-based *byte* columns; the scanner walks raw bytes exactly like
# the Rust one so every boundary decision matches.

IDENT = "Ident"
PUNCT = "Punct"
STR = "Str"

Tok = namedtuple("Tok", ["kind", "text", "line", "col"])

WS = (0x20, 0x09, 0x0D, 0x0C)  # u8::is_ascii_whitespace minus \n


def _ident_byte(c):
    return (0x30 <= c <= 0x39) or (0x41 <= c <= 0x5A) \
        or (0x61 <= c <= 0x7A) or c == 0x5F


def _utf8_len(first):
    if first <= 0x7F:
        return 1
    if 0xC0 <= first <= 0xDF:
        return 2
    if 0xE0 <= first <= 0xEF:
        return 3
    return 4


def _dec(b):
    return b.decode("utf-8", "replace")


def lex(src):
    return _lex_impl(src, False)


def lex_full(src):
    return _lex_impl(src, True)


def _lex_impl(src, keep_strings):
    b = src.encode("utf-8", "surrogateescape")
    n = len(b)
    toks = []
    comments = []
    i = 0
    line = 1
    line_start = 0
    while i < n:
        c = b[i]
        if c == 0x0A:
            line += 1
            i += 1
            line_start = i
            continue
        if c in WS:
            i += 1
            continue
        col = i - line_start + 1
        # Line comment: capture for the waiver parser.
        if c == 0x2F and i + 1 < n and b[i + 1] == 0x2F:
            start = i
            while i < n and b[i] != 0x0A:
                i += 1
            comments.append((line, _dec(b[start:i])))
            continue
        # Block comment (nested, like Rust's).
        if c == 0x2F and i + 1 < n and b[i + 1] == 0x2A:
            depth = 1
            i += 2
            while i < n and depth > 0:
                if b[i] == 0x2F and i + 1 < n and b[i + 1] == 0x2A:
                    depth += 1
                    i += 2
                elif b[i] == 0x2A and i + 1 < n and b[i + 1] == 0x2F:
                    depth -= 1
                    i += 2
                else:
                    if b[i] == 0x0A:
                        line += 1
                        line_start = i + 1
                    i += 1
            continue
        # Raw string r"..." / r#"..."# and br"..." / br#"..."#.
        if c == 0x72 or (c == 0x62 and i + 1 < n and b[i + 1] == 0x72):
            j = i + 1 + (1 if c == 0x62 else 0)
            hashes = 0
            while j < n and b[j] == 0x23:
                hashes += 1
                j += 1
            if j < n and b[j] == 0x22:
                tok_line = line
                j += 1
                inner_start = j
                inner_end = n
                while j < n:
                    if b[j] == 0x22 and j + 1 + hashes <= n \
                            and all(h == 0x23
                                    for h in b[j + 1:j + 1 + hashes]):
                        inner_end = j
                        j += 1 + hashes
                        break
                    if b[j] == 0x0A:
                        line += 1
                        line_start = j + 1
                    j += 1
                if keep_strings:
                    toks.append(Tok(STR, _dec(b[inner_start:inner_end]),
                                    tok_line, col))
                i = j
                continue
            # Not a raw string: fall through to the ident scanner.
        # Plain string literal.
        if c == 0x22:
            tok_line = line
            i += 1
            inner_start = i
            inner_end = n
            while i < n:
                ch = b[i]
                if ch == 0x5C:
                    if i + 1 < n and b[i + 1] == 0x0A:
                        line += 1
                        line_start = i + 2
                    i += 2
                elif ch == 0x22:
                    inner_end = i
                    i += 1
                    break
                elif ch == 0x0A:
                    line += 1
                    i += 1
                    line_start = i
                else:
                    i += 1
            if keep_strings:
                toks.append(Tok(STR, _dec(b[inner_start:min(inner_end, n)]),
                                tok_line, col))
            continue
        # Char literal vs lifetime tick.
        if c == 0x27:
            if i + 1 < n and b[i + 1] == 0x5C:
                j = i + 2
                while j < n and b[j] != 0x27:
                    j += 1
                i = min(j + 1, n)
                continue
            if i + 1 < n and b[i + 1] != 0x27:
                ln = _utf8_len(b[i + 1])
                if i + 1 + ln < n and b[i + 1 + ln] == 0x27:
                    i += ln + 2
                    continue
            i += 1
            continue
        if _ident_byte(c):
            start = i
            while i < n and _ident_byte(b[i]):
                i += 1
            toks.append(Tok(IDENT, _dec(b[start:i]), line, col))
            continue
        if c == 0x3A and i + 1 < n and b[i + 1] == 0x3A:
            toks.append(Tok(PUNCT, "::", line, col))
            i += 2
            continue
        ln = min(_utf8_len(c), n - i)
        toks.append(Tok(PUNCT, _dec(b[i:i + ln]), line, col))
        i += ln
    return toks, comments


# --------------------------------------------------------------- pylex
# Port of rust/src/analysis/pylex.rs.

_PY_PREFIX = frozenset(b"rbfuRBFU")


def lex_py(src):
    b = src.encode("utf-8", "surrogateescape")
    n = len(b)
    toks = []
    comments = []
    i = 0
    line = 1
    line_start = 0
    while i < n:
        c = b[i]
        if c == 0x0A:
            line += 1
            i += 1
            line_start = i
            continue
        if c in WS:
            i += 1
            continue
        col = i - line_start + 1
        if c == 0x23:  # '#'
            start = i
            while i < n and b[i] != 0x0A:
                i += 1
            comments.append((line, _dec(b[start:i])))
            continue
        if c == 0x5C and i + 1 < n and b[i + 1] == 0x0A:
            line += 1
            i += 2
            line_start = i
            continue
        if c in (0x22, 0x27) or c in _PY_PREFIX:
            q = i
            while q < n and q < i + 2 and b[q] in _PY_PREFIX:
                q += 1
            if q < n and b[q] in (0x22, 0x27):
                quote = b[q]
                tok_line = line
                triple = q + 2 < n and b[q + 1] == quote \
                    and b[q + 2] == quote
                j = q + (3 if triple else 1)
                inner_start = j
                inner_end = n
                while j < n:
                    if b[j] == 0x5C:
                        if j + 1 < n and b[j + 1] == 0x0A:
                            line += 1
                            line_start = j + 2
                        j += 2
                        continue
                    if triple:
                        if b[j] == quote and j + 2 < n \
                                and b[j + 1] == quote \
                                and b[j + 2] == quote:
                            inner_end = j
                            j += 3
                            break
                        if b[j] == 0x0A:
                            line += 1
                            line_start = j + 1
                    else:
                        if b[j] == quote:
                            inner_end = j
                            j += 1
                            break
                        if b[j] == 0x0A:
                            # Unterminated: stop at the newline.
                            inner_end = j
                            break
                    j += 1
                toks.append(Tok(STR, _dec(b[inner_start:min(inner_end, n)]),
                                tok_line, col))
                i = j
                continue
        if _ident_byte(c):
            start = i
            while i < n and _ident_byte(b[i]):
                i += 1
            toks.append(Tok(IDENT, _dec(b[start:i]), line, col))
            continue
        ln = min(_utf8_len(c), n - i)
        toks.append(Tok(PUNCT, _dec(b[i:i + ln]), line, col))
        i += ln
    return toks, comments


# --------------------------------------------------------------- rules
# Port of rust/src/analysis/rules.rs.

SEVERITY = {
    "D001": "error",
    "D002": "warning",
    "D003": "error",
    "D004": "error",
    "F001": "error",
    "M001": "error",
    "M002": "error",
    "M003": "error",
    "M004": "warning",
    "P001": "warning",
    "W001": "warning",
}

ORDER_METHODS = ("iter", "iter_mut", "into_iter", "keys", "values",
                 "values_mut", "drain", "retain")
RNG_METHODS = ("next_u32", "next_u64", "f64", "range_usize", "choose",
               "chance", "normal", "shuffle", "sample_indices", "fork")
ENTROPY_IDENTS = ("thread_rng", "ThreadRng", "from_entropy", "OsRng",
                  "getrandom")
DET_MODULES = ("eval", "dse", "pareto", "sim", "baselines")


def severity_of(rule):
    return SEVERITY.get(rule, "error")


# -------------------------------------------------------------- waiver
# Port of rust/src/analysis/waiver.rs.

Waiver = namedtuple("Waiver", ["rule", "line", "reason"])


def parse_waivers(comments):
    waivers = []
    w001 = []
    for line, text in comments:
        pos = text.find("lumina:")
        if pos < 0:
            continue
        rest = text[pos + len("lumina:"):].lstrip()
        if not rest.startswith("allow("):
            continue
        body = rest[len("allow("):]
        close = body.find(")")
        if close < 0:
            w001.append((line, "waiver is missing its closing `)`"))
            continue
        ids = [s.strip() for s in body[:close].split(",")]
        ids = [s for s in ids if s]
        reason = body[close + 1:].strip()
        if not ids:
            w001.append((line, "waiver lists no rule id"))
            continue
        for rid in ids:
            if rid == "W001":
                w001.append((line, "waiver may not target W001"))
                continue
            if rid not in SEVERITY:
                w001.append(
                    (line, "waiver names unknown rule `%s`" % rid))
                continue
            if not reason:
                w001.append(
                    (line, "waiver for %s gives no reason" % rid))
                continue
            waivers.append(Waiver(rid, line, reason))
    return waivers, w001


# ---------------------------------------------------------------- scan
# Port of rust/src/analysis/scan.rs.

Finding = namedtuple(
    "Finding",
    ["rule", "severity", "file", "line", "message", "waived",
     "waiver_reason"])


def _relkey(rel):
    r = rel[len("src/"):] if rel.startswith("src/") else rel
    return r[len("rust/src/"):] if r.startswith("rust/src/") else r


def is_det_module(rel):
    key = _relkey(rel)
    top = key.split("/", 1)[0]
    return top in DET_MODULES


def d002_allowed(rel):
    key = _relkey(rel)
    return key == "util/bench.rs" or key.startswith("bench/") \
        or "benches/" in key


def p001_exempt(rel):
    key = _relkey(rel)
    base = key.rsplit("/", 1)[-1]
    return base == "main.rs" or base == "golden.rs" \
        or "tests/" in key or "benches/" in key


def _punct(t, s):
    return t.kind == PUNCT and t.text == s


def _is_ident(t, s):
    return t.kind == IDENT and t.text == s


def scan_file(relpath, src):
    toks, comments = lex(src)
    n = len(toks)
    raw = []  # (rule, line, message)

    # Pre-pass: idents bound to a hash-container type.
    hash_idents = []
    for k in range(n):
        t = toks[k]
        if t.kind != IDENT or t.text not in ("HashMap", "HashSet"):
            continue
        j = k - 1
        while j >= 1 and _punct(toks[j], "::"):
            j -= 1
            if j >= 0 and toks[j].kind == IDENT:
                j -= 1
        if j >= 0 and (_punct(toks[j], ":") or _punct(toks[j], "=")):
            j -= 1
            if j >= 0:
                p = toks[j]
                if p.kind == IDENT and p.text != "mut" \
                        and p.text not in hash_idents:
                    hash_idents.append(p.text)

    depth = 0
    test_regions = []
    impl_dse = []
    tell_body = []
    pending_test = False
    pending_impl_dse = False
    pending_fn_tell = False

    i = 0
    while i < n:
        t = toks[i]
        in_test = bool(test_regions)

        if _punct(t, "{"):
            depth += 1
            if pending_test:
                test_regions.append(depth)
                pending_test = False
            if pending_impl_dse:
                impl_dse.append(depth)
                pending_impl_dse = False
            if pending_fn_tell:
                tell_body.append(depth)
                pending_fn_tell = False
            i += 1
            continue
        if _punct(t, "}"):
            if test_regions and test_regions[-1] == depth:
                test_regions.pop()
            if impl_dse and impl_dse[-1] == depth:
                impl_dse.pop()
            if tell_body and tell_body[-1] == depth:
                tell_body.pop()
            depth = max(depth - 1, 0)
            i += 1
            continue
        if _punct(t, ";"):
            pending_test = False
            pending_impl_dse = False
            pending_fn_tell = False
            i += 1
            continue

        # Attribute `#[...]`: a `test` token (unless negated) marks
        # the next body as a test region.
        if _punct(t, "#") and i + 1 < n and _punct(toks[i + 1], "["):
            j = i + 2
            d = 1
            has_test = False
            has_not = False
            while j < n and d > 0:
                a = toks[j]
                if _punct(a, "["):
                    d += 1
                elif _punct(a, "]"):
                    d -= 1
                    if d == 0:
                        break
                elif _is_ident(a, "test"):
                    has_test = True
                elif _is_ident(a, "not"):
                    has_not = True
                j += 1
            if has_test and not has_not:
                pending_test = True
            i = j + 1
            continue

        # `impl ... DseSession ... {` opens a D004-tracked impl.
        if _is_ident(t, "impl") and not in_test:
            j = i + 1
            seen_dse = False
            while j < n and not _punct(toks[j], "{") \
                    and not _punct(toks[j], ";"):
                if _is_ident(toks[j], "DseSession"):
                    seen_dse = True
                j += 1
            if seen_dse and j < n and _punct(toks[j], "{"):
                pending_impl_dse = True
            i += 1
            continue

        # `fn tell` inside a tracked impl.
        if _is_ident(t, "fn") and impl_dse and i + 1 < n \
                and _is_ident(toks[i + 1], "tell"):
            pending_fn_tell = True
            i += 2
            continue

        if t.kind == IDENT:
            if t.text in ENTROPY_IDENTS:
                raw.append((
                    "D003", t.line,
                    "entropy RNG `%s`; seed a stats::rng::Pcg32 "
                    "instead" % t.text))
            if not in_test and not d002_allowed(relpath):
                if t.text in ("SystemTime", "UNIX_EPOCH"):
                    raw.append((
                        "D002", t.line,
                        "wall-clock `%s` outside util/bench.rs"
                        % t.text))
                if t.text == "Instant" and i + 2 < n \
                        and _punct(toks[i + 1], "::") \
                        and _is_ident(toks[i + 2], "now"):
                    raw.append((
                        "D002", t.line,
                        "wall-clock `Instant::now` outside "
                        "util/bench.rs"))

        # Method call: `. name (`.
        if _punct(t, ".") and i + 2 < n and toks[i + 1].kind == IDENT \
                and _punct(toks[i + 2], "("):
            m = toks[i + 1].text
            mline = toks[i + 1].line
            recv = toks[i - 1].text if i > 0 \
                and toks[i - 1].kind == IDENT else None
            if not in_test:
                if m in ("unwrap", "expect") \
                        and not p001_exempt(relpath):
                    raw.append((
                        "P001", mline,
                        "`.%s(` may panic in library code; return "
                        "crate::error::Error or waive with a proof"
                        % m))
                if tell_body and m in RNG_METHODS:
                    raw.append((
                        "D004", mline,
                        "RNG draw `.%s(` inside a `tell` body; "
                        "draws belong in `ask`" % m))
                if recv is not None and recv in hash_idents \
                        and m in ORDER_METHODS:
                    if is_det_module(relpath):
                        raw.append((
                            "D001", mline,
                            "`%s.%s()` iterates an unordered hash "
                            "container" % (recv, m)))
                    _scan_float_reduction(toks, i, recv, m, relpath,
                                          raw)
            i += 1
            continue

        # `for pat in <hash ident> {`.
        if _is_ident(t, "for") and not in_test \
                and is_det_module(relpath):
            j = i + 1
            while j < n and not _is_ident(toks[j], "in") \
                    and not _punct(toks[j], "{"):
                j += 1
            if j < n and _is_ident(toks[j], "in") and j + 1 < n:
                core = []
                k = j + 1
                while k < n and not _punct(toks[k], "{"):
                    x = toks[k]
                    if not _punct(x, "&") and not _is_ident(x, "mut"):
                        core.append(x)
                    k += 1
                if len(core) == 1 and core[0].kind == IDENT \
                        and core[0].text in hash_idents:
                    raw.append((
                        "D001", core[0].line,
                        "`for _ in %s` iterates an unordered hash "
                        "container" % core[0].text))
        i += 1

    waivers, w001 = parse_waivers(comments)
    out = []
    for rule, line, message in raw:
        w = next((wv for wv in waivers
                  if wv.rule == rule
                  and (wv.line == line or wv.line + 1 == line)), None)
        out.append(Finding(rule, severity_of(rule), relpath, line,
                           message, w is not None,
                           w.reason if w is not None else None))
    for line, message in w001:
        out.append(Finding("W001", severity_of("W001"), relpath, line,
                           message, False, None))
    out.sort(key=lambda f: (f.line, f.rule, f.message))
    return out


def _scan_float_reduction(toks, i, recv, m, relpath, raw):
    n = len(toks)
    j = i + 2  # the call's own `(` — counted below
    d = 0
    while j < n:
        t = toks[j]
        if _punct(t, "(") or _punct(t, "["):
            d += 1
        elif _punct(t, ")") or _punct(t, "]") or _punct(t, "}"):
            d -= 1
            if d < 0:
                break
        elif _punct(t, "{"):
            if d == 0:
                break
            d += 1
        elif _punct(t, ";") and d == 0:
            break
        elif _punct(t, ".") and d == 0 and j + 1 < n \
                and (_is_ident(toks[j + 1], "sum")
                     or _is_ident(toks[j + 1], "fold")):
            if is_det_module(relpath):
                raw.append((
                    "F001", toks[j + 1].line,
                    "float reduction `.%s(` over unordered "
                    "`%s.%s()`" % (toks[j + 1].text, recv, m)))
            break
        j += 1


# -------------------------------------------------------------- report
# Port of rust/src/analysis/report.rs + the util::json pretty writer.

class Report(object):
    def __init__(self, engine, root, files, findings):
        self.engine = engine
        self.root = root
        self.files = files
        self.findings = findings

    def counts(self):
        errors = warnings = waived = 0
        for f in self.findings:
            if f.waived:
                waived += 1
            elif f.severity == "error":
                errors += 1
            else:
                warnings += 1
        return errors, warnings, waived

    def failed(self, deny_warnings):
        errors, warnings, _ = self.counts()
        return errors > 0 or (deny_warnings and warnings > 0)

    def render_text(self):
        out = []
        for f in self.findings:
            if f.waived:
                continue
            out.append("%s:%d: %s %s: %s\n" % (
                f.file, f.line, f.severity, f.rule, f.message))
        errors, warnings, waived = self.counts()
        out.append(
            "lint: %d files, %d findings (%d errors, %d warnings, "
            "%d waived)\n" % (self.files, len(self.findings), errors,
                              warnings, waived))
        return "".join(out)

    def to_json(self, v1=False):
        errors, warnings, waived = self.counts()
        findings = []
        for f in self.findings:
            findings.append({
                "file": f.file,
                "line": f.line,
                "message": f.message,
                "rule": f.rule,
                "severity": f.severity,
                "waived": f.waived,
                "waiver_reason": f.waiver_reason,
            })
        doc = {
            "counts": {
                "errors": errors,
                "waived": waived,
                "warnings": warnings,
            },
            "files": self.files,
            "findings": findings,
            "root": self.root,
            "version": 1 if v1 else 2,
        }
        if not v1:
            doc["engine"] = self.engine
        return doc


def _escape_into(s, out):
    out.append('"')
    for c in s:
        if c == '"':
            out.append('\\"')
        elif c == "\\":
            out.append("\\\\")
        elif c == "\n":
            out.append("\\n")
        elif c == "\t":
            out.append("\\t")
        elif c == "\r":
            out.append("\\r")
        elif ord(c) < 0x20:
            out.append("\\u%04x" % ord(c))
        else:
            out.append(c)
    out.append('"')


def _write_json(v, out, indent):
    pad = "  " * (indent + 1)
    pad0 = "  " * indent
    if v is None:
        out.append("null")
    elif v is True:
        out.append("true")
    elif v is False:
        out.append("false")
    elif isinstance(v, (int, float)):
        f = float(v)
        if f == int(f) and abs(f) < 1e15:
            out.append("%d" % int(f))
        else:
            out.append(repr(f))
    elif isinstance(v, str):
        _escape_into(v, out)
    elif isinstance(v, list):
        if not v:
            out.append("[]")
            return
        out.append("[\n")
        for i, item in enumerate(v):
            out.append(pad)
            _write_json(item, out, indent + 1)
            if i + 1 < len(v):
                out.append(",")
            out.append("\n")
        out.append(pad0)
        out.append("]")
    elif isinstance(v, dict):
        if not v:
            out.append("{}")
            return
        keys = sorted(v.keys())
        out.append("{\n")
        for i, k in enumerate(keys):
            out.append(pad)
            _escape_into(k, out)
            out.append(": ")
            _write_json(v[k], out, indent + 1)
            if i + 1 < len(keys):
                out.append(",")
            out.append("\n")
        out.append(pad0)
        out.append("}")
    else:
        raise TypeError("unsupported JSON value: %r" % (v,))


def pretty(v):
    out = []
    _write_json(v, out, 0)
    return "".join(out)


# ----------------------------------------------------------- lint tree
# Port of rust/src/analysis/mod.rs lint_tree/collect_rs/rel_of.

def _collect_rs(dirpath, out):
    for entry in os.scandir(dirpath):
        if entry.is_dir(follow_symlinks=False):
            if entry.name in ("target", "out"):
                continue
            _collect_rs(entry.path, out)
        elif entry.is_file() and entry.name.endswith(".rs"):
            out.append(entry.path)


def lint_tree(root):
    files = []
    _collect_rs(root, files)
    # Rust sorts Vec<PathBuf> component-wise; the findings are
    # re-sorted below so only the file count is order-free.
    files.sort(key=lambda p: p.replace(os.sep, "/").split("/"))
    findings = []
    for path in files:
        rel = os.path.relpath(path, root).replace(os.sep, "/")
        with open(path, "r", encoding="utf-8") as fh:
            text = fh.read()
        findings.extend(scan_file(rel, text))
    findings.sort(key=lambda f: (f.file, f.line, f.rule, f.message))
    return Report("determinism", root.replace("\\", "/"), len(files),
                  findings)


# ------------------------------------------------------------- extract
# Port of rust/src/analysis/extract.rs. Values are tagged tuples:
#   ("num", v, text, line) ("str", s, line) ("none",) ("ref", name)
#   ("call", name, args, kwargs) ("struct", name, fields, base)
#   ("arr", items) ("dict", entries) ("opaque",)

OPAQUE = ("opaque",)
NONE_LIT = ("none",)

Sym = namedtuple("Sym", ["name", "line", "value"])
PyClassT = namedtuple("PyClassT", ["name", "line", "fields"])


def _digit_start(t):
    return t.kind == IDENT and t.text[:1].isdigit()


def join_number(toks, i):
    n = len(toks)
    k = i
    neg = False
    if k < n and _punct(toks[k], "-"):
        neg = True
        k += 1
    if k >= n or not _digit_start(toks[k]):
        return None
    s = toks[k].text
    k += 1
    if "." not in s and k + 1 < n and _punct(toks[k], ".") \
            and _digit_start(toks[k + 1]):
        s += "." + toks[k + 1].text
        k += 2
    if s.endswith(("e", "E")) and k + 1 < n \
            and (_punct(toks[k], "-") or _punct(toks[k], "+")) \
            and _digit_start(toks[k + 1]):
        s += toks[k].text + toks[k + 1].text
        k += 2
    cleaned = s.replace("_", "")
    try:
        v = _parse_f64(cleaned)
    except ValueError:
        return None
    text = "-" + s if neg else s
    return (-v if neg else v, text, k)


def _parse_f64(s):
    # Rust str::parse::<f64> rejects leading/trailing junk that
    # Python's float() also rejects, but accepts fewer spellings:
    # no underscores (pre-stripped above), no inf/nan shorthands
    # beyond the same names. For the digit-led strings join_number
    # feeds in, float() matches exactly; hex strings like "0x54"
    # raise in both.
    if s.startswith("0x") or s.startswith("0X"):
        raise ValueError(s)
    return float(s)


def _expr_end(toks, i):
    d = 0
    j = i
    n = len(toks)
    while j < n:
        t = toks[j]
        if t.kind == PUNCT:
            if t.text in ("(", "[", "{"):
                d += 1
            elif t.text in (")", "]", "}"):
                if d == 0:
                    return j
                d -= 1
            elif t.text in (",", ";") and d == 0:
                return j
        j += 1
    return j


def _py_expr_end(toks, i):
    n = len(toks)
    if i >= n:
        return i
    d = 0
    cur = toks[i].line
    j = i
    while j < n:
        t = toks[j]
        if d == 0 and t.line > cur:
            return j
        if t.kind == PUNCT:
            if t.text in ("(", "[", "{"):
                d += 1
            elif t.text in (")", "]", "}"):
                if d == 0:
                    return j
                d -= 1
            elif t.text in (",", ";") and d == 0:
                return j
        if d == 0:
            cur = t.line
        j += 1
    return j


def _elem(toks, i, end, f):
    v, nxt = f(toks, i)
    return v if nxt == end else OPAQUE


def _path(toks, i, sep):
    name = toks[i].text
    j = i + 1
    n = len(toks)
    while j + 1 < n and _punct(toks[j], sep) \
            and toks[j + 1].kind == IDENT:
        name += sep + toks[j + 1].text
        j += 2
    return name, j


def extract_rust(src):
    toks, _ = lex_full(src)
    n = len(toks)
    out = []
    depth = 0
    i = 0
    while i < n:
        t = toks[i]
        if t.kind == PUNCT:
            if t.text in ("{", "(", "["):
                depth += 1
            elif t.text in ("}", ")", "]"):
                depth -= 1
        if depth == 0 and _is_ident(t, "const") and i + 2 < n \
                and toks[i + 1].kind == IDENT \
                and _punct(toks[i + 2], ":"):
            name = toks[i + 1].text
            line = toks[i + 1].line
            # Skip the type: up to `=` at relative bracket depth 0.
            j = i + 3
            bd = 0
            while j < n:
                tt = toks[j]
                if tt.kind == PUNCT:
                    if tt.text in ("[", "(", "<"):
                        bd += 1
                    elif tt.text in ("]", ")", ">"):
                        bd -= 1
                    elif tt.text == "=" and bd == 0:
                        break
                j += 1
            vstart = j + 1
            end = _expr_end(toks, vstart)
            out.append(Sym(name, line,
                           _elem(toks, vstart, end, _parse_rust_value)))
            i = end
            continue
        i += 1
    return out


def _parse_rust_value(toks, i):
    n = len(toks)
    if i >= n:
        return OPAQUE, i
    if _punct(toks[i], "&"):
        return _parse_rust_value(toks, i + 1)
    num = join_number(toks, i)
    if num is not None:
        v, text, nxt = num
        return ("num", v, text, toks[i].line), nxt
    if toks[i].kind == STR:
        return ("str", toks[i].text, toks[i].line), i + 1
    if _punct(toks[i], "["):
        items = []
        j = i + 1
        while j < n and not _punct(toks[j], "]"):
            end = _expr_end(toks, j)
            items.append(_elem(toks, j, end, _parse_rust_value))
            j = end
            if j < n and _punct(toks[j], ","):
                j += 1
        return ("arr", items), min(j + 1, n)
    if toks[i].kind == IDENT:
        name, j = _path(toks, i, "::")
        if j < n and _punct(toks[j], "{"):
            fields = []
            base = None
            j += 1
            while j < n and not _punct(toks[j], "}"):
                if _punct(toks[j], ".") and j + 2 < n \
                        and _punct(toks[j + 1], ".") \
                        and toks[j + 2].kind == IDENT:
                    base, j = _path(toks, j + 2, "::")
                    continue
                if toks[j].kind == IDENT and j + 1 < n \
                        and _punct(toks[j + 1], ":"):
                    fname = toks[j].text
                    vstart = j + 2
                    end = _expr_end(toks, vstart)
                    fields.append(
                        (fname,
                         _elem(toks, vstart, end, _parse_rust_value)))
                    j = end
                else:
                    j = _expr_end(toks, j)
                if j < n and _punct(toks[j], ","):
                    j += 1
            return ("struct", name, fields, base), min(j + 1, n)
        if j < n and _punct(toks[j], "("):
            args = []
            j += 1
            while j < n and not _punct(toks[j], ")"):
                end = _expr_end(toks, j)
                args.append(_elem(toks, j, end, _parse_rust_value))
                j = end
                if j < n and _punct(toks[j], ","):
                    j += 1
            return ("call", name, args, []), min(j + 1, n)
        return ("ref", name), j
    return OPAQUE, i + 1


PY_KEYWORDS = frozenset([
    "assert", "class", "def", "del", "elif", "else", "except",
    "finally", "for", "from", "global", "if", "import", "lambda",
    "nonlocal", "pass", "print", "raise", "return", "try", "while",
    "with",
])


def extract_py(src):
    toks, _ = lex_py(src)
    n = len(toks)
    syms = []
    classes = []
    depth = 0
    i = 0
    while i < n:
        t = toks[i]
        if t.kind == PUNCT:
            if t.text in ("(", "[", "{"):
                depth += 1
            elif t.text in (")", "]", "}"):
                depth -= 1
        if depth == 0 and t.col == 1 and t.kind == IDENT:
            if t.text == "class" and i + 1 < n \
                    and toks[i + 1].kind == IDENT:
                cls, nxt = _extract_py_class(toks, i)
                classes.append(cls)
                i = nxt
                continue
            if t.text not in PY_KEYWORDS:
                vstart = _assign_rhs(toks, i)
                if vstart is not None:
                    end = _py_expr_end(toks, vstart)
                    syms.append(Sym(
                        t.text, t.line,
                        _elem(toks, vstart, end, _parse_py_value)))
                    i = end
                    continue
        i += 1
    return syms, classes


def _assign_rhs(toks, i):
    n = len(toks)
    if i + 1 >= n:
        return None
    if _punct(toks[i + 1], "=") \
            and not (i + 2 < n and _punct(toks[i + 2], "=")):
        return i + 2
    if _punct(toks[i + 1], ":"):
        k = i + 2
        while k < n and toks[k].line == toks[i].line:
            if _punct(toks[k], "=") \
                    and not (k + 1 < n and _punct(toks[k + 1], "=")):
                return k + 1
            k += 1
    return None


def _extract_py_class(toks, i):
    n = len(toks)
    name = toks[i + 1].text
    line = toks[i + 1].line
    fields = []
    d = 0
    j = i + 2
    prev_line = toks[i].line
    while j < n:
        t = toks[j]
        if t.kind == PUNCT:
            if t.text in ("(", "[", "{"):
                d += 1
            elif t.text in (")", "]", "}"):
                d -= 1
        if d == 0 and t.col == 1 and t.line > toks[i].line:
            break  # next module-level statement
        if d == 0 and t.kind == IDENT and t.line > prev_line \
                and t.col > 1 and t.text not in PY_KEYWORDS:
            vstart = _assign_rhs(toks, j)
            if vstart is not None:
                end = _py_expr_end(toks, vstart)
                fields.append(Sym(
                    t.text, t.line,
                    _elem(toks, vstart, end, _parse_py_value)))
                prev_line = max(toks[max(end - 1, 0)].line, t.line)
                j = end
                continue
        prev_line = max(prev_line, t.line)
        j += 1
    return PyClassT(name, line, fields), j


def _parse_py_value(toks, i):
    n = len(toks)
    if i >= n:
        return OPAQUE, i
    num = join_number(toks, i)
    if num is not None:
        v, text, nxt = num
        return ("num", v, text, toks[i].line), nxt
    if toks[i].kind == STR:
        return ("str", toks[i].text, toks[i].line), i + 1
    if _punct(toks[i], "{"):
        entries = []
        j = i + 1
        while j < n and not _punct(toks[j], "}"):
            key, nk = _parse_py_value(toks, j)
            if nk >= n or not _punct(toks[nk], ":"):
                j = _expr_end(toks, j)
                if j < n and _punct(toks[j], ","):
                    j += 1
                continue
            vstart = nk + 1
            end = _expr_end(toks, vstart)
            entries.append(
                (key, _elem(toks, vstart, end, _parse_py_value)))
            j = end
            if j < n and _punct(toks[j], ","):
                j += 1
        return ("dict", entries), min(j + 1, n)
    if _punct(toks[i], "[") or _punct(toks[i], "("):
        close = "]" if _punct(toks[i], "[") else ")"
        items = []
        j = i + 1
        while j < n and not _punct(toks[j], close):
            end = _expr_end(toks, j)
            items.append(_elem(toks, j, end, _parse_py_value))
            j = end
            if j < n and _punct(toks[j], ","):
                j += 1
        return ("arr", items), min(j + 1, n)
    if toks[i].kind == IDENT:
        if toks[i].text == "None":
            return NONE_LIT, i + 1
        name, j = _path(toks, i, ".")
        if j < n and _punct(toks[j], "("):
            args = []
            kwargs = []
            j += 1
            while j < n and not _punct(toks[j], ")"):
                end = _expr_end(toks, j)
                if toks[j].kind == IDENT and j + 1 < end \
                        and _punct(toks[j + 1], "=") \
                        and not (j + 2 < n
                                 and _punct(toks[j + 2], "=")):
                    kwargs.append(
                        (toks[j].text,
                         _elem(toks, j + 2, end, _parse_py_value)))
                else:
                    args.append(_elem(toks, j, end, _parse_py_value))
                j = end
                if j < n and _punct(toks[j], ","):
                    j += 1
            return ("call", name, args, kwargs), min(j + 1, n)
        return ("ref", name), j
    return OPAQUE, i + 1


# ------------------------------------------------------------ mirrors
# Port of rust/src/analysis/mirrors.rs: the production manifest,
# plus the fixture manifest mirrored from rust/tests/mirror.rs.

ALL = ("all",)


def _named(*names):
    return ("named", frozenset(names))


def _except_prefixes(*prefixes):
    return ("except", tuple(prefixes))


def filter_keeps(flt, name):
    if flt[0] == "all":
        return True
    if flt[0] == "named":
        return name in flt[1]
    return not any(name.startswith(p) for p in flt[1])


CONSTS = ("consts",)

MirrorPair = namedtuple("MirrorPair", [
    "name", "rust_path", "rust_filter", "rust_aux", "python_path",
    "python_filter", "kind"])
OraclePin = namedtuple("OraclePin", ["name", "field", "value", "files"])

PROD_PAIRS = (
    MirrorPair("arch-constants", "rust/src/arch/constants.rs", ALL,
               (), "python/compile/constants.py",
               _except_prefixes("IDX_", "COL_", "KIND_", "MAX_", "N_"),
               CONSTS),
    MirrorPair("design-params", "rust/src/design/point.rs",
               _named("N_PARAMS"), (), "python/compile/constants.py",
               _named("N_PARAMS"), CONSTS),
    MirrorPair("op-table-bounds", "rust/src/workload/spec.rs",
               _named("MAX_OPS", "N_PHASES"), (),
               "python/compile/constants.py",
               _named("MAX_OPS", "N_PHASES"), CONSTS),
    MirrorPair("scenario-registry", "rust/src/workload/scenario.rs",
               ALL, ("rust/src/workload/spec.rs",),
               "python/compile/workload.py", ALL,
               ("registry", "SCENARIOS")),
)

_A100_PIN_FILES = ("rust/src/sim/roofline.rs",
                   "rust/tests/artifact_vs_mirror.rs")

PROD_PINS = (
    OraclePin("a100-ttft", "ttft_ms", "36.70556", _A100_PIN_FILES),
    OraclePin("a100-tpot", "tpot_ms", "0.4424397", _A100_PIN_FILES),
    OraclePin("a100-area", "area_mm2", "833.9728", _A100_PIN_FILES),
    OraclePin("a100-prefill-energy", "prefill_energy_mj", "8116.046",
              _A100_PIN_FILES),
    OraclePin("a100-decode-energy", "energy_per_token_mj",
              "41.352123", _A100_PIN_FILES),
    OraclePin("a100-avg-power", "avg_power_w", "219.59186",
              _A100_PIN_FILES),
)

# Mirror of the test-local manifest in rust/tests/mirror.rs, checked
# against the corpus under rust/tests/lint_fixtures/mirror/.
FIXTURE_PAIRS = (
    MirrorPair("consts-drift", "rust/src/consts_drift.rs", ALL, (),
               "python/consts_drift.py", ALL, CONSTS),
    MirrorPair("consts-clean", "rust/src/consts_clean.rs", ALL, (),
               "python/consts_clean.py", ALL, CONSTS),
    MirrorPair("consts-oneside", "rust/src/consts_oneside.rs", ALL,
               (), "python/consts_oneside.py", ALL, CONSTS),
    MirrorPair("consts-waived", "rust/src/consts_waived.rs", ALL, (),
               "python/consts_waived.py", ALL, CONSTS),
    MirrorPair("fixture-registry", "rust/src/registry.rs", ALL,
               ("rust/src/regspec.rs",), "python/registry.py", ALL,
               ("registry", "SCENARIOS")),
    MirrorPair("docs-stale", "rust/src/docs_stale.rs", ALL, (),
               "python/docs_stale.py", ALL, CONSTS),
    MirrorPair("no-marker", "rust/src/nomark.rs", ALL, (),
               "python/nomark.py", ALL, CONSTS),
)

FIXTURE_PINS = (
    OraclePin("fx-ttft", "ttft_ms", "12.5",
              ("rust/src/pin_a.rs", "rust/src/pin_b.rs",
               "rust/src/pin_c.rs")),
)


# -------------------------------------------------------------- mirror
# Port of rust/src/analysis/mirror.rs.

PATH_ROOTS = ("rust/", "python/", "tests/", "src/")

RUST, PY = "rust", "py"

Raw = namedtuple("Raw", ["rule", "file", "line", "message"])
Lit = namedtuple("Lit", ["v", "text", "file", "line"])


class LintError(Exception):
    pass


def check_repo(root):
    return check(root, PROD_PAIRS, PROD_PINS)


def check(root, pairs, pins):
    files = {}
    for pair in pairs:
        _load(files, root, pair.rust_path)
        for aux in pair.rust_aux:
            _load(files, root, aux)
        _load(files, root, pair.python_path)
    for pin in pins:
        for f in pin.files:
            _load(files, root, f)

    raw = []
    for pair in pairs:
        if pair.kind[0] == "consts":
            _diff_consts(pair, files, raw)
        else:
            _diff_registry(pair, pair.kind[1], files, raw)
    for pin in pins:
        _check_pin(pin, files, raw)
    _check_docs(root, pairs, files, raw)

    findings = []
    for rel in sorted(files):
        lang, text = files[rel]
        if lang == RUST:
            _, comments = lex(text)
        else:
            _, comments = lex_py(text)
        waivers, w001 = parse_waivers(comments)
        for r in raw:
            if r.file != rel:
                continue
            w = next((wv for wv in waivers
                      if wv.rule == r.rule
                      and (wv.line == r.line
                           or wv.line + 1 == r.line)), None)
            findings.append(Finding(
                r.rule, severity_of(r.rule), r.file, r.line,
                r.message, w is not None,
                w.reason if w is not None else None))
        for line, message in w001:
            findings.append(Finding(
                "W001", severity_of("W001"), rel, line, message,
                False, None))
    findings.sort(key=lambda f: (f.file, f.line, f.rule, f.message))
    return Report("mirror", root.replace("\\", "/"), len(files),
                  findings)


def _load(files, root, rel):
    if rel in files:
        return
    path = os.path.join(root, rel)
    try:
        with open(path, "r", encoding="utf-8") as fh:
            text = fh.read()
    except OSError as e:
        raise LintError("mirror: read %s: %s" % (path, e))
    files[rel] = (PY if rel.endswith(".py") else RUST, text)


def _diff_consts(pair, files, raw):
    rf = files.get(pair.rust_path)
    pf = files.get(pair.python_path)
    if rf is None or pf is None:
        return
    rsyms = extract_rust(rf[1])
    psyms, _classes = extract_py(pf[1])
    rmap = {s.name: s for s in rsyms
            if filter_keeps(pair.rust_filter, s.name)}
    pmap = {s.name: s for s in psyms
            if filter_keeps(pair.python_filter, s.name)}
    for name in sorted(set(rmap) | set(pmap)):
        r = rmap.get(name)
        p = pmap.get(name)
        if r is not None and p is not None:
            _diff_values(pair, name, r, p, raw)
        elif r is not None:
            raw.append(Raw(
                "M002", pair.rust_path, r.line,
                "`%s` only declared in %s; missing from %s "
                "(mirror pair `%s`)" % (name, pair.rust_path,
                                        pair.python_path, pair.name)))
        elif p is not None:
            raw.append(Raw(
                "M002", pair.python_path, p.line,
                "`%s` only declared in %s; missing from %s "
                "(mirror pair `%s`)" % (name, pair.python_path,
                                        pair.rust_path, pair.name)))


def _diff_values(pair, name, r, p, raw):
    rv, pv = r.value, p.value
    drift = None
    if rv[0] == "num" and pv[0] == "num":
        if rv[1] != pv[1]:
            drift = (rv[2], pv[2])
    elif rv[0] == "str" and pv[0] == "str":
        if rv[1] != pv[1]:
            drift = ('"%s"' % rv[1], '"%s"' % pv[1])
    if drift is not None:
        rt, pt = drift
        raw.append(Raw(
            "M001", pair.rust_path, r.line,
            "`%s` drifted: %s:%d has `%s`, %s:%d has `%s`"
            % (name, pair.rust_path, r.line, rt, pair.python_path,
               p.line, pt)))


def _tail(name):
    t = name.rsplit("::", 1)[-1]
    return t.rsplit(".", 1)[-1]


def _resolve_rust_spec(v, env, file):
    if v[0] == "ref":
        return dict(env.get(_tail(v[1]), {}))
    if v[0] == "struct":
        _, _name, fields, base = v
        spec = dict(env.get(_tail(base), {})) if base is not None \
            else {}
        for fname, fval in fields:
            if fval[0] == "num":
                spec[fname] = Lit(fval[1], fval[2], file, fval[3])
        return spec
    return {}


def _rust_scenarios(pair, symbol, files):
    env = {}
    reg = None
    sources = list(pair.rust_aux) + [pair.rust_path]
    for rel in sources:
        f = files.get(rel)
        if f is None:
            continue
        for sym in extract_rust(f[1]):
            if sym.name == symbol:
                reg = (rel, sym.value)
                continue
            spec = _resolve_rust_spec(sym.value, env, rel)
            if spec:
                env[sym.name] = spec
    out = []
    if reg is None or reg[1][0] != "arr":
        return out
    reg_file, (_, items) = reg
    for item in items:
        if item[0] != "struct":
            continue
        _, _sname, fields, _base = item
        name = None
        spec = {}
        for fname, fval in fields:
            if fname == "name":
                if fval[0] == "str":
                    name = (fval[1], fval[2])
            elif fname == "spec":
                spec = _resolve_rust_spec(fval, env, reg_file)
        if name is not None:
            out.append((name[0], name[1], spec))
    return out


def _py_class_defaults(c, file):
    spec = {}
    for f in c.fields:
        if f.value[0] == "num":
            spec[f.name] = Lit(f.value[1], f.value[2], file,
                               f.value[3])
    return spec


def _gqa_default(spec):
    if "n_kv_heads" not in spec and "n_heads" in spec:
        spec["n_kv_heads"] = spec["n_heads"]


def _resolve_py_spec(v, env, classes, file):
    if v[0] == "ref":
        return dict(env.get(_tail(v[1]), {}))
    if v[0] == "call":
        _, name, args, kwargs = v
        callee = _tail(name)
        if callee == "replace":
            spec = _resolve_py_spec(args[0], env, classes, file) \
                if args else {}
        else:
            defaults = classes.get(callee)
            if defaults is None:
                return {}
            spec = dict(defaults)
        for kname, kval in kwargs:
            if kval[0] == "num":
                spec[kname] = Lit(kval[1], kval[2], file, kval[3])
            if kval == NONE_LIT:
                spec.pop(kname, None)
        _gqa_default(spec)
        return spec
    return {}


def _py_scenarios(pair, symbol, files):
    f = files.get(pair.python_path)
    if f is None:
        return []
    syms, pyclasses = extract_py(f[1])
    classes = {c.name: _py_class_defaults(c, pair.python_path)
               for c in pyclasses}
    env = {}
    reg = None
    for sym in syms:
        if sym.name == symbol:
            reg = sym.value
            continue
        spec = _resolve_py_spec(sym.value, env, classes,
                                pair.python_path)
        if spec:
            env[sym.name] = spec
    out = []
    if reg is None or reg[0] != "dict":
        return out
    for key, val in reg[1]:
        if key[0] != "str":
            continue
        spec = _resolve_py_spec(val, env, classes, pair.python_path)
        out.append((key[1], key[2], spec))
    return out


def _diff_registry(pair, symbol, files, raw):
    rs = _rust_scenarios(pair, symbol, files)
    py = _py_scenarios(pair, symbol, files)
    rmap = {n: (l, s) for n, l, s in rs}
    pmap = {n: (l, s) for n, l, s in py}
    for name in sorted(set(rmap) | set(pmap)):
        r = rmap.get(name)
        p = pmap.get(name)
        if r is not None and p is not None:
            if not r[1] or not p[1]:
                continue  # resolution failed: presence-only
            _diff_specs(pair, name, r[1], p[1], raw)
        elif r is not None:
            raw.append(Raw(
                "M002", pair.rust_path, r[0],
                "scenario `%s` only registered in %s; missing from "
                "%s (mirror pair `%s`)" % (name, pair.rust_path,
                                           pair.python_path,
                                           pair.name)))
        elif p is not None:
            raw.append(Raw(
                "M002", pair.python_path, p[0],
                "scenario `%s` only registered in %s; missing from "
                "%s (mirror pair `%s`)" % (name, pair.python_path,
                                           pair.rust_path,
                                           pair.name)))


def _diff_specs(pair, name, rspec, pspec, raw):
    for fname in sorted(set(rspec) | set(pspec)):
        r = rspec.get(fname)
        p = pspec.get(fname)
        if r is not None and p is not None:
            if r.v != p.v:
                raw.append(Raw(
                    "M001", r.file, r.line,
                    "scenario `%s` field `%s` drifted: %s:%d has "
                    "`%s`, %s:%d has `%s`" % (name, fname, r.file,
                                              r.line, r.text, p.file,
                                              p.line, p.text)))
        elif r is not None:
            raw.append(Raw(
                "M002", r.file, r.line,
                "scenario `%s` field `%s` only set in %s; missing "
                "from %s (mirror pair `%s`)" % (name, fname, r.file,
                                                pair.python_path,
                                                pair.name)))
        elif p is not None:
            raw.append(Raw(
                "M002", p.file, p.line,
                "scenario `%s` field `%s` only set in %s; missing "
                "from %s (mirror pair `%s`)" % (name, fname, p.file,
                                                pair.rust_path,
                                                pair.name)))


def _check_pin(pin, files, raw):
    try:
        want = float(pin.value)
    except ValueError:
        return
    for rel in pin.files:
        f = files.get(rel)
        if f is None:
            continue
        toks, _ = lex(f[1])
        occs = []
        for i in range(len(toks)):
            if not _is_ident(toks[i], pin.field):
                continue
            if i + 2 >= len(toks) or not _punct(toks[i + 1], "-"):
                continue
            num = join_number(toks, i + 2)
            if num is not None:
                occs.append((num[0], num[1], toks[i + 2].line))
        if not occs:
            raw.append(Raw(
                "M003", rel, 1,
                "oracle pin `%s` (`%s`) not found in %s"
                % (pin.name, pin.field, rel)))
            continue
        if any(o[0] == want for o in occs):
            continue
        best = occs[0]
        for o in occs[1:]:
            if abs(o[0] - want) < abs(best[0] - want):
                best = o
        raw.append(Raw(
            "M003", rel, best[2],
            "oracle pin `%s` (`%s`) diverged: found `%s`, canonical "
            "is `%s`" % (pin.name, pin.field, best[1], pin.value)))


def _check_docs(root, pairs, files, raw):
    members = {}
    for pair in pairs:
        members.setdefault(pair.rust_path, []).append(pair.name)
        members.setdefault(pair.python_path, []).append(pair.name)
    corpus = _test_corpus(root, files)
    for rel in sorted(members):
        pair_names = members[rel]
        f = files.get(rel)
        if f is None:
            continue
        lines = _doc_lines(f)
        has_marker = any("mirror" in t.lower() for _, t in lines)
        if not has_marker:
            raw.append(Raw(
                "M004", rel, 1,
                "mirror pair file carries no MIRROR marker comment "
                "(pairs: %s)" % ", ".join(pair_names)))
        for line, text in lines:
            _check_doc_line(root, rel, line, text, corpus, raw)


def _doc_lines(f):
    lang, text = f
    out = []
    if lang == RUST:
        _, comments = lex(text)
        out.extend(comments)
    else:
        toks, comments = lex_py(text)
        out.extend(comments)
        if toks and toks[0].kind == STR:
            for k, seg in enumerate(toks[0].text.split("\n")):
                out.append((toks[0].line + k, seg))
    out.sort(key=lambda p: p[0])
    return out


def _check_doc_line(root, rel, line, text, corpus, raw):
    lower = text.lower()
    mentions_test = "test" in lower and "`" in text
    if "mirror" not in lower and not mentions_test:
        return
    for word in text.split():
        w = word.strip("`()\",;:'<>").rstrip(".,")
        if "{" in w or "*" in w:
            continue  # brace-glob shorthand, not a literal path
        if not any(w.startswith(p) for p in PATH_ROOTS):
            continue
        if "::" in w:
            path, sym = w.split("::", 1)
        else:
            path, sym = w, None
        path = path.rstrip("/")
        target = _resolve_path(root, path)
        if target is None:
            raw.append(Raw(
                "M004", rel, line,
                "stale mirror reference: `%s` does not exist"
                % path))
            continue
        if sym is not None:
            try:
                with open(target, "r", encoding="utf-8") as fh:
                    found = sym in fh.read()
            except OSError:
                found = False
            if not found:
                raw.append(Raw(
                    "M004", rel, line,
                    "stale mirror reference: `%s` has no symbol "
                    "`%s`" % (path, sym)))
    if not mentions_test:
        return
    for k, part in enumerate(text.split("`")):
        if k % 2 == 0 or not _snake_ident(part):
            continue
        fn_pat = "fn %s(" % part
        def_pat = "def %s(" % part
        found = any((t.find(fn_pat) >= 0 if lang == RUST
                     else t.find(def_pat) >= 0)
                    for lang, t in corpus)
        if not found:
            raw.append(Raw(
                "M004", rel, line,
                "stale mirror reference: no function or test named "
                "`%s`" % part))


def _resolve_path(root, rel):
    a = os.path.join(root, rel)
    if os.path.exists(a):
        return a
    b = os.path.join(root, "rust", rel)
    if os.path.exists(b):
        return b
    return None


def _snake_ident(s):
    b = s.encode("utf-8", "surrogateescape")
    return len(b) >= 4 and 0x5F in b \
        and (0x61 <= b[0] <= 0x7A or b[0] == 0x5F) \
        and all(0x61 <= c <= 0x7A or 0x30 <= c <= 0x39 or c == 0x5F
                for c in b)


def _test_corpus(root, files):
    out = [(files[rel][0], files[rel][1]) for rel in sorted(files)]
    for d in ("rust/tests", "tests"):
        full = os.path.join(root, d)
        try:
            entries = os.listdir(full)
        except OSError:
            continue
        paths = sorted(os.path.join(full, e) for e in entries
                       if e.endswith(".rs"))
        for p in paths:
            try:
                with open(p, "r", encoding="utf-8") as fh:
                    out.append((RUST, fh.read()))
            except OSError:
                pass
    return out


# ----------------------------------------------------------------- cli
# Mirrors `lumina lint` / `lumina mirror` (rust/src/main.rs).

def _default_lint_root():
    return "rust/src" if os.path.isdir("rust/src") else "src"


def _default_mirror_root():
    if os.path.isdir("rust/src") and os.path.isdir("python"):
        return "."
    return ".."


def main(argv):
    import argparse
    ap = argparse.ArgumentParser(
        description="Python mirror of `lumina lint`")
    ap.add_argument("--mirror", action="store_true")
    ap.add_argument("--root")
    ap.add_argument("--out")
    ap.add_argument("--format", default="text",
                    choices=["text", "json"])
    ap.add_argument("--deny-warnings", action="store_true")
    ap.add_argument("--manifest", default="production",
                    choices=["production", "fixture"])
    ap.add_argument("--v1", action="store_true",
                    help="legacy report layout (no engine key)")
    args = ap.parse_args(argv)

    root = args.root
    if root is None:
        root = _default_mirror_root() if args.mirror \
            else _default_lint_root()
    if not os.path.isdir(root):
        print("error: lint root %s is not a directory "
              "(pass --root <dir>)" % root, file=sys.stderr)
        return 1
    try:
        if args.mirror:
            if args.manifest == "fixture":
                report = check(root, FIXTURE_PAIRS, FIXTURE_PINS)
            else:
                report = check_repo(root)
        else:
            report = lint_tree(root)
    except LintError as e:
        print("error: %s" % e, file=sys.stderr)
        return 1

    out_path = args.out or (
        "out/mirror_findings.json" if args.mirror
        else "out/lint_findings.json")
    out_dir = os.path.dirname(out_path)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
    json_text = pretty(report.to_json(v1=args.v1)) + "\n"
    with open(out_path, "w", encoding="utf-8") as fh:
        fh.write(json_text)

    if args.format == "json":
        sys.stdout.write(json_text)
    else:
        sys.stdout.write(report.render_text())
        print("findings JSON: %s" % out_path)

    if report.failed(args.deny_warnings):
        errors, warnings, _ = report.counts()
        print("error: lint: %d unwaivered findings (%d errors, "
              "%d warnings); fix them or waive with "
              "`// lumina: allow(RULE) reason`"
              % (errors + warnings, errors, warnings),
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
