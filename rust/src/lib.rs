//! LUMINA — LLM-guided GPU architecture exploration via bottleneck analysis.
//!
//! Reproduction of *LUMINA: LLM-Guided GPU Architecture Exploration via
//! Bottleneck Analysis* (CS.AR 2026) as a three-layer Rust + JAX + Pallas
//! system:
//!
//! * **L3 (this crate)** — the paper's coordination contribution: the
//!   LUMINA engines ([`lumina`]), the DSE baselines ([`baselines`]),
//!   the ask/tell session drivers ([`dse`]), the
//!   DSE Benchmark ([`bench_dse`]), Pareto analytics ([`pareto`]), the
//!   detailed LLMCompass-class simulator with critical-path analysis
//!   ([`sim::compass`]) and the PJRT runtime that executes the AOT
//!   artifacts ([`runtime`]).
//! * **L2/L1 (python/, build-time only)** — the batched roofline
//!   evaluation model and its Pallas kernel, lowered once to
//!   `artifacts/*.hlo.txt` and loaded here; Python never runs on the
//!   exploration path.
//!
//! See `DESIGN.md` for the system inventory and the experiment index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.

pub mod analysis;
pub mod arch;
pub mod baselines;
pub mod bench;
pub mod bench_dse;
pub mod design;
pub mod dse;
pub mod error;
pub mod eval;
pub mod figures;
pub mod llm;
pub mod lumina;
pub mod pareto;
pub mod runtime;
pub mod sim;
pub mod stats;
pub mod util;
pub mod workload;

/// Crate-wide result alias (see [`error`] for the `anyhow`-style API).
pub type Result<T> = std::result::Result<T, error::Error>;
