//! The simulated analyst: a deterministic, seeded stand-in for the
//! paper's hosted LLMs.
//!
//! Contract: it sees only the rendered prompt text (`parse.rs` extracts
//! structure back out) and returns a completion string, exactly like a
//! hosted model. Internally it performs genuine — but deliberately
//! imperfect — architectural reasoning; the per-model failure modes of
//! `profile.rs` fire stochastically (seeded) and are suppressed when the
//! system prompt carries the paper's corrective rules.

use crate::design::{DesignPoint, Param};
use crate::llm::parse;
use crate::llm::profile::ModelProfile;
use crate::llm::prompts;
use crate::llm::LanguageModel;
use crate::stats::rng::Pcg32;

/// Parameters an analyst associates with each stall component. This is
/// the "pretrained domain knowledge" a real LLM would bring.
pub fn relevant_params(stall: &str) -> &'static [Param] {
    match stall {
        "compute" => &[
            Param::SystolicArray,
            Param::Cores,
            Param::Sublanes,
            Param::VectorWidth,
        ],
        "memory" => {
            &[Param::MemChannels, Param::GbufMb, Param::SramKb]
        }
        _ => &[Param::Links],
    }
}

/// The simulated analyst model.
pub struct SimulatedAnalyst {
    pub profile: ModelProfile,
    rng: Pcg32,
}

impl SimulatedAnalyst {
    pub fn new(profile: ModelProfile, seed: u64) -> Self {
        Self { profile, rng: Pcg32::with_stream(seed, 0x11a) }
    }

    pub fn qwen3(seed: u64) -> Self {
        Self::new(ModelProfile::qwen3(), seed)
    }

    // ----------------------------------------------------------- tasks

    fn answer_bottleneck(&mut self, prompt: &str, enhanced: bool) -> String {
        let rates = *self.profile.rates(enhanced);
        let choices = parse::parse_choices(prompt);
        let design = parse::parse_design_lines(prompt);
        let counters = parse::parse_assignments(prompt);

        // Dominant component from the counters.
        let comp = *counters.get("compute_stall_ms").unwrap_or(&0.0);
        let mem = *counters.get("memory_stall_ms").unwrap_or(&0.0);
        let net = *counters.get("network_stall_ms").unwrap_or(&0.0);
        let dominant = if comp >= mem && comp >= net {
            "compute"
        } else if mem >= net {
            "memory"
        } else {
            "network"
        };

        // Does the architecture look systolic-over-provisioned? (decode
        // phase questions carry "decode" in the counter header)
        let decode_phase = prompt.contains("(decode phase)");
        let sa_overprovisioned = decode_phase
            && dominant == "compute"
            && design
                .map(|d| d.get(Param::SystolicArray) >= 32)
                .unwrap_or(false);
        let sees_overprovisioning =
            !self.rng.chance(rates.systolic_blindness);

        // Score each choice.
        let relevant = relevant_params(dominant);
        let mut best: Option<(usize, i32)> = None;
        for (i, c) in choices.iter().enumerate() {
            let acts = parse_choice_actions(c);
            if acts.is_empty() {
                continue;
            }
            let mut score = 0i32;
            let single = acts.len() == 1;
            for (p, dir) in &acts {
                let rel = relevant.contains(p);
                let good_dir = if sa_overprovisioned
                    && *p == Param::SystolicArray
                    && sees_overprovisioning
                {
                    *dir < 0
                } else {
                    *dir > 0
                };
                if rel && good_dir {
                    score += 4;
                } else if rel {
                    score -= 2;
                } else {
                    score -= 3; // irrelevant parameter bundled in
                }
            }
            if single {
                score += 2;
            }
            // Failure mode: attracted to multi-resource bundles that
            // contain at least one relevant parameter.
            if !single
                && acts.iter().any(|(p, _)| relevant.contains(p))
                && self.rng.chance(rates.multi_resource)
            {
                score += 8;
            }
            if best.map(|(_, s)| score > s).unwrap_or(true) {
                best = Some((i, score));
            }
        }
        let idx = best.map(|(i, _)| i).unwrap_or(0);
        format!(
            "Dominant stall is {dominant}. Answer: {}",
            prompts::letter(idx)
        )
    }

    fn answer_prediction(&mut self, prompt: &str, enhanced: bool) -> String {
        let rates = *self.profile.rates(enhanced);
        let choices = parse::parse_choices(prompt);

        // Metric name appears as "Predict <metric> for config:".
        let metric = prompt
            .split("Predict ")
            .nth(1)
            .and_then(|s| s.split(' ').next())
            .unwrap_or("area_mm2")
            .to_string();

        let reference = parse::parse_section(prompt, "Sensitivity reference")
            .map(parse::parse_example_rows)
            .unwrap_or_default();
        let examples = parse::parse_section(prompt, "Observed examples")
            .map(parse::parse_example_rows)
            .unwrap_or_default();
        let target = prompt
            .split("for config:")
            .nth(1)
            .and_then(parse::parse_compact_design);

        let predicted = match (&target, reference.first()) {
            (Some(t), Some((rd, rv))) => {
                if metric == "area_mm2" {
                    // The analyst "executes" the quoted area-model source.
                    let zero_base = self.rng.chance(rates.zero_baseline);
                    if zero_base {
                        // Failure mode: sums per-parameter contributions
                        // against a zero baseline — drops the cross terms
                        // and fixed offsets of the reference.
                        analyst_area(t) - analyst_area(rd)
                    } else {
                        analyst_area(t)
                    }
                } else {
                    // Perf: local linear model from single-param deltas.
                    let mut v = *rv;
                    let slopes = single_param_slopes(rd, *rv, &examples);
                    let base: &DesignPoint = if self
                        .rng
                        .chance(rates.zero_baseline)
                    {
                        // Zero-baseline failure: deltas computed from the
                        // first example instead of the reference.
                        v = examples.first().map(|e| e.1).unwrap_or(v);
                        examples
                            .first()
                            .map(|e| &e.0)
                            .unwrap_or(rd)
                    } else {
                        rd
                    };
                    for p in Param::ALL {
                        let dv = t.get(p) as f64 - base.get(p) as f64;
                        if dv != 0.0 {
                            if let Some(s) = slopes[p.index()] {
                                v += s * dv;
                            }
                        }
                    }
                    v
                }
            }
            _ => 0.0,
        };

        // Pick the numerically closest choice.
        let mut idx = nearest_choice(&choices, predicted);
        if self.rng.chance(rates.arithmetic_slip) && choices.len() > 1 {
            // Generic slip: off-by-one choice.
            idx = (idx + 1) % choices.len();
        }
        format!(
            "Estimated {metric} = {predicted:.3}. Answer: {}",
            prompts::letter(idx)
        )
    }

    fn answer_tuning(&mut self, prompt: &str, enhanced: bool) -> String {
        let rates = *self.profile.rates(enhanced);
        let choices = parse::parse_choices(prompt);
        let initial = parse::parse_design_lines(prompt);
        let counters = parse::parse_assignments(prompt);
        let budget = *counters.get("area_budget").unwrap_or(
            &prompt
                .split("area_mm2 <=")
                .nth(1)
                .and_then(|s| {
                    s.trim().split_whitespace().next()
                })
                .and_then(|s| s.parse::<f64>().ok())
                .unwrap_or(f64::INFINITY),
        );
        let minimize_tpot = prompt.contains("minimize TPOT");

        let comp = *counters.get("compute_stall_ms").unwrap_or(&1.0);
        let mem = *counters.get("memory_stall_ms").unwrap_or(&1.0);
        let net = *counters.get("network_stall_ms").unwrap_or(&1.0);
        let total = (comp + mem + net).max(1e-9);

        let constraint_blind = self.rng.chance(rates.constraint_blind);
        let multi_adjust = self.rng.chance(rates.multi_adjust);

        let mut best: Option<(usize, f64)> = None;
        for (i, c) in choices.iter().enumerate() {
            let Some(d) = parse::parse_compact_design(c) else {
                continue;
            };
            let area = analyst_area(&d);
            if !constraint_blind && area > budget * 1.001 {
                continue;
            }
            // Coarse internal latency model, weighted by the observed
            // stall mix (this is the analyst's genuine reasoning step).
            let score = if multi_adjust {
                // Failure mode: prefers the candidate that changes the
                // most parameters ("compensate everywhere").
                initial
                    .map(|init| {
                        -(Param::ALL
                            .iter()
                            .filter(|&&p| d.get(p) != init.get(p))
                            .count() as f64)
                    })
                    .unwrap_or(0.0)
            } else {
                analyst_latency_score(
                    &d,
                    comp / total,
                    mem / total,
                    net / total,
                    minimize_tpot,
                )
            };
            if best.map(|(_, s)| score < s).unwrap_or(true) {
                best = Some((i, score));
            }
        }
        let idx = best.map(|(i, _)| i).unwrap_or(0);
        format!("Answer: {}", prompts::letter(idx))
    }

    fn answer_strategy(&mut self, prompt: &str, enhanced: bool) -> String {
        let rates = *self.profile.rates(enhanced);
        let design = parse::parse_design_lines(prompt)
            .unwrap_or_else(DesignPoint::a100);

        // Dominant stall comes from the critical-path section header.
        let dominant = if prompt.contains("dominant stall: network") {
            "network"
        } else if prompt.contains("dominant stall: memory") {
            "memory"
        } else {
            "compute"
        };
        let decode_target = prompt.contains("minimize TPOT");

        // Influence factors: lines "influence: <param> <value>" (higher =
        // more impact on the target metric per unit area).
        let mut influence: Vec<(Param, f64)> = Vec::new();
        for line in prompt.lines() {
            let Some(rest) = line.trim().strip_prefix("influence:") else {
                continue;
            };
            let mut toks = rest.split_whitespace();
            if let (Some(name), Some(v)) = (toks.next(), toks.next()) {
                if let (Some(p), Ok(v)) =
                    (Param::by_name(name), v.parse::<f64>())
                {
                    influence.push((p, v));
                }
            }
        }

        // Banned moves from the reflection section.
        let mut banned: Vec<(Param, i32)> = Vec::new();
        for line in prompt.lines() {
            let Some(rest) = line.trim().strip_prefix("banned:") else {
                continue;
            };
            let mut toks = rest.split_whitespace();
            if let (Some(name), Some(dir)) = (toks.next(), toks.next()) {
                if let Some(p) = Param::by_name(name) {
                    let d = if dir.starts_with('-') { -1 } else { 1 };
                    banned.push((p, d));
                }
            }
        }

        // Pick the boost parameter: most influential for the dominant
        // stall (fall back to the domain-knowledge mapping).
        let candidates = relevant_params(dominant);
        let pick = |influence: &[(Param, f64)], banned: &[(Param, i32)]| {
            let mut best: Option<(Param, f64)> = None;
            for &p in candidates {
                if banned.contains(&(p, 1)) {
                    continue;
                }
                let w = influence
                    .iter()
                    .find(|(q, _)| *q == p)
                    .map(|(_, v)| *v)
                    .unwrap_or(0.5);
                if best.map(|(_, bw)| w > bw).unwrap_or(true) {
                    best = Some((p, w));
                }
            }
            best.map(|(p, _)| p)
        };
        let mut boost = pick(&influence, &banned);

        // Systolic-blindness: for TPOT work the analyst may still try to
        // grow the systolic array even though decode can't use it.
        if decode_target
            && boost == Some(Param::SystolicArray)
            && !self.rng.chance(rates.systolic_blindness)
        {
            // Sees the pitfall (RULE 4): divert to memory instead.
            boost = Some(Param::MemChannels);
        }
        let Some(boost) = boost else {
            return "adjust: memory_channel_count +1".to_string();
        };

        // Funding parameter: least influential on the target metric,
        // largest area saving, not the boost itself.
        let mut fund: Option<(Param, f64)> = None;
        for p in Param::ALL {
            if p == boost
                || design.get(p)
                    == crate::design::DesignSpace::table1().values(p)[0]
                || banned.contains(&(p, -1))
            {
                continue;
            }
            let w = influence
                .iter()
                .find(|(q, _)| *q == p)
                .map(|(_, v)| *v)
                .unwrap_or(0.5);
            if fund.map(|(_, fw)| w < fw).unwrap_or(true) {
                fund = Some((p, w));
            }
        }

        let mut out = format!(
            "Dominant stall: {dominant}. Boost the most correlated \
             resource, fund from the least critical.\n\
             adjust: {} +1\n",
            boost.name()
        );
        if let Some((f, _)) = fund {
            out.push_str(&format!("adjust: {} -1\n", f.name()));
        }
        // Non-enhanced models sometimes bundle extra non-critical tweaks
        // (the failure the paper's RULE 3 exists to stop).
        if !enhanced && self.rng.chance(rates.multi_adjust) {
            for p in Param::ALL {
                if Some(p) != fund.map(|(f, _)| f) && p != boost {
                    out.push_str(&format!("adjust: {} +1\n", p.name()));
                    break;
                }
            }
        }
        out
    }
}

impl LanguageModel for SimulatedAnalyst {
    fn name(&self) -> &str {
        self.profile.name
    }

    fn complete(&mut self, system: &str, prompt: &str) -> String {
        let enhanced = prompts::has_enhanced_rules(system);
        if prompt.contains("## Task: bottleneck-analysis") {
            self.answer_bottleneck(prompt, enhanced)
        } else if prompt.contains("## Task: perf-area-prediction") {
            self.answer_prediction(prompt, enhanced)
        } else if prompt.contains("## Task: parameter-tuning") {
            self.answer_tuning(prompt, enhanced)
        } else if prompt.contains("## Task: bottleneck-mitigation-strategy")
        {
            self.answer_strategy(prompt, enhanced)
        } else {
            "Answer: A".to_string()
        }
    }
}

// ------------------------------------------------------------ helpers

/// Parse "increase core_count" / "decrease sram_kb" actions, possibly
/// several joined by ';'.
pub fn parse_choice_actions(choice: &str) -> Vec<(Param, i32)> {
    let mut out = Vec::new();
    for part in choice.split(';') {
        let mut toks = part.trim().split_whitespace();
        let Some(verb) = toks.next() else { continue };
        let dir = match verb {
            "increase" => 1,
            "decrease" => -1,
            _ => continue,
        };
        if let Some(p) = toks.next().and_then(Param::by_name) {
            out.push((p, dir));
        }
    }
    out
}

/// The analyst's mental copy of the quoted area-model source.
pub fn analyst_area(d: &DesignPoint) -> f64 {
    let cores = d.get(Param::Cores) as f64;
    let subl = d.get(Param::Sublanes) as f64;
    let sa = d.get(Param::SystolicArray) as f64;
    let vecw = d.get(Param::VectorWidth) as f64;
    let sram = d.get(Param::SramKb) as f64;
    let gbuf = d.get(Param::GbufMb) as f64;
    let memch = d.get(Param::MemChannels) as f64;
    let links = d.get(Param::Links) as f64;
    cores * (1.5 + subl * (sa * sa * 0.0004 + vecw * 0.012) + 1.1
        + sram * 0.0055)
        + gbuf * 1.9
        + memch * 15.0
        + links * 1.5
        + 60.0
}

/// Per-parameter slopes learned from examples that differ from the
/// reference in exactly one parameter (the analyst's sensitivity
/// reasoning for performance prediction).
fn single_param_slopes(
    reference: &DesignPoint,
    ref_value: f64,
    examples: &[(DesignPoint, f64)],
) -> [Option<f64>; crate::design::N_PARAMS] {
    let mut slopes = [None; crate::design::N_PARAMS];
    for (d, v) in examples {
        let mut changed: Option<Param> = None;
        let mut multi = false;
        for p in Param::ALL {
            if d.get(p) != reference.get(p) {
                if changed.is_some() {
                    multi = true;
                }
                changed = Some(p);
            }
        }
        if multi {
            continue;
        }
        if let Some(p) = changed {
            let dv = d.get(p) as f64 - reference.get(p) as f64;
            if dv != 0.0 {
                slopes[p.index()] = Some((v - ref_value) / dv);
            }
        }
    }
    slopes
}

/// Coarse latency proxy, weighted by the observed stall mix.
fn analyst_latency_score(
    d: &DesignPoint,
    w_comp: f64,
    w_mem: f64,
    w_net: f64,
    decode: bool,
) -> f64 {
    let cores = d.get(Param::Cores) as f64;
    let subl = d.get(Param::Sublanes) as f64;
    let sa = d.get(Param::SystolicArray) as f64;
    let memch = d.get(Param::MemChannels) as f64;
    let links = d.get(Param::Links) as f64;
    // Decode matmuls only light up min(sa, ~8) rows of the array.
    let eff_sa = if decode { sa.min(8.0) * sa } else { sa * sa };
    let compute = 1.0 / (cores * subl * eff_sa);
    let memory = 1.0 / memch;
    let network = 1.0 / links;
    w_comp * compute * 1e5 + w_mem * memory * 10.0 + w_net * network * 10.0
}

/// Index of the numerically closest choice string.
fn nearest_choice(choices: &[String], value: f64) -> usize {
    let mut best = 0usize;
    let mut best_d = f64::INFINITY;
    for (i, c) in choices.iter().enumerate() {
        if let Ok(v) = c.trim().parse::<f64>() {
            let d = (v - value).abs();
            if d < best_d {
                best_d = d;
                best = i;
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::{Metrics, Phase};

    fn metrics_net_bound() -> Metrics {
        Metrics {
            ttft_ms: 30.0,
            tpot_ms: 0.4,
            area_mm2: 834.0,
            stalls: [[8.0, 4.0, 18.0], [0.0, 0.3, 0.1]],
            ..Default::default()
        }
    }

    #[test]
    fn oracle_picks_relevant_single_param() {
        let mut m =
            SimulatedAnalyst::new(ModelProfile::oracle(), 1);
        let q = prompts::bottleneck_question(
            &crate::workload::GPT3_175B,
            &DesignPoint::a100(),
            &metrics_net_bound(),
            Phase::Prefill,
            &[
                "increase core_count".into(),
                "increase interconnect_link_count".into(),
                "increase memory_channel_count".into(),
                "increase interconnect_link_count ; increase sram_kb"
                    .into(),
            ],
        );
        let a = m.complete(prompts::SYSTEM_DEFAULT, &q);
        assert_eq!(parse::parse_answer_letter(&a), Some(1), "{a}");
    }

    #[test]
    fn oracle_detects_systolic_overprovisioning_in_decode() {
        let mut m =
            SimulatedAnalyst::new(ModelProfile::oracle(), 2);
        let d = DesignPoint::a100().with(Param::SystolicArray, 128);
        let metrics = Metrics {
            ttft_ms: 30.0,
            tpot_ms: 0.6,
            area_mm2: 900.0,
            stalls: [[20.0, 5.0, 5.0], [0.4, 0.15, 0.05]],
            ..Default::default()
        };
        let q = prompts::bottleneck_question(
            &crate::workload::GPT3_175B,
            &d,
            &metrics,
            Phase::Decode,
            &[
                "increase systolic_array_dim".into(),
                "decrease systolic_array_dim".into(),
                "increase interconnect_link_count".into(),
            ],
        );
        let a = m.complete(prompts::SYSTEM_DEFAULT, &q);
        assert_eq!(parse::parse_answer_letter(&a), Some(1), "{a}");
    }

    #[test]
    fn oracle_area_prediction_is_exact() {
        let mut m =
            SimulatedAnalyst::new(ModelProfile::oracle(), 3);
        let target = DesignPoint::a100().with(Param::Cores, 128);
        let truth = analyst_area(&target);
        let choices = vec![
            format!("{:.3}", truth * 0.9),
            format!("{:.3}", truth),
            format!("{:.3}", truth * 1.1),
            format!("{:.3}", truth * 1.25),
        ];
        let q = prompts::prediction_question(
            "area_mm2",
            &DesignPoint::a100(),
            analyst_area(&DesignPoint::a100()),
            &[(DesignPoint::a100().with(Param::Cores, 96),
               analyst_area(&DesignPoint::a100().with(Param::Cores, 96)))],
            &target,
            true,
            &choices,
        );
        let a = m.complete(prompts::SYSTEM_DEFAULT, &q);
        assert_eq!(parse::parse_answer_letter(&a), Some(1), "{a}");
    }

    #[test]
    fn oracle_tuning_respects_constraint() {
        let mut m =
            SimulatedAnalyst::new(ModelProfile::oracle(), 4);
        // Candidate A is faster but blows the area budget; B is feasible.
        let fat = DesignPoint::new([24, 256, 8, 64, 64, 512, 256, 12]);
        let feasible = DesignPoint::new([18, 108, 4, 16, 32, 192, 40, 6]);
        let slow = DesignPoint::new([6, 16, 1, 4, 4, 32, 32, 1]);
        let q = prompts::tuning_question(
            &DesignPoint::a100(),
            &metrics_net_bound(),
            Phase::Prefill,
            900.0,
            &[
                prompts::compact_design(&fat),
                prompts::compact_design(&feasible),
                prompts::compact_design(&slow),
            ],
        );
        let a = m.complete(prompts::SYSTEM_DEFAULT, &q);
        assert_eq!(parse::parse_answer_letter(&a), Some(1), "{a}");
    }

    #[test]
    fn strategy_boosts_dominant_and_funds_least_critical() {
        let mut m =
            SimulatedAnalyst::new(ModelProfile::oracle(), 5);
        let q = prompts::strategy_request(
            &DesignPoint::a100(),
            &metrics_net_bound(),
            Phase::Prefill,
            "critical path [TTFT] dominant stall: network\n",
            "influence: interconnect_link_count 0.9\n\
             influence: core_count 0.6\ninfluence: sram_kb 0.05\n",
            "(no failures recorded)\n",
            50.0,
            None,
        );
        let a = m.complete(&prompts::system_enhanced(), &q);
        let adj = parse::parse_adjustments(&a);
        assert_eq!(adj.len(), 2, "{a}");
        assert_eq!(adj[0].param, Param::Links);
        assert!(adj[0].steps > 0);
        assert_eq!(adj[1].param, Param::SramKb);
        assert!(adj[1].steps < 0);
    }

    #[test]
    fn strategy_respects_banned_moves() {
        let mut m =
            SimulatedAnalyst::new(ModelProfile::oracle(), 6);
        let q = prompts::strategy_request(
            &DesignPoint::a100(),
            &metrics_net_bound(),
            Phase::Prefill,
            "dominant stall: network\n",
            "influence: interconnect_link_count 0.9\n\
             influence: core_count 0.2\n",
            "banned: interconnect_link_count +1\n",
            50.0,
            None,
        );
        let a = m.complete(&prompts::system_enhanced(), &q);
        let adj = parse::parse_adjustments(&a);
        assert!(adj.iter().all(|x| !(x.param == Param::Links
            && x.steps > 0)), "{a}");
    }

    #[test]
    fn weak_model_errs_more_often_than_strong() {
        // Same 200 seeded bottleneck questions; llama should flip to the
        // bundled distractor more often than qwen.
        let count_errors = |profile: ModelProfile| {
            let mut m = SimulatedAnalyst::new(profile, 7);
            let mut errs = 0;
            for i in 0..200u64 {
                let q = prompts::bottleneck_question(
                    &crate::workload::GPT3_175B,
                    &DesignPoint::a100(),
                    &metrics_net_bound(),
                    Phase::Prefill,
                    &[
                        "increase interconnect_link_count".into(),
                        format!(
                            "increase interconnect_link_count ; \
                             increase sram_kb ; seed {i}"
                        ),
                    ],
                );
                let a = m.complete(prompts::SYSTEM_DEFAULT, &q);
                if parse::parse_answer_letter(&a) != Some(0) {
                    errs += 1;
                }
            }
            errs
        };
        let qwen = count_errors(ModelProfile::qwen3());
        let llama = count_errors(ModelProfile::llama31());
        assert!(llama > qwen, "llama={llama} qwen={qwen}");
    }
}
