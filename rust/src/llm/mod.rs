//! LLM substrate: the `LanguageModel` trait, prompt rendering, answer
//! parsing, and the **simulated analyst** models.
//!
//! No hosted LLM is reachable in this environment (see DESIGN.md
//! "Substitutions"), so the paper's Qwen3/Phi-4/Llama-3.1 backends are
//! stood in for by [`analyst::SimulatedAnalyst`]: a deterministic,
//! seeded reasoner that receives the *rendered prompt text*, parses it
//! back out (never side-channel structs), performs imperfect
//! architectural reasoning, and emits a textual answer. Per-model
//! [`profile::ModelProfile`]s inject the paper's observed failure modes
//! (multi-resource distractors, zero-baseline deltas, systolic
//! underutilization blindness, non-critical multi-adjust) at rates
//! calibrated to reproduce Table 3; "enhanced" system prompts carry the
//! paper's corrective rules, which the analyst detects and which suppress
//! the corresponding error modes.
//!
//! A real OpenAI-compatible HTTP backend can be slotted behind the same
//! trait without touching LUMINA.

pub mod analyst;
pub mod parse;
pub mod profile;
pub mod prompts;

pub use analyst::SimulatedAnalyst;
pub use profile::ModelProfile;

/// A chat-style language model: system prompt + user prompt -> completion.
pub trait LanguageModel {
    fn name(&self) -> &str;
    fn complete(&mut self, system: &str, prompt: &str) -> String;
}
