//! Parsing of prompt text (by the simulated analyst) and of model
//! completions (by LUMINA and the benchmark scorer).
//!
//! The simulated analyst is only allowed to see the rendered prompt — all
//! the structure it reasons over is re-extracted here, keeping the
//! text-in/text-out contract of a real LLM backend.

use std::collections::BTreeMap;

use crate::design::{DesignPoint, Param, N_PARAMS};

/// Extract `key = value` numeric assignments (one per line).
pub fn parse_assignments(text: &str) -> BTreeMap<String, f64> {
    let mut out = BTreeMap::new();
    for line in text.lines() {
        let line = line.trim();
        if let Some((k, v)) = line.split_once('=') {
            let k = k.trim();
            let v = v.trim();
            if k.contains(' ') || k.is_empty() {
                continue;
            }
            if let Ok(num) = v.parse::<f64>() {
                out.insert(k.to_string(), num);
            }
        }
    }
    out
}

/// Extract the first full design embedded as `key = value` lines.
///
/// Parameter values are grid integers; a completion proposing `320.9`
/// cores or `-2` links is malformed, not "roughly 320". Truncating casts
/// used to silently round-trip such lines onto different designs (and
/// saturate negatives to 0), so only exact non-negative integers that
/// fit `u32` are accepted.
pub fn parse_design_lines(text: &str) -> Option<DesignPoint> {
    let a = parse_assignments(text);
    let mut values = [0u32; N_PARAMS];
    for p in Param::ALL {
        values[p.index()] = exact_u32(*a.get(p.name())?)?;
    }
    Some(DesignPoint::new(values))
}

/// `v` as a `u32` iff it is an exactly-representable non-negative
/// integer (rejects NaN/inf, fractions, negatives, and overflow).
fn exact_u32(v: f64) -> Option<u32> {
    if v.is_finite() && v >= 0.0 && v <= u32::MAX as f64 && v.fract() == 0.0
    {
        Some(v as u32)
    } else {
        None
    }
}

/// Extract a compact one-line design (`k=v k=v ...`).
pub fn parse_compact_design(line: &str) -> Option<DesignPoint> {
    let mut values = [0u32; N_PARAMS];
    let mut seen = 0;
    for tok in line.split_whitespace() {
        if let Some((k, v)) = tok.split_once('=') {
            if let (Some(p), Ok(num)) = (Param::by_name(k), v.parse::<u32>())
            {
                values[p.index()] = num;
                seen += 1;
            }
        }
    }
    if seen == N_PARAMS {
        Some(DesignPoint::new(values))
    } else {
        None
    }
}

/// Extract the choice lines `X) text` in letter order.
pub fn parse_choices(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        let mut chars = line.chars();
        if let (Some(l), Some(')')) = (chars.next(), chars.next()) {
            if l.is_ascii_uppercase() {
                let idx = (l as u8 - b'A') as usize;
                if idx == out.len() {
                    out.push(chars.as_str().trim().to_string());
                }
            }
        }
    }
    out
}

/// Extract the section body following a `## name` header.
pub fn parse_section<'a>(text: &'a str, name: &str) -> Option<&'a str> {
    let header = format!("## {name}");
    let start = text.find(&header)? + header.len();
    let rest = &text[start..];
    let rest = rest.strip_prefix('\n').unwrap_or(rest);
    let end = rest.find("\n## ").unwrap_or(rest.len());
    Some(&rest[..end])
}

/// Extract the answer letter from a completion ("Answer: B").
pub fn parse_answer_letter(completion: &str) -> Option<usize> {
    let at = completion.rfind("Answer:")?;
    completion[at + 7..]
        .trim_start()
        .chars()
        .next()
        .filter(|c| c.is_ascii_alphabetic())
        .map(|c| (c.to_ascii_uppercase() as u8 - b'A') as usize)
}

/// One "adjust: <param> <±n>" directive from a strategy completion.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Adjustment {
    pub param: Param,
    pub steps: i32,
}

/// Parse all adjustment directives from a strategy completion.
pub fn parse_adjustments(completion: &str) -> Vec<Adjustment> {
    let mut out = Vec::new();
    for line in completion.lines() {
        let line = line.trim();
        let Some(rest) = line.strip_prefix("adjust:") else {
            continue;
        };
        let mut toks = rest.split_whitespace();
        let (Some(name), Some(delta)) = (toks.next(), toks.next()) else {
            continue;
        };
        let Some(param) = Param::by_name(name) else {
            continue;
        };
        let delta = delta.trim_start_matches('+');
        if let Ok(steps) = delta.parse::<i32>() {
            if steps != 0 {
                out.push(Adjustment { param, steps });
            }
        }
    }
    out
}

/// Extract `metric = value` example rows:
/// `config: k=v ...  -> metric = 12.3`.
pub fn parse_example_rows(text: &str) -> Vec<(DesignPoint, f64)> {
    let mut out = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        let Some(rest) = line.strip_prefix("config:") else {
            continue;
        };
        let Some((cfg, metric)) = rest.split_once("->") else {
            continue;
        };
        let Some(d) = parse_compact_design(cfg.trim()) else {
            continue;
        };
        if let Some((_, v)) = metric.split_once('=') {
            if let Ok(num) = v.trim().parse::<f64>() {
                out.push((d, num));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::llm::prompts;

    #[test]
    fn assignments_and_design_roundtrip() {
        let text = prompts::render_design(&DesignPoint::a100());
        let d = parse_design_lines(&text).unwrap();
        assert_eq!(d, DesignPoint::a100());
    }

    #[test]
    fn design_lines_reject_non_integral_values() {
        // Regression: `320.9` used to truncate to 320 and round-trip
        // onto a different design instead of being rejected.
        let mut text = prompts::render_design(&DesignPoint::a100());
        text = text.replace("core_count = 108", "core_count = 320.9");
        assert!(text.contains("320.9"), "fixture drifted: {text}");
        assert_eq!(parse_design_lines(&text), None);
    }

    #[test]
    fn design_lines_reject_negative_and_non_finite_values() {
        let base = prompts::render_design(&DesignPoint::a100());
        for bad in ["-2", "-0.5", "NaN", "inf", "4294967296"] {
            let text = base
                .replace("interconnect_link_count = 12",
                         &format!("interconnect_link_count = {bad}"));
            assert_ne!(text, base, "fixture drifted");
            assert_eq!(
                parse_design_lines(&text),
                None,
                "accepted {bad}"
            );
        }
    }

    #[test]
    fn design_lines_accept_exact_grid_integers_only() {
        assert_eq!(exact_u32(320.0), Some(320));
        assert_eq!(exact_u32(0.0), Some(0));
        assert_eq!(exact_u32(u32::MAX as f64), Some(u32::MAX));
        assert_eq!(exact_u32(320.9), None);
        assert_eq!(exact_u32(-1.0), None);
        assert_eq!(exact_u32(f64::NAN), None);
        assert_eq!(exact_u32(f64::INFINITY), None);
        assert_eq!(exact_u32(u32::MAX as f64 + 1.0), None);
    }

    #[test]
    fn compact_design_roundtrip() {
        let line = prompts::compact_design(&DesignPoint::paper_design_a());
        assert_eq!(
            parse_compact_design(&line),
            Some(DesignPoint::paper_design_a())
        );
        assert_eq!(parse_compact_design("core_count=4"), None);
    }

    #[test]
    fn choices_extracted_in_order() {
        let text = "junk\nA) first\nB) second\nC) third\nAnswer...\n";
        assert_eq!(parse_choices(text), vec!["first", "second", "third"]);
    }

    #[test]
    fn sections_split_on_headers() {
        let text = "## One\nalpha\nbeta\n## Two\ngamma\n";
        assert_eq!(parse_section(text, "One").unwrap(), "alpha\nbeta");
        assert_eq!(parse_section(text, "Two").unwrap(), "gamma\n");
        assert!(parse_section(text, "Three").is_none());
    }

    #[test]
    fn answer_letter_last_wins() {
        assert_eq!(parse_answer_letter("thinking... Answer: C"), Some(2));
        assert_eq!(
            parse_answer_letter("Answer: A\nwait no\nAnswer: D"),
            Some(3)
        );
        assert_eq!(parse_answer_letter("no answer here"), None);
    }

    #[test]
    fn adjustments_parse_signed_steps() {
        let c = "rationale...\nadjust: memory_channel_count +1\n\
                 adjust: core_count -2\nadjust: bogus_param +1\n";
        let a = parse_adjustments(c);
        assert_eq!(a.len(), 2);
        assert_eq!(a[0].param, Param::MemChannels);
        assert_eq!(a[0].steps, 1);
        assert_eq!(a[1].param, Param::Cores);
        assert_eq!(a[1].steps, -2);
    }

    #[test]
    fn example_rows_parse() {
        let line = format!(
            "config: {}  -> area_mm2 = 833.9700\n",
            prompts::compact_design(&DesignPoint::a100())
        );
        let rows = parse_example_rows(&line);
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].0, DesignPoint::a100());
        assert!((rows[0].1 - 833.97).abs() < 1e-9);
    }
}
