//! Per-model capability profiles for the simulated analyst.
//!
//! Each profile sets the probability of the paper's observed failure
//! modes, separately for default and enhanced prompts. The rates are
//! calibrated so the DSE-Benchmark accuracies land on Table 3 (the
//! calibration test in `bench_dse::runner` asserts a ±0.06 band):
//!
//! | task                | Phi-4       | Qwen-3      | Llama-3.1   |
//! |---------------------|-------------|-------------|-------------|
//! | bottleneck analysis | 0.70 / 0.76 | 0.73 / 0.80 | 0.47 / 0.53 |
//! | perf/area predict   | 0.42 / 0.61 | 0.59 / 0.82 | 0.23 / 0.39 |
//! | parameter tuning    | 0.30 / 0.48 | 0.40 / 0.63 | 0.26 / 0.46 |

/// Error-mode rates for one prompt configuration.
#[derive(Debug, Clone, Copy)]
pub struct ErrorRates {
    /// Bottleneck task: picks a multi-resource distractor containing
    /// irrelevant parameters.
    pub multi_resource: f64,
    /// Bottleneck task: fails to see systolic-array over-provisioning
    /// (answers "increase" when utilization is the problem).
    pub systolic_blindness: f64,
    /// Prediction task: computes deltas against a zero baseline instead
    /// of the sensitivity reference.
    pub zero_baseline: f64,
    /// Prediction task: generic arithmetic slip (picks adjacent choice).
    pub arithmetic_slip: f64,
    /// Tuning task: compensates via many non-critical adjustments.
    pub multi_adjust: f64,
    /// Tuning task: ignores the stated constraint.
    pub constraint_blind: f64,
}

/// A named model profile (default + enhanced rates).
#[derive(Debug, Clone, Copy)]
pub struct ModelProfile {
    pub name: &'static str,
    pub default: ErrorRates,
    pub enhanced: ErrorRates,
}

impl ModelProfile {
    pub fn rates(&self, enhanced: bool) -> &ErrorRates {
        if enhanced {
            &self.enhanced
        } else {
            &self.default
        }
    }

    /// Qwen3-Next-80B-A3B-Instruct — the strongest of the three.
    pub fn qwen3() -> ModelProfile {
        ModelProfile {
            name: "qwen3",
            default: ErrorRates {
                multi_resource: 0.27,
                systolic_blindness: 0.45,
                zero_baseline: 0.45,
                arithmetic_slip: 0.15,
                multi_adjust: 0.59,
                constraint_blind: 0.42,
            },
            enhanced: ErrorRates {
                multi_resource: 0.20,
                systolic_blindness: 0.30,
                zero_baseline: 0.04,
                arithmetic_slip: 0.07,
                multi_adjust: 0.20,
                constraint_blind: 0.26,
            },
        }
    }

    /// Phi-4-reasoning.
    pub fn phi4() -> ModelProfile {
        ModelProfile {
            name: "phi4",
            default: ErrorRates {
                multi_resource: 0.30,
                systolic_blindness: 0.50,
                zero_baseline: 0.52,
                arithmetic_slip: 0.28,
                multi_adjust: 0.62,
                constraint_blind: 0.50,
            },
            enhanced: ErrorRates {
                multi_resource: 0.24,
                systolic_blindness: 0.40,
                zero_baseline: 0.16,
                arithmetic_slip: 0.22,
                multi_adjust: 0.34,
                constraint_blind: 0.33,
            },
        }
    }

    /// Llama-3.1-8B-Instruct — the weakest.
    pub fn llama31() -> ModelProfile {
        ModelProfile {
            name: "llama3.1",
            default: ErrorRates {
                multi_resource: 0.53,
                systolic_blindness: 0.75,
                zero_baseline: 0.78,
                arithmetic_slip: 0.58,
                multi_adjust: 0.65,
                constraint_blind: 0.53,
            },
            enhanced: ErrorRates {
                multi_resource: 0.47,
                systolic_blindness: 0.65,
                zero_baseline: 0.42,
                arithmetic_slip: 0.42,
                multi_adjust: 0.36,
                constraint_blind: 0.39,
            },
        }
    }

    pub fn by_name(name: &str) -> Option<ModelProfile> {
        match name {
            "qwen3" => Some(Self::qwen3()),
            "phi4" => Some(Self::phi4()),
            "llama3.1" | "llama31" => Some(Self::llama31()),
            "oracle" => Some(Self::oracle()),
            _ => None,
        }
    }

    /// An error-free profile (upper bound / unit tests).
    pub fn oracle() -> ModelProfile {
        let zero = ErrorRates {
            multi_resource: 0.0,
            systolic_blindness: 0.0,
            zero_baseline: 0.0,
            arithmetic_slip: 0.0,
            multi_adjust: 0.0,
            constraint_blind: 0.0,
        };
        ModelProfile { name: "oracle", default: zero, enhanced: zero }
    }

    pub const EVALUATED: [&'static str; 3] = ["phi4", "qwen3", "llama3.1"];
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_by_name() {
        for n in ModelProfile::EVALUATED {
            assert_eq!(ModelProfile::by_name(n).unwrap().name, n);
        }
        assert!(ModelProfile::by_name("gpt-oss").is_none());
    }

    #[test]
    fn enhanced_rates_never_worse_on_rule_covered_modes() {
        for n in ModelProfile::EVALUATED {
            let p = ModelProfile::by_name(n).unwrap();
            assert!(p.enhanced.multi_resource <= p.default.multi_resource);
            assert!(p.enhanced.zero_baseline <= p.default.zero_baseline);
            assert!(p.enhanced.multi_adjust <= p.default.multi_adjust);
            assert!(
                p.enhanced.systolic_blindness
                    <= p.default.systolic_blindness
            );
        }
    }

    #[test]
    fn qwen_is_strongest_llama_weakest() {
        let q = ModelProfile::qwen3();
        let l = ModelProfile::llama31();
        assert!(q.default.multi_resource < l.default.multi_resource);
        assert!(q.default.zero_baseline < l.default.zero_baseline);
    }

    #[test]
    fn oracle_is_error_free() {
        let o = ModelProfile::oracle();
        assert_eq!(o.default.multi_resource, 0.0);
        assert_eq!(o.enhanced.multi_adjust, 0.0);
    }
}
