//! Prompt templates: the textual interface between LUMINA / the DSE
//! Benchmark and the language model.
//!
//! Prompts are deliberately structured (`## section` headers, `key =
//! value` lines) — the same shape the paper's Figure 3 examples use — so
//! both a hosted LLM and the simulated analyst can consume them, and so
//! `parse.rs` can extract the fields back out.

use crate::design::{DesignPoint, Param};
use crate::eval::{Metrics, Phase};
use crate::workload::WorkloadSpec;

/// The default system prompt: provides the architectural context the
/// paper says "already provides the necessary architectural context".
pub const SYSTEM_DEFAULT: &str = "\
You are a GPU architecture design assistant.
The design space of one GPU in an 8-GPU tensor-parallel node:
  interconnect_link_count in {6, 12, 18, 24}   (NVLink-class links)
  core_count in {1..256}                       (streaming multiprocessors)
  sublane_count in {1, 2, 4, 8}                (processing blocks per core)
  systolic_array_dim in {4..128}               (square tensor-unit, per sublane)
  vector_width in {4..128}                     (fp16 lanes per sublane)
  sram_kb in {32..1024}                        (per-core scratchpad)
  global_buffer_mb in {32..1024}               (shared L2)
  memory_channel_count in {1..12}              (HBM stacks, 408 GB/s each)
Peak tensor throughput scales with core_count * sublane_count *
systolic_array_dim^2; vector throughput with core_count * sublane_count *
vector_width; memory bandwidth with memory_channel_count; allreduce
bandwidth with interconnect_link_count. Die area grows with every
resource. TTFT is the prefill latency, TPOT the per-output-token decode
latency; both are to be minimized together with area.
Answer multiple-choice questions with a line 'Answer: <letter>'.";

/// The paper's corrective rules (§5.2), appended for the *enhanced*
/// configuration. The simulated analyst detects the `RULE n:` markers.
pub const ENHANCED_RULES: &str = "\
RULE 1: When mitigating a stall, adjust ONLY the single parameter most
correlated with the dominant bottleneck; never bundle unrelated resources.
RULE 2: Compute prediction deltas relative to the stated sensitivity
reference configuration, never against a zero baseline.
RULE 3: When a dominant bottleneck remains unresolved, adjust only the
least critical resource to fund it; do not compensate by tweaking many
non-critical resources.
RULE 4: Enlarging the systolic array dimension reduces utilization for
small-M (decode) matmuls; prefer balanced dims unless prefill-bound.";

/// System prompt for the enhanced configuration.
pub fn system_enhanced() -> String {
    format!("{SYSTEM_DEFAULT}\n\n{ENHANCED_RULES}")
}

/// True if a system prompt carries the corrective rules.
pub fn has_enhanced_rules(system: &str) -> bool {
    system.contains("RULE 1:")
}

/// The area-model source snippet quoted in perf/area-prediction prompts
/// (the paper gives models "the source code of the area model").
pub const AREA_MODEL_SOURCE: &str = "\
fn core_area_mm2(d) =
    1.5 /* base */
    + sublane_count * (systolic_array_dim^2 * 0.0004
                       + vector_width * 0.012)
    + 1.1 /* regfile */ + sram_kb * 0.0055
fn area_mm2(d) =
    core_count * core_area_mm2(d)
    + global_buffer_mb * 1.9 + memory_channel_count * 15.0
    + interconnect_link_count * 1.5 + 60.0 /* uncore */";

/// One-line target-application description rendered into benchmark
/// prompts — derived from the actual workload the ground truth is
/// simulated under, so a model never reasons about a different model
/// shape than it is scored against.
pub fn describe_workload(w: &WorkloadSpec) -> String {
    format!(
        "one transformer layer: d_model {}, {} heads ({} KV), d_ffn {}, \
         {}-way tensor parallel, batch {}, prefill {}, decode@{}",
        w.d_model,
        w.n_heads,
        w.n_kv_heads,
        w.d_ffn,
        w.tp,
        w.batch,
        w.prefill_seq,
        w.decode_pos,
    )
}

/// Render a design's parameters as `key = value` lines.
pub fn render_design(d: &DesignPoint) -> String {
    let mut out = String::new();
    for p in Param::ALL {
        out.push_str(&format!("{} = {}\n", p.name(), d.get(p)));
    }
    out
}

/// Render per-component stall counters for a phase.
pub fn render_stalls(m: &Metrics, phase: Phase) -> String {
    let s = &m.stalls[phase.index()];
    format!(
        "compute_stall_ms = {:.4}\nmemory_stall_ms = {:.4}\n\
         network_stall_ms = {:.4}\n",
        s[0], s[1], s[2]
    )
}

/// Render a multiple-choice block. `choices` are already formatted.
pub fn render_choices(choices: &[String]) -> String {
    let mut out = String::new();
    for (i, c) in choices.iter().enumerate() {
        out.push_str(&format!("{}) {}\n", letter(i), c));
    }
    out.push_str("Answer with 'Answer: <letter>'.\n");
    out
}

pub fn letter(i: usize) -> char {
    (b'A' + i as u8) as char
}

pub fn letter_index(c: char) -> Option<usize> {
    let c = c.to_ascii_uppercase();
    if c.is_ascii_uppercase() {
        Some((c as u8 - b'A') as usize)
    } else {
        None
    }
}

/// Bottleneck-analysis question (benchmark task 1).
pub fn bottleneck_question(
    w: &WorkloadSpec,
    d: &DesignPoint,
    m: &Metrics,
    phase: Phase,
    choices: &[String],
) -> String {
    format!(
        "## Task: bottleneck-analysis\n\
         ## Target application\n{}\n\
         ## Architecture\n{}\
         ## Objective\nminimize {}\n\
         ## Performance counters ({} phase)\n{}\
         ## Question\nWhich parameter adjustment most directly mitigates \
         the dominant stall?\n{}",
        describe_workload(w),
        render_design(d),
        m_name(phase),
        phase_name(phase),
        render_stalls(m, phase),
        render_choices(choices),
    )
}

/// Perf/area-prediction question (benchmark task 2).
#[allow(clippy::too_many_arguments)]
pub fn prediction_question(
    metric: &str,
    reference: &DesignPoint,
    reference_value: f64,
    examples: &[(DesignPoint, f64)],
    target: &DesignPoint,
    include_area_source: bool,
    choices: &[String],
) -> String {
    let mut ex = String::new();
    for (d, v) in examples {
        ex.push_str(&format!(
            "config: {}  -> {metric} = {v:.4}\n",
            compact_design(d)
        ));
    }
    format!(
        "## Task: perf-area-prediction\n\
         {}\
         ## Sensitivity reference\nconfig: {}  -> {metric} = {:.4}\n\
         ## Observed examples\n{}\
         ## Question\nPredict {metric} for config: {}\n{}",
        if include_area_source {
            format!("## Area model source\n{AREA_MODEL_SOURCE}\n")
        } else {
            String::new()
        },
        compact_design(reference),
        reference_value,
        ex,
        compact_design(target),
        render_choices(choices),
    )
}

/// Parameter-tuning question (benchmark task 3). Choices are full
/// candidate configs rendered with `compact_design`.
pub fn tuning_question(
    initial: &DesignPoint,
    m: &Metrics,
    phase: Phase,
    area_budget_mm2: f64,
    choices: &[String],
) -> String {
    format!(
        "## Task: parameter-tuning\n\
         ## Initial design\n{}\
         ## Initial counters ({} phase)\n{}\
         ## Constraint\narea_mm2 <= {:.1}\n\
         ## Objective\nminimize {}\n\
         ## Question\nWhich candidate best achieves the objective while \
         meeting the constraint?\n{}",
        render_design(initial),
        phase_name(phase),
        render_stalls(m, phase),
        area_budget_mm2,
        m_name(phase),
        render_choices(choices),
    )
}

/// One-line design rendering used inside example/candidate rows.
pub fn compact_design(d: &DesignPoint) -> String {
    Param::ALL
        .iter()
        .map(|p| format!("{}={}", p.name(), d.get(*p)))
        .collect::<Vec<_>>()
        .join(" ")
}

fn m_name(phase: Phase) -> &'static str {
    phase.metric_name()
}

fn phase_name(phase: Phase) -> &'static str {
    match phase {
        Phase::Prefill => "prefill",
        Phase::Decode => "decode",
    }
}

/// LUMINA Strategy-Engine request: critical path + influence map +
/// trajectory reflection, asking for a mitigation directive.
///
/// `power` is `Some((avg_power_w, power_headroom_w))` only in the
/// `ppa` objective mode: it renders the power column into the metrics
/// section. `None` (latency-area) produces the historical prompt
/// byte-for-byte, which is what keeps default-mode LLM trajectories
/// pinned.
#[allow(clippy::too_many_arguments)]
pub fn strategy_request(
    d: &DesignPoint,
    m: &Metrics,
    phase: Phase,
    critical_path: &str,
    influence: &str,
    reflection: &str,
    area_headroom_mm2: f64,
    power: Option<(f64, f64)>,
) -> String {
    let power_lines = match power {
        Some((avg_w, headroom_w)) => format!(
            "avg_power_w = {avg_w:.2}\n\
             energy_per_token_mj = {:.4}\n\
             power_headroom_w = {headroom_w:.2}\n",
            m.energy_per_token_mj,
        ),
        None => String::new(),
    };
    format!(
        "## Task: bottleneck-mitigation-strategy\n\
         ## Current design\n{}\
         ## Current metrics\nTTFT_ms = {:.4}\nTPOT_ms = {:.4}\n\
         area_mm2 = {:.2}\narea_headroom_mm2 = {:.2}\n{}\
         ## Optimization target\nminimize {}\n\
         ## Critical path\n{}\
         ## Architectural heuristic knowledge (influence factors)\n{}\
         ## Trajectory reflection\n{}\
         ## Instruction\nPropose grid-step adjustments as lines \
         'adjust: <parameter> <+1|+2|-1|-2>'. Mitigate only the dominant \
         bottleneck (RULE 1); fund area by shrinking only the least \
         critical resource (RULE 3).\n",
        render_design(d),
        m.ttft_ms,
        m.tpot_ms,
        m.area_mm2,
        area_headroom_mm2,
        power_lines,
        m_name(phase),
        critical_path,
        influence,
        reflection,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn metrics() -> Metrics {
        Metrics {
            ttft_ms: 36.7,
            tpot_ms: 0.44,
            area_mm2: 834.0,
            stalls: [[26.79, 3.63, 6.28], [0.0, 0.43, 0.02]],
            ..Default::default()
        }
    }

    #[test]
    fn bottleneck_prompt_contains_fields() {
        let q = bottleneck_question(
            &crate::workload::GPT3_175B,
            &DesignPoint::a100(),
            &metrics(),
            Phase::Prefill,
            &["increase core_count".into(), "increase sram_kb".into()],
        );
        assert!(q.contains("core_count = 108"));
        assert!(q.contains("compute_stall_ms = 26.7900"));
        assert!(q.contains("A) increase core_count"));
        assert!(q.contains("minimize TTFT"));
        assert!(q.contains("d_model 12288"));
    }

    #[test]
    fn workload_description_tracks_the_simulated_scenario() {
        let w = crate::workload::spec_by_name("llama-70b").unwrap();
        let q = bottleneck_question(
            &w,
            &DesignPoint::a100(),
            &metrics(),
            Phase::Decode,
            &["increase memory_channel_count".into()],
        );
        assert!(q.contains("d_model 8192"));
        assert!(q.contains("64 heads (8 KV)"));
        assert!(!q.contains("12288"));
    }

    #[test]
    fn enhanced_rules_detectable() {
        assert!(!has_enhanced_rules(SYSTEM_DEFAULT));
        assert!(has_enhanced_rules(&system_enhanced()));
    }

    #[test]
    fn letters_roundtrip() {
        for i in 0..6 {
            assert_eq!(letter_index(letter(i)), Some(i));
        }
    }

    #[test]
    fn compact_design_is_single_line() {
        let s = compact_design(&DesignPoint::a100());
        assert!(!s.contains('\n'));
        assert!(s.contains("memory_channel_count=5"));
    }

    #[test]
    fn strategy_request_mentions_rules_and_headroom() {
        let q = strategy_request(
            &DesignPoint::a100(),
            &metrics(),
            Phase::Prefill,
            "cp",
            "inf",
            "none",
            120.0,
            None,
        );
        assert!(q.contains("area_headroom_mm2 = 120.00"));
        assert!(q.contains("RULE 1") && q.contains("RULE 3"));
        // Latency-area prompts carry no power column.
        assert!(!q.contains("avg_power_w"));
    }

    #[test]
    fn strategy_request_renders_power_column_in_ppa_mode() {
        let q = strategy_request(
            &DesignPoint::a100(),
            &metrics(),
            Phase::Prefill,
            "cp",
            "inf",
            "none",
            120.0,
            Some((219.59, 35.5)),
        );
        assert!(q.contains("avg_power_w = 219.59"));
        assert!(q.contains("power_headroom_w = 35.50"));
        assert!(q.contains("energy_per_token_mj"));
    }
}
