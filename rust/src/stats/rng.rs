//! PCG32 pseudo-random generator (O'Neill 2014), seeded and portable.
//!
//! Every stochastic component in the system — baseline optimizers, the
//! simulated-LLM error models, benchmark question sampling — draws from
//! this generator so whole experiments replay bit-identically from a seed,
//! which is what makes the paper's "multiple independent trials" figures
//! reproducible.

/// PCG-XSH-RR 64/32.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Seed with the default stream.
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e39cb94b95bdb)
    }

    /// Seed with an explicit stream id (distinct streams are independent).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let inc = (stream << 1) | 1;
        let mut rng = Self { state: 0, inc };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Derive an independent child generator (for per-trial seeding).
    pub fn fork(&mut self, tag: u64) -> Pcg32 {
        let seed = (self.next_u64()).wrapping_add(tag.wrapping_mul(MULT));
        Pcg32::with_stream(seed, tag.wrapping_add(1))
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u32() as f64) / (u32::MAX as f64 + 1.0)
    }

    /// Uniform usize in [lo, hi) — hi exclusive, unbiased via rejection.
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo, "empty range [{lo}, {hi})");
        let span = (hi - lo) as u64;
        // Lemire-style rejection sampling on 32-bit draws.
        loop {
            let x = self.next_u64() % span;
            // span <= 2^53 in practice; modulo bias negligible for the
            // design-space sizes here, but keep a simple rejection guard
            // for exactness on power-of-two-adjacent spans.
            if span.is_power_of_two() || x < span {
                return lo + x as usize;
            }
        }
    }

    /// Pick a random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range_usize(0, xs.len())]
    }

    /// Bernoulli draw.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt()
            * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range_usize(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut rng = Pcg32::new(3);
        let n = 10_000;
        let mean: f64 =
            (0..n).map(|_| rng.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn range_respects_bounds_and_covers() {
        let mut rng = Pcg32::new(4);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.range_usize(3, 13);
            assert!((3..13).contains(&v));
            seen[v - 3] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_has_zero_mean_unit_var() {
        let mut rng = Pcg32::new(5);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
                / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::new(6);
        let mut v: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg32::new(7);
        let idx = rng.sample_indices(100, 30);
        let mut dedup = idx.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), 30);
    }

    #[test]
    fn forked_streams_are_independent() {
        let mut root = Pcg32::new(9);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }
}
