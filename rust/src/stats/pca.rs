//! Principal Component Analysis via Jacobi eigendecomposition.
//!
//! Used to reproduce the paper's Figure 1 (design-space embedding) and
//! Figure 6 (ACO-vs-LUMINA search-pattern trajectories): design vectors are
//! standardized, the covariance matrix is eigendecomposed with cyclic
//! Jacobi rotations (dimensions here are 8, so exactness beats speed), and
//! points are projected onto the top-k components.

/// A fitted PCA model.
#[derive(Debug, Clone)]
pub struct Pca {
    pub mean: Vec<f64>,
    pub scale: Vec<f64>,
    /// Principal axes, row-major `[k][d]`, ordered by decreasing variance.
    pub components: Vec<Vec<f64>>,
    /// Explained variance per retained component.
    pub explained: Vec<f64>,
}

impl Pca {
    /// Fit on `data` (n rows x d columns), retaining `k` components.
    /// Columns are standardized (z-score) before the eigendecomposition so
    /// heterogeneous design parameters (2..1024 ranges) contribute evenly.
    pub fn fit(data: &[Vec<f64>], k: usize) -> Pca {
        let n = data.len();
        assert!(n >= 2, "PCA needs at least two rows");
        let d = data[0].len();
        assert!(k <= d);

        let mut mean = vec![0.0; d];
        for row in data {
            for (m, v) in mean.iter_mut().zip(row) {
                *m += v;
            }
        }
        for m in &mut mean {
            *m /= n as f64;
        }
        let mut scale = vec![0.0; d];
        for row in data {
            for j in 0..d {
                let c = row[j] - mean[j];
                scale[j] += c * c;
            }
        }
        for s in &mut scale {
            *s = (*s / (n - 1) as f64).sqrt();
            if *s < 1e-12 {
                *s = 1.0; // constant column: leave centered at zero
            }
        }

        // Covariance of standardized data.
        let mut cov = vec![vec![0.0; d]; d];
        for row in data {
            let z: Vec<f64> = (0..d)
                .map(|j| (row[j] - mean[j]) / scale[j])
                .collect();
            for i in 0..d {
                for j in i..d {
                    cov[i][j] += z[i] * z[j];
                }
            }
        }
        for i in 0..d {
            for j in i..d {
                cov[i][j] /= (n - 1) as f64;
                cov[j][i] = cov[i][j];
            }
        }

        let (eigvals, eigvecs) = jacobi_eigen(cov);
        // Sort by descending eigenvalue.
        let mut order: Vec<usize> = (0..d).collect();
        order.sort_by(|&a, &b| eigvals[b].total_cmp(&eigvals[a]));

        let components: Vec<Vec<f64>> = order[..k]
            .iter()
            .map(|&c| (0..d).map(|r| eigvecs[r][c]).collect())
            .collect();
        let explained =
            order[..k].iter().map(|&c| eigvals[c].max(0.0)).collect();

        Pca { mean, scale, components, explained }
    }

    /// Project one row onto the retained components.
    pub fn transform(&self, row: &[f64]) -> Vec<f64> {
        let z: Vec<f64> = row
            .iter()
            .zip(&self.mean)
            .zip(&self.scale)
            .map(|((v, m), s)| (v - m) / s)
            .collect();
        self.components
            .iter()
            .map(|c| c.iter().zip(&z).map(|(a, b)| a * b).sum())
            .collect()
    }

    /// Fraction of total variance captured by the retained components.
    pub fn explained_ratio(&self) -> f64 {
        let d = self.mean.len() as f64;
        self.explained.iter().sum::<f64>() / d
    }
}

/// Cyclic Jacobi eigendecomposition of a symmetric matrix.
/// Returns (eigenvalues, eigenvectors-as-columns).
fn jacobi_eigen(mut a: Vec<Vec<f64>>) -> (Vec<f64>, Vec<Vec<f64>>) {
    let d = a.len();
    let mut v = vec![vec![0.0; d]; d];
    for (i, row) in v.iter_mut().enumerate() {
        row[i] = 1.0;
    }
    for _sweep in 0..64 {
        let mut off = 0.0;
        for i in 0..d {
            for j in i + 1..d {
                off += a[i][j] * a[i][j];
            }
        }
        if off < 1e-20 {
            break;
        }
        for p in 0..d {
            for q in p + 1..d {
                if a[p][q].abs() < 1e-18 {
                    continue;
                }
                let theta = (a[q][q] - a[p][p]) / (2.0 * a[p][q]);
                let t = theta.signum()
                    / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                for k in 0..d {
                    let akp = a[k][p];
                    let akq = a[k][q];
                    a[k][p] = c * akp - s * akq;
                    a[k][q] = s * akp + c * akq;
                }
                for k in 0..d {
                    let apk = a[p][k];
                    let aqk = a[q][k];
                    a[p][k] = c * apk - s * aqk;
                    a[q][k] = s * apk + c * aqk;
                }
                for k in 0..d {
                    let vkp = v[k][p];
                    let vkq = v[k][q];
                    v[k][p] = c * vkp - s * vkq;
                    v[k][q] = s * vkp + c * vkq;
                }
            }
        }
    }
    let vals = (0..d).map(|i| a[i][i]).collect();
    (vals, v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::rng::Pcg32;

    #[test]
    fn identity_covariance_eigenvalues_near_one() {
        let mut rng = Pcg32::new(1);
        let data: Vec<Vec<f64>> = (0..4000)
            .map(|_| (0..4).map(|_| rng.normal()).collect())
            .collect();
        let pca = Pca::fit(&data, 4);
        for e in &pca.explained {
            assert!((e - 1.0).abs() < 0.12, "eig={e}");
        }
    }

    #[test]
    fn recovers_dominant_direction() {
        // Points along (1,1)/sqrt(2) with small orthogonal noise.
        let mut rng = Pcg32::new(2);
        let data: Vec<Vec<f64>> = (0..2000)
            .map(|_| {
                let t = rng.normal() * 10.0;
                let n = rng.normal() * 0.1;
                vec![t + n, t - n]
            })
            .collect();
        let pca = Pca::fit(&data, 2);
        let c = &pca.components[0];
        // After standardization, dominant axis is (±1/√2, ±1/√2).
        assert!((c[0].abs() - 0.7071).abs() < 0.02, "{c:?}");
        assert!((c[1].abs() - 0.7071).abs() < 0.02, "{c:?}");
        assert!(pca.explained[0] > pca.explained[1] * 50.0);
    }

    #[test]
    fn transform_centers_the_mean() {
        let data =
            vec![vec![1.0, 10.0], vec![3.0, 30.0], vec![5.0, 50.0]];
        let pca = Pca::fit(&data, 2);
        let proj = pca.transform(&[3.0, 30.0]);
        assert!(proj.iter().all(|p| p.abs() < 1e-9), "{proj:?}");
    }

    #[test]
    fn constant_columns_do_not_blow_up() {
        let data: Vec<Vec<f64>> =
            (0..10).map(|i| vec![i as f64, 7.0]).collect();
        let pca = Pca::fit(&data, 2);
        let proj = pca.transform(&[4.0, 7.0]);
        assert!(proj.iter().all(|p| p.is_finite()));
    }

    #[test]
    fn components_are_orthonormal() {
        let mut rng = Pcg32::new(3);
        let data: Vec<Vec<f64>> = (0..500)
            .map(|_| (0..6).map(|_| rng.f64() * 5.0).collect())
            .collect();
        let pca = Pca::fit(&data, 6);
        for i in 0..6 {
            for j in i..6 {
                let dot: f64 = pca.components[i]
                    .iter()
                    .zip(&pca.components[j])
                    .map(|(a, b)| a * b)
                    .sum();
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 1e-6, "({i},{j}) dot={dot}");
            }
        }
    }
}
