//! Statistics substrate: seeded RNG, PCA (for Fig. 1/6 embeddings), and
//! scalar summaries. Implemented from scratch — no `rand`/`ndarray`
//! offline.

pub mod pca;
pub mod rng;
pub mod summary;

pub use pca::Pca;
pub use rng::Pcg32;
pub use summary::Summary;
