//! Scalar summaries (mean/std/min/max/percentiles) used in figure output
//! and the multi-trial variance reporting of Fig. 4/5.

/// Summary statistics over a sample of f64 values.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
}

impl Summary {
    pub fn of(values: &[f64]) -> Summary {
        assert!(!values.is_empty(), "summary of empty sample");
        let n = values.len();
        let mean = values.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            values.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>()
                / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = values.to_vec();
        sorted.sort_by(f64::total_cmp);
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median: percentile_sorted(&sorted, 50.0),
        }
    }

    /// Best-to-worst ratio (used for the paper's "ACO reaches 1.82x
    /// normalized PHV spread" observation).
    pub fn spread_ratio(&self) -> f64 {
        if self.min.abs() < 1e-30 {
            f64::INFINITY
        } else {
            self.max / self.min
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted slice, p in [0, 100].
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let w = rank - lo as f64;
    sorted[lo] * (1.0 - w) + sorted[hi] * w
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.std - 1.2909944).abs() < 1e-6);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.median - 2.5).abs() < 1e-12);
    }

    #[test]
    fn single_element() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.median, 7.0);
    }

    #[test]
    fn percentiles_interpolate() {
        let sorted = [0.0, 10.0];
        assert!((percentile_sorted(&sorted, 25.0) - 2.5).abs() < 1e-12);
        assert_eq!(percentile_sorted(&sorted, 0.0), 0.0);
        assert_eq!(percentile_sorted(&sorted, 100.0), 10.0);
    }

    #[test]
    fn spread_ratio() {
        let s = Summary::of(&[2.0, 3.0, 4.0]);
        assert!((s.spread_ratio() - 2.0).abs() < 1e-12);
    }
}
