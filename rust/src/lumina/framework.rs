//! The LUMINA refinement loop (paper Figure 2) as an explicit ask/tell
//! state machine: Reference -> AhkAcquire -> Refine -> Expansion ->
//! Shrink. Each `ask` runs the cheap reasoning (bottleneck analysis,
//! LLM directive, materialization) and proposes the next design(s);
//! each `tell` folds the observed metrics into the Trajectory Memory,
//! the AHK, and the hill-climb acceptance state. The blanket
//! `DseMethod::run` drives the machine sequentially with trajectories
//! bit-identical to the pre-redesign blocking loop (pinned by the
//! golden tests in `crate::dse::golden`).

use crate::design::{DesignPoint, Param};
use crate::dse::{AskCtx, DseSession};
use crate::eval::Metrics;
use crate::llm::{LanguageModel, ModelProfile, SimulatedAnalyst};
use crate::pareto::ObjectiveMode;
use crate::stats::rng::Pcg32;

use super::explore::ExplorationEngine;
use super::memory::{FailedMove, TrajectoryMemory};
use super::quale::InfluenceMap;
use super::quane::Ahk;
use super::strategy::StrategyEngine;

/// LUMINA configuration.
#[derive(Debug, Clone)]
pub struct LuminaConfig {
    pub seed: u64,
    /// Backbone model profile (the DSE Benchmark selects qwen3).
    pub model: ModelProfile,
    /// Run the full (sample-spending) QuanE sensitivity study when the
    /// budget is at least this large; otherwise the cheap area-only mode.
    pub full_quane_threshold: usize,
    /// Area ceiling relative to the reference design.
    pub area_ceiling: f64,
    /// Objective mode. `LatencyArea` (the default) reproduces the
    /// historical trajectories bit-for-bit; `Ppa` adds the energy lane
    /// to hill-climb acceptance and arms the Strategy Engine's power
    /// envelope + prompt power column.
    pub objectives: ObjectiveMode,
    /// Power envelope relative to the reference design's static
    /// peak-power proxy ([`crate::arch::tdp_w`]); only enforced in
    /// `Ppa` mode (doubled during front expansion, like the area
    /// ceiling).
    pub power_ceiling: f64,
    /// Hill-climb patience before restarting from the best known point.
    pub patience: usize,
}

impl Default for LuminaConfig {
    fn default() -> Self {
        Self {
            seed: 0,
            model: ModelProfile::qwen3(),
            full_quane_threshold: 100,
            area_ceiling: 1.0,
            objectives: ObjectiveMode::LatencyArea,
            power_ceiling: 1.0,
            patience: 4,
        }
    }
}

/// The explicit phases of the LUMINA session.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum LuminaPhase {
    /// Evaluate the reference design (the initial point).
    Reference,
    /// QuanE sensitivity sweep (sample-spending when the budget allows).
    AhkAcquire,
    /// Dominate the reference within its area envelope.
    Refine,
    /// Expand the Pareto front toward the 2x-area PHV reference point.
    Expansion,
    /// AHK-guided area shrink along the least perf-critical axes.
    Shrink,
    /// Spend leftover budget on near-front perturbations.
    Fill,
}

/// What the last `ask` proposed — tells `tell` how to interpret the
/// results it receives.
enum Pending {
    None,
    Reference,
    Sweep { slots: Vec<(Param, i32, usize)> },
    Proposal { metric: usize, boost: Param, steps: i32 },
    RestartNudge,
    ShrinkProposal,
    ShrinkNudge,
    Fill,
}

/// Shrink-phase runtime (paper phase 3).
struct ShrinkState {
    rng: Pcg32,
    /// Smallest in-box design seen (the restart anchor).
    anchor: (DesignPoint, Metrics),
    current: (DesignPoint, Metrics),
}

/// Fill runtime: leftover-budget perturbations around the front.
struct FillState {
    rng: Pcg32,
}

/// The LUMINA optimizer.
pub struct Lumina {
    pub config: LuminaConfig,
    /// Ablation switch: drive the Strategy Engine with the *default*
    /// system prompt instead of the enhanced one AND without the SE's
    /// rule enforcement (the paper's corrective rules live in the SE;
    /// this is the "vanilla LLM agent" configuration).
    pub use_default_prompts: bool,
    /// Filled during the run: the acquired + refined AHK.
    pub ahk: Option<Ahk>,
    /// Filled during the run: the trajectory memory.
    pub tm: TrajectoryMemory,
    // ---- session runtime ----
    model: Option<SimulatedAnalyst>,
    ee: Option<ExplorationEngine>,
    phase: LuminaPhase,
    pending: Pending,
    reference: Option<(DesignPoint, Metrics)>,
    current: Option<(DesignPoint, Metrics)>,
    expansion: bool,
    best_score: f64,
    stale: usize,
    step: usize,
    /// Set by a stagnation restart in `tell`; the next ask draws the
    /// nudge axis (all RNG lives in ask — the D004/replay invariant).
    restart_pending: bool,
    shrink: Option<ShrinkState>,
    fill: Option<FillState>,
}

impl Lumina {
    pub fn new(config: LuminaConfig) -> Self {
        Self {
            config,
            use_default_prompts: false,
            ahk: None,
            tm: TrajectoryMemory::new(),
            model: None,
            ee: None,
            phase: LuminaPhase::Reference,
            pending: Pending::None,
            reference: None,
            current: None,
            expansion: false,
            best_score: f64::INFINITY,
            stale: 0,
            step: 0,
            restart_pending: false,
            shrink: None,
            fill: None,
        }
    }

    pub fn with_seed(seed: u64) -> Self {
        Self::new(LuminaConfig { seed, ..Default::default() })
    }

    /// Weighted normalized score used for hill-climb acceptance (lower is
    /// better). In the dominate-the-reference phase the area term is a
    /// hard-ish wall above 1.0x; in the front-expansion phase it trades
    /// off linearly (PHV counts volume up to the 2x reference point).
    /// In `Ppa` mode the normalized energy/token joins the sum (weight
    /// 0.5 — power trades against the latencies without dominating
    /// them); in the default mode the formula is unchanged.
    fn score(
        m: &Metrics,
        reference: &Metrics,
        expansion: bool,
        mode: ObjectiveMode,
    ) -> f64 {
        let nt = (m.ttft_ms / reference.ttft_ms) as f64;
        let nd = (m.tpot_ms / reference.tpot_ms) as f64;
        let na = (m.area_mm2 / reference.area_mm2) as f64;
        let base = if expansion {
            nt + nd + na
        } else {
            nt + nd + 0.5 * na.max(1.0) * 4.0 - 2.0
        };
        match mode {
            ObjectiveMode::LatencyArea => base,
            ObjectiveMode::Ppa => {
                // Guard against zero-energy pre-PPA references: the
                // lane becomes a constant (no acceptance effect)
                // instead of NaN-poisoning the hill climb.
                let ne = if reference.energy_per_token_mj > 0.0 {
                    (m.energy_per_token_mj
                        / reference.energy_per_token_mj)
                        as f64
                } else {
                    1.0
                };
                base + 0.5 * ne
            }
        }
    }

    /// ---- Refine/Expansion ask: phase transitions, then one directive
    /// -> materialized proposal.
    fn refine_ask(&mut self, ctx: &AskCtx) -> Vec<DesignPoint> {
        // A stagnation restart was flagged last tell: draw the nudge
        // axis now (the draw belongs in ask, not tell — rule D004; the
        // one-shot stream is keyed on `step`, which tell already
        // advanced, so the drawn axis is identical to the pre-lint
        // draw-at-tell behavior) and evaluate the nudged point unless
        // already visited.
        if std::mem::take(&mut self.restart_pending) {
            let mut rng =
                Pcg32::new(self.config.seed ^ self.step as u64);
            let p = *rng.choose(&Param::ALL);
            // lumina: allow(P001) phase invariant: Refine implies the reference tell ran
            let cur = self.current.expect("current set by reference").0;
            let nudged = ctx.space.step(&cur, p, 1);
            if !self.tm.contains(&nudged) {
                self.pending = Pending::RestartNudge;
                return vec![nudged];
            }
        }
        // Phase 3 (final 20% of large budgets): AHK-guided area shrink —
        // walk down the least perf-critical parameters while both
        // latencies stay inside the PHV reference box, populating the
        // low-area corner of the front that bottleneck-removal alone
        // never visits.
        if ctx.budget > 64 && ctx.spent() >= ctx.budget * 4 / 5 {
            self.enter_shrink();
            return self.shrink_ask(ctx);
        }
        if !self.expansion
            && ctx.spent() >= ctx.budget * 3 / 5
            && ctx.budget > 64
        {
            self.expansion = true;
            self.phase = LuminaPhase::Expansion;
            self.best_score = f64::INFINITY; // re-anchor acceptance
        }

        let cfg = self.config.clone();
        let (current, current_m) =
            // lumina: allow(P001) phase invariant: Refine implies the reference tell ran
            self.current.expect("current set by reference");
        let reference_m =
            // lumina: allow(P001) phase invariant: Refine implies the reference tell ran
            self.reference.expect("reference evaluated").1;
        let directive = {
            // lumina: allow(P001) phase invariant: AhkAcquire built the AHK before Refine
            let ahk = self.ahk.as_ref().expect("ahk acquired");
            // lumina: allow(P001) phase invariant: the Reference ask built the model
            let model = self.model.as_mut().expect("model built");
            let mut se =
                StrategyEngine::new(model as &mut dyn LanguageModel);
            if self.use_default_prompts {
                se.system_prompt =
                    crate::llm::prompts::SYSTEM_DEFAULT.to_string();
                se.enforce_rules = false;
            }
            se.area_ceiling = if self.expansion {
                2.0 * cfg.area_ceiling
            } else {
                cfg.area_ceiling
            };
            if cfg.objectives == ObjectiveMode::Ppa {
                // Power envelope relative to the reference design's
                // static proxy, doubled during expansion like area.
                let reference_design =
                    // lumina: allow(P001) phase invariant: Refine implies the reference tell ran
                    self.reference.expect("reference evaluated").0;
                let scale = if self.expansion { 2.0 } else { 1.0 };
                se.power_ceiling_w = scale
                    * cfg.power_ceiling
                    * crate::arch::tdp_w(&reference_design) as f64;
            }
            se.propose(
                ctx.space, &current, &current_m, &reference_m, ahk,
                &self.tm, None,
            )
        };
        let proposal = self
            .ee
            .as_mut()
            // lumina: allow(P001) phase invariant: the Reference ask built the engine
            .expect("ee built")
            .materialize(ctx.space, &current, &directive, &self.tm);
        self.pending = Pending::Proposal {
            metric: directive.phase.index(),
            boost: directive.boost.0,
            steps: directive.boost.1,
        };
        vec![proposal]
    }

    fn enter_shrink(&mut self) {
        // lumina: allow(P001) phase invariant: shrink starts after the reference tell
        let reference = self.reference.expect("reference evaluated");
        let anchor = self
            .tm
            .best_weighted(&reference.1.objectives(), &[1.0, 1.0, 2.0])
            .map(|s| (s.design, s.metrics))
            .unwrap_or((DesignPoint::a100(), reference.1));
        self.shrink = Some(ShrinkState {
            rng: Pcg32::with_stream(self.config.seed, 0x54),
            anchor,
            current: anchor,
        });
        self.step = self.tm.len();
        self.phase = LuminaPhase::Shrink;
    }

    /// ---- Shrink ask: the least perf-critical downward step from the
    /// current point (anchor restarts when a walk dead-ends).
    fn shrink_ask(&mut self, ctx: &AskCtx) -> Vec<DesignPoint> {
        enum Next {
            Proposal(DesignPoint),
            Nudge(DesignPoint),
            Fill,
        }
        let next = {
            // lumina: allow(P001) phase invariant: AhkAcquire precedes Shrink
            let ahk = self.ahk.as_ref().expect("ahk acquired");
            let tm = &self.tm;
            // lumina: allow(P001) phase invariant: enter_shrink set the state
            let st = self.shrink.as_mut().expect("shrink entered");
            // Least perf-critical downward step from the current point.
            let mut cands: Vec<Param> = Param::ALL
                .iter()
                .copied()
                .filter(|&p| {
                    ctx.space.step(&st.current.0, p, -1) != st.current.0
                })
                .collect();
            cands.sort_by(|&a, &b| {
                let crit = |p: Param| {
                    ahk.perf_influence(p, 0).abs()
                        + ahk.perf_influence(p, 1).abs()
                };
                crit(a).total_cmp(&crit(b))
            });
            match cands.first() {
                None => Next::Fill,
                Some(&p) => {
                    let next = ctx.space.step(&st.current.0, p, -1);
                    let proposal = if tm.contains(&next) {
                        // Nudge to an unvisited neighbour
                        // deterministically.
                        let q = *st.rng.choose(&cands);
                        ctx.space.step(&next, q, -1)
                    } else {
                        next
                    };
                    if tm.contains(&proposal) {
                        // Walk exhausted around here: restart from a
                        // fresh perf-leaning anchor.
                        st.current = st.anchor;
                        let q = *st.rng.choose(&Param::ALL);
                        let nudged =
                            ctx.space.step(&st.current.0, q, -1);
                        if tm.contains(&nudged) {
                            Next::Fill
                        } else {
                            Next::Nudge(nudged)
                        }
                    } else {
                        Next::Proposal(proposal)
                    }
                }
            }
        };
        match next {
            Next::Fill => self.enter_fill(ctx),
            Next::Nudge(d) => {
                self.pending = Pending::ShrinkNudge;
                vec![d]
            }
            Next::Proposal(d) => {
                self.pending = Pending::ShrinkProposal;
                vec![d]
            }
        }
    }

    /// ---- Fill: spend any leftover budget on unvisited near-front
    /// perturbations so every method consumes exactly its sample
    /// budget.
    fn enter_fill(&mut self, ctx: &AskCtx) -> Vec<DesignPoint> {
        self.fill = Some(FillState {
            rng: Pcg32::with_stream(self.config.seed, 0xf111),
        });
        self.step = self.tm.len();
        self.phase = LuminaPhase::Fill;
        self.fill_ask(ctx)
    }

    fn fill_ask(&mut self, ctx: &AskCtx) -> Vec<DesignPoint> {
        let (reference_design, reference_m) =
            // lumina: allow(P001) phase invariant: Fill starts after the reference tell
            self.reference.expect("reference evaluated");
        let d = {
            let tm = &self.tm;
            // lumina: allow(P001) phase invariant: enter_fill set the state
            let st = self.fill.as_mut().expect("fill entered");
            let anchor = tm
                .best_weighted(
                    &reference_m.objectives(),
                    &[1.0, 1.0, 1.0 + st.rng.f64()],
                )
                .map(|s| s.design)
                .unwrap_or(reference_design);
            let mut d = anchor;
            for _ in 0..1 + st.rng.range_usize(0, 3) {
                let p = *st.rng.choose(&Param::ALL);
                let delta = if st.rng.chance(0.5) { 1 } else { -1 };
                d = ctx.space.step(&d, p, delta);
            }
            if tm.contains(&d) {
                d = crate::design::sample::uniform(
                    ctx.space,
                    &mut st.rng,
                );
            }
            d
        };
        self.pending = Pending::Fill;
        vec![d]
    }
}

impl DseSession for Lumina {
    fn name(&self) -> &'static str {
        "lumina"
    }

    fn phase(&self) -> &'static str {
        match self.phase {
            LuminaPhase::Reference => "reference",
            LuminaPhase::AhkAcquire => "ahk-acquire",
            LuminaPhase::Refine => "refine",
            LuminaPhase::Expansion => "expansion",
            LuminaPhase::Shrink | LuminaPhase::Fill => "shrink",
        }
    }

    fn ask(&mut self, ctx: &AskCtx) -> Vec<DesignPoint> {
        match self.phase {
            LuminaPhase::Reference => {
                // ---- Step 0: evaluate the reference design.
                let cfg = &self.config;
                self.model = Some(SimulatedAnalyst::new(
                    cfg.model,
                    cfg.seed ^ 0x5e5e,
                ));
                self.ee =
                    Some(ExplorationEngine::new(cfg.seed ^ 0xe0e0));
                self.pending = Pending::Reference;
                vec![DesignPoint::a100()]
            }
            LuminaPhase::AhkAcquire => {
                // ---- AHK acquisition (QualE is free; QuanE may spend
                // samples). The cheap-prior AHK is built here either
                // way; a sample-funded sweep refines it in `tell`.
                let reference_design =
                    // lumina: allow(P001) phase invariant: AhkAcquire follows the reference tell
                    self.reference.expect("reference evaluated").0;
                let qual = InfluenceMap::from_kernel();
                self.ahk = Some(Ahk::acquire_cheap(
                    qual,
                    ctx.space,
                    &reference_design,
                ));
                if ctx.budget >= self.config.full_quane_threshold {
                    let (designs, slots) = Ahk::sweep_designs(
                        ctx.space,
                        &reference_design,
                    );
                    self.pending = Pending::Sweep { slots };
                    designs
                } else {
                    self.step = self.tm.len();
                    self.phase = LuminaPhase::Refine;
                    self.refine_ask(ctx)
                }
            }
            LuminaPhase::Refine | LuminaPhase::Expansion => {
                self.refine_ask(ctx)
            }
            LuminaPhase::Shrink => self.shrink_ask(ctx),
            LuminaPhase::Fill => self.fill_ask(ctx),
        }
    }

    fn tell(&mut self, results: &[(DesignPoint, Metrics)]) {
        let pending =
            std::mem::replace(&mut self.pending, Pending::None);
        match pending {
            Pending::None => {}
            Pending::Reference => {
                let Some(&(d, m)) = results.first() else { return };
                self.tm.record(d, m, 0);
                self.reference = Some((d, m));
                self.current = Some((d, m));
                self.best_score = Self::score(
                    &m,
                    &m,
                    false,
                    self.config.objectives,
                );
                self.stale = 0;
                self.phase = LuminaPhase::AhkAcquire;
            }
            Pending::Sweep { slots } => {
                self.ahk
                    .as_mut()
                    // lumina: allow(P001) the Sweep ask built the cheap prior
                    .expect("cheap prior built in ask")
                    .absorb_sweep(&slots, results);
                // The sensitivity sweep's samples belong in the TM too.
                for (i, (d, m)) in results.iter().enumerate() {
                    self.tm.record(*d, *m, 1 + i);
                }
                self.step = self.tm.len();
                self.phase = LuminaPhase::Refine;
            }
            Pending::Proposal { metric, boost, steps } => {
                let Some(&(proposal, m)) = results.first() else {
                    return;
                };
                self.tm.record(proposal, m, self.step);
                self.step += 1;
                let (_, current_m) =
                    // lumina: allow(P001) phase invariant: a Proposal tell follows the reference tell
                    self.current.expect("current set by reference");
                let reference =
                    // lumina: allow(P001) phase invariant: a Proposal tell follows the reference tell
                    self.reference.expect("reference evaluated").1;

                // ---- Refinement: per-parameter observed
                // sensitivities.
                let obs =
                    |new: f32, old: f32| ((new - old) / old) as f64;
                let delta_metric = match metric {
                    0 => obs(m.ttft_ms, current_m.ttft_ms),
                    _ => obs(m.tpot_ms, current_m.tpot_ms),
                };
                // lumina: allow(P001) phase invariant: AhkAcquire precedes proposals
                self.ahk.as_mut().expect("ahk acquired").refine(
                    boost,
                    metric,
                    delta_metric / steps as f64,
                );

                // ---- Reflection: a boost that hurt its own metric is
                // a failure pattern.
                if delta_metric > 0.01 {
                    self.tm.record_failure(FailedMove {
                        param: boost,
                        direction: 1,
                        metric,
                    });
                }

                // ---- Hill-climb acceptance with restart on
                // stagnation.
                let s = Self::score(
                    &m,
                    &reference,
                    self.expansion,
                    self.config.objectives,
                );
                if s < self.best_score - 1e-6 {
                    self.best_score = s;
                    self.current = Some((proposal, m));
                    self.stale = 0;
                } else {
                    self.stale += 1;
                    if self.stale >= self.config.patience {
                        // Restart from the best weighted sample,
                        // nudged on a random axis (at the next ask) so
                        // the SE sees a different context.
                        if let Some(best) = self.tm.best_weighted(
                            &reference.objectives(),
                            &[1.0, 1.0, 0.7],
                        ) {
                            self.current =
                                Some((best.design, best.metrics));
                        }
                        self.restart_pending = true;
                        self.stale = 0;
                    }
                }
            }
            Pending::RestartNudge => {
                let Some(&(d, m)) = results.first() else { return };
                self.tm.record(d, m, self.step);
                self.step += 1;
                self.current = Some((d, m));
            }
            Pending::ShrinkProposal => {
                let Some(&(d, m)) = results.first() else { return };
                self.tm.record(d, m, self.step);
                self.step += 1;
                let reference =
                    // lumina: allow(P001) phase invariant: Shrink follows the reference tell
                    self.reference.expect("reference evaluated").1;
                let st =
                    // lumina: allow(P001) phase invariant: enter_shrink set the state
                    self.shrink.as_mut().expect("shrink entered");
                let in_box = m.ttft_ms < 2.0 * reference.ttft_ms
                    && m.tpot_ms < 2.0 * reference.tpot_ms;
                if in_box {
                    st.current = (d, m);
                    if m.area_mm2 < st.anchor.1.area_mm2 {
                        st.anchor = st.current;
                    }
                } else {
                    // Left the box: back to the smallest in-box design
                    // seen.
                    st.current = st.anchor;
                }
            }
            Pending::ShrinkNudge => {
                let Some(&(d, m)) = results.first() else { return };
                self.tm.record(d, m, self.step);
                self.step += 1;
                self.shrink
                    .as_mut()
                    // lumina: allow(P001) phase invariant: enter_shrink set the state
                    .expect("shrink entered")
                    .current = (d, m);
            }
            Pending::Fill => {
                let Some(&(d, m)) = results.first() else { return };
                self.tm.record(d, m, self.step);
                self.step += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::DseMethod;
    use crate::design::DesignSpace;
    use crate::eval::BudgetedEvaluator;
    use crate::pareto::{self, Objectives};
    use crate::sim::{CompassSim, RooflineSim};
    use crate::workload::GPT3_175B;

    fn run_lumina(budget: usize, seed: u64) -> (Vec<Objectives>, Objectives) {
        let mut sim = RooflineSim::new(GPT3_175B);
        let reference = {
            use crate::eval::Evaluator;
            sim.eval(&DesignPoint::a100()).unwrap().objectives()
        };
        let mut be = BudgetedEvaluator::new(&mut sim, budget);
        let mut lum = Lumina::with_seed(seed);
        lum.run(&DesignSpace::table1(), &mut be).unwrap();
        (be.objectives(), reference)
    }

    #[test]
    fn finds_superior_designs_within_60_samples() {
        let (objs, reference) = run_lumina(60, 3);
        let superior = pareto::superior_count(&objs, &reference);
        assert!(superior >= 3, "only {superior} superior designs");
    }

    #[test]
    fn sample_efficiency_beats_random_by_far() {
        let (objs, reference) = run_lumina(120, 4);
        let eff = pareto::sample_efficiency(&objs, &reference);
        // Random sampling lands < 1% superior; LUMINA should be >20%.
        assert!(eff > 0.2, "sample efficiency {eff}");
    }

    #[test]
    fn twenty_sample_compass_budget_beats_reference() {
        // The paper's headline: within 20 LLMCompass evaluations LUMINA
        // finds designs superior to A100.
        let mut sim = CompassSim::gpt3();
        let reference = {
            use crate::eval::Evaluator;
            sim.eval(&DesignPoint::a100()).unwrap().objectives()
        };
        let mut be = BudgetedEvaluator::new(&mut sim, 20);
        let mut lum = Lumina::with_seed(7);
        lum.run(&DesignSpace::table1(), &mut be).unwrap();
        let superior =
            pareto::superior_count(&be.objectives(), &reference);
        assert!(superior >= 1, "no superior design in 20 samples");
    }

    #[test]
    fn trajectory_and_ahk_exposed_after_run() {
        let mut sim = RooflineSim::new(GPT3_175B);
        let mut be = BudgetedEvaluator::new(&mut sim, 25);
        let mut lum = Lumina::with_seed(9);
        lum.run(&DesignSpace::table1(), &mut be).unwrap();
        assert!(lum.ahk.is_some());
        assert_eq!(lum.tm.len(), 25);
    }

    #[test]
    fn deterministic_given_seed() {
        let (a, _) = run_lumina(40, 11);
        let (b, _) = run_lumina(40, 11);
        assert_eq!(a, b);
    }

    fn run_lumina_mode(
        budget: usize,
        seed: u64,
        objectives: ObjectiveMode,
    ) -> Vec<(DesignPoint, Metrics)> {
        let mut sim = RooflineSim::new(GPT3_175B);
        let mut be = BudgetedEvaluator::new(&mut sim, budget);
        let mut lum = Lumina::new(LuminaConfig {
            seed,
            objectives,
            ..Default::default()
        });
        lum.run(&DesignSpace::table1(), &mut be).unwrap();
        be.log
    }

    #[test]
    fn ppa_mode_is_deterministic_and_power_aware() {
        use crate::arch::tdp_w;
        let a =
            run_lumina_mode(60, 13, ObjectiveMode::Ppa);
        let b =
            run_lumina_mode(60, 13, ObjectiveMode::Ppa);
        assert_eq!(a, b);
        // The power envelope + energy-aware acceptance genuinely steer
        // the search: the trajectory diverges from the latency-area one
        // under the same seed.
        let base =
            run_lumina_mode(60, 13, ObjectiveMode::LatencyArea);
        assert_ne!(
            a.iter().map(|(d, _)| *d).collect::<Vec<_>>(),
            base.iter().map(|(d, _)| *d).collect::<Vec<_>>(),
            "ppa mode proposed the identical trajectory"
        );
        // Every SE-proposed design in the refine window stays under the
        // reference power envelope (the shrink/fill tail and the
        // expansion phase are allowed a wider box, so check the designs
        // actually accepted as superior instead: any design strictly
        // better than A100 on all four lanes exists).
        let reference =
            RooflineSim::new(GPT3_175B).evaluate(&DesignPoint::a100());
        let superior = a
            .iter()
            .filter(|(_, m)| {
                m.ttft_ms < reference.ttft_ms
                    && m.tpot_ms < reference.tpot_ms
                    && m.area_mm2 < reference.area_mm2
                    && m.energy_per_token_mj
                        < reference.energy_per_token_mj
            })
            .count();
        assert!(superior >= 1, "no 4-lane superior design found");
        // Sanity of the envelope the SE enforced: the reference proxy
        // is finite and positive, and at least one evaluated design
        // stays within it (the refine phase never projects over 1.0x).
        let ceiling = tdp_w(&DesignPoint::a100()) as f64;
        assert!(ceiling > 0.0);
        assert!(
            a.iter().any(|(d, _)| (tdp_w(d) as f64) <= ceiling),
            "every evaluated design blew the reference power envelope"
        );
    }

    #[test]
    fn session_walks_the_named_phases_in_order() {
        use crate::dse::DseSession;
        let space = DesignSpace::table1();
        let mut sim = RooflineSim::new(GPT3_175B);
        let mut be = BudgetedEvaluator::new(&mut sim, 120);
        let mut lum = Lumina::with_seed(5);
        let mut seen: Vec<&'static str> =
            vec![DseSession::phase(&lum)];
        loop {
            let ctx = crate::dse::AskCtx {
                space: &space,
                budget: be.budget,
                remaining: be.remaining(),
                evaluations: be.evaluations(),
            };
            if be.exhausted() {
                break;
            }
            let proposals = lum.ask(&ctx);
            if proposals.is_empty() {
                break;
            }
            let results = be.eval_batch(&proposals).unwrap();
            if results.is_empty() {
                break;
            }
            lum.tell(&results);
            let p = DseSession::phase(&lum);
            if *seen.last().unwrap() != p {
                seen.push(p);
            }
        }
        assert_eq!(
            seen,
            vec![
                "reference",
                "ahk-acquire",
                "refine",
                "expansion",
                "shrink"
            ]
        );
    }
}
