//! The LUMINA refinement loop (paper Figure 2): evaluate -> bottleneck
//! analysis (SE) -> informed proposal (EE) -> Trajectory Memory -> AHK
//! refinement -> repeat until the sample budget is spent.

use crate::baselines::DseMethod;
use crate::design::{DesignPoint, DesignSpace, Param};
use crate::eval::{BudgetedEvaluator, Metrics};
use crate::llm::{LanguageModel, ModelProfile, SimulatedAnalyst};
use crate::Result;

use super::explore::ExplorationEngine;
use super::memory::{FailedMove, TrajectoryMemory};
use super::quale::InfluenceMap;
use super::quane::Ahk;
use super::strategy::StrategyEngine;

/// LUMINA configuration.
#[derive(Debug, Clone)]
pub struct LuminaConfig {
    pub seed: u64,
    /// Backbone model profile (the DSE Benchmark selects qwen3).
    pub model: ModelProfile,
    /// Run the full (sample-spending) QuanE sensitivity study when the
    /// budget is at least this large; otherwise the cheap area-only mode.
    pub full_quane_threshold: usize,
    /// Area ceiling relative to the reference design.
    pub area_ceiling: f64,
    /// Hill-climb patience before restarting from the best known point.
    pub patience: usize,
}

impl Default for LuminaConfig {
    fn default() -> Self {
        Self {
            seed: 0,
            model: ModelProfile::qwen3(),
            full_quane_threshold: 100,
            area_ceiling: 1.0,
            patience: 4,
        }
    }
}

/// The LUMINA optimizer.
pub struct Lumina {
    pub config: LuminaConfig,
    /// Ablation switch: drive the Strategy Engine with the *default*
    /// system prompt instead of the enhanced one AND without the SE's
    /// rule enforcement (the paper's corrective rules live in the SE;
    /// this is the "vanilla LLM agent" configuration).
    pub use_default_prompts: bool,
    /// Filled after `run`: the acquired + refined AHK.
    pub ahk: Option<Ahk>,
    /// Filled after `run`: the trajectory memory.
    pub tm: TrajectoryMemory,
}

impl Lumina {
    pub fn new(config: LuminaConfig) -> Self {
        Self {
            config,
            use_default_prompts: false,
            ahk: None,
            tm: TrajectoryMemory::new(),
        }
    }

    pub fn with_seed(seed: u64) -> Self {
        Self::new(LuminaConfig { seed, ..Default::default() })
    }

    /// Phase-3 sweep: from the best area-efficient sample, repeatedly
    /// step the least perf-critical parameter down (per the refined AHK)
    /// while both latencies stay within the PHV reference box, evaluating
    /// each rung. Restarts from progressively perf-better anchors when a
    /// walk leaves the box.
    fn shrink_sweep(
        &mut self,
        space: &DesignSpace,
        eval: &mut BudgetedEvaluator,
        tm: &mut TrajectoryMemory,
        ahk: &Ahk,
        reference: &Metrics,
    ) -> Result<()> {
        let mut rng =
            crate::stats::rng::Pcg32::with_stream(self.config.seed, 0x54);
        let mut ee = ExplorationEngine::new(self.config.seed ^ 0x54);
        let mut step = tm.len();
        let mut anchor = tm
            .best_weighted(&reference.objectives(), &[1.0, 1.0, 2.0])
            .map(|s| (s.design, s.metrics))
            .unwrap_or((DesignPoint::a100(), *reference));
        let mut current = anchor;
        while !eval.exhausted() {
            // Least perf-critical downward step from the current point.
            let mut cands: Vec<Param> = Param::ALL
                .iter()
                .copied()
                .filter(|&p| space.step(&current.0, p, -1) != current.0)
                .collect();
            cands.sort_by(|&a, &b| {
                let crit = |p: Param| {
                    ahk.perf_influence(p, 0).abs()
                        + ahk.perf_influence(p, 1).abs()
                };
                crit(a).partial_cmp(&crit(b)).unwrap()
            });
            let Some(&p) = cands.first() else { break };
            let next = space.step(&current.0, p, -1);
            let proposal = if tm.contains(&next) {
                // Nudge to an unvisited neighbour deterministically.
                let q = *rng.choose(&cands);
                space.step(&next, q, -1)
            } else {
                next
            };
            if tm.contains(&proposal) {
                // Walk exhausted around here: restart from a fresh
                // perf-leaning anchor.
                current = anchor;
                let q = *rng.choose(&Param::ALL);
                let nudged = space.step(&current.0, q, -1);
                if tm.contains(&nudged) {
                    break;
                }
                if let Some(m) =
                    ee.evaluate(eval, tm, nudged, step)?
                {
                    step += 1;
                    current = (nudged, m);
                }
                continue;
            }
            let Some(m) = ee.evaluate(eval, tm, proposal, step)? else {
                break;
            };
            step += 1;
            let in_box = m.ttft_ms < 2.0 * reference.ttft_ms
                && m.tpot_ms < 2.0 * reference.tpot_ms;
            if in_box {
                current = (proposal, m);
                if m.area_mm2 < anchor.1.area_mm2 {
                    anchor = current;
                }
            } else {
                // Left the box: back to the smallest in-box design seen.
                current = anchor;
            }
        }
        Ok(())
    }

    /// Weighted normalized score used for hill-climb acceptance (lower is
    /// better). In the dominate-the-reference phase the area term is a
    /// hard-ish wall above 1.0x; in the front-expansion phase it trades
    /// off linearly (PHV counts volume up to the 2x reference point).
    fn score(m: &Metrics, reference: &Metrics, expansion: bool) -> f64 {
        let nt = (m.ttft_ms / reference.ttft_ms) as f64;
        let nd = (m.tpot_ms / reference.tpot_ms) as f64;
        let na = (m.area_mm2 / reference.area_mm2) as f64;
        if expansion {
            nt + nd + na
        } else {
            nt + nd + 0.5 * na.max(1.0) * 4.0 - 2.0
        }
    }
}

impl DseMethod for Lumina {
    fn name(&self) -> &'static str {
        "lumina"
    }

    fn run(
        &mut self,
        space: &DesignSpace,
        eval: &mut BudgetedEvaluator,
    ) -> Result<()> {
        let cfg = self.config.clone();
        let mut model =
            SimulatedAnalyst::new(cfg.model, cfg.seed ^ 0x5e5e);
        let mut ee = ExplorationEngine::new(cfg.seed ^ 0xe0e0);
        let mut tm = TrajectoryMemory::new();

        // ---- Step 0: evaluate the reference design (the initial point).
        let reference_design = DesignPoint::a100();
        let Some(reference) = eval.eval(&reference_design)? else {
            return Ok(());
        };
        tm.record(reference_design, reference, 0);

        // ---- AHK acquisition (QualE is free; QuanE may spend samples).
        let qual = InfluenceMap::from_kernel();
        let mut ahk = if eval.budget >= cfg.full_quane_threshold {
            let a = Ahk::acquire_full(
                qual,
                space,
                &reference_design,
                eval,
            )?;
            // The sensitivity sweep's samples belong in the TM too.
            for (i, (d, m)) in eval.log.iter().skip(1).enumerate() {
                tm.record(*d, *m, 1 + i);
            }
            a
        } else {
            Ahk::acquire_cheap(qual, space, &reference_design)
        };

        // ---- Refinement loop. Two phases: dominate the reference
        // within its area envelope first (the paper's superior-design
        // hunt), then expand the Pareto front toward the PHV reference
        // point (2x area) with the remaining budget.
        let mut current = reference_design;
        let mut current_m = reference;
        let expansion_at = eval.budget * 3 / 5;
        let mut expansion = false;
        let mut best_score =
            Self::score(&reference, &reference, expansion);
        let mut stale = 0usize;
        let mut step = tm.len();

        // Phase 3 (final 20% of large budgets): AHK-guided area shrink —
        // walk down the least perf-critical parameters while both
        // latencies stay inside the PHV reference box, populating the
        // low-area corner of the front that bottleneck-removal alone
        // never visits.
        let shrink_at = eval.budget * 4 / 5;

        while !eval.exhausted() {
            if eval.budget > 64 && eval.spent() >= shrink_at {
                self.shrink_sweep(space, eval, &mut tm, &ahk, &reference)?;
                // The sweep can exhaust its local neighbourhood early;
                // spend any leftover budget on unvisited near-front
                // perturbations so every method consumes exactly its
                // sample budget.
                let mut rng = crate::stats::rng::Pcg32::with_stream(
                    cfg.seed, 0xf111,
                );
                let mut fill_step = tm.len();
                while !eval.exhausted() {
                    let anchor = tm
                        .best_weighted(
                            &reference.objectives(),
                            &[1.0, 1.0, 1.0 + rng.f64()],
                        )
                        .map(|s| s.design)
                        .unwrap_or(reference_design);
                    let mut d = anchor;
                    for _ in 0..1 + rng.range_usize(0, 3) {
                        let p = *rng.choose(&Param::ALL);
                        let delta = if rng.chance(0.5) { 1 } else { -1 };
                        d = space.step(&d, p, delta);
                    }
                    if tm.contains(&d) {
                        d = crate::design::sample::uniform(
                            space, &mut rng,
                        );
                    }
                    if ee.evaluate(eval, &mut tm, d, fill_step)?.is_some()
                    {
                        fill_step += 1;
                    }
                }
                break;
            }
            if !expansion
                && eval.spent() >= expansion_at
                && eval.budget > 64
            {
                expansion = true;
                best_score = f64::INFINITY; // re-anchor acceptance
            }
            let directive = {
                let mut se = StrategyEngine::new(
                    &mut model as &mut dyn LanguageModel,
                );
                if self.use_default_prompts {
                    se.system_prompt =
                        crate::llm::prompts::SYSTEM_DEFAULT.to_string();
                    se.enforce_rules = false;
                }
                se.area_ceiling = if expansion {
                    2.0 * cfg.area_ceiling
                } else {
                    cfg.area_ceiling
                };
                se.propose(
                    space, &current, &current_m, &reference, &ahk, &tm,
                    None,
                )
            };
            let proposal =
                ee.materialize(space, &current, &directive, &tm);
            let Some(m) = ee.evaluate(eval, &mut tm, proposal, step)?
            else {
                break;
            };
            step += 1;

            // ---- Refinement: per-parameter observed sensitivities.
            let metric = directive.phase.index();
            let obs = |new: f32, old: f32| ((new - old) / old) as f64;
            let delta_metric = match metric {
                0 => obs(m.ttft_ms, current_m.ttft_ms),
                _ => obs(m.tpot_ms, current_m.tpot_ms),
            };
            let (boost, steps) = directive.boost;
            ahk.refine(boost, metric, delta_metric / steps as f64);

            // ---- Reflection: a boost that hurt its own metric is a
            // failure pattern.
            if delta_metric > 0.01 {
                tm.record_failure(FailedMove {
                    param: boost,
                    direction: 1,
                    metric,
                });
            }

            // ---- Hill-climb acceptance with restart on stagnation.
            let s = Self::score(&m, &reference, expansion);
            if s < best_score - 1e-6 {
                best_score = s;
                current = proposal;
                current_m = m;
                stale = 0;
            } else {
                stale += 1;
                if stale >= cfg.patience {
                    // Restart from the best weighted sample, nudged on a
                    // random axis so the SE sees a different context.
                    if let Some(best) = tm.best_weighted(
                        &reference.objectives(),
                        &[1.0, 1.0, 0.7],
                    ) {
                        current = best.design;
                        current_m = best.metrics;
                    }
                    let mut rng = crate::stats::rng::Pcg32::new(
                        cfg.seed ^ step as u64,
                    );
                    let p = *rng.choose(&Param::ALL);
                    let nudged = space.step(&current, p, 1);
                    if !tm.contains(&nudged) {
                        if let Some(nm) =
                            ee.evaluate(eval, &mut tm, nudged, step)?
                        {
                            step += 1;
                            current = nudged;
                            current_m = nm;
                        }
                    }
                    stale = 0;
                }
            }
        }

        self.ahk = Some(ahk);
        self.tm = tm;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pareto::{self, Objectives};
    use crate::sim::{CompassSim, RooflineSim};
    use crate::workload::GPT3_175B;

    fn run_lumina(budget: usize, seed: u64) -> (Vec<Objectives>, Objectives) {
        let mut sim = RooflineSim::new(GPT3_175B);
        let reference = {
            use crate::eval::Evaluator;
            sim.eval(&DesignPoint::a100()).unwrap().objectives()
        };
        let mut be = BudgetedEvaluator::new(&mut sim, budget);
        let mut lum = Lumina::with_seed(seed);
        lum.run(&DesignSpace::table1(), &mut be).unwrap();
        (be.objectives(), reference)
    }

    #[test]
    fn finds_superior_designs_within_60_samples() {
        let (objs, reference) = run_lumina(60, 3);
        let superior = pareto::superior_count(&objs, &reference);
        assert!(superior >= 3, "only {superior} superior designs");
    }

    #[test]
    fn sample_efficiency_beats_random_by_far() {
        let (objs, reference) = run_lumina(120, 4);
        let eff = pareto::sample_efficiency(&objs, &reference);
        // Random sampling lands < 1% superior; LUMINA should be >20%.
        assert!(eff > 0.2, "sample efficiency {eff}");
    }

    #[test]
    fn twenty_sample_compass_budget_beats_reference() {
        // The paper's headline: within 20 LLMCompass evaluations LUMINA
        // finds designs superior to A100.
        let mut sim = CompassSim::gpt3();
        let reference = {
            use crate::eval::Evaluator;
            sim.eval(&DesignPoint::a100()).unwrap().objectives()
        };
        let mut be = BudgetedEvaluator::new(&mut sim, 20);
        let mut lum = Lumina::with_seed(7);
        lum.run(&DesignSpace::table1(), &mut be).unwrap();
        let superior =
            pareto::superior_count(&be.objectives(), &reference);
        assert!(superior >= 1, "no superior design in 20 samples");
    }

    #[test]
    fn trajectory_and_ahk_exposed_after_run() {
        let mut sim = RooflineSim::new(GPT3_175B);
        let mut be = BudgetedEvaluator::new(&mut sim, 25);
        let mut lum = Lumina::with_seed(9);
        lum.run(&DesignSpace::table1(), &mut be).unwrap();
        assert!(lum.ahk.is_some());
        assert_eq!(lum.tm.len(), 25);
    }

    #[test]
    fn deterministic_given_seed() {
        let (a, _) = run_lumina(40, 11);
        let (b, _) = run_lumina(40, 11);
        assert_eq!(a, b);
    }
}
