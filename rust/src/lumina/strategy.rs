//! Strategy Engine (SE): bottleneck analysis -> mitigation directive.
//!
//! The SE renders the critical-path feedback, the AHK influence factors
//! and the TM reflection into a strategy prompt, asks the language model
//! for grid-step adjustments, and then **enforces the corrective rules**
//! distilled from the DSE Benchmark (§5.2) on whatever comes back:
//!
//! * RULE 1 — only the single parameter most correlated with the dominant
//!   bottleneck is boosted;
//! * RULE 3 — area is funded by shrinking only the least-critical
//!   resource;
//! * RULE 4 — systolic-array growth is vetoed for decode-bound targets
//!   (utilization pitfall).
//!
//! In `ppa` objective mode the SE additionally enforces a **power
//! envelope**: a boost whose projected design exceeds
//! [`StrategyEngine::power_ceiling_w`] (static peak-power proxy,
//! [`crate::arch::tdp_w`]) is funded/vetoed exactly like an area
//! overrun — the same RULE 3 funding loop shrinks the least-critical
//! resource until both envelopes hold, and an unfundable boost falls
//! through to the next-best relevant parameter. The default ceiling is
//! infinite, so latency-area runs are bit-identical to the pre-power
//! engine.
//!
//! The SE also sets the search *aggressiveness* (how many grid steps the
//! boost takes) from the dominance of the stall.

use crate::design::{DesignPoint, DesignSpace, Param};
use crate::eval::{Bottleneck, Metrics, Phase};
use crate::llm::{parse, prompts, LanguageModel};

use super::memory::TrajectoryMemory;
use super::quane::Ahk;

/// A validated mitigation directive.
#[derive(Debug, Clone, PartialEq)]
pub struct Directive {
    pub phase: Phase,
    pub bottleneck: Bottleneck,
    /// The boosted (increased) parameter and its grid-step count.
    pub boost: (Param, i32),
    /// Funding (decreased) parameters.
    pub fund: Vec<(Param, i32)>,
}

/// Strategy Engine.
pub struct StrategyEngine<'m> {
    pub model: &'m mut dyn LanguageModel,
    pub system_prompt: String,
    /// Area ceiling as a fraction of the reference area (the paper's
    /// discovered designs all *reduce* area, so LUMINA trades within the
    /// reference envelope).
    pub area_ceiling: f64,
    /// Absolute power envelope, watts, checked against the static
    /// peak-power proxy [`crate::arch::tdp_w`] of the projected design.
    /// Infinite by default (latency-area mode — no power constraint and
    /// bit-identical directives); the ppa exploration sets it to a
    /// multiple of the reference design's proxy.
    pub power_ceiling_w: f64,
    /// Enforce the §5.2 corrective rules on the model's directives
    /// (RULE 1/3/4). Disabled only by the ablation study — without it
    /// the raw LLM adjustments are applied as-is, which is exactly the
    /// unreliable behaviour the DSE Benchmark documents.
    pub enforce_rules: bool,
}

impl<'m> StrategyEngine<'m> {
    pub fn new(model: &'m mut dyn LanguageModel) -> Self {
        Self {
            model,
            system_prompt: prompts::system_enhanced(),
            area_ceiling: 1.0,
            power_ceiling_w: f64::INFINITY,
            enforce_rules: true,
        }
    }

    /// Which phase to attack next: the one with the larger normalized gap
    /// to the reference (ties -> prefill, which dominates PHV here).
    pub fn pick_phase(current: &Metrics, reference: &Metrics) -> Phase {
        let gap_pf = current.ttft_ms / reference.ttft_ms;
        let gap_dc = current.tpot_ms / reference.tpot_ms;
        if gap_dc > gap_pf * 1.02 {
            Phase::Decode
        } else {
            Phase::Prefill
        }
    }

    /// Produce a directive for the current design.
    pub fn propose(
        &mut self,
        space: &DesignSpace,
        current: &DesignPoint,
        metrics: &Metrics,
        reference: &Metrics,
        ahk: &Ahk,
        tm: &TrajectoryMemory,
        critical_path_text: Option<&str>,
    ) -> Directive {
        let phase = Self::pick_phase(metrics, reference);
        let metric = phase.index();
        let bottleneck = metrics.dominant_bottleneck(phase);

        let headroom = self.area_ceiling * reference.area_mm2 as f64
            - metrics.area_mm2 as f64;
        let cp_text = critical_path_text
            .map(str::to_string)
            .unwrap_or_else(|| render_stall_cp(metrics, phase));

        // Power column: rendered only under a finite envelope, so
        // latency-area prompts stay byte-identical to the pre-power
        // engine.
        let power = self.power_ceiling_w.is_finite().then(|| {
            (
                metrics.avg_power_w as f64,
                self.power_ceiling_w
                    - crate::arch::tdp_w(current) as f64,
            )
        });
        let prompt = prompts::strategy_request(
            current,
            metrics,
            phase,
            &cp_text,
            &ahk.render_for(metric),
            &tm.render_reflection(metric),
            headroom,
            power,
        );
        let completion =
            self.model.complete(&self.system_prompt, &prompt);
        let adjustments = parse::parse_adjustments(&completion);

        if !self.enforce_rules {
            // Ablation path: trust the model verbatim. Take its first
            // positive adjustment as the boost and its negatives as the
            // funding, with no relevance filtering, no RULE-4 veto, and
            // no area-ceiling repair.
            let boost = adjustments
                .iter()
                .find(|a| a.steps > 0)
                .map(|a| (a.param, a.steps.clamp(1, 2)))
                .unwrap_or((Param::MemChannels, 1));
            let fund = adjustments
                .iter()
                .filter(|a| a.steps < 0 && a.param != boost.0)
                .map(|a| (a.param, (-a.steps).clamp(1, 2)))
                .collect();
            return Directive { phase, bottleneck, boost, fund };
        }

        // ---- RULE 1: one boost, structurally tied to the bottleneck.
        let relevant = ahk.qual.params_for(bottleneck);
        let banned = tm.banned_moves(metric, 2);
        let mut boost = adjustments
            .iter()
            .find(|a| {
                a.steps > 0
                    && relevant.contains(&a.param)
                    && !banned.contains(&(a.param, 1))
            })
            .map(|a| a.param)
            .or_else(|| {
                // Fallback: most beneficial relevant param per AHK.
                relevant
                    .iter()
                    .copied()
                    .filter(|p| !banned.contains(&(*p, 1)))
                    .min_by(|a, b| {
                        ahk.perf_influence(*a, metric)
                            .total_cmp(&ahk.perf_influence(*b, metric))
                    })
            })
            .unwrap_or(Param::MemChannels);

        // ---- RULE 4: decode-bound systolic growth is a pitfall.
        if phase == Phase::Decode && boost == Param::SystolicArray {
            boost = Param::MemChannels;
        }

        // Aggressiveness: a very dominant stall justifies two steps, but
        // only on the area-cheap linear resources — one grid step of the
        // geometric compute axes (systolic dim, cores) is already a big
        // jump.
        let frac = metrics.stall_fraction(phase, bottleneck) as f64;
        let cheap = matches!(boost, Param::Links | Param::MemChannels);
        let want_steps = if frac > 0.65 && cheap { 2 } else { 1 };

        // ---- RULE 3: fund the boost from the least-critical resources
        // until the projection fits under the area ceiling — and, in
        // ppa mode, under the power envelope (a boost that blows the
        // envelope is funded or vetoed exactly like an area overrun).
        // A design over the reference area can never dominate the
        // reference, so an unfundable boost is *rejected*: retry with
        // one step, then with the next-best relevant parameter.
        let ceiling = self.area_ceiling * reference.area_mm2 as f64;
        let over_envelope = |d: &DesignPoint| {
            crate::arch::area_mm2(d) as f64 > ceiling
                || crate::arch::tdp_w(d) as f64 > self.power_ceiling_w
        };
        let llm_fund = adjustments
            .iter()
            .find(|a| a.steps < 0 && a.param != boost)
            .map(|a| a.param);

        let mut boost_order: Vec<Param> = vec![boost];
        let mut rest: Vec<Param> = relevant
            .iter()
            .copied()
            .filter(|p| {
                *p != boost
                    && !banned.contains(&(*p, 1))
                    && !(phase == Phase::Decode
                        && *p == Param::SystolicArray)
            })
            .collect();
        rest.sort_by(|a, b| {
            ahk.perf_influence(*a, metric)
                .total_cmp(&ahk.perf_influence(*b, metric))
        });
        boost_order.extend(rest);

        for steps in [want_steps, 1] {
            if steps > want_steps {
                continue;
            }
            for &b in &boost_order {
                let mut fund: Vec<(Param, i32)> = Vec::new();
                // Honour the LLM's funding suggestion as the first cut.
                if let Some(f) = llm_fund {
                    if f != b {
                        fund.push((f, 1));
                    }
                }
                let mut projected = project(space, current, b, steps, &fund);
                let mut guard = 0;
                while over_envelope(&projected) && guard < 8 {
                    let Some(f) = least_critical(
                        space, &projected, ahk, metric, b, &banned,
                    ) else {
                        break;
                    };
                    fund.push((f, 1));
                    projected = project(space, current, b, steps, &fund);
                    guard += 1;
                }
                if !over_envelope(&projected) && projected != *current {
                    return Directive {
                        phase,
                        bottleneck,
                        boost: (b, steps),
                        fund,
                    };
                }
            }
        }
        // Nothing fundable (extreme corner): shrink toward the ceiling.
        let shrink = least_critical(
            space, current, ahk, metric, boost, &banned,
        )
        .unwrap_or(Param::SramKb);
        Directive {
            phase,
            bottleneck,
            boost: (shrink, -1),
            fund: Vec::new(),
        }
    }
}

/// Project a directive onto the grid without evaluating.
pub fn project(
    space: &DesignSpace,
    base: &DesignPoint,
    boost: Param,
    steps: i32,
    fund: &[(Param, i32)],
) -> DesignPoint {
    let mut d = space.step(base, boost, steps);
    for (p, s) in fund {
        d = space.step(&d, *p, -*s);
    }
    d
}

/// Least-critical fundable parameter: smallest |perf influence| on the
/// target metric with a real area saving, excluding the boost and moves
/// already banned.
fn least_critical(
    space: &DesignSpace,
    current: &DesignPoint,
    ahk: &Ahk,
    metric: usize,
    boost: Param,
    banned: &[(Param, i32)],
) -> Option<Param> {
    Param::ALL
        .iter()
        .copied()
        .filter(|&p| {
            p != boost
                && !banned.contains(&(p, -1))
                && space.step(current, p, -1) != *current
                && ahk.area_influence(p) > 0.0
        })
        .min_by(|&a, &b| {
            let crit = |p: Param| {
                ahk.perf_influence(p, metric).abs()
                    / ahk.area_influence(p).max(1e-6)
            };
            crit(a).total_cmp(&crit(b))
        })
}

/// Critical-path text from plain stall stacks (roofline environments
/// have no per-op report).
pub fn render_stall_cp(m: &Metrics, phase: Phase) -> String {
    let s = &m.stalls[phase.index()];
    format!(
        "critical path [{}] total={:.4} ms, dominant stall: {}\n\
         compute={:.4} ms memory={:.4} ms network={:.4} ms\n",
        phase.metric_name(),
        m.phase_time_ms(phase),
        m.dominant_bottleneck(phase).name(),
        s[0],
        s[1],
        s[2]
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::llm::{ModelProfile, SimulatedAnalyst};
    use crate::lumina::quale::InfluenceMap;

    fn fixture() -> (DesignSpace, DesignPoint, Ahk, TrajectoryMemory) {
        let space = DesignSpace::table1();
        let reference = DesignPoint::a100();
        let ahk = Ahk::acquire_cheap(
            InfluenceMap::from_kernel(),
            &space,
            &reference,
        );
        (space, reference, ahk, TrajectoryMemory::new())
    }

    fn net_bound() -> Metrics {
        Metrics {
            ttft_ms: 40.0,
            tpot_ms: 0.40,
            area_mm2: 834.0,
            energy_per_token_mj: 45.0,
            prefill_energy_mj: 8500.0,
            avg_power_w: 211.5,
            stalls: [[10.0, 5.0, 25.0], [0.0, 0.35, 0.05]],
        }
    }

    fn a100_like() -> Metrics {
        Metrics {
            ttft_ms: 36.7,
            tpot_ms: 0.44,
            area_mm2: 834.0,
            energy_per_token_mj: 41.4,
            prefill_energy_mj: 8116.0,
            avg_power_w: 219.6,
            stalls: [[26.8, 3.6, 6.3], [0.0, 0.43, 0.02]],
        }
    }

    #[test]
    fn phase_picking_targets_larger_gap() {
        let reference = a100_like();
        let mut worse_decode = a100_like();
        worse_decode.tpot_ms = 0.9;
        assert_eq!(
            StrategyEngine::pick_phase(&worse_decode, &reference),
            Phase::Decode
        );
        assert_eq!(
            StrategyEngine::pick_phase(&a100_like(), &reference),
            Phase::Prefill
        );
    }

    #[test]
    fn network_bound_prefill_boosts_links() {
        let (space, reference, ahk, tm) = fixture();
        let mut model = SimulatedAnalyst::new(ModelProfile::oracle(), 1);
        let mut se = StrategyEngine::new(&mut model);
        let d = se.propose(
            &space,
            &reference,
            &net_bound(),
            &a100_like(),
            &ahk,
            &tm,
            None,
        );
        assert_eq!(d.phase, Phase::Prefill);
        assert_eq!(d.bottleneck, Bottleneck::Network);
        assert_eq!(d.boost.0, Param::Links);
        assert!(d.boost.1 >= 1);
    }

    #[test]
    fn decode_memory_bound_boosts_channels_not_systolic() {
        let (space, reference, ahk, tm) = fixture();
        let mut model = SimulatedAnalyst::new(ModelProfile::oracle(), 2);
        let mut se = StrategyEngine::new(&mut model);
        let mut m = a100_like();
        m.tpot_ms = 1.2; // decode far off reference
        let d = se.propose(
            &space, &reference, &m, &a100_like(), &ahk, &tm, None,
        );
        assert_eq!(d.phase, Phase::Decode);
        assert_eq!(d.boost.0, Param::MemChannels);
    }

    #[test]
    fn over_ceiling_directive_funds_area() {
        let (space, _, ahk, tm) = fixture();
        let mut model = SimulatedAnalyst::new(ModelProfile::oracle(), 3);
        let mut se = StrategyEngine::new(&mut model);
        // Current design is already at the reference area; boosting links
        // must be funded by shrinking something.
        let fat = DesignPoint::new([12, 128, 4, 16, 32, 192, 64, 6]);
        let mut m = net_bound();
        m.area_mm2 = crate::arch::area_mm2(&fat);
        let d = se.propose(
            &space, &fat, &m, &a100_like(), &ahk, &tm, None,
        );
        assert!(!d.fund.is_empty(), "{d:?}");
        let projected =
            project(&space, &fat, d.boost.0, d.boost.1, &d.fund);
        assert!(
            crate::arch::area_mm2(&projected)
                <= m.area_mm2.max(834.0) * 1.01
        );
    }

    #[test]
    fn banned_boost_falls_back_to_next_relevant() {
        let (space, reference, ahk, mut tm) = fixture();
        for _ in 0..2 {
            tm.record_failure(super::super::memory::FailedMove {
                param: Param::Links,
                direction: 1,
                metric: 0,
            });
        }
        let mut model = SimulatedAnalyst::new(ModelProfile::oracle(), 4);
        let mut se = StrategyEngine::new(&mut model);
        let d = se.propose(
            &space,
            &reference,
            &net_bound(),
            &a100_like(),
            &ahk,
            &tm,
            None,
        );
        assert_ne!(d.boost.0, Param::Links, "{d:?}");
    }

    #[test]
    fn power_envelope_funds_or_vetoes_expensive_boosts() {
        use crate::arch::tdp_w;
        let (space, reference, ahk, tm) = fixture();
        let ceiling = tdp_w(&reference) as f64;
        // Compute-bound prefill would normally boost a tensor-grid
        // resource; with the power envelope pinned at the reference the
        // projected design must still fit under it.
        let compute_bound = Metrics {
            ttft_ms: 60.0,
            tpot_ms: 0.44,
            area_mm2: 834.0,
            energy_per_token_mj: 41.4,
            prefill_energy_mj: 9000.0,
            avg_power_w: 220.0,
            stalls: [[50.0, 5.0, 5.0], [0.0, 0.43, 0.01]],
        };
        let mut model = SimulatedAnalyst::new(ModelProfile::oracle(), 8);
        let mut se = StrategyEngine::new(&mut model);
        se.power_ceiling_w = ceiling;
        let d = se.propose(
            &space,
            &reference,
            &compute_bound,
            &a100_like(),
            &ahk,
            &tm,
            None,
        );
        if d.boost.1 > 0 {
            let projected =
                project(&space, &reference, d.boost.0, d.boost.1, &d.fund);
            assert!(
                tdp_w(&projected) as f64 <= ceiling * 1.0 + 1e-9,
                "{d:?} projects {} W over ceiling {ceiling}",
                tdp_w(&projected)
            );
        }
        // Same directive engine without the envelope: identical inputs
        // must reproduce the historical (area-only) behaviour.
        let mut model2 =
            SimulatedAnalyst::new(ModelProfile::oracle(), 8);
        let mut se2 = StrategyEngine::new(&mut model2);
        assert!(se2.power_ceiling_w.is_infinite());
        let d2 = se2.propose(
            &space,
            &reference,
            &compute_bound,
            &a100_like(),
            &ahk,
            &tm,
            None,
        );
        assert_eq!(d2.phase, d.phase);
        assert_eq!(d2.bottleneck, d.bottleneck);
    }

    #[test]
    fn project_applies_boost_and_fund() {
        let (space, reference, ..) = fixture();
        let p = project(
            &space,
            &reference,
            Param::Links,
            1,
            &[(Param::Cores, 1)],
        );
        assert_eq!(p.get(Param::Links), 18);
        assert_eq!(p.get(Param::Cores), 96);
    }
}
