//! Exploration Engine (EE): the integration layer between the Strategy
//! Engine and the simulation environment. Serializes a directive into a
//! concrete grid design, de-duplicates against the Trajectory Memory
//! (perturbing deterministically when a proposal was already visited),
//! issues the evaluation, and returns the structured sample.

use crate::design::{DesignPoint, DesignSpace, Param};
use crate::eval::{BudgetedEvaluator, Metrics};
use crate::stats::rng::Pcg32;
use crate::Result;

use super::memory::TrajectoryMemory;
use super::strategy::{project, Directive};

/// Exploration Engine.
pub struct ExplorationEngine {
    rng: Pcg32,
}

impl ExplorationEngine {
    pub fn new(seed: u64) -> Self {
        Self { rng: Pcg32::with_stream(seed, 0xee) }
    }

    /// Turn a directive into a concrete, unvisited grid point.
    pub fn materialize(
        &mut self,
        space: &DesignSpace,
        base: &DesignPoint,
        directive: &Directive,
        tm: &TrajectoryMemory,
    ) -> DesignPoint {
        let mut d = project(
            space,
            base,
            directive.boost.0,
            directive.boost.1,
            &directive.fund,
        );
        // Dedup: nudge deterministically until unvisited (bounded).
        let mut tries = 0;
        while tm.contains(&d) && tries < 16 {
            let p = *self.rng.choose(&Param::ALL);
            let delta = if self.rng.chance(0.5) { 1 } else { -1 };
            // Never undo the boost itself.
            if p == directive.boost.0 && delta < 0 {
                tries += 1;
                continue;
            }
            let nudged = space.step(&d, p, delta);
            if nudged != d {
                d = nudged;
            }
            tries += 1;
        }
        d
    }

    /// Evaluate `design` and record it in the TM. Returns `None` when the
    /// budget is exhausted.
    pub fn evaluate(
        &mut self,
        eval: &mut BudgetedEvaluator,
        tm: &mut TrajectoryMemory,
        design: DesignPoint,
        step: usize,
    ) -> Result<Option<Metrics>> {
        let Some(m) = eval.eval(&design)? else {
            return Ok(None);
        };
        tm.record(design, m, step);
        Ok(Some(m))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::{Bottleneck, Phase};
    use crate::sim::RooflineSim;
    use crate::workload::GPT3_175B;

    fn directive() -> Directive {
        Directive {
            phase: Phase::Prefill,
            bottleneck: Bottleneck::Network,
            boost: (Param::Links, 1),
            fund: vec![(Param::Cores, 1)],
        }
    }

    #[test]
    fn materialize_applies_directive() {
        let space = DesignSpace::table1();
        let mut ee = ExplorationEngine::new(7);
        let tm = TrajectoryMemory::new();
        let d = ee.materialize(
            &space,
            &DesignPoint::a100(),
            &directive(),
            &tm,
        );
        assert_eq!(d.get(Param::Links), 18);
        assert_eq!(d.get(Param::Cores), 96);
        assert!(space.contains(&d));
    }

    #[test]
    fn materialize_dedups_against_tm() {
        let space = DesignSpace::table1();
        let mut ee = ExplorationEngine::new(8);
        let mut tm = TrajectoryMemory::new();
        let first = ee.materialize(
            &space,
            &DesignPoint::a100(),
            &directive(),
            &tm,
        );
        let fake = Metrics {
            ttft_ms: 1.0,
            tpot_ms: 1.0,
            area_mm2: 1.0,
            stalls: [[1.0, 0.0, 0.0]; 2],
            ..Default::default()
        };
        tm.record(first, fake, 0);
        let second = ee.materialize(
            &space,
            &DesignPoint::a100(),
            &directive(),
            &tm,
        );
        assert_ne!(second, first);
        assert!(space.contains(&second));
        // Boost preserved through the nudges.
        assert!(second.get(Param::Links) >= 18);
    }

    #[test]
    fn evaluate_counts_budget_and_records() {
        let mut sim = RooflineSim::new(GPT3_175B);
        let mut be = BudgetedEvaluator::new(&mut sim, 1);
        let mut ee = ExplorationEngine::new(9);
        let mut tm = TrajectoryMemory::new();
        let m = ee
            .evaluate(&mut be, &mut tm, DesignPoint::a100(), 1)
            .unwrap();
        assert!(m.is_some());
        assert_eq!(tm.len(), 1);
        // Budget exhausted now.
        let m2 = ee
            .evaluate(&mut be, &mut tm, DesignPoint::paper_design_a(), 2)
            .unwrap();
        assert!(m2.is_none());
        assert_eq!(tm.len(), 1);
    }
}
