//! Quantitative Engine (QuanE) + the AHK store.
//!
//! Assigns numeric influence values to the structural dependencies QualE
//! found, by running a one-grid-step sensitivity study around the
//! reference design. Two modes, per the paper's cost note ("under complex
//! performance models ... the QuanE can focus on estimating only power
//! and area, which are faster to evaluate"):
//!
//! * **full** — perturb every parameter ±1 grid step through the
//!   evaluator (17 evaluations, counted against the sample budget); used
//!   with the cheap roofline environment.
//! * **cheap** — area sensitivities from the analytic area model (zero
//!   samples) plus structural priors for performance; used under the
//!   20-sample LLMCompass budget. The refinement loop then calibrates
//!   the priors from observed trajectory data.

use crate::arch::{area_mm2, tdp_w};
use crate::design::{DesignPoint, DesignSpace, Param, N_PARAMS};
use crate::eval::{BudgetedEvaluator, Phase};
use crate::Result;

use super::quale::InfluenceMap;

/// Metric lanes of the AHK influence table.
pub const AHK_METRICS: usize = 4;
/// Index of the power lane (average watts / static peak watts).
pub const METRIC_POWER: usize = 3;

/// Architectural Heuristic Knowledge: the structural map plus numeric
/// influence factors (relative metric change per +1 grid step).
#[derive(Debug, Clone)]
pub struct Ahk {
    pub qual: InfluenceMap,
    /// `influence[param][metric]`, metric in {0: TTFT, 1: TPOT,
    /// 2: area, 3: power}. Positive = metric increases when the
    /// parameter is stepped up. The power column is acquired at zero
    /// sample cost from the analytic peak-power model (the paper's
    /// "focus on estimating only power and area" cheap mode) and
    /// refined from observed `avg_power_w` when a sweep runs.
    pub influence: [[f64; AHK_METRICS]; N_PARAMS],
    /// How many observations refined each (param, metric) cell.
    pub refined: [[u32; AHK_METRICS]; N_PARAMS],
}

impl Ahk {
    /// Cheap acquisition: analytic area column + structural priors.
    pub fn acquire_cheap(
        qual: InfluenceMap,
        space: &DesignSpace,
        reference: &DesignPoint,
    ) -> Ahk {
        let mut influence = [[0.0f64; AHK_METRICS]; N_PARAMS];
        let ref_area = area_mm2(reference) as f64;
        let ref_power = tdp_w(reference) as f64;
        for p in Param::ALL {
            let up = space.step(reference, p, 1);
            let da = (area_mm2(&up) as f64 - ref_area) / ref_area;
            influence[p.index()][2] = da;
            // Power column: analytic peak-power deltas, zero samples
            // (like area, monotone in every parameter).
            influence[p.index()][METRIC_POWER] =
                (tdp_w(&up) as f64 - ref_power) / ref_power;
            // Structural performance priors (negative = reduces time).
            // Primary rate-setting resources per QualE component —
            // channels for memory bandwidth, links for the interconnect,
            // the tensor grid for compute — carry strong priors;
            // efficiency-only resources (L2, SRAM, vector width) carry
            // weak ones. Refined from observed data as samples arrive.
            let weight = match p {
                Param::MemChannels | Param::Links => 0.9,
                Param::Cores | Param::SystolicArray => 0.8,
                Param::Sublanes => 0.6,
                Param::VectorWidth => 0.2,
                Param::GbufMb => 0.15,
                Param::SramKb => 0.1,
            };
            for (metric, phase) in
                [(0usize, Phase::Prefill), (1usize, Phase::Decode)]
            {
                let relevant = crate::eval::Bottleneck::ALL
                    .iter()
                    .any(|&b| qual.params_for(b).contains(&p));
                if relevant {
                    let scale = match phase {
                        Phase::Prefill => 0.05,
                        Phase::Decode => 0.03,
                    };
                    influence[p.index()][metric] = -scale * weight;
                }
            }
        }
        Ahk { qual, influence, refined: [[0; AHK_METRICS]; N_PARAMS] }
    }

    /// The ±1-step sensitivity sweep around `reference`: the designs to
    /// evaluate (reference first) and the `(param, delta, index)` slots
    /// mapping each perturbation to its result position. Shared by
    /// [`Ahk::acquire_full`] and the LUMINA session's AhkAcquire phase
    /// (which asks the same batch through the driver).
    pub fn sweep_designs(
        space: &DesignSpace,
        reference: &DesignPoint,
    ) -> (Vec<DesignPoint>, Vec<(Param, i32, usize)>) {
        let mut designs = vec![*reference];
        let mut slots: Vec<(Param, i32, usize)> = Vec::new();
        for p in Param::ALL {
            for delta in [1, -1] {
                let d = space.step(reference, p, delta);
                if d != *reference {
                    slots.push((p, delta, designs.len()));
                    designs.push(d);
                }
            }
        }
        (designs, slots)
    }

    /// Fold an evaluated sensitivity sweep (as produced by
    /// [`Ahk::sweep_designs`]) into the influence table. `results[0]`
    /// is the reference; missing slots (budget-truncated sweeps) are
    /// skipped.
    pub fn absorb_sweep(
        &mut self,
        slots: &[(Param, i32, usize)],
        results: &[(DesignPoint, crate::eval::Metrics)],
    ) {
        let Some((_, base)) = results.first() else { return };
        let base_v = [
            base.ttft_ms as f64,
            base.tpot_ms as f64,
            base.area_mm2 as f64,
            base.avg_power_w as f64,
        ];
        // Pre-PPA trajectories (e.g. a resumed old checkpoint) carry
        // zero power fields; skip the power lane rather than divide by
        // zero.
        let lanes = if base.avg_power_w > 0.0 { 4 } else { 3 };
        for &(p, delta, idx) in slots {
            let Some((_, m)) = results.get(idx) else { continue };
            let v = [
                m.ttft_ms as f64,
                m.tpot_ms as f64,
                m.area_mm2 as f64,
                m.avg_power_w as f64,
            ];
            for metric in 0..lanes {
                // Sensitivity per +1 step (mirror -1 observations).
                let rel =
                    (v[metric] - base_v[metric]) / base_v[metric];
                let per_step = rel * delta as f64;
                let cell = &mut self.influence[p.index()][metric];
                let n = &mut self.refined[p.index()][metric];
                if *n == 0 {
                    *cell = per_step;
                } else {
                    *cell = (*cell * *n as f64 + per_step)
                        / (*n as f64 + 1.0);
                }
                *n += 1;
            }
        }
    }

    /// Full acquisition: ±1-step sensitivity study through the evaluator.
    /// Consumes up to `2 * N_PARAMS + 1` samples of the budget.
    pub fn acquire_full(
        qual: InfluenceMap,
        space: &DesignSpace,
        reference: &DesignPoint,
        eval: &mut BudgetedEvaluator,
    ) -> Result<Ahk> {
        let (designs, slots) = Self::sweep_designs(space, reference);
        let results = eval.eval_batch(&designs)?;
        if results.is_empty() {
            // Budget already exhausted: degrade to cheap mode.
            return Ok(Self::acquire_cheap(qual, space, reference));
        }
        let mut ahk = Self::acquire_cheap(qual, space, reference);
        ahk.absorb_sweep(&slots, &results);
        Ok(ahk)
    }

    /// Refinement-loop update (paper §3.4): fold an observed relative
    /// delta for (param, metric) into the influence factor with an EMA.
    pub fn refine(&mut self, p: Param, metric: usize, observed: f64) {
        const ALPHA: f64 = 0.35;
        let cell = &mut self.influence[p.index()][metric];
        *cell = (1.0 - ALPHA) * *cell + ALPHA * observed;
        self.refined[p.index()][metric] += 1;
    }

    /// Influence of `p` on a phase metric (0 prefill / 1 decode).
    pub fn perf_influence(&self, p: Param, metric: usize) -> f64 {
        self.influence[p.index()][metric]
    }

    pub fn area_influence(&self, p: Param) -> f64 {
        self.influence[p.index()][2]
    }

    /// Relative power change per +1 grid step of `p`.
    pub fn power_influence(&self, p: Param) -> f64 {
        self.influence[p.index()][METRIC_POWER]
    }

    /// Render the quantitative factors for the strategy prompt:
    /// `influence: <param> <benefit-per-step>` for the target metric.
    pub fn render_for(&self, metric: usize) -> String {
        let mut out = String::new();
        for p in Param::ALL {
            // Benefit = how much the metric *improves* per +1 step.
            let benefit = -self.perf_influence(p, metric);
            out.push_str(&format!(
                "influence: {} {:.4}\n",
                p.name(),
                benefit
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::RooflineSim;
    use crate::workload::GPT3_175B;

    fn setup() -> (DesignSpace, DesignPoint, InfluenceMap) {
        (
            DesignSpace::table1(),
            DesignPoint::a100(),
            InfluenceMap::from_kernel(),
        )
    }

    #[test]
    fn cheap_mode_has_signed_area_column() {
        let (space, reference, qual) = setup();
        let ahk = Ahk::acquire_cheap(qual, &space, &reference);
        // Every parameter grows area when stepped up.
        for p in Param::ALL {
            assert!(
                ahk.area_influence(p) > 0.0,
                "{p}: {}",
                ahk.area_influence(p)
            );
        }
    }

    #[test]
    fn cheap_mode_power_column_is_analytic_and_ranked() {
        let (space, reference, qual) = setup();
        let ahk = Ahk::acquire_cheap(qual, &space, &reference);
        // Every parameter grows peak power when stepped up (zero
        // sample cost, like area).
        for p in Param::ALL {
            assert!(
                ahk.power_influence(p) > 0.0,
                "{p}: {}",
                ahk.power_influence(p)
            );
        }
        // Doubling the systolic dim quadruples MAC power: it must be
        // the most power-expensive step by far.
        let sa = ahk.power_influence(Param::SystolicArray);
        assert!(sa > ahk.power_influence(Param::MemChannels));
        assert!(sa > ahk.power_influence(Param::Links));
        assert!(sa > ahk.power_influence(Param::SramKb));
    }

    #[test]
    fn full_mode_refines_the_power_lane_from_observations() {
        let (space, reference, qual) = setup();
        let mut sim = RooflineSim::new(GPT3_175B);
        let mut be = BudgetedEvaluator::new(&mut sim, 64);
        let ahk =
            Ahk::acquire_full(qual, &space, &reference, &mut be).unwrap();
        // The sweep observed avg_power_w deltas for every parameter.
        for p in Param::ALL {
            assert!(
                ahk.refined[p.index()][METRIC_POWER] > 0,
                "{p} power lane unrefined"
            );
        }
        // More memory channels raise observed power (more HBM draw on
        // the same traffic in less time).
        assert!(ahk.power_influence(Param::MemChannels) != 0.0);
    }

    #[test]
    fn full_mode_learns_real_sensitivities() {
        let (space, reference, qual) = setup();
        let mut sim = RooflineSim::new(GPT3_175B);
        let mut be = BudgetedEvaluator::new(&mut sim, 64);
        let ahk =
            Ahk::acquire_full(qual, &space, &reference, &mut be).unwrap();
        assert!(be.spent() <= 17);
        // More links reduce TTFT (network stall shrinks).
        assert!(ahk.perf_influence(Param::Links, 0) < 0.0);
        // More memory channels reduce TPOT (decode memory-bound).
        assert!(ahk.perf_influence(Param::MemChannels, 1) < 0.0);
        // Links shouldn't matter much for TPOT compared to channels.
        assert!(
            ahk.perf_influence(Param::Links, 1).abs()
                < ahk.perf_influence(Param::MemChannels, 1).abs()
        );
    }

    #[test]
    fn full_mode_respects_exhausted_budget() {
        let (space, reference, qual) = setup();
        let mut sim = RooflineSim::new(GPT3_175B);
        let mut be = BudgetedEvaluator::new(&mut sim, 0);
        let ahk =
            Ahk::acquire_full(qual, &space, &reference, &mut be).unwrap();
        assert_eq!(be.spent(), 0);
        // Degraded to cheap priors.
        assert!(ahk.refined.iter().all(|r| r.iter().all(|&n| n == 0)));
    }

    #[test]
    fn refine_moves_cell_toward_observation() {
        let (space, reference, qual) = setup();
        let mut ahk = Ahk::acquire_cheap(qual, &space, &reference);
        let before = ahk.perf_influence(Param::Links, 0);
        ahk.refine(Param::Links, 0, -0.5);
        let after = ahk.perf_influence(Param::Links, 0);
        assert!(after < before);
        assert_eq!(ahk.refined[Param::Links.index()][0], 1);
    }

    #[test]
    fn render_contains_every_param() {
        let (space, reference, qual) = setup();
        let ahk = Ahk::acquire_cheap(qual, &space, &reference);
        let text = ahk.render_for(0);
        for p in Param::ALL {
            assert!(text.contains(p.name()));
        }
    }
}
