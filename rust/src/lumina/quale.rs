//! Qualitative Engine (QualE): structural knowledge from simulator code.
//!
//! The paper: "the QualE performs static code analysis, utilizing the
//! LLM's interpretative strength to explicitly map the causal influence of
//! each resource hyper-parameter onto specific PPA metrics", producing an
//! *Influence Map*. Here the analysis is implemented as a deterministic
//! parser over the **real simulator source** — the L1 Pallas kernel that
//! the artifacts are lowered from, embedded at compile time — which plays
//! the role of the LLM's code reading: it finds the derived-rate
//! definitions (`t_peak`, `v_peak`, `m_bw`, `n_bw`, `area*`) and records
//! which design-parameter variables appear in each.

use std::collections::BTreeMap;

use crate::design::{Param, N_PARAMS};
use crate::eval::Bottleneck;

/// The simulator source QualE reads (the Pallas kernel the AOT artifact
/// is lowered from — L1 of the stack).
pub const KERNEL_SOURCE: &str =
    include_str!("../../../python/compile/kernels/roofline.py");

/// Variable-name -> parameter mapping inside the kernel source.
const VAR_NAMES: [(&str, Param); N_PARAMS] = [
    ("links", Param::Links),
    ("cores", Param::Cores),
    ("subl", Param::Sublanes),
    ("sa", Param::SystolicArray),
    ("vecw", Param::VectorWidth),
    ("sram", Param::SramKb),
    ("gbuf", Param::GbufMb),
    ("memch", Param::MemChannels),
];

/// Structural dependencies: which parameters feed which stall component
/// and whether they appear in the area expression.
#[derive(Debug, Clone, Default)]
pub struct InfluenceMap {
    /// `component -> params that structurally influence it`.
    pub bottleneck_params: BTreeMap<usize, Vec<Param>>,
    /// Params appearing in the area computation.
    pub area_params: Vec<Param>,
    /// Raw derived-rate -> params table (for reports / prompts).
    pub rates: BTreeMap<String, Vec<Param>>,
}

impl InfluenceMap {
    /// Run the static analysis over `source`.
    pub fn from_source(source: &str) -> InfluenceMap {
        // Collect multi-line assignment expressions: `name = expr` where
        // expr continues while lines end with an operator or open paren.
        let mut defs: BTreeMap<String, String> = BTreeMap::new();
        let mut lines = source.lines().peekable();
        while let Some(line) = lines.next() {
            let t = line.trim();
            if t.starts_with('#') || !t.contains('=') || t.contains("==") {
                continue;
            }
            let Some((name, rhs)) = t.split_once('=') else { continue };
            let name = name.trim();
            if !name
                .chars()
                .all(|c| c.is_ascii_alphanumeric() || c == '_')
                || name.is_empty()
            {
                continue;
            }
            let mut expr = rhs.trim().to_string();
            // Greedy continuation: unbalanced parens pull more lines in.
            while open_parens(&expr) > 0 {
                match lines.next() {
                    Some(l) => {
                        expr.push(' ');
                        expr.push_str(l.trim());
                    }
                    None => break,
                }
            }
            defs.entry(name.to_string()).or_insert(expr);
        }

        // Transitively resolve which design parameters feed a definition.
        let params_of = |expr: &str,
                         defs: &BTreeMap<String, String>|
         -> Vec<Param> {
            let mut seen = Vec::new();
            let mut stack = vec![expr.to_string()];
            let mut visited: Vec<String> = Vec::new();
            while let Some(e) = stack.pop() {
                for (var, p) in VAR_NAMES {
                    if has_ident(&e, var) && !seen.contains(&p) {
                        seen.push(p);
                    }
                }
                for (name, sub) in defs {
                    if has_ident(&e, name) && !visited.contains(name) {
                        visited.push(name.clone());
                        stack.push(sub.clone());
                    }
                }
            }
            seen.sort_by_key(|p| p.index());
            seen
        };

        let mut rates = BTreeMap::new();
        for key in ["t_peak", "v_peak", "m_bw", "n_bw", "area"] {
            if let Some(expr) = defs.get(key) {
                rates.insert(key.to_string(), params_of(expr, &defs));
            }
        }

        // Map rates -> stall components:
        //   compute <- t_peak + v_peak (+ per-op utilization terms: sram)
        //   memory  <- m_bw
        //   network <- n_bw
        let mut bottleneck_params: BTreeMap<usize, Vec<Param>> =
            BTreeMap::new();
        let mut comp: Vec<Param> = Vec::new();
        for key in ["t_peak", "v_peak"] {
            for p in rates.get(key).cloned().unwrap_or_default() {
                if !comp.contains(&p) {
                    comp.push(p);
                }
            }
        }
        // Utilization factors (sram_f) gate tensor throughput: pull
        // params referenced by `sram_f` / `sram_req` into compute too.
        for key in ["sram_f"] {
            if let Some(expr) = defs.get(key) {
                for p in params_of(expr, &defs) {
                    if !comp.contains(&p) {
                        comp.push(p);
                    }
                }
            }
        }
        comp.sort_by_key(|p| p.index());
        bottleneck_params.insert(Bottleneck::Compute.index(), comp);
        bottleneck_params.insert(
            Bottleneck::Memory.index(),
            rates.get("m_bw").cloned().unwrap_or_default(),
        );
        bottleneck_params.insert(
            Bottleneck::Network.index(),
            rates.get("n_bw").cloned().unwrap_or_default(),
        );

        let area_params = rates.get("area").cloned().unwrap_or_default();
        InfluenceMap { bottleneck_params, area_params, rates }
    }

    /// The default map, parsed from the embedded kernel source.
    pub fn from_kernel() -> InfluenceMap {
        Self::from_source(KERNEL_SOURCE)
    }

    /// Params structurally relevant to a bottleneck component.
    pub fn params_for(&self, b: Bottleneck) -> &[Param] {
        self.bottleneck_params
            .get(&b.index())
            .map(|v| v.as_slice())
            .unwrap_or(&[])
    }

    /// Render for prompts / DESIGN reports.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for b in Bottleneck::ALL {
            let names: Vec<&str> =
                self.params_for(b).iter().map(|p| p.name()).collect();
            out.push_str(&format!(
                "{} <- {}\n",
                b.name(),
                names.join(", ")
            ));
        }
        let names: Vec<&str> =
            self.area_params.iter().map(|p| p.name()).collect();
        out.push_str(&format!("area <- {}\n", names.join(", ")));
        out
    }
}

/// Whole-word identifier search (avoids `sa` matching `sram`).
fn has_ident(expr: &str, ident: &str) -> bool {
    let b = expr.as_bytes();
    let mut start = 0;
    while let Some(pos) = expr[start..].find(ident) {
        let at = start + pos;
        let before_ok = at == 0 || {
            let c = b[at - 1] as char;
            !c.is_ascii_alphanumeric() && c != '_'
        };
        let after = at + ident.len();
        let after_ok = after >= b.len() || {
            let c = b[after] as char;
            !c.is_ascii_alphanumeric() && c != '_'
        };
        if before_ok && after_ok {
            return true;
        }
        start = at + ident.len();
    }
    false
}

fn open_parens(s: &str) -> i32 {
    s.chars().fold(0, |acc, c| match c {
        '(' | '[' => acc + 1,
        ')' | ']' => acc - 1,
        _ => acc,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_source_is_embedded() {
        assert!(KERNEL_SOURCE.contains("pallas_call"));
        assert!(KERNEL_SOURCE.contains("t_peak"));
    }

    #[test]
    fn compute_depends_on_tensor_resources_not_links() {
        let m = InfluenceMap::from_kernel();
        let comp = m.params_for(Bottleneck::Compute);
        assert!(comp.contains(&Param::Cores), "{comp:?}");
        assert!(comp.contains(&Param::Sublanes));
        assert!(comp.contains(&Param::SystolicArray));
        assert!(comp.contains(&Param::VectorWidth));
        assert!(!comp.contains(&Param::Links));
        assert!(!comp.contains(&Param::MemChannels));
    }

    #[test]
    fn memory_depends_on_channels_and_l2() {
        let m = InfluenceMap::from_kernel();
        let mem = m.params_for(Bottleneck::Memory);
        assert!(mem.contains(&Param::MemChannels), "{mem:?}");
        assert!(mem.contains(&Param::GbufMb));
        assert!(!mem.contains(&Param::SystolicArray));
    }

    #[test]
    fn network_depends_only_on_links() {
        let m = InfluenceMap::from_kernel();
        assert_eq!(m.params_for(Bottleneck::Network), &[Param::Links]);
    }

    #[test]
    fn area_depends_on_everything() {
        let m = InfluenceMap::from_kernel();
        assert_eq!(m.area_params.len(), N_PARAMS, "{:?}", m.area_params);
    }

    #[test]
    fn paper_example_holds() {
        // "peak vector compute throughput is influenced by core count,
        // sublane count, and vector unit, but has no direct structural
        // dependency on the tensor unit."
        let m = InfluenceMap::from_kernel();
        let v = m.rates.get("v_peak").unwrap();
        assert!(v.contains(&Param::Cores));
        assert!(v.contains(&Param::Sublanes));
        assert!(v.contains(&Param::VectorWidth));
        assert!(!v.contains(&Param::SystolicArray));
    }

    #[test]
    fn render_lists_all_components() {
        let text = InfluenceMap::from_kernel().render();
        assert!(text.contains("compute <-"));
        assert!(text.contains("memory <-"));
        assert!(text.contains("network <- interconnect_link_count"));
        assert!(text.contains("area <-"));
    }

    #[test]
    fn ident_matching_is_word_bounded() {
        assert!(has_ident("sa * sa + x", "sa"));
        assert!(!has_ident("sram * 2", "sa"));
        assert!(!has_ident("x_sa", "sa"));
    }
}
