//! Trajectory Memory (TM): the sample store, failure-pattern mining and
//! the reflection text the Strategy Engine feeds back into prompts
//! (paper §3.4: "reflects on the trajectory history ... to identify past
//! design attempts that failed to meet PPA targets and conclude the
//! patterns to prevent their repetition").

use std::collections::HashSet;

use crate::design::{DesignPoint, Param};
use crate::eval::Metrics;
use crate::pareto::{Objectives, ParetoArchive};

/// One trajectory entry.
#[derive(Debug, Clone, Copy)]
pub struct Sample {
    pub design: DesignPoint,
    pub metrics: Metrics,
    /// Which step of the exploration produced it (0 = seed/sensitivity).
    pub step: usize,
}

/// A move that was tried and made the target metric worse.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct FailedMove {
    pub param: Param,
    pub direction: i32,
    /// Metric index (0 TTFT, 1 TPOT).
    pub metric: usize,
}

/// Trajectory Memory.
#[derive(Debug, Default)]
pub struct TrajectoryMemory {
    pub samples: Vec<Sample>,
    seen: HashSet<DesignPoint>,
    failures: Vec<(FailedMove, u32)>,
    /// Incrementally maintained Pareto front over the samples (ids are
    /// sample indices) — no per-query O(n^2) front recomputation.
    archive: ParetoArchive,
}

impl TrajectoryMemory {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, design: DesignPoint, metrics: Metrics, step: usize) {
        self.seen.insert(design);
        self.archive
            .push_with_id(self.samples.len(), metrics.objectives());
        self.samples.push(Sample { design, metrics, step });
    }

    pub fn contains(&self, d: &DesignPoint) -> bool {
        self.seen.contains(d)
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Record that stepping `param` in `direction` hurt `metric`.
    pub fn record_failure(&mut self, m: FailedMove) {
        for (f, n) in &mut self.failures {
            if *f == m {
                *n += 1;
                return;
            }
        }
        self.failures.push((m, 1));
    }

    /// Moves failed at least `threshold` times for the metric — the
    /// Strategy Engine bans these in the prompt.
    pub fn banned_moves(
        &self,
        metric: usize,
        threshold: u32,
    ) -> Vec<(Param, i32)> {
        self.failures
            .iter()
            .filter(|(f, n)| f.metric == metric && *n >= threshold)
            .map(|(f, _)| (f.param, f.direction))
            .collect()
    }

    /// Reflection text for the strategy prompt.
    pub fn render_reflection(&self, metric: usize) -> String {
        let banned = self.banned_moves(metric, 2);
        if banned.is_empty() {
            return "(no repeated failure patterns yet)\n".to_string();
        }
        let mut out = String::from(
            "Repeatedly unsuccessful moves for this objective:\n",
        );
        for (p, dir) in banned {
            out.push_str(&format!(
                "banned: {} {}\n",
                p.name(),
                if dir > 0 { "+1" } else { "-1" }
            ));
        }
        out
    }

    /// All objective vectors observed so far.
    pub fn objectives(&self) -> Vec<Objectives> {
        self.samples.iter().map(|s| s.metrics.objectives()).collect()
    }

    /// Current Pareto-optimal samples (served from the incremental
    /// archive maintained by [`TrajectoryMemory::record`]).
    pub fn pareto_samples(&self) -> Vec<&Sample> {
        self.archive
            .front_ids()
            .into_iter()
            .map(|i| &self.samples[i])
            .collect()
    }

    /// The best sample for a weighted normalized objective (used to pick
    /// the restart point when exploration stalls).
    pub fn best_weighted(
        &self,
        baseline: &Objectives,
        weights: &Objectives,
    ) -> Option<&Sample> {
        self.samples.iter().min_by(|a, b| {
            let score = |s: &Sample| {
                let o = s.metrics.objectives();
                (0..3)
                    .map(|i| weights[i] * o[i] / baseline[i])
                    .sum::<f64>()
            };
            score(a).total_cmp(&score(b))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(ttft: f32, tpot: f32, area: f32) -> Metrics {
        Metrics {
            ttft_ms: ttft,
            tpot_ms: tpot,
            area_mm2: area,
            stalls: [[ttft, 0.0, 0.0], [0.0, tpot, 0.0]],
            ..Default::default()
        }
    }

    #[test]
    fn records_and_dedups() {
        let mut tm = TrajectoryMemory::new();
        let d = DesignPoint::a100();
        assert!(!tm.contains(&d));
        tm.record(d, m(30.0, 0.4, 800.0), 0);
        assert!(tm.contains(&d));
        assert_eq!(tm.len(), 1);
    }

    #[test]
    fn failures_ban_after_threshold() {
        let mut tm = TrajectoryMemory::new();
        let fm = FailedMove { param: Param::Links, direction: 1, metric: 1 };
        tm.record_failure(fm);
        assert!(tm.banned_moves(1, 2).is_empty());
        tm.record_failure(fm);
        assert_eq!(tm.banned_moves(1, 2), vec![(Param::Links, 1)]);
        // Other metric unaffected.
        assert!(tm.banned_moves(0, 2).is_empty());
        let text = tm.render_reflection(1);
        assert!(text.contains("banned: interconnect_link_count +1"));
    }

    #[test]
    fn pareto_samples_filter_dominated() {
        let mut tm = TrajectoryMemory::new();
        tm.record(DesignPoint::a100(), m(30.0, 0.4, 800.0), 0);
        tm.record(
            DesignPoint::paper_design_a(),
            m(20.0, 0.3, 700.0),
            1,
        );
        tm.record(
            DesignPoint::paper_design_b(),
            m(40.0, 0.5, 900.0),
            2,
        ); // dominated
        let front = tm.pareto_samples();
        assert_eq!(front.len(), 1);
        assert_eq!(front[0].design, DesignPoint::paper_design_a());
    }

    #[test]
    fn incremental_front_matches_batch_pareto_front() {
        use crate::pareto::pareto_front;
        let mut tm = TrajectoryMemory::new();
        // A zig-zag of improving/worsening samples with a duplicate.
        let series = [
            (30.0, 0.40, 800.0),
            (25.0, 0.45, 820.0),
            (25.0, 0.45, 820.0),
            (20.0, 0.50, 700.0),
            (35.0, 0.39, 900.0),
            (19.0, 0.41, 650.0),
        ];
        for (i, (a, b, c)) in series.iter().enumerate() {
            tm.record(DesignPoint::a100(), m(*a, *b, *c), i);
        }
        let batch = pareto_front(&tm.objectives());
        let inc: Vec<usize> = tm
            .pareto_samples()
            .iter()
            .map(|s| s.step)
            .collect();
        assert_eq!(inc, batch);
    }

    #[test]
    fn best_weighted_prefers_balanced_improvement() {
        let mut tm = TrajectoryMemory::new();
        tm.record(DesignPoint::a100(), m(30.0, 0.4, 800.0), 0);
        tm.record(
            DesignPoint::paper_design_a(),
            m(15.0, 0.38, 640.0),
            1,
        );
        let base = [30.0, 0.4, 800.0];
        let best = tm
            .best_weighted(&base, &[1.0, 1.0, 1.0])
            .unwrap();
        assert_eq!(best.design, DesignPoint::paper_design_a());
    }
}
