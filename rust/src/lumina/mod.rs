//! The LUMINA framework (paper §3): automatic acquisition of
//! Architectural Heuristic Knowledge and the LLM-guided exploration loop.
//!
//! * [`quale`] — Qualitative Engine: static analysis of the *actual
//!   simulator source* (the Pallas kernel is embedded at build time) that
//!   derives the Influence Map (which parameters structurally feed which
//!   bandwidth/throughput/metric).
//! * [`quane`] — Quantitative Engine: sensitivity study around the
//!   reference design, assigning numeric influence factors (area
//!   sensitivities are computed from the analytic area model at zero
//!   sample cost; performance sensitivities through the evaluator when
//!   the budget allows — the paper's "focus on power and area when
//!   perturbations are costly").
//! * [`memory`] — Trajectory Memory: every sample, failure patterns,
//!   banned moves, reflection rendering.
//! * [`strategy`] — Strategy Engine: bottleneck analysis over the
//!   critical-path feedback, prompt construction, LLM directive parsing,
//!   and enforcement of the corrective rules from the DSE Benchmark
//!   (§5.2).
//! * [`explore`] — Exploration Engine: directive -> concrete grid design,
//!   dedup, evaluation, TM recording.
//! * [`framework`] — the refinement loop tying it all together.

pub mod explore;
pub mod framework;
pub mod memory;
pub mod quale;
pub mod quane;
pub mod strategy;

pub use framework::{Lumina, LuminaConfig};
pub use quale::InfluenceMap;
pub use quane::Ahk;
