//! Wall-clock micro-bench harness (criterion is unavailable offline).
//!
//! Each `rust/benches/*.rs` target is `harness = false` and drives this:
//! warmup, N timed iterations, and a median/mean/p95 report printed in a
//! stable machine-grepable format plus CSV rows for EXPERIMENTS.md.

use std::time::Instant;

/// The crate's one sanctioned wall-clock handle. All elapsed-time
/// measurement outside `benches/` goes through this so the D002 lint
/// can keep `std::time` confined to this module — replayed runs and
/// golden tests never see host time except through here.
#[derive(Debug, Clone, Copy)]
pub struct Stopwatch {
    t0: Instant,
}

impl Stopwatch {
    /// Start timing now.
    pub fn start() -> Stopwatch {
        Stopwatch { t0: Instant::now() }
    }

    /// Seconds elapsed since [`Stopwatch::start`].
    pub fn elapsed_s(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }
}

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub p95_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.mean_s
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed runs.
pub fn bench(
    name: &str,
    warmup: usize,
    iters: usize,
    mut f: impl FnMut(),
) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    summarize(name, samples)
}

/// Summarize raw per-iteration samples.
pub fn summarize(name: &str, mut samples: Vec<f64>) -> BenchResult {
    assert!(!samples.is_empty());
    samples.sort_by(f64::total_cmp);
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let median = samples[n / 2];
    let p95 = samples[(n as f64 * 0.95) as usize % n];
    let r = BenchResult {
        name: name.to_string(),
        iters: n,
        mean_s: mean,
        median_s: median,
        p95_s: p95,
        min_s: samples[0],
    };
    println!(
        "bench {:<42} iters={:<5} mean={:>10} median={:>10} p95={:>10}",
        r.name,
        r.iters,
        fmt_time(r.mean_s),
        fmt_time(r.median_s),
        fmt_time(r.p95_s),
    );
    r
}

/// Human-scale duration formatting.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}us", s * 1e6)
    } else {
        format!("{:.1}ns", s * 1e9)
    }
}

/// Print a section header so bench output reads like the paper's eval.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let r = bench("noop", 1, 16, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(r.iters, 16);
        assert!(r.min_s <= r.median_s && r.median_s <= r.p95_s);
        assert!(r.mean_s > 0.0);
    }

    #[test]
    fn stopwatch_measures_nonnegative_time() {
        let sw = Stopwatch::start();
        std::hint::black_box(1 + 1);
        let a = sw.elapsed_s();
        let b = sw.elapsed_s();
        assert!(a >= 0.0);
        assert!(b >= a);
    }

    #[test]
    fn fmt_time_scales() {
        assert!(fmt_time(2.0).ends_with('s'));
        assert!(fmt_time(2e-3).ends_with("ms"));
        assert!(fmt_time(2e-6).ends_with("us"));
        assert!(fmt_time(2e-9).ends_with("ns"));
    }

    #[test]
    fn throughput_is_items_over_mean() {
        let r = summarize("x", vec![0.5, 0.5]);
        assert!((r.throughput(100.0) - 200.0).abs() < 1e-9);
    }
}
