//! Little-endian binary codec + FNV-1a checksum for the on-disk memo
//! store (`crate::eval::store`). Kept in `util` so the byte layout has
//! one authoritative, unit-tested home independent of the store's
//! segment-file plumbing.
//!
//! Everything here is explicit-width and little-endian regardless of
//! host byte order, so segment files written on one machine read
//! identically on any other. Floats travel as raw IEEE-754 bit
//! patterns (`to_bits`/`from_bits`) — the store's bit-identity
//! guarantee forbids any text round-trip.

/// FNV-1a 64-bit offset basis.
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// FNV-1a 64-bit prime.
pub const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a 64-bit hash of `bytes`. Dependency-free, stable across
/// platforms and releases (unlike `DefaultHasher`), and cheap enough
/// to checksum every 96-byte record on the append path.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Append `v` to `out` as 4 little-endian bytes.
pub fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Append `v` to `out` as 8 little-endian bytes.
pub fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Append `v`'s IEEE-754 bit pattern to `out` as 4 LE bytes.
pub fn put_f32(out: &mut Vec<u8>, v: f32) {
    put_u32(out, v.to_bits());
}

/// Read a little-endian u32 at `off`; `None` if out of bounds.
pub fn read_u32(bytes: &[u8], off: usize) -> Option<u32> {
    let end = off.checked_add(4)?;
    let raw = bytes.get(off..end)?;
    let mut buf = [0u8; 4];
    buf.copy_from_slice(raw);
    Some(u32::from_le_bytes(buf))
}

/// Read a little-endian u64 at `off`; `None` if out of bounds.
pub fn read_u64(bytes: &[u8], off: usize) -> Option<u64> {
    let end = off.checked_add(8)?;
    let raw = bytes.get(off..end)?;
    let mut buf = [0u8; 8];
    buf.copy_from_slice(raw);
    Some(u64::from_le_bytes(buf))
}

/// Read an f32 (stored as its bit pattern) at `off`.
pub fn read_f32(bytes: &[u8], off: usize) -> Option<f32> {
    read_u32(bytes, off).map(f32::from_bits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a64_matches_reference_vectors() {
        // Published FNV-1a test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn ints_round_trip_little_endian() {
        let mut buf = Vec::new();
        put_u32(&mut buf, 0xdead_beef);
        put_u64(&mut buf, 0x0123_4567_89ab_cdef);
        assert_eq!(buf.len(), 12);
        // Explicit byte order: LSB first.
        assert_eq!(&buf[..4], &[0xef, 0xbe, 0xad, 0xde]);
        assert_eq!(read_u32(&buf, 0), Some(0xdead_beef));
        assert_eq!(read_u64(&buf, 4), Some(0x0123_4567_89ab_cdef));
    }

    #[test]
    fn floats_round_trip_bitwise() {
        // Bit-exact through the codec, including non-finite and
        // negative-zero payloads a text round-trip would mangle.
        let specials = [
            0.0f32,
            -0.0,
            1.5,
            f32::MIN_POSITIVE,
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::NAN,
            1.0e-42, // subnormal
        ];
        let mut buf = Vec::new();
        for v in specials {
            put_f32(&mut buf, v);
        }
        for (i, v) in specials.iter().enumerate() {
            let got = read_f32(&buf, i * 4).unwrap();
            assert_eq!(got.to_bits(), v.to_bits());
        }
    }

    #[test]
    fn out_of_bounds_reads_return_none() {
        let buf = [0u8; 7];
        assert_eq!(read_u32(&buf, 4), None);
        assert_eq!(read_u64(&buf, 0), None);
        assert_eq!(read_u64(&buf, usize::MAX), None);
        assert_eq!(read_f32(&buf, 5), None);
    }
}
