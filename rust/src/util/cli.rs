//! Hand-rolled CLI argument parsing (`clap` is unavailable offline).
//!
//! Supports the subcommand + `--flag value` / `--flag=value` / boolean
//! `--flag` shapes the `lumina` binary needs, with typed accessors and
//! a generated usage string.

use std::collections::BTreeMap;

use crate::{bail, err, Result};

/// Parsed command line: a subcommand, positional args, and `--key value`
/// options.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args> {
        let mut it = raw.into_iter().peekable();
        let mut args = Args::default();
        if let Some(first) = it.next_if(|f| !f.starts_with('-')) {
            args.command = first;
        }
        while let Some(tok) = it.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if rest.is_empty() {
                    bail!("bare '--' is not supported");
                }
                if let Some((k, v)) = rest.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else {
                    // `--key value` if the next token is not an option,
                    // otherwise a boolean flag.
                    match it.next_if(|v| !v.starts_with("--")) {
                        Some(v) => {
                            args.options.insert(rest.to_string(), v);
                        }
                        None => args.flags.push(rest.to_string()),
                    }
                }
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    /// Parse from the process environment.
    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
            || self.opt(key).is_some_and(|v| v == "true" || v == "1")
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.opt(key).unwrap_or(default).to_string()
    }

    /// `--key <path>` with a default, as a `PathBuf`.
    pub fn path_or(
        &self,
        key: &str,
        default: &str,
    ) -> std::path::PathBuf {
        std::path::PathBuf::from(self.str_or(key, default))
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| err!("--{key} must be an integer: {e}")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| err!("--{key} must be a number: {e}")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| err!("--{key} must be an integer: {e}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn parses_subcommand_and_options() {
        let a = parse("explore --budget 20 --model qwen3 --verbose");
        assert_eq!(a.command, "explore");
        assert_eq!(a.opt("budget"), Some("20"));
        assert_eq!(a.opt("model"), Some("qwen3"));
        assert!(a.flag("verbose"));
    }

    #[test]
    fn parses_equals_form() {
        let a = parse("race --samples=1000 --trials=5");
        assert_eq!(a.usize_or("samples", 0).unwrap(), 1000);
        assert_eq!(a.usize_or("trials", 0).unwrap(), 5);
    }

    #[test]
    fn boolean_flag_before_option() {
        let a = parse("bench --fast --out dir");
        assert!(a.flag("fast"));
        assert_eq!(a.opt("out"), Some("dir"));
    }

    #[test]
    fn defaults_and_type_errors() {
        let a = parse("x --n abc");
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
        assert!(a.usize_or("n", 0).is_err());
    }

    #[test]
    fn path_or_builds_pathbufs() {
        let a = parse("lint --out custom/findings.json");
        assert_eq!(
            a.path_or("out", "out/lint_findings.json"),
            std::path::PathBuf::from("custom/findings.json")
        );
        assert_eq!(
            a.path_or("root", "."),
            std::path::PathBuf::from(".")
        );
    }

    #[test]
    fn positional_args() {
        let a = parse("report designA designB --format md");
        assert_eq!(a.positional, vec!["designA", "designB"]);
        assert_eq!(a.opt("format"), Some("md"));
    }
}
