//! Seeded property-testing mini-framework.
//!
//! `proptest` is not available offline, so this module provides the subset
//! the test suite needs: deterministic seeded generators, a `forall` runner
//! that reports the failing case and its seed, and simple shrinking for
//! integer-vector inputs (halving toward a floor). Used throughout the
//! coordinator tests for routing/batching/state invariants.

use crate::stats::rng::Pcg32;

/// Number of cases per property (kept modest: the suite has many
/// properties and CI here is a single core).
pub const DEFAULT_CASES: usize = 128;

/// Run `prop` on `cases` inputs drawn by `gen`. On failure, attempt to
/// shrink via `shrink` and panic with the smallest failing input.
pub fn forall_with<T: Clone + std::fmt::Debug>(
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Pcg32) -> T,
    shrink: impl Fn(&T) -> Vec<T>,
    prop: impl Fn(&T) -> bool,
) {
    let mut rng = Pcg32::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if !prop(&input) {
            let minimal = shrink_loop(input, &shrink, &prop);
            panic!(
                "property failed (seed={seed}, case={case}):\n  input: \
                 {minimal:?}"
            );
        }
    }
}

/// `forall` without shrinking.
pub fn forall<T: Clone + std::fmt::Debug>(
    seed: u64,
    cases: usize,
    gen: impl FnMut(&mut Pcg32) -> T,
    prop: impl Fn(&T) -> bool,
) {
    forall_with(seed, cases, gen, |_| Vec::new(), prop);
}

fn shrink_loop<T: Clone + std::fmt::Debug>(
    mut failing: T,
    shrink: &impl Fn(&T) -> Vec<T>,
    prop: &impl Fn(&T) -> bool,
) -> T {
    // Greedy descent: repeatedly take the first shrunk candidate that
    // still fails, up to a step bound to guarantee termination.
    for _ in 0..1000 {
        let mut advanced = false;
        for cand in shrink(&failing) {
            if !prop(&cand) {
                failing = cand;
                advanced = true;
                break;
            }
        }
        if !advanced {
            break;
        }
    }
    failing
}

/// Shrinker for `Vec<usize>` index vectors: try zeroing and halving each
/// coordinate.
pub fn shrink_indices(v: &Vec<usize>) -> Vec<Vec<usize>> {
    let mut out = Vec::new();
    for i in 0..v.len() {
        if v[i] > 0 {
            let mut a = v.clone();
            a[i] = 0;
            out.push(a);
            let mut b = v.clone();
            b[i] /= 2;
            out.push(b);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        forall(
            1,
            50,
            |rng| {
                n += 1;
                rng.range_usize(0, 100)
            },
            |&x| x < 100,
        );
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        forall(2, 100, |rng| rng.range_usize(0, 10), |&x| x < 9);
    }

    #[test]
    fn shrinking_finds_smaller_case() {
        // Property fails for any vector with sum >= 10; shrinker should
        // reach a near-minimal failing example.
        let failing = shrink_loop(
            vec![50usize, 50, 50],
            &shrink_indices,
            &|v: &Vec<usize>| v.iter().sum::<usize>() < 10,
        );
        let sum: usize = failing.iter().sum();
        assert!(sum >= 10 && sum <= 25, "shrunk to {failing:?}");
    }

    #[test]
    fn deterministic_across_runs() {
        let mut a = Vec::new();
        let mut b = Vec::new();
        forall(7, 10, |rng| { a.push(rng.next_u32()); 0usize }, |_| true);
        forall(7, 10, |rng| { b.push(rng.next_u32()); 0usize }, |_| true);
        assert_eq!(a, b);
    }
}
