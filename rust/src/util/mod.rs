//! Small self-contained substrates (no external crates are available
//! offline; see `crate::error` for the `anyhow` stand-in): JSON, CSV,
//! CLI parsing, a seeded property-testing mini-framework, and a
//! wall-clock bench timer.

pub mod bench;
pub mod bin;
pub mod cli;
pub mod csv;
pub mod json;
pub mod prop;
