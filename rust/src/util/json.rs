//! Minimal JSON parser/emitter.
//!
//! `serde` is not available offline, and the only JSON this project touches
//! is `artifacts/meta.json` (written by our own `aot.py`) plus experiment
//! reports we emit ourselves — a small, total parser is sufficient and
//! keeps the crate dependency-free.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::{bail, err, Result};

/// A JSON value. Numbers are kept as f64 (this project never needs u64
/// precision beyond 2^53).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["a"]["b"]`-style access.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()
            .and_then(|o| o.get(key))
            .ok_or_else(|| err!("missing key {key:?}"))
    }

    /// Serialize with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String, indent: usize) {
        let pad = "  ".repeat(indent + 1);
        let pad0 = "  ".repeat(indent);
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                out.push_str(if *b { "true" } else { "false" })
            }
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => escape_into(s, out),
            Json::Arr(a) if a.is_empty() => out.push_str("[]"),
            Json::Arr(a) => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    out.push_str(&pad);
                    v.write(out, indent + 1);
                    if i + 1 < a.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&pad0);
                out.push(']');
            }
            Json::Obj(o) if o.is_empty() => out.push_str("{}"),
            Json::Obj(o) => {
                out.push_str("{\n");
                for (i, (k, v)) in o.iter().enumerate() {
                    out.push_str(&pad);
                    escape_into(k, out);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                    if i + 1 < o.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&pad0);
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Build a `Json::Obj` from key/value pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| err!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? == c {
            self.i += 1;
            Ok(())
        } else {
            bail!(
                "expected {:?} at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek()? as char
            )
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            map.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                c => bail!("expected ',' or '}}', found {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                c => bail!("expected ',' or ']', found {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| err!("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)?,
                                16,
                            )?;
                            self.i += 4;
                            s.push(
                                char::from_u32(code)
                                    .ok_or_else(|| err!("bad codepoint"))?,
                            );
                        }
                        e => bail!("bad escape \\{}", e as char),
                    }
                }
                c => {
                    // Re-decode UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let chunk = self
                            .b
                            .get(start..start + len)
                            .ok_or_else(|| err!("bad utf-8"))?;
                        s.push_str(std::str::from_utf8(chunk)?);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| {
            err!("bad number {text:?} at byte {start}: {e}")
        })?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("c"));
    }

    #[test]
    fn roundtrips_pretty() {
        let src = r#"{"batches": {"1": "roofline_b1.hlo.txt"},
                      "n_params": 8, "x": [1.5, true, null]}"#;
        let v = Json::parse(src).unwrap();
        let again = Json::parse(&v.pretty()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{,}").is_err());
        assert!(Json::parse("[1 2]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn parses_unicode_and_escapes() {
        let v = Json::parse("\"\\u00e9 caf\u{00e9}\"").unwrap();
        assert_eq!(v.as_str(), Some("\u{00e9} caf\u{00e9}"));
    }
}
