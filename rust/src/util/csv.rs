//! Tiny CSV writer used by the figure/bench drivers to emit the series the
//! paper plots (one file per figure, see `out/` after `cargo bench`).

use std::fs;
use std::path::Path;

use crate::error::Context;
use crate::Result;

/// An in-memory CSV table with a fixed header.
pub struct Csv {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Csv {
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Push a row; panics (in debug) on arity mismatch — the writers are
    /// all internal so this is a programming error, not input error.
    pub fn row(&mut self, cells: Vec<String>) {
        debug_assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells);
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Write to `path`, creating parent directories.
    pub fn write(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)
                .with_context(|| format!("mkdir -p {dir:?}"))?;
        }
        fs::write(path, self.to_string())
            .with_context(|| format!("write {path:?}"))
    }
}

impl std::fmt::Display for Csv {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut out = String::new();
        out.push_str(&self.header.join(","));
        out.push('\n');
        for r in &self.rows {
            let quoted: Vec<String> =
                r.iter().map(|c| quote(c)).collect();
            out.push_str(&quoted.join(","));
            out.push('\n');
        }
        f.write_str(&out)
    }
}

fn quote(cell: &str) -> String {
    if cell.contains(',') || cell.contains('"') || cell.contains('\n') {
        format!("\"{}\"", cell.replace('"', "\"\""))
    } else {
        cell.to_string()
    }
}

/// Format helper: shorthand for building a row of mixed display values.
#[macro_export]
macro_rules! csv_row {
    ($($v:expr),* $(,)?) => {
        vec![$(format!("{}", $v)),*]
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let mut c = Csv::new(&["a", "b"]);
        c.row(csv_row![1, 2.5]);
        c.row(csv_row!["x,y", "q\"p"]);
        let s = c.to_string();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[0], "a,b");
        assert_eq!(lines[1], "1,2.5");
        assert_eq!(lines[2], "\"x,y\",\"q\"\"p\"");
    }

    #[test]
    fn writes_file_with_parents() {
        let dir = std::env::temp_dir().join("lumina_csv_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut c = Csv::new(&["v"]);
        c.row(csv_row![42]);
        let path = dir.join("sub/fig.csv");
        c.write(&path).unwrap();
        assert!(std::fs::read_to_string(path).unwrap().contains("42"));
    }
}
