//! Rule registry for the determinism lint: ids, severities, and the
//! identifier/method vocabularies each rule matches on.
//!
//! The vocabularies are grounded in this repo, not generic Rust:
//! [`RNG_METHODS`] is exactly the public surface of
//! [`crate::stats::rng::Pcg32`], and [`DET_MODULES`] is the set of
//! top-level modules whose outputs are pinned bit-for-bit by golden
//! tests (ask/tell trajectories, SoA equivalence, checkpoint replay).

/// Finding severity. `--deny-warnings` (the CI gate) promotes
/// warnings to failures; without it only errors fail the run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Warning,
    Error,
}

impl Severity {
    pub fn as_str(self) -> &'static str {
        match self {
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

/// One lint rule: stable id, severity, and human-facing docs (the
/// README rule table is generated from this registry's fields).
#[derive(Debug, Clone, Copy)]
pub struct Rule {
    pub id: &'static str,
    pub severity: Severity,
    pub title: &'static str,
    pub rationale: &'static str,
}

/// Every rule the scanner can emit, in id order.
pub const RULES: [Rule; 11] = [
    Rule {
        id: "D001",
        severity: Severity::Error,
        title: "hash-container iteration in a deterministic module",
        rationale: "HashMap/HashSet iteration order varies per \
                    process; inside eval/, dse/, pareto/, sim/, \
                    baselines/ it can leak into golden-tested \
                    results. Keyed lookup is fine; drains are not.",
    },
    Rule {
        id: "D002",
        severity: Severity::Warning,
        title: "wall-clock read outside util/bench.rs",
        rationale: "Instant::now/SystemTime make output depend on \
                    the host; all timing goes through the \
                    util::bench helpers so replay stays bit-exact.",
    },
    Rule {
        id: "D003",
        severity: Severity::Error,
        title: "entropy-seeded RNG",
        rationale: "thread_rng/from_entropy/OsRng break replay \
                    everywhere, tests included; all randomness \
                    routes through the seeded stats::rng::Pcg32.",
    },
    Rule {
        id: "D004",
        severity: Severity::Error,
        title: "RNG draw inside a DseSession tell body",
        rationale: "the checkpoint-replay invariant: all draws \
                    happen in ask, tell only records. A draw in \
                    tell desynchronizes resumed trajectories.",
    },
    Rule {
        id: "F001",
        severity: Severity::Error,
        title: "float reduction over an unordered container",
        rationale: "float addition is not associative; summing a \
                    hash container's values in iteration order \
                    yields run-dependent bits.",
    },
    Rule {
        id: "M001",
        severity: Severity::Error,
        title: "mirrored constant value drift",
        rationale: "a symbol declared on both sides of a mirror \
                    pair carries different literals; the Rust \
                    simulators and the Python kernels would \
                    silently disagree. The finding names the exact \
                    declaration site on both sides.",
    },
    Rule {
        id: "M002",
        severity: Severity::Error,
        title: "one-sided mirror symbol",
        rationale: "a symbol (or registry entry) exists in only one \
                    half of a declared mirror pair; the other side \
                    either lost it or never gained it — both break \
                    the cross-language contract.",
    },
    Rule {
        id: "M003",
        severity: Severity::Error,
        title: "pinned-oracle divergence",
        rationale: "the same named oracle literal (A100 reference \
                    pins) is duplicated across Rust files; if one \
                    copy drifts, tests pin different physics than \
                    the docs claim.",
    },
    Rule {
        id: "M004",
        severity: Severity::Warning,
        title: "stale mirror declaration",
        rationale: "a MIRROR-of doc comment names a path, symbol, \
                    or test that no longer exists; stale pointers \
                    send maintainers to the wrong place exactly \
                    when drift happens.",
    },
    Rule {
        id: "P001",
        severity: Severity::Warning,
        title: "unwrap/expect in library code",
        rationale: "library paths return crate::error::Error so \
                    callers can handle failure; panics are for \
                    provably-unreachable states, which need a \
                    reasoned waiver.",
    },
    Rule {
        id: "W001",
        severity: Severity::Warning,
        title: "malformed or unjustified waiver",
        rationale: "a waiver without a reason (or naming an \
                    unknown rule) is ignored and flagged; the \
                    audit trail is the point. W001 itself cannot \
                    be waived.",
    },
];

/// Look up a rule by id.
pub fn by_id(id: &str) -> Option<&'static Rule> {
    RULES.iter().find(|r| r.id == id)
}

/// Severity for a rule id (unknown ids are treated as errors; the
/// scanner only emits ids from [`RULES`]).
pub fn severity_of(id: &str) -> Severity {
    match by_id(id) {
        Some(r) => r.severity,
        None => Severity::Error,
    }
}

/// Iteration-order-sensitive methods on hash containers (D001 and
/// F001 receivers). Keyed ops (`get`, `insert`, `contains_key`,
/// `remove`) are deliberately absent: keyed lookup is deterministic.
pub const ORDER_METHODS: [&str; 8] = [
    "iter",
    "iter_mut",
    "into_iter",
    "keys",
    "values",
    "values_mut",
    "drain",
    "retain",
];

/// The draw surface of `stats::rng::Pcg32` (D004). `fork` is here
/// because forking advances parent state just like a draw.
pub const RNG_METHODS: [&str; 10] = [
    "next_u32",
    "next_u64",
    "f64",
    "range_usize",
    "choose",
    "chance",
    "normal",
    "shuffle",
    "sample_indices",
    "fork",
];

/// Entropy sources (D003): any appearance is a finding — these are
/// the std/rand idents a future dependency or hand-rolled shim would
/// surface under.
pub const ENTROPY_IDENTS: [&str; 5] = [
    "thread_rng",
    "ThreadRng",
    "from_entropy",
    "OsRng",
    "getrandom",
];

/// Top-level modules under `src/` whose results are pinned by golden
/// tests; D001/F001 only fire inside these.
pub const DET_MODULES: [&str; 5] =
    ["eval", "dse", "pareto", "sim", "baselines"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_is_sorted_and_unique() {
        for w in RULES.windows(2) {
            assert!(w[0].id < w[1].id);
        }
    }

    #[test]
    fn lookup_round_trips() {
        for r in &RULES {
            assert_eq!(by_id(r.id).map(|x| x.id), Some(r.id));
            assert_eq!(severity_of(r.id), r.severity);
        }
        assert!(by_id("D999").is_none());
        assert_eq!(severity_of("D999"), Severity::Error);
    }
}
