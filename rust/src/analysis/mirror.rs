//! Cross-language mirror-drift differ (`lumina lint --mirror`).
//!
//! The Rust simulators and the Python compiler share one model
//! contract: architecture constants, design-encoding bounds, and the
//! scenario registry are declared twice, once per language, and the
//! pair must stay in lockstep. This engine proves the contract
//! statically: it parses both sides of every pair declared in
//! [`crate::analysis::mirrors`] into typed symbol tables
//! ([`crate::analysis::extract`]) and diffs them:
//!
//! * **M001** — same symbol, different literal (exact `file:line`
//!   on both sides);
//! * **M002** — a symbol or registry entry exists on one side only;
//! * **M003** — a named oracle pin (A100 reference values) drifted
//!   between the Rust files that duplicate it;
//! * **M004** — a MIRROR doc pointer names a path, symbol, or test
//!   that no longer exists.
//!
//! Findings flow through the same tail as the determinism lint:
//! inline waivers (`// lumina: allow(M001) reason`, also `#`
//! comments on the Python side), the sorted [`Report`], the JSON
//! artifact, and the `--deny-warnings` CI gate.

use std::collections::{BTreeMap, BTreeSet};
use std::fs;
use std::path::{Path, PathBuf};

use crate::analysis::extract::{self, PyClass, Sym, Value};
use crate::analysis::lexer::{Tok, TokKind};
use crate::analysis::mirrors::{
    MirrorKind, MirrorPair, OraclePin, PAIRS, PINS,
};
use crate::analysis::{lexer, pylex, rules, waiver, Finding, Report};
use crate::error::Context;
use crate::Result;

/// Doc-comment path words are only treated as repo paths when they
/// start with one of these roots; everything else ("names/specs" in
/// prose) is left alone.
const PATH_ROOTS: [&str; 4] = ["rust/", "python/", "tests/", "src/"];

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Lang {
    Rust,
    Py,
}

struct SrcFile {
    lang: Lang,
    text: String,
}

/// A finding before waiver application.
struct Raw {
    rule: &'static str,
    file: String,
    line: u32,
    message: String,
}

/// A resolved numeric field: value for comparison, source text for
/// display, declaration site for the finding anchor.
#[derive(Debug, Clone)]
struct Lit {
    v: f64,
    text: String,
    file: String,
    line: u32,
}

/// A fully resolved scenario spec: field name -> literal.
type Spec = BTreeMap<String, Lit>;

/// Check the production manifest against the repo at `root` (the
/// directory holding `rust/` and `python/`).
pub fn check_repo(root: &Path) -> Result<Report> {
    check(root, &PAIRS, &PINS)
}

/// Check an explicit manifest (fixture corpora use their own).
pub fn check(
    root: &Path,
    pairs: &[MirrorPair],
    pins: &[OraclePin],
) -> Result<Report> {
    let mut files: BTreeMap<String, SrcFile> = BTreeMap::new();
    for pair in pairs {
        load(&mut files, root, pair.rust_path)?;
        for aux in pair.rust_aux {
            load(&mut files, root, aux)?;
        }
        load(&mut files, root, pair.python_path)?;
    }
    for pin in pins {
        for f in pin.files {
            load(&mut files, root, f)?;
        }
    }

    let mut raw: Vec<Raw> = Vec::new();
    for pair in pairs {
        check_pair(pair, &files, &mut raw);
    }
    for pin in pins {
        check_pin(pin, &files, &mut raw);
    }
    check_docs(root, pairs, &files, &mut raw);

    let mut findings: Vec<Finding> = Vec::new();
    for (rel, f) in &files {
        let lexed = match f.lang {
            Lang::Rust => lexer::lex(&f.text),
            Lang::Py => pylex::lex_py(&f.text),
        };
        let (waivers, w001) = waiver::parse(&lexed.comments);
        for r in raw.iter().filter(|r| &r.file == rel) {
            let w = waivers.iter().find(|wv| {
                wv.rule == r.rule
                    && (wv.line == r.line || wv.line + 1 == r.line)
            });
            findings.push(Finding {
                rule: r.rule.to_string(),
                severity: rules::severity_of(r.rule),
                file: r.file.clone(),
                line: r.line,
                message: r.message.clone(),
                waived: w.is_some(),
                waiver_reason: w.map(|wv| wv.reason.clone()),
            });
        }
        for (line, message) in w001 {
            findings.push(Finding {
                rule: "W001".to_string(),
                severity: rules::severity_of("W001"),
                file: rel.clone(),
                line,
                message,
                waived: false,
                waiver_reason: None,
            });
        }
    }
    findings.sort_by(|a, b| {
        (&a.file, a.line, &a.rule, &a.message)
            .cmp(&(&b.file, b.line, &b.rule, &b.message))
    });
    Ok(Report {
        engine: "mirror".to_string(),
        root: root.display().to_string().replace('\\', "/"),
        files: files.len(),
        findings,
    })
}

fn load(
    files: &mut BTreeMap<String, SrcFile>,
    root: &Path,
    rel: &str,
) -> Result<()> {
    if files.contains_key(rel) {
        return Ok(());
    }
    let path = root.join(rel);
    let text = fs::read_to_string(&path).with_context(|| {
        format!("mirror: read {}", path.display())
    })?;
    let lang = if rel.ends_with(".py") {
        Lang::Py
    } else {
        Lang::Rust
    };
    files.insert(rel.to_string(), SrcFile { lang, text });
    Ok(())
}

fn check_pair(
    pair: &MirrorPair,
    files: &BTreeMap<String, SrcFile>,
    raw: &mut Vec<Raw>,
) {
    match pair.kind {
        MirrorKind::Consts => diff_consts(pair, files, raw),
        MirrorKind::Registry { symbol } => {
            diff_registry(pair, symbol, files, raw);
        }
    }
}

// ---------------------------------------------------------------
// Flat constant pairs (M001/M002 per symbol)
// ---------------------------------------------------------------

fn diff_consts(
    pair: &MirrorPair,
    files: &BTreeMap<String, SrcFile>,
    raw: &mut Vec<Raw>,
) {
    let Some(rf) = files.get(pair.rust_path) else { return };
    let Some(pf) = files.get(pair.python_path) else { return };
    let rsyms = extract::extract_rust(&rf.text);
    let pmod = extract::extract_py(&pf.text);
    let rmap: BTreeMap<&str, &Sym> = rsyms
        .iter()
        .filter(|s| pair.rust_filter.keeps(&s.name))
        .map(|s| (s.name.as_str(), s))
        .collect();
    let pmap: BTreeMap<&str, &Sym> = pmod
        .syms
        .iter()
        .filter(|s| pair.python_filter.keeps(&s.name))
        .map(|s| (s.name.as_str(), s))
        .collect();
    let names: BTreeSet<&str> =
        rmap.keys().chain(pmap.keys()).copied().collect();
    for name in names {
        match (rmap.get(name), pmap.get(name)) {
            (Some(r), Some(p)) => {
                diff_values(pair, name, r, p, raw);
            }
            (Some(r), None) => raw.push(Raw {
                rule: "M002",
                file: pair.rust_path.to_string(),
                line: r.line,
                message: format!(
                    "`{}` only declared in {}; missing from {} \
                     (mirror pair `{}`)",
                    name, pair.rust_path, pair.python_path, pair.name
                ),
            }),
            (None, Some(p)) => raw.push(Raw {
                rule: "M002",
                file: pair.python_path.to_string(),
                line: p.line,
                message: format!(
                    "`{}` only declared in {}; missing from {} \
                     (mirror pair `{}`)",
                    name, pair.python_path, pair.rust_path, pair.name
                ),
            }),
            (None, None) => {}
        }
    }
}

/// Compare two same-named symbols. Only like kinds are compared
/// (number vs number, string vs string); anything else — arrays,
/// structs, hex literals, expressions — is presence-only.
fn diff_values(
    pair: &MirrorPair,
    name: &str,
    r: &Sym,
    p: &Sym,
    raw: &mut Vec<Raw>,
) {
    let drift = match (&r.value, &p.value) {
        (
            Value::Num { v: rv, text: rt, .. },
            Value::Num { v: pv, text: pt, .. },
        ) => (rv != pv).then(|| (rt.clone(), pt.clone())),
        (Value::Str { s: rs, .. }, Value::Str { s: ps, .. }) => {
            (rs != ps).then(|| {
                (format!("\"{rs}\""), format!("\"{ps}\""))
            })
        }
        _ => None,
    };
    if let Some((rt, pt)) = drift {
        raw.push(Raw {
            rule: "M001",
            file: pair.rust_path.to_string(),
            line: r.line,
            message: format!(
                "`{}` drifted: {}:{} has `{}`, {}:{} has `{}`",
                name,
                pair.rust_path,
                r.line,
                rt,
                pair.python_path,
                p.line,
                pt
            ),
        });
    }
}

// ---------------------------------------------------------------
// Scenario registries (named specs resolved on both sides)
// ---------------------------------------------------------------

/// Last path segment: `spec::GPT3_175B` -> `GPT3_175B`,
/// `dataclasses.replace` -> `replace`.
fn tail(name: &str) -> &str {
    let t = name.rsplit("::").next().unwrap_or(name);
    t.rsplit('.').next().unwrap_or(t)
}

fn resolve_rust_spec(
    v: &Value,
    env: &BTreeMap<String, Spec>,
    file: &str,
) -> Spec {
    match v {
        Value::Ref(r) => {
            env.get(tail(r)).cloned().unwrap_or_default()
        }
        Value::Struct { fields, base, .. } => {
            let mut spec = match base {
                Some(b) => {
                    env.get(tail(b)).cloned().unwrap_or_default()
                }
                None => Spec::new(),
            };
            for (fname, fval) in fields {
                if let Value::Num { v, text, line } = fval {
                    spec.insert(
                        fname.clone(),
                        Lit {
                            v: *v,
                            text: text.clone(),
                            file: file.to_string(),
                            line: *line,
                        },
                    );
                }
            }
            spec
        }
        _ => Spec::new(),
    }
}

/// Extract the Rust side of a registry pair: every scenario name
/// with its fully resolved spec. Named specs may live in aux files
/// (processed first, source order preserved within each file).
fn rust_scenarios(
    pair: &MirrorPair,
    symbol: &str,
    files: &BTreeMap<String, SrcFile>,
) -> Vec<(String, u32, Spec)> {
    let mut env: BTreeMap<String, Spec> = BTreeMap::new();
    let mut reg: Option<(String, Value)> = None;
    let mut sources: Vec<&str> = pair.rust_aux.to_vec();
    sources.push(pair.rust_path);
    for rel in sources {
        let Some(f) = files.get(rel) else { continue };
        for sym in extract::extract_rust(&f.text) {
            if sym.name == symbol {
                reg = Some((rel.to_string(), sym.value));
                continue;
            }
            let spec = resolve_rust_spec(&sym.value, &env, rel);
            if !spec.is_empty() {
                env.insert(sym.name, spec);
            }
        }
    }
    let mut out = Vec::new();
    let Some((reg_file, Value::Arr(items))) = reg else {
        return out;
    };
    for item in &items {
        let Value::Struct { fields, .. } = item else { continue };
        let mut name: Option<(String, u32)> = None;
        let mut spec = Spec::new();
        for (fname, fval) in fields {
            if fname == "name" {
                if let Value::Str { s, line } = fval {
                    name = Some((s.clone(), *line));
                }
            } else if fname == "spec" {
                spec = resolve_rust_spec(fval, &env, &reg_file);
            }
        }
        if let Some((n, line)) = name {
            out.push((n, line, spec));
        }
    }
    out
}

fn py_class_defaults(c: &PyClass, file: &str) -> Spec {
    let mut spec = Spec::new();
    for f in &c.fields {
        if let Value::Num { v, text, line } = &f.value {
            spec.insert(
                f.name.clone(),
                Lit {
                    v: *v,
                    text: text.clone(),
                    file: file.to_string(),
                    line: *line,
                },
            );
        }
    }
    spec
}

/// `WorkloadSpec.__post_init__` models GQA: a `n_kv_heads` left at
/// its `None` default resolves to `n_heads`. Replicated here so
/// defaulted Python scenarios compare field-complete against the
/// always-explicit Rust structs.
fn gqa_default(spec: &mut Spec) {
    if !spec.contains_key("n_kv_heads") {
        if let Some(h) = spec.get("n_heads").cloned() {
            spec.insert("n_kv_heads".to_string(), h);
        }
    }
}

fn resolve_py_spec(
    v: &Value,
    env: &BTreeMap<String, Spec>,
    classes: &BTreeMap<String, Spec>,
    file: &str,
) -> Spec {
    match v {
        Value::Ref(r) => {
            env.get(tail(r)).cloned().unwrap_or_default()
        }
        Value::Call { name, args, kwargs } => {
            let callee = tail(name);
            let mut spec = if callee == "replace" {
                match args.first() {
                    Some(base) => {
                        resolve_py_spec(base, env, classes, file)
                    }
                    None => Spec::new(),
                }
            } else {
                match classes.get(callee) {
                    Some(defaults) => defaults.clone(),
                    None => return Spec::new(),
                }
            };
            for (kname, kval) in kwargs {
                if let Value::Num { v, text, line } = kval {
                    spec.insert(
                        kname.clone(),
                        Lit {
                            v: *v,
                            text: text.clone(),
                            file: file.to_string(),
                            line: *line,
                        },
                    );
                }
                // An explicit `field=None` falls back to the
                // post-init default: drop it so gqa_default
                // re-fills.
                if matches!(kval, Value::NoneLit) {
                    spec.remove(kname);
                }
            }
            gqa_default(&mut spec);
            spec
        }
        _ => Spec::new(),
    }
}

/// Extract the Python side of a registry pair: `symbol` must be a
/// module-level dict of name -> spec expression.
fn py_scenarios(
    pair: &MirrorPair,
    symbol: &str,
    files: &BTreeMap<String, SrcFile>,
) -> Vec<(String, u32, Spec)> {
    let Some(f) = files.get(pair.python_path) else {
        return Vec::new();
    };
    let module = extract::extract_py(&f.text);
    let classes: BTreeMap<String, Spec> = module
        .classes
        .iter()
        .map(|c| {
            (c.name.clone(), py_class_defaults(c, pair.python_path))
        })
        .collect();
    let mut env: BTreeMap<String, Spec> = BTreeMap::new();
    let mut reg: Option<&Value> = None;
    for sym in &module.syms {
        if sym.name == symbol {
            reg = Some(&sym.value);
            continue;
        }
        let spec = resolve_py_spec(
            &sym.value,
            &env,
            &classes,
            pair.python_path,
        );
        if !spec.is_empty() {
            env.insert(sym.name.clone(), spec);
        }
    }
    let mut out = Vec::new();
    let Some(Value::Dict(entries)) = reg else { return out };
    for (key, val) in entries {
        let Value::Str { s, line } = key else { continue };
        let spec = resolve_py_spec(
            val,
            &env,
            &classes,
            pair.python_path,
        );
        out.push((s.clone(), *line, spec));
    }
    out
}

fn diff_registry(
    pair: &MirrorPair,
    symbol: &str,
    files: &BTreeMap<String, SrcFile>,
    raw: &mut Vec<Raw>,
) {
    let rs = rust_scenarios(pair, symbol, files);
    let py = py_scenarios(pair, symbol, files);
    let rmap: BTreeMap<&str, (u32, &Spec)> = rs
        .iter()
        .map(|(n, l, s)| (n.as_str(), (*l, s)))
        .collect();
    let pmap: BTreeMap<&str, (u32, &Spec)> = py
        .iter()
        .map(|(n, l, s)| (n.as_str(), (*l, s)))
        .collect();
    let names: BTreeSet<&str> =
        rmap.keys().chain(pmap.keys()).copied().collect();
    for name in names {
        match (rmap.get(name), pmap.get(name)) {
            (Some((_, rspec)), Some((_, pspec))) => {
                if rspec.is_empty() || pspec.is_empty() {
                    // Resolution failed on one side (unknown base,
                    // opaque expression): presence-only.
                    continue;
                }
                diff_specs(pair, name, rspec, pspec, raw);
            }
            (Some((rl, _)), None) => raw.push(Raw {
                rule: "M002",
                file: pair.rust_path.to_string(),
                line: *rl,
                message: format!(
                    "scenario `{}` only registered in {}; missing \
                     from {} (mirror pair `{}`)",
                    name,
                    pair.rust_path,
                    pair.python_path,
                    pair.name
                ),
            }),
            (None, Some((pl, _))) => raw.push(Raw {
                rule: "M002",
                file: pair.python_path.to_string(),
                line: *pl,
                message: format!(
                    "scenario `{}` only registered in {}; missing \
                     from {} (mirror pair `{}`)",
                    name,
                    pair.python_path,
                    pair.rust_path,
                    pair.name
                ),
            }),
            (None, None) => {}
        }
    }
}

fn diff_specs(
    pair: &MirrorPair,
    name: &str,
    rspec: &Spec,
    pspec: &Spec,
    raw: &mut Vec<Raw>,
) {
    let fields: BTreeSet<&str> = rspec
        .keys()
        .chain(pspec.keys())
        .map(String::as_str)
        .collect();
    for fname in fields {
        match (rspec.get(fname), pspec.get(fname)) {
            (Some(r), Some(p)) => {
                if r.v != p.v {
                    raw.push(Raw {
                        rule: "M001",
                        file: r.file.clone(),
                        line: r.line,
                        message: format!(
                            "scenario `{}` field `{}` drifted: \
                             {}:{} has `{}`, {}:{} has `{}`",
                            name, fname, r.file, r.line, r.text,
                            p.file, p.line, p.text
                        ),
                    });
                }
            }
            (Some(r), None) => raw.push(Raw {
                rule: "M002",
                file: r.file.clone(),
                line: r.line,
                message: format!(
                    "scenario `{}` field `{}` only set in {}; \
                     missing from {} (mirror pair `{}`)",
                    name, fname, r.file, pair.python_path, pair.name
                ),
            }),
            (None, Some(p)) => raw.push(Raw {
                rule: "M002",
                file: p.file.clone(),
                line: p.line,
                message: format!(
                    "scenario `{}` field `{}` only set in {}; \
                     missing from {} (mirror pair `{}`)",
                    name, fname, p.file, pair.rust_path, pair.name
                ),
            }),
            (None, None) => {}
        }
    }
}

// ---------------------------------------------------------------
// Oracle pins (M003)
// ---------------------------------------------------------------

fn is_punct(t: &Tok<'_>, s: &str) -> bool {
    t.kind == TokKind::Punct && t.text == s
}

/// Scan one Rust file for `(m.<field> - <literal>)` pin sites. A
/// file passes if ANY occurrence matches the canonical value (files
/// legitimately pin other scenarios on the same fields); otherwise
/// the occurrence closest to the canonical value anchors the
/// finding.
fn check_pin(
    pin: &OraclePin,
    files: &BTreeMap<String, SrcFile>,
    raw: &mut Vec<Raw>,
) {
    let Ok(want) = pin.value.parse::<f64>() else { return };
    for rel in pin.files {
        let Some(f) = files.get(*rel) else { continue };
        let lexed = lexer::lex(&f.text);
        let toks = &lexed.toks;
        let mut occs: Vec<(f64, String, u32)> = Vec::new();
        for i in 0..toks.len() {
            if !toks[i].is_ident(pin.field) {
                continue;
            }
            if i + 2 >= toks.len() || !is_punct(&toks[i + 1], "-") {
                continue;
            }
            if let Some((v, text, _)) =
                extract::join_number(toks, i + 2)
            {
                occs.push((v, text, toks[i + 2].line));
            }
        }
        if occs.is_empty() {
            raw.push(Raw {
                rule: "M003",
                file: rel.to_string(),
                line: 1,
                message: format!(
                    "oracle pin `{}` (`{}`) not found in {}",
                    pin.name, pin.field, rel
                ),
            });
            continue;
        }
        if occs.iter().any(|o| o.0 == want) {
            continue;
        }
        let mut best = &occs[0];
        for o in &occs[1..] {
            if (o.0 - want).abs() < (best.0 - want).abs() {
                best = o;
            }
        }
        raw.push(Raw {
            rule: "M003",
            file: rel.to_string(),
            line: best.2,
            message: format!(
                "oracle pin `{}` (`{}`) diverged: found `{}`, \
                 canonical is `{}`",
                pin.name, pin.field, best.1, pin.value
            ),
        });
    }
}

// ---------------------------------------------------------------
// Stale mirror declarations (M004)
// ---------------------------------------------------------------

fn check_docs(
    root: &Path,
    pairs: &[MirrorPair],
    files: &BTreeMap<String, SrcFile>,
    raw: &mut Vec<Raw>,
) {
    let mut members: BTreeMap<&str, Vec<&str>> = BTreeMap::new();
    for pair in pairs {
        members.entry(pair.rust_path).or_default().push(pair.name);
        members
            .entry(pair.python_path)
            .or_default()
            .push(pair.name);
    }
    let corpus = test_corpus(root, files);
    for (rel, pair_names) in &members {
        let Some(f) = files.get(*rel) else { continue };
        let lines = doc_lines(f);
        let has_marker = lines.iter().any(|(_, t)| {
            t.to_ascii_lowercase().contains("mirror")
        });
        if !has_marker {
            raw.push(Raw {
                rule: "M004",
                file: rel.to_string(),
                line: 1,
                message: format!(
                    "mirror pair file carries no MIRROR marker \
                     comment (pairs: {})",
                    pair_names.join(", ")
                ),
            });
        }
        for (line, text) in &lines {
            check_doc_line(root, rel, *line, text, &corpus, raw);
        }
    }
}

/// Comment lines (plus the module docstring, for Python) with their
/// 1-based line numbers.
fn doc_lines(f: &SrcFile) -> Vec<(u32, String)> {
    let mut out: Vec<(u32, String)> = Vec::new();
    match f.lang {
        Lang::Rust => {
            for (line, text) in lexer::lex(&f.text).comments {
                out.push((line, text.to_string()));
            }
        }
        Lang::Py => {
            let lexed = pylex::lex_py(&f.text);
            for (line, text) in &lexed.comments {
                out.push((*line, (*text).to_string()));
            }
            if let Some(t) = lexed.toks.first() {
                if t.kind == TokKind::Str {
                    for (k, seg) in t.text.split('\n').enumerate() {
                        out.push((
                            t.line + k as u32,
                            seg.to_string(),
                        ));
                    }
                }
            }
        }
    }
    out.sort_by_key(|(l, _)| *l);
    out
}

/// A doc line is checked when it mentions "mirror", or names a test
/// in backticks. Two checks: path-shaped words must exist (relative
/// to the repo root or its `rust/` subtree, `::SYMBOL` suffixes
/// must resolve inside the target file), and backticked snake_case
/// idents on test lines must name a live `fn`/`def`.
fn check_doc_line(
    root: &Path,
    rel: &str,
    line: u32,
    text: &str,
    corpus: &[(Lang, String)],
    raw: &mut Vec<Raw>,
) {
    let lower = text.to_ascii_lowercase();
    let mentions_test = lower.contains("test") && text.contains('`');
    if !lower.contains("mirror") && !mentions_test {
        return;
    }
    for word in text.split_whitespace() {
        let w = word
            .trim_matches(|c: char| "`()\",;:'<>".contains(c))
            .trim_end_matches(['.', ',']);
        if w.contains('{') || w.contains('*') {
            // Brace-glob shorthand, not a literal path.
            continue;
        }
        if !PATH_ROOTS.iter().any(|p| w.starts_with(p)) {
            continue;
        }
        let (path, sym) = match w.split_once("::") {
            Some((p, s)) => (p, Some(s)),
            None => (w, None),
        };
        let path = path.trim_end_matches('/');
        let Some(target) = resolve_path(root, path) else {
            raw.push(Raw {
                rule: "M004",
                file: rel.to_string(),
                line,
                message: format!(
                    "stale mirror reference: `{path}` does not \
                     exist"
                ),
            });
            continue;
        };
        if let Some(sym) = sym {
            let found = fs::read_to_string(&target)
                .map(|t| t.contains(sym))
                .unwrap_or(false);
            if !found {
                raw.push(Raw {
                    rule: "M004",
                    file: rel.to_string(),
                    line,
                    message: format!(
                        "stale mirror reference: `{path}` has no \
                         symbol `{sym}`"
                    ),
                });
            }
        }
    }
    if !mentions_test {
        return;
    }
    for (k, part) in text.split('`').enumerate() {
        if k % 2 == 0 || !snake_ident(part) {
            continue;
        }
        let fn_pat = format!("fn {part}(");
        let def_pat = format!("def {part}(");
        let found = corpus.iter().any(|(lang, t)| match lang {
            Lang::Rust => t.contains(&fn_pat),
            Lang::Py => t.contains(&def_pat),
        });
        if !found {
            raw.push(Raw {
                rule: "M004",
                file: rel.to_string(),
                line,
                message: format!(
                    "stale mirror reference: no function or test \
                     named `{part}`"
                ),
            });
        }
    }
}

fn resolve_path(root: &Path, rel: &str) -> Option<PathBuf> {
    let a = root.join(rel);
    if a.exists() {
        return Some(a);
    }
    let b = root.join("rust").join(rel);
    if b.exists() {
        return Some(b);
    }
    None
}

/// Lowercase snake_case ident of useful length — the shape of every
/// test and helper name the doc comments point at. Uppercase words
/// (const names) and pathy strings are excluded on purpose.
fn snake_ident(s: &str) -> bool {
    s.len() >= 4
        && s.contains('_')
        && s.bytes().next().is_some_and(|c| {
            c.is_ascii_lowercase() || c == b'_'
        })
        && s.bytes().all(|c| {
            c.is_ascii_lowercase()
                || c.is_ascii_digit()
                || c == b'_'
        })
}

/// Sources searched for `fn X(` / `def X(`: every loaded mirror
/// file plus the integration-test trees.
fn test_corpus(
    root: &Path,
    files: &BTreeMap<String, SrcFile>,
) -> Vec<(Lang, String)> {
    let mut out: Vec<(Lang, String)> = files
        .values()
        .map(|f| (f.lang, f.text.clone()))
        .collect();
    for dir in ["rust/tests", "tests"] {
        let Ok(entries) = fs::read_dir(root.join(dir)) else {
            continue;
        };
        let mut paths: Vec<PathBuf> = entries
            .flatten()
            .map(|e| e.path())
            .filter(|p| p.extension().is_some_and(|e| e == "rs"))
            .collect();
        paths.sort();
        for p in paths {
            if let Ok(t) = fs::read_to_string(&p) {
                out.push((Lang::Rust, t));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tail_strips_rust_and_python_paths() {
        assert_eq!(tail("GPT3_175B"), "GPT3_175B");
        assert_eq!(tail("spec::GPT3_175B"), "GPT3_175B");
        assert_eq!(tail("dataclasses.replace"), "replace");
    }

    #[test]
    fn snake_ident_shape() {
        assert!(snake_ident("artifact_matches_rust_mirror"));
        assert!(snake_ident("op_table_v2"));
        assert!(!snake_ident("SCENARIOS"));
        assert!(!snake_ident("abc"));
        assert!(!snake_ident("cargo test"));
        assert!(!snake_ident("tests/artifact.rs"));
        assert!(!snake_ident("nounderscore"));
    }

    fn lit(v: f64, text: &str, line: u32) -> Lit {
        Lit {
            v,
            text: text.to_string(),
            file: "f.py".to_string(),
            line,
        }
    }

    #[test]
    fn gqa_default_copies_n_heads_when_absent() {
        let mut spec = Spec::new();
        spec.insert("n_heads".to_string(), lit(96.0, "96", 4));
        gqa_default(&mut spec);
        assert_eq!(spec["n_kv_heads"].v, 96.0);
        // Explicit values win.
        let mut spec = Spec::new();
        spec.insert("n_heads".to_string(), lit(64.0, "64", 4));
        spec.insert("n_kv_heads".to_string(), lit(8.0, "8", 5));
        gqa_default(&mut spec);
        assert_eq!(spec["n_kv_heads"].v, 8.0);
    }

    #[test]
    fn rust_spec_resolution_applies_base_then_overrides() {
        let mut env: BTreeMap<String, Spec> = BTreeMap::new();
        let mut base = Spec::new();
        base.insert("batch".to_string(), lit(8.0, "8", 2));
        base.insert("seq".to_string(), lit(2048.0, "2048", 3));
        env.insert("BASE".to_string(), base);
        let v = Value::Struct {
            name: "WorkloadSpec".to_string(),
            fields: vec![(
                "batch".to_string(),
                Value::Num {
                    v: 1.0,
                    text: "1".to_string(),
                    line: 9,
                },
            )],
            base: Some("BASE".to_string()),
        };
        let spec = resolve_rust_spec(&v, &env, "s.rs");
        assert_eq!(spec["batch"].v, 1.0);
        assert_eq!(spec["batch"].file, "s.rs");
        assert_eq!(spec["batch"].line, 9);
        assert_eq!(spec["seq"].v, 2048.0);
    }

    #[test]
    fn py_replace_resolves_base_from_env() {
        let mut env: BTreeMap<String, Spec> = BTreeMap::new();
        let mut base = Spec::new();
        base.insert("batch".to_string(), lit(8.0, "8", 2));
        base.insert("n_heads".to_string(), lit(64.0, "64", 3));
        base.insert("n_kv_heads".to_string(), lit(8.0, "8", 4));
        env.insert("_LLAMA".to_string(), base);
        let classes: BTreeMap<String, Spec> = BTreeMap::new();
        let v = Value::Call {
            name: "replace".to_string(),
            args: vec![Value::Ref("_LLAMA".to_string())],
            kwargs: vec![(
                "batch".to_string(),
                Value::Num {
                    v: 64.0,
                    text: "64".to_string(),
                    line: 12,
                },
            )],
        };
        let spec = resolve_py_spec(&v, &env, &classes, "w.py");
        assert_eq!(spec["batch"].v, 64.0);
        assert_eq!(spec["batch"].line, 12);
        assert_eq!(spec["n_kv_heads"].v, 8.0);
    }
}
