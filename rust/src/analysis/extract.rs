//! Constant/registry extractor for the mirror-drift analyzer.
//!
//! Parses both sides of a declared mirror pair into typed symbol
//! tables: Rust module-level `const NAME: T = <value>;` items
//! (including struct-literal registries like
//! `const SCENARIOS: [Scenario; N]`) and Python module-level
//! `NAME = <value>` assignments, `SCENARIOS = {...}` dicts, and
//! dataclass field defaults. The extractor is total: anything it
//! cannot parse becomes [`Value::Opaque`], which the differ treats
//! as presence-only (never a value-drift finding).
//!
//! Numeric literals arrive from the lexers split at `.` and sign
//! chars (`0.45e-12` lexes as `0`, `.`, `45e`, `-`, `12`);
//! [`join_number`] re-joins them and keeps the source spelling so
//! findings can show the literal exactly as written on each side.

use crate::analysis::lexer::{self, Tok, TokKind};
use crate::analysis::pylex;

/// A parsed right-hand side. `Num` keeps both the parsed value (for
/// comparison) and the source text (for display, exactly as
/// written). Everything unrecognized is `Opaque`.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Num {
        v: f64,
        text: String,
        /// 1-based line of the literal (finding anchor).
        line: u32,
    },
    Str {
        s: String,
        /// 1-based line of the literal (finding anchor).
        line: u32,
    },
    NoneLit,
    /// Bare (possibly dotted/pathed) identifier reference.
    Ref(String),
    /// Python call: `Name(arg, kw=value, ...)`.
    Call {
        name: String,
        args: Vec<Value>,
        kwargs: Vec<(String, Value)>,
    },
    /// Rust struct literal: `Name { field: value, ..BASE }`.
    Struct {
        name: String,
        fields: Vec<(String, Value)>,
        base: Option<String>,
    },
    /// Array / list / tuple.
    Arr(Vec<Value>),
    /// Python dict, entries in source order.
    Dict(Vec<(Value, Value)>),
    Opaque,
}

/// One extracted symbol: a Rust const, a Python module-level
/// assignment, or a dataclass field default.
#[derive(Debug, Clone)]
pub struct Sym {
    pub name: String,
    /// 1-based line of the declaration's name.
    pub line: u32,
    pub value: Value,
}

/// A Python class region with its annotated field defaults (the
/// dataclass pattern `name: ann = default`).
#[derive(Debug, Clone)]
pub struct PyClass {
    pub name: String,
    pub line: u32,
    pub fields: Vec<Sym>,
}

/// Extraction result for one Python module.
#[derive(Debug, Clone, Default)]
pub struct PyModule {
    pub syms: Vec<Sym>,
    pub classes: Vec<PyClass>,
}

fn punct(t: &Tok<'_>, s: &str) -> bool {
    t.kind == TokKind::Punct && t.text == s
}

fn digit_start(t: &Tok<'_>) -> bool {
    t.kind == TokKind::Ident
        && t.text.as_bytes().first().is_some_and(u8::is_ascii_digit)
}

/// Re-join a numeric literal starting at `toks[i]` (optionally
/// signed). Returns `(value, source_text, next_index)`; `None` when
/// the tokens there do not form a parseable number (hex literals,
/// suffixed literals, non-numbers).
pub fn join_number(
    toks: &[Tok<'_>],
    i: usize,
) -> Option<(f64, String, usize)> {
    let n = toks.len();
    let mut k = i;
    let mut neg = false;
    if k < n && punct(&toks[k], "-") {
        neg = true;
        k += 1;
    }
    if k >= n || !digit_start(&toks[k]) {
        return None;
    }
    let mut s = toks[k].text.to_string();
    k += 1;
    if !s.contains('.')
        && k + 1 < n
        && punct(&toks[k], ".")
        && digit_start(&toks[k + 1])
    {
        s.push('.');
        s.push_str(toks[k + 1].text);
        k += 2;
    }
    if (s.ends_with('e') || s.ends_with('E'))
        && k + 1 < n
        && (punct(&toks[k], "-") || punct(&toks[k], "+"))
        && digit_start(&toks[k + 1])
    {
        s.push_str(toks[k].text);
        s.push_str(toks[k + 1].text);
        k += 2;
    }
    let cleaned: String = s.chars().filter(|&c| c != '_').collect();
    let v: f64 = cleaned.parse().ok()?;
    let text = if neg { format!("-{s}") } else { s };
    Some((if neg { -v } else { v }, text, k))
}

/// Index of the next `,`, `;`, or unmatched closing bracket at
/// relative depth 0 — the structural end of one expression/element.
fn expr_end(toks: &[Tok<'_>], i: usize) -> usize {
    let mut d = 0i32;
    let mut j = i;
    while j < toks.len() {
        let t = &toks[j];
        if t.kind == TokKind::Punct {
            match t.text {
                "(" | "[" | "{" => d += 1,
                ")" | "]" | "}" => {
                    if d == 0 {
                        return j;
                    }
                    d -= 1;
                }
                "," | ";" if d == 0 => return j,
                _ => {}
            }
        }
        j += 1;
    }
    j
}

/// Like [`expr_end`] but also ends at the first token on a later
/// line while at relative depth 0 — the Python statement rule
/// (newlines only continue an expression inside brackets).
fn py_expr_end(toks: &[Tok<'_>], i: usize) -> usize {
    let n = toks.len();
    if i >= n {
        return i;
    }
    let mut d = 0i32;
    let mut cur = toks[i].line;
    let mut j = i;
    while j < n {
        let t = &toks[j];
        if d == 0 && t.line > cur {
            return j;
        }
        if t.kind == TokKind::Punct {
            match t.text {
                "(" | "[" | "{" => d += 1,
                ")" | "]" | "}" => {
                    if d == 0 {
                        return j;
                    }
                    d -= 1;
                }
                "," | ";" if d == 0 => return j,
                _ => {}
            }
        }
        if d == 0 {
            cur = t.line;
        }
        j += 1;
    }
    j
}

/// Parse one element whose structural end is `end`; anything that
/// does not consume exactly the whole span is `Opaque` (so `8 * 64`
/// never half-parses as `8`).
fn elem<F>(toks: &[Tok<'_>], i: usize, end: usize, f: F) -> Value
where
    F: Fn(&[Tok<'_>], usize) -> (Value, usize),
{
    let (v, next) = f(toks, i);
    if next == end {
        v
    } else {
        Value::Opaque
    }
}

/// Collect a (possibly pathed) identifier: `A`, `A::B`, `a.b`.
/// Returns `(joined_name, next_index)`.
fn path(toks: &[Tok<'_>], i: usize, sep: &str) -> (String, usize) {
    let mut name = toks[i].text.to_string();
    let mut j = i + 1;
    while j + 1 < toks.len()
        && punct(&toks[j], sep)
        && toks[j + 1].kind == TokKind::Ident
    {
        name.push_str(sep);
        name.push_str(toks[j + 1].text);
        j += 2;
    }
    (name, j)
}

// ---------------------------------------------------------------
// Rust side
// ---------------------------------------------------------------

/// Extract every module-level `const NAME: T = value;` from Rust
/// source (with or without `pub`; items nested in blocks are
/// intentionally skipped — mirrors are module-level by convention).
pub fn extract_rust(src: &str) -> Vec<Sym> {
    let lexed = lexer::lex_full(src);
    let toks = &lexed.toks;
    let n = toks.len();
    let mut out = Vec::new();
    let mut depth = 0i32;
    let mut i = 0usize;
    while i < n {
        let t = &toks[i];
        if t.kind == TokKind::Punct {
            match t.text {
                "{" | "(" | "[" => depth += 1,
                "}" | ")" | "]" => depth -= 1,
                _ => {}
            }
        }
        if depth == 0
            && t.is_ident("const")
            && i + 2 < n
            && toks[i + 1].kind == TokKind::Ident
            && punct(&toks[i + 2], ":")
        {
            let name = toks[i + 1].text.to_string();
            let line = toks[i + 1].line;
            // Skip the type: everything up to `=` at relative
            // bracket depth 0 (`[Scenario; 7]` contains `;`).
            let mut j = i + 3;
            let mut bd = 0i32;
            while j < n {
                let tt = &toks[j];
                if tt.kind == TokKind::Punct {
                    match tt.text {
                        "[" | "(" | "<" => bd += 1,
                        "]" | ")" | ">" => bd -= 1,
                        "=" if bd == 0 => break,
                        _ => {}
                    }
                }
                j += 1;
            }
            let vstart = j + 1;
            let end = expr_end(toks, vstart);
            let value = elem(toks, vstart, end, parse_rust_value);
            out.push(Sym { name, line, value });
            i = end;
            continue;
        }
        i += 1;
    }
    out
}

fn parse_rust_value(
    toks: &[Tok<'_>],
    i: usize,
) -> (Value, usize) {
    let n = toks.len();
    if i >= n {
        return (Value::Opaque, i);
    }
    if punct(&toks[i], "&") {
        return parse_rust_value(toks, i + 1);
    }
    if let Some((v, text, next)) = join_number(toks, i) {
        let line = toks[i].line;
        return (Value::Num { v, text, line }, next);
    }
    if toks[i].kind == TokKind::Str {
        let line = toks[i].line;
        return (
            Value::Str { s: toks[i].text.to_string(), line },
            i + 1,
        );
    }
    if punct(&toks[i], "[") {
        let mut items = Vec::new();
        let mut j = i + 1;
        while j < n && !punct(&toks[j], "]") {
            let end = expr_end(toks, j);
            items.push(elem(toks, j, end, parse_rust_value));
            j = end;
            if j < n && punct(&toks[j], ",") {
                j += 1;
            }
        }
        return (Value::Arr(items), (j + 1).min(n));
    }
    if toks[i].kind == TokKind::Ident {
        let (name, mut j) = path(toks, i, "::");
        if j < n && punct(&toks[j], "{") {
            let mut fields = Vec::new();
            let mut base = None;
            j += 1;
            while j < n && !punct(&toks[j], "}") {
                if punct(&toks[j], ".")
                    && j + 2 < n
                    && punct(&toks[j + 1], ".")
                    && toks[j + 2].kind == TokKind::Ident
                {
                    let (b, nj) = path(toks, j + 2, "::");
                    base = Some(b);
                    j = nj;
                    continue;
                }
                if toks[j].kind == TokKind::Ident
                    && j + 1 < n
                    && punct(&toks[j + 1], ":")
                {
                    let fname = toks[j].text.to_string();
                    let vstart = j + 2;
                    let end = expr_end(toks, vstart);
                    fields.push((
                        fname,
                        elem(toks, vstart, end, parse_rust_value),
                    ));
                    j = end;
                } else {
                    j = expr_end(toks, j);
                }
                if j < n && punct(&toks[j], ",") {
                    j += 1;
                }
            }
            return (
                Value::Struct { name, fields, base },
                (j + 1).min(n),
            );
        }
        if j < n && punct(&toks[j], "(") {
            let mut args = Vec::new();
            j += 1;
            while j < n && !punct(&toks[j], ")") {
                let end = expr_end(toks, j);
                args.push(elem(toks, j, end, parse_rust_value));
                j = end;
                if j < n && punct(&toks[j], ",") {
                    j += 1;
                }
            }
            return (
                Value::Call { name, args, kwargs: Vec::new() },
                (j + 1).min(n),
            );
        }
        return (Value::Ref(name), j);
    }
    (Value::Opaque, i + 1)
}

// ---------------------------------------------------------------
// Python side
// ---------------------------------------------------------------

const PY_KEYWORDS: [&str; 22] = [
    "assert", "class", "def", "del", "elif", "else", "except",
    "finally", "for", "from", "global", "if", "import", "lambda",
    "nonlocal", "pass", "print", "raise", "return", "try", "while",
    "with",
];

fn py_keyword(s: &str) -> bool {
    PY_KEYWORDS.contains(&s)
}

/// Extract module-level assignments and class field defaults from
/// Python source.
pub fn extract_py(src: &str) -> PyModule {
    let lexed = pylex::lex_py(src);
    let toks = &lexed.toks;
    let n = toks.len();
    let mut out = PyModule::default();
    let mut depth = 0i32;
    let mut i = 0usize;
    while i < n {
        let t = &toks[i];
        if t.kind == TokKind::Punct {
            match t.text {
                "(" | "[" | "{" => depth += 1,
                ")" | "]" | "}" => depth -= 1,
                _ => {}
            }
        }
        if depth == 0 && t.col == 1 && t.kind == TokKind::Ident {
            if t.text == "class"
                && i + 1 < n
                && toks[i + 1].kind == TokKind::Ident
            {
                let (class, next) = extract_py_class(toks, i);
                out.classes.push(class);
                i = next;
                continue;
            }
            if !py_keyword(t.text) {
                if let Some(vstart) = assign_rhs(toks, i) {
                    let end = py_expr_end(toks, vstart);
                    out.syms.push(Sym {
                        name: t.text.to_string(),
                        line: t.line,
                        value: elem(toks, vstart, end, parse_py_value),
                    });
                    i = end;
                    continue;
                }
            }
        }
        i += 1;
    }
    out
}

/// For `NAME = value` or `NAME: ann = value` starting at `i`,
/// return the index of the value start. Rejects `==` (the lexers
/// split it into two `=` puncts).
fn assign_rhs(toks: &[Tok<'_>], i: usize) -> Option<usize> {
    let n = toks.len();
    if i + 1 >= n {
        return None;
    }
    if punct(&toks[i + 1], "=")
        && !(i + 2 < n && punct(&toks[i + 2], "="))
    {
        return Some(i + 2);
    }
    if punct(&toks[i + 1], ":") {
        // Annotated: find `=` later on the same line, outside any
        // comparison (annotations contain no `=`).
        let mut k = i + 2;
        while k < n && toks[k].line == toks[i].line {
            if punct(&toks[k], "=")
                && !(k + 1 < n && punct(&toks[k + 1], "="))
            {
                return Some(k + 1);
            }
            k += 1;
        }
    }
    None
}

/// Parse a `class Name:` region starting at the `class` keyword.
/// The region ends at the next column-1 token at depth 0.
fn extract_py_class(
    toks: &[Tok<'_>],
    i: usize,
) -> (PyClass, usize) {
    let n = toks.len();
    let name = toks[i + 1].text.to_string();
    let line = toks[i + 1].line;
    let mut fields = Vec::new();
    let mut d = 0i32;
    let mut j = i + 2;
    let mut prev_line = toks[i].line;
    while j < n {
        let t = &toks[j];
        if t.kind == TokKind::Punct {
            match t.text {
                "(" | "[" | "{" => d += 1,
                ")" | "]" | "}" => d -= 1,
                _ => {}
            }
        }
        if d == 0 && t.col == 1 && t.line > toks[i].line {
            break; // next module-level statement
        }
        // A field default: first ident on its line, inside the
        // class body, not a keyword, with `: ann = value`.
        if d == 0
            && t.kind == TokKind::Ident
            && t.line > prev_line
            && t.col > 1
            && !py_keyword(t.text)
        {
            if let Some(vstart) = assign_rhs(toks, j) {
                let end = py_expr_end(toks, vstart);
                fields.push(Sym {
                    name: t.text.to_string(),
                    line: t.line,
                    value: elem(toks, vstart, end, parse_py_value),
                });
                prev_line = toks[end.saturating_sub(1)]
                    .line
                    .max(t.line);
                j = end;
                continue;
            }
        }
        prev_line = prev_line.max(t.line);
        j += 1;
    }
    (PyClass { name, line, fields }, j)
}

fn parse_py_value(toks: &[Tok<'_>], i: usize) -> (Value, usize) {
    let n = toks.len();
    if i >= n {
        return (Value::Opaque, i);
    }
    if let Some((v, text, next)) = join_number(toks, i) {
        let line = toks[i].line;
        return (Value::Num { v, text, line }, next);
    }
    if toks[i].kind == TokKind::Str {
        let line = toks[i].line;
        return (
            Value::Str { s: toks[i].text.to_string(), line },
            i + 1,
        );
    }
    if punct(&toks[i], "{") {
        let mut entries = Vec::new();
        let mut j = i + 1;
        while j < n && !punct(&toks[j], "}") {
            let (key, nk) = parse_py_value(toks, j);
            if nk >= n || !punct(&toks[nk], ":") {
                j = expr_end(toks, j);
                if j < n && punct(&toks[j], ",") {
                    j += 1;
                }
                continue;
            }
            let vstart = nk + 1;
            let end = expr_end(toks, vstart);
            entries
                .push((key, elem(toks, vstart, end, parse_py_value)));
            j = end;
            if j < n && punct(&toks[j], ",") {
                j += 1;
            }
        }
        return (Value::Dict(entries), (j + 1).min(n));
    }
    if punct(&toks[i], "[") || punct(&toks[i], "(") {
        let close = if punct(&toks[i], "[") { "]" } else { ")" };
        let mut items = Vec::new();
        let mut j = i + 1;
        while j < n && !punct(&toks[j], close) {
            let end = expr_end(toks, j);
            items.push(elem(toks, j, end, parse_py_value));
            j = end;
            if j < n && punct(&toks[j], ",") {
                j += 1;
            }
        }
        return (Value::Arr(items), (j + 1).min(n));
    }
    if toks[i].kind == TokKind::Ident {
        if toks[i].text == "None" {
            return (Value::NoneLit, i + 1);
        }
        let (name, mut j) = path(toks, i, ".");
        if j < n && punct(&toks[j], "(") {
            let mut args = Vec::new();
            let mut kwargs = Vec::new();
            j += 1;
            while j < n && !punct(&toks[j], ")") {
                let end = expr_end(toks, j);
                if toks[j].kind == TokKind::Ident
                    && j + 1 < end
                    && punct(&toks[j + 1], "=")
                    && !(j + 2 < n && punct(&toks[j + 2], "="))
                {
                    kwargs.push((
                        toks[j].text.to_string(),
                        elem(toks, j + 2, end, parse_py_value),
                    ));
                } else {
                    args.push(elem(toks, j, end, parse_py_value));
                }
                j = end;
                if j < n && punct(&toks[j], ",") {
                    j += 1;
                }
            }
            return (
                Value::Call { name, args, kwargs },
                (j + 1).min(n),
            );
        }
        return (Value::Ref(name), j);
    }
    (Value::Opaque, i + 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn num(v: &Value) -> f64 {
        match v {
            Value::Num { v, .. } => *v,
            other => panic!("expected Num, got {other:?}"),
        }
    }

    #[test]
    fn rust_consts_with_split_literals() {
        let src = "\
pub const CLOCK_HZ: f32 = 1.41e9;
pub const BASE_LEAK: f32 = 0.45e-12;
pub const MAX_OPS: usize = 16;
const NEG: f32 = -2.5;
pub const HEX: u32 = 0x54;
";
        let syms = extract_rust(src);
        let names: Vec<_> =
            syms.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(
            names,
            vec!["CLOCK_HZ", "BASE_LEAK", "MAX_OPS", "NEG", "HEX"]
        );
        assert_eq!(num(&syms[0].value), 1.41e9);
        assert_eq!(num(&syms[1].value), 0.45e-12);
        match &syms[1].value {
            Value::Num { text, .. } => assert_eq!(text, "0.45e-12"),
            _ => unreachable!(),
        }
        assert_eq!(num(&syms[2].value), 16.0);
        assert_eq!(num(&syms[3].value), -2.5);
        // Hex does not parse as f64: presence-only.
        assert_eq!(syms[4].value, Value::Opaque);
        assert_eq!(syms[0].line, 1);
        assert_eq!(syms[3].line, 4);
    }

    #[test]
    fn rust_const_inside_fn_is_skipped() {
        let src = "fn f() { const X: u32 = 1; }\n\
                   pub const Y: u32 = 2;\n";
        let syms = extract_rust(src);
        assert_eq!(syms.len(), 1);
        assert_eq!(syms[0].name, "Y");
    }

    #[test]
    fn rust_registry_structs_with_base_update() {
        let src = "\
pub const SCENARIOS: [Scenario; 2] = [
    Scenario { name: \"a\", spec: BASE },
    Scenario {
        name: \"b\",
        spec: WorkloadSpec { batch: 1, ..BASE },
    },
];
";
        let syms = extract_rust(src);
        assert_eq!(syms.len(), 1);
        let arr = match &syms[0].value {
            Value::Arr(items) => items,
            v => panic!("want Arr, got {v:?}"),
        };
        assert_eq!(arr.len(), 2);
        match &arr[1] {
            Value::Struct { name, fields, .. } => {
                assert_eq!(name, "Scenario");
                match &fields[0].1 {
                    Value::Str { s, .. } => assert_eq!(s, "b"),
                    v => panic!("want Str, got {v:?}"),
                }
                match &fields[1].1 {
                    Value::Struct { base, fields, .. } => {
                        assert_eq!(base.as_deref(), Some("BASE"));
                        assert_eq!(num(&fields[0].1), 1.0);
                    }
                    v => panic!("want Struct, got {v:?}"),
                }
            }
            v => panic!("want Struct, got {v:?}"),
        }
    }

    #[test]
    fn rust_arithmetic_rhs_is_opaque_not_half_parsed() {
        let syms = extract_rust("pub const X: usize = 8 * 64;\n");
        assert_eq!(syms[0].value, Value::Opaque);
    }

    #[test]
    fn py_module_constants_and_dict() {
        let src = "\
\"\"\"doc\"\"\"
CLOCK_HZ = 1.41e9
MEM_EFF_BASE = 0.55  # tuned
SCENARIOS = {
    \"a\": BASE,
    \"b\": replace(BASE, batch=1, prefill_seq=16384),
}
if __name__ == \"__main__\":
    X = 9
";
        let m = extract_py(src);
        let names: Vec<_> =
            m.syms.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(
            names,
            vec!["CLOCK_HZ", "MEM_EFF_BASE", "SCENARIOS"]
        );
        assert_eq!(num(&m.syms[1].value), 0.55);
        assert_eq!(m.syms[1].line, 3);
        let entries = match &m.syms[2].value {
            Value::Dict(e) => e,
            v => panic!("want Dict, got {v:?}"),
        };
        assert_eq!(entries.len(), 2);
        match &entries[0].0 {
            Value::Str { s, .. } => assert_eq!(s, "a"),
            v => panic!("want Str, got {v:?}"),
        }
        assert_eq!(entries[0].1, Value::Ref("BASE".to_string()));
        match &entries[1].1 {
            Value::Call { name, args, kwargs } => {
                assert_eq!(name, "replace");
                assert_eq!(args[0], Value::Ref("BASE".to_string()));
                assert_eq!(kwargs[0].0, "batch");
                assert_eq!(num(&kwargs[0].1), 1.0);
                assert_eq!(num(&kwargs[1].1), 16384.0);
            }
            v => panic!("want Call, got {v:?}"),
        }
    }

    #[test]
    fn py_dataclass_fields_and_call_kwargs() {
        let src = "\
from dataclasses import dataclass

@dataclass(frozen=True)
class WorkloadSpec:
    d_model: int = 12288
    n_kv_heads: int | None = None

    def __post_init__(self):
        if self.n_kv_heads is None:
            pass

GPT3 = WorkloadSpec()
TINY = WorkloadSpec(d_model=1024)
";
        let m = extract_py(src);
        assert_eq!(m.classes.len(), 1);
        let c = &m.classes[0];
        assert_eq!(c.name, "WorkloadSpec");
        let fnames: Vec<_> =
            c.fields.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(fnames, vec!["d_model", "n_kv_heads"]);
        assert_eq!(num(&c.fields[0].value), 12288.0);
        assert_eq!(c.fields[1].value, Value::NoneLit);
        assert_eq!(m.syms.len(), 2);
        match &m.syms[1].value {
            Value::Call { name, kwargs, .. } => {
                assert_eq!(name, "WorkloadSpec");
                assert_eq!(kwargs[0].0, "d_model");
                assert_eq!(num(&kwargs[0].1), 1024.0);
            }
            v => panic!("want Call, got {v:?}"),
        }
    }

    #[test]
    fn join_number_shapes() {
        let l = pylex::lex_py("0.45e-12 1_000 16 -3.5 0x54");
        let t = &l.toks;
        let (v, s, k) = join_number(t, 0).expect("sci");
        assert_eq!((v, s.as_str()), (0.45e-12, "0.45e-12"));
        let (v, s, k2) = join_number(t, k).expect("underscore");
        assert_eq!((v, s.as_str()), (1000.0, "1_000"));
        let (v, _, k3) = join_number(t, k2).expect("int");
        assert_eq!(v, 16.0);
        let (v, s, k4) = join_number(t, k3).expect("neg");
        assert_eq!((v, s.as_str()), (-3.5, "-3.5"));
        assert!(join_number(t, k4).is_none());
    }
}
