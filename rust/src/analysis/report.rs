//! Lint report: aggregation, text rendering, and the machine-read
//! JSON findings format (CI uploads it as an artifact).
//!
//! The JSON layout is stable and golden-tested: keys are emitted in
//! `util::json`'s sorted-object order, so byte-for-byte comparison
//! against a committed golden file is meaningful.

use crate::analysis::rules::Severity;
use crate::analysis::Finding;
use crate::util::json::{obj, Json};

/// The outcome of linting a tree.
#[derive(Debug)]
pub struct Report {
    /// Which analysis produced the findings: `"determinism"` (the
    /// single-file rule scanner) or `"mirror"` (the cross-language
    /// mirror-drift differ).
    pub engine: String,
    /// Lint root as given (forward slashes). Tests overwrite this
    /// before golden comparison so the file is machine-independent.
    pub root: String,
    /// Number of files scanned.
    pub files: usize,
    /// All findings, waived included, sorted by (file, line, rule).
    pub findings: Vec<Finding>,
}

/// Unwaivered error/warning counts plus the waived total.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Counts {
    pub errors: usize,
    pub warnings: usize,
    pub waived: usize,
}

impl Report {
    pub fn counts(&self) -> Counts {
        let mut c = Counts { errors: 0, warnings: 0, waived: 0 };
        for f in &self.findings {
            if f.waived {
                c.waived += 1;
            } else if f.severity == Severity::Error {
                c.errors += 1;
            } else {
                c.warnings += 1;
            }
        }
        c
    }

    /// Gate check: errors always fail; `--deny-warnings` (the CI
    /// mode) fails on any unwaivered finding.
    pub fn failed(&self, deny_warnings: bool) -> bool {
        let c = self.counts();
        c.errors > 0 || (deny_warnings && c.warnings > 0)
    }

    /// Human-facing rendering: one line per unwaivered finding plus
    /// a summary trailer.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            if f.waived {
                continue;
            }
            out.push_str(&format!(
                "{}:{}: {} {}: {}\n",
                f.file,
                f.line,
                f.severity.as_str(),
                f.rule,
                f.message
            ));
        }
        let c = self.counts();
        out.push_str(&format!(
            "lint: {} files, {} findings ({} errors, {} warnings, \
             {} waived)\n",
            self.files,
            self.findings.len(),
            c.errors,
            c.warnings,
            c.waived
        ));
        out
    }

    /// Machine-readable findings document (waived included, so the
    /// artifact is a full audit trail).
    pub fn to_json(&self) -> Json {
        let c = self.counts();
        let findings: Vec<Json> = self
            .findings
            .iter()
            .map(|f| {
                obj(vec![
                    ("file", Json::Str(f.file.clone())),
                    ("line", Json::Num(f.line as f64)),
                    ("message", Json::Str(f.message.clone())),
                    ("rule", Json::Str(f.rule.clone())),
                    (
                        "severity",
                        Json::Str(f.severity.as_str().to_string()),
                    ),
                    ("waived", Json::Bool(f.waived)),
                    (
                        "waiver_reason",
                        match &f.waiver_reason {
                            Some(r) => Json::Str(r.clone()),
                            None => Json::Null,
                        },
                    ),
                ])
            })
            .collect();
        obj(vec![
            (
                "counts",
                obj(vec![
                    ("errors", Json::Num(c.errors as f64)),
                    ("waived", Json::Num(c.waived as f64)),
                    ("warnings", Json::Num(c.warnings as f64)),
                ]),
            ),
            ("engine", Json::Str(self.engine.clone())),
            ("files", Json::Num(self.files as f64)),
            ("findings", Json::Arr(findings)),
            ("root", Json::Str(self.root.clone())),
            ("version", Json::Num(2.0)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finding(
        rule: &str,
        sev: Severity,
        waived: bool,
    ) -> Finding {
        Finding {
            rule: rule.to_string(),
            severity: sev,
            file: "x/y.rs".to_string(),
            line: 3,
            message: "msg".to_string(),
            waived,
            waiver_reason: if waived {
                Some("reason".to_string())
            } else {
                None
            },
        }
    }

    #[test]
    fn counts_and_gate() {
        let r = Report {
            engine: "determinism".to_string(),
            root: "src".to_string(),
            files: 2,
            findings: vec![
                finding("D001", Severity::Error, false),
                finding("P001", Severity::Warning, false),
                finding("P001", Severity::Warning, true),
            ],
        };
        let c = r.counts();
        assert_eq!(
            c,
            Counts { errors: 1, warnings: 1, waived: 1 }
        );
        assert!(r.failed(false));
        assert!(r.failed(true));

        let warn_only = Report {
            engine: "determinism".to_string(),
            root: "src".to_string(),
            files: 1,
            findings: vec![finding(
                "P001",
                Severity::Warning,
                false,
            )],
        };
        assert!(!warn_only.failed(false));
        assert!(warn_only.failed(true));
    }

    #[test]
    fn text_hides_waived_but_summary_counts_them() {
        let r = Report {
            engine: "determinism".to_string(),
            root: "src".to_string(),
            files: 1,
            findings: vec![
                finding("D001", Severity::Error, false),
                finding("P001", Severity::Warning, true),
            ],
        };
        let text = r.render_text();
        assert!(text.contains("error D001"));
        assert!(!text.contains("P001"));
        assert!(text.contains("1 waived"));
    }

    #[test]
    fn json_round_trips_through_the_parser() {
        let r = Report {
            engine: "mirror".to_string(),
            root: "src".to_string(),
            files: 1,
            findings: vec![finding(
                "D001",
                Severity::Error,
                false,
            )],
        };
        let text = r.to_json().pretty();
        let back = Json::parse(&text).expect("own output parses");
        let findings =
            back.get("findings").expect("findings key present");
        match findings.as_arr() {
            Some(a) => assert_eq!(a.len(), 1),
            None => panic!("findings is not an array"),
        }
    }
}
