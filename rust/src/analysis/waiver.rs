//! Inline waiver syntax: `// lumina: allow(D002) <reason>`.
//!
//! A waiver suppresses findings of the named rule(s) on its own line
//! or on the line directly below it (so it can sit above the
//! offending statement or trail it). Several ids may be listed,
//! comma-separated: `// lumina: allow(P001, D001) reason`.
//!
//! Enforcement is part of the syntax: a waiver with no reason, an
//! unknown rule id, or a missing `)` does **not** apply and instead
//! produces a `W001` finding. `W001` itself cannot be waived — the
//! audit trail must stay un-silence-able.

use crate::analysis::rules;

/// A well-formed waiver: rule id, comment line, justification.
#[derive(Debug, Clone)]
pub struct Waiver {
    pub rule: String,
    pub line: u32,
    pub reason: String,
}

/// Parse waivers out of captured line comments.
///
/// Returns the applicable waivers plus the `W001` findings as
/// `(line, message)` pairs.
pub fn parse(
    comments: &[(u32, &str)],
) -> (Vec<Waiver>, Vec<(u32, String)>) {
    let mut waivers = Vec::new();
    let mut w001 = Vec::new();
    for &(line, text) in comments {
        let Some(pos) = text.find("lumina:") else { continue };
        let rest = text[pos + "lumina:".len()..].trim_start();
        let Some(body) = rest.strip_prefix("allow(") else {
            continue;
        };
        let Some(close) = body.find(')') else {
            w001.push((
                line,
                "waiver is missing its closing `)`".to_string(),
            ));
            continue;
        };
        let ids: Vec<&str> = body[..close]
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .collect();
        let reason = body[close + 1..].trim();
        if ids.is_empty() {
            w001.push((line, "waiver lists no rule id".to_string()));
            continue;
        }
        for id in ids {
            if id == "W001" {
                w001.push((
                    line,
                    "waiver may not target W001".to_string(),
                ));
                continue;
            }
            if rules::by_id(id).is_none() {
                w001.push((
                    line,
                    format!("waiver names unknown rule `{id}`"),
                ));
                continue;
            }
            if reason.is_empty() {
                w001.push((
                    line,
                    format!("waiver for {id} gives no reason"),
                ));
                continue;
            }
            waivers.push(Waiver {
                rule: id.to_string(),
                line,
                reason: reason.to_string(),
            });
        }
    }
    (waivers, w001)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn well_formed_waiver_parses() {
        let (w, bad) =
            parse(&[(7, "// lumina: allow(D002) bench timing")]);
        assert_eq!(bad.len(), 0);
        assert_eq!(w.len(), 1);
        assert_eq!(w[0].rule, "D002");
        assert_eq!(w[0].line, 7);
        assert_eq!(w[0].reason, "bench timing");
    }

    #[test]
    fn multiple_ids_share_one_reason() {
        let (w, bad) =
            parse(&[(3, "// lumina: allow(P001, D001) proven safe")]);
        assert_eq!(bad.len(), 0);
        let ids: Vec<&str> =
            w.iter().map(|x| x.rule.as_str()).collect();
        assert_eq!(ids, vec!["P001", "D001"]);
    }

    #[test]
    fn reasonless_waiver_is_a_finding_and_does_not_apply() {
        let (w, bad) = parse(&[(9, "// lumina: allow(P001)")]);
        assert!(w.is_empty());
        assert_eq!(bad.len(), 1);
        assert!(bad[0].1.contains("no reason"));
    }

    #[test]
    fn unknown_rule_and_w001_target_are_findings() {
        let (w, bad) = parse(&[
            (1, "// lumina: allow(D999) whatever"),
            (2, "// lumina: allow(W001) silence the auditor"),
            (3, "// lumina: allow() empty"),
            (4, "// lumina: allow(D001 unterminated"),
        ]);
        assert!(w.is_empty());
        assert_eq!(bad.len(), 4);
    }

    #[test]
    fn ordinary_comments_are_ignored() {
        let (w, bad) = parse(&[
            (1, "// normal comment"),
            (2, "// lumina: disallow(D001) not the marker"),
        ]);
        assert!(w.is_empty());
        assert!(bad.is_empty());
    }
}
