//! Checked-in mirror manifest: which Rust↔Python file pairs must
//! stay in lockstep, and which named oracle literals are pinned
//! across Rust files.
//!
//! Adding a pair is one entry here — the differ
//! ([`crate::analysis::mirror`]) does the rest. Paths are relative
//! to the repo root (the directory holding `rust/` and `python/`).

/// Which symbols of a file participate in a mirror pair.
#[derive(Debug, Clone, Copy)]
pub enum Filter {
    /// Every extracted symbol.
    All,
    /// Only the listed symbols.
    Named(&'static [&'static str]),
    /// Every symbol except those starting with one of the prefixes
    /// (for files that also hold side-local definitions).
    ExceptPrefixes(&'static [&'static str]),
}

impl Filter {
    pub fn keeps(&self, name: &str) -> bool {
        match self {
            Filter::All => true,
            Filter::Named(names) => names.contains(&name),
            Filter::ExceptPrefixes(prefixes) => {
                !prefixes.iter().any(|p| name.starts_with(p))
            }
        }
    }
}

/// How the pair's symbol tables are compared.
#[derive(Debug, Clone, Copy)]
pub enum MirrorKind {
    /// Flat named constants on both sides (M001/M002 per symbol).
    Consts,
    /// A scenario registry: Rust `[Scenario; N]` array vs Python
    /// dict under `symbol`, compared entry-by-entry and
    /// field-by-field after resolving named specs, struct bases,
    /// dataclass defaults, and `replace()` overrides.
    Registry { symbol: &'static str },
}

/// One declared mirror pair.
#[derive(Debug, Clone, Copy)]
pub struct MirrorPair {
    /// Stable name, used in finding messages.
    pub name: &'static str,
    pub rust_path: &'static str,
    pub rust_filter: Filter,
    /// Extra Rust files whose consts feed named-spec resolution
    /// (e.g. `GPT3_175B` lives in `spec.rs`, not the registry file).
    pub rust_aux: &'static [&'static str],
    pub python_path: &'static str,
    pub python_filter: Filter,
    pub kind: MirrorKind,
}

/// The production manifest: every contract the repo relies on.
pub const PAIRS: [MirrorPair; 4] = [
    MirrorPair {
        name: "arch-constants",
        rust_path: "rust/src/arch/constants.rs",
        rust_filter: Filter::All,
        rust_aux: &[],
        python_path: "python/compile/constants.py",
        // The python file also holds the design-encoding /
        // op-table-layout block, mirrored structurally (field
        // order, enum codes) rather than by named constant.
        python_filter: Filter::ExceptPrefixes(&[
            "IDX_", "COL_", "KIND_", "MAX_", "N_",
        ]),
        kind: MirrorKind::Consts,
    },
    MirrorPair {
        name: "design-params",
        rust_path: "rust/src/design/point.rs",
        rust_filter: Filter::Named(&["N_PARAMS"]),
        rust_aux: &[],
        python_path: "python/compile/constants.py",
        python_filter: Filter::Named(&["N_PARAMS"]),
        kind: MirrorKind::Consts,
    },
    MirrorPair {
        name: "op-table-bounds",
        rust_path: "rust/src/workload/spec.rs",
        rust_filter: Filter::Named(&["MAX_OPS", "N_PHASES"]),
        rust_aux: &[],
        python_path: "python/compile/constants.py",
        python_filter: Filter::Named(&["MAX_OPS", "N_PHASES"]),
        kind: MirrorKind::Consts,
    },
    MirrorPair {
        name: "scenario-registry",
        rust_path: "rust/src/workload/scenario.rs",
        rust_filter: Filter::All,
        rust_aux: &["rust/src/workload/spec.rs"],
        python_path: "python/compile/workload.py",
        python_filter: Filter::All,
        kind: MirrorKind::Registry { symbol: "SCENARIOS" },
    },
];

/// A named oracle literal duplicated across Rust files: every file
/// must pin `field` to exactly `value` at least once (M003).
#[derive(Debug, Clone, Copy)]
pub struct OraclePin {
    /// Stable name, used in finding messages.
    pub name: &'static str,
    /// The metric field the pin asserts on
    /// (`(m.<field> - <value>).abs() / <value> < rtol` idiom).
    pub field: &'static str,
    /// Canonical literal, exactly as the python oracle prints it.
    pub value: &'static str,
    pub files: &'static [&'static str],
}

/// Files carrying the A100 reference pins.
const A100_PIN_FILES: &[&str] = &[
    "rust/src/sim/roofline.rs",
    "rust/tests/artifact_vs_mirror.rs",
];

/// The A100 reference values printed by the python oracle
/// (`python/tests`), duplicated in the roofline tests and the
/// artifact integration tests.
pub const PINS: [OraclePin; 6] = [
    OraclePin {
        name: "a100-ttft",
        field: "ttft_ms",
        value: "36.70556",
        files: A100_PIN_FILES,
    },
    OraclePin {
        name: "a100-tpot",
        field: "tpot_ms",
        value: "0.4424397",
        files: A100_PIN_FILES,
    },
    OraclePin {
        name: "a100-area",
        field: "area_mm2",
        value: "833.9728",
        files: A100_PIN_FILES,
    },
    OraclePin {
        name: "a100-prefill-energy",
        field: "prefill_energy_mj",
        value: "8116.046",
        files: A100_PIN_FILES,
    },
    OraclePin {
        name: "a100-decode-energy",
        field: "energy_per_token_mj",
        value: "41.352123",
        files: A100_PIN_FILES,
    },
    OraclePin {
        name: "a100-avg-power",
        field: "avg_power_w",
        value: "219.59186",
        files: A100_PIN_FILES,
    },
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn filters_behave() {
        assert!(Filter::All.keeps("ANYTHING"));
        let named = Filter::Named(&["A", "B"]);
        assert!(named.keeps("A"));
        assert!(!named.keeps("C"));
        let exc = Filter::ExceptPrefixes(&["IDX_", "N_"]);
        assert!(exc.keeps("CLOCK_HZ"));
        assert!(!exc.keeps("IDX_CORES"));
        assert!(!exc.keeps("N_PARAMS"));
    }

    #[test]
    fn manifest_names_are_unique() {
        let mut names: Vec<&str> =
            PAIRS.iter().map(|p| p.name).collect();
        names.extend(PINS.iter().map(|p| p.name));
        let total = names.len();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), total);
    }

    #[test]
    fn pin_values_parse_as_finite_floats() {
        for pin in &PINS {
            let v: f64 = pin.value.parse().expect(pin.name);
            assert!(v.is_finite() && v > 0.0, "{}", pin.name);
            assert!(!pin.files.is_empty());
        }
    }
}
