//! Comment- and string-stripping lexer for the determinism lint.
//!
//! In the style of the hand-rolled [`crate::util::json`] parser: a
//! small, total, dependency-free byte scanner — not a full Rust lexer.
//! It produces the token stream the rule scanner needs (identifiers
//! and punctuation with line numbers) while discarding exactly the
//! contexts that cause false positives (string literals, char
//! literals, block comments) and *capturing* line comments for the
//! waiver parser (see [`crate::analysis::waiver`] for the syntax).
//!
//! Two entry points share one scanner: [`lex`] drops string literals
//! entirely (the determinism rules must never match inside them),
//! while [`lex_full`] keeps each one as a [`TokKind::Str`] token so
//! the mirror extractor can read scenario names and doc strings.
//!
//! Deliberate approximations, safe for linting purposes:
//! * numeric literals lex as identifier-like tokens (`0x54`, `1e15`);
//!   no rule matches them;
//! * a raw identifier `r#type` lexes as `r`, `#`, `type`;
//! * lifetimes drop their tick, so `'a` lexes as the ident `a`.

/// Token class — the scanner only distinguishes words from symbols,
/// plus (under [`lex_full`]) string literals.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier-like: `[A-Za-z0-9_]+` (includes keywords, numbers).
    Ident,
    /// Single punctuation char, or the two-char path separator `::`.
    Punct,
    /// String literal (only emitted by [`lex_full`]); `text` is the
    /// content between the quotes, escapes left as written.
    Str,
}

/// One lexed token, borrowing from the source text.
#[derive(Debug, Clone, Copy)]
pub struct Tok<'a> {
    pub kind: TokKind,
    pub text: &'a str,
    /// 1-based source line of the token's first byte.
    pub line: u32,
    /// 1-based byte column of the token's first byte on its line.
    pub col: u32,
}

impl<'a> Tok<'a> {
    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == TokKind::Ident && self.text == s
    }
}

/// Lexer output: the token stream plus every `//` comment (with its
/// line), which the waiver parser consumes.
#[derive(Debug, Default)]
pub struct Lexed<'a> {
    pub toks: Vec<Tok<'a>>,
    pub comments: Vec<(u32, &'a str)>,
}

fn ident_byte(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// Byte length of the UTF-8 char starting with `first` (total: never
/// more than what keeps slicing on a char boundary).
fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

/// Lex `src` into tokens + captured line comments, dropping string
/// literals (the determinism scanner's view).
pub fn lex(src: &str) -> Lexed<'_> {
    lex_impl(src, false)
}

/// Like [`lex`] but keeps every string literal as a [`TokKind::Str`]
/// token (the mirror extractor's view).
pub fn lex_full(src: &str) -> Lexed<'_> {
    lex_impl(src, true)
}

fn lex_impl(src: &str, keep_strings: bool) -> Lexed<'_> {
    let b = src.as_bytes();
    let n = b.len();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line = 1u32;
    let mut line_start = 0usize;
    while i < n {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            line_start = i;
            continue;
        }
        if c.is_ascii_whitespace() {
            i += 1;
            continue;
        }
        let col = (i - line_start + 1) as u32;
        // Line comment: capture for the waiver parser.
        if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            let start = i;
            while i < n && b[i] != b'\n' {
                i += 1;
            }
            out.comments.push((line, &src[start..i]));
            continue;
        }
        // Block comment (nested, like Rust's).
        if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if b[i] == b'/' && i + 1 < n && b[i + 1] == b'*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == b'*' && i + 1 < n && b[i + 1] == b'/'
                {
                    depth -= 1;
                    i += 2;
                } else {
                    if b[i] == b'\n' {
                        line += 1;
                        line_start = i + 1;
                    }
                    i += 1;
                }
            }
            continue;
        }
        // Raw string r"..." / r#"..."# (any number of hashes), and
        // the byte-string spelling br"..." / br#"..."#.
        if c == b'r' || (c == b'b' && i + 1 < n && b[i + 1] == b'r') {
            let mut j = i + 1 + usize::from(c == b'b');
            let mut hashes = 0usize;
            while j < n && b[j] == b'#' {
                hashes += 1;
                j += 1;
            }
            if j < n && b[j] == b'"' {
                let tok_line = line;
                j += 1;
                let inner_start = j;
                let mut inner_end = n;
                while j < n {
                    if b[j] == b'"'
                        && j + 1 + hashes <= n
                        && b[j + 1..j + 1 + hashes]
                            .iter()
                            .all(|&h| h == b'#')
                    {
                        inner_end = j;
                        j += 1 + hashes;
                        break;
                    }
                    if b[j] == b'\n' {
                        line += 1;
                        line_start = j + 1;
                    }
                    j += 1;
                }
                if keep_strings {
                    out.toks.push(Tok {
                        kind: TokKind::Str,
                        text: &src[inner_start..inner_end],
                        line: tok_line,
                        col,
                    });
                }
                i = j;
                continue;
            }
            // Not a raw string: fall through (ident starting with r
            // or b, or a raw identifier's `r` + `#`).
        }
        // Plain string literal.
        if c == b'"' {
            let tok_line = line;
            i += 1;
            let inner_start = i;
            let mut inner_end = n;
            while i < n {
                match b[i] {
                    b'\\' => {
                        // Escaped char; a `\<newline>` continuation
                        // still advances the line counter.
                        if i + 1 < n && b[i + 1] == b'\n' {
                            line += 1;
                            line_start = i + 2;
                        }
                        i += 2;
                    }
                    b'"' => {
                        inner_end = i;
                        i += 1;
                        break;
                    }
                    b'\n' => {
                        line += 1;
                        i += 1;
                        line_start = i;
                    }
                    _ => i += 1,
                }
            }
            if keep_strings {
                out.toks.push(Tok {
                    kind: TokKind::Str,
                    text: &src[inner_start..inner_end.min(n)],
                    line: tok_line,
                    col,
                });
            }
            continue;
        }
        // Char literal vs lifetime tick.
        if c == b'\'' {
            if i + 1 < n && b[i + 1] == b'\\' {
                // Escaped char literal: skip to the closing quote.
                let mut j = i + 2;
                while j < n && b[j] != b'\'' {
                    j += 1;
                }
                i = (j + 1).min(n);
                continue;
            }
            if i + 1 < n && b[i + 1] != b'\'' {
                let len = utf8_len(b[i + 1]);
                if i + 1 + len < n && b[i + 1 + len] == b'\'' {
                    // One char between quotes: a char literal.
                    i += len + 2;
                    continue;
                }
            }
            // A lifetime: drop the tick, lex the ident next round.
            i += 1;
            continue;
        }
        if ident_byte(c) {
            let start = i;
            while i < n && ident_byte(b[i]) {
                i += 1;
            }
            out.toks.push(Tok {
                kind: TokKind::Ident,
                text: &src[start..i],
                line,
                col,
            });
            continue;
        }
        if c == b':' && i + 1 < n && b[i + 1] == b':' {
            out.toks.push(Tok {
                kind: TokKind::Punct,
                text: &src[i..i + 2],
                line,
                col,
            });
            i += 2;
            continue;
        }
        // Single punctuation char (full UTF-8 char so slicing stays
        // on a boundary even for stray non-ASCII bytes).
        let len = utf8_len(c).min(n - i);
        out.toks.push(Tok {
            kind: TokKind::Punct,
            text: &src[i..i + len],
            line,
            col,
        });
        i += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts(src: &str) -> Vec<String> {
        lex(src).toks.iter().map(|t| t.text.to_string()).collect()
    }

    #[test]
    fn strips_strings_and_comments() {
        let l = lex("let x = \"a.unwrap()\"; // lumina: allow(X) y\n");
        let t: Vec<_> = l.toks.iter().map(|t| t.text).collect();
        assert_eq!(t, vec!["let", "x", "=", ";"]);
        assert_eq!(l.comments.len(), 1);
        assert_eq!(l.comments[0].0, 1);
        assert!(l.comments[0].1.contains("allow(X)"));
    }

    #[test]
    fn nested_block_comments_and_raw_strings() {
        let src = "a /* x /* y */ z */ b r#\"s \"quoted\" t\"# c";
        assert_eq!(texts(src), vec!["a", "b", "c"]);
    }

    #[test]
    fn char_literals_vs_lifetimes() {
        let src = "m('\\n'); f::<'a>(x); push('}'); q('\u{e9}')";
        let t = texts(src);
        assert!(t.contains(&"a".to_string())); // lifetime ident kept
        // no brace tokens leaked from the char literals:
        assert!(!t.contains(&"}".to_string()));
        assert!(!t.contains(&"\u{e9}".to_string()));
    }

    #[test]
    fn line_numbers_survive_multiline_constructs() {
        let src = "a\n\"two\nlines\"\n/* c\nc */\nb";
        let l = lex(src);
        assert_eq!(l.toks[0].line, 1);
        assert_eq!(l.toks[1].line, 6);
    }

    #[test]
    fn path_separator_is_one_token() {
        assert_eq!(
            texts("Instant::now()"),
            vec!["Instant", "::", "now", "(", ")"]
        );
    }

    #[test]
    fn numbers_lex_as_ident_like_tokens() {
        assert_eq!(texts("0x54 1e15"), vec!["0x54", "1e15"]);
        assert_eq!(texts("1.5"), vec!["1", ".", "5"]);
    }

    // ---- hardening: raw strings, byte strings, nesting ----------

    #[test]
    fn raw_string_with_trailing_backslash_does_not_escape() {
        // In a raw string `\` is literal, so the quote after it
        // closes the literal; `x` must survive as a token.
        assert_eq!(texts("a r\"c:\\\" x"), vec!["a", "x"]);
    }

    #[test]
    fn raw_byte_strings_are_skipped_whole() {
        // `br"..."` used to lex as ident `br` + plain string, so an
        // inner `\"` was mis-read as an escape and leaked tokens.
        assert_eq!(texts("a br\"x \\\" y\" b"), vec!["a", "b"]);
        assert_eq!(
            texts("a br#\"q \"inner\" r\"# b"),
            vec!["a", "b"]
        );
        // A bare `br` ident (no quote) still lexes as an ident.
        assert_eq!(texts("let br = 1;"), vec!["let", "br", "=", ";"]);
    }

    #[test]
    fn nested_block_comments_with_string_like_content() {
        let src = "a /* \" /* 'x */ \" still comment */ b";
        assert_eq!(texts(src), vec!["a", "b"]);
    }

    #[test]
    fn multiline_raw_string_keeps_line_count() {
        let l = lex("a r#\"one\ntwo\nthree\"# b");
        assert_eq!(l.toks[0].line, 1);
        assert_eq!(l.toks[1].line, 3);
    }

    #[test]
    fn lex_full_keeps_string_contents() {
        let l = lex_full("let s = \"name\"; r#\"raw \"q\" t\"#");
        let strs: Vec<_> = l
            .toks
            .iter()
            .filter(|t| t.kind == TokKind::Str)
            .map(|t| t.text)
            .collect();
        assert_eq!(strs, vec!["name", "raw \"q\" t"]);
        // And `lex` drops the same literals entirely.
        let stripped = lex("let s = \"name\";");
        assert!(stripped
            .toks
            .iter()
            .all(|t| t.kind != TokKind::Str));
    }

    #[test]
    fn columns_are_one_based_byte_offsets() {
        let l = lex("ab cd\n  ef::gh");
        let pos: Vec<(u32, u32)> =
            l.toks.iter().map(|t| (t.line, t.col)).collect();
        assert_eq!(
            pos,
            vec![(1, 1), (1, 4), (2, 3), (2, 5), (2, 7)]
        );
    }
}
