//! Comment- and string-stripping lexer for the determinism lint.
//!
//! In the style of the hand-rolled [`crate::util::json`] parser: a
//! small, total, dependency-free byte scanner — not a full Rust lexer.
//! It produces the token stream the rule scanner needs (identifiers
//! and punctuation with line numbers) while discarding exactly the
//! contexts that cause false positives (string literals, char
//! literals, block comments) and *capturing* line comments for the
//! waiver parser (see [`crate::analysis::waiver`] for the syntax).
//!
//! Deliberate approximations, safe for linting purposes:
//! * numeric literals lex as identifier-like tokens (`0x54`, `1e15`);
//!   no rule matches them;
//! * a raw identifier `r#type` lexes as `r`, `#`, `type`;
//! * lifetimes drop their tick, so `'a` lexes as the ident `a`.

/// Token class — the scanner only distinguishes words from symbols.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier-like: `[A-Za-z0-9_]+` (includes keywords, numbers).
    Ident,
    /// Single punctuation char, or the two-char path separator `::`.
    Punct,
}

/// One lexed token, borrowing from the source text.
#[derive(Debug, Clone, Copy)]
pub struct Tok<'a> {
    pub kind: TokKind,
    pub text: &'a str,
    /// 1-based source line of the token's first byte.
    pub line: u32,
}

impl<'a> Tok<'a> {
    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == TokKind::Ident && self.text == s
    }
}

/// Lexer output: the token stream plus every `//` comment (with its
/// line), which the waiver parser consumes.
#[derive(Debug, Default)]
pub struct Lexed<'a> {
    pub toks: Vec<Tok<'a>>,
    pub comments: Vec<(u32, &'a str)>,
}

fn ident_byte(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// Byte length of the UTF-8 char starting with `first` (total: never
/// more than what keeps slicing on a char boundary).
fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

/// Lex `src` into tokens + captured line comments.
pub fn lex(src: &str) -> Lexed<'_> {
    let b = src.as_bytes();
    let n = b.len();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line = 1u32;
    while i < n {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_ascii_whitespace() {
            i += 1;
            continue;
        }
        // Line comment: capture for the waiver parser.
        if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            let start = i;
            while i < n && b[i] != b'\n' {
                i += 1;
            }
            out.comments.push((line, &src[start..i]));
            continue;
        }
        // Block comment (nested, like Rust's).
        if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if b[i] == b'/' && i + 1 < n && b[i + 1] == b'*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == b'*' && i + 1 < n && b[i + 1] == b'/'
                {
                    depth -= 1;
                    i += 2;
                } else {
                    if b[i] == b'\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            continue;
        }
        // Raw string r"..." / r#"..."# (any number of hashes).
        if c == b'r' {
            let mut j = i + 1;
            let mut hashes = 0usize;
            while j < n && b[j] == b'#' {
                hashes += 1;
                j += 1;
            }
            if j < n && b[j] == b'"' {
                j += 1;
                while j < n {
                    if b[j] == b'"'
                        && j + 1 + hashes <= n
                        && b[j + 1..j + 1 + hashes]
                            .iter()
                            .all(|&h| h == b'#')
                    {
                        j += 1 + hashes;
                        break;
                    }
                    if b[j] == b'\n' {
                        line += 1;
                    }
                    j += 1;
                }
                i = j;
                continue;
            }
            // Not a raw string: fall through (ident starting with r,
            // or a raw identifier's `r` + `#`).
        }
        // Plain string literal.
        if c == b'"' {
            i += 1;
            while i < n {
                match b[i] {
                    b'\\' => {
                        // Escaped char; a `\<newline>` continuation
                        // still advances the line counter.
                        if i + 1 < n && b[i + 1] == b'\n' {
                            line += 1;
                        }
                        i += 2;
                    }
                    b'"' => {
                        i += 1;
                        break;
                    }
                    b'\n' => {
                        line += 1;
                        i += 1;
                    }
                    _ => i += 1,
                }
            }
            continue;
        }
        // Char literal vs lifetime tick.
        if c == b'\'' {
            if i + 1 < n && b[i + 1] == b'\\' {
                // Escaped char literal: skip to the closing quote.
                let mut j = i + 2;
                while j < n && b[j] != b'\'' {
                    j += 1;
                }
                i = (j + 1).min(n);
                continue;
            }
            if i + 1 < n && b[i + 1] != b'\'' {
                let len = utf8_len(b[i + 1]);
                if i + 1 + len < n && b[i + 1 + len] == b'\'' {
                    // One char between quotes: a char literal.
                    i += len + 2;
                    continue;
                }
            }
            // A lifetime: drop the tick, lex the ident next round.
            i += 1;
            continue;
        }
        if ident_byte(c) {
            let start = i;
            while i < n && ident_byte(b[i]) {
                i += 1;
            }
            out.toks.push(Tok {
                kind: TokKind::Ident,
                text: &src[start..i],
                line,
            });
            continue;
        }
        if c == b':' && i + 1 < n && b[i + 1] == b':' {
            out.toks.push(Tok {
                kind: TokKind::Punct,
                text: &src[i..i + 2],
                line,
            });
            i += 2;
            continue;
        }
        // Single punctuation char (full UTF-8 char so slicing stays
        // on a boundary even for stray non-ASCII bytes).
        let len = utf8_len(c).min(n - i);
        out.toks.push(Tok {
            kind: TokKind::Punct,
            text: &src[i..i + len],
            line,
        });
        i += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts(src: &str) -> Vec<String> {
        lex(src).toks.iter().map(|t| t.text.to_string()).collect()
    }

    #[test]
    fn strips_strings_and_comments() {
        let l = lex("let x = \"a.unwrap()\"; // lumina: allow(X) y\n");
        let t: Vec<_> = l.toks.iter().map(|t| t.text).collect();
        assert_eq!(t, vec!["let", "x", "=", ";"]);
        assert_eq!(l.comments.len(), 1);
        assert_eq!(l.comments[0].0, 1);
        assert!(l.comments[0].1.contains("allow(X)"));
    }

    #[test]
    fn nested_block_comments_and_raw_strings() {
        let src = "a /* x /* y */ z */ b r#\"s \"quoted\" t\"# c";
        assert_eq!(texts(src), vec!["a", "b", "c"]);
    }

    #[test]
    fn char_literals_vs_lifetimes() {
        let src = "m('\\n'); f::<'a>(x); push('}'); q('\u{e9}')";
        let t = texts(src);
        assert!(t.contains(&"a".to_string())); // lifetime ident kept
        // no brace tokens leaked from the char literals:
        assert!(!t.contains(&"}".to_string()));
        assert!(!t.contains(&"\u{e9}".to_string()));
    }

    #[test]
    fn line_numbers_survive_multiline_constructs() {
        let src = "a\n\"two\nlines\"\n/* c\nc */\nb";
        let l = lex(src);
        assert_eq!(l.toks[0].line, 1);
        assert_eq!(l.toks[1].line, 6);
    }

    #[test]
    fn path_separator_is_one_token() {
        assert_eq!(
            texts("Instant::now()"),
            vec!["Instant", "::", "now", "(", ")"]
        );
    }

    #[test]
    fn numbers_lex_as_ident_like_tokens() {
        assert_eq!(texts("0x54 1e15"), vec!["0x54", "1e15"]);
        assert_eq!(texts("1.5"), vec!["1", ".", "5"]);
    }
}
