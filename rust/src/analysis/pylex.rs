//! Python lexer for the mirror-drift analyzer.
//!
//! Sibling of [`crate::analysis::lexer`], same idiom and token
//! types: a small, total, dependency-free byte scanner producing
//! [`Tok`] streams — not a full Python lexer. It keeps string
//! literals (the extractor reads dict keys and docstrings from
//! them), captures `#` comments for the waiver parser (the waiver
//! syntax is comment-marker-agnostic, so
//! `# lumina: allow(M002) reason` works unchanged), and tracks
//! 1-based byte columns so the extractor can tell module level
//! (column 1) from class and function bodies.
//!
//! Deliberate approximations, safe for extraction purposes:
//! * numeric literals lex as identifier-like tokens, split at `.`
//!   and sign chars exactly like the Rust lexer (`1.5` is three
//!   tokens) — the extractor re-joins them;
//! * f-string interpolation is not parsed; the content is kept as
//!   one [`TokKind::Str`] token;
//! * indentation is not tokenized — column tracking subsumes it.

use crate::analysis::lexer::{Lexed, Tok, TokKind};

fn ident_byte(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

/// True for the letters Python allows as string-literal prefixes.
fn prefix_byte(c: u8) -> bool {
    matches!(
        c,
        b'r' | b'b' | b'f' | b'u' | b'R' | b'B' | b'F' | b'U'
    )
}

/// Lex Python `src` into tokens (strings kept) + `#` comments.
pub fn lex_py(src: &str) -> Lexed<'_> {
    let b = src.as_bytes();
    let n = b.len();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line = 1u32;
    let mut line_start = 0usize;
    while i < n {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            line_start = i;
            continue;
        }
        if c.is_ascii_whitespace() {
            i += 1;
            continue;
        }
        let col = (i - line_start + 1) as u32;
        // Comment: capture for the waiver parser.
        if c == b'#' {
            let start = i;
            while i < n && b[i] != b'\n' {
                i += 1;
            }
            out.comments.push((line, &src[start..i]));
            continue;
        }
        // Line continuation: `\` at end of line joins lines without
        // producing a token.
        if c == b'\\' && i + 1 < n && b[i + 1] == b'\n' {
            line += 1;
            i += 2;
            line_start = i;
            continue;
        }
        // String literal, with optional 1-2 letter prefix (r, b, f,
        // u and combinations, any case).
        if c == b'"' || c == b'\'' || prefix_byte(c) {
            let mut q = i;
            while q < n && q < i + 2 && prefix_byte(b[q]) {
                q += 1;
            }
            if q < n && (b[q] == b'"' || b[q] == b'\'') {
                let quote = b[q];
                let tok_line = line;
                let triple = q + 2 < n
                    && b[q + 1] == quote
                    && b[q + 2] == quote;
                let mut j = q + if triple { 3 } else { 1 };
                let inner_start = j;
                let mut inner_end = n;
                while j < n {
                    if b[j] == b'\\' {
                        if j + 1 < n && b[j + 1] == b'\n' {
                            line += 1;
                            line_start = j + 2;
                        }
                        j += 2;
                        continue;
                    }
                    if triple {
                        if b[j] == quote
                            && j + 2 < n
                            && b[j + 1] == quote
                            && b[j + 2] == quote
                        {
                            inner_end = j;
                            j += 3;
                            break;
                        }
                        if b[j] == b'\n' {
                            line += 1;
                            line_start = j + 1;
                        }
                    } else {
                        if b[j] == quote {
                            inner_end = j;
                            j += 1;
                            break;
                        }
                        if b[j] == b'\n' {
                            // Unterminated single-quoted string:
                            // stop at the newline.
                            inner_end = j;
                            break;
                        }
                    }
                    j += 1;
                }
                out.toks.push(Tok {
                    kind: TokKind::Str,
                    text: &src[inner_start..inner_end.min(n)],
                    line: tok_line,
                    col,
                });
                i = j;
                continue;
            }
            // Prefix letters not followed by a quote: fall through
            // to the ident scanner (plain identifier like `replace`).
        }
        if ident_byte(c) {
            let start = i;
            while i < n && ident_byte(b[i]) {
                i += 1;
            }
            out.toks.push(Tok {
                kind: TokKind::Ident,
                text: &src[start..i],
                line,
                col,
            });
            continue;
        }
        // Single punctuation char (UTF-8 safe).
        let len = utf8_len(c).min(n - i);
        out.toks.push(Tok {
            kind: TokKind::Punct,
            text: &src[i..i + len],
            line,
            col,
        });
        i += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts(src: &str) -> Vec<String> {
        lex_py(src)
            .toks
            .iter()
            .map(|t| t.text.to_string())
            .collect()
    }

    #[test]
    fn idents_numbers_and_puncts() {
        assert_eq!(
            texts("X = 1.5e-3\n"),
            vec!["X", "=", "1", ".", "5e", "-", "3"]
        );
    }

    #[test]
    fn comments_are_captured_not_tokenized() {
        let l = lex_py("A = 1  # lumina: allow(M001) pinned\nB = 2");
        assert_eq!(l.comments.len(), 1);
        assert_eq!(l.comments[0].0, 1);
        assert!(l.comments[0].1.contains("allow(M001)"));
        let t: Vec<_> = l.toks.iter().map(|t| t.text).collect();
        assert_eq!(t, vec!["A", "=", "1", "B", "=", "2"]);
    }

    #[test]
    fn strings_kept_with_content_and_prefixes() {
        let l = lex_py("s = \"abc\"\nt = r'd\\e'\nu = f\"x{y}\"");
        let strs: Vec<_> = l
            .toks
            .iter()
            .filter(|t| t.kind == TokKind::Str)
            .map(|t| (t.text, t.line))
            .collect();
        assert_eq!(
            strs,
            vec![("abc", 1), ("d\\e", 2), ("x{y}", 3)]
        );
    }

    #[test]
    fn triple_quoted_docstring_spans_lines() {
        let src = "\"\"\"Doc line one.\n\nSee foo.\n\"\"\"\nX = 1\n";
        let l = lex_py(src);
        assert_eq!(l.toks[0].kind, TokKind::Str);
        assert_eq!(l.toks[0].line, 1);
        assert!(l.toks[0].text.contains("Doc line one."));
        assert!(l.toks[0].text.contains("See foo."));
        let x = &l.toks[1];
        assert!(x.is_ident("X"));
        assert_eq!(x.line, 5);
        assert_eq!(x.col, 1);
    }

    #[test]
    fn triple_quotes_containing_single_quotes() {
        let src = "d = '''it's \"fine\"'''\nY = 2";
        let l = lex_py(src);
        let s = l
            .toks
            .iter()
            .find(|t| t.kind == TokKind::Str)
            .expect("str tok");
        assert_eq!(s.text, "it's \"fine\"");
        assert!(l.toks.iter().any(|t| t.is_ident("Y")));
    }

    #[test]
    fn columns_distinguish_module_level_from_bodies() {
        let src = "A = 1\nclass C:\n    b: int = 2\n";
        let l = lex_py(src);
        let a = l.toks.iter().find(|t| t.is_ident("A")).expect("A");
        let bfield =
            l.toks.iter().find(|t| t.is_ident("b")).expect("b");
        assert_eq!(a.col, 1);
        assert_eq!(bfield.col, 5);
    }

    #[test]
    fn escaped_quote_does_not_close_string() {
        let l = lex_py("s = 'a\\'b'\nZ = 1");
        let s = l
            .toks
            .iter()
            .find(|t| t.kind == TokKind::Str)
            .expect("str tok");
        assert_eq!(s.text, "a\\'b");
        assert!(l.toks.iter().any(|t| t.is_ident("Z")));
    }

    #[test]
    fn line_continuation_joins_lines() {
        let l = lex_py("A = 1 + \\\n    2\nB = 3");
        let bt = l.toks.iter().find(|t| t.is_ident("B")).expect("B");
        assert_eq!(bt.line, 3);
        assert_eq!(bt.col, 1);
    }
}
