//! Determinism lint: a dependency-free static-analysis pass over the
//! crate's own sources (`lumina lint`).
//!
//! The repo's test strategy — golden ask/tell trajectories, bitwise
//! SoA equivalence, checkpoint replay — rests on determinism
//! invariants that used to be conventions. This subsystem turns them
//! into checked rules:
//!
//! | rule | severity | invariant |
//! |------|----------|-----------|
//! | D001 | error    | no hash-container iteration in det modules |
//! | D002 | warning  | wall-clock only via `util::bench` |
//! | D003 | error    | no entropy RNG anywhere |
//! | D004 | error    | no RNG draws in `DseSession::tell` |
//! | F001 | error    | no float reduction over unordered iters |
//! | P001 | warning  | no unwrap/expect in library code |
//! | W001 | warning  | waivers must be well-formed + reasoned |
//!
//! Findings can be waived inline (`// lumina: allow(D002) reason`,
//! see [`waiver`]); the CI gate runs `lumina lint --deny-warnings`
//! and requires zero unwaivered findings.
//!
//! Pipeline: [`lexer`] strips comments/strings and tokenizes,
//! [`scan`] matches rules with region tracking, [`waiver`] applies
//! inline suppressions, [`report`] aggregates and serializes.
//!
//! A second engine shares that pipeline tail: the cross-language
//! mirror-drift differ (`lumina lint --mirror`). [`pylex`] lexes
//! Python with the same token types, [`extract`] parses both sides
//! of every pair declared in [`mirrors`] into typed symbol tables,
//! and [`mirror`] diffs them into M001-M004 findings:
//!
//! | rule | severity | invariant |
//! |------|----------|-----------|
//! | M001 | error    | mirrored constants carry equal literals |
//! | M002 | error    | mirror symbols exist on both sides |
//! | M003 | error    | duplicated oracle pins agree everywhere |
//! | M004 | warning  | MIRROR doc pointers name live targets |

pub mod extract;
pub mod lexer;
pub mod mirror;
pub mod mirrors;
pub mod pylex;
pub mod report;
pub mod rules;
pub mod scan;
pub mod waiver;

pub use report::{Counts, Report};
pub use rules::{Rule, Severity, RULES};

use std::fs;
use std::path::{Path, PathBuf};

use crate::error::Context;
use crate::Result;

/// One lint finding, waiver state already resolved.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Rule id, e.g. `"D001"`.
    pub rule: String,
    pub severity: Severity,
    /// Path relative to the lint root, forward slashes.
    pub file: String,
    /// 1-based line number.
    pub line: u32,
    pub message: String,
    /// True when an applicable reasoned waiver covers this finding.
    pub waived: bool,
    pub waiver_reason: Option<String>,
}

/// Lint a single in-memory source file. `relpath` scopes the
/// path-sensitive rules (D001/D002/P001), so pass the path the file
/// would have under `src/`.
pub fn lint_source(relpath: &str, src: &str) -> Vec<Finding> {
    scan::scan_file(relpath, src)
}

/// Lint every `.rs` file under `root` (recursively, sorted walk) and
/// aggregate into a [`Report`]. Deterministic: same tree in, same
/// report out, independent of directory-entry order.
pub fn lint_tree(root: &Path) -> Result<Report> {
    let mut files: Vec<PathBuf> = Vec::new();
    collect_rs(root, &mut files)?;
    files.sort();
    let mut findings: Vec<Finding> = Vec::new();
    for path in &files {
        let rel = rel_of(root, path);
        let text = fs::read_to_string(path).with_context(|| {
            format!("lint: read {}", path.display())
        })?;
        findings.extend(scan::scan_file(&rel, &text));
    }
    findings.sort_by(|a, b| {
        (&a.file, a.line, &a.rule, &a.message)
            .cmp(&(&b.file, b.line, &b.rule, &b.message))
    });
    Ok(Report {
        engine: "determinism".to_string(),
        root: root.display().to_string().replace('\\', "/"),
        files: files.len(),
        findings,
    })
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let entries = fs::read_dir(dir).with_context(|| {
        format!("lint: read dir {}", dir.display())
    })?;
    for entry in entries {
        let entry = entry.with_context(|| {
            format!("lint: walk {}", dir.display())
        })?;
        let path = entry.path();
        let ty = entry.file_type().with_context(|| {
            format!("lint: stat {}", path.display())
        })?;
        if ty.is_dir() {
            if path
                .file_name()
                .is_some_and(|d| d == "target" || d == "out")
            {
                continue;
            }
            collect_rs(&path, out)?;
        } else if ty.is_file()
            && path.extension().is_some_and(|e| e == "rs")
        {
            out.push(path);
        }
    }
    Ok(())
}

fn rel_of(root: &Path, path: &Path) -> String {
    let p = path.strip_prefix(root).unwrap_or(path);
    p.to_string_lossy().replace('\\', "/")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lint_source_scopes_rules_by_relpath() {
        let src = "fn f() { let t = Instant::now(); }";
        assert_eq!(lint_source("runtime/x.rs", src).len(), 1);
        assert_eq!(lint_source("util/bench.rs", src).len(), 0);
    }

    #[test]
    fn lint_tree_walks_sorted_and_reports_counts() {
        let dir = std::env::temp_dir().join(format!(
            "lumina_lint_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let sub = dir.join("eval");
        fs::create_dir_all(&sub).expect("mkdir");
        fs::write(
            sub.join("b.rs"),
            "fn f(x: Option<u32>) -> u32 { x.unwrap() }",
        )
        .expect("write b.rs");
        fs::write(dir.join("a.rs"), "fn ok() {}")
            .expect("write a.rs");
        let report = lint_tree(&dir).expect("lint_tree");
        fs::remove_dir_all(&dir).expect("cleanup");
        assert_eq!(report.files, 2);
        assert_eq!(report.findings.len(), 1);
        assert_eq!(report.findings[0].file, "eval/b.rs");
        assert_eq!(report.findings[0].rule, "P001");
        assert!(report.failed(true));
        assert!(!report.failed(false));
    }
}
