//! The token scanner: walks one file's token stream, tracks regions
//! (`#[cfg(test)]` bodies, `impl DseSession` blocks, `fn tell`
//! bodies), and emits rule findings.
//!
//! The scanner is a heuristic token matcher, not a type checker. Its
//! contract is: no false positives on this repo's idioms (enforced by
//! the self-lint test over `rust/src`), and every true positive class
//! covered by a fixture under `tests/lint_fixtures/`.

use crate::analysis::lexer::{lex, Tok, TokKind};
use crate::analysis::rules::{
    self, DET_MODULES, ENTROPY_IDENTS, ORDER_METHODS, RNG_METHODS,
};
use crate::analysis::waiver;
use crate::analysis::Finding;

/// Path key for rule scoping: forward slashes, `src/` prefix
/// stripped so the same file keys identically whether the lint root
/// is `src` or `rust/src`.
fn relkey(rel: &str) -> &str {
    let r = rel.strip_prefix("src/").unwrap_or(rel);
    r.strip_prefix("rust/src/").unwrap_or(r)
}

/// D001/F001 scope: top-level modules with golden-pinned outputs.
pub fn is_det_module(rel: &str) -> bool {
    let key = relkey(rel);
    let top = key.split('/').next().unwrap_or(key);
    DET_MODULES.contains(&top)
}

/// D002 allowlist: the one sanctioned timing module plus benches.
pub fn d002_allowed(rel: &str) -> bool {
    let key = relkey(rel);
    key == "util/bench.rs"
        || key.starts_with("bench/")
        || key.contains("benches/")
}

/// P001 exemptions: binaries, golden-trajectory oracles, test and
/// bench trees. (`#[cfg(test)]` regions are exempted separately.)
pub fn p001_exempt(rel: &str) -> bool {
    let key = relkey(rel);
    let base = key.rsplit('/').next().unwrap_or(key);
    base == "main.rs"
        || base == "golden.rs"
        || key.contains("tests/")
        || key.contains("benches/")
}

fn punct(t: &Tok<'_>, s: &str) -> bool {
    t.kind == TokKind::Punct && t.text == s
}

/// Scan one file and return its complete findings, waivers already
/// applied. `relpath` is the path relative to the lint root.
pub fn scan_file(relpath: &str, src: &str) -> Vec<Finding> {
    let lexed = lex(src);
    let toks = &lexed.toks;
    let n = toks.len();
    // (rule id, line, message)
    let mut raw: Vec<(&'static str, u32, String)> = Vec::new();

    // Pre-pass: identifiers bound to a hash-container type, found by
    // walking back from a `HashMap`/`HashSet` token over an optional
    // `::`-path to a `:` (type ascription) or `=` (init), then to
    // the bound name. `use` imports and type aliases don't match —
    // they have no `:`/`=` immediately before the path.
    let mut hash_idents: Vec<&str> = Vec::new();
    for k in 0..n {
        let t = &toks[k];
        if t.kind != TokKind::Ident
            || (t.text != "HashMap" && t.text != "HashSet")
        {
            continue;
        }
        let mut j = k as isize - 1;
        while j >= 1 && punct(&toks[j as usize], "::") {
            j -= 1;
            if j >= 0 && toks[j as usize].kind == TokKind::Ident {
                j -= 1;
            }
        }
        if j >= 0
            && (punct(&toks[j as usize], ":")
                || punct(&toks[j as usize], "="))
        {
            j -= 1;
            if j >= 0 {
                let p = &toks[j as usize];
                if p.kind == TokKind::Ident
                    && p.text != "mut"
                    && !hash_idents.contains(&p.text)
                {
                    hash_idents.push(p.text);
                }
            }
        }
    }

    // Region tracking: stacks of brace depths.
    let mut depth = 0u32;
    let mut test_regions: Vec<u32> = Vec::new();
    let mut impl_dse: Vec<u32> = Vec::new();
    let mut tell_body: Vec<u32> = Vec::new();
    let mut pending_test = false;
    let mut pending_impl_dse = false;
    let mut pending_fn_tell = false;

    let mut i = 0usize;
    while i < n {
        let t = &toks[i];
        let in_test = !test_regions.is_empty();

        if punct(t, "{") {
            depth += 1;
            if pending_test {
                test_regions.push(depth);
                pending_test = false;
            }
            if pending_impl_dse {
                impl_dse.push(depth);
                pending_impl_dse = false;
            }
            if pending_fn_tell {
                tell_body.push(depth);
                pending_fn_tell = false;
            }
            i += 1;
            continue;
        }
        if punct(t, "}") {
            if test_regions.last() == Some(&depth) {
                test_regions.pop();
            }
            if impl_dse.last() == Some(&depth) {
                impl_dse.pop();
            }
            if tell_body.last() == Some(&depth) {
                tell_body.pop();
            }
            depth = depth.saturating_sub(1);
            i += 1;
            continue;
        }
        if punct(t, ";") {
            // An item ended before any body opened.
            pending_test = false;
            pending_impl_dse = false;
            pending_fn_tell = false;
            i += 1;
            continue;
        }

        // Attribute: `#[...]`. A `test` token inside (covers both
        // `#[test]` and `#[cfg(test)]`) marks the next body as a
        // test region — unless negated, as in `#[cfg(not(test))]`.
        if punct(t, "#") && i + 1 < n && punct(&toks[i + 1], "[") {
            let mut j = i + 2;
            let mut d = 1u32;
            let mut has_test = false;
            let mut has_not = false;
            while j < n && d > 0 {
                let a = &toks[j];
                if punct(a, "[") {
                    d += 1;
                } else if punct(a, "]") {
                    d -= 1;
                    if d == 0 {
                        break;
                    }
                } else if a.is_ident("test") {
                    has_test = true;
                } else if a.is_ident("not") {
                    has_not = true;
                }
                j += 1;
            }
            if has_test && !has_not {
                pending_test = true;
            }
            i = j + 1;
            continue;
        }

        // `impl ... DseSession ... {` opens a D004-tracked impl.
        if t.is_ident("impl") && !in_test {
            let mut j = i + 1;
            let mut seen_dse = false;
            while j < n
                && !punct(&toks[j], "{")
                && !punct(&toks[j], ";")
            {
                if toks[j].is_ident("DseSession") {
                    seen_dse = true;
                }
                j += 1;
            }
            if seen_dse && j < n && punct(&toks[j], "{") {
                pending_impl_dse = true;
            }
            i += 1;
            continue;
        }

        // `fn tell` inside a tracked impl: next `{` opens the body.
        if t.is_ident("fn")
            && !impl_dse.is_empty()
            && i + 1 < n
            && toks[i + 1].is_ident("tell")
        {
            pending_fn_tell = true;
            i += 2;
            continue;
        }

        if t.kind == TokKind::Ident {
            // D003: entropy sources, everywhere — tests included,
            // since test replay matters as much as library replay.
            if ENTROPY_IDENTS.contains(&t.text) {
                raw.push((
                    "D003",
                    t.line,
                    format!(
                        "entropy RNG `{}`; seed a \
                         stats::rng::Pcg32 instead",
                        t.text
                    ),
                ));
            }
            // D002: wall-clock reads outside the allowlist.
            if !in_test && !d002_allowed(relpath) {
                if t.text == "SystemTime" || t.text == "UNIX_EPOCH" {
                    raw.push((
                        "D002",
                        t.line,
                        format!(
                            "wall-clock `{}` outside \
                             util/bench.rs",
                            t.text
                        ),
                    ));
                }
                if t.text == "Instant"
                    && i + 2 < n
                    && punct(&toks[i + 1], "::")
                    && toks[i + 2].is_ident("now")
                {
                    raw.push((
                        "D002",
                        t.line,
                        "wall-clock `Instant::now` outside \
                         util/bench.rs"
                            .to_string(),
                    ));
                }
            }
        }

        // Method call: `. name (`.
        if punct(t, ".")
            && i + 2 < n
            && toks[i + 1].kind == TokKind::Ident
            && punct(&toks[i + 2], "(")
        {
            let m = toks[i + 1].text;
            let mline = toks[i + 1].line;
            let recv = if i > 0 && toks[i - 1].kind == TokKind::Ident
            {
                Some(toks[i - 1].text)
            } else {
                None
            };
            if !in_test {
                if (m == "unwrap" || m == "expect")
                    && !p001_exempt(relpath)
                {
                    raw.push((
                        "P001",
                        mline,
                        format!(
                            "`.{m}(` may panic in library code; \
                             return crate::error::Error or waive \
                             with a proof"
                        ),
                    ));
                }
                if !tell_body.is_empty() && RNG_METHODS.contains(&m)
                {
                    raw.push((
                        "D004",
                        mline,
                        format!(
                            "RNG draw `.{m}(` inside a `tell` \
                             body; draws belong in `ask`"
                        ),
                    ));
                }
                if let Some(r) = recv {
                    if hash_idents.contains(&r)
                        && ORDER_METHODS.contains(&m)
                    {
                        if is_det_module(relpath) {
                            raw.push((
                                "D001",
                                mline,
                                format!(
                                    "`{r}.{m}()` iterates an \
                                     unordered hash container"
                                ),
                            ));
                        }
                        scan_float_reduction(
                            toks, i, r, m, relpath, &mut raw,
                        );
                    }
                }
            }
            i += 1;
            continue;
        }

        // `for pat in <hash ident> {` — iteration without a method.
        if t.is_ident("for") && !in_test && is_det_module(relpath) {
            let mut j = i + 1;
            while j < n
                && !toks[j].is_ident("in")
                && !punct(&toks[j], "{")
            {
                j += 1;
            }
            if j < n && toks[j].is_ident("in") && j + 1 < n {
                let mut core: Vec<&Tok<'_>> = Vec::new();
                let mut k = j + 1;
                while k < n && !punct(&toks[k], "{") {
                    let x = &toks[k];
                    if !punct(x, "&") && !x.is_ident("mut") {
                        core.push(x);
                    }
                    k += 1;
                }
                if core.len() == 1
                    && core[0].kind == TokKind::Ident
                    && hash_idents.contains(&core[0].text)
                {
                    raw.push((
                        "D001",
                        core[0].line,
                        format!(
                            "`for _ in {}` iterates an unordered \
                             hash container",
                            core[0].text
                        ),
                    ));
                }
            }
        }
        i += 1;
    }

    // Apply waivers; malformed waivers surface as W001.
    let (waivers, w001) = waiver::parse(&lexed.comments);
    let mut out: Vec<Finding> = Vec::new();
    for (rule, line, message) in raw {
        let w = waivers.iter().find(|wv| {
            wv.rule == rule
                && (wv.line == line || wv.line + 1 == line)
        });
        out.push(Finding {
            rule: rule.to_string(),
            severity: rules::severity_of(rule),
            file: relpath.to_string(),
            line,
            message,
            waived: w.is_some(),
            waiver_reason: w.map(|wv| wv.reason.clone()),
        });
    }
    for (line, message) in w001 {
        out.push(Finding {
            rule: "W001".to_string(),
            severity: rules::severity_of("W001"),
            file: relpath.to_string(),
            line,
            message,
            waived: false,
            waiver_reason: None,
        });
    }
    out.sort_by(|a, b| {
        (a.line, &a.rule, &a.message)
            .cmp(&(b.line, &b.rule, &b.message))
    });
    out
}

/// F001: from the call `recv.m(` at token index `i` (of the `.`),
/// scan the rest of the expression for a chained `.sum`/`.fold`.
/// Depth-counts brackets so closure bodies inside the chain don't
/// terminate the scan; stops at the statement boundary (`;` or a
/// block opening at depth zero, or an enclosing closer).
fn scan_float_reduction(
    toks: &[Tok<'_>],
    i: usize,
    recv: &str,
    m: &str,
    relpath: &str,
    raw: &mut Vec<(&'static str, u32, String)>,
) {
    let n = toks.len();
    let mut j = i + 2; // the call's own `(` — counted below
    let mut d = 0i32;
    while j < n {
        let t = &toks[j];
        if punct(t, "(") || punct(t, "[") {
            d += 1;
        } else if punct(t, ")") || punct(t, "]") || punct(t, "}") {
            d -= 1;
            if d < 0 {
                break;
            }
        } else if punct(t, "{") {
            if d == 0 {
                break;
            }
            d += 1;
        } else if punct(t, ";") && d == 0 {
            break;
        } else if punct(t, ".")
            && d == 0
            && j + 1 < n
            && (toks[j + 1].is_ident("sum")
                || toks[j + 1].is_ident("fold"))
        {
            if is_det_module(relpath) {
                raw.push((
                    "F001",
                    toks[j + 1].line,
                    format!(
                        "float reduction `.{}(` over unordered \
                         `{recv}.{m}()`",
                        toks[j + 1].text
                    ),
                ));
            }
            break;
        }
        j += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ids(rel: &str, src: &str) -> Vec<(String, bool)> {
        scan_file(rel, src)
            .into_iter()
            .map(|f| (f.rule, f.waived))
            .collect()
    }

    #[test]
    fn d001_flags_hash_iteration_in_det_modules_only() {
        let src = "fn f() { let m: HashMap<u32, f64> = \
                   HashMap::new(); for v in m.values() { use_(v); } \
                   }";
        assert_eq!(
            ids("eval/x.rs", src),
            vec![("D001".to_string(), false)]
        );
        assert!(ids("util/x.rs", src).is_empty());
    }

    #[test]
    fn d001_keyed_lookup_is_clean() {
        let src = "fn f(m: &HashMap<u32, f64>) -> Option<&f64> { \
                   m.get(&3) }";
        assert!(ids("eval/x.rs", src).is_empty());
    }

    #[test]
    fn d001_for_loop_over_hash_set() {
        let src = "fn f() { let s: HashSet<u32> = HashSet::new(); \
                   for k in &s { use_(k); } }";
        assert_eq!(
            ids("dse/x.rs", src),
            vec![("D001".to_string(), false)]
        );
    }

    #[test]
    fn d002_instant_now_flagged_outside_bench() {
        let src = "fn f() { let t = Instant::now(); }";
        assert_eq!(
            ids("runtime/x.rs", src),
            vec![("D002".to_string(), false)]
        );
        assert!(ids("util/bench.rs", src).is_empty());
    }

    #[test]
    fn d003_everywhere_even_in_tests() {
        let src = "#[cfg(test)] mod tests { #[test] fn t() { let r \
                   = thread_rng(); } }";
        assert_eq!(
            ids("util/x.rs", src),
            vec![("D003".to_string(), false)]
        );
    }

    #[test]
    fn d004_rng_draw_in_tell_body() {
        let src = "impl DseSession for S { fn ask(&mut self) -> \
                   u32 { self.rng.next_u32() } fn tell(&mut self, \
                   o: f64) { let x = self.rng.choose(&P); } }";
        assert_eq!(
            ids("dse/x.rs", src),
            vec![("D004".to_string(), false)]
        );
    }

    #[test]
    fn d004_ignores_plain_impls() {
        let src = "impl S { fn tell(&mut self, o: f64) { let x = \
                   self.rng.choose(&P); } }";
        assert!(ids("dse/x.rs", src).is_empty());
    }

    #[test]
    fn p001_unwrap_in_library_flagged_main_exempt() {
        let src = "fn f(x: Option<u32>) -> u32 { x.unwrap() }";
        assert_eq!(
            ids("util/x.rs", src),
            vec![("P001".to_string(), false)]
        );
        assert!(ids("main.rs", src).is_empty());
        assert!(ids("dse/golden.rs", src).is_empty());
    }

    #[test]
    fn p001_cfg_test_region_exempt() {
        let src = "#[cfg(test)] mod tests { fn h(x: Option<u32>) \
                   -> u32 { x.unwrap() } }";
        assert!(ids("util/x.rs", src).is_empty());
    }

    #[test]
    fn cfg_not_test_region_is_not_exempt() {
        let src = "#[cfg(not(test))] mod real { fn h(x: \
                   Option<u32>) -> u32 { x.unwrap() } }";
        assert_eq!(
            ids("util/x.rs", src),
            vec![("P001".to_string(), false)]
        );
    }

    #[test]
    fn f001_sum_over_hash_values() {
        let src = "fn f() { let m: HashMap<u32, f64> = \
                   HashMap::new(); let s: f64 = \
                   m.values().sum::<f64>(); }";
        let got = ids("eval/x.rs", src);
        assert!(got.contains(&("F001".to_string(), false)));
        assert!(got.contains(&("D001".to_string(), false)));
    }

    #[test]
    fn waiver_on_line_above_applies() {
        let src = "fn f(x: Option<u32>) -> u32 {\n    // lumina: \
                   allow(P001) init-checked upstream\n    \
                   x.unwrap()\n}";
        assert_eq!(
            ids("util/x.rs", src),
            vec![("P001".to_string(), true)]
        );
        let f = &scan_file("util/x.rs", src)[0];
        assert_eq!(
            f.waiver_reason.as_deref(),
            Some("init-checked upstream")
        );
    }

    #[test]
    fn trailing_waiver_on_same_line_applies() {
        let src = "fn f(x: Option<u32>) -> u32 {\n    x.unwrap() \
                   // lumina: allow(P001) checked above\n}";
        assert_eq!(
            ids("util/x.rs", src),
            vec![("P001".to_string(), true)]
        );
    }

    #[test]
    fn reasonless_waiver_leaves_finding_and_adds_w001() {
        let src = "fn f(x: Option<u32>) -> u32 {\n    // lumina: \
                   allow(P001)\n    x.unwrap()\n}";
        let got = ids("util/x.rs", src);
        assert!(got.contains(&("P001".to_string(), false)));
        assert!(got.contains(&("W001".to_string(), false)));
    }

    #[test]
    fn strings_and_comments_do_not_trip_rules() {
        let src = "fn f() -> &'static str { /* x.unwrap() */ \
                   \"thread_rng Instant::now\" }";
        assert!(ids("util/x.rs", src).is_empty());
    }
}
