//! Operator-table construction for a tensor-parallel GPT-3 layer.
//!
//! Exact mirror of `python/compile/workload.py` (f64 math, f32 storage —
//! same rounding as numpy's `astype(float32)`).

use crate::arch::constants as c;

pub const MAX_OPS: usize = 16;
pub const N_PHASES: usize = 2;

/// Model + deployment hyper-parameters (paper §5.3 setup).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkloadSpec {
    pub d_model: u64,
    pub n_heads: u64,
    pub d_head: u64,
    pub d_ffn: u64,
    pub tp: u64,
    pub batch: u64,
    pub prefill_seq: u64,
    pub decode_pos: u64,
}

pub const GPT3_175B: WorkloadSpec = WorkloadSpec {
    d_model: 12288,
    n_heads: 96,
    d_head: 128,
    d_ffn: 49152,
    tp: 8,
    batch: 8,
    prefill_seq: 2048,
    decode_pos: 1024,
};

pub const GPT3_TINY: WorkloadSpec = WorkloadSpec {
    d_model: 1024,
    n_heads: 16,
    d_head: 64,
    d_ffn: 4096,
    tp: 8,
    batch: 8,
    prefill_seq: 256,
    decode_pos: 128,
};

/// Resolve a workload by its artifact name (`meta.json` `workload` key).
pub fn spec_by_name(name: &str) -> Option<WorkloadSpec> {
    match name {
        "gpt3-175b" => Some(GPT3_175B),
        "gpt3-tiny" => Some(GPT3_TINY),
        _ => None,
    }
}

impl WorkloadSpec {
    pub fn heads_local(&self) -> u64 {
        self.n_heads / self.tp
    }
    pub fn ffn_local(&self) -> u64 {
        self.d_ffn / self.tp
    }
    pub fn kv_len(&self) -> u64 {
        self.prefill_seq + self.decode_pos
    }
}

/// Operator kind — matches the f32 sentinels in the shared table layout.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpKind {
    Matmul,
    Vector,
    Comm,
}

impl OpKind {
    pub fn code(self) -> f32 {
        match self {
            OpKind::Matmul => 0.0,
            OpKind::Vector => 1.0,
            OpKind::Comm => 2.0,
        }
    }
}

/// One operator of the evaluation trace.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Op {
    pub kind: OpKind,
    /// Human name for critical-path reports and benchmark prompts.
    pub name: &'static str,
    pub m: f64,
    pub n: f64,
    pub k: f64,
    pub count: f64,
    pub flops: f64,
    pub bytes: f64,
    pub comm_bytes: f64,
}

fn matmul(name: &'static str, m: u64, n: u64, k: u64, count: u64) -> Op {
    let (mf, nf, kf, cf) = (m as f64, n as f64, k as f64, count as f64);
    Op {
        kind: OpKind::Matmul,
        name,
        m: mf,
        n: nf,
        k: kf,
        count: cf,
        flops: 2.0 * mf * nf * kf * cf,
        bytes: (mf * kf + kf * nf + mf * nf) * cf * c::FP16_BYTES as f64,
        comm_bytes: 0.0,
    }
}

fn vector(name: &'static str, elems: u64, flops_per_elem: f64) -> Op {
    let e = elems as f64;
    Op {
        kind: OpKind::Vector,
        name,
        m: 0.0,
        n: 0.0,
        k: 0.0,
        count: 1.0,
        flops: flops_per_elem * e,
        bytes: 2.0 * e * c::FP16_BYTES as f64,
        comm_bytes: 0.0,
    }
}

fn allreduce(name: &'static str, raw_bytes: f64, tp: u64) -> Op {
    let ring = 2.0 * (tp as f64 - 1.0) / tp as f64;
    Op {
        kind: OpKind::Comm,
        name,
        m: 0.0,
        n: 0.0,
        k: 0.0,
        count: 1.0,
        flops: 0.0,
        bytes: 2.0 * raw_bytes,
        comm_bytes: ring * raw_bytes,
    }
}

/// Operators of one prefill layer (TTFT phase).
pub fn prefill_ops(w: &WorkloadSpec) -> Vec<Op> {
    let t = w.batch * w.prefill_seq;
    let s = w.prefill_seq;
    let (hl, d, dh) = (w.heads_local(), w.d_model, w.d_head);
    let ar = (t * d) as f64 * c::FP16_BYTES as f64;
    vec![
        vector("layernorm_1", t * d, 8.0),
        matmul("qkv_proj", t, 3 * d / w.tp, d, 1),
        matmul("attn_scores", s, s, dh, w.batch * hl),
        vector("softmax", w.batch * hl * s * s, 5.0),
        matmul("attn_value", s, dh, s, w.batch * hl),
        matmul("out_proj", t, d, d / w.tp, 1),
        allreduce("allreduce_attn", ar, w.tp),
        vector("layernorm_2", t * d, 8.0),
        matmul("mlp_up", t, w.ffn_local(), d, 1),
        vector("gelu", t * w.ffn_local(), 8.0),
        matmul("mlp_down", t, d, w.ffn_local(), 1),
        allreduce("allreduce_mlp", ar, w.tp),
    ]
}

/// Operators of one decode layer at output token `decode_pos`.
pub fn decode_ops(w: &WorkloadSpec) -> Vec<Op> {
    let b = w.batch;
    let sk = w.kv_len();
    let (hl, d, dh) = (w.heads_local(), w.d_model, w.d_head);
    let ar = (b * d) as f64 * c::FP16_BYTES as f64;
    vec![
        vector("layernorm_1", b * d, 8.0),
        matmul("qkv_proj", b, 3 * d / w.tp, d, 1),
        matmul("attn_scores", 1, sk, dh, b * hl),
        vector("softmax", b * hl * sk, 5.0),
        matmul("attn_value", 1, dh, sk, b * hl),
        matmul("out_proj", b, d, d / w.tp, 1),
        allreduce("allreduce_attn", ar, w.tp),
        vector("layernorm_2", b * d, 8.0),
        matmul("mlp_up", b, w.ffn_local(), d, 1),
        vector("gelu", b * w.ffn_local(), 8.0),
        matmul("mlp_down", b, d, w.ffn_local(), 1),
        allreduce("allreduce_mlp", ar, w.tp),
    ]
}

/// Padded `[N_PHASES][MAX_OPS][8]` f32 table — byte-compatible with the
/// Python `workload.op_table` layout (kind sentinel -1 marks padding).
pub fn op_table(w: &WorkloadSpec) -> [[[f32; 8]; MAX_OPS]; N_PHASES] {
    let mut tbl = [[[0.0f32; 8]; MAX_OPS]; N_PHASES];
    for phase in &mut tbl {
        for row in phase.iter_mut() {
            row[0] = -1.0;
        }
    }
    for (p, ops) in [prefill_ops(w), decode_ops(w)].iter().enumerate() {
        assert!(ops.len() <= MAX_OPS, "operator table overflow");
        for (i, op) in ops.iter().enumerate() {
            tbl[p][i] = [
                op.kind.code(),
                op.m as f32,
                op.n as f32,
                op.k as f32,
                op.count as f32,
                op.flops as f32,
                op.bytes as f32,
                op.comm_bytes as f32,
            ];
        }
    }
    tbl
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefill_flops_match_analytic() {
        let w = GPT3_175B;
        let total: f64 = prefill_ops(&w)
            .iter()
            .filter(|o| o.kind == OpKind::Matmul)
            .map(|o| o.flops)
            .sum();
        let t = (w.batch * w.prefill_seq) as f64;
        let d = w.d_model as f64;
        let proj =
            2.0 * t * (4.0 * d * d + 2.0 * d * w.d_ffn as f64) / w.tp as f64;
        let attn = 2.0
            * 2.0
            * (w.batch * w.heads_local()) as f64
            * (w.prefill_seq * w.prefill_seq) as f64
            * w.d_head as f64;
        let err = (total - (proj + attn)).abs() / (proj + attn);
        assert!(err < 1e-12, "err={err}");
    }

    #[test]
    fn decode_is_much_cheaper_than_prefill() {
        let w = GPT3_175B;
        let pf: f64 = prefill_ops(&w).iter().map(|o| o.flops).sum();
        let dc: f64 = decode_ops(&w).iter().map(|o| o.flops).sum();
        assert!(dc < pf / 500.0);
    }

    #[test]
    fn table_padding_and_layout() {
        let tbl = op_table(&GPT3_175B);
        let n_pf = prefill_ops(&GPT3_175B).len();
        for p in 0..N_PHASES {
            for (i, row) in tbl[p].iter().enumerate() {
                let live = if p == 0 {
                    i < n_pf
                } else {
                    i < decode_ops(&GPT3_175B).len()
                };
                if live {
                    assert!(row[0] >= 0.0);
                } else {
                    assert_eq!(row[0], -1.0);
                    assert!(row[1..].iter().all(|&v| v == 0.0));
                }
            }
        }
    }

    #[test]
    fn allreduce_ring_factor() {
        let w = GPT3_175B;
        let ops = prefill_ops(&w);
        let ar: Vec<&Op> =
            ops.iter().filter(|o| o.kind == OpKind::Comm).collect();
        assert_eq!(ar.len(), 2);
        let raw =
            (w.batch * w.prefill_seq * w.d_model) as f64 * 2.0;
        let want = raw * 2.0 * 7.0 / 8.0;
        assert!((ar[0].comm_bytes - want).abs() < 1.0);
    }

    #[test]
    fn kv_length_tracks_decode_pos() {
        let mut w = GPT3_175B;
        let b0 = decode_ops(&w)[2].bytes;
        w.decode_pos *= 2;
        let b1 = decode_ops(&w)[2].bytes;
        assert!(b1 > b0);
    }

    #[test]
    fn op_names_are_unique_within_phase() {
        for ops in [prefill_ops(&GPT3_175B), decode_ops(&GPT3_175B)] {
            let mut names: Vec<&str> =
                ops.iter().map(|o| o.name).collect();
            names.sort();
            names.dedup();
            assert_eq!(names.len(), ops.len());
        }
    }
}
