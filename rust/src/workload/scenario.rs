//! Named workload scenarios: the registry behind `--workload <name>` /
//! `--suite`, the artifact `meta.json` `workload` key, and the
//! [`crate::eval::SuiteEvaluator`] composite objective.
//!
//! Each scenario pins a full [`WorkloadSpec`] plus a suite weight and a
//! human note on the bottleneck regime it is expected to exercise —
//! prefill and decode flip between compute-, bandwidth- and
//! latency-bound across the set, which is what makes multi-scenario DSE
//! meaningfully different from the single hardwired GPT-3 run.
//!
//! MIRROR of `python/compile/workload.py::SCENARIOS` — same names,
//! same resolved specs. Pair `scenario-registry` in
//! `lumina lint --mirror` proves the registries equal statically.

use super::spec::{WorkloadSpec, GPT3_175B, GPT3_TINY};

/// A named, documented workload configuration.
#[derive(Debug, Clone, Copy)]
pub struct Scenario {
    /// Registry key (CLI `--workload` value, artifact `workload` field).
    pub name: &'static str,
    /// One-line description for listings.
    pub summary: &'static str,
    /// Expected dominant bottleneck regime (prefill / decode).
    pub regime: &'static str,
    /// Relative weight in the suite composite objective; 0 excludes the
    /// scenario from `--suite` runs (it stays addressable by name).
    pub weight: f64,
    pub spec: WorkloadSpec,
}

/// Llama-70B-class dense GQA model, the shared base of the deployment
/// scenarios below.
const LLAMA_70B: WorkloadSpec = WorkloadSpec {
    d_model: 8192,
    n_heads: 64,
    n_kv_heads: 8,
    d_head: 128,
    d_ffn: 28672,
    n_layers: 80,
    tp: 8,
    batch: 8,
    prefill_seq: 2048,
    decode_pos: 1024,
};

/// Registry order is stable: index 0 is the default scenario.
pub const SCENARIOS: [Scenario; 7] = [
    Scenario {
        name: "gpt3-175b",
        summary: "GPT-3 175B, TP=8, batch 8 (paper §5.3 setup)",
        regime: "prefill compute-bound / decode bandwidth-bound",
        weight: 1.0,
        spec: GPT3_175B,
    },
    Scenario {
        name: "gpt3-tiny",
        summary: "scaled-down GPT-3 for fast tests and examples",
        regime: "overhead/latency-dominated at this scale",
        weight: 0.0,
        spec: GPT3_TINY,
    },
    Scenario {
        name: "llama-7b",
        summary: "Llama-7B-class dense MHA model, TP=2, batch 8",
        regime: "prefill compute-bound / decode bandwidth-bound",
        weight: 1.0,
        spec: WorkloadSpec {
            d_model: 4096,
            n_heads: 32,
            n_kv_heads: 32,
            d_head: 128,
            d_ffn: 11008,
            n_layers: 32,
            tp: 2,
            batch: 8,
            prefill_seq: 2048,
            decode_pos: 1024,
        },
    },
    Scenario {
        name: "llama-70b",
        summary: "Llama-70B-class dense GQA model (8 KV heads), TP=8",
        regime: "prefill compute-bound / decode bandwidth-bound (GQA)",
        weight: 1.0,
        spec: LLAMA_70B,
    },
    Scenario {
        name: "long-context",
        summary: "70B-class single-request 16k-token prefill",
        regime: "prefill attention-compute-bound, O(s^2) softmax",
        weight: 1.0,
        spec: WorkloadSpec {
            batch: 1,
            prefill_seq: 16384,
            decode_pos: 512,
            ..LLAMA_70B
        },
    },
    Scenario {
        name: "latency-decode",
        summary: "70B-class interactive chat: batch 1, deep decode",
        regime: "decode latency-bound (allreduce + KV stream)",
        weight: 1.0,
        spec: WorkloadSpec {
            batch: 1,
            prefill_seq: 128,
            decode_pos: 3968,
            ..LLAMA_70B
        },
    },
    Scenario {
        name: "serving",
        summary: "70B-class throughput serving: batch 64",
        regime: "decode bandwidth/throughput-bound",
        weight: 1.0,
        spec: WorkloadSpec {
            batch: 64,
            prefill_seq: 512,
            decode_pos: 1536,
            ..LLAMA_70B
        },
    },
];

/// Name of the default scenario (registry index 0).
pub const DEFAULT_SCENARIO: &str = SCENARIOS[0].name;

/// Every registered scenario, in stable registry order.
pub fn all_scenarios() -> &'static [Scenario] {
    &SCENARIOS
}

/// The default scenario (the paper's GPT-3 175B setup).
pub fn default_scenario() -> &'static Scenario {
    &SCENARIOS[0]
}

/// Resolve a scenario by its registry name.
pub fn scenario_by_name(name: &str) -> Option<&'static Scenario> {
    SCENARIOS.iter().find(|s| s.name == name)
}

/// Resolve a workload spec by its scenario name (`meta.json` `workload`
/// key, CLI `--workload` value).
pub fn spec_by_name(name: &str) -> Option<WorkloadSpec> {
    scenario_by_name(name).map(|s| s.spec)
}

/// Scenarios participating in `--suite` runs (positive weight).
pub fn suite_scenarios() -> Vec<&'static Scenario> {
    SCENARIOS.iter().filter(|s| s.weight > 0.0).collect()
}

/// Render the scenario matrix for the CLI `workloads` listing and docs.
pub fn scenario_matrix() -> String {
    let mut out = format!(
        "{:<15} {:>7} {:>5}/{:<3} {:>6} {:>6} {:>3} {:>3} {:>7} \
         {:>7} {:>3}  {}\n",
        "name", "d_model", "heads", "kv", "d_ffn", "layers", "tp",
        "b", "prefill", "decode", "w", "expected regime"
    );
    for s in &SCENARIOS {
        let w = &s.spec;
        out.push_str(&format!(
            "{:<15} {:>7} {:>5}/{:<3} {:>6} {:>6} {:>3} {:>3} {:>7} \
             {:>7} {:>3}  {}\n",
            s.name,
            w.d_model,
            w.n_heads,
            w.n_kv_heads,
            w.d_ffn,
            w.n_layers,
            w.tp,
            w.batch,
            w.prefill_seq,
            w.decode_pos,
            s.weight,
            s.regime,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{op_table, prefill_ops, MAX_OPS};

    #[test]
    fn registry_names_are_unique_and_resolvable() {
        let mut names: Vec<&str> =
            SCENARIOS.iter().map(|s| s.name).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), SCENARIOS.len());
        for s in all_scenarios() {
            assert_eq!(spec_by_name(s.name), Some(s.spec));
        }
        assert!(spec_by_name("bogus").is_none());
        assert_eq!(default_scenario().name, DEFAULT_SCENARIO);
        assert_eq!(spec_by_name(DEFAULT_SCENARIO), Some(GPT3_175B));
    }

    #[test]
    fn every_scenario_is_consistent_and_fits_the_table() {
        for s in all_scenarios() {
            assert!(s.spec.is_consistent(), "{} inconsistent", s.name);
            assert!(prefill_ops(&s.spec).len() <= MAX_OPS);
            let tbl = op_table(&s.spec);
            for phase in &tbl {
                for row in phase {
                    assert!(
                        row.iter().all(|v| v.is_finite()),
                        "{}: non-finite table entry",
                        s.name
                    );
                }
            }
        }
    }

    #[test]
    fn fingerprints_are_pairwise_distinct() {
        for a in all_scenarios() {
            for b in all_scenarios() {
                if a.name != b.name {
                    assert_ne!(
                        a.spec.fingerprint(),
                        b.spec.fingerprint(),
                        "{} vs {}",
                        a.name,
                        b.name
                    );
                }
            }
        }
    }

    #[test]
    fn suite_excludes_zero_weight_scenarios() {
        let suite = suite_scenarios();
        assert!(suite.len() >= 5);
        assert!(suite.iter().all(|s| s.weight > 0.0));
        assert!(!suite.iter().any(|s| s.name == "gpt3-tiny"));
    }

    #[test]
    fn matrix_lists_every_scenario() {
        let m = scenario_matrix();
        for s in all_scenarios() {
            assert!(m.contains(s.name), "{} missing from matrix", s.name);
        }
    }
}
