//! Operator-table construction for one tensor-parallel transformer layer.
//!
//! Exact mirror of `python/compile/workload.py` (f64 math, f32 storage —
//! same rounding as numpy's `astype(float32)`). The spec supports both
//! classic multi-head attention and grouped-query attention (GQA): when
//! `n_kv_heads == n_heads` every formula reduces bit-for-bit to the
//! historical MHA construction, so the pinned GPT-3 oracle values are
//! unchanged.

use crate::arch::constants as c;

pub const MAX_OPS: usize = 16;
pub const N_PHASES: usize = 2;

/// Model + deployment hyper-parameters (paper §5.3 setup).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct WorkloadSpec {
    pub d_model: u64,
    pub n_heads: u64,
    /// KV heads (GQA); equal to `n_heads` for classic MHA.
    pub n_kv_heads: u64,
    pub d_head: u64,
    pub d_ffn: u64,
    /// Decoder layers of the full model. Evaluation stays per-layer (the
    /// artifact contract); reports multiply by this for full-model times.
    pub n_layers: u64,
    pub tp: u64,
    pub batch: u64,
    pub prefill_seq: u64,
    pub decode_pos: u64,
}

pub const GPT3_175B: WorkloadSpec = WorkloadSpec {
    d_model: 12288,
    n_heads: 96,
    n_kv_heads: 96,
    d_head: 128,
    d_ffn: 49152,
    n_layers: 96,
    tp: 8,
    batch: 8,
    prefill_seq: 2048,
    decode_pos: 1024,
};

pub const GPT3_TINY: WorkloadSpec = WorkloadSpec {
    d_model: 1024,
    n_heads: 16,
    n_kv_heads: 16,
    d_head: 64,
    d_ffn: 4096,
    n_layers: 4,
    tp: 8,
    batch: 8,
    prefill_seq: 256,
    decode_pos: 128,
};

impl WorkloadSpec {
    pub fn heads_local(&self) -> u64 {
        self.n_heads / self.tp
    }
    pub fn kv_heads_local(&self) -> u64 {
        self.n_kv_heads / self.tp
    }
    /// Query heads sharing one KV head (1 for MHA).
    pub fn group(&self) -> u64 {
        self.heads_local() / self.kv_heads_local()
    }
    pub fn ffn_local(&self) -> u64 {
        self.d_ffn / self.tp
    }
    pub fn kv_len(&self) -> u64 {
        self.prefill_seq + self.decode_pos
    }
    /// Per-partition QKV projection output width: Q plus the (possibly
    /// grouped) K and V. Equals `3 * d_model / tp` for MHA.
    pub fn qkv_cols(&self) -> u64 {
        (self.d_model + 2 * self.n_kv_heads * self.d_head) / self.tp
    }

    /// Structural invariants the op builders rely on (divisibility of
    /// heads/FFN across the TP group, grouped heads, non-zero phases,
    /// and Q width consistency: the qkv projection produces
    /// `d_model / tp` Q columns that attention consumes as
    /// `heads_local * d_head` — the two must agree).
    pub fn is_consistent(&self) -> bool {
        self.tp > 0
            && self.batch > 0
            && self.prefill_seq > 0
            && self.decode_pos > 0
            && self.d_model == self.n_heads * self.d_head
            && self.n_heads % self.tp == 0
            && self.n_kv_heads % self.tp == 0
            && self.kv_heads_local() > 0
            && self.heads_local() % self.kv_heads_local() == 0
            && self.d_ffn % self.tp == 0
            && self.d_model % self.tp == 0
            && (self.d_model + 2 * self.n_kv_heads * self.d_head)
                % self.tp
                == 0
            && self.n_layers > 0
    }

    /// Stable 64-bit identity of the workload, used as the cache-key
    /// component that distinguishes the same design evaluated under
    /// different workloads (FNV-1a over the field values).
    pub fn fingerprint(&self) -> u64 {
        let mut h: u64 = 0xcbf29ce484222325;
        for v in [
            self.d_model,
            self.n_heads,
            self.n_kv_heads,
            self.d_head,
            self.d_ffn,
            self.n_layers,
            self.tp,
            self.batch,
            self.prefill_seq,
            self.decode_pos,
        ] {
            h ^= v;
            h = h.wrapping_mul(0x100000001b3);
        }
        h
    }
}

/// Operator kind — matches the f32 sentinels in the shared table layout.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpKind {
    Matmul,
    Vector,
    Comm,
}

impl OpKind {
    pub fn code(self) -> f32 {
        match self {
            OpKind::Matmul => 0.0,
            OpKind::Vector => 1.0,
            OpKind::Comm => 2.0,
        }
    }
}

/// One operator of the evaluation trace.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Op {
    pub kind: OpKind,
    /// Human name for critical-path reports and benchmark prompts.
    pub name: &'static str,
    pub m: f64,
    pub n: f64,
    pub k: f64,
    pub count: f64,
    pub flops: f64,
    pub bytes: f64,
    pub comm_bytes: f64,
}

fn matmul(name: &'static str, m: u64, n: u64, k: u64, count: u64) -> Op {
    let (mf, nf, kf, cf) = (m as f64, n as f64, k as f64, count as f64);
    Op {
        kind: OpKind::Matmul,
        name,
        m: mf,
        n: nf,
        k: kf,
        count: cf,
        flops: 2.0 * mf * nf * kf * cf,
        bytes: (mf * kf + kf * nf + mf * nf) * cf * c::FP16_BYTES as f64,
        comm_bytes: 0.0,
    }
}

fn vector(name: &'static str, elems: u64, flops_per_elem: f64) -> Op {
    let e = elems as f64;
    Op {
        kind: OpKind::Vector,
        name,
        m: 0.0,
        n: 0.0,
        k: 0.0,
        count: 1.0,
        flops: flops_per_elem * e,
        bytes: 2.0 * e * c::FP16_BYTES as f64,
        comm_bytes: 0.0,
    }
}

fn allreduce(name: &'static str, raw_bytes: f64, tp: u64) -> Op {
    let ring = 2.0 * (tp as f64 - 1.0) / tp as f64;
    Op {
        kind: OpKind::Comm,
        name,
        m: 0.0,
        n: 0.0,
        k: 0.0,
        count: 1.0,
        flops: 0.0,
        bytes: 2.0 * raw_bytes,
        comm_bytes: ring * raw_bytes,
    }
}

/// Operators of one prefill layer (TTFT phase).
///
/// Attention is folded per KV head: each KV head's K/V tiles serve
/// `group` query heads, so the score/value matmuls carry `m = group * s`
/// with `count = batch * kv_heads_local` — identical FLOPs to the
/// per-query-head form, with K/V operand bytes counted once per KV head
/// (for MHA, `group == 1` and the construction is bit-identical to the
/// historical one).
pub fn prefill_ops(w: &WorkloadSpec) -> Vec<Op> {
    let t = w.batch * w.prefill_seq;
    let s = w.prefill_seq;
    let (kvl, g, d, dh) =
        (w.kv_heads_local(), w.group(), w.d_model, w.d_head);
    let ar = (t * d) as f64 * c::FP16_BYTES as f64;
    vec![
        vector("layernorm_1", t * d, 8.0),
        matmul("qkv_proj", t, w.qkv_cols(), d, 1),
        matmul("attn_scores", g * s, s, dh, w.batch * kvl),
        vector("softmax", w.batch * w.heads_local() * s * s, 5.0),
        matmul("attn_value", g * s, dh, s, w.batch * kvl),
        matmul("out_proj", t, d, d / w.tp, 1),
        allreduce("allreduce_attn", ar, w.tp),
        vector("layernorm_2", t * d, 8.0),
        matmul("mlp_up", t, w.ffn_local(), d, 1),
        vector("gelu", t * w.ffn_local(), 8.0),
        matmul("mlp_down", t, d, w.ffn_local(), 1),
        allreduce("allreduce_mlp", ar, w.tp),
    ]
}

/// Operators of one decode layer at output token `decode_pos` (same
/// KV-head folding as [`prefill_ops`]: `m = group` rows per KV head).
pub fn decode_ops(w: &WorkloadSpec) -> Vec<Op> {
    let b = w.batch;
    let sk = w.kv_len();
    let (kvl, g, d, dh) =
        (w.kv_heads_local(), w.group(), w.d_model, w.d_head);
    let ar = (b * d) as f64 * c::FP16_BYTES as f64;
    vec![
        vector("layernorm_1", b * d, 8.0),
        matmul("qkv_proj", b, w.qkv_cols(), d, 1),
        matmul("attn_scores", g, sk, dh, b * kvl),
        vector("softmax", b * w.heads_local() * sk, 5.0),
        matmul("attn_value", g, dh, sk, b * kvl),
        matmul("out_proj", b, d, d / w.tp, 1),
        allreduce("allreduce_attn", ar, w.tp),
        vector("layernorm_2", b * d, 8.0),
        matmul("mlp_up", b, w.ffn_local(), d, 1),
        vector("gelu", b * w.ffn_local(), 8.0),
        matmul("mlp_down", b, d, w.ffn_local(), 1),
        allreduce("allreduce_mlp", ar, w.tp),
    ]
}

/// Padded `[N_PHASES][MAX_OPS][8]` f32 table — byte-compatible with the
/// Python `workload.op_table` layout (kind sentinel -1 marks padding).
pub fn op_table(w: &WorkloadSpec) -> [[[f32; 8]; MAX_OPS]; N_PHASES] {
    let mut tbl = [[[0.0f32; 8]; MAX_OPS]; N_PHASES];
    for phase in &mut tbl {
        for row in phase.iter_mut() {
            row[0] = -1.0;
        }
    }
    for (p, ops) in [prefill_ops(w), decode_ops(w)].iter().enumerate() {
        assert!(ops.len() <= MAX_OPS, "operator table overflow");
        for (i, op) in ops.iter().enumerate() {
            tbl[p][i] = [
                op.kind.code(),
                op.m as f32,
                op.n as f32,
                op.k as f32,
                op.count as f32,
                op.flops as f32,
                op.bytes as f32,
                op.comm_bytes as f32,
            ];
        }
    }
    tbl
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::scenario::spec_by_name;

    #[test]
    fn prefill_flops_match_analytic() {
        let w = GPT3_175B;
        let total: f64 = prefill_ops(&w)
            .iter()
            .filter(|o| o.kind == OpKind::Matmul)
            .map(|o| o.flops)
            .sum();
        let t = (w.batch * w.prefill_seq) as f64;
        let d = w.d_model as f64;
        let proj =
            2.0 * t * (4.0 * d * d + 2.0 * d * w.d_ffn as f64) / w.tp as f64;
        let attn = 2.0
            * 2.0
            * (w.batch * w.heads_local()) as f64
            * (w.prefill_seq * w.prefill_seq) as f64
            * w.d_head as f64;
        let err = (total - (proj + attn)).abs() / (proj + attn);
        assert!(err < 1e-12, "err={err}");
    }

    #[test]
    fn decode_is_much_cheaper_than_prefill() {
        let w = GPT3_175B;
        let pf: f64 = prefill_ops(&w).iter().map(|o| o.flops).sum();
        let dc: f64 = decode_ops(&w).iter().map(|o| o.flops).sum();
        assert!(dc < pf / 500.0);
    }

    #[test]
    fn table_padding_and_layout() {
        let tbl = op_table(&GPT3_175B);
        let n_pf = prefill_ops(&GPT3_175B).len();
        for p in 0..N_PHASES {
            for (i, row) in tbl[p].iter().enumerate() {
                let live = if p == 0 {
                    i < n_pf
                } else {
                    i < decode_ops(&GPT3_175B).len()
                };
                if live {
                    assert!(row[0] >= 0.0);
                } else {
                    assert_eq!(row[0], -1.0);
                    assert!(row[1..].iter().all(|&v| v == 0.0));
                }
            }
        }
    }

    #[test]
    fn allreduce_ring_factor() {
        let w = GPT3_175B;
        let ops = prefill_ops(&w);
        let ar: Vec<&Op> =
            ops.iter().filter(|o| o.kind == OpKind::Comm).collect();
        assert_eq!(ar.len(), 2);
        let raw =
            (w.batch * w.prefill_seq * w.d_model) as f64 * 2.0;
        let want = raw * 2.0 * 7.0 / 8.0;
        assert!((ar[0].comm_bytes - want).abs() < 1.0);
    }

    #[test]
    fn kv_length_tracks_decode_pos() {
        let mut w = GPT3_175B;
        let b0 = decode_ops(&w)[2].bytes;
        w.decode_pos *= 2;
        let b1 = decode_ops(&w)[2].bytes;
        assert!(b1 > b0);
    }

    #[test]
    fn op_names_are_unique_within_phase() {
        for ops in [prefill_ops(&GPT3_175B), decode_ops(&GPT3_175B)] {
            let mut names: Vec<&str> =
                ops.iter().map(|o| o.name).collect();
            names.sort();
            names.dedup();
            assert_eq!(names.len(), ops.len());
        }
    }

    #[test]
    fn mha_gqa_formulas_reduce_to_historical_shapes() {
        // For n_kv_heads == n_heads the folded attention must reproduce
        // the pre-GQA shapes exactly.
        let w = GPT3_175B;
        assert_eq!(w.group(), 1);
        assert_eq!(w.qkv_cols(), 3 * w.d_model / w.tp);
        let pf = prefill_ops(&w);
        assert_eq!(pf[2].m, w.prefill_seq as f64);
        assert_eq!(pf[2].count, (w.batch * w.heads_local()) as f64);
        let dc = decode_ops(&w);
        assert_eq!(dc[2].m, 1.0);
        assert_eq!(dc[4].n, w.d_head as f64);
    }

    #[test]
    fn gqa_preserves_flops_and_cuts_kv_bytes() {
        // Grouping KV heads must not change attention FLOPs, but must
        // shrink the decode KV-cache operand traffic.
        let gqa = spec_by_name("llama-70b").unwrap();
        let mha = WorkloadSpec { n_kv_heads: gqa.n_heads, ..gqa };
        assert!(gqa.n_kv_heads < gqa.n_heads);
        let flops = |w: &WorkloadSpec| -> f64 {
            decode_ops(w)
                .iter()
                .filter(|o| o.name.starts_with("attn"))
                .map(|o| o.flops)
                .sum()
        };
        let bytes = |w: &WorkloadSpec| -> f64 {
            decode_ops(w)
                .iter()
                .filter(|o| o.name.starts_with("attn"))
                .map(|o| o.bytes)
                .sum()
        };
        let df = (flops(&mha) - flops(&gqa)).abs() / flops(&mha);
        assert!(df < 1e-12, "GQA changed attention FLOPs: {df}");
        assert!(bytes(&gqa) < bytes(&mha) * 0.5);
        // QKV projection shrinks too (smaller K/V output).
        assert!(gqa.qkv_cols() < mha.qkv_cols());
    }

    #[test]
    fn fingerprint_distinguishes_specs() {
        let a = GPT3_175B.fingerprint();
        assert_eq!(a, GPT3_175B.fingerprint());
        assert_ne!(a, GPT3_TINY.fingerprint());
        let mut w = GPT3_175B;
        w.batch *= 2;
        assert_ne!(a, w.fingerprint());
    }
}
