//! GPT-3 inference workload (runtime copy).
//!
//! Mirrors `python/compile/workload.py`: the same per-layer operator
//! tables for prefill/decode, used by the Rust roofline mirror, the
//! detailed compass simulator, and the benchmark question generators.
//! The artifact bakes the Python copy in as constants; the cross-check
//! test compares both.

pub mod gpt3;

pub use gpt3::{
    decode_ops, op_table, prefill_ops, Op, OpKind, WorkloadSpec, GPT3_175B,
    GPT3_TINY, MAX_OPS, N_PHASES,
};
