//! Inference workloads (runtime copy).
//!
//! Mirrors `python/compile/workload.py`: the same per-layer operator
//! tables for prefill/decode, used by the Rust roofline mirror, the
//! detailed compass simulator, and the benchmark question generators.
//! The artifact bakes the Python copy in as constants; the cross-check
//! test compares both.
//!
//! [`spec`] holds the parameterized [`WorkloadSpec`] and the op-table
//! builders; [`scenario`] is the registry of named scenarios
//! (`gpt3-175b`, `llama-70b`, `long-context`, ...) behind the CLI
//! `--workload` / `--suite` flags and the suite evaluator.

pub mod scenario;
pub mod spec;

pub use scenario::{
    all_scenarios, default_scenario, scenario_by_name, scenario_matrix,
    spec_by_name, suite_scenarios, Scenario, DEFAULT_SCENARIO, SCENARIOS,
};
pub use spec::{
    decode_ops, op_table, prefill_ops, Op, OpKind, WorkloadSpec, GPT3_175B,
    GPT3_TINY, MAX_OPS, N_PHASES,
};
