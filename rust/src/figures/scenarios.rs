//! Per-scenario Pareto-front comparison (the suite figure): run the
//! same DSE pipeline on every suite scenario and extract each
//! scenario's normalized front, so the figure shows how the trade-off
//! surface — and the designs that populate it — shift as the bottleneck
//! regime flips from compute-bound prefill to bandwidth- and
//! latency-bound decode. `benches/fig7_scenario_fronts.rs` writes the
//! CSV this module computes.

use crate::baselines::DseMethod;
use crate::design::{DesignPoint, DesignSpace};
use crate::eval::BudgetedEvaluator;
use crate::lumina::{Lumina, LuminaConfig};
use crate::pareto::{
    phv_ref, ObjectiveMode, Objectives, ParetoArchive, PHV_REF,
};
use crate::workload::Scenario;
use crate::Result;

use super::race::EvaluatorKind;

/// The normalized Pareto front one scenario's exploration produced.
#[derive(Debug, Clone)]
pub struct ScenarioFront {
    pub name: &'static str,
    /// A100 objectives under this scenario (the normalization base).
    pub reference: Objectives,
    /// Non-dominated samples as (design, objectives normalized by the
    /// scenario reference), in discovery order.
    pub front: Vec<(DesignPoint, Objectives)>,
    /// Normalized energy/token of each front point (the 4th PPA lane),
    /// aligned with `front`.
    pub front_energy: Vec<f64>,
    /// PHV of the normalized trajectory w.r.t. [`PHV_REF`] (or its 4-D
    /// analogue in ppa mode).
    pub phv: f64,
    /// Samples spent (equals the budget unless evaluation failed early).
    pub samples: usize,
}

/// Run LUMINA under `budget` samples on each scenario and collect the
/// per-scenario normalized fronts (latency-area mode).
pub fn scenario_fronts(
    scenarios: &[&Scenario],
    kind: EvaluatorKind,
    budget: usize,
    seed: u64,
) -> Result<Vec<ScenarioFront>> {
    scenario_fronts_mode(
        scenarios,
        kind,
        budget,
        seed,
        ObjectiveMode::LatencyArea,
    )
}

/// [`scenario_fronts`] under an objective mode: `ppa` runs the
/// power-aware LUMINA configuration and selects/scores the front in
/// 4-D (TTFT, TPOT, area, energy/token); `front` still reports the 3-D
/// projection for plot compatibility, with the energy lane alongside
/// in `front_energy`.
pub fn scenario_fronts_mode(
    scenarios: &[&Scenario],
    kind: EvaluatorKind,
    budget: usize,
    seed: u64,
    mode: ObjectiveMode,
) -> Result<Vec<ScenarioFront>> {
    let space = DesignSpace::table1();
    let mut out = Vec::with_capacity(scenarios.len());
    for s in scenarios {
        let mut ev = kind.make_for(&s.spec);
        let reference_m = ev.eval(&DesignPoint::a100())?;
        let reference = reference_m.objectives();
        let mut be = BudgetedEvaluator::new(ev.as_mut(), budget);
        Lumina::new(LuminaConfig {
            seed,
            objectives: mode,
            ..Default::default()
        })
        .run(&space, &mut be)?;
        let traj: Vec<(DesignPoint, Objectives)> = be
            .log
            .iter()
            .map(|(d, m)| {
                let o = m.objectives();
                (
                    *d,
                    [
                        o[0] / reference[0],
                        o[1] / reference[1],
                        o[2] / reference[2],
                    ],
                )
            })
            .collect();
        // A zero reference energy (pre-PPA PJRT artifact) normalizes
        // to the neutral 1.0 rather than NaN (shared policy, see
        // arch::power::norm_or_neutral), keeping the CSV and the 4-D
        // front selection well-defined.
        let ref_energy = reference_m.energy_per_token_mj;
        let energies: Vec<f64> = be
            .log
            .iter()
            .map(|(_, m)| {
                crate::arch::power::norm_or_neutral(
                    m.energy_per_token_mj,
                    ref_energy,
                ) as f64
            })
            .collect();
        let (front_ids, phv) = match mode {
            ObjectiveMode::LatencyArea => {
                let mut archive = ParetoArchive::new(PHV_REF);
                for (_, o) in &traj {
                    archive.push(*o);
                }
                (archive.front_ids(), archive.hypervolume())
            }
            ObjectiveMode::Ppa => {
                let mut archive: ParetoArchive<4> =
                    ParetoArchive::new(phv_ref::<4>());
                for ((_, o), e) in traj.iter().zip(&energies) {
                    archive.push([o[0], o[1], o[2], *e]);
                }
                (archive.front_ids(), archive.hypervolume())
            }
        };
        out.push(ScenarioFront {
            name: s.name,
            reference,
            front: front_ids.iter().map(|&i| traj[i]).collect(),
            front_energy: front_ids
                .iter()
                .map(|&i| energies[i])
                .collect(),
            phv,
            samples: traj.len(),
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pareto::dominates;
    use crate::workload::suite_scenarios;

    #[test]
    fn fronts_are_nondominated_and_scenario_specific() {
        let scenarios = suite_scenarios();
        let fronts = scenario_fronts(
            &scenarios[..3],
            EvaluatorKind::RooflineRust,
            30,
            5,
        )
        .unwrap();
        assert_eq!(fronts.len(), 3);
        for f in &fronts {
            assert_eq!(f.samples, 30);
            assert!(!f.front.is_empty(), "{} empty front", f.name);
            for (i, (_, a)) in f.front.iter().enumerate() {
                for (j, (_, b)) in f.front.iter().enumerate() {
                    assert!(
                        i == j || !dominates(b, a),
                        "{}: dominated point on front",
                        f.name
                    );
                }
            }
        }
        // References differ across scenarios (different regimes).
        assert!(
            (fronts[0].reference[0] - fronts[1].reference[0]).abs()
                / fronts[0].reference[0]
                > 0.01
        );
    }

    #[test]
    fn ppa_fronts_carry_the_energy_lane_and_4d_nondominance() {
        let scenarios = suite_scenarios();
        let fronts = scenario_fronts_mode(
            &scenarios[..2],
            EvaluatorKind::RooflineRust,
            25,
            7,
            ObjectiveMode::Ppa,
        )
        .unwrap();
        for f in &fronts {
            assert_eq!(f.front.len(), f.front_energy.len());
            assert!(f.front_energy.iter().all(|&e| e > 0.0));
            // 4-D non-dominance of the reported front.
            for i in 0..f.front.len() {
                for j in 0..f.front.len() {
                    if i == j {
                        continue;
                    }
                    let a = [
                        f.front[i].1[0],
                        f.front[i].1[1],
                        f.front[i].1[2],
                        f.front_energy[i],
                    ];
                    let b = [
                        f.front[j].1[0],
                        f.front[j].1[1],
                        f.front[j].1[2],
                        f.front_energy[j],
                    ];
                    assert!(
                        !dominates(&b, &a),
                        "{}: 4-D dominated point on ppa front",
                        f.name
                    );
                }
            }
        }
    }
}
