//! Per-scenario Pareto-front comparison (the suite figure): run the
//! same DSE pipeline on every suite scenario and extract each
//! scenario's normalized front, so the figure shows how the trade-off
//! surface — and the designs that populate it — shift as the bottleneck
//! regime flips from compute-bound prefill to bandwidth- and
//! latency-bound decode. `benches/fig7_scenario_fronts.rs` writes the
//! CSV this module computes.

use crate::baselines::DseMethod;
use crate::design::{DesignPoint, DesignSpace};
use crate::eval::BudgetedEvaluator;
use crate::lumina::Lumina;
use crate::pareto::{Objectives, ParetoArchive, PHV_REF};
use crate::workload::Scenario;
use crate::Result;

use super::race::EvaluatorKind;

/// The normalized Pareto front one scenario's exploration produced.
#[derive(Debug, Clone)]
pub struct ScenarioFront {
    pub name: &'static str,
    /// A100 objectives under this scenario (the normalization base).
    pub reference: Objectives,
    /// Non-dominated samples as (design, objectives normalized by the
    /// scenario reference), in discovery order.
    pub front: Vec<(DesignPoint, Objectives)>,
    /// PHV of the normalized trajectory w.r.t. [`PHV_REF`].
    pub phv: f64,
    /// Samples spent (equals the budget unless evaluation failed early).
    pub samples: usize,
}

/// Run LUMINA under `budget` samples on each scenario and collect the
/// per-scenario normalized fronts.
pub fn scenario_fronts(
    scenarios: &[&Scenario],
    kind: EvaluatorKind,
    budget: usize,
    seed: u64,
) -> Result<Vec<ScenarioFront>> {
    let space = DesignSpace::table1();
    let mut out = Vec::with_capacity(scenarios.len());
    for s in scenarios {
        let mut ev = kind.make_for(&s.spec);
        let reference = ev.eval(&DesignPoint::a100())?.objectives();
        let mut be = BudgetedEvaluator::new(ev.as_mut(), budget);
        Lumina::with_seed(seed).run(&space, &mut be)?;
        let traj: Vec<(DesignPoint, Objectives)> = be
            .log
            .iter()
            .map(|(d, m)| {
                let o = m.objectives();
                (
                    *d,
                    [
                        o[0] / reference[0],
                        o[1] / reference[1],
                        o[2] / reference[2],
                    ],
                )
            })
            .collect();
        let mut archive = ParetoArchive::new(PHV_REF);
        for (_, o) in &traj {
            archive.push(*o);
        }
        out.push(ScenarioFront {
            name: s.name,
            reference,
            front: archive
                .front_ids()
                .into_iter()
                .map(|i| traj[i])
                .collect(),
            phv: archive.hypervolume(),
            samples: traj.len(),
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pareto::dominates;
    use crate::workload::suite_scenarios;

    #[test]
    fn fronts_are_nondominated_and_scenario_specific() {
        let scenarios = suite_scenarios();
        let fronts = scenario_fronts(
            &scenarios[..3],
            EvaluatorKind::RooflineRust,
            30,
            5,
        )
        .unwrap();
        assert_eq!(fronts.len(), 3);
        for f in &fronts {
            assert_eq!(f.samples, 30);
            assert!(!f.front.is_empty(), "{} empty front", f.name);
            for (i, (_, a)) in f.front.iter().enumerate() {
                for (j, (_, b)) in f.front.iter().enumerate() {
                    assert!(
                        i == j || !dominates(b, a),
                        "{}: dominated point on front",
                        f.name
                    );
                }
            }
        }
        // References differ across scenarios (different regimes).
        assert!(
            (fronts[0].reference[0] - fronts[1].reference[0]).abs()
                / fronts[0].reference[0]
                > 0.01
        );
    }
}
