//! PCA embeddings of design space and search trajectories (Figures 1 and
//! 6): fit a PCA on a uniform background sample of the space, then
//! project arbitrary designs / trajectories into the same 2-D plane.

use crate::design::{sample, DesignPoint, DesignSpace};
use crate::eval::Evaluator;
use crate::stats::{Pca, Pcg32};
use crate::Result;

/// A fitted 2-D design-space embedding with evaluated background points.
pub struct SpaceEmbedding {
    pub pca: Pca,
    /// (x, y, ttft, tpot, area) per background sample.
    pub background: Vec<[f64; 5]>,
}

impl SpaceEmbedding {
    /// Sample `n` designs uniformly, evaluate them, fit the PCA.
    pub fn fit(
        space: &DesignSpace,
        eval: &mut dyn Evaluator,
        n: usize,
        seed: u64,
    ) -> Result<SpaceEmbedding> {
        let mut rng = Pcg32::with_stream(seed, 0xf1);
        let designs = sample::uniform_batch(space, &mut rng, n);
        let rows: Vec<Vec<f64>> =
            designs.iter().map(|d| d.as_f64()).collect();
        let pca = Pca::fit(&rows, 2);

        let metrics = eval.eval_batch(&designs)?;
        let background = designs
            .iter()
            .zip(&metrics)
            .map(|(d, m)| {
                let p = pca.transform(&d.as_f64());
                [
                    p[0],
                    p[1],
                    m.ttft_ms as f64,
                    m.tpot_ms as f64,
                    m.area_mm2 as f64,
                ]
            })
            .collect();
        Ok(SpaceEmbedding { pca, background })
    }

    /// Project one design into the embedding plane.
    pub fn project(&self, d: &DesignPoint) -> [f64; 2] {
        let p = self.pca.transform(&d.as_f64());
        [p[0], p[1]]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::RooflineSim;
    use crate::workload::GPT3_175B;

    #[test]
    fn embedding_covers_space_and_projects() {
        let space = DesignSpace::table1();
        let mut sim = RooflineSim::new(GPT3_175B);
        let emb =
            SpaceEmbedding::fit(&space, &mut sim, 300, 1).unwrap();
        assert_eq!(emb.background.len(), 300);
        assert!(emb.pca.explained_ratio() > 0.2);
        let p = emb.project(&DesignPoint::a100());
        assert!(p.iter().all(|v| v.is_finite()));
        // Distinct designs land on distinct points (non-degenerate).
        let q = emb.project(&DesignPoint::new([6, 1, 1, 4, 4, 32, 32, 1]));
        assert!((p[0] - q[0]).abs() + (p[1] - q[1]).abs() > 1e-6);
    }
}
