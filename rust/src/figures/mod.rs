//! Experiment drivers: one module per paper table/figure (see the
//! experiment index in DESIGN.md). The `rust/benches/*` targets and the
//! CLI both drive these.

pub mod embedding;
pub mod race;
pub mod scenarios;
pub mod table4;

pub use race::{
    run_race, run_race_fused, EvaluatorKind, RaceConfig, RaceResult,
};
pub use scenarios::{scenario_fronts, ScenarioFront};
