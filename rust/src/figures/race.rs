//! The DSE race harness behind Figures 4, 5 and 6 and the §5.3 budget-20
//! study: run every method under identical budget accounting, over
//! multiple independent trials, and report PHV / sample efficiency /
//! superior-design counts plus the raw trajectories.

use crate::baselines::{all_methods_mode, all_sessions_mode, DseMethod};
use crate::design::{DesignPoint, DesignSpace};
use crate::dse::{FusedRace, NullObserver, Observer};
use std::sync::Arc;

use crate::eval::{
    BudgetedEvaluator, CachedEvaluator, DiskBackedCache, DiskStore,
    Evaluator, Metrics, ParallelEvaluator, SuiteBackend,
};
use crate::pareto::{
    normalize, phv_ref, sample_efficiency, superior_count,
    ObjectiveMode, Objectives, ParetoArchive, PHV_REF,
};
use crate::runtime::PjrtEvaluator;
use crate::sim::{CompassSim, RooflineSim};
use crate::workload::{default_scenario, spec_by_name, WorkloadSpec};
use crate::Result;

/// Which simulation environment the race runs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvaluatorKind {
    /// The AOT roofline artifact through PJRT (production path); falls
    /// back to the Rust mirror when artifacts are missing.
    RooflinePjrt,
    /// The Rust mirror (bit-compatible with the artifact).
    RooflineRust,
    /// The detailed critical-path simulator.
    Compass,
}

impl EvaluatorKind {
    /// Build the evaluation pipeline every DSE method drives. The pure
    /// analytical simulators are wrapped in [`ParallelEvaluator`],
    /// which shards SoA chunks across the persistent
    /// [`crate::eval::WorkerPool`] with results bit-identical to the
    /// sequential path; PJRT does its own artifact-level batching. All
    /// pipelines built here draw from the one process-wide pool, so a
    /// race's (method x trial) cells can never oversubscribe the host.
    ///
    /// Deliberately *not* memoized: the races compare methods under
    /// identical per-sample accounting, and a cache shared across
    /// (method, trial) cells would hand later methods free revisits of
    /// earlier methods' points. Single-method exploration (the CLI
    /// `explore` command) uses [`Self::make_cached_for`] instead.
    ///
    /// `make()` uses the default registry scenario; [`Self::make_for`]
    /// builds the same pipeline for an explicit workload.
    pub fn make(self) -> Box<dyn Evaluator> {
        self.make_for(&default_scenario().spec)
    }

    /// Build the memoized exploration stack for a workload:
    /// `ParallelEvaluator<CachedEvaluator<Sim>>` — the parallel layer
    /// probes the concurrent sharded memo store up front, serves hits
    /// on the caller thread without touching the worker pool, and
    /// evaluates only unique misses in parallel through the SoA chunk
    /// kernels. Counters and results are bit-identical to the
    /// sequential caching path, so
    /// [`crate::eval::BudgetedEvaluator`]'s hits-ride-free accounting
    /// is unchanged. The PJRT artifact (which batches internally and
    /// is not a pure per-design function) keeps the historical
    /// cache-outside composition.
    pub fn make_cached_for(
        self,
        spec: &WorkloadSpec,
    ) -> Box<dyn Evaluator> {
        match self {
            EvaluatorKind::RooflinePjrt => {
                match open_matching_pjrt(spec) {
                    Some(e) => Box::new(CachedEvaluator::new(e)),
                    None => Box::new(ParallelEvaluator::new(
                        CachedEvaluator::new(RooflineSim::new(*spec)),
                    )),
                }
            }
            EvaluatorKind::RooflineRust => {
                Box::new(ParallelEvaluator::new(CachedEvaluator::new(
                    RooflineSim::new(*spec),
                )))
            }
            EvaluatorKind::Compass => {
                Box::new(ParallelEvaluator::new(CachedEvaluator::new(
                    CompassSim::new(*spec),
                )))
            }
        }
    }

    /// [`Self::make_cached_for`] with the memo store spilled to disk:
    /// `ParallelEvaluator<DiskBackedCache<Sim>>`. The in-memory
    /// [`crate::eval::SharedCache`] stays the hot tier (probed on the
    /// caller thread, hits never touch the worker pool); the
    /// [`DiskStore`] underneath serves warm restarts and is shared —
    /// via its `Arc` — by every process pointing `--cache-dir` at the
    /// same directory. Results and budget accounting are bit-identical
    /// to the purely in-memory stack: the disk tier only changes
    /// *where* a memoized metric is found, never its value.
    pub fn make_cached_disk_for(
        self,
        spec: &WorkloadSpec,
        disk: Arc<DiskStore>,
    ) -> Box<dyn Evaluator> {
        match self {
            EvaluatorKind::RooflinePjrt => {
                match open_matching_pjrt(spec) {
                    Some(e) => {
                        Box::new(DiskBackedCache::new(e, disk))
                    }
                    None => Box::new(ParallelEvaluator::new(
                        DiskBackedCache::new(
                            RooflineSim::new(*spec),
                            disk,
                        ),
                    )),
                }
            }
            EvaluatorKind::RooflineRust => {
                Box::new(ParallelEvaluator::new(DiskBackedCache::new(
                    RooflineSim::new(*spec),
                    disk,
                )))
            }
            EvaluatorKind::Compass => {
                Box::new(ParallelEvaluator::new(DiskBackedCache::new(
                    CompassSim::new(*spec),
                    disk,
                )))
            }
        }
    }

    /// Build one [`crate::eval::SuiteEvaluator`] member backend for a
    /// suite scenario. The pure analytical simulators come back as
    /// [`SuiteBackend::Fused`] — thread-safe per-design functions the
    /// suite folds into its single fused cross-scenario pool dispatch
    /// and probes through the per-member memo tiers. A PJRT artifact
    /// matching the scenario stays [`SuiteBackend::Sequential`]: it
    /// batches internally, is not a pure per-design function, and so
    /// can neither fuse nor be tier-served.
    pub fn make_suite_backend(self, spec: &WorkloadSpec) -> SuiteBackend {
        match self {
            EvaluatorKind::RooflinePjrt => {
                match open_matching_pjrt(spec) {
                    Some(e) => SuiteBackend::Sequential(Box::new(e)),
                    None => SuiteBackend::Fused(Box::new(
                        RooflineSim::new(*spec),
                    )),
                }
            }
            EvaluatorKind::RooflineRust => {
                SuiteBackend::Fused(Box::new(RooflineSim::new(*spec)))
            }
            EvaluatorKind::Compass => {
                SuiteBackend::Fused(Box::new(CompassSim::new(*spec)))
            }
        }
    }

    /// Build the evaluation pipeline for a specific workload. The PJRT
    /// artifact is lowered for exactly one workload; when the requested
    /// spec differs from the artifact's, the race falls back to the
    /// bit-compatible Rust mirror rather than silently evaluating the
    /// wrong workload. The match is probed from `meta.json` *before*
    /// constructing the PJRT client, so non-matching scenarios (e.g.
    /// 6 of 7 suite members) never pay client/table setup.
    pub fn make_for(self, spec: &WorkloadSpec) -> Box<dyn Evaluator> {
        match self {
            EvaluatorKind::RooflinePjrt => {
                match open_matching_pjrt(spec) {
                    Some(e) => Box::new(e),
                    None => Box::new(ParallelEvaluator::new(
                        RooflineSim::new(*spec),
                    )),
                }
            }
            EvaluatorKind::RooflineRust => Box::new(
                ParallelEvaluator::new(RooflineSim::new(*spec)),
            ),
            EvaluatorKind::Compass => Box::new(ParallelEvaluator::new(
                CompassSim::new(*spec),
            )),
        }
    }
}

/// The single artifact-match policy shared by [`EvaluatorKind::make_for`]
/// and [`EvaluatorKind::make_cached_for`]: open the PJRT evaluator only
/// when the default artifact was lowered for exactly `spec` (probed from
/// `meta.json` *before* constructing the PJRT client, so non-matching
/// scenarios never pay client/table setup).
fn open_matching_pjrt(spec: &WorkloadSpec) -> Option<PjrtEvaluator> {
    let artifact_matches = crate::runtime::ArtifactDir::open_default()
        .map(|a| spec_by_name(&a.workload) == Some(*spec))
        .unwrap_or(false);
    if artifact_matches {
        PjrtEvaluator::open_default().ok()
    } else {
        None
    }
}

/// Race configuration.
#[derive(Debug, Clone)]
pub struct RaceConfig {
    pub samples: usize,
    pub trials: usize,
    pub seed: u64,
    pub evaluator: EvaluatorKind,
    /// Workload scenario every method is raced on.
    pub workload: WorkloadSpec,
    /// Objective vector the race scores (3-D latency-area by default,
    /// 4-D PPA with `--objectives ppa`).
    pub objectives: ObjectiveMode,
}

impl Default for RaceConfig {
    fn default() -> Self {
        Self {
            samples: 1000,
            trials: 5,
            seed: 2026,
            evaluator: EvaluatorKind::RooflinePjrt,
            workload: default_scenario().spec,
            objectives: ObjectiveMode::LatencyArea,
        }
    }
}

/// One (method, trial) outcome.
#[derive(Debug, Clone)]
pub struct RaceResult {
    pub method: &'static str,
    pub trial: usize,
    /// PHV of the normalized trajectory w.r.t. [2,2,2].
    pub phv: f64,
    /// Fraction of samples strictly better than the A100 reference.
    pub sample_efficiency: f64,
    /// Count of superior designs.
    pub superior: usize,
    /// Evaluated designs in order (for the Fig. 6 search patterns).
    pub trajectory: Vec<(DesignPoint, Objectives)>,
}

/// The A100 reference metrics under the chosen evaluator + workload
/// (carries every objective lane; mode-specific vectors derive from
/// it).
pub fn reference_metrics(
    kind: EvaluatorKind,
    workload: &WorkloadSpec,
) -> Result<Metrics> {
    let mut ev = kind.make_for(workload);
    ev.eval(&DesignPoint::a100())
}

/// The A100 reference objectives (3-D) under the chosen evaluator +
/// workload.
pub fn reference_objectives(
    kind: EvaluatorKind,
    workload: &WorkloadSpec,
) -> Result<Objectives> {
    Ok(reference_metrics(kind, workload)?.objectives())
}

/// Per-trial session seed. Every race driver — serial, fused, and the
/// sharded workers/merge in [`crate::dse::shard`] — derives cell seeds
/// through this one formula, so a shard worker on another process
/// constructs sessions bit-identical to the in-process race.
pub fn trial_seed(seed: u64, trial: usize) -> u64 {
    seed.wrapping_mul(0x9e3779b97f4a7c15)
        .wrapping_add(trial as u64)
}

/// Run the full race: every method in the paper's comparison x trials.
///
/// One evaluator instance is shared across all (method, trial) cells so
/// the PJRT executables compile exactly once per race (§Perf iteration
/// 2: 210s -> ~50s for the 1,000 x 5 race); per-cell isolation lives in
/// the `BudgetedEvaluator` wrapper, and every evaluator here is a pure
/// function of the design.
pub fn run_race(cfg: &RaceConfig) -> Result<Vec<RaceResult>> {
    let space = DesignSpace::table1();
    let reference = reference_metrics(cfg.evaluator, &cfg.workload)?;
    let mut ev = cfg.evaluator.make_for(&cfg.workload);
    let mut out = Vec::new();
    for trial in 0..cfg.trials {
        let seed = trial_seed(cfg.seed, trial);
        for mut method in all_methods_mode(seed, cfg.objectives) {
            let mut be =
                BudgetedEvaluator::new(ev.as_mut(), cfg.samples);
            method.run(&space, &mut be)?;
            out.push(score_log(
                method.name(),
                trial,
                &be.log,
                &reference,
                cfg.objectives,
            ));
        }
    }
    Ok(out)
}

/// [`run_race`] with the ask/tell cells fused: every driver round
/// gathers `ask()` proposals from all live (method x trial) cells into
/// **one** `eval_batch` against the shared pipeline (see
/// [`crate::dse::FusedRace`]), then scatters the `tell()`s. Per-cell
/// budget ledgers carry the exact accounting of the serial race, and
/// the evaluators on this path are pure functions of the design, so
/// per-cell trajectories — and the PHV / sample-efficiency scores — are
/// bit-identical to [`run_race`].
pub fn run_race_fused(cfg: &RaceConfig) -> Result<Vec<RaceResult>> {
    run_race_fused_observed(cfg, &mut NullObserver)
}

/// [`run_race_fused`] with observer hooks (live per-cell PHV progress
/// for `race --fused --verbose`).
pub fn run_race_fused_observed(
    cfg: &RaceConfig,
    observer: &mut dyn Observer,
) -> Result<Vec<RaceResult>> {
    let space = DesignSpace::table1();
    let reference = reference_metrics(cfg.evaluator, &cfg.workload)?;
    let mut ev = cfg.evaluator.make_for(&cfg.workload);
    let mut race = FusedRace::new(&space);
    for trial in 0..cfg.trials {
        let seed = trial_seed(cfg.seed, trial);
        for (name, session) in
            all_sessions_mode(seed, cfg.objectives)
        {
            race.add_cell(name, trial, session, cfg.samples);
        }
    }
    let cells =
        race.run(ev.as_mut(), &reference, cfg.objectives, observer)?;
    Ok(cells
        .into_iter()
        .map(|c| {
            score_log(
                c.method,
                c.trial,
                &c.log,
                &reference,
                cfg.objectives,
            )
        })
        .collect())
}

/// Score one trajectory into a RaceResult. PHV comes from one pass over
/// an incremental [`ParetoArchive`] rather than a from-scratch
/// hypervolume of the whole trajectory.
pub fn score_trajectory(
    method: &'static str,
    trial: usize,
    trajectory: &[(DesignPoint, Objectives)],
    reference: &Objectives,
) -> RaceResult {
    let objs: Vec<Objectives> =
        trajectory.iter().map(|(_, o)| *o).collect();
    let (phv, sample_efficiency, superior) =
        score_vectors(&objs, reference);
    RaceResult {
        method,
        trial,
        phv,
        sample_efficiency,
        superior,
        trajectory: trajectory.to_vec(),
    }
}

/// Score a raw `(design, metrics)` log under an objective mode. The
/// latency-area arm reproduces [`score_trajectory`] exactly; the ppa
/// arm scores the 4-D (TTFT, TPOT, area, energy/token) vectors against
/// `phv_ref::<4>()`. `RaceResult::trajectory` stays 3-D in both modes
/// (the Fig. 6 search-pattern consumers are latency-area plots).
pub fn score_log(
    method: &'static str,
    trial: usize,
    log: &[(DesignPoint, Metrics)],
    reference: &Metrics,
    mode: ObjectiveMode,
) -> RaceResult {
    let trajectory: Vec<(DesignPoint, Objectives)> =
        log.iter().map(|(d, m)| (*d, m.objectives())).collect();
    match mode {
        ObjectiveMode::LatencyArea => score_trajectory(
            method,
            trial,
            &trajectory,
            &reference.objectives(),
        ),
        ObjectiveMode::Ppa => {
            // Degenerate zero-energy reference (pre-PPA artifact
            // data): the energy lane carries no information, so ppa
            // scoring degrades to the latency-area scores entirely —
            // a neutral constant lane would instead zero
            // sample-efficiency/superior under their strict-< rule.
            if reference.energy_per_token_mj <= 0.0 {
                return score_trajectory(
                    method,
                    trial,
                    &trajectory,
                    &reference.objectives(),
                );
            }
            let objs: Vec<Objectives<4>> =
                log.iter().map(|(_, m)| m.objectives_ppa()).collect();
            let (phv, sample_efficiency, superior) =
                score_vectors(&objs, &reference.objectives_ppa());
            RaceResult {
                method,
                trial,
                phv,
                sample_efficiency,
                superior,
                trajectory,
            }
        }
    }
}

/// Dimension-generic trajectory scoring: normalized incremental PHV
/// against `[2.0; D]`, sample efficiency, superior count.
fn score_vectors<const D: usize>(
    objs: &[Objectives<D>],
    reference: &Objectives<D>,
) -> (f64, f64, usize) {
    let mut archive: ParetoArchive<D> =
        ParetoArchive::new(phv_ref::<D>());
    for o in normalize(objs, reference) {
        archive.push(o);
    }
    (
        archive.hypervolume(),
        sample_efficiency(objs, reference),
        superior_count(objs, reference),
    )
}

/// PHV after every step of a trajectory (the Fig. 4 race curves,
/// written by `benches/fig4_phv_race.rs`), in one incremental pass —
/// computing each prefix from scratch would cost an O(n^2 log n)
/// hypervolume per step.
pub fn phv_curve(
    trajectory: &[(DesignPoint, Objectives)],
    reference: &Objectives,
) -> Vec<f64> {
    let mut archive = ParetoArchive::new(PHV_REF);
    trajectory
        .iter()
        .map(|(_, o)| {
            archive.push([
                o[0] / reference[0],
                o[1] / reference[1],
                o[2] / reference[2],
            ]);
            archive.hypervolume()
        })
        .collect()
}

/// Aggregate per-method summary (Fig. 4's summary points):
/// `(method, mean PHV, mean sample efficiency, std PHV, mean superior
/// count)`, methods in first-appearance order. One grouped pass over
/// the results — the old shape re-filtered the full result vec once
/// per method per metric.
pub fn aggregate(
    results: &[RaceResult],
) -> Vec<(&'static str, f64, f64, f64, f64)> {
    struct Group {
        method: &'static str,
        phvs: Vec<f64>,
        eff_sum: f64,
        superior_sum: usize,
    }
    let mut groups: Vec<Group> = Vec::new();
    for r in results {
        let g = match groups
            .iter_mut()
            .find(|g| g.method == r.method)
        {
            Some(g) => g,
            None => {
                groups.push(Group {
                    method: r.method,
                    phvs: Vec::new(),
                    eff_sum: 0.0,
                    superior_sum: 0,
                });
                // lumina: allow(P001) last_mut on the vec pushed one line up
                groups.last_mut().expect("just pushed")
            }
        };
        g.phvs.push(r.phv);
        g.eff_sum += r.sample_efficiency;
        g.superior_sum += r.superior;
    }
    groups
        .into_iter()
        .map(|g| {
            let n = g.phvs.len() as f64;
            let mean_phv = g.phvs.iter().sum::<f64>() / n;
            let var_phv = g
                .phvs
                .iter()
                .map(|p| (p - mean_phv) * (p - mean_phv))
                .sum::<f64>()
                / n;
            (
                g.method,
                mean_phv,
                g.eff_sum / n,
                var_phv.sqrt(),
                g.superior_sum as f64 / n,
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_race_runs_all_methods() {
        let cfg = RaceConfig {
            samples: 40,
            trials: 2,
            seed: 5,
            evaluator: EvaluatorKind::RooflineRust,
            ..Default::default()
        };
        let results = run_race(&cfg).unwrap();
        assert_eq!(results.len(), 6 * 2);
        for r in &results {
            assert_eq!(r.trajectory.len(), 40, "{}", r.method);
            assert!(r.phv.is_finite() && r.phv >= 0.0);
        }
    }

    #[test]
    fn lumina_wins_phv_and_efficiency_in_small_race() {
        let cfg = RaceConfig {
            samples: 120,
            trials: 2,
            seed: 7,
            evaluator: EvaluatorKind::RooflineRust,
            ..Default::default()
        };
        let agg = aggregate(&run_race(&cfg).unwrap());
        let lumina = agg.iter().find(|(m, ..)| *m == "lumina").unwrap();
        for (m, phv, eff, _, _) in &agg {
            if *m != "lumina" {
                assert!(
                    lumina.1 >= *phv * 0.95,
                    "{m} PHV {phv:.3} vs lumina {:.3}",
                    lumina.1
                );
                assert!(
                    lumina.2 > *eff,
                    "{m} eff {eff:.3} vs lumina {:.3}",
                    lumina.2
                );
            }
        }
    }

    #[test]
    fn aggregate_groups_in_one_pass_with_mean_superior() {
        let traj = vec![(DesignPoint::a100(), [1.0, 1.0, 1.0])];
        let mk = |m: &'static str, t: usize, phv: f64, sup: usize| {
            RaceResult {
                method: m,
                trial: t,
                phv,
                sample_efficiency: 0.5,
                superior: sup,
                trajectory: traj.clone(),
            }
        };
        let agg = aggregate(&[
            mk("a", 0, 1.0, 2),
            mk("b", 0, 5.0, 0),
            mk("a", 1, 3.0, 4),
        ]);
        assert_eq!(agg.len(), 2);
        let (m, phv, eff, std, sup) = agg[0];
        assert_eq!(m, "a");
        assert!((phv - 2.0).abs() < 1e-12);
        assert!((eff - 0.5).abs() < 1e-12);
        assert!((std - 1.0).abs() < 1e-12);
        assert!((sup - 3.0).abs() < 1e-12);
        let (m, phv, _, std, sup) = agg[1];
        assert_eq!(m, "b");
        assert!((phv - 5.0).abs() < 1e-12);
        assert!(std.abs() < 1e-12);
        assert!(sup.abs() < 1e-12);
    }

    #[test]
    fn reference_matches_roofline_a100() {
        let r = reference_objectives(
            EvaluatorKind::RooflineRust,
            &default_scenario().spec,
        )
        .unwrap();
        assert!((r[0] - 36.70556).abs() < 0.01);
    }

    #[test]
    fn race_runs_on_non_default_workload() {
        let cfg = RaceConfig {
            samples: 25,
            trials: 1,
            seed: 21,
            evaluator: EvaluatorKind::RooflineRust,
            workload: spec_by_name("llama-70b").unwrap(),
            objectives: ObjectiveMode::LatencyArea,
        };
        let results = run_race(&cfg).unwrap();
        assert_eq!(results.len(), 6);
        for r in &results {
            assert_eq!(r.trajectory.len(), 25, "{}", r.method);
        }
        // The reference objectives differ from the GPT-3 default ones.
        let gpt3 = reference_objectives(
            EvaluatorKind::RooflineRust,
            &default_scenario().spec,
        )
        .unwrap();
        let llama = reference_objectives(
            EvaluatorKind::RooflineRust,
            &cfg.workload,
        )
        .unwrap();
        assert!((gpt3[0] - llama[0]).abs() / gpt3[0] > 0.05);
    }

    #[test]
    fn ppa_race_scores_a_4d_objective() {
        let base = RaceConfig {
            samples: 40,
            trials: 1,
            seed: 5,
            evaluator: EvaluatorKind::RooflineRust,
            ..Default::default()
        };
        let ppa = RaceConfig {
            objectives: ObjectiveMode::Ppa,
            ..base.clone()
        };
        let r3 = run_race(&base).unwrap();
        let r4 = run_race(&ppa).unwrap();
        assert_eq!(r3.len(), r4.len());
        for (a, b) in r3.iter().zip(&r4) {
            assert_eq!(a.method, b.method);
            assert_eq!(a.trajectory.len(), b.trajectory.len());
            assert!(b.phv.is_finite() && b.phv >= 0.0);
            if a.method != "lumina" {
                // The baselines are objective-agnostic: same designs,
                // only the scoring changes — so the 4-D superior count
                // is at most the 3-D one (one more lane to strictly
                // beat).
                assert_eq!(a.trajectory, b.trajectory, "{}", a.method);
                assert!(b.superior <= a.superior, "{}", a.method);
            }
        }
        // LUMINA is the mode-aware searcher: the ppa race runs its
        // power-aware configuration, so its trajectory diverges.
        let (la, pa) = r3
            .iter()
            .zip(&r4)
            .find(|(a, _)| a.method == "lumina")
            .unwrap();
        assert_ne!(
            la.trajectory, pa.trajectory,
            "ppa race did not engage power-aware LUMINA"
        );
        // The 4-D PHV differs from the 3-D PHV for at least one cell
        // (the energy lane genuinely participates).
        assert!(
            r3.iter()
                .zip(&r4)
                .any(|(a, b)| (a.phv - b.phv).abs() > 1e-9),
            "ppa scoring identical to latency-area"
        );
    }

    #[test]
    fn fused_ppa_race_matches_serial_ppa_race() {
        let cfg = RaceConfig {
            samples: 30,
            trials: 1,
            seed: 9,
            evaluator: EvaluatorKind::RooflineRust,
            objectives: ObjectiveMode::Ppa,
            ..Default::default()
        };
        let serial = run_race(&cfg).unwrap();
        let fused = run_race_fused(&cfg).unwrap();
        for (s, f) in serial.iter().zip(&fused) {
            assert_eq!(s.method, f.method);
            assert_eq!(s.trajectory, f.trajectory);
            assert_eq!(s.phv.to_bits(), f.phv.to_bits());
            assert_eq!(s.superior, f.superior);
        }
    }

    #[test]
    fn phv_curve_is_monotone_and_ends_at_trajectory_phv() {
        let cfg = RaceConfig {
            samples: 60,
            trials: 1,
            seed: 13,
            evaluator: EvaluatorKind::RooflineRust,
            ..Default::default()
        };
        let reference =
            reference_objectives(cfg.evaluator, &cfg.workload).unwrap();
        let results = run_race(&cfg).unwrap();
        for r in &results {
            let curve = phv_curve(&r.trajectory, &reference);
            assert_eq!(curve.len(), r.trajectory.len());
            assert!(
                curve.windows(2).all(|w| w[1] >= w[0] - 1e-12),
                "{}: PHV curve not monotone",
                r.method
            );
            let last = *curve.last().unwrap();
            assert!(
                (last - r.phv).abs() <= 1e-9 * r.phv.max(1.0),
                "{}: curve end {last} != scored {phv}",
                r.method,
                phv = r.phv
            );
        }
    }
}
