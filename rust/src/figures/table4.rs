//! Table 4: the top designs discovered by LUMINA vs the A100 reference —
//! specification rows plus normalized TTFT / TPOT / area and the
//! TTFT/Area, TPOT/Area efficiency ratios.

use crate::design::{DesignPoint, Param};
use crate::eval::{Evaluator, Metrics};
use crate::pareto::{Objectives, ParetoArchive};
use crate::Result;

/// One column of Table 4.
#[derive(Debug, Clone)]
pub struct DesignReportRow {
    pub label: String,
    pub design: DesignPoint,
    pub metrics: Metrics,
    pub norm_ttft: f64,
    pub norm_tpot: f64,
    pub norm_area: f64,
    /// Energy/token relative to the reference (the PPA column).
    pub norm_energy: f64,
    /// Average power relative to the reference.
    pub norm_power: f64,
}

impl DesignReportRow {
    /// TTFT-per-area efficiency relative to the reference (>1 = better).
    pub fn ttft_per_area(&self) -> f64 {
        1.0 / (self.norm_ttft * self.norm_area)
    }

    pub fn tpot_per_area(&self) -> f64 {
        1.0 / (self.norm_tpot * self.norm_area)
    }

    /// Tokens-per-joule efficiency relative to the reference
    /// (>1 = better).
    pub fn tokens_per_joule(&self) -> f64 {
        1.0 / self.norm_energy
    }
}

/// Pick the two paper-style headline designs from a trajectory: the best
/// TTFT/Area trade-off and the best raw-TTFT design among superior
/// points (Design A and Design B analogues).
pub fn pick_top2(
    trajectory: &[(DesignPoint, Objectives)],
    reference: &Objectives,
) -> Vec<DesignPoint> {
    let superior: Vec<&(DesignPoint, Objectives)> = trajectory
        .iter()
        .filter(|(_, o)| (0..3).all(|i| o[i] < reference[i]))
        .collect();
    if superior.is_empty() {
        // Fall back to the Pareto front (incremental archive — ids are
        // trajectory indices).
        let mut archive = ParetoArchive::front_only();
        for (_, o) in trajectory {
            archive.push(*o);
        }
        return archive
            .front_ids()
            .into_iter()
            .take(2)
            .map(|i| trajectory[i].0)
            .collect();
    }
    let eff = |o: &Objectives| {
        (reference[0] / o[0]) / (o[2] / reference[2])
    };
    let design_a = superior
        .iter()
        .max_by(|a, b| eff(&a.1).total_cmp(&eff(&b.1)))
        // lumina: allow(P001) superior is non-empty (early return above)
        .unwrap()
        .0;
    let design_b = superior
        .iter()
        .min_by(|a, b| a.1[0].total_cmp(&b.1[0]))
        // lumina: allow(P001) superior is non-empty (early return above)
        .unwrap()
        .0;
    if design_a == design_b {
        vec![design_a]
    } else {
        vec![design_a, design_b]
    }
}

/// Evaluate and normalize a set of designs against the reference.
pub fn report_rows(
    eval: &mut dyn Evaluator,
    designs: &[(String, DesignPoint)],
) -> Result<Vec<DesignReportRow>> {
    let reference = eval.eval(&DesignPoint::a100())?;
    // A pre-PPA artifact evaluator reports zero energy lanes; normalize
    // to 1.0 (neutral) instead of dividing into NaN (shared policy,
    // see arch::power::norm_or_neutral).
    let norm = |v: f32, r: f32| {
        crate::arch::power::norm_or_neutral(v, r) as f64
    };
    let mut rows = Vec::new();
    for (label, d) in designs {
        let m = eval.eval(d)?;
        rows.push(DesignReportRow {
            label: label.clone(),
            design: *d,
            metrics: m,
            norm_ttft: (m.ttft_ms / reference.ttft_ms) as f64,
            norm_tpot: (m.tpot_ms / reference.tpot_ms) as f64,
            norm_area: (m.area_mm2 / reference.area_mm2) as f64,
            norm_energy: norm(
                m.energy_per_token_mj,
                reference.energy_per_token_mj,
            ),
            norm_power: norm(m.avg_power_w, reference.avg_power_w),
        });
    }
    rows.push(DesignReportRow {
        label: "A100".into(),
        design: DesignPoint::a100(),
        metrics: reference,
        norm_ttft: 1.0,
        norm_tpot: 1.0,
        norm_area: 1.0,
        norm_energy: 1.0,
        norm_power: 1.0,
    });
    Ok(rows)
}

/// Render Table 4 as markdown.
pub fn render(rows: &[DesignReportRow]) -> String {
    let mut out = String::from("| Specifications |");
    for r in rows {
        out.push_str(&format!(" {} |", r.label));
    }
    out.push('\n');
    out.push_str("|---|");
    for _ in rows {
        out.push_str("---|");
    }
    out.push('\n');
    for p in Param::ALL {
        out.push_str(&format!("| {} |", p.label()));
        for r in rows {
            out.push_str(&format!(" {} |", r.design.get(p)));
        }
        out.push('\n');
    }
    let metric_rows: [(&str, fn(&DesignReportRow) -> f64); 7] = [
        ("Normalized TTFT", |r| r.norm_ttft),
        ("Normalized TPOT", |r| r.norm_tpot),
        ("Normalized Area", |r| r.norm_area),
        ("Normalized Energy/token", |r| r.norm_energy),
        ("Normalized Power", |r| r.norm_power),
        ("TTFT/Area", |r| r.ttft_per_area()),
        ("TPOT/Area", |r| r.tpot_per_area()),
    ];
    for (name, f) in metric_rows {
        out.push_str(&format!("| {name} |"));
        for r in rows {
            out.push_str(&format!(" {:.3} |", f(r)));
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::RooflineSim;
    use crate::workload::GPT3_175B;

    #[test]
    fn paper_designs_report_superior_ratios() {
        let mut sim = RooflineSim::new(GPT3_175B);
        let rows = report_rows(
            &mut sim,
            &[
                ("Design A".into(), DesignPoint::paper_design_a()),
                ("Design B".into(), DesignPoint::paper_design_b()),
            ],
        )
        .unwrap();
        let a = &rows[0];
        assert!(a.norm_ttft < 1.0 && a.norm_tpot < 1.0 && a.norm_area < 1.0);
        assert!(a.ttft_per_area() > 1.0);
        // PPA columns: populated and self-consistent.
        assert!(a.norm_energy > 0.0 && a.norm_power > 0.0);
        assert!(
            (a.tokens_per_joule() - 1.0 / a.norm_energy).abs() < 1e-12
        );
        let reference = rows.last().unwrap();
        assert_eq!(reference.norm_energy, 1.0);
        let table = render(&rows);
        assert!(table.contains("Design A") && table.contains("A100"));
        assert!(table.contains("Interconnect Link Count"));
        assert!(table.contains("Normalized Energy/token"));
        assert!(table.contains("Normalized Power"));
    }

    #[test]
    fn pick_top2_prefers_superior_designs() {
        let reference = [10.0, 1.0, 100.0];
        let traj = vec![
            (DesignPoint::a100(), [10.0, 1.0, 100.0]),
            (DesignPoint::paper_design_a(), [7.0, 0.9, 60.0]),
            (DesignPoint::paper_design_b(), [5.0, 0.95, 95.0]),
            (DesignPoint::new([6, 1, 1, 4, 4, 32, 32, 1]),
             [50.0, 5.0, 20.0]),
        ];
        let picks = pick_top2(&traj, &reference);
        assert_eq!(picks.len(), 2);
        assert_eq!(picks[0], DesignPoint::paper_design_a()); // best eff
        assert_eq!(picks[1], DesignPoint::paper_design_b()); // best TTFT
    }

    #[test]
    fn pick_top2_falls_back_to_front() {
        let reference = [1.0, 1.0, 1.0];
        let traj = vec![
            (DesignPoint::a100(), [2.0, 2.0, 2.0]),
            (DesignPoint::paper_design_a(), [3.0, 1.5, 2.0]),
        ];
        let picks = pick_top2(&traj, &reference);
        assert!(!picks.is_empty());
    }
}
