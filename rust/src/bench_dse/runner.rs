//! Benchmark runner + scoring (reproduces paper Table 3).

use crate::llm::parse::parse_answer_letter;
use crate::llm::{prompts, LanguageModel, ModelProfile, SimulatedAnalyst};
use crate::pareto::ObjectiveMode;
use crate::workload::{default_scenario, WorkloadSpec};

use super::generator::{Question, QuestionSet, Task};

/// Accuracy of one (model, task) cell.
#[derive(Debug, Clone, Copy)]
pub struct TaskAccuracy {
    pub task: Task,
    pub original: f64,
    pub enhanced: f64,
    pub n: usize,
}

/// Full benchmark report for a set of models.
#[derive(Debug, Clone)]
pub struct BenchmarkReport {
    /// (model name, per-task accuracies).
    pub rows: Vec<(String, Vec<TaskAccuracy>)>,
}

/// Score one model on one question set under a given system prompt.
pub fn score(
    model: &mut dyn LanguageModel,
    system: &str,
    questions: &[Question],
) -> f64 {
    let mut right = 0usize;
    for q in questions {
        let completion = model.complete(system, &q.prompt);
        if parse_answer_letter(&completion) == Some(q.correct) {
            right += 1;
        }
    }
    right as f64 / questions.len().max(1) as f64
}

/// Run the full benchmark (all three tasks, original + enhanced prompts)
/// for the given model profiles. `scale` in (0, 1] shrinks the question
/// counts proportionally for quick runs.
///
/// Model cells are independent (each builds its own seeded analysts), so
/// profiles are scored on parallel scoped threads; each worker writes
/// only its own row, keeping the report order — and, because the analyst
/// seeds depend only on `seed` — the scores bit-identical to the
/// sequential loop.
pub fn run_benchmark(
    profiles: &[ModelProfile],
    seed: u64,
    scale: f64,
) -> BenchmarkReport {
    run_benchmark_for(profiles, seed, scale, &default_scenario().spec)
}

/// [`run_benchmark`] with the question ground truth simulated under an
/// explicit workload scenario.
pub fn run_benchmark_for(
    profiles: &[ModelProfile],
    seed: u64,
    scale: f64,
    workload: &WorkloadSpec,
) -> BenchmarkReport {
    run_benchmark_mode(
        profiles,
        seed,
        scale,
        workload,
        ObjectiveMode::LatencyArea,
    )
}

/// [`run_benchmark_for`] under an objective mode: `ppa` folds
/// average-power prediction questions into the Perf/Area task (the
/// benchmark then measures the full PPA skill surface), `latency-area`
/// scores the historical sets bit-identically.
pub fn run_benchmark_mode(
    profiles: &[ModelProfile],
    seed: u64,
    scale: f64,
    workload: &WorkloadSpec,
    mode: ObjectiveMode,
) -> BenchmarkReport {
    run_benchmark_disk(profiles, seed, scale, workload, mode, None)
}

/// [`run_benchmark_mode`] with the question ground truth memoized in
/// a shared disk store (`benchmark --cache-dir`): repeat runs serve
/// their simulations from disk and score bit-identical question sets.
pub fn run_benchmark_disk(
    profiles: &[ModelProfile],
    seed: u64,
    scale: f64,
    workload: &WorkloadSpec,
    mode: ObjectiveMode,
    disk: Option<std::sync::Arc<crate::eval::DiskStore>>,
) -> BenchmarkReport {
    let sets: Vec<QuestionSet> = Task::ALL
        .iter()
        .map(|&t| {
            let n = ((t.paper_count() as f64 * scale).round() as usize)
                .max(10);
            QuestionSet::generate_n_disk(
                t,
                n,
                seed,
                workload,
                mode,
                disk.clone(),
            )
        })
        .collect();

    let enhanced_system = prompts::system_enhanced();
    let score_profile = |profile: &ModelProfile| -> Vec<TaskAccuracy> {
        sets.iter()
            .map(|set| {
                let mut m_orig =
                    SimulatedAnalyst::new(*profile, seed ^ 0x0f1);
                let original = score(
                    &mut m_orig,
                    prompts::SYSTEM_DEFAULT,
                    &set.questions,
                );
                let mut m_enh =
                    SimulatedAnalyst::new(*profile, seed ^ 0x0f2);
                let enhanced =
                    score(&mut m_enh, &enhanced_system, &set.questions);
                TaskAccuracy {
                    task: set.task,
                    original,
                    enhanced,
                    n: set.questions.len(),
                }
            })
            .collect()
    };

    let mut rows: Vec<Option<(String, Vec<TaskAccuracy>)>> =
        profiles.iter().map(|_| None).collect();
    std::thread::scope(|s| {
        for (slot, profile) in rows.iter_mut().zip(profiles) {
            let score_profile = &score_profile;
            s.spawn(move || {
                *slot = Some((profile.name.to_string(), score_profile(profile)));
            });
        }
    });
    BenchmarkReport {
        rows: rows
            .into_iter()
            // lumina: allow(P001) the loop above fills a row for every profile
            .map(|r| r.expect("every profile row is scored"))
            .collect(),
    }
}

impl BenchmarkReport {
    /// Render as the paper's Table 3.
    pub fn render_table3(&self) -> String {
        let mut out = String::from(
            "| Benchmark Task       | Model     | Accuracy (Original) | \
             Accuracy (Enhanced) |\n|---|---|---|---|\n",
        );
        for task in Task::ALL {
            for (name, accs) in &self.rows {
                // lumina: allow(P001) every row scores all Task::ALL entries
                let a = accs.iter().find(|a| a.task == task).unwrap();
                out.push_str(&format!(
                    "| {:<20} | {:<9} | {:.2} | {:.2} |\n",
                    task.name(),
                    name,
                    a.original,
                    a.enhanced
                ));
            }
        }
        out
    }

    pub fn get(&self, model: &str, task: Task) -> Option<TaskAccuracy> {
        self.rows
            .iter()
            .find(|(n, _)| n == model)
            .and_then(|(_, accs)| {
                accs.iter().find(|a| a.task == task).copied()
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> BenchmarkReport {
        run_benchmark(
            &[
                ModelProfile::phi4(),
                ModelProfile::qwen3(),
                ModelProfile::llama31(),
            ],
            77,
            0.35,
        )
    }

    #[test]
    fn oracle_model_is_near_perfect_on_bottleneck_and_prediction() {
        let r = run_benchmark(&[ModelProfile::oracle()], 3, 0.3);
        let b = r.get("oracle", Task::BottleneckAnalysis).unwrap();
        let p = r.get("oracle", Task::PerfAreaPrediction).unwrap();
        assert!(b.original > 0.85, "bottleneck oracle {:.2}", b.original);
        assert!(p.original > 0.85, "prediction oracle {:.2}", p.original);
    }

    #[test]
    fn enhanced_prompts_help_every_model_and_task() {
        let r = report();
        for (name, accs) in &r.rows {
            for a in accs {
                assert!(
                    a.enhanced >= a.original - 0.05,
                    "{name} {:?}: {:.2} -> {:.2}",
                    a.task,
                    a.original,
                    a.enhanced
                );
            }
        }
    }

    #[test]
    fn model_ordering_matches_paper() {
        // Qwen-3 strongest, Llama-3.1 weakest, on every task (original).
        let r = report();
        for task in Task::ALL {
            let q = r.get("qwen3", task).unwrap().original;
            let l = r.get("llama3.1", task).unwrap().original;
            assert!(q > l, "{task:?}: qwen {q:.2} vs llama {l:.2}");
        }
    }

    #[test]
    fn table3_calibration_bands() {
        // Accuracies land near the paper's Table 3 (generous ±0.12 band —
        // the simulated models are stand-ins, the *ordering and deltas*
        // are the contract; see EXPERIMENTS.md for measured values).
        // Full question counts: the 30-question tuning task is too noisy
        // at reduced scale.
        let r = run_benchmark(
            &[
                ModelProfile::phi4(),
                ModelProfile::qwen3(),
                ModelProfile::llama31(),
            ],
            2026,
            1.0,
        );
        let expect = [
            ("phi4", Task::BottleneckAnalysis, 0.70, 0.76),
            ("qwen3", Task::BottleneckAnalysis, 0.73, 0.80),
            ("llama3.1", Task::BottleneckAnalysis, 0.47, 0.53),
            ("phi4", Task::PerfAreaPrediction, 0.42, 0.61),
            ("qwen3", Task::PerfAreaPrediction, 0.59, 0.82),
            ("llama3.1", Task::PerfAreaPrediction, 0.23, 0.39),
            ("phi4", Task::ParameterTuning, 0.30, 0.48),
            ("qwen3", Task::ParameterTuning, 0.40, 0.63),
            ("llama3.1", Task::ParameterTuning, 0.26, 0.46),
        ];
        for (model, task, orig, enh) in expect {
            let a = r.get(model, task).unwrap();
            assert!(
                (a.original - orig).abs() < 0.12,
                "{model} {task:?} original {:.2} vs paper {orig}",
                a.original
            );
            assert!(
                (a.enhanced - enh).abs() < 0.15,
                "{model} {task:?} enhanced {:.2} vs paper {enh}",
                a.enhanced
            );
        }
    }

    #[test]
    fn ppa_mode_adds_power_predictions_the_oracle_still_nails() {
        // The ppa benchmark folds avg_power_w predictions into the
        // Perf/Area task; the linear-slope prediction path is metric
        // generic, so the oracle stays near-perfect on them.
        let sets = QuestionSet::generate_n_mode(
            Task::PerfAreaPrediction,
            60,
            11,
            &default_scenario().spec,
            ObjectiveMode::Ppa,
        );
        let n_power = sets
            .questions
            .iter()
            .filter(|q| q.prompt.contains("Predict avg_power_w"))
            .count();
        assert!(n_power >= 5, "only {n_power}/60 power questions");
        let mut oracle =
            SimulatedAnalyst::new(ModelProfile::oracle(), 5);
        let acc = score(
            &mut oracle,
            prompts::SYSTEM_DEFAULT,
            &sets.questions,
        );
        assert!(acc > 0.8, "oracle ppa prediction accuracy {acc:.2}");
        // Default mode generates no power questions (bit-identical
        // historical sets).
        let base = QuestionSet::generate_n_for(
            Task::PerfAreaPrediction,
            60,
            11,
            &default_scenario().spec,
        );
        assert!(base
            .questions
            .iter()
            .all(|q| !q.prompt.contains("avg_power_w")));
    }

    #[test]
    fn render_contains_all_rows() {
        let r = report();
        let t = r.render_table3();
        for m in ["phi4", "qwen3", "llama3.1"] {
            assert!(t.contains(m));
        }
        assert!(t.contains("Bottleneck Analysis"));
    }
}
