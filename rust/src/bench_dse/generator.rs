//! Question generation for the DSE Benchmark.
//!
//! Every question's ground truth is computed from the simulation
//! environment (never from the heuristics the answering models use):
//! bottleneck questions score each candidate adjustment by simulated
//! improvement per unit area; prediction questions use the simulated
//! metric; tuning questions pick the constraint-feasible candidate with
//! the best simulated objective.

use std::sync::Arc;

use crate::design::{sample, DesignPoint, DesignSpace, Param};
use crate::eval::{DiskStore, Metrics, Phase};
use crate::llm::analyst::analyst_area;
use crate::llm::prompts;
use crate::pareto::ObjectiveMode;
use crate::sim::RooflineSim;
use crate::stats::rng::Pcg32;
use crate::workload::{default_scenario, WorkloadSpec};

/// The ground-truth simulator behind question generation, optionally
/// memoized in a persistent [`DiskStore`] (`benchmark --cache-dir`).
/// Question ground truth revisits step-neighborhoods of sampled
/// designs, and repeat benchmark runs (CI, scale sweeps) re-derive
/// the same truths — warm restarts serve those simulations from disk.
/// Served metrics are the stored f32 bits, so cached and uncached
/// generation produce bit-identical question sets.
pub struct TruthSim {
    sim: RooflineSim,
    fp: u64,
    disk: Option<Arc<DiskStore>>,
}

impl TruthSim {
    pub fn new(
        sim: RooflineSim,
        disk: Option<Arc<DiskStore>>,
    ) -> TruthSim {
        let fp = sim.spec().fingerprint();
        TruthSim { sim, fp, disk }
    }

    pub fn spec(&self) -> &WorkloadSpec {
        self.sim.spec()
    }

    pub fn evaluate(&self, d: &DesignPoint) -> Metrics {
        let Some(disk) = &self.disk else {
            return self.sim.evaluate(d);
        };
        if let Some(m) = disk.get(self.fp, d) {
            disk.note_hit();
            return m;
        }
        let m = self.sim.evaluate(d);
        disk.append(self.fp, d, &m);
        m
    }
}

/// Benchmark task families (paper Figure 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Task {
    BottleneckAnalysis,
    PerfAreaPrediction,
    ParameterTuning,
}

impl Task {
    pub const ALL: [Task; 3] = [
        Task::BottleneckAnalysis,
        Task::PerfAreaPrediction,
        Task::ParameterTuning,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Task::BottleneckAnalysis => "Bottleneck Analysis",
            Task::PerfAreaPrediction => "Perf/Area Prediction",
            Task::ParameterTuning => "Parameter Tuning",
        }
    }

    /// Question counts from the paper (§5.2).
    pub fn paper_count(self) -> usize {
        match self {
            Task::BottleneckAnalysis => 308,
            Task::PerfAreaPrediction => 127,
            Task::ParameterTuning => 30,
        }
    }
}

/// One multiple-choice question.
#[derive(Debug, Clone)]
pub struct Question {
    pub task: Task,
    pub prompt: String,
    pub choices: Vec<String>,
    pub correct: usize,
}

/// A generated benchmark (one task family).
#[derive(Debug, Clone)]
pub struct QuestionSet {
    pub task: Task,
    pub questions: Vec<Question>,
}

impl QuestionSet {
    /// Generate the paper-sized question set for `task` on the default
    /// workload scenario (the paper's GPT-3 setup).
    pub fn generate(task: Task, seed: u64) -> QuestionSet {
        Self::generate_n(task, task.paper_count(), seed)
    }

    pub fn generate_n(task: Task, n: usize, seed: u64) -> QuestionSet {
        Self::generate_n_for(task, n, seed, &default_scenario().spec)
    }

    /// Generate `n` questions whose ground truth is simulated under an
    /// explicit workload (per-scenario benchmark variants).
    pub fn generate_n_for(
        task: Task,
        n: usize,
        seed: u64,
        workload: &WorkloadSpec,
    ) -> QuestionSet {
        Self::generate_n_mode(
            task,
            n,
            seed,
            workload,
            ObjectiveMode::LatencyArea,
        )
    }

    /// [`QuestionSet::generate_n_for`] under an objective mode: `ppa`
    /// extends the prediction task with `avg_power_w` questions (the
    /// energy model is part of the skill surface the benchmark
    /// measures); `latency-area` generates the historical sets
    /// bit-identically.
    pub fn generate_n_mode(
        task: Task,
        n: usize,
        seed: u64,
        workload: &WorkloadSpec,
        mode: ObjectiveMode,
    ) -> QuestionSet {
        Self::generate_n_disk(task, n, seed, workload, mode, None)
    }

    /// [`QuestionSet::generate_n_mode`] with the ground-truth
    /// simulations memoized in a shared disk store (`benchmark
    /// --cache-dir`). `None` generates uncached, bit-identically.
    pub fn generate_n_disk(
        task: Task,
        n: usize,
        seed: u64,
        workload: &WorkloadSpec,
        mode: ObjectiveMode,
        disk: Option<Arc<DiskStore>>,
    ) -> QuestionSet {
        let mut rng = Pcg32::with_stream(seed, task as u64 + 0xbe);
        let space = DesignSpace::table1();
        let sim = TruthSim::new(RooflineSim::new(*workload), disk);
        let questions = (0..n)
            .map(|_| match task {
                Task::BottleneckAnalysis => {
                    gen_bottleneck(&space, &sim, &mut rng)
                }
                Task::PerfAreaPrediction => {
                    gen_prediction(&space, &sim, &mut rng, mode)
                }
                Task::ParameterTuning => {
                    gen_tuning(&space, &sim, &mut rng)
                }
            })
            .collect();
        QuestionSet { task, questions }
    }
}

/// A design whose stall profile is interesting (non-degenerate).
fn sample_design(
    space: &DesignSpace,
    sim: &TruthSim,
    rng: &mut Pcg32,
) -> (DesignPoint, Metrics) {
    loop {
        let d = sample::uniform(space, rng);
        let m = sim.evaluate(&d);
        if m.ttft_ms.is_finite() && m.ttft_ms < 10_000.0 {
            return (d, m);
        }
    }
}

fn action_str(p: Param, dir: i32) -> String {
    format!(
        "{} {}",
        if dir > 0 { "increase" } else { "decrease" },
        p.name()
    )
}

/// Apply a parsed action list to a design (1 grid step per action).
fn apply_actions(
    space: &DesignSpace,
    d: &DesignPoint,
    actions: &[(Param, i32)],
) -> DesignPoint {
    let mut out = *d;
    for (p, dir) in actions {
        out = space.step(&out, *p, *dir);
    }
    out
}

fn gen_bottleneck(
    space: &DesignSpace,
    sim: &TruthSim,
    rng: &mut Pcg32,
) -> Question {
    // Resample until the dominant-stall fix is *unambiguously* the best
    // candidate under simulation — the paper's questions have exactly one
    // correct answer; ambiguous draws (where an off-bottleneck resource
    // happens to score better) are discarded.
    for _ in 0..40 {
        if let Some(q) = try_gen_bottleneck(space, sim, rng) {
            return q;
        }
    }
    // Statistically unreachable; keep the last attempt regardless.
    try_gen_bottleneck_relaxed(space, sim, rng)
}

fn try_gen_bottleneck(
    space: &DesignSpace,
    sim: &TruthSim,
    rng: &mut Pcg32,
) -> Option<Question> {
    gen_bottleneck_inner(space, sim, rng, true)
}

fn try_gen_bottleneck_relaxed(
    space: &DesignSpace,
    sim: &TruthSim,
    rng: &mut Pcg32,
) -> Question {
    // lumina: allow(P001) strict=false never returns None (no regenerate path)
    gen_bottleneck_inner(space, sim, rng, false).unwrap()
}

fn gen_bottleneck_inner(
    space: &DesignSpace,
    sim: &TruthSim,
    rng: &mut Pcg32,
    strict: bool,
) -> Option<Question> {
    let (d, m) = sample_design(space, sim, rng);
    let phase = if rng.chance(0.5) { Phase::Prefill } else { Phase::Decode };
    let dominant = m.dominant_bottleneck(phase);

    // Candidate actions: primary fix, a decrease-systolic option when
    // over-provisioned, irrelevant singles, and one multi-resource
    // bundle (the paper's observed distractor class).
    let primary: Vec<(Param, i32)> = {
        use crate::eval::Bottleneck::*;
        match dominant {
            Network => vec![(Param::Links, 1)],
            Memory => vec![(Param::MemChannels, 1)],
            Compute => {
                if phase == Phase::Decode
                    && d.get(Param::SystolicArray) >= 32
                {
                    vec![(Param::SystolicArray, -1)]
                } else {
                    vec![(Param::SystolicArray, 1)]
                }
            }
        }
    };
    // Distractors draw from parameters *irrelevant to the dominant
    // stall* (the paper's wrong answers bundle "irrelevant parameters").
    let relevant_set =
        crate::llm::analyst::relevant_params(dominant.name());
    let irrelevant_pool: Vec<Param> = Param::ALL
        .iter()
        .copied()
        .filter(|p| *p != primary[0].0 && !relevant_set.contains(p))
        .collect();
    let irr1 = *rng.choose(&irrelevant_pool);
    let irr2 = loop {
        let p = *rng.choose(&irrelevant_pool);
        if p != irr1 {
            break p;
        }
    };
    let bundle = vec![primary[0], (irr1, 1)];

    let mut actions: Vec<Vec<(Param, i32)>> = vec![
        primary.clone(),
        vec![(irr1, 1)],
        vec![(irr2, 1)],
        bundle,
    ];

    // Ground truth: simulated improvement of the phase metric per mm^2
    // of area spent (bundles pay for their irrelevant resource).
    let base_t = m.phase_time_ms(phase) as f64;
    let base_a = m.area_mm2 as f64;
    let score = |acts: &[(Param, i32)]| -> f64 {
        let nd = apply_actions(space, &d, acts);
        if nd == d {
            return f64::NEG_INFINITY;
        }
        let nm = sim.evaluate(&nd);
        let dt = base_t - nm.phase_time_ms(phase) as f64;
        let da = (nm.area_mm2 as f64 - base_a).max(-base_a * 0.2);
        dt / base_t - 0.5 * da / base_a
    };
    let scores: Vec<f64> = actions.iter().map(|a| score(a)).collect();
    let best = scores
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        // lumina: allow(P001) actions is non-empty, so max_by yields a winner
        .unwrap();
    // Strict mode: the dominant-stall fix (index 0) must win by a clear
    // margin, otherwise the question is ambiguous — regenerate.
    if strict {
        let max_other = scores[1..]
            .iter()
            .cloned()
            .fold(f64::NEG_INFINITY, f64::max);
        if best != 0 || scores[0] < max_other + 0.005 {
            return None;
        }
    }
    // Shuffle choices, tracking the correct index.
    let mut order: Vec<usize> = (0..actions.len()).collect();
    rng.shuffle(&mut order);
    // lumina: allow(P001) order is a permutation of 0..len, position always hits
    let correct = order.iter().position(|&i| i == best).unwrap();
    let shuffled: Vec<Vec<(Param, i32)>> =
        order.iter().map(|&i| actions[i].clone()).collect();
    actions = shuffled;

    let choices: Vec<String> = actions
        .iter()
        .map(|acts| {
            acts.iter()
                .map(|(p, dir)| action_str(*p, *dir))
                .collect::<Vec<_>>()
                .join(" ; ")
        })
        .collect();
    let prompt =
        prompts::bottleneck_question(sim.spec(), &d, &m, phase, &choices);
    Some(Question {
        task: Task::BottleneckAnalysis,
        prompt,
        choices,
        correct,
    })
}

fn gen_prediction(
    space: &DesignSpace,
    sim: &TruthSim,
    rng: &mut Pcg32,
    mode: ObjectiveMode,
) -> Question {
    let (reference, ref_m) = sample_design(space, sim, rng);
    // 0-2 area, 3 ttft, 4 tpot; ppa adds 5 = average power. The
    // latency-area draw range is unchanged so historical question sets
    // stay bit-identical.
    let metric_kind = match mode {
        ObjectiveMode::LatencyArea => rng.range_usize(0, 5),
        ObjectiveMode::Ppa => rng.range_usize(0, 6),
    };
    let (metric, ref_v): (&str, f64) = match metric_kind {
        0..=2 => ("area_mm2", ref_m.area_mm2 as f64),
        3 => ("TTFT_ms", ref_m.ttft_ms as f64),
        4 => ("TPOT_ms", ref_m.tpot_ms as f64),
        _ => ("avg_power_w", ref_m.avg_power_w as f64),
    };
    let value_of = |m: &Metrics| -> f64 {
        match metric_kind {
            0..=2 => m.area_mm2 as f64,
            3 => m.ttft_ms as f64,
            4 => m.tpot_ms as f64,
            _ => m.avg_power_w as f64,
        }
    };

    // Single-parameter example perturbations.
    let mut examples = Vec::new();
    let mut perturbed: Vec<Param> = Vec::new();
    for _ in 0..4 {
        let p = *rng.choose(&Param::ALL);
        let dir = if rng.chance(0.5) { 1 } else { -1 };
        let d = space.step(&reference, p, dir);
        if d == reference {
            continue;
        }
        examples.push((d, value_of(&sim.evaluate(&d))));
        if !perturbed.contains(&p) {
            perturbed.push(p);
        }
    }
    // Target: step one of the example-covered params (or a fresh one).
    let tp = if !perturbed.is_empty() && rng.chance(0.8) {
        *rng.choose(&perturbed)
    } else {
        *rng.choose(&Param::ALL)
    };
    let steps = if rng.chance(0.5) { 1 } else { 2 };
    let target = space.step(&reference, tp, steps);
    let truth = value_of(&sim.evaluate(&target));

    // Choices: truth, the zero-baseline failure value, and offset decoys.
    let zero_baseline_value = if metric == "area_mm2" {
        analyst_area(&target) - analyst_area(&reference)
    } else {
        truth * 0.45
    };
    let mut values = vec![
        truth,
        zero_baseline_value,
        truth * (1.18 + rng.f64() * 0.12),
        truth * (0.72 + rng.f64() * 0.1),
    ];
    // Ensure distinctness (rare degenerate cases).
    for i in 1..values.len() {
        while (values[i] - values[0]).abs() < truth.abs() * 0.04 + 1e-9 {
            values[i] *= 1.3;
        }
    }
    let mut order: Vec<usize> = (0..values.len()).collect();
    rng.shuffle(&mut order);
    // lumina: allow(P001) order is a permutation of 0..len, position always hits
    let correct = order.iter().position(|&i| i == 0).unwrap();
    let shuffled: Vec<f64> = order.iter().map(|&i| values[i]).collect();
    values = shuffled;

    let choices: Vec<String> =
        values.iter().map(|v| format!("{v:.3}")).collect();
    let prompt = prompts::prediction_question(
        metric,
        &reference,
        ref_v,
        &examples,
        &target,
        metric == "area_mm2",
        &choices,
    );
    Question { task: Task::PerfAreaPrediction, prompt, choices, correct }
}

fn gen_tuning(
    space: &DesignSpace,
    sim: &TruthSim,
    rng: &mut Pcg32,
) -> Question {
    let (initial, m) = sample_design(space, sim, rng);
    let phase = if rng.chance(0.5) { Phase::Prefill } else { Phase::Decode };
    let budget = m.area_mm2 as f64 * (0.95 + rng.f64() * 0.15);

    // Candidates: targeted fix, infeasible monster, scattershot
    // multi-adjust, and a lateral feasible move.
    let dominant = m.dominant_bottleneck(phase);
    let fix = {
        use crate::eval::Bottleneck::*;
        let p = match dominant {
            Network => Param::Links,
            Memory => Param::MemChannels,
            Compute => Param::SystolicArray,
        };
        let mut d = space.step(&initial, p, 1);
        // Fund if needed to stay under budget.
        let mut guard = 0;
        while (crate::arch::area_mm2(&d) as f64) > budget && guard < 6 {
            let f = *rng.choose(&[
                Param::Cores,
                Param::SramKb,
                Param::VectorWidth,
            ]);
            let nd = space.step(&d, f, -1);
            if nd == d {
                guard += 1;
                continue;
            }
            d = nd;
            guard += 1;
        }
        d
    };
    let monster = DesignPoint::new([24, 256, 8, 64, 64, 512, 256, 12]);
    let scattershot = {
        let mut d = initial;
        for p in Param::ALL {
            if rng.chance(0.6) {
                let dir = if rng.chance(0.5) { 1 } else { -1 };
                d = space.step(&d, p, dir);
            }
        }
        d
    };
    let lateral = {
        // Guaranteed-feasible fallback: shrink axes until under budget.
        let mut d = space.step(&initial, *rng.choose(&Param::ALL), -1);
        let shrink_order = [
            Param::Cores,
            Param::SystolicArray,
            Param::SramKb,
            Param::GbufMb,
            Param::VectorWidth,
            Param::MemChannels,
        ];
        let mut i = 0;
        while (crate::arch::area_mm2(&d) as f64) > budget && i < 24 {
            d = space.step(&d, shrink_order[i % shrink_order.len()], -1);
            i += 1;
        }
        d
    };
    let mut cands = vec![fix, monster, scattershot, lateral];

    // Ground truth: best simulated phase metric among feasible ones
    // (the lateral candidate is feasible by construction).
    let feasible_best = |cands: &[DesignPoint]| -> usize {
        let mut best: Option<(usize, f64)> = None;
        for (i, c) in cands.iter().enumerate() {
            if crate::arch::area_mm2(c) as f64 > budget {
                continue;
            }
            let t = sim.evaluate(c).phase_time_ms(phase) as f64;
            if best.map(|(_, bt)| t < bt).unwrap_or(true) {
                best = Some((i, t));
            }
        }
        best.map(|(i, _)| i).unwrap_or(3)
    };
    let best = feasible_best(&cands);
    let mut order: Vec<usize> = (0..cands.len()).collect();
    rng.shuffle(&mut order);
    // lumina: allow(P001) order is a permutation of 0..len, position always hits
    let correct = order.iter().position(|&i| i == best).unwrap();
    let shuffled: Vec<DesignPoint> =
        order.iter().map(|&i| cands[i]).collect();
    cands = shuffled;

    let choices: Vec<String> =
        cands.iter().map(prompts::compact_design).collect();
    let prompt =
        prompts::tuning_question(&initial, &m, phase, budget, &choices);
    Question { task: Task::ParameterTuning, prompt, choices, correct }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_counts() {
        assert_eq!(Task::BottleneckAnalysis.paper_count(), 308);
        assert_eq!(Task::PerfAreaPrediction.paper_count(), 127);
        assert_eq!(Task::ParameterTuning.paper_count(), 30);
    }

    #[test]
    fn questions_are_well_formed() {
        for task in Task::ALL {
            let qs = QuestionSet::generate_n(task, 20, 1);
            assert_eq!(qs.questions.len(), 20);
            for q in &qs.questions {
                assert!(q.choices.len() >= 3);
                assert!(q.correct < q.choices.len());
                assert!(q.prompt.contains("## Task:"));
                assert!(q.prompt.contains("Answer with"));
                // Choice lines present in the prompt.
                for (i, c) in q.choices.iter().enumerate() {
                    assert!(q.prompt.contains(&format!(
                        "{}) {c}",
                        prompts::letter(i)
                    )));
                }
            }
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let a = QuestionSet::generate_n(Task::BottleneckAnalysis, 5, 9);
        let b = QuestionSet::generate_n(Task::BottleneckAnalysis, 5, 9);
        for (x, y) in a.questions.iter().zip(&b.questions) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.correct, y.correct);
        }
    }

    #[test]
    fn disk_cached_generation_is_bit_identical() {
        let dir = std::env::temp_dir().join(format!(
            "lumina_bench_truth_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let disk = DiskStore::open_shared(&dir).unwrap();
        let spec = default_scenario().spec;
        let plain = QuestionSet::generate_n_mode(
            Task::BottleneckAnalysis,
            6,
            9,
            &spec,
            ObjectiveMode::LatencyArea,
        );
        // Cold pass fills the store, warm pass serves from it; both
        // must reproduce the uncached question set exactly.
        for pass in 0..2 {
            let cached = QuestionSet::generate_n_disk(
                Task::BottleneckAnalysis,
                6,
                9,
                &spec,
                ObjectiveMode::LatencyArea,
                Some(disk.clone()),
            );
            for (a, b) in plain.questions.iter().zip(&cached.questions)
            {
                assert_eq!(a.prompt, b.prompt, "pass {pass}");
                assert_eq!(a.correct, b.correct, "pass {pass}");
                assert_eq!(a.choices, b.choices, "pass {pass}");
            }
        }
        assert!(disk.counters().hits > 0, "warm pass never hit disk");
        disk.seal().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn correct_answers_are_distributed() {
        let qs = QuestionSet::generate_n(Task::BottleneckAnalysis, 60, 3);
        let mut counts = [0usize; 4];
        for q in &qs.questions {
            counts[q.correct] += 1;
        }
        // Shuffling should spread the answer key.
        assert!(counts.iter().all(|&c| c > 3), "{counts:?}");
    }

    #[test]
    fn prediction_truth_is_uniquely_closest() {
        let qs = QuestionSet::generate_n(Task::PerfAreaPrediction, 30, 4);
        for q in &qs.questions {
            let vals: Vec<f64> = q
                .choices
                .iter()
                .map(|c| c.parse::<f64>().unwrap())
                .collect();
            let truth = vals[q.correct];
            for (i, v) in vals.iter().enumerate() {
                if i != q.correct {
                    assert!(
                        (v - truth).abs() > truth.abs() * 0.03,
                        "ambiguous choices {vals:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn tuning_correct_candidate_is_feasible() {
        let qs = QuestionSet::generate_n(Task::ParameterTuning, 15, 5);
        for q in &qs.questions {
            let budget: f64 = q
                .prompt
                .split("area_mm2 <=")
                .nth(1)
                .unwrap()
                .split_whitespace()
                .next()
                .unwrap()
                .parse()
                .unwrap();
            let d = crate::llm::parse::parse_compact_design(
                &q.choices[q.correct],
            )
            .unwrap();
            assert!(
                (crate::arch::area_mm2(&d) as f64) <= budget * 1.001,
                "correct candidate violates constraint"
            );
        }
    }
}
