//! The DSE Benchmark (paper §4): a Q&A benchmark of the three skills
//! architecture optimization needs — bottleneck analysis (308 questions),
//! performance/area prediction (127) and parameter tuning (30) — with
//! ground truth computed from the simulators, multiple-choice format
//! (LongBench-style), and an accuracy scorer over `LanguageModel`s.
//!
//! This is what selects the backbone model for LUMINA and what the §5.2
//! corrective rules were distilled from.

pub mod generator;
pub mod runner;

pub use generator::{Question, QuestionSet, Task, TruthSim};
pub use runner::{
    run_benchmark, run_benchmark_disk, run_benchmark_for,
    run_benchmark_mode, BenchmarkReport, TaskAccuracy,
};
