//! Design points and parameters.
//!
//! The encoding order (and the meaning of each lane of the f32 design
//! vector) is a MIRROR of `python/compile/constants.py` — the artifact
//! and every simulator consume the same layout. Pair `design-params`
//! in `lumina lint --mirror` checks `N_PARAMS` statically.

use std::fmt;

/// Number of free design parameters (Table 1; systolic array height and
/// width are a single square parameter).
pub const N_PARAMS: usize = 8;

/// A design parameter, in encoding order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Param {
    Links = 0,
    Cores = 1,
    Sublanes = 2,
    SystolicArray = 3,
    VectorWidth = 4,
    SramKb = 5,
    GbufMb = 6,
    MemChannels = 7,
}

impl Param {
    pub const ALL: [Param; N_PARAMS] = [
        Param::Links,
        Param::Cores,
        Param::Sublanes,
        Param::SystolicArray,
        Param::VectorWidth,
        Param::SramKb,
        Param::GbufMb,
        Param::MemChannels,
    ];

    pub fn index(self) -> usize {
        self as usize
    }

    pub fn from_index(i: usize) -> Param {
        Param::ALL[i]
    }

    /// Canonical identifier, used in prompts, reports and the QualE
    /// influence map (must match the names that appear in the simulator
    /// sources QualE parses).
    pub fn name(self) -> &'static str {
        match self {
            Param::Links => "interconnect_link_count",
            Param::Cores => "core_count",
            Param::Sublanes => "sublane_count",
            Param::SystolicArray => "systolic_array_dim",
            Param::VectorWidth => "vector_width",
            Param::SramKb => "sram_kb",
            Param::GbufMb => "global_buffer_mb",
            Param::MemChannels => "memory_channel_count",
        }
    }

    /// Human label as in the paper's Table 1/4.
    pub fn label(self) -> &'static str {
        match self {
            Param::Links => "Interconnect Link Count",
            Param::Cores => "Core Count",
            Param::Sublanes => "Sublane Count",
            Param::SystolicArray => "Systolic Array Height x Width",
            Param::VectorWidth => "Vector Width",
            Param::SramKb => "SRAM Size (KB)",
            Param::GbufMb => "Global Buffer (MB)",
            Param::MemChannels => "Memory Channel Count",
        }
    }

    pub fn by_name(name: &str) -> Option<Param> {
        Param::ALL.iter().copied().find(|p| p.name() == name)
    }
}

impl fmt::Display for Param {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// A concrete design point: raw parameter values (not grid indices).
/// Ordered lexicographically over the value lanes so deterministic
/// containers (the disk store's `BTreeMap` index) can key on it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct DesignPoint {
    pub values: [u32; N_PARAMS],
}

impl DesignPoint {
    pub fn new(values: [u32; N_PARAMS]) -> Self {
        Self { values }
    }

    pub fn get(&self, p: Param) -> u32 {
        self.values[p.index()]
    }

    pub fn set(&mut self, p: Param, v: u32) {
        self.values[p.index()] = v;
    }

    pub fn with(&self, p: Param, v: u32) -> DesignPoint {
        let mut d = *self;
        d.set(p, v);
        d
    }

    /// Encode for the evaluator / artifact (f32 lanes in Param order).
    pub fn encode(&self) -> [f32; N_PARAMS] {
        let mut out = [0f32; N_PARAMS];
        for (o, v) in out.iter_mut().zip(self.values.iter()) {
            *o = *v as f32;
        }
        out
    }

    /// Raw values as f64 (PCA input).
    pub fn as_f64(&self) -> Vec<f64> {
        self.values.iter().map(|&v| v as f64).collect()
    }

    /// The NVIDIA A100-class reference configuration (Table 4 rightmost
    /// column): 12 NVLinks, 108 SMs, 4 sublanes, 16x16 systolic arrays,
    /// 32-wide vector units, 192 KB SRAM/SM, 40 MB L2, 5 HBM channels.
    pub fn a100() -> DesignPoint {
        DesignPoint::new([12, 108, 4, 16, 32, 192, 40, 5])
    }

    /// Paper Table 4 "Design A".
    pub fn paper_design_a() -> DesignPoint {
        DesignPoint::new([24, 64, 4, 32, 16, 128, 40, 6])
    }

    /// Paper Table 4 "Design B".
    pub fn paper_design_b() -> DesignPoint {
        DesignPoint::new([18, 96, 4, 32, 16, 128, 40, 6])
    }
}

impl fmt::Display for DesignPoint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "links={} cores={} sublanes={} sa={}x{} vec={} sram={}KB \
             gbuf={}MB memch={}",
            self.values[0],
            self.values[1],
            self.values[2],
            self.values[3],
            self.values[3],
            self.values[4],
            self.values[5],
            self.values[6],
            self.values[7],
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_roundtrip() {
        for p in Param::ALL {
            assert_eq!(Param::from_index(p.index()), p);
            assert_eq!(Param::by_name(p.name()), Some(p));
        }
        assert_eq!(Param::by_name("bogus"), None);
    }

    #[test]
    fn encode_matches_python_layout() {
        // Mirrors constants.IDX_* ordering.
        let a100 = DesignPoint::a100();
        let e = a100.encode();
        assert_eq!(e[0], 12.0); // links
        assert_eq!(e[1], 108.0); // cores
        assert_eq!(e[2], 4.0); // sublanes
        assert_eq!(e[3], 16.0); // systolic dim
        assert_eq!(e[4], 32.0); // vector width
        assert_eq!(e[5], 192.0); // sram kb
        assert_eq!(e[6], 40.0); // gbuf mb
        assert_eq!(e[7], 5.0); // memory channels
    }

    #[test]
    fn with_does_not_mutate_original() {
        let a = DesignPoint::a100();
        let b = a.with(Param::Cores, 64);
        assert_eq!(a.get(Param::Cores), 108);
        assert_eq!(b.get(Param::Cores), 64);
        assert_eq!(b.get(Param::Links), a.get(Param::Links));
    }

    #[test]
    fn paper_designs_match_table4() {
        let a = DesignPoint::paper_design_a();
        assert_eq!(a.get(Param::Links), 24);
        assert_eq!(a.get(Param::Cores), 64);
        assert_eq!(a.get(Param::SystolicArray), 32);
        assert_eq!(a.get(Param::MemChannels), 6);
        let b = DesignPoint::paper_design_b();
        assert_eq!(b.get(Param::Links), 18);
        assert_eq!(b.get(Param::Cores), 96);
    }
}
