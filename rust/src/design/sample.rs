//! Design-space sampling strategies shared by the baselines and figures:
//! uniform grid sampling, Latin-hypercube-style stratified sampling, and
//! dedup-aware batch draws.

use std::collections::HashSet;

use super::point::{DesignPoint, Param, N_PARAMS};
use super::space::DesignSpace;
use crate::stats::rng::Pcg32;

/// Draw one uniform random grid point.
pub fn uniform(space: &DesignSpace, rng: &mut Pcg32) -> DesignPoint {
    let idx = rng.next_u64() % space.size();
    space
        .decode_index(idx)
        // lumina: allow(P001) index reduced modulo size() always decodes
        .expect("index reduced modulo size() is always decodable")
}

/// Draw `n` uniform points (may repeat).
pub fn uniform_batch(
    space: &DesignSpace,
    rng: &mut Pcg32,
    n: usize,
) -> Vec<DesignPoint> {
    (0..n).map(|_| uniform(space, rng)).collect()
}

/// Draw `n` distinct uniform points (rejection on duplicates).
pub fn uniform_distinct(
    space: &DesignSpace,
    rng: &mut Pcg32,
    n: usize,
) -> Vec<DesignPoint> {
    assert!((n as u64) <= space.size());
    let mut seen = HashSet::with_capacity(n);
    let mut out = Vec::with_capacity(n);
    while out.len() < n {
        let d = uniform(space, rng);
        if seen.insert(d) {
            out.push(d);
        }
    }
    out
}

/// Latin-hypercube-flavoured stratified sample: each axis's grid values
/// are cycled through a shuffled order so every value appears ~n/k times,
/// decorrelating axes. Used to seed BO/GA populations.
pub fn stratified(
    space: &DesignSpace,
    rng: &mut Pcg32,
    n: usize,
) -> Vec<DesignPoint> {
    let mut columns: Vec<Vec<u32>> = Vec::with_capacity(N_PARAMS);
    for p in Param::ALL {
        let vals = space.values(p);
        let mut col = Vec::with_capacity(n);
        while col.len() < n {
            let mut order: Vec<u32> = vals.to_vec();
            rng.shuffle(&mut order);
            col.extend(order);
        }
        col.truncate(n);
        rng.shuffle(&mut col);
        columns.push(col);
    }
    (0..n)
        .map(|i| {
            let mut values = [0u32; N_PARAMS];
            for (j, col) in columns.iter().enumerate() {
                values[j] = col[i];
            }
            DesignPoint::new(values)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_points_are_on_grid() {
        let s = DesignSpace::table1();
        let mut rng = Pcg32::new(1);
        for _ in 0..200 {
            assert!(s.contains(&uniform(&s, &mut rng)));
        }
    }

    #[test]
    fn uniform_distinct_has_no_duplicates() {
        let s = DesignSpace::table1();
        let mut rng = Pcg32::new(2);
        let pts = uniform_distinct(&s, &mut rng, 500);
        let set: HashSet<_> = pts.iter().collect();
        assert_eq!(set.len(), 500);
    }

    #[test]
    fn stratified_covers_each_axis() {
        let s = DesignSpace::table1();
        let mut rng = Pcg32::new(3);
        let pts = stratified(&s, &mut rng, 64);
        assert_eq!(pts.len(), 64);
        for p in Param::ALL {
            let distinct: HashSet<u32> =
                pts.iter().map(|d| d.get(p)).collect();
            // With 64 samples every axis (<=14 values) should be covered.
            assert_eq!(
                distinct.len(),
                s.values(p).len(),
                "axis {p} not fully covered"
            );
        }
        for d in &pts {
            assert!(s.contains(d));
        }
    }

    #[test]
    fn uniform_hits_varied_regions() {
        // Smoke-test that sampling is not collapsed to a corner.
        let s = DesignSpace::table1();
        let mut rng = Pcg32::new(4);
        let pts = uniform_batch(&s, &mut rng, 300);
        let distinct_cores: HashSet<u32> =
            pts.iter().map(|d| d.get(Param::Cores)).collect();
        assert!(distinct_cores.len() >= 10);
    }
}
