//! The Table 1 design-space grid: legal values per parameter, point
//! validation, grid stepping (the Strategy Engine moves in grid steps),
//! and enumeration (~4.74M points).

use super::point::{DesignPoint, Param, N_PARAMS};

/// The discrete design space. Values per parameter are sorted ascending.
#[derive(Debug, Clone)]
pub struct DesignSpace {
    values: [Vec<u32>; N_PARAMS],
}

impl DesignSpace {
    /// The paper's Table 1 grid. The Global Buffer axis additionally
    /// carries the A100-class anchor value 40 MB (Table 4 lists 40 MB for
    /// every reported design even though Table 1's grid omits it — see
    /// DESIGN.md "Known paper inconsistencies").
    pub fn table1() -> DesignSpace {
        DesignSpace {
            values: [
                vec![6, 12, 18, 24],                                // links
                vec![1, 2, 4, 8, 16, 32, 64, 96, 108, 128, 132, 136,
                     140, 256],                                     // cores
                vec![1, 2, 4, 8],                                   // subl
                vec![4, 8, 16, 32, 64, 128],                        // sa
                vec![4, 8, 16, 32, 64, 128],                        // vecw
                vec![32, 64, 128, 192, 256, 512, 1024],             // sram
                vec![32, 40, 64, 128, 256, 320, 512, 1024],         // gbuf
                (1..=12).collect(),                                 // memch
            ],
        }
    }

    /// The strict Table 1 grid (no 40 MB anchor) — 4.74M points exactly;
    /// used by the size test and available for ablations.
    pub fn table1_strict() -> DesignSpace {
        let mut s = Self::table1();
        s.values[Param::GbufMb.index()] =
            vec![32, 64, 128, 256, 320, 512, 1024];
        s
    }

    pub fn values(&self, p: Param) -> &[u32] {
        &self.values[p.index()]
    }

    /// Total number of grid points.
    pub fn size(&self) -> u64 {
        self.values.iter().map(|v| v.len() as u64).product()
    }

    /// Is every coordinate of `d` on the grid?
    pub fn contains(&self, d: &DesignPoint) -> bool {
        Param::ALL
            .iter()
            .all(|&p| self.values(p).contains(&d.get(p)))
    }

    /// Grid index of a value (None if off-grid).
    pub fn index_of(&self, p: Param, value: u32) -> Option<usize> {
        self.values(p).iter().position(|&v| v == value)
    }

    /// Step `p` by `delta` grid positions from its current value,
    /// clamping at the ends. Off-grid values snap to the nearest grid
    /// value first.
    pub fn step(&self, d: &DesignPoint, p: Param, delta: i32) -> DesignPoint {
        let vals = self.values(p);
        let cur = self
            .index_of(p, d.get(p))
            .unwrap_or_else(|| self.nearest_index(p, d.get(p)));
        let next = (cur as i64 + delta as i64)
            .clamp(0, vals.len() as i64 - 1) as usize;
        d.with(p, vals[next])
    }

    /// Index of the grid value closest to `value`.
    pub fn nearest_index(&self, p: Param, value: u32) -> usize {
        let vals = self.values(p);
        let mut best = 0usize;
        let mut best_d = u32::MAX;
        for (i, &v) in vals.iter().enumerate() {
            let dist = v.abs_diff(value);
            if dist < best_d {
                best_d = dist;
                best = i;
            }
        }
        best
    }

    /// Snap an arbitrary point onto the grid (nearest value per axis).
    pub fn snap(&self, d: &DesignPoint) -> DesignPoint {
        let mut out = *d;
        for p in Param::ALL {
            let idx = self.nearest_index(p, d.get(p));
            out.set(p, self.values(p)[idx]);
        }
        out
    }

    /// Decode a flat enumeration index into a point (mixed-radix).
    ///
    /// Returns `None` for `idx >= size()` rather than wrapping: in a
    /// 4.7M-point space, silently aliasing out-of-range ids onto valid
    /// points masks enumeration bugs (an off-by-N id and a legitimate
    /// one become indistinguishable). Callers iterating a ring reduce
    /// modulo [`Self::size`] explicitly first.
    pub fn decode_index(&self, mut idx: u64) -> Option<DesignPoint> {
        if idx >= self.size() {
            return None;
        }
        let mut values = [0u32; N_PARAMS];
        for i in (0..N_PARAMS).rev() {
            let n = self.values[i].len() as u64;
            values[i] = self.values[i][(idx % n) as usize];
            idx /= n;
        }
        Some(DesignPoint::new(values))
    }

    /// Encode a grid point into its flat enumeration index.
    pub fn encode_index(&self, d: &DesignPoint) -> Option<u64> {
        let mut idx = 0u64;
        for i in 0..N_PARAMS {
            let pos = self.values[i]
                .iter()
                .position(|&v| v == d.values[i])? as u64;
            idx = idx * self.values[i].len() as u64 + pos;
        }
        Some(idx)
    }

    /// All single-axis grid neighbours of `d` (up to 2 per axis).
    pub fn neighbors(&self, d: &DesignPoint) -> Vec<DesignPoint> {
        let mut out = Vec::with_capacity(2 * N_PARAMS);
        for p in Param::ALL {
            for delta in [-1, 1] {
                let n = self.step(d, p, delta);
                if n != *d {
                    out.push(n);
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn strict_grid_matches_paper_size() {
        // 4 * 14 * 4 * 6 * 6 * 7 * 7 * 12 = 4,741,632 ~ "4.7 million"
        assert_eq!(DesignSpace::table1_strict().size(), 4_741_632);
    }

    #[test]
    fn extended_grid_contains_a100_gbuf() {
        let s = DesignSpace::table1();
        assert!(s.values(Param::GbufMb).contains(&40));
        assert_eq!(s.size(), 4_741_632 / 7 * 8);
    }

    #[test]
    fn a100_reference_is_on_extended_grid() {
        let s = DesignSpace::table1();
        assert!(s.contains(&DesignPoint::a100()));
        assert!(s.contains(&DesignPoint::paper_design_a()));
        assert!(s.contains(&DesignPoint::paper_design_b()));
    }

    #[test]
    fn step_clamps_at_boundaries() {
        let s = DesignSpace::table1();
        let d = DesignPoint::a100();
        let max_links = s.step(&d, Param::Links, 100);
        assert_eq!(max_links.get(Param::Links), 24);
        let min_links = s.step(&d, Param::Links, -100);
        assert_eq!(min_links.get(Param::Links), 6);
    }

    #[test]
    fn step_moves_one_grid_position() {
        let s = DesignSpace::table1();
        let d = DesignPoint::a100();
        assert_eq!(s.step(&d, Param::Cores, 1).get(Param::Cores), 128);
        assert_eq!(s.step(&d, Param::Cores, -1).get(Param::Cores), 96);
    }

    #[test]
    fn snap_finds_nearest() {
        let s = DesignSpace::table1();
        let off = DesignPoint::new([13, 100, 3, 20, 24, 200, 45, 5]);
        let snapped = s.snap(&off);
        assert_eq!(snapped.get(Param::Links), 12);
        assert_eq!(snapped.get(Param::Cores), 96);
        assert_eq!(snapped.get(Param::SystolicArray), 16);
        assert_eq!(snapped.get(Param::GbufMb), 40);
        assert!(s.contains(&snapped));
    }

    #[test]
    fn index_roundtrip_property() {
        let s = DesignSpace::table1();
        let size = s.size();
        prop::forall(
            11,
            256,
            |rng| rng.next_u64() % size,
            |&idx| {
                let d = s.decode_index(idx).unwrap();
                s.contains(&d) && s.encode_index(&d) == Some(idx)
            },
        );
    }

    #[test]
    fn decode_index_rejects_out_of_range() {
        let s = DesignSpace::table1();
        let size = s.size();
        assert!(s.decode_index(size - 1).is_some());
        // Regression: these used to wrap (idx % n per axis) and alias
        // onto valid in-range points.
        assert_eq!(s.decode_index(size), None);
        assert_eq!(s.decode_index(size + 12345), None);
        assert_eq!(s.decode_index(u64::MAX), None);
    }

    #[test]
    fn neighbors_are_on_grid_and_distinct() {
        let s = DesignSpace::table1();
        prop::forall(
            12,
            128,
            |rng| s.decode_index(rng.next_u64() % s.size()).unwrap(),
            |d| {
                let ns = s.neighbors(d);
                !ns.is_empty()
                    && ns.iter().all(|n| s.contains(n) && n != d)
            },
        );
    }

    #[test]
    fn snap_is_idempotent_property() {
        let s = DesignSpace::table1();
        prop::forall(
            13,
            128,
            |rng| {
                DesignPoint::new([
                    rng.range_usize(1, 30) as u32,
                    rng.range_usize(1, 300) as u32,
                    rng.range_usize(1, 10) as u32,
                    rng.range_usize(2, 140) as u32,
                    rng.range_usize(2, 140) as u32,
                    rng.range_usize(16, 1100) as u32,
                    rng.range_usize(16, 1100) as u32,
                    rng.range_usize(1, 14) as u32,
                ])
            },
            |d| {
                let s1 = s.snap(d);
                s.snap(&s1) == s1 && s.contains(&s1)
            },
        );
    }
}
