//! The GPU-node design space (paper Table 1): parameters, the ~4.7M-point
//! grid, encoding to the evaluator's f32 design vectors, and sampling.

pub mod point;
pub mod sample;
pub mod space;

pub use point::{DesignPoint, Param, N_PARAMS};
pub use space::DesignSpace;
