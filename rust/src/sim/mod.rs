//! Simulation environments.
//!
//! * [`roofline`] — Rust mirror of the AOT roofline artifact (test oracle
//!   and artifact-free fallback).
//! * [`compass`] — the detailed LLMCompass-class analytical simulator with
//!   tile-level execution modelling and critical-path stall attribution;
//!   the "expensive, high-fidelity" evaluator of the paper's §5.3
//!   20-sample study.

pub mod compass;
pub mod roofline;

pub use compass::CompassSim;
pub use roofline::RooflineSim;
