//! Memory-system model: HBM channels behind an L2 (global buffer) with
//! working-set-dependent hit rates and a shared-bandwidth contention
//! factor. Richer than the roofline's single effective-bandwidth scalar:
//! traffic classes (streaming weights, reused activations, KV cache) see
//! different service rates.

use crate::arch::constants as c;
use crate::design::{DesignPoint, Param};

/// Traffic class for a memory access stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrafficClass {
    /// Weights: streamed once per layer, far larger than L2 — always HBM.
    StreamingWeights,
    /// Activations: high temporal reuse; hit in L2 when the working set
    /// fits.
    Activations,
    /// KV cache reads during decode: sequential, partially cacheable.
    KvCache,
}

/// The memory system of one GPU in the node.
#[derive(Debug, Clone, Copy)]
pub struct MemorySystem {
    /// Raw HBM bandwidth (channels x per-channel), B/s.
    pub hbm_bw: f32,
    /// L2 capacity, bytes.
    pub l2_bytes: f32,
    /// L2 bandwidth, B/s (scales with capacity banks).
    pub l2_bw: f32,
}

impl MemorySystem {
    pub fn new(d: &DesignPoint) -> Self {
        let channels = d.get(Param::MemChannels) as f32;
        let l2_mb = d.get(Param::GbufMb) as f32;
        let hbm_bw = channels * c::HBM_BPS_PER_CHANNEL;
        // L2 bandwidth: the shared banked-crossbar model (single
        // definition with the peak-power proxy — see
        // `crate::arch::power::l2_peak_bps`).
        let l2_bw = crate::arch::power::l2_peak_bps(l2_mb);
        MemorySystem { hbm_bw, l2_bytes: l2_mb * 1024.0 * 1024.0, l2_bw }
    }

    /// L2 hit fraction for a stream with the given working set and class.
    pub fn hit_fraction(&self, class: TrafficClass, working_set: f32) -> f32 {
        match class {
            TrafficClass::StreamingWeights => 0.0,
            TrafficClass::Activations => {
                if working_set <= 0.0 {
                    return 0.0;
                }
                // Fully resident -> 90% hits (cold misses remain);
                // gracefully degrades as the set outgrows L2.
                (self.l2_bytes / working_set).min(1.0) * 0.9
            }
            TrafficClass::KvCache => {
                if working_set <= 0.0 {
                    return 0.0;
                }
                (self.l2_bytes / working_set).min(1.0) * 0.5
            }
        }
    }

    /// Service time for `bytes` of a traffic class, given DRAM efficiency
    /// degraded by row-conflict behaviour (streaming is efficient, short
    /// strided decode reads are not).
    pub fn service_s(
        &self,
        class: TrafficClass,
        bytes: f32,
        working_set: f32,
    ) -> f32 {
        if bytes <= 0.0 {
            return 0.0;
        }
        let hit = self.hit_fraction(class, working_set);
        let dram_eff = match class {
            TrafficClass::StreamingWeights => 0.88,
            TrafficClass::Activations => 0.75,
            TrafficClass::KvCache => 0.65,
        };
        let hbm_time =
            bytes * (1.0 - hit) / (self.hbm_bw * dram_eff);
        let l2_time = bytes * hit / self.l2_bw;
        // L2 and HBM service overlap only partially (miss handling holds
        // MSHRs): charge the max plus 20% of the minor term.
        let (hi, lo) = if hbm_time > l2_time {
            (hbm_time, l2_time)
        } else {
            (l2_time, hbm_time)
        };
        hi + 0.2 * lo
    }

    /// Energy split of a traffic stream: `(hbm_j, l2_j)` — bytes that
    /// miss L2 pay the HBM pJ/byte, hits pay the (much cheaper) L2
    /// rate. Same hit model as [`MemorySystem::service_s`].
    pub fn energy_split_j(
        &self,
        class: TrafficClass,
        bytes: f32,
        working_set: f32,
    ) -> (f32, f32) {
        if bytes <= 0.0 {
            return (0.0, 0.0);
        }
        let hit = self.hit_fraction(class, working_set);
        (
            bytes * (1.0 - hit) * c::E_J_PER_BYTE_HBM,
            bytes * hit * c::E_J_PER_BYTE_L2,
        )
    }

    /// Total memory energy of a traffic stream, joules.
    pub fn energy_j(
        &self,
        class: TrafficClass,
        bytes: f32,
        working_set: f32,
    ) -> f32 {
        let (hbm, l2) = self.energy_split_j(class, bytes, working_set);
        hbm + l2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn a100_mem() -> MemorySystem {
        MemorySystem::new(&DesignPoint::a100())
    }

    #[test]
    fn a100_bandwidths_are_sane() {
        let m = a100_mem();
        assert!((m.hbm_bw - 5.0 * 408.0e9).abs() < 1e6);
        assert!(m.l2_bw > m.hbm_bw * 2.0);
        assert!((m.l2_bytes - 40.0 * 1048576.0).abs() < 1.0);
    }

    #[test]
    fn weights_never_hit_l2() {
        let m = a100_mem();
        assert_eq!(
            m.hit_fraction(TrafficClass::StreamingWeights, 1e6),
            0.0
        );
    }

    #[test]
    fn small_activation_set_mostly_hits() {
        let m = a100_mem();
        let hit =
            m.hit_fraction(TrafficClass::Activations, 10.0 * 1048576.0);
        assert!((hit - 0.9).abs() < 1e-6);
        let miss_heavy =
            m.hit_fraction(TrafficClass::Activations, 400.0 * 1048576.0);
        assert!(miss_heavy < 0.1);
    }

    #[test]
    fn service_time_monotone_in_bytes() {
        let m = a100_mem();
        let t1 =
            m.service_s(TrafficClass::StreamingWeights, 1e8, 1e8);
        let t2 =
            m.service_s(TrafficClass::StreamingWeights, 2e8, 2e8);
        assert!(t2 > t1 * 1.9);
    }

    #[test]
    fn cached_traffic_is_faster_than_streamed() {
        let m = a100_mem();
        let bytes = 8.0 * 1048576.0;
        let cached =
            m.service_s(TrafficClass::Activations, bytes, bytes);
        let streamed =
            m.service_s(TrafficClass::StreamingWeights, bytes, bytes);
        assert!(cached < streamed);
    }

    #[test]
    fn cached_traffic_is_cheaper_energy_too() {
        let m = a100_mem();
        let bytes = 8.0 * 1048576.0;
        let cached =
            m.energy_j(TrafficClass::Activations, bytes, bytes);
        let streamed =
            m.energy_j(TrafficClass::StreamingWeights, bytes, bytes);
        assert!(cached < streamed);
        let (hbm, l2) =
            m.energy_split_j(TrafficClass::Activations, bytes, bytes);
        assert!((hbm + l2 - cached).abs() < cached * 1e-6);
        assert_eq!(
            m.energy_j(TrafficClass::KvCache, 0.0, 0.0),
            0.0
        );
    }

    #[test]
    fn bigger_l2_helps_kv_reads() {
        let small = MemorySystem::new(
            &DesignPoint::a100().with(Param::GbufMb, 32),
        );
        let big = MemorySystem::new(
            &DesignPoint::a100().with(Param::GbufMb, 256),
        );
        let ws = 150.0 * 1048576.0;
        let t_small = small.service_s(TrafficClass::KvCache, ws, ws);
        let t_big = big.service_s(TrafficClass::KvCache, ws, ws);
        assert!(t_big < t_small);
    }
}
