//! The compass evaluation engine: walks the workload's operator list and
//! composes the tiling, memory and interconnect models into per-operator
//! wall times with stall attribution.

use crate::arch::{area_mm2, constants as c};
use crate::design::{DesignPoint, Param};
use crate::eval::{Bottleneck, Evaluator, Metrics, Phase};
use crate::workload::{
    decode_ops, prefill_ops, Op, OpKind, WorkloadSpec, GPT3_175B,
};
use crate::Result;

use super::critical_path::{CriticalPath, OpRecord};
use super::interconnect::Interconnect;
use super::memory::{MemorySystem, TrafficClass};
use super::tiles::map_matmul;

/// Per-operator launch/dispatch overhead in the detailed model (larger
/// than the roofline's: includes kernel argument setup and wave ramp-up).
const LAUNCH_OVERHEAD_S: f32 = 3.0e-6;

/// The detailed simulator.
#[derive(Debug, Clone)]
pub struct CompassSim {
    pub spec: WorkloadSpec,
}

impl CompassSim {
    pub fn new(spec: WorkloadSpec) -> Self {
        Self { spec }
    }

    pub fn gpt3() -> Self {
        Self::new(GPT3_175B)
    }

    /// Evaluate one design, returning metrics plus the full critical-path
    /// report (the paper's extended-LLMCompass output).
    pub fn evaluate_detailed(
        &self,
        d: &DesignPoint,
    ) -> (Metrics, CriticalPath) {
        let mem = MemorySystem::new(d);
        let icn = Interconnect::new(d, self.spec.tp);
        let mut cp = CriticalPath::default();

        for (phase, ops) in [
            (Phase::Prefill, prefill_ops(&self.spec)),
            (Phase::Decode, decode_ops(&self.spec)),
        ] {
            for op in &ops {
                cp.ops.push(self.run_op(d, &mem, &icn, phase, op));
            }
        }

        let pf = cp.stall_stack(Phase::Prefill);
        let dc = cp.stall_stack(Phase::Decode);
        let metrics = Metrics {
            ttft_ms: cp.phase_total_s(Phase::Prefill) * 1e3,
            tpot_ms: cp.phase_total_s(Phase::Decode) * 1e3,
            area_mm2: area_mm2(d),
            stalls: [
                [pf[0] * 1e3, pf[1] * 1e3, pf[2] * 1e3],
                [dc[0] * 1e3, dc[1] * 1e3, dc[2] * 1e3],
            ],
        };
        (metrics, cp)
    }

    fn run_op(
        &self,
        d: &DesignPoint,
        mem: &MemorySystem,
        icn: &Interconnect,
        phase: Phase,
        op: &Op,
    ) -> OpRecord {
        match op.kind {
            OpKind::Matmul => self.run_matmul(d, mem, phase, op),
            OpKind::Vector => self.run_vector(d, mem, phase, op),
            OpKind::Comm => self.run_comm(mem, icn, phase, op),
        }
    }

    fn run_matmul(
        &self,
        d: &DesignPoint,
        mem: &MemorySystem,
        phase: Phase,
        op: &Op,
    ) -> OpRecord {
        let (m, n, k, count) =
            (op.m as f32, op.n as f32, op.k as f32, op.count as f32);

        // Memory side: weights stream from DRAM; activations get L2
        // reuse; decode attention reads the KV cache.
        let w_bytes = k * n * count * c::FP16_BYTES;
        let a_bytes = (m * k + m * n) * count * c::FP16_BYTES;
        let is_attention = op.name.starts_with("attn");
        let (w_class, a_ws) = if is_attention && phase == Phase::Decode {
            (TrafficClass::KvCache, a_bytes)
        } else {
            (TrafficClass::StreamingWeights, a_bytes)
        };
        // When the streamed operand is re-traversed per L2-sized block of
        // the other operand, charge an inflation factor.
        let resident = (m * k * c::FP16_BYTES).min(w_bytes);
        let inflation = if resident <= mem.l2_bytes { 1.0 } else { 1.6 };
        let mem_s = mem.service_s(w_class, w_bytes * inflation, w_bytes)
            + mem.service_s(TrafficClass::Activations, a_bytes, a_ws);

        // Compute side: effective staging bandwidth for the tiling model
        // is the blended service rate implied by the memory times.
        let total_bytes = w_bytes + a_bytes;
        let eff_bw = total_bytes / mem_s.max(1e-30);
        let map = map_matmul(d, m, n, k, count, eff_bw);

        let wall = map.wall_s() + LAUNCH_OVERHEAD_S;
        let stall = if map.memory_bound() {
            Bottleneck::Memory
        } else {
            Bottleneck::Compute
        };
        OpRecord {
            name: op.name,
            phase,
            wall_s: wall,
            stall,
            compute_s: map.compute_s,
            memory_s: mem_s,
            network_s: 0.0,
            utilization: map.utilization,
            latency_bound: false,
        }
    }

    fn run_vector(
        &self,
        d: &DesignPoint,
        mem: &MemorySystem,
        phase: Phase,
        op: &Op,
    ) -> OpRecord {
        let arrays =
            (d.get(Param::Cores) * d.get(Param::Sublanes)) as f32;
        let vecw = d.get(Param::VectorWidth) as f32;
        let v_peak = arrays * vecw * c::FLOPS_PER_LANE * c::CLOCK_HZ;
        // Occupancy: tiny element counts cannot fill every lane.
        let elems = (op.bytes as f32) / (2.0 * c::FP16_BYTES);
        let occupancy = (elems / (arrays * vecw * 4.0)).min(1.0).max(0.05);
        let compute_s = op.flops as f32 / (v_peak * occupancy);
        let mem_s = mem.service_s(
            TrafficClass::Activations,
            op.bytes as f32,
            op.bytes as f32,
        );
        let wall = compute_s.max(mem_s) + LAUNCH_OVERHEAD_S;
        let stall = if compute_s >= mem_s {
            Bottleneck::Compute
        } else {
            Bottleneck::Memory
        };
        OpRecord {
            name: op.name,
            phase,
            wall_s: wall,
            stall,
            compute_s,
            memory_s: mem_s,
            network_s: 0.0,
            utilization: occupancy,
            latency_bound: false,
        }
    }

    fn run_comm(
        &self,
        mem: &MemorySystem,
        icn: &Interconnect,
        phase: Phase,
        op: &Op,
    ) -> OpRecord {
        // Ring transport; payload also crosses HBM twice on each rank.
        let payload = op.comm_bytes as f32
            / (2.0 * (self.spec.tp as f32 - 1.0) / self.spec.tp as f32);
        let net_s = icn.allreduce_s(payload);
        let mem_s = mem.service_s(
            TrafficClass::Activations,
            op.bytes as f32,
            op.bytes as f32,
        );
        let wall = net_s.max(mem_s) + LAUNCH_OVERHEAD_S;
        let stall = if net_s >= mem_s {
            Bottleneck::Network
        } else {
            Bottleneck::Memory
        };
        OpRecord {
            name: op.name,
            phase,
            wall_s: wall,
            stall,
            compute_s: 0.0,
            memory_s: mem_s,
            network_s: net_s,
            utilization: 0.0,
            latency_bound: icn.latency_bound(payload),
        }
    }
}

impl Evaluator for CompassSim {
    fn eval_batch(&mut self, designs: &[DesignPoint]) -> Result<Vec<Metrics>> {
        Ok(designs
            .iter()
            .map(|d| self.evaluate_detailed(d).0)
            .collect())
    }

    fn name(&self) -> &'static str {
        "compass"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim() -> CompassSim {
        CompassSim::gpt3()
    }

    #[test]
    fn a100_magnitudes_are_plausible() {
        let (m, _) = sim().evaluate_detailed(&DesignPoint::a100());
        // One GPT-3 layer, prefill 8x2048 on 8 GPUs: tens of ms.
        assert!(m.ttft_ms > 5.0 && m.ttft_ms < 200.0, "{m:?}");
        // Decode step per layer: fraction of a ms.
        assert!(m.tpot_ms > 0.05 && m.tpot_ms < 5.0, "{m:?}");
        assert!((m.area_mm2 - 834.0).abs() < 20.0);
    }

    #[test]
    fn a100_phase_bottlenecks_match_expectation() {
        let (m, cp) = sim().evaluate_detailed(&DesignPoint::a100());
        assert_eq!(
            m.dominant_bottleneck(Phase::Prefill),
            Bottleneck::Compute,
            "{}",
            cp.render(Phase::Prefill)
        );
        assert_eq!(
            m.dominant_bottleneck(Phase::Decode),
            Bottleneck::Memory,
            "{}",
            cp.render(Phase::Decode)
        );
    }

    #[test]
    fn paper_designs_dominate_a100_under_compass_too() {
        let s = sim();
        let (a100, _) = s.evaluate_detailed(&DesignPoint::a100());
        for d in
            [DesignPoint::paper_design_a(), DesignPoint::paper_design_b()]
        {
            let (m, cp) = s.evaluate_detailed(&d);
            assert!(
                m.ttft_ms < a100.ttft_ms
                    && m.tpot_ms < a100.tpot_ms
                    && m.area_mm2 < a100.area_mm2,
                "{d}: {m:?}\n{}",
                cp.render(Phase::Prefill)
            );
        }
    }

    #[test]
    fn critical_path_covers_all_ops_and_sums() {
        let (m, cp) = sim().evaluate_detailed(&DesignPoint::a100());
        assert_eq!(cp.ops.len(), 24); // 12 prefill + 12 decode
        let pf = cp.phase_total_s(Phase::Prefill) * 1e3;
        assert!((pf - m.ttft_ms).abs() / m.ttft_ms < 1e-5);
    }

    #[test]
    fn decode_allreduce_is_latency_bound() {
        let (_, cp) = sim().evaluate_detailed(&DesignPoint::a100());
        let ar = cp
            .phase_ops(Phase::Decode)
            .find(|o| o.name == "allreduce_attn")
            .unwrap();
        assert!(ar.latency_bound);
    }

    #[test]
    fn more_memory_channels_cut_tpot() {
        let s = sim();
        let base = s.evaluate_detailed(&DesignPoint::a100()).0;
        let more = s
            .evaluate_detailed(
                &DesignPoint::a100().with(Param::MemChannels, 10),
            )
            .0;
        assert!(more.tpot_ms < base.tpot_ms * 0.8);
    }

    #[test]
    fn more_links_cut_ttft_but_not_tpot_much() {
        let s = sim();
        let base = s.evaluate_detailed(&DesignPoint::a100()).0;
        let more = s
            .evaluate_detailed(&DesignPoint::a100().with(Param::Links, 24))
            .0;
        assert!(more.ttft_ms < base.ttft_ms);
        let tpot_gain = (base.tpot_ms - more.tpot_ms) / base.tpot_ms;
        assert!(tpot_gain < 0.10, "tpot gain {tpot_gain}");
    }

    #[test]
    fn compass_differs_from_roofline_model() {
        // They are different fidelity models; identical outputs would
        // mean one is a copy of the other.
        use crate::sim::roofline::RooflineSim;
        let r = RooflineSim::new(GPT3_175B)
            .evaluate(&DesignPoint::a100());
        let (cm, _) = sim().evaluate_detailed(&DesignPoint::a100());
        // TTFT happens to agree closely on A100 (both compute-bound at
        // similar utilization); TPOT's richer memory model must not.
        let d_ttft = (r.ttft_ms - cm.ttft_ms).abs() / r.ttft_ms;
        let d_tpot = (r.tpot_ms - cm.tpot_ms).abs() / r.tpot_ms;
        assert!(
            d_ttft > 0.02 || d_tpot > 0.05,
            "models identical: dttft={d_ttft} dtpot={d_tpot}"
        );
    }
}
