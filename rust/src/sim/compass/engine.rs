//! The compass evaluation engine: walks the workload's operator list and
//! composes the tiling, memory and interconnect models into per-operator
//! wall times with stall attribution.
//!
//! The operator lists and every design-*independent* per-op quantity
//! (operand byte counts, traffic classes, ring payloads) are prepared
//! once in the constructor — mirroring `RooflineSim::op_table` — so the
//! per-design hot loop touches only the design-dependent models. The
//! arithmetic (expressions and evaluation order) is kept identical to
//! the historical per-evaluation construction, so results are
//! bit-identical to the pre-hoisting engine.

use crate::arch::{area_mm2, constants as c, EnergyBreakdown};
use crate::design::{DesignPoint, Param};
use crate::eval::{
    with_caller_scratch, Bottleneck, EvalOne, EvalScratch, Evaluator,
    Metrics, Phase, SOA_LANES,
};
use crate::workload::{
    decode_ops, default_scenario, prefill_ops, Op, OpKind, WorkloadSpec,
};
use crate::Result;

use super::critical_path::{CriticalPath, OpRecord};
use super::interconnect::Interconnect;
use super::memory::{MemorySystem, TrafficClass};
use super::tiles::map_matmul;

/// Per-operator launch/dispatch overhead in the detailed model (larger
/// than the roofline's: includes kernel argument setup and wave ramp-up).
/// Public so the stall/energy accounting invariant tests can subtract it
/// from per-op wall times.
pub const LAUNCH_OVERHEAD_S: f32 = 3.0e-6;

/// Design-independent invariants of one operator, hoisted out of the
/// per-design evaluation loop.
#[derive(Debug, Clone, Copy)]
enum Prepped {
    Matmul {
        m: f32,
        n: f32,
        k: f32,
        count: f32,
        /// Total MAC work: `2 * m * n * k * count` FLOPs (hoisted for
        /// the per-op energy attribution).
        flops: f32,
        /// Streamed (weight-side) bytes: `k * n * count` in fp16.
        w_bytes: f32,
        /// Activation bytes: `(m*k + m*n) * count` in fp16.
        a_bytes: f32,
        /// Bytes that must stay L2-resident for single-pass streaming.
        resident: f32,
        /// Decode attention reads the KV cache; everything else streams
        /// weights.
        w_class: TrafficClass,
    },
    Vector {
        flops: f32,
        bytes: f32,
        elems: f32,
    },
    Comm {
        /// Raw payload implied by the ring transport factor.
        payload: f32,
        bytes: f32,
    },
}

/// One operator with its phase and precomputed invariants.
#[derive(Debug, Clone, Copy)]
struct PreppedOp {
    name: &'static str,
    phase: Phase,
    prep: Prepped,
}

impl PreppedOp {
    fn new(spec: &WorkloadSpec, phase: Phase, op: &Op) -> PreppedOp {
        let prep = match op.kind {
            OpKind::Matmul => {
                let (m, n, k, count) = (
                    op.m as f32,
                    op.n as f32,
                    op.k as f32,
                    op.count as f32,
                );
                let w_bytes = k * n * count * c::FP16_BYTES;
                let a_bytes = (m * k + m * n) * count * c::FP16_BYTES;
                let is_attention = op.name.starts_with("attn");
                let w_class = if is_attention && phase == Phase::Decode {
                    TrafficClass::KvCache
                } else {
                    TrafficClass::StreamingWeights
                };
                let resident = (m * k * c::FP16_BYTES).min(w_bytes);
                let flops = 2.0 * m * n * k * count;
                Prepped::Matmul {
                    m,
                    n,
                    k,
                    count,
                    flops,
                    w_bytes,
                    a_bytes,
                    resident,
                    w_class,
                }
            }
            OpKind::Vector => Prepped::Vector {
                flops: op.flops as f32,
                bytes: op.bytes as f32,
                elems: (op.bytes as f32) / (2.0 * c::FP16_BYTES),
            },
            OpKind::Comm => Prepped::Comm {
                payload: op.comm_bytes as f32
                    / (2.0 * (spec.tp as f32 - 1.0) / spec.tp as f32),
                bytes: op.bytes as f32,
            },
        };
        PreppedOp { name: op.name, phase, prep }
    }
}

/// Per-op dynamic energy components, joules. The **single** pricing
/// implementation: the hot path sums it into [`OpRecord::energy_j`]
/// (via `run_op`) and the report-path [`CompassSim::energy_breakdown`]
/// aggregates the same components, so the two can never drift.
struct OpEnergy {
    compute: f32,
    sram: f32,
    hbm: f32,
    l2: f32,
    link: f32,
}

impl OpEnergy {
    fn total(&self) -> f32 {
        self.compute + self.sram + self.hbm + self.l2 + self.link
    }
}

/// Price one operator's dynamic energy from its hoisted invariants and
/// the design's memory/interconnect models (the same hit split and
/// inflation factor the timing model charges).
fn op_energy(
    prep: &Prepped,
    mem: &MemorySystem,
    icn: &Interconnect,
) -> OpEnergy {
    match *prep {
        Prepped::Matmul {
            flops,
            w_bytes,
            a_bytes,
            resident,
            w_class,
            ..
        } => {
            let inflation =
                if resident <= mem.l2_bytes { 1.0 } else { 1.6 };
            let (w_hbm, w_l2) = mem.energy_split_j(
                w_class,
                w_bytes * inflation,
                w_bytes,
            );
            let (a_hbm, a_l2) = mem.energy_split_j(
                TrafficClass::Activations,
                a_bytes,
                a_bytes,
            );
            OpEnergy {
                compute: flops * c::E_J_PER_FLOP_SYSTOLIC,
                sram: flops
                    * c::SRAM_BYTES_PER_FLOP
                    * c::E_J_PER_BYTE_SRAM,
                hbm: w_hbm + a_hbm,
                l2: w_l2 + a_l2,
                link: 0.0,
            }
        }
        Prepped::Vector { flops, bytes, .. } => {
            let (hbm, l2) = mem.energy_split_j(
                TrafficClass::Activations,
                bytes,
                bytes,
            );
            OpEnergy {
                compute: flops * c::E_J_PER_FLOP_VECTOR,
                sram: 0.0,
                hbm,
                l2,
                link: 0.0,
            }
        }
        Prepped::Comm { payload, bytes } => {
            let (hbm, l2) = mem.energy_split_j(
                TrafficClass::Activations,
                bytes,
                bytes,
            );
            OpEnergy {
                compute: 0.0,
                sram: 0.0,
                hbm,
                l2,
                link: icn.allreduce_energy_j(payload),
            }
        }
    }
}

/// The detailed simulator.
#[derive(Debug, Clone)]
pub struct CompassSim {
    /// Private: `prepped` is derived from the spec in the constructor,
    /// so the spec must not change underneath it (build a new sim for a
    /// new workload).
    spec: WorkloadSpec,
    /// Prefill then decode operators, in execution order.
    prepped: Vec<PreppedOp>,
}

impl CompassSim {
    pub fn new(spec: WorkloadSpec) -> Self {
        let mut prepped = Vec::new();
        for (phase, ops) in [
            (Phase::Prefill, prefill_ops(&spec)),
            (Phase::Decode, decode_ops(&spec)),
        ] {
            for op in &ops {
                prepped.push(PreppedOp::new(&spec, phase, op));
            }
        }
        Self { spec, prepped }
    }

    /// Convenience constructor for the default registry scenario (the
    /// paper's GPT-3 175B setup).
    pub fn gpt3() -> Self {
        Self::new(default_scenario().spec)
    }

    /// The workload this simulator was built for.
    pub fn spec(&self) -> &WorkloadSpec {
        &self.spec
    }

    /// Evaluate one design, returning metrics plus the full critical-path
    /// report (the paper's extended-LLMCompass output).
    pub fn evaluate_detailed(
        &self,
        d: &DesignPoint,
    ) -> (Metrics, CriticalPath) {
        let mem = MemorySystem::new(d);
        let icn = Interconnect::new(d, self.spec.tp);
        let mut cp = CriticalPath::default();

        for op in &self.prepped {
            cp.ops.push(self.run_op(d, &mem, &icn, op));
        }

        let pf = cp.stall_stack(Phase::Prefill);
        let dc = cp.stall_stack(Phase::Decode);
        let area = area_mm2(d);
        let ttft_ms = cp.phase_total_s(Phase::Prefill) * 1e3;
        let tpot_ms = cp.phase_total_s(Phase::Decode) * 1e3;
        // Phase energy = per-op dynamic attributions + area-proportional
        // leakage over the phase wall time (W * ms = mJ).
        let prefill_energy_mj = cp.phase_energy_j(Phase::Prefill) * 1e3
            + c::LEAKAGE_W_PER_MM2 * area * ttft_ms;
        let energy_per_token_mj = cp.phase_energy_j(Phase::Decode) * 1e3
            + c::LEAKAGE_W_PER_MM2 * area * tpot_ms;
        let metrics = Metrics {
            ttft_ms,
            tpot_ms,
            area_mm2: area,
            energy_per_token_mj,
            prefill_energy_mj,
            avg_power_w: crate::arch::power::avg_power_w(
                prefill_energy_mj,
                energy_per_token_mj,
                ttft_ms,
                tpot_ms,
            ),
            stalls: [
                [pf[0] * 1e3, pf[1] * 1e3, pf[2] * 1e3],
                [dc[0] * 1e3, dc[1] * 1e3, dc[2] * 1e3],
            ],
        };
        (metrics, cp)
    }

    /// Evaluate a batch with the structure-of-arrays kernel: **one**
    /// walk of the prepped op table per batch (not per design), with
    /// the design-dependent model scalars laid out across designs in
    /// the caller's [`EvalScratch`] arena and the design-inner loop
    /// windowed over `[f32; L]` lanes so one op kind's code path runs
    /// back-to-back over all designs and auto-vectorizes where the
    /// models allow.
    ///
    /// Bit-identity: every per-design quantity is produced by the same
    /// functions (`run_matmul` / `run_vector` / `run_comm` /
    /// `op_energy`) in the same per-design accumulation order as
    /// [`CompassSim::evaluate_detailed`] — ops in table order, phase
    /// totals / stall buckets / energies summed op-by-op — so results
    /// equal `eval_one` bitwise (asserted per scenario and across lane
    /// widths in `tests/soa_pool.rs`). What the batch form *removes*
    /// is the per-design `CriticalPath` allocation and the six
    /// summation re-passes over its records.
    pub fn eval_batch_soa(&self, designs: &[DesignPoint]) -> Vec<Metrics> {
        let mut out = vec![Metrics::default(); designs.len()];
        with_caller_scratch(|s| self.eval_soa_into(designs, &mut out, s));
        out
    }

    /// [`CompassSim::eval_batch_soa`] writing into a caller buffer (the
    /// pool-worker chunk path), carving all model/accumulator lanes out
    /// of the reusable `scratch` arena — zero heap allocations once the
    /// arena is warm.
    pub fn eval_soa_into(
        &self,
        designs: &[DesignPoint],
        out: &mut [Metrics],
        scratch: &mut EvalScratch,
    ) {
        self.eval_soa_into_lanes::<SOA_LANES>(designs, out, scratch);
    }

    /// The SoA kernel at an explicit lane width `L`. Lane math is
    /// elementwise, so every width produces bitwise-identical results;
    /// the remainder (`n % L` designs) runs through the same window
    /// body at `L = 1`.
    pub fn eval_soa_into_lanes<const L: usize>(
        &self,
        designs: &[DesignPoint],
        out: &mut [Metrics],
        scratch: &mut EvalScratch,
    ) {
        assert!(L > 0, "lane width must be positive");
        debug_assert_eq!(designs.len(), out.len());
        let n = designs.len();
        if n == 0 {
            return;
        }
        // 14 lanes: 4 per-design model scalars (the `Copy` fields of
        // `MemorySystem` / `Interconnect`, rebuilt per lane window) +
        // 2 phases x (wall time, 3 stall buckets, energy) accumulators.
        let [
            hbm_bw, l2_bytes, l2_bw, icn_bw, wall0, wall1, st00, st01,
            st02, st10, st11, st12, en0, en1,
        ] = scratch.lanes::<14>(n);
        for (j, d) in designs.iter().enumerate() {
            let mem = MemorySystem::new(d);
            hbm_bw[j] = mem.hbm_bw;
            l2_bytes[j] = mem.l2_bytes;
            l2_bw[j] = mem.l2_bw;
            icn_bw[j] = Interconnect::new(d, self.spec.tp).bw;
        }
        {
            let mut phases = [
                (
                    &mut *wall0,
                    [&mut *st00, &mut *st01, &mut *st02],
                    &mut *en0,
                ),
                (
                    &mut *wall1,
                    [&mut *st10, &mut *st11, &mut *st12],
                    &mut *en1,
                ),
            ];
            for op in &self.prepped {
                let p = op.phase.index();
                let (pt, st, en) = &mut phases[p];
                let [s0, s1, s2] = st;
                let mut i = 0;
                while i + L <= n {
                    self.op_window::<L>(
                        i, op, designs, hbm_bw, l2_bytes, l2_bw,
                        icn_bw, pt, s0, s1, s2, en,
                    );
                    i += L;
                }
                while i < n {
                    self.op_window::<1>(
                        i, op, designs, hbm_bw, l2_bytes, l2_bw,
                        icn_bw, pt, s0, s1, s2, en,
                    );
                    i += 1;
                }
            }
        }
        // Assembly: the exact tail expressions of `evaluate_detailed`.
        for (j, (d, slot)) in
            designs.iter().zip(out.iter_mut()).enumerate()
        {
            let area = area_mm2(d);
            let ttft_ms = wall0[j] * 1e3;
            let tpot_ms = wall1[j] * 1e3;
            let prefill_energy_mj =
                en0[j] * 1e3 + c::LEAKAGE_W_PER_MM2 * area * ttft_ms;
            let energy_per_token_mj =
                en1[j] * 1e3 + c::LEAKAGE_W_PER_MM2 * area * tpot_ms;
            *slot = Metrics {
                ttft_ms,
                tpot_ms,
                area_mm2: area,
                energy_per_token_mj,
                prefill_energy_mj,
                avg_power_w: crate::arch::power::avg_power_w(
                    prefill_energy_mj,
                    energy_per_token_mj,
                    ttft_ms,
                    tpot_ms,
                ),
                stalls: [
                    [st00[j] * 1e3, st01[j] * 1e3, st02[j] * 1e3],
                    [st10[j] * 1e3, st11[j] * 1e3, st12[j] * 1e3],
                ],
            };
        }
    }

    /// One lane window of the op walk: evaluate designs `i..i + L`
    /// against one prepped op through the exact `run_*` / `op_energy`
    /// record construction of `run_op` (models rebuilt per lane from
    /// their SoA scalar fields — `Copy` structs, so identical by
    /// construction), staging per-lane wall times, stall buckets and
    /// energies, then accumulating with branch-free selects. The
    /// select form `acc += if hit { w } else { 0.0 }` equals the
    /// scalar `if hit { acc += w }` bitwise because accumulators start
    /// at `+0.0` and only ever add non-negative wall times.
    #[allow(clippy::too_many_arguments)]
    #[inline(always)]
    fn op_window<const L: usize>(
        &self,
        i: usize,
        op: &PreppedOp,
        designs: &[DesignPoint],
        hbm_bw: &[f32],
        l2_bytes: &[f32],
        l2_bw: &[f32],
        icn_bw: &[f32],
        pt: &mut [f32],
        st0: &mut [f32],
        st1: &mut [f32],
        st2: &mut [f32],
        en: &mut [f32],
    ) {
        let mut wall = [0f32; L];
        let mut bucket = [0usize; L];
        let mut e_tot = [0f32; L];
        for l in 0..L {
            let j = i + l;
            let mem = MemorySystem {
                hbm_bw: hbm_bw[j],
                l2_bytes: l2_bytes[j],
                l2_bw: l2_bw[j],
            };
            let icn = Interconnect {
                bw: icn_bw[j],
                hop_latency: 1.0e-6,
                tp: self.spec.tp as f32,
            };
            // The op-kind branch predicts perfectly inside a window
            // (it is constant per op).
            let rec = match op.prep {
                Prepped::Matmul { .. } => {
                    self.run_matmul(&designs[j], &mem, op)
                }
                Prepped::Vector { .. } => {
                    self.run_vector(&designs[j], &mem, op)
                }
                Prepped::Comm { .. } => self.run_comm(&mem, &icn, op),
            };
            wall[l] = rec.wall_s;
            bucket[l] = rec.stall.index();
            e_tot[l] = op_energy(&op.prep, &mem, &icn).total();
        }
        for l in 0..L {
            let j = i + l;
            let w = wall[l];
            pt[j] += w;
            st0[j] += if bucket[l] == 0 { w } else { 0.0 };
            st1[j] += if bucket[l] == 1 { w } else { 0.0 };
            st2[j] += if bucket[l] == 2 { w } else { 0.0 };
            en[j] += e_tot[l];
        }
    }

    /// Component-wise energy attribution of one phase — the PPA report
    /// path (Table 4 / `lumina eval`), not the hot loop. The totals
    /// match the per-op accounting of [`CompassSim::evaluate_detailed`]:
    /// `breakdown.total_mj() == Metrics::phase_energy_mj(phase)` up to
    /// f32 accumulation order.
    pub fn energy_breakdown(
        &self,
        d: &DesignPoint,
        phase: Phase,
    ) -> EnergyBreakdown {
        let mem = MemorySystem::new(d);
        let icn = Interconnect::new(d, self.spec.tp);
        let mut out = EnergyBreakdown::default();
        let mut phase_s = 0f32;
        for op in self.prepped.iter().filter(|o| o.phase == phase) {
            let e = op_energy(&op.prep, &mem, &icn);
            out.compute_mj += e.compute * 1e3;
            out.sram_mj += e.sram * 1e3;
            out.hbm_mj += e.hbm * 1e3;
            out.l2_mj += e.l2 * 1e3;
            out.link_mj += e.link * 1e3;
            // Timing dispatch only (the energy above is already
            // priced; `run_op` would price it a second time).
            let rec = match op.prep {
                Prepped::Matmul { .. } => self.run_matmul(d, &mem, op),
                Prepped::Vector { .. } => self.run_vector(d, &mem, op),
                Prepped::Comm { .. } => self.run_comm(&mem, &icn, op),
            };
            phase_s += rec.wall_s;
        }
        out.leakage_mj +=
            c::LEAKAGE_W_PER_MM2 * area_mm2(d) * phase_s * 1e3;
        out
    }

    fn run_op(
        &self,
        d: &DesignPoint,
        mem: &MemorySystem,
        icn: &Interconnect,
        op: &PreppedOp,
    ) -> OpRecord {
        let mut rec = match op.prep {
            Prepped::Matmul { .. } => self.run_matmul(d, mem, op),
            Prepped::Vector { .. } => self.run_vector(d, mem, op),
            Prepped::Comm { .. } => self.run_comm(mem, icn, op),
        };
        rec.energy_j = op_energy(&op.prep, mem, icn).total();
        rec
    }

    fn run_matmul(
        &self,
        d: &DesignPoint,
        mem: &MemorySystem,
        op: &PreppedOp,
    ) -> OpRecord {
        let Prepped::Matmul {
            m,
            n,
            k,
            count,
            w_bytes,
            a_bytes,
            resident,
            w_class,
            ..
        } = op.prep
        else {
            unreachable!("run_matmul on non-matmul op")
        };

        // Memory side: weights stream from DRAM; activations get L2
        // reuse; decode attention reads the KV cache. When the streamed
        // operand is re-traversed per L2-sized block of the other
        // operand, charge an inflation factor.
        let inflation = if resident <= mem.l2_bytes { 1.0 } else { 1.6 };
        let mem_s = mem.service_s(w_class, w_bytes * inflation, w_bytes)
            + mem.service_s(TrafficClass::Activations, a_bytes, a_bytes);

        // Compute side: effective staging bandwidth for the tiling model
        // is the blended service rate implied by the memory times.
        let total_bytes = w_bytes + a_bytes;
        let eff_bw = total_bytes / mem_s.max(1e-30);
        let map = map_matmul(d, m, n, k, count, eff_bw);

        let wall = map.wall_s() + LAUNCH_OVERHEAD_S;
        let stall = if map.memory_bound() {
            Bottleneck::Memory
        } else {
            Bottleneck::Compute
        };
        // energy_j is attributed by `run_op` through the shared
        // `op_energy` pricing.
        OpRecord {
            name: op.name,
            phase: op.phase,
            wall_s: wall,
            stall,
            compute_s: map.compute_s,
            memory_s: mem_s,
            network_s: 0.0,
            energy_j: 0.0,
            utilization: map.utilization,
            latency_bound: false,
        }
    }

    fn run_vector(
        &self,
        d: &DesignPoint,
        mem: &MemorySystem,
        op: &PreppedOp,
    ) -> OpRecord {
        let Prepped::Vector { flops, bytes, elems } = op.prep else {
            unreachable!("run_vector on non-vector op")
        };
        let arrays =
            (d.get(Param::Cores) * d.get(Param::Sublanes)) as f32;
        let vecw = d.get(Param::VectorWidth) as f32;
        let v_peak = arrays * vecw * c::FLOPS_PER_LANE * c::CLOCK_HZ;
        // Occupancy: tiny element counts cannot fill every lane.
        let occupancy = (elems / (arrays * vecw * 4.0)).min(1.0).max(0.05);
        let compute_s = flops / (v_peak * occupancy);
        let mem_s =
            mem.service_s(TrafficClass::Activations, bytes, bytes);
        let wall = compute_s.max(mem_s) + LAUNCH_OVERHEAD_S;
        let stall = if compute_s >= mem_s {
            Bottleneck::Compute
        } else {
            Bottleneck::Memory
        };
        OpRecord {
            name: op.name,
            phase: op.phase,
            wall_s: wall,
            stall,
            compute_s,
            memory_s: mem_s,
            network_s: 0.0,
            energy_j: 0.0,
            utilization: occupancy,
            latency_bound: false,
        }
    }

    fn run_comm(
        &self,
        mem: &MemorySystem,
        icn: &Interconnect,
        op: &PreppedOp,
    ) -> OpRecord {
        let Prepped::Comm { payload, bytes } = op.prep else {
            unreachable!("run_comm on non-comm op")
        };
        // Ring transport; payload also crosses HBM twice on each rank.
        let net_s = icn.allreduce_s(payload);
        let mem_s =
            mem.service_s(TrafficClass::Activations, bytes, bytes);
        let wall = net_s.max(mem_s) + LAUNCH_OVERHEAD_S;
        let stall = if net_s >= mem_s {
            Bottleneck::Network
        } else {
            Bottleneck::Memory
        };
        OpRecord {
            name: op.name,
            phase: op.phase,
            wall_s: wall,
            stall,
            compute_s: 0.0,
            memory_s: mem_s,
            network_s: net_s,
            energy_j: 0.0,
            utilization: 0.0,
            latency_bound: icn.latency_bound(payload),
        }
    }
}

impl EvalOne for CompassSim {
    fn eval_one(&self, d: &DesignPoint) -> Metrics {
        self.evaluate_detailed(d).0
    }

    fn label(&self) -> &'static str {
        "compass"
    }

    fn workload_fingerprint(&self) -> u64 {
        self.spec.fingerprint()
    }

    fn eval_chunk(
        &self,
        designs: &[DesignPoint],
        out: &mut [Metrics],
        scratch: &mut EvalScratch,
    ) {
        self.eval_soa_into(designs, out, scratch);
    }
}

impl Evaluator for CompassSim {
    fn eval_batch(&mut self, designs: &[DesignPoint]) -> Result<Vec<Metrics>> {
        Ok(self.eval_batch_soa(designs))
    }

    fn name(&self) -> &'static str {
        "compass"
    }

    fn workload_fingerprint(&self) -> u64 {
        self.spec.fingerprint()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim() -> CompassSim {
        CompassSim::gpt3()
    }

    #[test]
    fn a100_magnitudes_are_plausible() {
        let (m, _) = sim().evaluate_detailed(&DesignPoint::a100());
        // One GPT-3 layer, prefill 8x2048 on 8 GPUs: tens of ms.
        assert!(m.ttft_ms > 5.0 && m.ttft_ms < 200.0, "{m:?}");
        // Decode step per layer: fraction of a ms.
        assert!(m.tpot_ms > 0.05 && m.tpot_ms < 5.0, "{m:?}");
        assert!((m.area_mm2 - 834.0).abs() < 20.0);
    }

    #[test]
    fn a100_phase_bottlenecks_match_expectation() {
        let (m, cp) = sim().evaluate_detailed(&DesignPoint::a100());
        assert_eq!(
            m.dominant_bottleneck(Phase::Prefill),
            Bottleneck::Compute,
            "{}",
            cp.render(Phase::Prefill)
        );
        assert_eq!(
            m.dominant_bottleneck(Phase::Decode),
            Bottleneck::Memory,
            "{}",
            cp.render(Phase::Decode)
        );
    }

    #[test]
    fn paper_designs_dominate_a100_under_compass_too() {
        let s = sim();
        let (a100, _) = s.evaluate_detailed(&DesignPoint::a100());
        for d in
            [DesignPoint::paper_design_a(), DesignPoint::paper_design_b()]
        {
            let (m, cp) = s.evaluate_detailed(&d);
            assert!(
                m.ttft_ms < a100.ttft_ms
                    && m.tpot_ms < a100.tpot_ms
                    && m.area_mm2 < a100.area_mm2,
                "{d}: {m:?}\n{}",
                cp.render(Phase::Prefill)
            );
        }
    }

    #[test]
    fn critical_path_covers_all_ops_and_sums() {
        let (m, cp) = sim().evaluate_detailed(&DesignPoint::a100());
        assert_eq!(cp.ops.len(), 24); // 12 prefill + 12 decode
        let pf = cp.phase_total_s(Phase::Prefill) * 1e3;
        assert!((pf - m.ttft_ms).abs() / m.ttft_ms < 1e-5);
    }

    #[test]
    fn decode_allreduce_is_latency_bound() {
        let (_, cp) = sim().evaluate_detailed(&DesignPoint::a100());
        let ar = cp
            .phase_ops(Phase::Decode)
            .find(|o| o.name == "allreduce_attn")
            .unwrap();
        assert!(ar.latency_bound);
    }

    #[test]
    fn more_memory_channels_cut_tpot() {
        let s = sim();
        let base = s.evaluate_detailed(&DesignPoint::a100()).0;
        let more = s
            .evaluate_detailed(
                &DesignPoint::a100().with(Param::MemChannels, 10),
            )
            .0;
        assert!(more.tpot_ms < base.tpot_ms * 0.8);
    }

    #[test]
    fn more_links_cut_ttft_but_not_tpot_much() {
        let s = sim();
        let base = s.evaluate_detailed(&DesignPoint::a100()).0;
        let more = s
            .evaluate_detailed(&DesignPoint::a100().with(Param::Links, 24))
            .0;
        assert!(more.ttft_ms < base.ttft_ms);
        let tpot_gain = (base.tpot_ms - more.tpot_ms) / base.tpot_ms;
        assert!(tpot_gain < 0.10, "tpot gain {tpot_gain}");
    }

    #[test]
    fn hoisted_op_prep_matches_direct_construction() {
        // The constructor-prepared invariants must equal what the
        // historical per-evaluation path computed from the raw op list.
        let s = sim();
        let ops = prefill_ops(&s.spec);
        assert_eq!(s.prepped.len(), ops.len() + decode_ops(&s.spec).len());
        for (p, op) in s.prepped.iter().zip(&ops) {
            assert_eq!(p.name, op.name);
            assert_eq!(p.phase, Phase::Prefill);
            if let Prepped::Matmul { w_bytes, a_bytes, .. } = p.prep {
                let k = op.k as f32;
                let n = op.n as f32;
                let m = op.m as f32;
                let count = op.count as f32;
                assert_eq!(w_bytes, k * n * count * c::FP16_BYTES);
                assert_eq!(
                    a_bytes,
                    (m * k + m * n) * count * c::FP16_BYTES
                );
            }
        }
        // Decode attention reads the KV cache; prefill attention streams.
        let kv_ops: Vec<&PreppedOp> = s
            .prepped
            .iter()
            .filter(|p| {
                matches!(
                    p.prep,
                    Prepped::Matmul {
                        w_class: TrafficClass::KvCache,
                        ..
                    }
                )
            })
            .collect();
        assert!(!kv_ops.is_empty());
        assert!(kv_ops
            .iter()
            .all(|p| p.phase == Phase::Decode
                && p.name.starts_with("attn")));
    }

    #[test]
    fn per_op_energies_sum_to_phase_energy() {
        // The satellite accounting invariant: per-op dynamic energies
        // plus the phase-level leakage reproduce the Metrics energy
        // fields exactly (up to f32 accumulation).
        let s = sim();
        for d in [
            DesignPoint::a100(),
            DesignPoint::paper_design_a(),
            DesignPoint::new([6, 1, 1, 4, 4, 32, 32, 1]),
        ] {
            let (m, cp) = s.evaluate_detailed(&d);
            for phase in Phase::ALL {
                let dynamic_mj = cp.phase_energy_j(phase) * 1e3;
                let leak_mj = c::LEAKAGE_W_PER_MM2
                    * m.area_mm2
                    * m.phase_time_ms(phase);
                let want = dynamic_mj + leak_mj;
                let got = m.phase_energy_mj(phase);
                assert!(
                    (got - want).abs() / want.max(1e-6) < 1e-5,
                    "{d} {phase:?}: {got} vs {want}"
                );
                assert!(cp
                    .phase_ops(phase)
                    .all(|o| o.energy_j > 0.0));
            }
            assert_eq!(
                m.avg_power_w,
                crate::arch::power::avg_power_w(
                    m.prefill_energy_mj,
                    m.energy_per_token_mj,
                    m.ttft_ms,
                    m.tpot_ms
                )
            );
        }
    }

    #[test]
    fn per_op_stall_components_sum_to_wall_minus_launch_overhead() {
        // Each op's wall time decomposes into its winning candidate
        // component plus the fixed launch overhead; summed per phase,
        // the stall stack reproduces the phase wall time exactly.
        let s = sim();
        let (m, cp) = s.evaluate_detailed(&DesignPoint::a100());
        for phase in Phase::ALL {
            let n_ops = cp.phase_ops(phase).count() as f32;
            let stack: f32 = cp.stall_stack(phase).iter().sum();
            let total = cp.phase_total_s(phase);
            assert!((stack - total).abs() / total < 1e-5);
            assert!(
                (total * 1e3 - m.phase_time_ms(phase)).abs()
                    / m.phase_time_ms(phase)
                    < 1e-5
            );
            // Work time (wall minus launch overhead) is at least the
            // largest candidate component of every op, with equality
            // for the overlap-free vector/comm paths.
            let mut work = 0f32;
            for op in cp.phase_ops(phase) {
                let t = op.wall_s - LAUNCH_OVERHEAD_S;
                assert!(t > 0.0, "{}", op.name);
                let cand = op
                    .compute_s
                    .max(op.memory_s)
                    .max(op.network_s);
                if op.compute_s == 0.0 {
                    // Comm ops: wall = max(candidates) + launch.
                    assert!(
                        (t - cand).abs() / cand < 1e-5,
                        "{}: {t} vs {cand}",
                        op.name
                    );
                }
                work += t;
            }
            let want = total - n_ops * LAUNCH_OVERHEAD_S;
            assert!((work - want).abs() / want < 1e-4);
        }
    }

    #[test]
    fn energy_breakdown_matches_per_op_accounting() {
        let s = sim();
        let (m, _) = s.evaluate_detailed(&DesignPoint::a100());
        for phase in Phase::ALL {
            let b = s.energy_breakdown(&DesignPoint::a100(), phase);
            let want = m.phase_energy_mj(phase);
            assert!(
                (b.total_mj() - want).abs() / want < 1e-4,
                "{phase:?}: breakdown {} vs metrics {want}",
                b.total_mj()
            );
            assert!(b.compute_mj > 0.0 && b.hbm_mj > 0.0);
            assert!(b.leakage_mj > 0.0);
        }
        // Decode is traffic-dominated: HBM energy beats MAC energy.
        let dc = s.energy_breakdown(&DesignPoint::a100(), Phase::Decode);
        assert!(dc.hbm_mj > dc.compute_mj, "{dc:?}");
    }

    #[test]
    fn soa_batch_is_bitwise_identical_to_eval_one() {
        let s = sim();
        let designs = [
            DesignPoint::a100(),
            DesignPoint::paper_design_a(),
            DesignPoint::paper_design_b(),
            DesignPoint::new([6, 1, 1, 4, 4, 32, 32, 1]),
            DesignPoint::new([24, 256, 8, 128, 128, 1024, 1024, 12]),
        ];
        let soa = s.eval_batch_soa(&designs);
        for (d, got) in designs.iter().zip(&soa) {
            assert_eq!(*got, s.eval_one(d), "{d}");
        }
        // Chunk form writes through the same kernel.
        let mut out = vec![Metrics::default(); designs.len()];
        s.eval_chunk(&designs, &mut out, &mut EvalScratch::new());
        assert_eq!(out, soa);
        assert!(s.eval_batch_soa(&[]).is_empty());
    }

    #[test]
    fn compass_differs_from_roofline_model() {
        // They are different fidelity models; identical outputs would
        // mean one is a copy of the other.
        use crate::sim::roofline::RooflineSim;
        use crate::workload::GPT3_175B;
        let r = RooflineSim::new(GPT3_175B)
            .evaluate(&DesignPoint::a100());
        let (cm, _) = sim().evaluate_detailed(&DesignPoint::a100());
        // TTFT happens to agree closely on A100 (both compute-bound at
        // similar utilization); TPOT's richer memory model must not.
        let d_ttft = (r.ttft_ms - cm.ttft_ms).abs() / r.ttft_ms;
        let d_tpot = (r.tpot_ms - cm.tpot_ms).abs() / r.tpot_ms;
        assert!(
            d_ttft > 0.02 || d_tpot > 0.05,
            "models identical: dttft={d_ttft} dtpot={d_tpot}"
        );
    }
}
