//! Critical-path report: per-operator timing with stall attribution.
//!
//! This is the structured feedback the paper's extended LLMCompass emits
//! ("we extended LLMCompass to include critical path analysis, enabling
//! identification of dominant stalls for both TTFT and TPOT") and what the
//! Strategy Engine's bottleneck analysis consumes — rendered into the LLM
//! prompt verbatim by `llm::prompts`.

use crate::eval::{Bottleneck, Phase};

/// Timing record for one operator on a phase's execution path.
#[derive(Debug, Clone)]
pub struct OpRecord {
    pub name: &'static str,
    pub phase: Phase,
    /// Wall time, seconds.
    pub wall_s: f32,
    /// Which component the wall time is attributed to.
    pub stall: Bottleneck,
    /// Compute / memory / network candidate times (s) before max().
    pub compute_s: f32,
    pub memory_s: f32,
    pub network_s: f32,
    /// Dynamic energy attributed to this operator, joules (compute +
    /// SRAM staging + memory traffic + link traffic; leakage is
    /// phase-level, see [`CriticalPath::phase_energy_j`]'s caller).
    pub energy_j: f32,
    /// PE-grid utilization if this was a tensor op, else 0.
    pub utilization: f32,
    /// For network ops: latency-bound collectives can't be fixed with
    /// more links.
    pub latency_bound: bool,
}

/// Full per-design critical-path analysis.
#[derive(Debug, Clone, Default)]
pub struct CriticalPath {
    pub ops: Vec<OpRecord>,
}

impl CriticalPath {
    pub fn phase_ops(&self, phase: Phase) -> impl Iterator<Item = &OpRecord> {
        self.ops.iter().filter(move |o| o.phase == phase)
    }

    /// Total wall time of a phase, seconds.
    pub fn phase_total_s(&self, phase: Phase) -> f32 {
        self.phase_ops(phase).map(|o| o.wall_s).sum()
    }

    /// Total dynamic energy of a phase, joules (sum of the per-op
    /// attributions; the engine adds area-proportional leakage on top
    /// when it assembles `Metrics`).
    pub fn phase_energy_j(&self, phase: Phase) -> f32 {
        self.phase_ops(phase).map(|o| o.energy_j).sum()
    }

    /// Stall stack of a phase: seconds per component.
    pub fn stall_stack(&self, phase: Phase) -> [f32; 3] {
        let mut s = [0f32; 3];
        for op in self.phase_ops(phase) {
            s[op.stall.index()] += op.wall_s;
        }
        s
    }

    /// The single operator contributing the most time to the phase.
    pub fn dominant_op(&self, phase: Phase) -> Option<&OpRecord> {
        self.phase_ops(phase)
            .max_by(|a, b| a.wall_s.total_cmp(&b.wall_s))
    }

    /// The dominant stall component of a phase.
    pub fn dominant_stall(&self, phase: Phase) -> Bottleneck {
        let s = self.stall_stack(phase);
        let mut best = Bottleneck::Compute;
        for b in Bottleneck::ALL {
            if s[b.index()] > s[best.index()] {
                best = b;
            }
        }
        best
    }

    /// Render a compact textual report (used inside LLM prompts and the
    /// CLI `explore --verbose` output).
    pub fn render(&self, phase: Phase) -> String {
        let mut out = String::new();
        let total = self.phase_total_s(phase).max(1e-30);
        out.push_str(&format!(
            "critical path [{}] total={:.4} ms, dominant stall: {}\n",
            phase.metric_name(),
            total * 1e3,
            self.dominant_stall(phase)
        ));
        for op in self.phase_ops(phase) {
            out.push_str(&format!(
                "  {:<16} {:>9.4} ms {:>5.1}% stall={:<7} util={:.2}{}\n",
                op.name,
                op.wall_s * 1e3,
                op.wall_s / total * 100.0,
                op.stall.name(),
                op.utilization,
                if op.latency_bound { " latency-bound" } else { "" },
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(
        name: &'static str,
        phase: Phase,
        wall_s: f32,
        stall: Bottleneck,
    ) -> OpRecord {
        OpRecord {
            name,
            phase,
            wall_s,
            stall,
            compute_s: 0.0,
            memory_s: 0.0,
            network_s: 0.0,
            energy_j: 0.1,
            utilization: 0.5,
            latency_bound: false,
        }
    }

    fn sample() -> CriticalPath {
        CriticalPath {
            ops: vec![
                rec("qkv", Phase::Prefill, 3.0, Bottleneck::Compute),
                rec("ar", Phase::Prefill, 2.0, Bottleneck::Network),
                rec("mlp", Phase::Prefill, 4.0, Bottleneck::Compute),
                rec("qkv", Phase::Decode, 0.2, Bottleneck::Memory),
                rec("ar", Phase::Decode, 0.1, Bottleneck::Network),
            ],
        }
    }

    #[test]
    fn totals_and_stacks() {
        let cp = sample();
        assert!((cp.phase_total_s(Phase::Prefill) - 9.0).abs() < 1e-6);
        let s = cp.stall_stack(Phase::Prefill);
        assert_eq!(s, [7.0, 0.0, 2.0]);
        // Per-op energies sum per phase (3 prefill ops at 0.1 J each).
        assert!(
            (cp.phase_energy_j(Phase::Prefill) - 0.3).abs() < 1e-6
        );
        assert!(
            (cp.phase_energy_j(Phase::Decode) - 0.2).abs() < 1e-6
        );
    }

    #[test]
    fn dominant_op_and_stall() {
        let cp = sample();
        assert_eq!(cp.dominant_op(Phase::Prefill).unwrap().name, "mlp");
        assert_eq!(cp.dominant_stall(Phase::Prefill), Bottleneck::Compute);
        assert_eq!(cp.dominant_stall(Phase::Decode), Bottleneck::Memory);
    }

    #[test]
    fn render_mentions_every_op() {
        let cp = sample();
        let text = cp.render(Phase::Prefill);
        assert!(text.contains("qkv") && text.contains("mlp"));
        assert!(text.contains("dominant stall: compute"));
    }
}
