//! The detailed analytical GPU simulator ("LLMCompass-class").
//!
//! The paper evaluates DSE methods on two environments: a fast roofline
//! model and LLMCompass (Zhang et al., ISCA'24), an analytical GPU
//! simulator for LLM inference which the authors extended with critical
//! path analysis. This module is our from-scratch equivalent: it models
//! execution at **tile granularity** — systolic-array mapping with
//! double-buffered SRAM staging, an L2-aware memory system, and a chunked
//! ring-allreduce interconnect — and attributes every operator's time to
//! a dominant stall component, producing the per-design critical-path
//! report that LUMINA's Strategy Engine consumes.
//!
//! It is intentionally a *different, richer* model than `sim::roofline`
//! (overlap, cache reuse, wave scheduling overheads), standing in for the
//! "hours per sample" simulator of §5.3 — while still fast enough that the
//! 20-sample budget study runs in milliseconds here.

pub mod critical_path;
pub mod engine;
pub mod interconnect;
pub mod memory;
pub mod tiles;

pub use critical_path::{CriticalPath, OpRecord};
pub use engine::{CompassSim, LAUNCH_OVERHEAD_S};
