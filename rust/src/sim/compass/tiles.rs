//! Systolic-array tiling model: how an M x N x K (x count) matmul maps
//! onto `cores x sublanes` weight-stationary arrays, with SRAM-capacity
//! aware tile sizing and double-buffering analysis.

use crate::arch::constants as c;
use crate::design::{DesignPoint, Param};

/// Result of mapping one matmul onto the machine.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MatmulMapping {
    /// Chosen K-chunk (elements accumulated per weight load).
    pub k_tile: f32,
    /// Total output tiles across all instances.
    pub tiles: f32,
    /// Full waves + remainder wave over all arrays.
    pub waves: f32,
    /// Compute seconds (systolic cycles / clock), including drain.
    pub compute_s: f32,
    /// Seconds spent staging weights/activations into SRAM.
    pub stage_s: f32,
    /// True when SRAM fits two tile working-sets so staging overlaps
    /// compute (ping-pong buffers).
    pub double_buffered: bool,
    /// Effective utilization of the PE grid (0..1], for reports.
    pub utilization: f32,
}

/// Per-tile SRAM working set (bytes): weight tile (sa x kt) + activation
/// tile (sa x kt) + output accumulator (sa x sa, fp32).
fn tile_working_set(sa: f32, kt: f32) -> f32 {
    2.0 * sa * kt * c::FP16_BYTES + sa * sa * 4.0
}

/// Map an M x N x K matmul repeated `count` times onto `d`.
pub fn map_matmul(
    d: &DesignPoint,
    m: f32,
    n: f32,
    k: f32,
    count: f32,
    mem_bw: f32,
) -> MatmulMapping {
    let sa = d.get(Param::SystolicArray) as f32;
    let sram_bytes = d.get(Param::SramKb) as f32 * 1024.0;
    let arrays =
        (d.get(Param::Cores) * d.get(Param::Sublanes)) as f32;

    // Largest K-chunk whose double-buffered working set fits SRAM,
    // bounded by the canonical K_TILE and K itself.
    let mut kt = k.min(c::K_TILE);
    while kt > 8.0 && 2.0 * tile_working_set(sa, kt) > sram_bytes {
        kt /= 2.0;
    }
    let double_buffered = 2.0 * tile_working_set(sa, kt) <= sram_bytes;

    let tiles_m = (m / sa).ceil();
    let tiles_n = (n / sa).ceil();
    let tiles = tiles_m * tiles_n * count;
    let waves = (tiles / arrays).ceil();

    // Cycles per output tile: for each K-chunk, `kt` beats of accumulation
    // plus `sa` drain cycles (weight-stationary reload).
    let k_chunks = (k / kt).ceil();
    let cycles_per_tile = k_chunks * (kt + sa);
    let compute_s = waves * cycles_per_tile / c::CLOCK_HZ;

    // Staging traffic: unique operand + output bytes (L2 multicast and
    // loop blocking make tile re-reads hit in cache; the engine charges
    // an inflation factor separately when the reused operand outgrows
    // L2). This is what actually crosses the DRAM pins.
    let stage_bytes =
        (m * k + k * n + m * n) * count * c::FP16_BYTES;
    let stage_s = stage_bytes / mem_bw;

    // PE-grid utilization for reporting: valid MACs / (PE * cycles).
    let valid_macs = m * n * k * count;
    let total_pe_cycles = tiles * cycles_per_tile * sa * sa;
    let utilization = (valid_macs / total_pe_cycles).min(1.0);

    MatmulMapping {
        k_tile: kt,
        tiles,
        waves,
        compute_s,
        stage_s,
        double_buffered,
        utilization,
    }
}

impl MatmulMapping {
    /// Wall time for the matmul: with double buffering the stage traffic
    /// hides behind compute (whichever is longer wins); without it, the
    /// array stalls on staging with only partial overlap.
    pub fn wall_s(&self) -> f32 {
        if self.double_buffered {
            self.compute_s.max(self.stage_s)
        } else {
            // Serialized staging with ~30% overlap from in-flight loads.
            self.compute_s + 0.7 * self.stage_s
        }
    }

    /// True when staging (memory) dominates the wall time.
    pub fn memory_bound(&self) -> bool {
        self.stage_s > self.compute_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn a100() -> DesignPoint {
        DesignPoint::a100()
    }

    const BW: f32 = 1.5e12;

    #[test]
    fn big_prefill_matmul_is_compute_bound_and_utilized() {
        let m = map_matmul(&a100(), 16384.0, 4608.0, 12288.0, 1.0, BW);
        assert!(!m.memory_bound(), "{m:?}");
        assert!(m.utilization > 0.7, "{m:?}");
        assert!(m.double_buffered);
    }

    #[test]
    fn decode_gemv_is_memory_bound_with_low_utilization() {
        // M=8 onto 16x16 arrays: at most half the rows are live.
        let m = map_matmul(&a100(), 8.0, 12288.0, 6144.0, 1.0, BW);
        assert!(m.memory_bound(), "{m:?}");
        assert!(m.utilization < 0.5, "{m:?}");
    }

    #[test]
    fn giant_array_hurts_small_matmul_utilization() {
        let small = map_matmul(&a100(), 8.0, 12288.0, 6144.0, 1.0, BW);
        let big_d = a100().with(Param::SystolicArray, 128);
        let big = map_matmul(&big_d, 8.0, 12288.0, 6144.0, 1.0, BW);
        assert!(big.utilization < small.utilization / 4.0);
    }

    #[test]
    fn tiny_sram_forces_smaller_k_tile_or_serialization() {
        // 64x64 arrays need ~96 KB for double-buffered 128-deep chunks;
        // a 32 KB scratchpad must shrink the chunk or serialize.
        let wide = a100().with(Param::SystolicArray, 64);
        let starved = wide.with(Param::SramKb, 32);
        let m = map_matmul(&starved, 4096.0, 4096.0, 4096.0, 1.0, BW);
        let roomy = map_matmul(&wide, 4096.0, 4096.0, 4096.0, 1.0, BW);
        assert!(
            m.k_tile < roomy.k_tile || !m.double_buffered,
            "{m:?} vs {roomy:?}"
        );
        assert!(m.wall_s() >= roomy.wall_s());
    }

    #[test]
    fn wall_time_scales_down_with_more_arrays() {
        let half = a100().with(Param::Cores, 64);
        let t_small =
            map_matmul(&half, 16384.0, 4608.0, 12288.0, 1.0, BW).wall_s();
        let t_big =
            map_matmul(&a100(), 16384.0, 4608.0, 12288.0, 1.0, BW)
                .wall_s();
        assert!(t_big < t_small);
    }

    #[test]
    fn count_multiplies_tiles() {
        let one = map_matmul(&a100(), 2048.0, 2048.0, 128.0, 1.0, BW);
        let many = map_matmul(&a100(), 2048.0, 2048.0, 128.0, 96.0, BW);
        assert!((many.tiles / one.tiles - 96.0).abs() < 1e-3);
    }
}
