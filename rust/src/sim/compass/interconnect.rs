//! Interconnect model: chunked ring allreduce over the node's links.
//!
//! Unlike the roofline's single bandwidth term, this models the 2(n-1)
//! ring steps explicitly with per-step latency, chunking, and a
//! protocol-efficiency curve that degrades for small messages — which is
//! what makes decode-phase allreduces latency- rather than
//! bandwidth-dominated, a distinction the Strategy Engine must see to
//! avoid "add links" when links would not help TPOT.

use crate::arch::constants as c;
use crate::design::{DesignPoint, Param};

/// Ring-allreduce model for a `tp`-way tensor-parallel group.
#[derive(Debug, Clone, Copy)]
pub struct Interconnect {
    /// Per-GPU aggregate link bandwidth, B/s.
    pub bw: f32,
    /// Per-hop latency, s (switch + serialization).
    pub hop_latency: f32,
    pub tp: f32,
}

impl Interconnect {
    pub fn new(d: &DesignPoint, tp: u64) -> Self {
        let links = d.get(Param::Links) as f32;
        Interconnect {
            bw: links * c::LINK_BPS,
            hop_latency: 1.0e-6,
            tp: tp as f32,
        }
    }

    /// Time for one ring allreduce of `bytes` payload.
    pub fn allreduce_s(&self, bytes: f32) -> f32 {
        if bytes <= 0.0 {
            return 0.0;
        }
        let steps = 2.0 * (self.tp - 1.0);
        let chunk = bytes / self.tp;
        // Protocol efficiency falls off for small chunks (header +
        // synchronization amortization).
        let eff = c::NET_EFF * (chunk / (chunk + 64.0 * 1024.0));
        let bw_term = steps * chunk / (self.bw * eff.max(0.05));
        let lat_term = steps * self.hop_latency;
        bw_term + lat_term
    }

    /// Link energy of one ring allreduce, joules: every rank puts
    /// `2(tp-1)` chunks of `bytes / tp` on the wire.
    pub fn allreduce_energy_j(&self, bytes: f32) -> f32 {
        if bytes <= 0.0 {
            return 0.0;
        }
        let steps = 2.0 * (self.tp - 1.0);
        let chunk = bytes / self.tp;
        steps * chunk * c::E_J_PER_BYTE_LINK
    }

    /// True when the transfer is latency- (not bandwidth-) dominated;
    /// the critical-path report uses this to tell the Strategy Engine
    /// that adding links will not help.
    pub fn latency_bound(&self, bytes: f32) -> bool {
        let steps = 2.0 * (self.tp - 1.0);
        let chunk = bytes / self.tp;
        let eff = c::NET_EFF * (chunk / (chunk + 64.0 * 1024.0));
        steps * self.hop_latency > steps * chunk / (self.bw * eff.max(0.05))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn icn(links: u32) -> Interconnect {
        Interconnect::new(
            &DesignPoint::a100().with(Param::Links, links),
            8,
        )
    }

    #[test]
    fn large_allreduce_scales_with_links() {
        let bytes = 4.0e8; // prefill activation allreduce
        let t12 = icn(12).allreduce_s(bytes);
        let t24 = icn(24).allreduce_s(bytes);
        assert!(t24 < t12 * 0.6, "t12={t12} t24={t24}");
        assert!(!icn(12).latency_bound(bytes));
    }

    #[test]
    fn tiny_allreduce_is_latency_bound_and_links_do_not_help() {
        let bytes = 8.0 * 12288.0 * 2.0 / 8.0; // decode-sized chunk
        assert!(icn(12).latency_bound(bytes));
        let t12 = icn(12).allreduce_s(bytes);
        let t24 = icn(24).allreduce_s(bytes);
        assert!(t24 > t12 * 0.8, "links should barely matter");
    }

    #[test]
    fn allreduce_monotone_in_bytes() {
        let i = icn(12);
        assert!(i.allreduce_s(2e8) > i.allreduce_s(1e8));
        assert_eq!(i.allreduce_s(0.0), 0.0);
    }

    #[test]
    fn allreduce_energy_scales_with_payload_not_links() {
        // Wire energy is payload-bound: more links speed the collective
        // but move the same bytes.
        let e12 = icn(12).allreduce_energy_j(2e8);
        let e24 = icn(24).allreduce_energy_j(2e8);
        assert_eq!(e12, e24);
        assert!((icn(12).allreduce_energy_j(4e8) - 2.0 * e12).abs()
            < e12 * 1e-5);
        assert_eq!(icn(12).allreduce_energy_j(0.0), 0.0);
    }

    #[test]
    fn ring_steps_match_tp() {
        // Doubling tp roughly doubles latency term for tiny messages.
        let a = Interconnect {
            bw: 3e11,
            hop_latency: 1e-6,
            tp: 2.0,
        };
        let b = Interconnect {
            bw: 3e11,
            hop_latency: 1e-6,
            tp: 8.0,
        };
        let small = 1024.0;
        let ra = a.allreduce_s(small);
        let rb = b.allreduce_s(small);
        assert!(rb > ra * 3.0);
    }
}
