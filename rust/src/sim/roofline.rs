//! Rust mirror of the roofline evaluation model.
//!
//! Formula-for-formula port of the L1 Pallas kernel
//! (`python/compile/kernels/roofline.py`), in f32 with matching operation
//! order so results agree with the artifact to float tolerance. Serves as
//! the test oracle for the PJRT path (`tests/artifact_vs_mirror.rs`) and
//! as the evaluator fallback when `artifacts/` has not been built.

use crate::arch::constants as c;
use crate::design::{DesignPoint, Param};
use crate::eval::{EvalOne, Evaluator, Metrics};
use crate::workload::{op_table, WorkloadSpec, MAX_OPS, N_PHASES};
use crate::Result;

/// Roofline simulator for a fixed workload.
#[derive(Debug, Clone)]
pub struct RooflineSim {
    /// Private: `table` is derived from the spec in the constructor, so
    /// the spec must not change underneath it (build a new sim for a
    /// new workload).
    spec: WorkloadSpec,
    table: [[[f32; 8]; MAX_OPS]; N_PHASES],
}

impl RooflineSim {
    pub fn new(spec: WorkloadSpec) -> Self {
        Self { spec, table: op_table(&spec) }
    }

    /// The workload this simulator was built for.
    pub fn spec(&self) -> &WorkloadSpec {
        &self.spec
    }

    /// Evaluate one design (pure function of the design vector).
    pub fn evaluate(&self, d: &DesignPoint) -> Metrics {
        let links = d.get(Param::Links) as f32;
        let cores = d.get(Param::Cores) as f32;
        let subl = d.get(Param::Sublanes) as f32;
        let sa = d.get(Param::SystolicArray) as f32;
        let vecw = d.get(Param::VectorWidth) as f32;
        let sram = d.get(Param::SramKb) as f32;
        let gbuf = d.get(Param::GbufMb) as f32;
        let memch = d.get(Param::MemChannels) as f32;

        let arrays = cores * subl;
        let t_peak = arrays * sa * sa * c::FLOPS_PER_PE * c::CLOCK_HZ;
        let v_peak = arrays * vecw * c::FLOPS_PER_LANE * c::CLOCK_HZ;
        let mem_eff = (c::MEM_EFF_BASE
            + c::MEM_EFF_L2_SLOPE * (gbuf / 8.0).log2())
        .clamp(c::MEM_EFF_BASE, c::MEM_EFF_MAX);
        let m_bw = memch * c::HBM_BPS_PER_CHANNEL * mem_eff;
        let n_bw = links * c::LINK_BPS * c::NET_EFF;

        let area_core = c::AREA_CORE_BASE
            + subl * (sa * sa * c::AREA_PER_PE + vecw * c::AREA_PER_LANE)
            + c::AREA_REGFILE
            + sram * c::AREA_SRAM_PER_KB;
        let area = cores * area_core
            + gbuf * c::AREA_L2_PER_MB
            + memch * c::AREA_HBM_PHY
            + links * c::AREA_LINK_PHY
            + c::AREA_UNCORE;

        let mut phase_total = [0f32; 2];
        let mut stalls = [[0f32; 3]; 2];
        let mut energy = [0f32; 2];
        for (p, phase) in self.table.iter().enumerate() {
            for row in phase {
                let kind = row[0];
                let m = row[1].max(1.0);
                let n = row[2].max(1.0);
                let k = row[3].max(1.0);
                let count = row[4].max(1.0);
                let flops = row[5];
                let bytes = row[6];
                let comm = row[7];

                let tiles_m = (m / sa).ceil();
                let tiles_n = (n / sa).ceil();
                let edge = (m * n) / (tiles_m * sa * tiles_n * sa);
                let kt = k.min(c::K_TILE);
                let drain = kt / (kt + sa);
                let sram_req =
                    (2.0 * sa * kt + sa * sa) * c::FP16_BYTES / 1024.0;
                let sram_f =
                    (sram / sram_req).clamp(c::SRAM_UTIL_FLOOR, 1.0);
                let tiles = tiles_m * tiles_n * count;
                let waves = (tiles / arrays).ceil();
                let quant = tiles / (waves * arrays);

                let t_tensor =
                    flops / (t_peak * edge * drain * sram_f * quant);
                let t_vec = flops / v_peak;
                let t_mem = bytes / m_bw;
                let t_net = comm / n_bw + c::ALLREDUCE_LAT_S;

                let is_mm = kind == 0.0;
                let is_vec = kind == 1.0;
                let is_comm = kind == 2.0;

                let t_compute = if is_mm { t_tensor } else { t_vec };
                let mut t_op = if is_comm {
                    t_net.max(t_mem)
                } else {
                    t_compute.max(t_mem)
                };
                t_op = if is_mm || is_vec || is_comm {
                    t_op + c::OP_OVERHEAD_S
                } else {
                    0.0
                };

                let live = t_op > 0.0;
                let comp_win = !is_comm && t_compute >= t_mem && live;
                let net_win = is_comm && t_net >= t_mem && live;
                let mem_win = live && !comp_win && !net_win;

                phase_total[p] += t_op;
                if comp_win {
                    stalls[p][0] += t_op;
                }
                if mem_win {
                    stalls[p][1] += t_op;
                }
                if net_win {
                    stalls[p][2] += t_op;
                }

                // Dynamic energy (J), mirroring the kernel's pricing:
                // FLOPs per execution unit (systolic MACs include SRAM
                // operand staging), HBM traffic crosses L2 once, comm
                // payload crosses the links. Pad rows contribute 0.
                if is_mm || is_vec || is_comm {
                    let e_compute = if is_mm {
                        flops
                            * (c::E_J_PER_FLOP_SYSTOLIC
                                + c::SRAM_BYTES_PER_FLOP
                                    * c::E_J_PER_BYTE_SRAM)
                    } else if is_vec {
                        flops * c::E_J_PER_FLOP_VECTOR
                    } else {
                        comm * c::E_J_PER_BYTE_LINK
                    };
                    let e_mem = bytes
                        * (c::E_J_PER_BYTE_HBM + c::E_J_PER_BYTE_L2);
                    energy[p] += e_compute + e_mem;
                }
            }
            // Static leakage: area-proportional draw over the phase
            // wall time.
            energy[p] += c::LEAKAGE_W_PER_MM2 * area * phase_total[p];
        }

        let prefill_energy_mj = energy[0] * 1e3;
        let energy_per_token_mj = energy[1] * 1e3;
        let ttft_ms = phase_total[0] * 1e3;
        let tpot_ms = phase_total[1] * 1e3;
        Metrics {
            ttft_ms,
            tpot_ms,
            area_mm2: area,
            energy_per_token_mj,
            prefill_energy_mj,
            avg_power_w: crate::arch::power::avg_power_w(
                prefill_energy_mj,
                energy_per_token_mj,
                ttft_ms,
                tpot_ms,
            ),
            stalls: [
                [
                    stalls[0][0] * 1e3,
                    stalls[0][1] * 1e3,
                    stalls[0][2] * 1e3,
                ],
                [
                    stalls[1][0] * 1e3,
                    stalls[1][1] * 1e3,
                    stalls[1][2] * 1e3,
                ],
            ],
        }
    }
}

/// Per-design derived machine scalars of the roofline model, hoisted
/// once per batch by the SoA kernel — exactly the quantities
/// [`RooflineSim::evaluate`] computes before its table walk, produced
/// by the same expressions in the same order.
struct Derived {
    arrays: f32,
    t_peak: f32,
    v_peak: f32,
    m_bw: f32,
    n_bw: f32,
    sa: f32,
    sram: f32,
    area: f32,
}

impl Derived {
    fn new(d: &DesignPoint) -> Derived {
        let links = d.get(Param::Links) as f32;
        let cores = d.get(Param::Cores) as f32;
        let subl = d.get(Param::Sublanes) as f32;
        let sa = d.get(Param::SystolicArray) as f32;
        let vecw = d.get(Param::VectorWidth) as f32;
        let sram = d.get(Param::SramKb) as f32;
        let gbuf = d.get(Param::GbufMb) as f32;
        let memch = d.get(Param::MemChannels) as f32;

        let arrays = cores * subl;
        let t_peak = arrays * sa * sa * c::FLOPS_PER_PE * c::CLOCK_HZ;
        let v_peak = arrays * vecw * c::FLOPS_PER_LANE * c::CLOCK_HZ;
        let mem_eff = (c::MEM_EFF_BASE
            + c::MEM_EFF_L2_SLOPE * (gbuf / 8.0).log2())
        .clamp(c::MEM_EFF_BASE, c::MEM_EFF_MAX);
        let m_bw = memch * c::HBM_BPS_PER_CHANNEL * mem_eff;
        let n_bw = links * c::LINK_BPS * c::NET_EFF;

        let area_core = c::AREA_CORE_BASE
            + subl * (sa * sa * c::AREA_PER_PE + vecw * c::AREA_PER_LANE)
            + c::AREA_REGFILE
            + sram * c::AREA_SRAM_PER_KB;
        let area = cores * area_core
            + gbuf * c::AREA_L2_PER_MB
            + memch * c::AREA_HBM_PHY
            + links * c::AREA_LINK_PHY
            + c::AREA_UNCORE;
        Derived { arrays, t_peak, v_peak, m_bw, n_bw, sa, sram, area }
    }
}

impl RooflineSim {
    /// Evaluate a batch with the structure-of-arrays kernel: the
    /// machine scalars are derived once per design, then the op table
    /// is walked **once per batch** with a design-inner loop per row —
    /// the row constants (operand shapes, FLOPs, bytes, per-row energy
    /// prices) stay in registers and the design-lane arithmetic
    /// auto-vectorizes. Padding rows (kind sentinel `-1`, which
    /// contribute exactly `0.0` in [`RooflineSim::evaluate`]) are
    /// skipped whole.
    ///
    /// Bit-identity: per design, every expression and accumulation
    /// order matches `evaluate` verbatim (rows in table order, then
    /// the phase leakage term), so results equal `eval_one` bitwise —
    /// asserted for every registered scenario in `tests/soa_pool.rs`.
    pub fn eval_batch_soa(&self, designs: &[DesignPoint]) -> Vec<Metrics> {
        let mut out = vec![Metrics::default(); designs.len()];
        self.eval_soa_into(designs, &mut out);
        out
    }

    /// [`RooflineSim::eval_batch_soa`] writing into a caller buffer
    /// (the pool-worker chunk path).
    pub fn eval_soa_into(
        &self,
        designs: &[DesignPoint],
        out: &mut [Metrics],
    ) {
        debug_assert_eq!(designs.len(), out.len());
        let n = designs.len();
        if n == 0 {
            return;
        }
        let derived: Vec<Derived> =
            designs.iter().map(Derived::new).collect();
        let mut phase_total: [Vec<f32>; 2] =
            std::array::from_fn(|_| vec![0f32; n]);
        let mut stalls: [[Vec<f32>; 3]; 2] = std::array::from_fn(|_| {
            std::array::from_fn(|_| vec![0f32; n])
        });
        let mut energy: [Vec<f32>; 2] =
            std::array::from_fn(|_| vec![0f32; n]);
        for (p, phase) in self.table.iter().enumerate() {
            for row in phase {
                // Row constants (design-independent), hoisted out of
                // the design lane.
                let kind = row[0];
                let is_mm = kind == 0.0;
                let is_vec = kind == 1.0;
                let is_comm = kind == 2.0;
                if !(is_mm || is_vec || is_comm) {
                    // Padding row: contributes exactly 0.0 everywhere
                    // in the scalar path.
                    continue;
                }
                let m = row[1].max(1.0);
                let nn = row[2].max(1.0);
                let k = row[3].max(1.0);
                let count = row[4].max(1.0);
                let flops = row[5];
                let bytes = row[6];
                let comm = row[7];
                let kt = k.min(c::K_TILE);
                // Per-row dynamic-energy prices (J), identical to the
                // scalar path's expressions — design-independent, so
                // priced once per row.
                let e_compute = if is_mm {
                    flops
                        * (c::E_J_PER_FLOP_SYSTOLIC
                            + c::SRAM_BYTES_PER_FLOP
                                * c::E_J_PER_BYTE_SRAM)
                } else if is_vec {
                    flops * c::E_J_PER_FLOP_VECTOR
                } else {
                    comm * c::E_J_PER_BYTE_LINK
                };
                let e_mem =
                    bytes * (c::E_J_PER_BYTE_HBM + c::E_J_PER_BYTE_L2);

                for (i, dv) in derived.iter().enumerate() {
                    let sa = dv.sa;
                    let tiles_m = (m / sa).ceil();
                    let tiles_n = (nn / sa).ceil();
                    let edge =
                        (m * nn) / (tiles_m * sa * tiles_n * sa);
                    let drain = kt / (kt + sa);
                    let sram_req = (2.0 * sa * kt + sa * sa)
                        * c::FP16_BYTES
                        / 1024.0;
                    let sram_f = (dv.sram / sram_req)
                        .clamp(c::SRAM_UTIL_FLOOR, 1.0);
                    let tiles = tiles_m * tiles_n * count;
                    let waves = (tiles / dv.arrays).ceil();
                    let quant = tiles / (waves * dv.arrays);

                    let t_tensor = flops
                        / (dv.t_peak * edge * drain * sram_f * quant);
                    let t_vec = flops / dv.v_peak;
                    let t_mem = bytes / dv.m_bw;
                    let t_net = comm / dv.n_bw + c::ALLREDUCE_LAT_S;

                    let t_compute = if is_mm { t_tensor } else { t_vec };
                    let mut t_op = if is_comm {
                        t_net.max(t_mem)
                    } else {
                        t_compute.max(t_mem)
                    };
                    t_op += c::OP_OVERHEAD_S;

                    let live = t_op > 0.0;
                    let comp_win = !is_comm && t_compute >= t_mem && live;
                    let net_win = is_comm && t_net >= t_mem && live;
                    let mem_win = live && !comp_win && !net_win;

                    phase_total[p][i] += t_op;
                    if comp_win {
                        stalls[p][0][i] += t_op;
                    }
                    if mem_win {
                        stalls[p][1][i] += t_op;
                    }
                    if net_win {
                        stalls[p][2][i] += t_op;
                    }
                    energy[p][i] += e_compute + e_mem;
                }
            }
            // Static leakage: area-proportional draw over the phase
            // wall time (added after the phase's rows, as in the
            // scalar path).
            for (i, dv) in derived.iter().enumerate() {
                energy[p][i] +=
                    c::LEAKAGE_W_PER_MM2 * dv.area * phase_total[p][i];
            }
        }
        for (i, (dv, slot)) in
            derived.iter().zip(out.iter_mut()).enumerate()
        {
            let prefill_energy_mj = energy[0][i] * 1e3;
            let energy_per_token_mj = energy[1][i] * 1e3;
            let ttft_ms = phase_total[0][i] * 1e3;
            let tpot_ms = phase_total[1][i] * 1e3;
            *slot = Metrics {
                ttft_ms,
                tpot_ms,
                area_mm2: dv.area,
                energy_per_token_mj,
                prefill_energy_mj,
                avg_power_w: crate::arch::power::avg_power_w(
                    prefill_energy_mj,
                    energy_per_token_mj,
                    ttft_ms,
                    tpot_ms,
                ),
                stalls: [
                    [
                        stalls[0][0][i] * 1e3,
                        stalls[0][1][i] * 1e3,
                        stalls[0][2][i] * 1e3,
                    ],
                    [
                        stalls[1][0][i] * 1e3,
                        stalls[1][1][i] * 1e3,
                        stalls[1][2][i] * 1e3,
                    ],
                ],
            };
        }
    }
}

impl EvalOne for RooflineSim {
    fn eval_one(&self, d: &DesignPoint) -> Metrics {
        self.evaluate(d)
    }

    fn label(&self) -> &'static str {
        "roofline-rs"
    }

    fn workload_fingerprint(&self) -> u64 {
        self.spec.fingerprint()
    }

    fn eval_chunk(&self, designs: &[DesignPoint], out: &mut [Metrics]) {
        self.eval_soa_into(designs, out);
    }
}

impl Evaluator for RooflineSim {
    fn eval_batch(&mut self, designs: &[DesignPoint]) -> Result<Vec<Metrics>> {
        Ok(self.eval_batch_soa(designs))
    }

    fn name(&self) -> &'static str {
        "roofline-rs"
    }

    fn workload_fingerprint(&self) -> u64 {
        self.spec.fingerprint()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::{Bottleneck, Phase};
    use crate::workload::GPT3_175B;

    fn sim() -> RooflineSim {
        RooflineSim::new(GPT3_175B)
    }

    #[test]
    fn a100_matches_python_reference_numbers() {
        // Values printed by the python oracle for the A100 config
        // (see python/tests): ttft=36.70556, tpot=0.4424397, area=833.9728
        let m = sim().evaluate(&DesignPoint::a100());
        assert!((m.ttft_ms - 36.70556).abs() / 36.70556 < 1e-4, "{m:?}");
        assert!((m.tpot_ms - 0.4424397).abs() / 0.4424397 < 1e-4);
        assert!((m.area_mm2 - 833.9728).abs() / 833.9728 < 1e-4);
    }

    #[test]
    fn a100_energy_matches_python_reference_numbers() {
        // Values printed by the python oracle (kernels/ref.py) for the
        // A100 config: prefill 8116.046 mJ, decode 41.352123 mJ/token,
        // avg power 219.59186 W.
        let m = sim().evaluate(&DesignPoint::a100());
        assert!(
            (m.prefill_energy_mj - 8116.046).abs() / 8116.046 < 1e-4,
            "{m:?}"
        );
        assert!(
            (m.energy_per_token_mj - 41.352123).abs() / 41.352123
                < 1e-4
        );
        assert!((m.avg_power_w - 219.59186).abs() / 219.59186 < 1e-4);
        // The derived field is exactly the shared helper's output.
        assert_eq!(
            m.avg_power_w,
            crate::arch::power::avg_power_w(
                m.prefill_energy_mj,
                m.energy_per_token_mj,
                m.ttft_ms,
                m.tpot_ms
            )
        );
    }

    #[test]
    fn tiny_workload_energy_matches_python() {
        // Python oracle, gpt3-tiny on A100: [14.875684, 1.7696981] mJ.
        let m = RooflineSim::new(crate::workload::GPT3_TINY)
            .evaluate(&DesignPoint::a100());
        assert!(
            (m.prefill_energy_mj - 14.875684).abs() / 14.875684 < 1e-4,
            "{m:?}"
        );
        assert!(
            (m.energy_per_token_mj - 1.7696981).abs() / 1.7696981
                < 1e-4
        );
    }

    #[test]
    fn energy_exceeds_leakage_floor_and_tracks_traffic() {
        use crate::arch::constants as c;
        let s = sim();
        let m = s.evaluate(&DesignPoint::a100());
        // Each phase's energy is at least its leakage-only draw
        // (W * ms = mJ).
        let leak_pf = c::LEAKAGE_W_PER_MM2 * m.area_mm2 * m.ttft_ms;
        let leak_dc = c::LEAKAGE_W_PER_MM2 * m.area_mm2 * m.tpot_ms;
        assert!(m.prefill_energy_mj > leak_pf);
        assert!(m.energy_per_token_mj > leak_dc);
        // More memory channels cut decode *time* but the dominant
        // decode energy term (HBM traffic) is byte-count-bound, so
        // energy/token must not grow with time savings.
        let fast = s.evaluate(
            &DesignPoint::a100().with(Param::MemChannels, 10),
        );
        assert!(fast.tpot_ms < m.tpot_ms);
        assert!(fast.energy_per_token_mj < m.energy_per_token_mj * 1.05);
    }

    #[test]
    fn a100_stall_stack_matches_python() {
        let m = sim().evaluate(&DesignPoint::a100());
        // prefill: [26.794, 3.634, 6.277]; decode: [0, 0.4254, 0.01706]
        assert!((m.stalls[0][0] - 26.794451).abs() < 2e-3, "{m:?}");
        assert!((m.stalls[0][1] - 3.6336124).abs() < 2e-3);
        assert!((m.stalls[0][2] - 6.277494).abs() < 2e-3);
        assert!((m.stalls[1][1] - 0.42538139).abs() < 2e-4);
    }

    #[test]
    fn prefill_compute_bound_decode_memory_bound_on_a100() {
        let m = sim().evaluate(&DesignPoint::a100());
        assert_eq!(m.dominant_bottleneck(Phase::Prefill), Bottleneck::Compute);
        assert_eq!(m.dominant_bottleneck(Phase::Decode), Bottleneck::Memory);
    }

    #[test]
    fn paper_designs_dominate_a100() {
        let s = sim();
        let a100 = s.evaluate(&DesignPoint::a100());
        for d in
            [DesignPoint::paper_design_a(), DesignPoint::paper_design_b()]
        {
            let m = s.evaluate(&d);
            assert!(m.ttft_ms < a100.ttft_ms, "{d}: {m:?}");
            assert!(m.tpot_ms < a100.tpot_ms);
            assert!(m.area_mm2 < a100.area_mm2);
        }
    }

    #[test]
    fn stall_buckets_sum_to_phase_time() {
        let s = sim();
        for d in [
            DesignPoint::a100(),
            DesignPoint::new([6, 1, 1, 4, 4, 32, 32, 1]),
            DesignPoint::new([24, 256, 8, 128, 128, 1024, 1024, 12]),
        ] {
            let m = s.evaluate(&d);
            let pf: f32 = m.stalls[0].iter().sum();
            let dc: f32 = m.stalls[1].iter().sum();
            assert!((pf - m.ttft_ms).abs() / m.ttft_ms < 1e-5);
            assert!((dc - m.tpot_ms).abs() / m.tpot_ms < 1e-5);
        }
    }

    #[test]
    fn batch_eval_matches_single() {
        let mut s = sim();
        let ds = vec![
            DesignPoint::a100(),
            DesignPoint::paper_design_a(),
            DesignPoint::paper_design_b(),
        ];
        let batch = s.eval_batch(&ds).unwrap();
        for (d, b) in ds.iter().zip(&batch) {
            assert_eq!(*b, s.evaluate(d));
        }
    }

    #[test]
    fn soa_batch_is_bitwise_identical_to_eval_one() {
        let s = sim();
        let designs = [
            DesignPoint::a100(),
            DesignPoint::paper_design_a(),
            DesignPoint::paper_design_b(),
            DesignPoint::new([6, 1, 1, 4, 4, 32, 32, 1]),
            DesignPoint::new([24, 256, 8, 128, 128, 1024, 1024, 12]),
        ];
        let soa = s.eval_batch_soa(&designs);
        for (d, got) in designs.iter().zip(&soa) {
            assert_eq!(*got, s.evaluate(d), "{d}");
        }
        let mut out = vec![Metrics::default(); designs.len()];
        s.eval_chunk(&designs, &mut out);
        assert_eq!(out, soa);
        assert!(s.eval_batch_soa(&[]).is_empty());
    }

    #[test]
    fn tiny_workload_runs() {
        let s = RooflineSim::new(crate::workload::GPT3_TINY);
        let m = s.evaluate(&DesignPoint::a100());
        assert!(m.ttft_ms > 0.0 && m.tpot_ms > 0.0);
        assert!(m.ttft_ms < sim().evaluate(&DesignPoint::a100()).ttft_ms);
    }
}
