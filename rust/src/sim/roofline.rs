//! Rust mirror of the roofline evaluation model.
//!
//! Formula-for-formula port of the L1 Pallas kernel
//! (`python/compile/kernels/roofline.py`), in f32 with matching operation
//! order so results agree with the artifact to float tolerance. Serves as
//! the test oracle for the PJRT path (`tests/artifact_vs_mirror.rs`) and
//! as the evaluator fallback when `artifacts/` has not been built.

use crate::arch::constants as c;
use crate::design::{DesignPoint, Param};
use crate::eval::{
    with_caller_scratch, EvalOne, EvalScratch, Evaluator, Metrics,
    SOA_LANES,
};
use crate::workload::{op_table, WorkloadSpec, MAX_OPS, N_PHASES};
use crate::Result;

/// Roofline simulator for a fixed workload.
#[derive(Debug, Clone)]
pub struct RooflineSim {
    /// Private: `table` is derived from the spec in the constructor, so
    /// the spec must not change underneath it (build a new sim for a
    /// new workload).
    spec: WorkloadSpec,
    table: [[[f32; 8]; MAX_OPS]; N_PHASES],
}

impl RooflineSim {
    pub fn new(spec: WorkloadSpec) -> Self {
        Self { spec, table: op_table(&spec) }
    }

    /// The workload this simulator was built for.
    pub fn spec(&self) -> &WorkloadSpec {
        &self.spec
    }

    /// Evaluate one design (pure function of the design vector).
    pub fn evaluate(&self, d: &DesignPoint) -> Metrics {
        let links = d.get(Param::Links) as f32;
        let cores = d.get(Param::Cores) as f32;
        let subl = d.get(Param::Sublanes) as f32;
        let sa = d.get(Param::SystolicArray) as f32;
        let vecw = d.get(Param::VectorWidth) as f32;
        let sram = d.get(Param::SramKb) as f32;
        let gbuf = d.get(Param::GbufMb) as f32;
        let memch = d.get(Param::MemChannels) as f32;

        let arrays = cores * subl;
        let t_peak = arrays * sa * sa * c::FLOPS_PER_PE * c::CLOCK_HZ;
        let v_peak = arrays * vecw * c::FLOPS_PER_LANE * c::CLOCK_HZ;
        let mem_eff = (c::MEM_EFF_BASE
            + c::MEM_EFF_L2_SLOPE * (gbuf / 8.0).log2())
        .clamp(c::MEM_EFF_BASE, c::MEM_EFF_MAX);
        let m_bw = memch * c::HBM_BPS_PER_CHANNEL * mem_eff;
        let n_bw = links * c::LINK_BPS * c::NET_EFF;

        let area_core = c::AREA_CORE_BASE
            + subl * (sa * sa * c::AREA_PER_PE + vecw * c::AREA_PER_LANE)
            + c::AREA_REGFILE
            + sram * c::AREA_SRAM_PER_KB;
        let area = cores * area_core
            + gbuf * c::AREA_L2_PER_MB
            + memch * c::AREA_HBM_PHY
            + links * c::AREA_LINK_PHY
            + c::AREA_UNCORE;

        let mut phase_total = [0f32; 2];
        let mut stalls = [[0f32; 3]; 2];
        let mut energy = [0f32; 2];
        for (p, phase) in self.table.iter().enumerate() {
            for row in phase {
                let kind = row[0];
                let m = row[1].max(1.0);
                let n = row[2].max(1.0);
                let k = row[3].max(1.0);
                let count = row[4].max(1.0);
                let flops = row[5];
                let bytes = row[6];
                let comm = row[7];

                let tiles_m = (m / sa).ceil();
                let tiles_n = (n / sa).ceil();
                let edge = (m * n) / (tiles_m * sa * tiles_n * sa);
                let kt = k.min(c::K_TILE);
                let drain = kt / (kt + sa);
                let sram_req =
                    (2.0 * sa * kt + sa * sa) * c::FP16_BYTES / 1024.0;
                let sram_f =
                    (sram / sram_req).clamp(c::SRAM_UTIL_FLOOR, 1.0);
                let tiles = tiles_m * tiles_n * count;
                let waves = (tiles / arrays).ceil();
                let quant = tiles / (waves * arrays);

                let t_tensor =
                    flops / (t_peak * edge * drain * sram_f * quant);
                let t_vec = flops / v_peak;
                let t_mem = bytes / m_bw;
                let t_net = comm / n_bw + c::ALLREDUCE_LAT_S;

                let is_mm = kind == 0.0;
                let is_vec = kind == 1.0;
                let is_comm = kind == 2.0;

                let t_compute = if is_mm { t_tensor } else { t_vec };
                let mut t_op = if is_comm {
                    t_net.max(t_mem)
                } else {
                    t_compute.max(t_mem)
                };
                t_op = if is_mm || is_vec || is_comm {
                    t_op + c::OP_OVERHEAD_S
                } else {
                    0.0
                };

                let live = t_op > 0.0;
                let comp_win = !is_comm && t_compute >= t_mem && live;
                let net_win = is_comm && t_net >= t_mem && live;
                let mem_win = live && !comp_win && !net_win;

                phase_total[p] += t_op;
                if comp_win {
                    stalls[p][0] += t_op;
                }
                if mem_win {
                    stalls[p][1] += t_op;
                }
                if net_win {
                    stalls[p][2] += t_op;
                }

                // Dynamic energy (J), mirroring the kernel's pricing:
                // FLOPs per execution unit (systolic MACs include SRAM
                // operand staging), HBM traffic crosses L2 once, comm
                // payload crosses the links. Pad rows contribute 0.
                if is_mm || is_vec || is_comm {
                    let e_compute = if is_mm {
                        flops
                            * (c::E_J_PER_FLOP_SYSTOLIC
                                + c::SRAM_BYTES_PER_FLOP
                                    * c::E_J_PER_BYTE_SRAM)
                    } else if is_vec {
                        flops * c::E_J_PER_FLOP_VECTOR
                    } else {
                        comm * c::E_J_PER_BYTE_LINK
                    };
                    let e_mem = bytes
                        * (c::E_J_PER_BYTE_HBM + c::E_J_PER_BYTE_L2);
                    energy[p] += e_compute + e_mem;
                }
            }
            // Static leakage: area-proportional draw over the phase
            // wall time.
            energy[p] += c::LEAKAGE_W_PER_MM2 * area * phase_total[p];
        }

        let prefill_energy_mj = energy[0] * 1e3;
        let energy_per_token_mj = energy[1] * 1e3;
        let ttft_ms = phase_total[0] * 1e3;
        let tpot_ms = phase_total[1] * 1e3;
        Metrics {
            ttft_ms,
            tpot_ms,
            area_mm2: area,
            energy_per_token_mj,
            prefill_energy_mj,
            avg_power_w: crate::arch::power::avg_power_w(
                prefill_energy_mj,
                energy_per_token_mj,
                ttft_ms,
                tpot_ms,
            ),
            stalls: [
                [
                    stalls[0][0] * 1e3,
                    stalls[0][1] * 1e3,
                    stalls[0][2] * 1e3,
                ],
                [
                    stalls[1][0] * 1e3,
                    stalls[1][1] * 1e3,
                    stalls[1][2] * 1e3,
                ],
            ],
        }
    }
}

/// Per-design derived machine scalars of the roofline model, hoisted
/// once per batch by the SoA kernel — exactly the quantities
/// [`RooflineSim::evaluate`] computes before its table walk, produced
/// by the same expressions in the same order.
struct Derived {
    arrays: f32,
    t_peak: f32,
    v_peak: f32,
    m_bw: f32,
    n_bw: f32,
    sa: f32,
    sram: f32,
    area: f32,
}

impl Derived {
    fn new(d: &DesignPoint) -> Derived {
        let links = d.get(Param::Links) as f32;
        let cores = d.get(Param::Cores) as f32;
        let subl = d.get(Param::Sublanes) as f32;
        let sa = d.get(Param::SystolicArray) as f32;
        let vecw = d.get(Param::VectorWidth) as f32;
        let sram = d.get(Param::SramKb) as f32;
        let gbuf = d.get(Param::GbufMb) as f32;
        let memch = d.get(Param::MemChannels) as f32;

        let arrays = cores * subl;
        let t_peak = arrays * sa * sa * c::FLOPS_PER_PE * c::CLOCK_HZ;
        let v_peak = arrays * vecw * c::FLOPS_PER_LANE * c::CLOCK_HZ;
        let mem_eff = (c::MEM_EFF_BASE
            + c::MEM_EFF_L2_SLOPE * (gbuf / 8.0).log2())
        .clamp(c::MEM_EFF_BASE, c::MEM_EFF_MAX);
        let m_bw = memch * c::HBM_BPS_PER_CHANNEL * mem_eff;
        let n_bw = links * c::LINK_BPS * c::NET_EFF;

        let area_core = c::AREA_CORE_BASE
            + subl * (sa * sa * c::AREA_PER_PE + vecw * c::AREA_PER_LANE)
            + c::AREA_REGFILE
            + sram * c::AREA_SRAM_PER_KB;
        let area = cores * area_core
            + gbuf * c::AREA_L2_PER_MB
            + memch * c::AREA_HBM_PHY
            + links * c::AREA_LINK_PHY
            + c::AREA_UNCORE;
        Derived { arrays, t_peak, v_peak, m_bw, n_bw, sa, sram, area }
    }
}

/// Design-independent constants of one live op-table row, hoisted out
/// of the design-inner lane loop by the SoA kernel. Produced by the
/// exact expressions [`RooflineSim::evaluate`] computes per row.
#[derive(Clone, Copy)]
struct RowConsts {
    is_mm: bool,
    is_comm: bool,
    m: f32,
    nn: f32,
    count: f32,
    flops: f32,
    bytes: f32,
    comm: f32,
    kt: f32,
    /// Dynamic energy (J) this row adds to every design: the scalar
    /// path's `e_compute + e_mem`, priced once per row.
    e_row: f32,
}

/// One lane window of the roofline row walk: evaluate designs
/// `i..i + L` against one op row, staging `[f32; L]` op times and
/// `[bool; L]` win flags, then accumulate with branch-free selects.
///
/// Bit-identity with [`RooflineSim::evaluate`]: every per-design
/// expression is verbatim, and the select accumulation
/// `acc += if win { t } else { 0.0 }` equals the scalar `if win
/// { acc += t }` bitwise because the accumulators start at `+0.0` and
/// only ever add non-negative op times (`x + 0.0 == x` for every
/// non-`-0.0` float).
#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn row_window<const L: usize>(
    i: usize,
    rc: RowConsts,
    sa: &[f32],
    sram: &[f32],
    arrays: &[f32],
    t_peak: &[f32],
    v_peak: &[f32],
    m_bw: &[f32],
    n_bw: &[f32],
    pt: &mut [f32],
    st_comp: &mut [f32],
    st_mem: &mut [f32],
    st_net: &mut [f32],
    energy: &mut [f32],
) {
    let mut t_op = [0f32; L];
    let mut comp_win = [false; L];
    let mut net_win = [false; L];
    let mut mem_win = [false; L];
    for l in 0..L {
        let j = i + l;
        let sa_j = sa[j];
        let tiles_m = (rc.m / sa_j).ceil();
        let tiles_n = (rc.nn / sa_j).ceil();
        let edge = (rc.m * rc.nn) / (tiles_m * sa_j * tiles_n * sa_j);
        let drain = rc.kt / (rc.kt + sa_j);
        let sram_req =
            (2.0 * sa_j * rc.kt + sa_j * sa_j) * c::FP16_BYTES / 1024.0;
        let sram_f =
            (sram[j] / sram_req).clamp(c::SRAM_UTIL_FLOOR, 1.0);
        let tiles = tiles_m * tiles_n * rc.count;
        let waves = (tiles / arrays[j]).ceil();
        let quant = tiles / (waves * arrays[j]);

        let t_tensor =
            rc.flops / (t_peak[j] * edge * drain * sram_f * quant);
        let t_vec = rc.flops / v_peak[j];
        let t_mem = rc.bytes / m_bw[j];
        let t_net = rc.comm / n_bw[j] + c::ALLREDUCE_LAT_S;

        let t_compute = if rc.is_mm { t_tensor } else { t_vec };
        let mut top = if rc.is_comm {
            t_net.max(t_mem)
        } else {
            t_compute.max(t_mem)
        };
        top += c::OP_OVERHEAD_S;

        let live = top > 0.0;
        comp_win[l] = !rc.is_comm && t_compute >= t_mem && live;
        net_win[l] = rc.is_comm && t_net >= t_mem && live;
        mem_win[l] = live && !comp_win[l] && !net_win[l];
        t_op[l] = top;
    }
    for l in 0..L {
        let j = i + l;
        let t = t_op[l];
        pt[j] += t;
        st_comp[j] += if comp_win[l] { t } else { 0.0 };
        st_mem[j] += if mem_win[l] { t } else { 0.0 };
        st_net[j] += if net_win[l] { t } else { 0.0 };
        energy[j] += rc.e_row;
    }
}

impl RooflineSim {
    /// Evaluate a batch with the structure-of-arrays kernel: the
    /// machine scalars are derived once per design, then the op table
    /// is walked **once per batch** with a lane-vectorized design-inner
    /// loop per row — the row constants (operand shapes, FLOPs, bytes,
    /// per-row energy prices) stay in registers and the `[f32; L]` lane
    /// windows auto-vectorize. Padding rows (kind sentinel `-1`, which
    /// contribute exactly `0.0` in [`RooflineSim::evaluate`]) are
    /// skipped whole.
    ///
    /// Bit-identity: per design, every expression and accumulation
    /// order matches `evaluate` verbatim (rows in table order, then
    /// the phase leakage term), so results equal `eval_one` bitwise —
    /// asserted for every registered scenario and across lane widths
    /// in `tests/soa_pool.rs`.
    pub fn eval_batch_soa(&self, designs: &[DesignPoint]) -> Vec<Metrics> {
        let mut out = vec![Metrics::default(); designs.len()];
        with_caller_scratch(|s| self.eval_soa_into(designs, &mut out, s));
        out
    }

    /// [`RooflineSim::eval_batch_soa`] writing into a caller buffer
    /// (the pool-worker chunk path), carving all accumulator lanes out
    /// of the reusable `scratch` arena — zero heap allocations once the
    /// arena is warm.
    pub fn eval_soa_into(
        &self,
        designs: &[DesignPoint],
        out: &mut [Metrics],
        scratch: &mut EvalScratch,
    ) {
        self.eval_soa_into_lanes::<SOA_LANES>(designs, out, scratch);
    }

    /// The SoA kernel at an explicit lane width `L`. Lane math is
    /// elementwise, so every width produces bitwise-identical results;
    /// the remainder (`n % L` designs) runs through the same window
    /// body at `L = 1`.
    pub fn eval_soa_into_lanes<const L: usize>(
        &self,
        designs: &[DesignPoint],
        out: &mut [Metrics],
        scratch: &mut EvalScratch,
    ) {
        assert!(L > 0, "lane width must be positive");
        debug_assert_eq!(designs.len(), out.len());
        let n = designs.len();
        if n == 0 {
            return;
        }
        // 18 lanes: 8 derived machine scalars + 2 phases x (wall time,
        // 3 stall buckets, energy) accumulators.
        let [
            arrays, t_peak, v_peak, m_bw, n_bw, sa, sram, area, pt0,
            pt1, s00, s01, s02, s10, s11, s12, en0, en1,
        ] = scratch.lanes::<18>(n);
        for (j, d) in designs.iter().enumerate() {
            let dv = Derived::new(d);
            arrays[j] = dv.arrays;
            t_peak[j] = dv.t_peak;
            v_peak[j] = dv.v_peak;
            m_bw[j] = dv.m_bw;
            n_bw[j] = dv.n_bw;
            sa[j] = dv.sa;
            sram[j] = dv.sram;
            area[j] = dv.area;
        }
        {
            let phases = [
                (
                    &mut *pt0,
                    [&mut *s00, &mut *s01, &mut *s02],
                    &mut *en0,
                ),
                (
                    &mut *pt1,
                    [&mut *s10, &mut *s11, &mut *s12],
                    &mut *en1,
                ),
            ];
            for ((pt, st, en), phase) in
                phases.into_iter().zip(self.table.iter())
            {
                let [st_comp, st_mem, st_net] = st;
                for row in phase {
                    // Row constants (design-independent), hoisted out
                    // of the design lane.
                    let kind = row[0];
                    let is_mm = kind == 0.0;
                    let is_vec = kind == 1.0;
                    let is_comm = kind == 2.0;
                    if !(is_mm || is_vec || is_comm) {
                        // Padding row: contributes exactly 0.0
                        // everywhere in the scalar path.
                        continue;
                    }
                    let flops = row[5];
                    let bytes = row[6];
                    let comm = row[7];
                    // Per-row dynamic-energy price (J), identical to
                    // the scalar path's expressions.
                    let e_compute = if is_mm {
                        flops
                            * (c::E_J_PER_FLOP_SYSTOLIC
                                + c::SRAM_BYTES_PER_FLOP
                                    * c::E_J_PER_BYTE_SRAM)
                    } else if is_vec {
                        flops * c::E_J_PER_FLOP_VECTOR
                    } else {
                        comm * c::E_J_PER_BYTE_LINK
                    };
                    let e_mem = bytes
                        * (c::E_J_PER_BYTE_HBM + c::E_J_PER_BYTE_L2);
                    let rc = RowConsts {
                        is_mm,
                        is_comm,
                        m: row[1].max(1.0),
                        nn: row[2].max(1.0),
                        count: row[4].max(1.0),
                        flops,
                        bytes,
                        comm,
                        kt: row[3].max(1.0).min(c::K_TILE),
                        e_row: e_compute + e_mem,
                    };
                    let mut i = 0;
                    while i + L <= n {
                        row_window::<L>(
                            i, rc, sa, sram, arrays, t_peak, v_peak,
                            m_bw, n_bw, pt, st_comp, st_mem, st_net,
                            en,
                        );
                        i += L;
                    }
                    while i < n {
                        row_window::<1>(
                            i, rc, sa, sram, arrays, t_peak, v_peak,
                            m_bw, n_bw, pt, st_comp, st_mem, st_net,
                            en,
                        );
                        i += 1;
                    }
                }
                // Static leakage: area-proportional draw over the
                // phase wall time (added after the phase's rows, as in
                // the scalar path).
                for j in 0..n {
                    en[j] += c::LEAKAGE_W_PER_MM2 * area[j] * pt[j];
                }
            }
        }
        for (j, slot) in out.iter_mut().enumerate() {
            let prefill_energy_mj = en0[j] * 1e3;
            let energy_per_token_mj = en1[j] * 1e3;
            let ttft_ms = pt0[j] * 1e3;
            let tpot_ms = pt1[j] * 1e3;
            *slot = Metrics {
                ttft_ms,
                tpot_ms,
                area_mm2: area[j],
                energy_per_token_mj,
                prefill_energy_mj,
                avg_power_w: crate::arch::power::avg_power_w(
                    prefill_energy_mj,
                    energy_per_token_mj,
                    ttft_ms,
                    tpot_ms,
                ),
                stalls: [
                    [s00[j] * 1e3, s01[j] * 1e3, s02[j] * 1e3],
                    [s10[j] * 1e3, s11[j] * 1e3, s12[j] * 1e3],
                ],
            };
        }
    }
}

impl EvalOne for RooflineSim {
    fn eval_one(&self, d: &DesignPoint) -> Metrics {
        self.evaluate(d)
    }

    fn label(&self) -> &'static str {
        "roofline-rs"
    }

    fn workload_fingerprint(&self) -> u64 {
        self.spec.fingerprint()
    }

    fn eval_chunk(
        &self,
        designs: &[DesignPoint],
        out: &mut [Metrics],
        scratch: &mut EvalScratch,
    ) {
        self.eval_soa_into(designs, out, scratch);
    }
}

impl Evaluator for RooflineSim {
    fn eval_batch(&mut self, designs: &[DesignPoint]) -> Result<Vec<Metrics>> {
        Ok(self.eval_batch_soa(designs))
    }

    fn name(&self) -> &'static str {
        "roofline-rs"
    }

    fn workload_fingerprint(&self) -> u64 {
        self.spec.fingerprint()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::{Bottleneck, Phase};
    use crate::workload::GPT3_175B;

    fn sim() -> RooflineSim {
        RooflineSim::new(GPT3_175B)
    }

    #[test]
    fn a100_matches_python_reference_numbers() {
        // Values printed by the python oracle for the A100 config
        // (see python/tests): ttft=36.70556, tpot=0.4424397, area=833.9728
        let m = sim().evaluate(&DesignPoint::a100());
        assert!((m.ttft_ms - 36.70556).abs() / 36.70556 < 1e-4, "{m:?}");
        assert!((m.tpot_ms - 0.4424397).abs() / 0.4424397 < 1e-4);
        assert!((m.area_mm2 - 833.9728).abs() / 833.9728 < 1e-4);
    }

    #[test]
    fn a100_energy_matches_python_reference_numbers() {
        // Values printed by the python oracle (kernels/ref.py) for the
        // A100 config: prefill 8116.046 mJ, decode 41.352123 mJ/token,
        // avg power 219.59186 W.
        let m = sim().evaluate(&DesignPoint::a100());
        assert!(
            (m.prefill_energy_mj - 8116.046).abs() / 8116.046 < 1e-4,
            "{m:?}"
        );
        assert!(
            (m.energy_per_token_mj - 41.352123).abs() / 41.352123
                < 1e-4
        );
        assert!((m.avg_power_w - 219.59186).abs() / 219.59186 < 1e-4);
        // The derived field is exactly the shared helper's output.
        assert_eq!(
            m.avg_power_w,
            crate::arch::power::avg_power_w(
                m.prefill_energy_mj,
                m.energy_per_token_mj,
                m.ttft_ms,
                m.tpot_ms
            )
        );
    }

    #[test]
    fn tiny_workload_energy_matches_python() {
        // Python oracle, gpt3-tiny on A100: [14.875684, 1.7696981] mJ.
        let m = RooflineSim::new(crate::workload::GPT3_TINY)
            .evaluate(&DesignPoint::a100());
        assert!(
            (m.prefill_energy_mj - 14.875684).abs() / 14.875684 < 1e-4,
            "{m:?}"
        );
        assert!(
            (m.energy_per_token_mj - 1.7696981).abs() / 1.7696981
                < 1e-4
        );
    }

    #[test]
    fn energy_exceeds_leakage_floor_and_tracks_traffic() {
        use crate::arch::constants as c;
        let s = sim();
        let m = s.evaluate(&DesignPoint::a100());
        // Each phase's energy is at least its leakage-only draw
        // (W * ms = mJ).
        let leak_pf = c::LEAKAGE_W_PER_MM2 * m.area_mm2 * m.ttft_ms;
        let leak_dc = c::LEAKAGE_W_PER_MM2 * m.area_mm2 * m.tpot_ms;
        assert!(m.prefill_energy_mj > leak_pf);
        assert!(m.energy_per_token_mj > leak_dc);
        // More memory channels cut decode *time* but the dominant
        // decode energy term (HBM traffic) is byte-count-bound, so
        // energy/token must not grow with time savings.
        let fast = s.evaluate(
            &DesignPoint::a100().with(Param::MemChannels, 10),
        );
        assert!(fast.tpot_ms < m.tpot_ms);
        assert!(fast.energy_per_token_mj < m.energy_per_token_mj * 1.05);
    }

    #[test]
    fn a100_stall_stack_matches_python() {
        let m = sim().evaluate(&DesignPoint::a100());
        // prefill: [26.794, 3.634, 6.277]; decode: [0, 0.4254, 0.01706]
        assert!((m.stalls[0][0] - 26.794451).abs() < 2e-3, "{m:?}");
        assert!((m.stalls[0][1] - 3.6336124).abs() < 2e-3);
        assert!((m.stalls[0][2] - 6.277494).abs() < 2e-3);
        assert!((m.stalls[1][1] - 0.42538139).abs() < 2e-4);
    }

    #[test]
    fn prefill_compute_bound_decode_memory_bound_on_a100() {
        let m = sim().evaluate(&DesignPoint::a100());
        assert_eq!(m.dominant_bottleneck(Phase::Prefill), Bottleneck::Compute);
        assert_eq!(m.dominant_bottleneck(Phase::Decode), Bottleneck::Memory);
    }

    #[test]
    fn paper_designs_dominate_a100() {
        let s = sim();
        let a100 = s.evaluate(&DesignPoint::a100());
        for d in
            [DesignPoint::paper_design_a(), DesignPoint::paper_design_b()]
        {
            let m = s.evaluate(&d);
            assert!(m.ttft_ms < a100.ttft_ms, "{d}: {m:?}");
            assert!(m.tpot_ms < a100.tpot_ms);
            assert!(m.area_mm2 < a100.area_mm2);
        }
    }

    #[test]
    fn stall_buckets_sum_to_phase_time() {
        let s = sim();
        for d in [
            DesignPoint::a100(),
            DesignPoint::new([6, 1, 1, 4, 4, 32, 32, 1]),
            DesignPoint::new([24, 256, 8, 128, 128, 1024, 1024, 12]),
        ] {
            let m = s.evaluate(&d);
            let pf: f32 = m.stalls[0].iter().sum();
            let dc: f32 = m.stalls[1].iter().sum();
            assert!((pf - m.ttft_ms).abs() / m.ttft_ms < 1e-5);
            assert!((dc - m.tpot_ms).abs() / m.tpot_ms < 1e-5);
        }
    }

    #[test]
    fn batch_eval_matches_single() {
        let mut s = sim();
        let ds = vec![
            DesignPoint::a100(),
            DesignPoint::paper_design_a(),
            DesignPoint::paper_design_b(),
        ];
        let batch = s.eval_batch(&ds).unwrap();
        for (d, b) in ds.iter().zip(&batch) {
            assert_eq!(*b, s.evaluate(d));
        }
    }

    #[test]
    fn soa_batch_is_bitwise_identical_to_eval_one() {
        let s = sim();
        let designs = [
            DesignPoint::a100(),
            DesignPoint::paper_design_a(),
            DesignPoint::paper_design_b(),
            DesignPoint::new([6, 1, 1, 4, 4, 32, 32, 1]),
            DesignPoint::new([24, 256, 8, 128, 128, 1024, 1024, 12]),
        ];
        let soa = s.eval_batch_soa(&designs);
        for (d, got) in designs.iter().zip(&soa) {
            assert_eq!(*got, s.evaluate(d), "{d}");
        }
        let mut out = vec![Metrics::default(); designs.len()];
        s.eval_chunk(&designs, &mut out, &mut EvalScratch::new());
        assert_eq!(out, soa);
        assert!(s.eval_batch_soa(&[]).is_empty());
    }

    #[test]
    fn tiny_workload_runs() {
        let s = RooflineSim::new(crate::workload::GPT3_TINY);
        let m = s.evaluate(&DesignPoint::a100());
        assert!(m.ttft_ms > 0.0 && m.tpot_ms > 0.0);
        assert!(m.ttft_ms < sim().evaluate(&DesignPoint::a100()).ttft_ms);
    }
}
