//! Component-wise die-area model.
//!
//! This is the "area model source code" that the QualE static analysis and
//! the DSE-benchmark perf/area-prediction questions quote verbatim (see
//! `llm::prompts::AREA_MODEL_SOURCE`), so variable names here are part of
//! the prompt interface.

use super::constants as c;
use crate::design::{DesignPoint, Param};

/// Per-component area, mm^2.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AreaBreakdown {
    pub cores: f32,
    pub global_buffer: f32,
    pub memory_phys: f32,
    pub link_phys: f32,
    pub uncore: f32,
}

impl AreaBreakdown {
    pub fn total(&self) -> f32 {
        self.cores
            + self.global_buffer
            + self.memory_phys
            + self.link_phys
            + self.uncore
    }
}

/// Area of one core (SM): fixed base + per-sublane compute (systolic PEs +
/// vector lanes) + register file + scratchpad SRAM.
pub fn core_area_mm2(d: &DesignPoint) -> f32 {
    let sublane_count = d.get(Param::Sublanes) as f32;
    let systolic_array_dim = d.get(Param::SystolicArray) as f32;
    let vector_width = d.get(Param::VectorWidth) as f32;
    let sram_kb = d.get(Param::SramKb) as f32;
    c::AREA_CORE_BASE
        + sublane_count
            * (systolic_array_dim * systolic_array_dim * c::AREA_PER_PE
                + vector_width * c::AREA_PER_LANE)
        + c::AREA_REGFILE
        + sram_kb * c::AREA_SRAM_PER_KB
}

/// Full-die breakdown.
pub fn area_breakdown(d: &DesignPoint) -> AreaBreakdown {
    let core_count = d.get(Param::Cores) as f32;
    let global_buffer_mb = d.get(Param::GbufMb) as f32;
    let memory_channel_count = d.get(Param::MemChannels) as f32;
    let interconnect_link_count = d.get(Param::Links) as f32;
    AreaBreakdown {
        cores: core_count * core_area_mm2(d),
        global_buffer: global_buffer_mb * c::AREA_L2_PER_MB,
        memory_phys: memory_channel_count * c::AREA_HBM_PHY,
        link_phys: interconnect_link_count * c::AREA_LINK_PHY,
        uncore: c::AREA_UNCORE,
    }
}

/// Total die area, mm^2.
pub fn area_mm2(d: &DesignPoint) -> f32 {
    area_breakdown(d).total()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn a100_calibration_within_2pct() {
        let area = area_mm2(&DesignPoint::a100());
        let err = (area - 826.0).abs() / 826.0;
        assert!(err < 0.02, "A100 model area {area} vs 826 real");
    }

    #[test]
    fn table4_relative_areas_hold() {
        // Paper: Design A ~0.77x, Design B ~0.95x of A100.
        let a100 = area_mm2(&DesignPoint::a100());
        let a = area_mm2(&DesignPoint::paper_design_a()) / a100;
        let b = area_mm2(&DesignPoint::paper_design_b()) / a100;
        assert!(a < 0.85 && a > 0.65, "design A ratio {a}");
        assert!(b < 1.05 && b > 0.85, "design B ratio {b}");
        assert!(a < b);
    }

    #[test]
    fn breakdown_sums_to_total() {
        let d = DesignPoint::a100();
        let b = area_breakdown(&d);
        assert!((b.total() - area_mm2(&d)).abs() < 1e-4);
    }

    #[test]
    fn monotone_in_every_parameter() {
        use crate::design::DesignSpace;
        let s = DesignSpace::table1();
        prop::forall(
            21,
            128,
            |rng| s.decode_index(rng.next_u64() % s.size()).unwrap(),
            |d| {
                Param::ALL.iter().all(|&p| {
                    let up = s.step(d, p, 1);
                    up == *d || area_mm2(&up) >= area_mm2(d)
                })
            },
        );
    }

    #[test]
    fn cores_dominate_a100_area() {
        let b = area_breakdown(&DesignPoint::a100());
        assert!(b.cores > b.total() * 0.5);
    }
}
