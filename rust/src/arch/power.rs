//! Component-wise energy/power model, the PPA counterpart of
//! [`super::area`].
//!
//! Two kinds of quantity live here:
//!
//! * **Per-evaluation energy** — [`EnergyBreakdown`], the component
//!   attribution (compute, SRAM staging, L2, HBM, link, leakage) of the
//!   dynamic + static energy a simulated phase consumed. The simulators
//!   accumulate per-op dynamic energy from the same hoisted invariants
//!   that feed their timing models (see `sim::roofline` and
//!   `sim::compass::engine`); this module holds the shared constants
//!   glue so both backends and the Python kernel mirror price a FLOP or
//!   a byte identically.
//! * **Static peak power** — [`tdp_w`], a design-only proxy (every
//!   component drawing at its peak rate, plus leakage). It needs no
//!   simulation, is monotone in every parameter like [`super::area_mm2`],
//!   and is what the Strategy Engine's power envelope checks project
//!   against when vetoing/funding a boost in `--objectives ppa` mode.

use super::constants as c;
use crate::design::{DesignPoint, Param};

/// Per-component energy of one evaluated phase, millijoules.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct EnergyBreakdown {
    pub compute_mj: f32,
    pub sram_mj: f32,
    pub l2_mj: f32,
    pub hbm_mj: f32,
    pub link_mj: f32,
    pub leakage_mj: f32,
}

impl EnergyBreakdown {
    pub fn total_mj(&self) -> f32 {
        self.compute_mj
            + self.sram_mj
            + self.l2_mj
            + self.hbm_mj
            + self.link_mj
            + self.leakage_mj
    }
}

/// Per-component peak power draw, watts.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PowerBreakdown {
    pub tensor: f32,
    pub vector: f32,
    pub sram: f32,
    pub l2: f32,
    pub hbm: f32,
    pub link: f32,
    pub leakage: f32,
}

impl PowerBreakdown {
    pub fn total_w(&self) -> f32 {
        self.tensor
            + self.vector
            + self.sram
            + self.l2
            + self.hbm
            + self.link
            + self.leakage
    }
}

/// Peak L2 (global-buffer) bandwidth, B/s: banked, ~4x HBM at
/// A100-like capacity, scaling sub-linearly with capacity (more banks,
/// same crossbar). The **single** definition shared by the detailed
/// memory timing model (`sim::compass::memory::MemorySystem`) and the
/// peak-power proxy below, so the two can never drift.
pub fn l2_peak_bps(gbuf_mb: f32) -> f32 {
    4.0 * 5.0 * c::HBM_BPS_PER_CHANNEL * (gbuf_mb / 40.0).sqrt()
}

/// Static peak-power breakdown of a design (TDP-style proxy): every
/// compute/memory/link resource drawing at its peak rate, plus leakage
/// proportional to die area. Needs no workload or simulation.
pub fn power_breakdown(d: &DesignPoint) -> PowerBreakdown {
    let links = d.get(Param::Links) as f32;
    let cores = d.get(Param::Cores) as f32;
    let subl = d.get(Param::Sublanes) as f32;
    let sa = d.get(Param::SystolicArray) as f32;
    let vecw = d.get(Param::VectorWidth) as f32;
    let gbuf = d.get(Param::GbufMb) as f32;
    let memch = d.get(Param::MemChannels) as f32;

    let arrays = cores * subl;
    let t_peak = arrays * sa * sa * c::FLOPS_PER_PE * c::CLOCK_HZ;
    let v_peak = arrays * vecw * c::FLOPS_PER_LANE * c::CLOCK_HZ;
    let l2_bw = l2_peak_bps(gbuf);
    PowerBreakdown {
        tensor: t_peak * c::E_J_PER_FLOP_SYSTOLIC,
        vector: v_peak * c::E_J_PER_FLOP_VECTOR,
        sram: t_peak * c::SRAM_BYTES_PER_FLOP * c::E_J_PER_BYTE_SRAM,
        l2: l2_bw * c::E_J_PER_BYTE_L2,
        hbm: memch * c::HBM_BPS_PER_CHANNEL * c::E_J_PER_BYTE_HBM,
        link: links * c::LINK_BPS * c::E_J_PER_BYTE_LINK,
        leakage: c::LEAKAGE_W_PER_MM2 * super::area_mm2(d),
    }
}

/// Total static peak power, watts (the Strategy Engine's power-envelope
/// projection, analogous to [`super::area_mm2`]).
pub fn tdp_w(d: &DesignPoint) -> f32 {
    power_breakdown(d).total_w()
}

/// Normalize `v` by a reference lane, degrading to the **neutral 1.0**
/// when the reference lane is non-positive — the single definition of
/// how degenerate zero-energy references (pre-PPA PJRT artifacts load
/// with zero energy lanes) are scored. Used by the suite composite,
/// Table-4 rows and the scenario-front CSVs;
/// `Metrics::objectives_ppa_vs` applies the same policy pairwise for
/// front tracking.
pub fn norm_or_neutral(v: f32, r: f32) -> f32 {
    if r > 0.0 {
        v / r
    } else {
        1.0
    }
}

/// Time-averaged power over prefill + one decode step, watts
/// (mJ / ms = W). The single definition every metrics producer uses, so
/// the derived field can never drift between backends, the suite
/// composite, and checkpoint reads.
pub fn avg_power_w(
    prefill_energy_mj: f32,
    energy_per_token_mj: f32,
    ttft_ms: f32,
    tpot_ms: f32,
) -> f32 {
    let t = ttft_ms + tpot_ms;
    if t <= 0.0 {
        0.0
    } else {
        (prefill_energy_mj + energy_per_token_mj) / t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a100_tdp_is_in_a_plausible_envelope() {
        // A100-class peak envelope: a few hundred watts.
        let w = tdp_w(&DesignPoint::a100());
        assert!(w > 150.0 && w < 900.0, "A100 tdp proxy {w} W");
    }

    #[test]
    fn breakdown_sums_to_total() {
        let b = power_breakdown(&DesignPoint::a100());
        assert!((b.total_w() - tdp_w(&DesignPoint::a100())).abs() < 1e-3);
        assert!(b.leakage > 0.0 && b.hbm > 0.0 && b.tensor > 0.0);
    }

    #[test]
    fn monotone_in_every_parameter() {
        use crate::design::DesignSpace;
        use crate::util::prop;
        let s = DesignSpace::table1();
        prop::forall(
            23,
            128,
            |rng| s.decode_index(rng.next_u64() % s.size()).unwrap(),
            |d| {
                Param::ALL.iter().all(|&p| {
                    let up = s.step(d, p, 1);
                    up == *d || tdp_w(&up) >= tdp_w(d)
                })
            },
        );
    }

    #[test]
    fn wider_systolic_arrays_dominate_the_power_envelope() {
        // The utilization pitfall has a power twin: doubling the array
        // dim quadruples peak MAC power, which is exactly what the
        // power-aware corrective rule must see to veto decode-bound
        // systolic growth in ppa mode.
        let base = power_breakdown(&DesignPoint::a100());
        let wide = power_breakdown(
            &DesignPoint::a100().with(Param::SystolicArray, 32),
        );
        assert!(wide.tensor > base.tensor * 3.5);
        assert!(wide.total_w() > base.total_w() * 1.3);
        // Memory channels are the power-cheap boost by comparison.
        let chan = power_breakdown(
            &DesignPoint::a100().with(Param::MemChannels, 6),
        );
        assert!(chan.total_w() < base.total_w() * 1.1);
    }

    #[test]
    fn avg_power_is_energy_over_time() {
        assert_eq!(avg_power_w(30.0, 10.0, 3.0, 1.0), 10.0);
        assert_eq!(avg_power_w(1.0, 1.0, 0.0, 0.0), 0.0);
    }

    #[test]
    fn norm_or_neutral_degrades_zero_references_to_unity() {
        assert_eq!(norm_or_neutral(2.0, 4.0), 0.5);
        assert_eq!(norm_or_neutral(5.0, 0.0), 1.0);
        assert_eq!(norm_or_neutral(0.0, 0.0), 1.0);
    }

    #[test]
    fn energy_breakdown_totals() {
        let e = EnergyBreakdown {
            compute_mj: 1.0,
            sram_mj: 2.0,
            l2_mj: 3.0,
            hbm_mj: 4.0,
            link_mj: 5.0,
            leakage_mj: 6.0,
        };
        assert!((e.total_mj() - 21.0).abs() < 1e-6);
        assert_eq!(EnergyBreakdown::default().total_mj(), 0.0);
    }
}
