//! Performance/area model constants.
//!
//! MIRROR of `python/compile/constants.py` — keep in lockstep. The
//! integration test `artifact_matches_rust_mirror_on_random_designs`
//! (`tests/artifact_vs_mirror.rs`) cross-checks the lowered artifact
//! against `sim::roofline` (which consumes these constants) on random
//! designs, so any drift fails `cargo test`; `lumina lint --mirror`
//! proves the literals equal statically (pair `arch-constants`).
//!
//! All math on both sides is float32; units are seconds / bytes / FLOPs /
//! mm^2, frequencies in Hz, bandwidths in B/s.

// ---------------------------------------------------------------- compute
pub const CLOCK_HZ: f32 = 1.41e9;
pub const FLOPS_PER_PE: f32 = 2.0;
pub const FLOPS_PER_LANE: f32 = 2.0;
pub const K_TILE: f32 = 128.0;

// ---------------------------------------------------------------- memory
pub const HBM_BPS_PER_CHANNEL: f32 = 408.0e9;
pub const MEM_EFF_BASE: f32 = 0.55;
pub const MEM_EFF_L2_SLOPE: f32 = 0.08;
pub const MEM_EFF_MAX: f32 = 0.92;
pub const SRAM_UTIL_FLOOR: f32 = 0.25;

// ----------------------------------------------------------- interconnect
pub const LINK_BPS: f32 = 25.0e9;
pub const NET_EFF: f32 = 0.75;
pub const ALLREDUCE_LAT_S: f32 = 5.0e-6;

// ---------------------------------------------------------------- timing
pub const OP_OVERHEAD_S: f32 = 2.0e-6;
pub const FP16_BYTES: f32 = 2.0;

// ---------------------------------------------------------------- energy
// Per-operation dynamic energy (joules per FLOP / per byte moved) and a
// leakage density proportional to die area. Calibrated to land the A100
// reference at a plausible inference power envelope (see the sanity
// tests in `arch::power` and EXPERIMENTS.md §PPA).
pub const E_J_PER_FLOP_SYSTOLIC: f32 = 0.45e-12;
pub const E_J_PER_FLOP_VECTOR: f32 = 1.1e-12;
pub const E_J_PER_BYTE_SRAM: f32 = 0.18e-12;
/// Operand bytes staged through SRAM per FLOP of systolic work
/// (one MAC = 2 FLOPs reads two fp16 operands = 4 bytes).
pub const SRAM_BYTES_PER_FLOP: f32 = 2.0;
pub const E_J_PER_BYTE_L2: f32 = 1.5e-12;
pub const E_J_PER_BYTE_HBM: f32 = 31.0e-12;
pub const E_J_PER_BYTE_LINK: f32 = 60.0e-12;
pub const LEAKAGE_W_PER_MM2: f32 = 0.05;

// ------------------------------------------------------------------ area
pub const AREA_CORE_BASE: f32 = 1.5;
pub const AREA_PER_PE: f32 = 0.0004;
pub const AREA_PER_LANE: f32 = 0.012;
pub const AREA_REGFILE: f32 = 1.1;
pub const AREA_SRAM_PER_KB: f32 = 0.0055;
pub const AREA_L2_PER_MB: f32 = 1.9;
pub const AREA_HBM_PHY: f32 = 15.0;
pub const AREA_LINK_PHY: f32 = 1.5;
pub const AREA_UNCORE: f32 = 60.0;
