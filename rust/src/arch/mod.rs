//! Architecture model: the shared performance/area/energy constants
//! (mirror of `python/compile/constants.py`), the component-wise area
//! model, and the energy/power model (per-op dynamic energy pricing and
//! the static peak-power proxy the PPA objective mode uses).

pub mod area;
pub mod constants;
pub mod power;

pub use area::{area_breakdown, area_mm2, AreaBreakdown};
pub use power::{
    avg_power_w, power_breakdown, tdp_w, EnergyBreakdown, PowerBreakdown,
};
