//! Architecture model: the shared performance/area constants (mirror of
//! `python/compile/constants.py`) and the component-wise area model.

pub mod area;
pub mod constants;

pub use area::{area_breakdown, area_mm2, AreaBreakdown};
