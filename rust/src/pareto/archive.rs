//! Incremental Pareto archive: maintains the non-dominated front and the
//! dominated hypervolume under point insertion, so trajectory consumers
//! (the PHV race, Table 4 picks, LUMINA's Trajectory Memory) never
//! recompute either from scratch per step.
//!
//! Front maintenance is the classic archive update: a new point is
//! rejected if any archived point dominates or equals it (equality keeps
//! the *first* occurrence, matching [`pareto_front`]'s tie rule);
//! otherwise archived points it dominates are evicted and the point is
//! appended. Entries therefore stay in insertion order, so
//! [`ParetoArchive::front_ids`] reproduces [`pareto_front`]'s output on
//! the same sequence exactly.
//!
//! The hypervolume update adds the new point's *exclusive* contribution:
//! for minimization, the region a point `o` dominates inside the
//! reference box is `[o, r]`, and the part already covered by an
//! archived point `p` is `[max(p, o), r]` — so the increment is
//! `vol([o, r])` minus the hypervolume of the coordinate-wise-clipped
//! front. Evicted points change nothing (their region is a subset of the
//! new point's). Each insertion costs one O(f^2 log f) sweep over the
//! current front `f`, which stays tiny next to the O(n^2 log n)
//! from-scratch recomputation per step it replaces.
//!
//! [`pareto_front`]: crate::pareto::pareto_front

use super::{dominates, hypervolume, Objectives};

/// Incrementally maintained Pareto front + hypervolume, generic over the
/// objective dimensionality (3-D latency-area by default, 4-D for the
/// `ppa` mode).
#[derive(Debug, Clone)]
pub struct ParetoArchive<const D: usize = 3> {
    reference: Objectives<D>,
    /// Non-dominated `(id, point)` entries, in insertion order.
    entries: Vec<(usize, Objectives<D>)>,
    hv: f64,
    pushed: usize,
}

impl<const D: usize> Default for ParetoArchive<D> {
    /// Front-only archive (see [`ParetoArchive::front_only`]).
    fn default() -> Self {
        Self::front_only()
    }
}

impl<const D: usize> ParetoArchive<D> {
    /// Archive tracking hypervolume against `reference`.
    pub fn new(reference: Objectives<D>) -> Self {
        Self { reference, entries: Vec::new(), hv: 0.0, pushed: 0 }
    }

    /// Archive that only maintains the front (no finite reference box,
    /// hypervolume stays 0) — for callers that need front membership of
    /// raw, unnormalized objectives.
    pub fn front_only() -> Self {
        Self::new([f64::INFINITY; D])
    }

    /// Insert with an auto-assigned id (`0, 1, 2, ...` in push order, so
    /// ids equal trajectory indices). Returns true iff the point joined
    /// the front.
    pub fn push(&mut self, o: Objectives<D>) -> bool {
        self.push_with_id(self.pushed, o)
    }

    /// Insert with an explicit caller id. Returns true iff the point
    /// joined the front.
    pub fn push_with_id(&mut self, id: usize, o: Objectives<D>) -> bool {
        self.pushed += 1;
        if self
            .entries
            .iter()
            .any(|(_, p)| dominates(p, &o) || *p == o)
        {
            return false;
        }
        if (0..D).all(|i| o[i] < self.reference[i])
            && self.reference.iter().all(|r| r.is_finite())
        {
            let boxed: f64 =
                (0..D).map(|i| self.reference[i] - o[i]).product();
            let clipped: Vec<Objectives<D>> = self
                .entries
                .iter()
                .map(|(_, p)| {
                    std::array::from_fn(|i| p[i].max(o[i]))
                })
                .collect();
            let covered = hypervolume(&clipped, &self.reference);
            self.hv += (boxed - covered).max(0.0);
        }
        self.entries.retain(|(_, p)| !dominates(&o, p));
        self.entries.push((id, o));
        true
    }

    /// Dominated hypervolume w.r.t. the reference, accumulated
    /// incrementally.
    pub fn hypervolume(&self) -> f64 {
        self.hv
    }

    /// Ids of the current front, in insertion order (equal to
    /// `pareto_front` of the pushed sequence when ids are push indices).
    pub fn front_ids(&self) -> Vec<usize> {
        self.entries.iter().map(|(id, _)| *id).collect()
    }

    /// Objective vectors of the current front, in insertion order.
    pub fn front(&self) -> Vec<Objectives<D>> {
        self.entries.iter().map(|(_, p)| *p).collect()
    }

    /// Number of points on the front.
    pub fn front_len(&self) -> usize {
        self.entries.len()
    }

    /// Total points pushed (front or not).
    pub fn len(&self) -> usize {
        self.pushed
    }

    pub fn is_empty(&self) -> bool {
        self.pushed == 0
    }

    pub fn reference(&self) -> &Objectives<D> {
        &self.reference
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pareto::pareto_front;

    #[test]
    fn front_tracks_insertion_order_and_evictions() {
        let mut ar = ParetoArchive::front_only();
        assert!(ar.push([3.0, 3.0, 3.0])); // id 0
        assert!(ar.push([4.0, 1.0, 4.0])); // id 1, incomparable
        assert!(!ar.push([5.0, 5.0, 5.0])); // dominated by id 0
        assert!(!ar.push([3.0, 3.0, 3.0])); // duplicate: first wins
        assert!(ar.push([2.0, 2.0, 2.0])); // id 4, evicts id 0
        assert_eq!(ar.front_ids(), vec![1, 4]);
        assert_eq!(ar.front_len(), 2);
        assert_eq!(ar.len(), 5);
        assert_eq!(ar.hypervolume(), 0.0); // front-only archives track no HV
    }

    #[test]
    fn hv_matches_batch_on_known_boxes() {
        // Same fixtures as pareto::tests::hv_union_of_two_boxes.
        let r = [2.0, 2.0, 2.0];
        let mut ar = ParetoArchive::new(r);
        ar.push([1.0, 1.0, 1.0]);
        assert!((ar.hypervolume() - 1.0).abs() < 1e-12);
        ar.push([0.0, 1.5, 1.5]);
        assert!((ar.hypervolume() - 1.25).abs() < 1e-9);
        // Dominated and out-of-box points add nothing.
        ar.push([1.5, 1.5, 1.5]);
        ar.push([3.0, 0.5, 0.5]);
        assert!((ar.hypervolume() - 1.25).abs() < 1e-9);
    }

    #[test]
    fn four_dimensional_archive_tracks_hv_incrementally() {
        // The ppa-mode archive: same update rule, one more lane. HV of
        // each prefix must match a from-scratch batch hypervolume.
        use crate::pareto::hypervolume;
        let r = [2.0, 2.0, 2.0, 2.0];
        let pts: Vec<[f64; 4]> = vec![
            [1.0, 1.0, 1.0, 1.0],
            [0.5, 1.5, 1.5, 1.5],
            [1.5, 0.5, 1.5, 0.5],
            [1.2, 1.2, 1.2, 1.2], // dominated by the first point
            [3.0, 0.1, 0.1, 0.1], // on the front, outside the ref box
        ];
        let mut ar: ParetoArchive<4> = ParetoArchive::new(r);
        for (i, p) in pts.iter().enumerate() {
            ar.push(*p);
            let batch = hypervolume(&pts[..=i], &r);
            assert!(
                (ar.hypervolume() - batch).abs() < 1e-9,
                "prefix {i}: incremental {} vs batch {batch}",
                ar.hypervolume()
            );
        }
        // Front keeps the out-of-box point (fronts are reference-free);
        // only the dominated one is excluded.
        assert_eq!(ar.front_len(), 4);
    }

    #[test]
    fn ids_reproduce_batch_pareto_front() {
        let pts = [
            [1.0, 4.0, 4.0],
            [4.0, 1.0, 4.0],
            [4.0, 4.0, 1.0],
            [3.0, 3.0, 3.0],
            [5.0, 5.0, 5.0],
            [1.0, 4.0, 4.0], // duplicate of 0
        ];
        let mut ar = ParetoArchive::front_only();
        for p in pts {
            ar.push(p);
        }
        assert_eq!(ar.front_ids(), pareto_front(&pts));
    }
}
