//! Pareto analytics: dominance, frontier extraction, hypervolume (PHV) and
//! the paper's Sample Efficiency metric.
//!
//! Conventions: all objectives are **minimized** (TTFT ms, TPOT ms, area
//! mm^2). PHV is computed against a reference point `r`; only points that
//! dominate `r` contribute. Objectives are normalized by the A100
//! reference before PHV so the paper's "normalized PHV" comparisons hold.

pub mod archive;

pub use archive::ParetoArchive;

/// An objective vector (minimize each lane).
pub type Objectives = [f64; 3];

/// True iff `a` dominates `b` (<= everywhere, < somewhere).
pub fn dominates(a: &Objectives, b: &Objectives) -> bool {
    let mut strictly = false;
    for i in 0..3 {
        if a[i] > b[i] {
            return false;
        }
        if a[i] < b[i] {
            strictly = true;
        }
    }
    strictly
}

/// Indices of the non-dominated subset (first occurrence wins on ties).
///
/// Sort-based 3-objective skyline sweep, O(n log n): process points in
/// lexicographic `(x, y, z, index)` order — every dominator of a point
/// sorts strictly before it — and keep a Fenwick tree of the minimum `z`
/// seen per compressed `y` rank. A point is dominated (or a repeat of an
/// earlier identical point) exactly when some already-processed point
/// with `y <= y_q` has `z <= z_q`.
pub fn pareto_front(points: &[Objectives]) -> Vec<usize> {
    let n = points.len();
    if n <= 1 {
        return (0..n).collect();
    }
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| {
        points[a]
            .partial_cmp(&points[b])
            .expect("objectives must not be NaN")
            .then(a.cmp(&b))
    });

    // Compress y coordinates to Fenwick ranks.
    let mut ys: Vec<f64> = points.iter().map(|p| p[1]).collect();
    ys.sort_by(|a, b| a.partial_cmp(b).unwrap());
    ys.dedup();

    // Fenwick tree over y ranks holding prefix-minimum z (insert-only).
    let mut tree = vec![f64::INFINITY; ys.len() + 1];
    let mut keep = vec![false; n];
    for &i in &order {
        let p = &points[i];
        // 1-based rank of the largest tree index with y <= p[1].
        let r = ys.partition_point(|&v| v < p[1]) + 1;
        let mut min_z = f64::INFINITY;
        let mut j = r;
        while j > 0 {
            min_z = min_z.min(tree[j]);
            j -= j & j.wrapping_neg();
        }
        // No earlier-sorted point covers (y, z) => non-dominated.
        if min_z > p[2] {
            keep[i] = true;
        }
        let mut j = r;
        while j < tree.len() {
            if p[2] < tree[j] {
                tree[j] = p[2];
            }
            j += j & j.wrapping_neg();
        }
    }
    (0..n).filter(|&i| keep[i]).collect()
}

/// Reference O(n^2) pairwise-dominance front — the oracle the sweep is
/// property-tested against (`front_sweep_matches_pairwise_oracle`).
pub fn pareto_front_pairwise(points: &[Objectives]) -> Vec<usize> {
    let mut front = Vec::new();
    'outer: for (i, p) in points.iter().enumerate() {
        for (j, q) in points.iter().enumerate() {
            if i != j && (dominates(q, p) || (q == p && j < i)) {
                continue 'outer;
            }
        }
        front.push(i);
    }
    front
}

/// Exact 3-D hypervolume dominated by `points` w.r.t. reference `r`
/// (minimization). Points not strictly better than `r` in all objectives
/// contribute nothing. O(n^2 log n) slicing — fine for n <= a few 1000.
pub fn hypervolume(points: &[Objectives], r: &Objectives) -> f64 {
    // Keep only points that improve on the reference everywhere.
    let mut pts: Vec<Objectives> = points
        .iter()
        .filter(|p| (0..3).all(|i| p[i] < r[i]))
        .copied()
        .collect();
    if pts.is_empty() {
        return 0.0;
    }
    // Dominated points contribute no volume; reducing to the front first
    // cuts the O(n^2 log n) sweep to the (much smaller) front size.
    // (§Perf iteration 1: 624us -> ~60us on 1,000-point trajectories.)
    if pts.len() > 64 {
        pts = pareto_front(&pts).into_iter().map(|i| pts[i]).collect();
    }
    // Slice along z: between consecutive z-levels, the xy cross-section is
    // the union of rectangles [x_i, rx] x [y_i, ry] for points with z_i <=
    // slab bottom.
    let mut zs: Vec<f64> = pts.iter().map(|p| p[2]).collect();
    zs.push(r[2]);
    zs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    zs.dedup();

    let mut vol = 0.0;
    for w in zs.windows(2) {
        let (z0, z1) = (w[0], w[1]);
        let live: Vec<[f64; 2]> = pts
            .iter()
            .filter(|p| p[2] <= z0)
            .map(|p| [p[0], p[1]])
            .collect();
        vol += area2d(&live, r[0], r[1]) * (z1 - z0);
    }
    vol
}

/// Area of the union of [x_i, rx] x [y_i, ry] rectangles (staircase sweep).
fn area2d(pts: &[[f64; 2]], rx: f64, ry: f64) -> f64 {
    if pts.is_empty() {
        return 0.0;
    }
    let mut sorted: Vec<[f64; 2]> = pts.to_vec();
    // Sort by x ascending; sweep keeping the lowest y seen so far.
    sorted.sort_by(|a, b| a[0].partial_cmp(&b[0]).unwrap());
    let mut area = 0.0;
    let mut best_y = ry;
    let mut prev_x = sorted[0][0];
    for p in &sorted {
        if p[0] > prev_x {
            area += (p[0] - prev_x) * (ry - best_y);
            prev_x = p[0];
        }
        if p[1] < best_y {
            best_y = p[1];
        }
    }
    area += (rx - prev_x) * (ry - best_y);
    area
}

/// Paper §5.3: fraction of evaluated designs strictly better than the
/// reference point in **all** objectives.
pub fn sample_efficiency(points: &[Objectives], reference: &Objectives) -> f64 {
    if points.is_empty() {
        return 0.0;
    }
    let better = points
        .iter()
        .filter(|p| (0..3).all(|i| p[i] < reference[i]))
        .count();
    better as f64 / points.len() as f64
}

/// Count of designs strictly better than the reference in all objectives.
pub fn superior_count(points: &[Objectives], reference: &Objectives) -> usize {
    points
        .iter()
        .filter(|p| (0..3).all(|i| p[i] < reference[i]))
        .count()
}

/// Normalize objective vectors by a baseline (A100), so PHV is unitless.
pub fn normalize(points: &[Objectives], baseline: &Objectives) -> Vec<Objectives> {
    points
        .iter()
        .map(|p| {
            [
                p[0] / baseline[0],
                p[1] / baseline[1],
                p[2] / baseline[2],
            ]
        })
        .collect()
}

/// The PHV reference point used throughout the evaluation: 2x the A100 on
/// every normalized objective (designs worse than 2x A100 in any metric
/// contribute no volume).
pub const PHV_REF: Objectives = [2.0, 2.0, 2.0];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::rng::Pcg32;
    use crate::util::prop;

    #[test]
    fn dominance_basics() {
        assert!(dominates(&[1.0, 1.0, 1.0], &[2.0, 2.0, 2.0]));
        assert!(dominates(&[1.0, 2.0, 2.0], &[2.0, 2.0, 2.0]));
        assert!(!dominates(&[2.0, 2.0, 2.0], &[2.0, 2.0, 2.0]));
        assert!(!dominates(&[1.0, 3.0, 1.0], &[2.0, 2.0, 2.0]));
    }

    #[test]
    fn front_excludes_dominated() {
        let pts = vec![
            [1.0, 4.0, 4.0],
            [4.0, 1.0, 4.0],
            [4.0, 4.0, 1.0],
            [3.0, 3.0, 3.0],
            [5.0, 5.0, 5.0], // dominated by everything
        ];
        let f = pareto_front(&pts);
        assert_eq!(f, vec![0, 1, 2, 3]);
    }

    #[test]
    fn front_dedups_ties() {
        let pts = vec![[1.0, 1.0, 1.0], [1.0, 1.0, 1.0]];
        assert_eq!(pareto_front(&pts), vec![0]);
    }

    #[test]
    fn front_sweep_matches_pairwise_oracle() {
        // Random sets with deliberate duplicates and shared coordinates
        // (a quantized grid makes axis ties common): the O(n log n)
        // sweep must reproduce the O(n^2) oracle exactly, including the
        // first-occurrence tie rule.
        prop::forall(
            1133,
            96,
            |r| {
                let n = r.range_usize(0, 40);
                let mut pts: Vec<Objectives> = (0..n)
                    .map(|_| {
                        [
                            r.range_usize(0, 6) as f64,
                            r.range_usize(0, 6) as f64,
                            r.range_usize(0, 6) as f64,
                        ]
                    })
                    .collect();
                // Inject exact duplicates of earlier points.
                for _ in 0..n / 4 {
                    let i = r.range_usize(0, pts.len().max(1));
                    if i < pts.len() {
                        let p = pts[i];
                        pts.push(p);
                    }
                }
                pts
            },
            |pts| pareto_front(pts) == pareto_front_pairwise(pts),
        );
    }

    #[test]
    fn hv_single_point_box() {
        let hv = hypervolume(&[[1.0, 1.0, 1.0]], &[2.0, 2.0, 2.0]);
        assert!((hv - 1.0).abs() < 1e-12);
    }

    #[test]
    fn hv_ignores_points_outside_reference() {
        let hv = hypervolume(
            &[[3.0, 1.0, 1.0], [1.0, 1.0, 2.5]],
            &[2.0, 2.0, 2.0],
        );
        assert_eq!(hv, 0.0);
    }

    #[test]
    fn hv_union_of_two_boxes() {
        // Boxes [1,2]^3 and [0,2]x[1.5,2]x[1.5,2]:
        // vol = 1 + 2*0.5*0.5 - 1*0.5*0.5 = 1.25
        let hv = hypervolume(
            &[[1.0, 1.0, 1.0], [0.0, 1.5, 1.5]],
            &[2.0, 2.0, 2.0],
        );
        assert!((hv - 1.25).abs() < 1e-9, "hv={hv}");
    }

    #[test]
    fn hv_dominated_point_adds_nothing() {
        let a = hypervolume(&[[1.0, 1.0, 1.0]], &[2.0, 2.0, 2.0]);
        let b = hypervolume(
            &[[1.0, 1.0, 1.0], [1.5, 1.5, 1.5]],
            &[2.0, 2.0, 2.0],
        );
        assert!((a - b).abs() < 1e-12);
    }

    #[test]
    fn hv_monotone_under_adding_points_property() {
        let mut rng = Pcg32::new(31);
        prop::forall(
            32,
            64,
            move |r| {
                let n = r.range_usize(1, 12);
                (0..n)
                    .map(|_| {
                        [r.f64() * 2.0, r.f64() * 2.0, r.f64() * 2.0]
                    })
                    .collect::<Vec<Objectives>>()
            },
            |pts| {
                let r = [1.8, 1.8, 1.8];
                let hv_all = hypervolume(pts, &r);
                let hv_front: f64 = hypervolume(
                    &pareto_front(pts)
                        .into_iter()
                        .map(|i| pts[i])
                        .collect::<Vec<_>>(),
                    &r,
                );
                // Front alone has identical HV, and dropping a point never
                // increases HV.
                let hv_less = if pts.len() > 1 {
                    hypervolume(&pts[1..], &r)
                } else {
                    0.0
                };
                (hv_all - hv_front).abs() < 1e-9 && hv_less <= hv_all + 1e-9
            },
        );
        let _ = rng.next_u32();
    }

    #[test]
    fn hv_brute_force_monte_carlo_agreement() {
        let pts = vec![
            [0.3, 1.2, 0.9],
            [1.0, 0.2, 1.4],
            [0.8, 0.8, 0.4],
            [1.5, 1.5, 0.1],
        ];
        let r = [1.8, 1.6, 1.7];
        let exact = hypervolume(&pts, &r);
        // Monte-Carlo estimate.
        let mut rng = Pcg32::new(99);
        let n = 200_000;
        let mut hits = 0usize;
        for _ in 0..n {
            let x = [
                rng.f64() * r[0],
                rng.f64() * r[1],
                rng.f64() * r[2],
            ];
            if pts
                .iter()
                .any(|p| (0..3).all(|i| p[i] < r[i] && p[i] <= x[i]))
            {
                hits += 1;
            }
        }
        let mc = hits as f64 / n as f64 * (r[0] * r[1] * r[2]);
        assert!(
            (exact - mc).abs() / exact < 0.02,
            "exact={exact} mc={mc}"
        );
    }

    #[test]
    fn sample_efficiency_counts_strict_improvements() {
        let r = [1.0, 1.0, 1.0];
        let pts = vec![
            [0.9, 0.9, 0.9], // better
            [0.9, 1.1, 0.9], // worse in one
            [1.0, 0.9, 0.9], // tie in one -> not strictly better
            [0.5, 0.5, 0.5], // better
        ];
        assert!((sample_efficiency(&pts, &r) - 0.5).abs() < 1e-12);
        assert_eq!(superior_count(&pts, &r), 2);
    }

    #[test]
    fn normalize_by_baseline() {
        let pts = vec![[2.0, 4.0, 8.0]];
        let n = normalize(&pts, &[2.0, 2.0, 2.0]);
        assert_eq!(n[0], [1.0, 2.0, 4.0]);
    }
}
