//! Pareto analytics: dominance, frontier extraction, hypervolume (PHV) and
//! the paper's Sample Efficiency metric.
//!
//! Conventions: all objectives are **minimized**. The objective vector is
//! dimension-generic (`Objectives<D>`, a `[f64; D]`): the default 3-D
//! vector is (TTFT ms, TPOT ms, area mm^2) and the 4-D `ppa` mode appends
//! energy/token mJ (see [`ObjectiveMode`]). PHV is computed against a
//! reference point `r`; only points that dominate `r` contribute.
//! Objectives are normalized by the A100 reference before PHV so the
//! paper's "normalized PHV" comparisons hold.
//!
//! The 3-D hot paths (Fenwick skyline front sweep, slab-sliced exact
//! hypervolume) are kept verbatim and dispatched to from the generic
//! entry points, so default-mode results are bit-identical to the
//! pre-generalization implementation; other dimensions use a pairwise
//! front and a recursive last-axis slicing hypervolume, cross-checked by
//! a Monte-Carlo oracle at D=3 and D=4.

pub mod archive;

pub use archive::ParetoArchive;

/// An objective vector (minimize each lane). `Objectives` with no
/// argument is the historical 3-D (TTFT, TPOT, area) vector.
pub type Objectives<const D: usize = 3> = [f64; D];

/// Which objective vector exploration optimizes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ObjectiveMode {
    /// 3-D (TTFT, TPOT, area) — the historical default.
    #[default]
    LatencyArea,
    /// 4-D (TTFT, TPOT, area, energy/token) — full PPA.
    Ppa,
}

impl ObjectiveMode {
    pub const ALL: [ObjectiveMode; 2] =
        [ObjectiveMode::LatencyArea, ObjectiveMode::Ppa];

    pub fn name(self) -> &'static str {
        match self {
            ObjectiveMode::LatencyArea => "latency-area",
            ObjectiveMode::Ppa => "ppa",
        }
    }

    /// Objective-vector dimensionality.
    pub fn dim(self) -> usize {
        match self {
            ObjectiveMode::LatencyArea => 3,
            ObjectiveMode::Ppa => 4,
        }
    }

    /// Parse a CLI/`SessionState` name.
    pub fn parse(s: &str) -> Option<ObjectiveMode> {
        match s {
            "latency-area" => Some(ObjectiveMode::LatencyArea),
            "ppa" => Some(ObjectiveMode::Ppa),
            _ => None,
        }
    }
}

impl std::fmt::Display for ObjectiveMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// True iff `a` dominates `b` (<= everywhere, < somewhere).
pub fn dominates<const D: usize>(
    a: &Objectives<D>,
    b: &Objectives<D>,
) -> bool {
    let mut strictly = false;
    for i in 0..D {
        if a[i] > b[i] {
            return false;
        }
        if a[i] < b[i] {
            strictly = true;
        }
    }
    strictly
}

/// Indices of the non-dominated subset (first occurrence wins on ties).
///
/// D=3 dispatches to the sort-based Fenwick skyline sweep (O(n log n),
/// unchanged from the 3-D-only implementation); other dimensions use the
/// pairwise oracle (O(n^2) — D=4 sets are front-reduction inputs of a
/// few hundred points, far from the sweep's break-even).
pub fn pareto_front<const D: usize>(points: &[Objectives<D>]) -> Vec<usize> {
    if D == 3 {
        return pareto_front3(points);
    }
    pareto_front_pairwise(points)
}

/// The 3-objective skyline sweep: process points in lexicographic
/// `(x, y, z, index)` order — every dominator of a point sorts strictly
/// before it — and keep a Fenwick tree of the minimum `z` seen per
/// compressed `y` rank. A point is dominated (or a repeat of an earlier
/// identical point) exactly when some already-processed point with
/// `y <= y_q` has `z <= z_q`.
///
/// Generic over `D` only so the `D == 3` dispatch avoids copying the
/// input (lanes 0..3 are indexed directly; callers guarantee `D == 3`,
/// where the whole-array lexicographic sort is exactly the historical
/// 3-lane sort).
fn pareto_front3<const D: usize>(points: &[Objectives<D>]) -> Vec<usize> {
    let n = points.len();
    if n <= 1 {
        return (0..n).collect();
    }
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| {
        // Elementwise total_cmp chain: identical to the array's
        // lexicographic PartialOrd on NaN-free data, total on all.
        points[a]
            .iter()
            .zip(points[b].iter())
            .map(|(x, y)| x.total_cmp(y))
            .find(|o| o.is_ne())
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });

    // Compress y coordinates to Fenwick ranks.
    let mut ys: Vec<f64> = points.iter().map(|p| p[1]).collect();
    ys.sort_by(f64::total_cmp);
    ys.dedup();

    // Fenwick tree over y ranks holding prefix-minimum z (insert-only).
    let mut tree = vec![f64::INFINITY; ys.len() + 1];
    let mut keep = vec![false; n];
    for &i in &order {
        let p = &points[i];
        // 1-based rank of the largest tree index with y <= p[1].
        let r = ys.partition_point(|&v| v < p[1]) + 1;
        let mut min_z = f64::INFINITY;
        let mut j = r;
        while j > 0 {
            min_z = min_z.min(tree[j]);
            j -= j & j.wrapping_neg();
        }
        // No earlier-sorted point covers (y, z) => non-dominated.
        if min_z > p[2] {
            keep[i] = true;
        }
        let mut j = r;
        while j < tree.len() {
            if p[2] < tree[j] {
                tree[j] = p[2];
            }
            j += j & j.wrapping_neg();
        }
    }
    (0..n).filter(|&i| keep[i]).collect()
}

/// Reference O(n^2) pairwise-dominance front — the oracle the sweep is
/// property-tested against (`front_sweep_matches_pairwise_oracle`) and
/// the execution path for D != 3.
pub fn pareto_front_pairwise<const D: usize>(
    points: &[Objectives<D>],
) -> Vec<usize> {
    let mut front = Vec::new();
    'outer: for (i, p) in points.iter().enumerate() {
        for (j, q) in points.iter().enumerate() {
            if i != j && (dominates(q, p) || (q == p && j < i)) {
                continue 'outer;
            }
        }
        front.push(i);
    }
    front
}

/// Exact D-dimensional hypervolume dominated by `points` w.r.t. reference
/// `r` (minimization). Points not strictly better than `r` in all
/// objectives contribute nothing. D=3 runs the historical slab-slicing
/// implementation verbatim (bit-identical results); other dimensions
/// recurse on the last axis down to the same 2-D staircase base case.
pub fn hypervolume<const D: usize>(
    points: &[Objectives<D>],
    r: &Objectives<D>,
) -> f64 {
    // Keep only points that improve on the reference everywhere.
    let mut pts: Vec<Objectives<D>> = points
        .iter()
        .filter(|p| (0..D).all(|i| p[i] < r[i]))
        .copied()
        .collect();
    if pts.is_empty() {
        return 0.0;
    }
    // Dominated points contribute no volume; reducing to the front first
    // cuts the slicing sweep to the (much smaller) front size.
    // (§Perf iteration 1: 624us -> ~60us on 1,000-point trajectories.)
    if pts.len() > 64 {
        pts = pareto_front(&pts).into_iter().map(|i| pts[i]).collect();
    }
    if D == 3 {
        return hv3(&pts, r);
    }
    let dyn_pts: Vec<Vec<f64>> =
        pts.iter().map(|p| p.to_vec()).collect();
    hv_slices(&dyn_pts, r)
}

/// The historical 3-D implementation: slice along z — between
/// consecutive z-levels, the xy cross-section is the union of rectangles
/// [x_i, rx] x [y_i, ry] for points with z_i <= slab bottom.
/// O(n^2 log n) slicing — fine for n <= a few 1000. Generic over `D`
/// only so the `D == 3` dispatch avoids copying (callers guarantee
/// `D == 3`; lanes 0..3 are indexed directly).
fn hv3<const D: usize>(pts: &[Objectives<D>], r: &Objectives<D>) -> f64 {
    let mut zs: Vec<f64> = pts.iter().map(|p| p[2]).collect();
    zs.push(r[2]);
    zs.sort_by(f64::total_cmp);
    zs.dedup();

    let mut vol = 0.0;
    for w in zs.windows(2) {
        let (z0, z1) = (w[0], w[1]);
        let live: Vec<[f64; 2]> = pts
            .iter()
            .filter(|p| p[2] <= z0)
            .map(|p| [p[0], p[1]])
            .collect();
        vol += area2d(&live, r[0], r[1]) * (z1 - z0);
    }
    vol
}

/// Recursive last-axis slicing for D >= 3 (dim read from `r.len()`),
/// bottoming out in the same 2-D staircase the 3-D path uses. Points are
/// assumed pre-filtered to the reference box by [`hypervolume`].
fn hv_slices(pts: &[Vec<f64>], r: &[f64]) -> f64 {
    let d = r.len();
    if pts.is_empty() {
        return 0.0;
    }
    if d == 1 {
        let min = pts.iter().map(|p| p[0]).fold(f64::INFINITY, f64::min);
        return r[0] - min;
    }
    if d == 2 {
        let xy: Vec<[f64; 2]> =
            pts.iter().map(|p| [p[0], p[1]]).collect();
        return area2d(&xy, r[0], r[1]);
    }
    let mut zs: Vec<f64> = pts.iter().map(|p| p[d - 1]).collect();
    zs.push(r[d - 1]);
    zs.sort_by(f64::total_cmp);
    zs.dedup();

    let mut vol = 0.0;
    for w in zs.windows(2) {
        let live: Vec<Vec<f64>> = pts
            .iter()
            .filter(|p| p[d - 1] <= w[0])
            .map(|p| p[..d - 1].to_vec())
            .collect();
        vol += hv_slices(&live, &r[..d - 1]) * (w[1] - w[0]);
    }
    vol
}

/// Area of the union of [x_i, rx] x [y_i, ry] rectangles (staircase sweep).
fn area2d(pts: &[[f64; 2]], rx: f64, ry: f64) -> f64 {
    if pts.is_empty() {
        return 0.0;
    }
    let mut sorted: Vec<[f64; 2]> = pts.to_vec();
    // Sort by x ascending; sweep keeping the lowest y seen so far.
    sorted.sort_by(|a, b| a[0].total_cmp(&b[0]));
    let mut area = 0.0;
    let mut best_y = ry;
    let mut prev_x = sorted[0][0];
    for p in &sorted {
        if p[0] > prev_x {
            area += (p[0] - prev_x) * (ry - best_y);
            prev_x = p[0];
        }
        if p[1] < best_y {
            best_y = p[1];
        }
    }
    area += (rx - prev_x) * (ry - best_y);
    area
}

/// Monte-Carlo hypervolume estimate — the brute-force oracle the exact
/// implementations are cross-checked against at D=3 and D=4 (and what
/// the `--objectives ppa` acceptance test compares an explored 4-D
/// front's PHV to).
pub fn hypervolume_mc<const D: usize>(
    points: &[Objectives<D>],
    r: &Objectives<D>,
    samples: usize,
    seed: u64,
) -> f64 {
    let mut rng = crate::stats::rng::Pcg32::new(seed);
    let mut hits = 0usize;
    for _ in 0..samples {
        let x: Objectives<D> =
            std::array::from_fn(|i| rng.f64() * r[i]);
        if points
            .iter()
            .any(|p| (0..D).all(|i| p[i] < r[i] && p[i] <= x[i]))
        {
            hits += 1;
        }
    }
    let box_vol: f64 = r.iter().product();
    hits as f64 / samples as f64 * box_vol
}

/// Paper §5.3: fraction of evaluated designs strictly better than the
/// reference point in **all** objectives.
pub fn sample_efficiency<const D: usize>(
    points: &[Objectives<D>],
    reference: &Objectives<D>,
) -> f64 {
    if points.is_empty() {
        return 0.0;
    }
    let better = points
        .iter()
        .filter(|p| (0..D).all(|i| p[i] < reference[i]))
        .count();
    better as f64 / points.len() as f64
}

/// Count of designs strictly better than the reference in all objectives.
pub fn superior_count<const D: usize>(
    points: &[Objectives<D>],
    reference: &Objectives<D>,
) -> usize {
    points
        .iter()
        .filter(|p| (0..D).all(|i| p[i] < reference[i]))
        .count()
}

/// Normalize objective vectors by a baseline (A100), so PHV is unitless.
pub fn normalize<const D: usize>(
    points: &[Objectives<D>],
    baseline: &Objectives<D>,
) -> Vec<Objectives<D>> {
    points
        .iter()
        .map(|p| std::array::from_fn(|i| p[i] / baseline[i]))
        .collect()
}

/// The PHV reference point used throughout the evaluation: 2x the A100 on
/// every normalized objective (designs worse than 2x A100 in any metric
/// contribute no volume).
pub const PHV_REF: Objectives = [2.0, 2.0, 2.0];

/// [`PHV_REF`] at any dimensionality (the 4-D `ppa` races use
/// `phv_ref::<4>()`).
pub const fn phv_ref<const D: usize>() -> Objectives<D> {
    [2.0; D]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::rng::Pcg32;
    use crate::util::prop;

    #[test]
    fn dominance_basics() {
        assert!(dominates(&[1.0, 1.0, 1.0], &[2.0, 2.0, 2.0]));
        assert!(dominates(&[1.0, 2.0, 2.0], &[2.0, 2.0, 2.0]));
        assert!(!dominates(&[2.0, 2.0, 2.0], &[2.0, 2.0, 2.0]));
        assert!(!dominates(&[1.0, 3.0, 1.0], &[2.0, 2.0, 2.0]));
        // Any dimensionality.
        assert!(dominates(&[1.0, 1.0, 1.0, 1.0], &[1.0, 1.0, 1.0, 2.0]));
        assert!(!dominates(
            &[1.0, 1.0, 1.0, 3.0],
            &[2.0, 2.0, 2.0, 2.0]
        ));
    }

    #[test]
    fn objective_mode_roundtrip() {
        for m in ObjectiveMode::ALL {
            assert_eq!(ObjectiveMode::parse(m.name()), Some(m));
        }
        assert_eq!(ObjectiveMode::parse("bogus"), None);
        assert_eq!(ObjectiveMode::default().dim(), 3);
        assert_eq!(ObjectiveMode::Ppa.dim(), 4);
    }

    #[test]
    fn front_excludes_dominated() {
        let pts = vec![
            [1.0, 4.0, 4.0],
            [4.0, 1.0, 4.0],
            [4.0, 4.0, 1.0],
            [3.0, 3.0, 3.0],
            [5.0, 5.0, 5.0], // dominated by everything
        ];
        let f = pareto_front(&pts);
        assert_eq!(f, vec![0, 1, 2, 3]);
    }

    #[test]
    fn front_dedups_ties() {
        let pts = vec![[1.0, 1.0, 1.0], [1.0, 1.0, 1.0]];
        assert_eq!(pareto_front(&pts), vec![0]);
    }

    #[test]
    fn front_4d_matches_pairwise_semantics() {
        let pts: Vec<Objectives<4>> = vec![
            [1.0, 4.0, 4.0, 4.0],
            [4.0, 1.0, 4.0, 4.0],
            [4.0, 4.0, 4.0, 1.0],
            [5.0, 5.0, 5.0, 5.0], // dominated
            [1.0, 4.0, 4.0, 4.0], // duplicate of 0
        ];
        assert_eq!(pareto_front(&pts), vec![0, 1, 2]);
    }

    #[test]
    fn front_sweep_matches_pairwise_oracle() {
        // Random sets with deliberate duplicates and shared coordinates
        // (a quantized grid makes axis ties common): the O(n log n)
        // sweep must reproduce the O(n^2) oracle exactly, including the
        // first-occurrence tie rule.
        prop::forall(
            1133,
            96,
            |r| {
                let n = r.range_usize(0, 40);
                let mut pts: Vec<Objectives> = (0..n)
                    .map(|_| {
                        [
                            r.range_usize(0, 6) as f64,
                            r.range_usize(0, 6) as f64,
                            r.range_usize(0, 6) as f64,
                        ]
                    })
                    .collect();
                // Inject exact duplicates of earlier points.
                for _ in 0..n / 4 {
                    let i = r.range_usize(0, pts.len().max(1));
                    if i < pts.len() {
                        let p = pts[i];
                        pts.push(p);
                    }
                }
                pts
            },
            |pts| pareto_front(pts) == pareto_front_pairwise(pts),
        );
    }

    #[test]
    fn hv_single_point_box() {
        let hv = hypervolume(&[[1.0, 1.0, 1.0]], &[2.0, 2.0, 2.0]);
        assert!((hv - 1.0).abs() < 1e-12);
        let hv4 = hypervolume(
            &[[1.0, 1.0, 1.0, 1.5]],
            &[2.0, 2.0, 2.0, 2.0],
        );
        assert!((hv4 - 0.5).abs() < 1e-12, "hv4={hv4}");
    }

    #[test]
    fn hv_ignores_points_outside_reference() {
        let hv = hypervolume(
            &[[3.0, 1.0, 1.0], [1.0, 1.0, 2.5]],
            &[2.0, 2.0, 2.0],
        );
        assert_eq!(hv, 0.0);
    }

    #[test]
    fn hv_union_of_two_boxes() {
        // Boxes [1,2]^3 and [0,2]x[1.5,2]x[1.5,2]:
        // vol = 1 + 2*0.5*0.5 - 1*0.5*0.5 = 1.25
        let hv = hypervolume(
            &[[1.0, 1.0, 1.0], [0.0, 1.5, 1.5]],
            &[2.0, 2.0, 2.0],
        );
        assert!((hv - 1.25).abs() < 1e-9, "hv={hv}");
    }

    #[test]
    fn hv_dominated_point_adds_nothing() {
        let a = hypervolume(&[[1.0, 1.0, 1.0]], &[2.0, 2.0, 2.0]);
        let b = hypervolume(
            &[[1.0, 1.0, 1.0], [1.5, 1.5, 1.5]],
            &[2.0, 2.0, 2.0],
        );
        assert!((a - b).abs() < 1e-12);
    }

    #[test]
    fn hv_4d_degenerate_axis_reduces_to_3d() {
        // Points sharing the 4th coordinate at c: HV4 = HV3 * (r3 - c).
        let pts3: Vec<Objectives> =
            vec![[0.3, 1.2, 0.9], [1.0, 0.2, 1.4], [0.8, 0.8, 0.4]];
        let r3 = [1.8, 1.6, 1.7];
        let c = 0.5;
        let pts4: Vec<Objectives<4>> = pts3
            .iter()
            .map(|p| [p[0], p[1], p[2], c])
            .collect();
        let r4 = [r3[0], r3[1], r3[2], 2.0];
        let hv3 = hypervolume(&pts3, &r3);
        let hv4 = hypervolume(&pts4, &r4);
        assert!(
            (hv4 - hv3 * (2.0 - c)).abs() < 1e-9,
            "hv4={hv4} hv3={hv3}"
        );
    }

    #[test]
    fn hv_monotone_under_adding_points_property() {
        let mut rng = Pcg32::new(31);
        prop::forall(
            32,
            64,
            move |r| {
                let n = r.range_usize(1, 12);
                (0..n)
                    .map(|_| {
                        [r.f64() * 2.0, r.f64() * 2.0, r.f64() * 2.0]
                    })
                    .collect::<Vec<Objectives>>()
            },
            |pts| {
                let r = [1.8, 1.8, 1.8];
                let hv_all = hypervolume(pts, &r);
                let hv_front: f64 = hypervolume(
                    &pareto_front(pts)
                        .into_iter()
                        .map(|i| pts[i])
                        .collect::<Vec<_>>(),
                    &r,
                );
                // Front alone has identical HV, and dropping a point never
                // increases HV.
                let hv_less = if pts.len() > 1 {
                    hypervolume(&pts[1..], &r)
                } else {
                    0.0
                };
                (hv_all - hv_front).abs() < 1e-9 && hv_less <= hv_all + 1e-9
            },
        );
        let _ = rng.next_u32();
    }

    #[test]
    fn hv_brute_force_monte_carlo_agreement() {
        let pts = vec![
            [0.3, 1.2, 0.9],
            [1.0, 0.2, 1.4],
            [0.8, 0.8, 0.4],
            [1.5, 1.5, 0.1],
        ];
        let r = [1.8, 1.6, 1.7];
        let exact = hypervolume(&pts, &r);
        let mc = hypervolume_mc(&pts, &r, 200_000, 99);
        assert!(
            (exact - mc).abs() / exact < 0.02,
            "exact={exact} mc={mc}"
        );
    }

    #[test]
    fn hv_4d_monte_carlo_agreement_on_random_fronts() {
        // The satellite invariant: the const-generic exact HV at D=4
        // (recursive slicing) agrees with the brute-force Monte-Carlo
        // oracle on random point sets; and at D=3 the generic entry
        // point (the historical implementation) agrees with both.
        let mut rng = Pcg32::new(2026);
        for case in 0..4u64 {
            let n = 3 + rng.range_usize(0, 8);
            let pts4: Vec<Objectives<4>> = (0..n)
                .map(|_| {
                    std::array::from_fn(|_| 0.1 + rng.f64() * 1.7)
                })
                .collect();
            let r4 = [1.9, 1.9, 1.9, 1.9];
            let exact = hypervolume(&pts4, &r4);
            if exact <= 1e-6 {
                continue;
            }
            let mc = hypervolume_mc(&pts4, &r4, 300_000, 7 + case);
            assert!(
                (exact - mc).abs() / exact < 0.03,
                "case {case}: exact={exact} mc={mc}"
            );
            // 3-D projection cross-check with shared 4th coordinate.
            let pts3: Vec<Objectives> =
                pts4.iter().map(|p| [p[0], p[1], p[2]]).collect();
            let r3 = [1.9, 1.9, 1.9];
            let exact3 = hypervolume(&pts3, &r3);
            let mc3 = hypervolume_mc(&pts3, &r3, 300_000, 77 + case);
            assert!(
                exact3 <= 1e-6
                    || (exact3 - mc3).abs() / exact3 < 0.03,
                "case {case}: exact3={exact3} mc3={mc3}"
            );
        }
    }

    #[test]
    fn sample_efficiency_counts_strict_improvements() {
        let r = [1.0, 1.0, 1.0];
        let pts = vec![
            [0.9, 0.9, 0.9], // better
            [0.9, 1.1, 0.9], // worse in one
            [1.0, 0.9, 0.9], // tie in one -> not strictly better
            [0.5, 0.5, 0.5], // better
        ];
        assert!((sample_efficiency(&pts, &r) - 0.5).abs() < 1e-12);
        assert_eq!(superior_count(&pts, &r), 2);
    }

    #[test]
    fn normalize_by_baseline() {
        let pts = vec![[2.0, 4.0, 8.0]];
        let n = normalize(&pts, &[2.0, 2.0, 2.0]);
        assert_eq!(n[0], [1.0, 2.0, 4.0]);
        let pts4: Vec<Objectives<4>> = vec![[2.0, 4.0, 8.0, 16.0]];
        let n4 = normalize(&pts4, &[2.0, 2.0, 2.0, 2.0]);
        assert_eq!(n4[0], [1.0, 2.0, 4.0, 8.0]);
    }

    #[test]
    fn phv_ref_matches_constant() {
        assert_eq!(phv_ref::<3>(), PHV_REF);
        assert_eq!(phv_ref::<4>(), [2.0; 4]);
    }
}
