//! `lumina` — the L3 coordinator CLI.
//!
//! Subcommands:
//!   eval        evaluate one design point (8 raw values)
//!   explore     run LUMINA on a sample budget (optionally --suite)
//!   race        run all six DSE methods under identical budgets
//!   benchmark   run the DSE Benchmark (Table 3)
//!   sensitivity QuanE sensitivity study around a design
//!   report      Table-4 style design report
//!   workloads   list the registered workload scenarios
//!   cache       stats/compact/clear a disk memo store (--cache-dir)
//!   bench       check/update/show the perf-bench regression ratchet
//!   lint        determinism static-analysis pass over the sources
//!   mirror      cross-language mirror-drift check (lint --mirror)
//!
//! All exploration traffic flows through the AOT roofline artifact via
//! PJRT when `artifacts/` exists (`make artifacts`); `--evaluator`
//! selects `roofline`, `roofline-rs` or `compass`. Every evaluating
//! subcommand accepts `--workload <name>` (see `lumina workloads`);
//! `explore --suite` optimizes the weighted multi-scenario composite.

use lumina::analysis;
use lumina::bench::{ratchet, resolve_existing, Baseline};
use lumina::bench_dse::run_benchmark_disk;
use lumina::design::{DesignPoint, DesignSpace, Param};
use lumina::dse::{
    self, driver::CheckpointSink, merge_race, merged_front,
    run_race_shard, run_race_shard_observed, shard, Driver,
    NullObserver, Observer, ProgressObserver, SessionState, ShardSpec,
};
use lumina::eval::{
    BudgetedEvaluator, DiskStore, Evaluator, Phase, SuiteEvaluator,
};
use lumina::figures::race::{
    aggregate, reference_objectives, run_race, run_race_fused,
    run_race_fused_observed, score_log, EvaluatorKind, RaceConfig,
    RaceResult,
};
use lumina::figures::table4::{pick_top2, render, report_rows};
use lumina::llm::ModelProfile;
use lumina::lumina::{quale::InfluenceMap, quane::Ahk, Lumina, LuminaConfig};
use lumina::pareto::{ObjectiveMode, Objectives};
use lumina::sim::CompassSim;
use lumina::util::bench::Stopwatch;
use lumina::util::cli::Args;
use lumina::util::json::Json;
use lumina::workload::{
    scenario_by_name, scenario_matrix, suite_scenarios, Scenario,
    WorkloadSpec, DEFAULT_SCENARIO,
};

use std::sync::Arc;

const USAGE: &str = "\
lumina — LLM-guided GPU architecture exploration (paper reproduction)

USAGE: lumina <command> [--options]

  eval <8 values>            evaluate links cores sublanes sa vecw
                             sram_kb gbuf_mb memch
  explore [--budget N] [--seed S] [--model qwen3|phi4|llama3.1]
          [--evaluator roofline|roofline-rs|compass]
          [--workload NAME | --suite] [--verbose]
          [--objectives latency-area|ppa]
          [--checkpoint PATH [--resume] [--checkpoint-every K]]
          [--cache-dir DIR]  persist the memo store on disk: repeat
                             runs serve known designs as free hits
                             (with --suite, keyed per scenario, so
                             designs interchange with single-workload
                             runs)
  race [--samples N] [--trials T] [--evaluator ...] [--workload NAME]
       [--objectives latency-area|ppa] [--fused] [--verbose]
       [--cache-dir DIR --shard I/N]
                             run worker I of N: claim a disjoint slice
                             of the (method x trial) cells, checkpoint
                             each to DIR/cells (evaluations stay
                             unmemoized for budget fairness)
       [--cache-dir DIR --merge [--verify]]
                             fold the cell checkpoints back into the
                             exact single-process race result
                             (--verify reruns it in-process and
                             asserts bitwise identity)
  benchmark [--scale F] [--seed S] [--workload NAME]
            [--objectives latency-area|ppa] [--cache-dir DIR]
  cache [stats|compact|clear] --cache-dir DIR
                             inspect/maintain a disk memo store:
                             stats (segments, entries per workload,
                             lifetime hit counters), compact (rewrite
                             live records into one sealed segment),
                             clear (delete every segment)
  sensitivity [--evaluator ...] [--workload NAME]
  report [<8 values>]        Table-4 style PPA report (defaults: paper
                             designs) [--workload NAME]
  workloads                  list the workload scenario registry
  bench [check|update|show]  hold BENCH_10.json to BENCH_BASELINE.json
        [--snapshot PATH] [--baseline PATH] [--issue N]
                             check: non-zero exit on any regressed row
                             update: ratchet the baseline forward
  lint [--root PATH] [--format text|json] [--out PATH]
       [--deny-warnings]     determinism lint over the sources; writes
                             findings JSON (default
                             out/lint_findings.json); --deny-warnings
                             fails on any unwaivered finding (CI mode)
       [--mirror]            run the cross-language mirror-drift
                             differ instead: checks every declared
                             Rust<->Python mirror pair and oracle pin
                             (M001-M004); --root is the repo root,
                             findings default to
                             out/mirror_findings.json
  mirror [...]               alias for `lint --mirror`

Objective modes: latency-area (default) optimizes the 3-D (TTFT, TPOT,
area) vector; ppa adds energy/token as a 4th minimized objective, arms
LUMINA's power envelope, and scores 4-D hypervolume.

Run `make artifacts` first to enable the PJRT roofline evaluator.";

/// An evaluated exploration trajectory (design, objectives) in order.
type Trajectory = Vec<(DesignPoint, Objectives)>;

fn evaluator_kind(args: &Args) -> EvaluatorKind {
    match args.str_or("evaluator", "roofline").as_str() {
        "compass" => EvaluatorKind::Compass,
        "roofline-rs" => EvaluatorKind::RooflineRust,
        _ => EvaluatorKind::RooflinePjrt,
    }
}

/// Resolve `--objectives` (default latency-area).
fn objectives_arg(args: &Args) -> lumina::Result<ObjectiveMode> {
    let name = args.str_or("objectives", "latency-area");
    ObjectiveMode::parse(&name).ok_or_else(|| {
        lumina::err!(
            "unknown objective mode {name:?}; use latency-area or ppa"
        )
    })
}

/// Resolve `--workload` against the scenario registry.
fn workload_arg(args: &Args) -> lumina::Result<&'static Scenario> {
    let name = args.str_or("workload", DEFAULT_SCENARIO);
    scenario_by_name(&name).ok_or_else(|| {
        lumina::err!(
            "unknown workload {name:?}; run `lumina workloads` for the \
             registry"
        )
    })
}

fn parse_design(values: &[String]) -> Option<DesignPoint> {
    let v: Vec<u32> =
        values.iter().filter_map(|a| a.parse().ok()).collect();
    (v.len() == 8).then(|| {
        DesignPoint::new([v[0], v[1], v[2], v[3], v[4], v[5], v[6], v[7]])
    })
}

/// Open `--cache-dir` as a shared on-disk memo store, when present.
/// A crash-truncated tail is recovered, not fatal: intact records are
/// kept and the skip count is reported on stderr.
fn cache_dir_arg(args: &Args) -> lumina::Result<Option<Arc<DiskStore>>> {
    let Some(dir) = args.opt("cache-dir") else {
        return Ok(None);
    };
    let disk = DiskStore::open_shared(std::path::Path::new(dir))?;
    let skipped = disk.skipped_on_open();
    if skipped > 0 {
        eprintln!(
            "note: skipped {skipped} corrupt record(s) while opening \
             {dir} (crash-truncated tail; intact records were kept)"
        );
    }
    Ok(Some(disk))
}

/// Report how a disk-backed run used its store.
fn print_disk_summary(disk: &DiskStore) {
    let c = disk.counters();
    println!(
        "cache dir: {} ({} entries, {} disk hits, {} appended)",
        disk.dir().display(),
        disk.len(),
        c.hits,
        c.appended
    );
}

/// The shared coordination directory `race --shard`/`--merge` need.
fn race_dir_arg(args: &Args) -> lumina::Result<std::path::PathBuf> {
    args.opt("cache-dir")
        .map(std::path::PathBuf::from)
        .ok_or_else(|| {
            lumina::err!(
                "--shard/--merge need --cache-dir <dir> as the shared \
                 coordination directory"
            )
        })
}

fn print_race_table(results: &[RaceResult]) {
    println!(
        "{:<16} {:>10} {:>10} {:>12} {:>9}",
        "method", "mean PHV", "std PHV", "sample eff", "superior"
    );
    for (m, phv, eff, std, sup) in aggregate(results) {
        println!(
            "{m:<16} {phv:>10.4} {std:>10.4} {eff:>12.4} {sup:>9.1}"
        );
    }
}

fn main() -> lumina::Result<()> {
    let args = Args::from_env()?;
    match args.command.as_str() {
        "eval" => cmd_eval(&args),
        "explore" => cmd_explore(&args),
        "race" => cmd_race(&args),
        "benchmark" => cmd_benchmark(&args),
        "sensitivity" => cmd_sensitivity(&args),
        "report" => cmd_report(&args),
        "workloads" => {
            print!("{}", scenario_matrix());
            Ok(())
        }
        "cache" => cmd_cache(&args),
        "bench" => cmd_bench(&args),
        "lint" => cmd_lint(&args, args.flag("mirror")),
        "mirror" => cmd_lint(&args, true),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

fn cmd_eval(args: &Args) -> lumina::Result<()> {
    let d = parse_design(&args.positional)
        .unwrap_or_else(DesignPoint::a100);
    let scenario = workload_arg(args)?;
    let mut ev = evaluator_kind(args).make_for(&scenario.spec);
    let m = ev.eval(&d)?;
    println!("design: {d}");
    println!("workload: {}", scenario.name);
    println!("evaluator: {}", ev.name());
    println!(
        "TTFT {:.4} ms   TPOT {:.5} ms   area {:.1} mm^2",
        m.ttft_ms, m.tpot_ms, m.area_mm2
    );
    println!(
        "energy/token {:.3} mJ   prefill energy {:.1} mJ   \
         avg power {:.1} W   peak (tdp proxy) {:.1} W",
        m.energy_per_token_mj,
        m.prefill_energy_mj,
        m.avg_power_w,
        lumina::arch::tdp_w(&d)
    );
    for phase in Phase::ALL {
        let s = &m.stalls[phase.index()];
        println!(
            "{:<4} stalls: compute {:.4} / memory {:.4} / network {:.4} \
             ms  (dominant: {})",
            phase.metric_name(),
            s[0],
            s[1],
            s[2],
            m.dominant_bottleneck(phase)
        );
    }
    // The detailed simulator can attribute energy per component.
    if ev.name() == "compass" {
        let sim = CompassSim::new(scenario.spec);
        for phase in Phase::ALL {
            let b = sim.energy_breakdown(&d, phase);
            println!(
                "{:<4} energy: compute {:.2} / sram {:.2} / l2 {:.2} / \
                 hbm {:.2} / link {:.2} / leakage {:.2} mJ",
                phase.metric_name(),
                b.compute_mj,
                b.sram_mj,
                b.l2_mj,
                b.hbm_mj,
                b.link_mj,
                b.leakage_mj
            );
        }
    }
    Ok(())
}

/// Shared `explore` driver: memoized + budgeted LUMINA session driven
/// through the observable ask/tell [`Driver`], with optional
/// `--checkpoint <path>` persistence and `--resume` replay. Used by
/// both the single-workload and suite paths.
fn run_explore(
    args: &Args,
    label: &'static str,
    ev: &mut dyn Evaluator,
) -> lumina::Result<(Trajectory, Objectives, Lumina)> {
    let budget = args.usize_or("budget", 100)?;
    let seed = args.u64_or("seed", 2026)?;
    let objectives = objectives_arg(args)?;
    let model = ModelProfile::by_name(&args.str_or("model", "qwen3"))
        .unwrap_or_else(ModelProfile::qwen3);
    let space = DesignSpace::table1();
    let evaluator_name = ev.name().to_string();
    let workload_fp = ev.workload_fingerprint();
    let ckpt = args.opt("checkpoint").map(std::path::PathBuf::from);
    if args.flag("resume") && ckpt.is_none() {
        lumina::bail!(
            "--resume needs --checkpoint <path> to know which state \
             to reload"
        );
    }

    // Load + validate the checkpoint and warm the memo cache *before*
    // the reference evaluation below, so on resume no simulator work
    // at all is redone (the recorded log always contains the a100
    // reference).
    let resume_state = if let (Some(path), true) =
        (&ckpt, args.flag("resume"))
    {
        let st = SessionState::load(path)?;
        st.expect_identity(
            &format!("checkpoint {}", path.display()),
            "lumina",
            Some(model.name),
            seed,
            budget,
            Some(&evaluator_name),
            workload_fp,
            objectives,
        )?;
        ev.preload(&st.log);
        Some(st)
    } else {
        None
    };

    let reference_m = ev.eval(&DesignPoint::a100())?;
    let reference = reference_m.objectives();
    let mut lum = Lumina::new(LuminaConfig {
        seed,
        model,
        objectives,
        ..Default::default()
    });

    let t0 = Stopwatch::start();
    let mut be = if let Some(st) = resume_state {
        // Replay the session's ask/tell bookkeeping against the
        // recorded trajectory and continue with the reconstructed
        // budget ledger.
        let spent = dse::replay(
            &mut lum,
            &space,
            budget,
            &st.log,
            &[DesignPoint::a100()],
        )?;
        if spent != st.spent {
            lumina::bail!(
                "checkpoint records {} budget units spent but replay \
                 reconstructed {spent}",
                st.spent
            );
        }
        println!(
            "resumed from {} ({} samples, {} spent)",
            ckpt.as_ref().expect("resume implies a path").display(),
            st.log.len(),
            spent
        );
        BudgetedEvaluator::resume(ev, budget, st.log, spent)
    } else {
        BudgetedEvaluator::new(ev, budget)
    };

    let mut observer: Box<dyn Observer> = if args.flag("verbose") {
        Box::new(ProgressObserver::new())
    } else {
        Box::new(NullObserver)
    };
    let mut driver = Driver::new(&space, observer.as_mut());
    driver.track(objectives, &reference_m);
    if let Some(path) = &ckpt {
        driver.checkpoint = Some(CheckpointSink {
            path: path.clone(),
            model: model.name.to_string(),
            seed,
            evaluator: evaluator_name,
            workload_fp,
            objectives,
            every: args.usize_or("checkpoint-every", 1)?,
        });
    }
    driver.run(&mut lum, &mut be)?;

    let traj: Trajectory =
        be.log.iter().map(|(d, m)| (*d, m.objectives())).collect();
    let r = score_log(label, 0, &be.log, &reference_m, objectives);
    let hits = be
        .cache_counters()
        .map(|c| format!(", {} cache hits", c.hits))
        .unwrap_or_default();
    let disk = be
        .disk_counters()
        .map(|c| format!(", {} disk hits", c.hits))
        .unwrap_or_default();
    println!(
        "explored {} samples ({} simulated{hits}{disk}) in {:.2}s  \
         [{objectives}] PHV={:.4}  eff={:.4}  superior={}",
        traj.len(),
        be.spent(),
        t0.elapsed_s(),
        r.phv,
        r.sample_efficiency,
        r.superior
    );
    if let Some(path) = &ckpt {
        println!("checkpoint: {}", path.display());
    }
    Ok((traj, reference, lum))
}

fn cmd_explore(args: &Args) -> lumina::Result<()> {
    if args.flag("suite") {
        if args.opt("workload").is_some() {
            lumina::bail!(
                "--suite runs every positive-weight scenario and \
                 conflicts with --workload; pass one or the other"
            );
        }
        return cmd_explore_suite(args);
    }
    let kind = evaluator_kind(args);
    let scenario = workload_arg(args)?;
    println!("workload: {} ({})", scenario.name, scenario.regime);

    // The composed memoized stack
    // (`ParallelEvaluator<CachedEvaluator<_>>`): LUMINA restarts and
    // sensitivity sweeps revisit grid points — hits are served from the
    // concurrent memo store without touching the worker pool and don't
    // burn the sample budget, while fresh proposals evaluate in
    // parallel through the SoA chunk kernels. With `--cache-dir` the
    // memo gains a disk tier, so a warm restart serves every known
    // design without re-simulating.
    let disk = cache_dir_arg(args)?;
    let mut ev = match &disk {
        Some(d) => kind.make_cached_disk_for(&scenario.spec, d.clone()),
        None => kind.make_cached_for(&scenario.spec),
    };
    let (traj, reference, lum) =
        run_explore(args, "lumina", ev.as_mut())?;
    if let Some(d) = &disk {
        print_disk_summary(d);
    }
    if args.flag("verbose") {
        if let Some(ahk) = &lum.ahk {
            println!("\ninfluence map:\n{}", ahk.qual.render());
        }
        for (i, (d, o)) in traj.iter().enumerate() {
            let sup = (0..3).all(|k| o[k] < reference[k]);
            println!(
                "{i:>4} {}{d}  ttft={:.2} tpot={:.4} area={:.0}",
                if sup { "*" } else { " " },
                o[0],
                o[1],
                o[2]
            );
        }
    }
    let picks = pick_top2(&traj, &reference);
    if !picks.is_empty() {
        println!("\ntop designs:");
        for d in &picks {
            println!("  {d}");
        }
    }
    Ok(())
}

/// `explore --suite`: optimize the weighted multi-scenario composite and
/// report the top designs per scenario.
fn cmd_explore_suite(args: &Args) -> lumina::Result<()> {
    let kind = evaluator_kind(args);
    let scenarios = suite_scenarios();
    println!(
        "suite: {} scenarios ({})",
        scenarios.len(),
        scenarios
            .iter()
            .map(|s| s.name)
            .collect::<Vec<_>>()
            .join(", ")
    );

    // Pure members join one fused cross-scenario pool dispatch per ask
    // batch: all (member x chunk) tasks run under a single batch
    // latch, so a 7-scenario suite pays one barrier per batch and
    // still cannot oversubscribe the host (one process-wide pool).
    // One sample = one design evaluated under every scenario; the
    // suite memoizes composites (keyed on the combined suite
    // fingerprint) so a revisited design skips all members at once
    // and rides free on the budget. With `--cache-dir` every member
    // also probes and write-behinds the shared disk store under its
    // *own* workload fingerprint, so designs interchange freely
    // between single-workload and suite runs.
    let disk = cache_dir_arg(args)?;
    let mut factory =
        |spec: &WorkloadSpec| kind.make_suite_backend(spec);
    let mut suite = SuiteEvaluator::with_backends(
        &scenarios,
        &mut factory,
        disk.clone(),
    )?;
    let (traj, reference, _lum) =
        run_explore(args, "lumina-suite", &mut suite)?;
    if let Some(d) = &disk {
        print_disk_summary(d);
    }

    let picks = pick_top2(&traj, &reference);
    for d in &picks {
        println!("\ntop design: {d}");
        println!(
            "  {:<16} {:>11} {:>11} {:>9} {:>9}",
            "scenario", "TTFT ms/ly", "TPOT ms/ly", "vs A100", "vs A100"
        );
        for row in suite.eval_scenarios(d)? {
            println!(
                "  {:<16} {:>11.4} {:>11.5} {:>8.2}x {:>8.2}x",
                row.name,
                row.metrics.ttft_ms,
                row.metrics.tpot_ms,
                row.metrics.ttft_ms / row.reference.ttft_ms,
                row.metrics.tpot_ms / row.reference.tpot_ms,
            );
        }
    }
    Ok(())
}

fn cmd_race(args: &Args) -> lumina::Result<()> {
    let cfg = RaceConfig {
        samples: args.usize_or("samples", 200)?,
        trials: args.usize_or("trials", 3)?,
        seed: args.u64_or("seed", 2026)?,
        evaluator: evaluator_kind(args),
        workload: workload_arg(args)?.spec,
        objectives: objectives_arg(args)?,
    };
    if let Some(spec) = args.opt("shard") {
        let spec = ShardSpec::parse(spec)?;
        return cmd_race_shard(args, &cfg, spec);
    }
    if args.flag("merge") {
        return cmd_race_merge(args, &cfg);
    }
    let fused = args.flag("fused");
    if args.flag("verbose") && !fused {
        eprintln!(
            "note: live progress (--verbose) is driven by the fused \
             ask/tell observer; add --fused to see it"
        );
    }
    let t0 = Stopwatch::start();
    let results = if fused {
        if args.flag("verbose") {
            let mut obs = ProgressObserver::new();
            run_race_fused_observed(&cfg, &mut obs)?
        } else {
            run_race_fused(&cfg)?
        }
    } else {
        run_race(&cfg)?
    };
    println!(
        "{} race: 6 methods x {} trials x {} samples [{}] in {:.2}s",
        if fused { "fused" } else { "serial" },
        cfg.trials,
        cfg.samples,
        cfg.objectives,
        t0.elapsed_s()
    );
    print_race_table(&results);
    Ok(())
}

/// `race --shard I/N --cache-dir DIR`: run worker I's disjoint slice
/// of the (method x trial) cells against the shared coordination
/// directory. Workers coordinate purely through the store's lock
/// files and atomic checkpoint renames — no IPC, so the N processes
/// can live on different hosts sharing a filesystem.
fn cmd_race_shard(
    args: &Args,
    cfg: &RaceConfig,
    spec: ShardSpec,
) -> lumina::Result<()> {
    let dir = race_dir_arg(args)?;
    let t0 = Stopwatch::start();
    let outcome = if args.flag("verbose") {
        let mut obs = ProgressObserver::new();
        run_race_shard_observed(cfg, spec, &dir, &mut obs)?
    } else {
        run_race_shard(cfg, spec, &dir)?
    };
    println!(
        "shard {spec}: ran {} of {} cells ({} already done, {} claimed \
         by other workers) in {:.2}s",
        outcome.ran,
        outcome.total,
        outcome.done,
        outcome.contended,
        t0.elapsed_s()
    );
    println!("cells: {}", shard::cells_dir(&dir).display());
    println!(
        "merge with: lumina race --merge --cache-dir {}",
        dir.display()
    );
    Ok(())
}

/// `race --merge --cache-dir DIR`: fold a completed sharded race's
/// cell checkpoints back into the exact single-process result.
/// `--verify` reruns the race in-process and asserts bitwise identity
/// of every cell and of the merged Pareto front.
fn cmd_race_merge(args: &Args, cfg: &RaceConfig) -> lumina::Result<()> {
    let dir = race_dir_arg(args)?;
    let t0 = Stopwatch::start();
    let results = merge_race(cfg, &dir)?;
    let reference =
        reference_objectives(cfg.evaluator, &cfg.workload)?;
    let (front, phv) = merged_front(&results, &reference);
    println!(
        "merged race: 6 methods x {} trials x {} samples [{}] in \
         {:.2}s",
        cfg.trials,
        cfg.samples,
        cfg.objectives,
        t0.elapsed_s()
    );
    print_race_table(&results);
    println!("merged front: {} points, PHV {phv:.6}", front.len());
    if args.flag("verify") {
        let serial = run_race_fused(cfg)?;
        verify_merge(&results, &serial, &front, phv, &reference)?;
        println!(
            "verify: merged cells bitwise-identical to the in-process \
             fused race"
        );
    }
    Ok(())
}

/// Bitwise comparison of merged shard cells against an in-process
/// serial rerun — the `--verify` acceptance gate.
fn verify_merge(
    merged: &[RaceResult],
    serial: &[RaceResult],
    front: &[Objectives],
    phv: f64,
    reference: &Objectives,
) -> lumina::Result<()> {
    if merged.len() != serial.len() {
        lumina::bail!(
            "verify: merged {} cells but the in-process race ran {}",
            merged.len(),
            serial.len()
        );
    }
    for (m, s) in merged.iter().zip(serial) {
        if m.method != s.method
            || m.trial != s.trial
            || m.phv.to_bits() != s.phv.to_bits()
            || m.superior != s.superior
            || m.trajectory != s.trajectory
        {
            lumina::bail!(
                "verify: cell {}-t{} diverged from the in-process race",
                m.method,
                m.trial
            );
        }
    }
    let (sf, sphv) = merged_front(serial, reference);
    if front != sf.as_slice() || phv.to_bits() != sphv.to_bits() {
        lumina::bail!(
            "verify: merged Pareto front diverged from the in-process \
             race ({} vs {} points, PHV {phv} vs {sphv})",
            front.len(),
            sf.len()
        );
    }
    Ok(())
}

fn cmd_benchmark(args: &Args) -> lumina::Result<()> {
    let scale = args.f64_or("scale", 1.0)?;
    let seed = args.u64_or("seed", 2026)?;
    let scenario = workload_arg(args)?;
    let objectives = objectives_arg(args)?;
    // `--cache-dir` memoizes the question-set ground truth: repeat
    // benchmark runs at the same seed serve every simulation from
    // disk and score bit-identical question sets.
    let disk = cache_dir_arg(args)?;
    let report = run_benchmark_disk(
        &[
            ModelProfile::phi4(),
            ModelProfile::qwen3(),
            ModelProfile::llama31(),
        ],
        seed,
        scale,
        &scenario.spec,
        objectives,
        disk.clone(),
    );
    println!("workload: {} [{objectives}]", scenario.name);
    println!("{}", report.render_table3());
    if let Some(d) = &disk {
        print_disk_summary(d);
    }
    Ok(())
}

/// `lumina cache {stats,compact,clear} --cache-dir DIR` — disk memo
/// store maintenance. `stats` reports segments, live entries per
/// workload fingerprint and the persisted lifetime counters;
/// `compact` rewrites the live index into one sealed segment;
/// `clear` deletes every segment (both are serialized against
/// concurrent writers by the store's advisory lock).
fn cmd_cache(args: &Args) -> lumina::Result<()> {
    let verb = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("stats");
    let dir = args
        .opt("cache-dir")
        .map(std::path::PathBuf::from)
        .ok_or_else(|| {
            lumina::err!("cache needs --cache-dir <dir> to operate on")
        })?;
    match verb {
        "stats" => {
            let store = DiskStore::open(&dir)?;
            let s = store.stats()?;
            println!("store: {}", dir.display());
            println!(
                "segments: {} sealed + {} in progress ({} bytes)",
                s.sealed_segments, s.wip_segments, s.bytes
            );
            println!(
                "entries: {} live ({} corrupt/truncated skipped)",
                s.entries, s.skipped
            );
            for (fp, n) in &s.per_workload {
                println!("  workload {fp:#018x}: {n} entries");
            }
            println!(
                "lifetime: {} hits served, {} records appended",
                s.lifetime_hits, s.lifetime_appended
            );
            Ok(())
        }
        "compact" => {
            let store = DiskStore::open(&dir)?;
            let (records, removed) = store.compact()?;
            println!(
                "compacted {}: {} live records into 1 sealed segment \
                 ({} old segment files removed)",
                dir.display(),
                records,
                removed
            );
            Ok(())
        }
        "clear" => {
            let (files, bytes) = DiskStore::clear(&dir)?;
            println!(
                "cleared {}: removed {} segment files ({} bytes)",
                dir.display(),
                files,
                bytes
            );
            Ok(())
        }
        other => Err(lumina::err!(
            "unknown cache verb {other:?}; use stats, compact or clear"
        )),
    }
}

fn cmd_sensitivity(args: &Args) -> lumina::Result<()> {
    let space = DesignSpace::table1();
    let reference = parse_design(&args.positional)
        .unwrap_or_else(DesignPoint::a100);
    let kind = evaluator_kind(args);
    let mut ev = kind.make_for(&workload_arg(args)?.spec);
    let mut be = BudgetedEvaluator::new(ev.as_mut(), 64);
    let ahk = Ahk::acquire_full(
        InfluenceMap::from_kernel(),
        &space,
        &reference,
        &mut be,
    )?;
    println!(
        "sensitivity around {reference} ({} evaluations):",
        be.spent()
    );
    println!(
        "{:<28} {:>11} {:>11} {:>11} {:>11}",
        "parameter", "dTTFT/step", "dTPOT/step", "dArea/step",
        "dPower/step"
    );
    for p in Param::ALL {
        println!(
            "{:<28} {:>10.3}% {:>10.3}% {:>10.3}% {:>10.3}%",
            p.name(),
            ahk.perf_influence(p, 0) * 100.0,
            ahk.perf_influence(p, 1) * 100.0,
            ahk.area_influence(p) * 100.0,
            ahk.power_influence(p) * 100.0
        );
    }
    Ok(())
}

/// `lumina bench {check,update,show}` — the perf regression ratchet.
/// `check` exits non-zero when any enrolled `BENCH_10.json` row
/// regressed past `BENCH_BASELINE.json`'s tolerance band; `update`
/// adopts the snapshot's values as the new baseline (the escape hatch
/// for intentional trade-offs — commit the result).
fn cmd_bench(args: &Args) -> lumina::Result<()> {
    let verb = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("check");
    let baseline_path = args
        .opt("baseline")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| resolve_existing("BENCH_BASELINE.json"));
    let snapshot_path = args
        .opt("snapshot")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| resolve_existing("BENCH_10.json"));
    let mut baseline = Baseline::load(&baseline_path)?;
    let text =
        std::fs::read_to_string(&snapshot_path).map_err(|e| {
            lumina::err!(
                "reading snapshot {}: {e} (run `cargo bench --bench \
                 perf_hotpath` first)",
                snapshot_path.display()
            )
        })?;
    let snapshot = Json::parse(&text)?;
    match verb {
        "check" => {
            let report = ratchet::check(&baseline, &snapshot);
            print!("{}", report.render());
            if report.failed() {
                lumina::bail!(
                    "bench ratchet: regression vs {} (intentional \
                     trade-off? ratchet with `lumina bench update` \
                     and commit the new baseline)",
                    baseline_path.display()
                );
            }
            println!(
                "bench ratchet: all {} rows within tolerance",
                report.rows.len()
            );
            Ok(())
        }
        "update" => {
            let issue =
                args.u64_or("issue", baseline.updated_by_issue)?;
            let (updated, missing) =
                ratchet::update(&mut baseline, &snapshot, issue);
            baseline.save(&baseline_path)?;
            println!(
                "ratcheted {} rows in {}",
                updated.len(),
                baseline_path.display()
            );
            for name in &missing {
                println!("  missing from snapshot (kept): {name}");
            }
            Ok(())
        }
        "show" => {
            println!("baseline: {}", baseline_path.display());
            println!("snapshot: {}", snapshot_path.display());
            print!("{}", ratchet::check(&baseline, &snapshot).render());
            Ok(())
        }
        other => Err(lumina::err!(
            "unknown bench verb {other:?}; use check, update or show"
        )),
    }
}

/// `lumina lint` — the static-analysis pass over the crate's own
/// sources (see `src/analysis/`). Two engines share the pipeline
/// tail: the default determinism rule scanner, and (`--mirror` /
/// `lumina mirror`) the cross-language mirror-drift differ, whose
/// root is the repo root rather than a source tree. Always writes
/// the machine-readable findings JSON (CI uploads it as an
/// artifact); `--deny-warnings` is the CI gate: any unwaivered
/// finding fails.
fn cmd_lint(args: &Args, mirror: bool) -> lumina::Result<()> {
    let root = match args.opt("root") {
        Some(r) => std::path::PathBuf::from(r),
        None if mirror => default_mirror_root(),
        None => default_lint_root(),
    };
    if !root.is_dir() {
        lumina::bail!(
            "lint root {} is not a directory (pass --root <dir>)",
            root.display()
        );
    }
    let report = if mirror {
        analysis::mirror::check_repo(&root)?
    } else {
        analysis::lint_tree(&root)?
    };

    let out_path = args.path_or(
        "out",
        if mirror {
            "out/mirror_findings.json"
        } else {
            "out/lint_findings.json"
        },
    );
    if let Some(dir) = out_path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).map_err(|e| {
                lumina::err!("creating {}: {e}", dir.display())
            })?;
        }
    }
    let json = report.to_json().pretty() + "\n";
    std::fs::write(&out_path, &json).map_err(|e| {
        lumina::err!("writing {}: {e}", out_path.display())
    })?;

    match args.str_or("format", "text").as_str() {
        "json" => print!("{json}"),
        "text" => {
            print!("{}", report.render_text());
            println!("findings JSON: {}", out_path.display());
        }
        other => lumina::bail!(
            "unknown lint format {other:?}; use text or json"
        ),
    }

    if report.failed(args.flag("deny-warnings")) {
        let c = report.counts();
        lumina::bail!(
            "lint: {} unwaivered findings ({} errors, {} warnings); \
             fix them or waive with `// lumina: allow(RULE) reason`",
            c.errors + c.warnings,
            c.errors,
            c.warnings
        );
    }
    Ok(())
}

/// The lint root when `--root` is absent: `src` when invoked from
/// `rust/`, `rust/src` from the repo root (mirrors how the bench
/// ratchet resolves its snapshot paths).
fn default_lint_root() -> std::path::PathBuf {
    let nested = std::path::PathBuf::from("rust/src");
    if nested.is_dir() {
        return nested;
    }
    std::path::PathBuf::from("src")
}

/// The mirror root when `--root` is absent: the manifest paths are
/// repo-root-relative (`rust/...`, `python/...`), so `.` when
/// invoked from the repo root, `..` when invoked from `rust/`.
fn default_mirror_root() -> std::path::PathBuf {
    let here = std::path::Path::new("rust/src");
    if here.is_dir() && std::path::Path::new("python").is_dir() {
        return std::path::PathBuf::from(".");
    }
    std::path::PathBuf::from("..")
}

fn cmd_report(args: &Args) -> lumina::Result<()> {
    let designs = match parse_design(&args.positional) {
        Some(d) => vec![("Custom".to_string(), d)],
        None => vec![
            ("Design A".to_string(), DesignPoint::paper_design_a()),
            ("Design B".to_string(), DesignPoint::paper_design_b()),
        ],
    };
    let scenario = workload_arg(args)?;
    let mut sim = CompassSim::new(scenario.spec);
    println!("workload: {}", scenario.name);
    println!("{}", render(&report_rows(&mut sim, &designs)?));
    Ok(())
}
