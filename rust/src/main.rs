//! `lumina` — the L3 coordinator CLI.
//!
//! Subcommands:
//!   eval        evaluate one design point (8 raw values)
//!   explore     run LUMINA on a sample budget
//!   race        run all six DSE methods under identical budgets
//!   benchmark   run the DSE Benchmark (Table 3)
//!   sensitivity QuanE sensitivity study around a design
//!   report      Table-4 style design report
//!
//! All exploration traffic flows through the AOT roofline artifact via
//! PJRT when `artifacts/` exists (`make artifacts`); `--evaluator`
//! selects `roofline`, `roofline-rs` or `compass`.

use lumina::baselines::DseMethod;
use lumina::bench_dse::run_benchmark;
use lumina::design::{DesignPoint, DesignSpace, Param};
use lumina::eval::{BudgetedEvaluator, CachedEvaluator, Evaluator, Phase};
use lumina::figures::race::{
    aggregate, run_race, score_trajectory, EvaluatorKind, RaceConfig,
};
use lumina::figures::table4::{pick_top2, render, report_rows};
use lumina::llm::ModelProfile;
use lumina::lumina::{quale::InfluenceMap, quane::Ahk, Lumina, LuminaConfig};
use lumina::sim::CompassSim;
use lumina::util::cli::Args;

const USAGE: &str = "\
lumina — LLM-guided GPU architecture exploration (paper reproduction)

USAGE: lumina <command> [--options]

  eval <8 values>            evaluate links cores sublanes sa vecw
                             sram_kb gbuf_mb memch
  explore [--budget N] [--seed S] [--model qwen3|phi4|llama3.1]
          [--evaluator roofline|roofline-rs|compass] [--verbose]
  race [--samples N] [--trials T] [--evaluator ...]
  benchmark [--scale F] [--seed S]
  sensitivity [--evaluator ...]
  report [<8 values>]        Table-4 style report (defaults: paper designs)

Run `make artifacts` first to enable the PJRT roofline evaluator.";

fn evaluator_kind(args: &Args) -> EvaluatorKind {
    match args.str_or("evaluator", "roofline").as_str() {
        "compass" => EvaluatorKind::Compass,
        "roofline-rs" => EvaluatorKind::RooflineRust,
        _ => EvaluatorKind::RooflinePjrt,
    }
}

fn parse_design(values: &[String]) -> Option<DesignPoint> {
    let v: Vec<u32> =
        values.iter().filter_map(|a| a.parse().ok()).collect();
    (v.len() == 8).then(|| {
        DesignPoint::new([v[0], v[1], v[2], v[3], v[4], v[5], v[6], v[7]])
    })
}

fn main() -> lumina::Result<()> {
    let args = Args::from_env()?;
    match args.command.as_str() {
        "eval" => cmd_eval(&args),
        "explore" => cmd_explore(&args),
        "race" => cmd_race(&args),
        "benchmark" => cmd_benchmark(&args),
        "sensitivity" => cmd_sensitivity(&args),
        "report" => cmd_report(&args),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

fn cmd_eval(args: &Args) -> lumina::Result<()> {
    let d = parse_design(&args.positional)
        .unwrap_or_else(DesignPoint::a100);
    let mut ev = evaluator_kind(args).make();
    let m = ev.eval(&d)?;
    println!("design: {d}");
    println!("evaluator: {}", ev.name());
    println!(
        "TTFT {:.4} ms   TPOT {:.5} ms   area {:.1} mm^2",
        m.ttft_ms, m.tpot_ms, m.area_mm2
    );
    for phase in Phase::ALL {
        let s = &m.stalls[phase.index()];
        println!(
            "{:<4} stalls: compute {:.4} / memory {:.4} / network {:.4} \
             ms  (dominant: {})",
            phase.metric_name(),
            s[0],
            s[1],
            s[2],
            m.dominant_bottleneck(phase)
        );
    }
    Ok(())
}

fn cmd_explore(args: &Args) -> lumina::Result<()> {
    let budget = args.usize_or("budget", 100)?;
    let seed = args.u64_or("seed", 2026)?;
    let model = ModelProfile::by_name(&args.str_or("model", "qwen3"))
        .unwrap_or_else(ModelProfile::qwen3);
    let kind = evaluator_kind(args);
    let space = DesignSpace::table1();

    // Memoize over the evaluation pipeline: LUMINA restarts and
    // sensitivity sweeps revisit grid points, and cache hits don't burn
    // the sample budget.
    let mut ev = CachedEvaluator::new(kind.make());
    let reference = ev.eval(&DesignPoint::a100())?.objectives();
    let mut be = BudgetedEvaluator::new(&mut ev, budget);
    let mut lum = Lumina::new(LuminaConfig {
        seed,
        model,
        ..Default::default()
    });
    let t0 = std::time::Instant::now();
    lum.run(&space, &mut be)?;
    let traj: Vec<_> =
        be.log.iter().map(|(d, m)| (*d, m.objectives())).collect();
    let r = score_trajectory("lumina", 0, &traj, &reference);
    let counters = be.cache_counters().unwrap_or_default();
    println!(
        "explored {} samples ({} simulated, {} cache hits) in {:.2}s  \
         PHV={:.4}  eff={:.4}  superior={}",
        traj.len(),
        be.spent(),
        counters.hits,
        t0.elapsed().as_secs_f64(),
        r.phv,
        r.sample_efficiency,
        r.superior
    );
    if args.flag("verbose") {
        if let Some(ahk) = &lum.ahk {
            println!("\ninfluence map:\n{}", ahk.qual.render());
        }
        for (i, (d, o)) in traj.iter().enumerate() {
            let sup = (0..3).all(|k| o[k] < reference[k]);
            println!(
                "{i:>4} {}{d}  ttft={:.2} tpot={:.4} area={:.0}",
                if sup { "*" } else { " " },
                o[0],
                o[1],
                o[2]
            );
        }
    }
    let picks = pick_top2(&traj, &reference);
    if !picks.is_empty() {
        println!("\ntop designs:");
        for d in &picks {
            println!("  {d}");
        }
    }
    Ok(())
}

fn cmd_race(args: &Args) -> lumina::Result<()> {
    let cfg = RaceConfig {
        samples: args.usize_or("samples", 200)?,
        trials: args.usize_or("trials", 3)?,
        seed: args.u64_or("seed", 2026)?,
        evaluator: evaluator_kind(args),
    };
    let results = run_race(&cfg)?;
    println!(
        "{:<16} {:>10} {:>10} {:>12} {:>9}",
        "method", "mean PHV", "std PHV", "sample eff", "superior"
    );
    for (m, phv, eff, std) in aggregate(&results) {
        let sup: usize = results
            .iter()
            .filter(|r| r.method == m)
            .map(|r| r.superior)
            .sum::<usize>()
            / cfg.trials;
        println!(
            "{m:<16} {phv:>10.4} {std:>10.4} {eff:>12.4} {sup:>9}"
        );
    }
    Ok(())
}

fn cmd_benchmark(args: &Args) -> lumina::Result<()> {
    let scale = args.f64_or("scale", 1.0)?;
    let seed = args.u64_or("seed", 2026)?;
    let report = run_benchmark(
        &[
            ModelProfile::phi4(),
            ModelProfile::qwen3(),
            ModelProfile::llama31(),
        ],
        seed,
        scale,
    );
    println!("{}", report.render_table3());
    Ok(())
}

fn cmd_sensitivity(args: &Args) -> lumina::Result<()> {
    let space = DesignSpace::table1();
    let reference = parse_design(&args.positional)
        .unwrap_or_else(DesignPoint::a100);
    let kind = evaluator_kind(args);
    let mut ev = kind.make();
    let mut be = BudgetedEvaluator::new(ev.as_mut(), 64);
    let ahk = Ahk::acquire_full(
        InfluenceMap::from_kernel(),
        &space,
        &reference,
        &mut be,
    )?;
    println!(
        "sensitivity around {reference} ({} evaluations):",
        be.spent()
    );
    println!(
        "{:<28} {:>11} {:>11} {:>11}",
        "parameter", "dTTFT/step", "dTPOT/step", "dArea/step"
    );
    for p in Param::ALL {
        println!(
            "{:<28} {:>10.3}% {:>10.3}% {:>10.3}%",
            p.name(),
            ahk.perf_influence(p, 0) * 100.0,
            ahk.perf_influence(p, 1) * 100.0,
            ahk.area_influence(p) * 100.0
        );
    }
    Ok(())
}

fn cmd_report(args: &Args) -> lumina::Result<()> {
    let designs = match parse_design(&args.positional) {
        Some(d) => vec![("Custom".to_string(), d)],
        None => vec![
            ("Design A".to_string(), DesignPoint::paper_design_a()),
            ("Design B".to_string(), DesignPoint::paper_design_b()),
        ],
    };
    let mut sim = CompassSim::gpt3();
    println!("{}", render(&report_rows(&mut sim, &designs)?));
    Ok(())
}
