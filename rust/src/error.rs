//! Minimal error substrate (`anyhow` is unavailable offline).
//!
//! Mirrors the subset of the `anyhow` API this crate uses: a
//! message-chaining [`Error`], the [`err!`](crate::err)/[`bail!`](crate::bail)
//! macros, and a [`Context`] extension trait for `Result` and `Option`.
//! Like `anyhow::Error`, [`Error`] deliberately does **not** implement
//! `std::error::Error` so the blanket `From<E: std::error::Error>`
//! conversion stays coherent.

use std::fmt;

/// An error with a chain of context messages, outermost first.
pub struct Error {
    msg: String,
    cause: Option<Box<Error>>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg(msg: impl fmt::Display) -> Error {
        Error { msg: msg.to_string(), cause: None }
    }

    /// Wrap this error in an outer context message.
    pub fn context(self, msg: impl fmt::Display) -> Error {
        Error { msg: msg.to_string(), cause: Some(Box::new(self)) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut at = self.cause.as_deref();
        while let Some(e) = at {
            write!(f, ": {}", e.msg)?;
            at = e.cause.as_deref();
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(self, f)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// Construct an [`Error`] from a format string (the `anyhow!` analogue).
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::err!($($arg)*))
    };
}

/// Context-attachment extension for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> crate::Result<T>;
    fn with_context<C, F>(self, f: F) -> crate::Result<T>
    where
        C: fmt::Display,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> crate::Result<T> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C, F>(self, f: F) -> crate::Result<T>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> crate::Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C, F>(self, f: F) -> crate::Result<T>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_chains_context_outermost_first() {
        let e = Error::msg("inner").context("mid").context("outer");
        assert_eq!(e.to_string(), "outer: mid: inner");
        assert_eq!(format!("{e:?}"), "outer: mid: inner");
    }

    #[test]
    fn from_std_error_via_question_mark() {
        fn f() -> crate::Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(f().unwrap_err().to_string().contains("gone"));
    }

    #[test]
    fn result_and_option_context() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("reading meta").unwrap_err();
        assert_eq!(e.to_string(), "reading meta: gone");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "missing 7");

        let ok: Option<u32> = Some(3);
        assert_eq!(ok.context("unused").unwrap(), 3);
    }

    #[test]
    fn bail_and_err_macros() {
        fn f(fail: bool) -> crate::Result<u32> {
            if fail {
                bail!("boom {}", 42);
            }
            Ok(1)
        }
        assert_eq!(f(true).unwrap_err().to_string(), "boom 42");
        assert_eq!(f(false).unwrap(), 1);
        assert_eq!(err!("x={}", 2).to_string(), "x=2");
    }
}
