//! Serializable session state: checkpoint a mid-run exploration and
//! resume it later (`explore --checkpoint <path>` / `--resume`).
//!
//! A checkpoint does **not** serialize optimizer internals (RNG words,
//! GP training sets, pheromone trails, LUMINA's trajectory memory).
//! Because every [`crate::dse::DseSession`] performs all of its draws
//! and decisions in `ask` and only records in `tell`, the internal
//! state is a pure function of *(configuration, evaluated trajectory)*
//! — so the checkpoint stores exactly that: the identity of the run
//! (method, seed, budget, evaluator, workload fingerprint) plus the
//! `(design, metrics)` log. [`crate::dse::replay`] reconstructs the
//! session by re-running the cheap ask/tell bookkeeping against the
//! recorded results; the expensive simulator evaluations are never
//! redone. The same log warms the memo cache on resume so budget
//! accounting continues bit-identically.
//!
//! Numbers: `u64` identities (seed, workload fingerprint) are encoded
//! as hex strings — JSON numbers are f64 and would silently round
//! beyond 2^53. Metrics are f32, exactly representable in f64, and the
//! emitter prints f64 with a round-trippable shortest representation,
//! so metric bits survive save/load exactly.

use crate::design::{DesignPoint, N_PARAMS};
use crate::eval::Metrics;
use crate::pareto::ObjectiveMode;
use crate::util::json::{obj, Json};
use crate::{bail, err, Result};

/// Checkpoint format version. Still 1.0 after the PPA extension: the
/// layout only *gained* fields (an optional `objectives` mode string,
/// metrics arrays of 12 instead of 9 numbers), and reads accept both
/// shapes — a PR-3-era checkpoint without them loads with zero energy
/// fields and `latency-area` mode, which replays bit-identically
/// because default-mode session decisions never read the energy lanes.
const VERSION: f64 = 1.0;

/// A serializable snapshot of a budgeted session run.
#[derive(Debug, Clone, PartialEq)]
pub struct SessionState {
    /// Session name (must match on resume).
    pub method: String,
    /// LLM backbone profile name the run used (must match on resume —
    /// a different analyst proposes a different trajectory).
    pub model: String,
    /// Seed the session was constructed with.
    pub seed: u64,
    /// Total sample budget of the run.
    pub budget: usize,
    /// Budget units spent so far (simulator invocations).
    pub spent: usize,
    /// Evaluator name the run used (must match on resume).
    pub evaluator: String,
    /// Workload fingerprint the run evaluated under.
    pub workload_fp: u64,
    /// Objective mode the run optimized (must match on resume — a
    /// power-aware session proposes a different trajectory). Absent in
    /// pre-PPA checkpoints, which read as the default `latency-area`.
    pub objectives: ObjectiveMode,
    /// The evaluated trajectory, in order (cache hits included).
    pub log: Vec<(DesignPoint, Metrics)>,
}

impl SessionState {
    pub fn to_json(&self) -> Json {
        let samples: Vec<Json> = self
            .log
            .iter()
            .map(|(d, m)| {
                obj(vec![
                    ("design", design_to_json(d)),
                    ("metrics", metrics_to_json(m)),
                ])
            })
            .collect();
        obj(vec![
            ("version", Json::Num(VERSION)),
            ("method", Json::from(self.method.as_str())),
            ("model", Json::from(self.model.as_str())),
            ("seed", Json::Str(format!("{:#x}", self.seed))),
            ("budget", Json::from(self.budget)),
            ("spent", Json::from(self.spent)),
            ("evaluator", Json::from(self.evaluator.as_str())),
            (
                "workload_fp",
                Json::Str(format!("{:#x}", self.workload_fp)),
            ),
            ("objectives", Json::from(self.objectives.name())),
            ("samples", Json::Arr(samples)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<SessionState> {
        let version = j.get("version")?.as_f64().unwrap_or(0.0);
        if version != VERSION {
            bail!("unsupported checkpoint version {version}");
        }
        let log = j
            .get("samples")?
            .as_arr()
            .ok_or_else(|| err!("samples must be an array"))?
            .iter()
            .map(|s| {
                Ok((
                    design_from_json(s.get("design")?)?,
                    metrics_from_json(s.get("metrics")?)?,
                ))
            })
            .collect::<Result<Vec<_>>>()?;
        // Pre-PPA checkpoints carry no mode: default latency-area.
        let objectives = match j.get("objectives") {
            Ok(v) => {
                let name = v.as_str().ok_or_else(|| {
                    err!("objectives must be a string")
                })?;
                ObjectiveMode::parse(name).ok_or_else(|| {
                    err!("unknown objective mode {name:?}")
                })?
            }
            Err(_) => ObjectiveMode::LatencyArea,
        };
        Ok(SessionState {
            method: str_field(j, "method")?,
            model: str_field(j, "model")?,
            seed: hex_field(j, "seed")?,
            budget: usize_field(j, "budget")?,
            spent: usize_field(j, "spent")?,
            evaluator: str_field(j, "evaluator")?,
            workload_fp: hex_field(j, "workload_fp")?,
            objectives,
            log,
        })
    }

    /// Write the checkpoint to disk (pretty JSON). The write is
    /// staged through a sibling temp file and renamed into place, so
    /// an interruption mid-write never truncates the only copy of a
    /// live checkpoint.
    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        let mut tmp = path.as_os_str().to_os_string();
        tmp.push(".tmp");
        let tmp = std::path::PathBuf::from(tmp);
        std::fs::write(&tmp, self.to_json().pretty())?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Load a checkpoint from disk.
    pub fn load(path: &std::path::Path) -> Result<SessionState> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json(&Json::parse(&text)?)
    }

    /// Validate the identity lane of a checkpoint against the run it
    /// is being folded back into — replaying a log recorded under a
    /// different configuration would silently produce a different
    /// (but plausible-looking) trajectory. `what` names the
    /// checkpoint in error messages (`"checkpoint"`, `"cell
    /// genetic-t1"`); `None` fields are not checked.
    #[allow(clippy::too_many_arguments)]
    pub fn expect_identity(
        &self,
        what: &str,
        method: &str,
        model: Option<&str>,
        seed: u64,
        budget: usize,
        evaluator: Option<&str>,
        workload_fp: u64,
        objectives: ObjectiveMode,
    ) -> Result<()> {
        if self.method != method {
            bail!(
                "{what} ran method {:?}, expected {method:?}",
                self.method
            );
        }
        if let Some(model) = model {
            if self.model != model {
                bail!(
                    "{what} ran model {:?}, expected {model:?}",
                    self.model
                );
            }
        }
        if self.seed != seed {
            bail!(
                "{what} ran seed {:#x}, expected {seed:#x}",
                self.seed
            );
        }
        if self.budget != budget {
            bail!(
                "{what} ran budget {}, expected {budget}",
                self.budget
            );
        }
        if let Some(evaluator) = evaluator {
            if self.evaluator != evaluator {
                bail!(
                    "{what} ran evaluator {:?}, expected {evaluator:?}",
                    self.evaluator
                );
            }
        }
        if self.workload_fp != workload_fp {
            bail!(
                "{what} ran workload {:#x}, expected {workload_fp:#x}",
                self.workload_fp
            );
        }
        if self.objectives != objectives {
            bail!(
                "{what} optimized {}, expected {}",
                self.objectives.name(),
                objectives.name()
            );
        }
        Ok(())
    }
}

fn str_field(j: &Json, key: &str) -> Result<String> {
    Ok(j.get(key)?
        .as_str()
        .ok_or_else(|| err!("{key} must be a string"))?
        .to_string())
}

fn usize_field(j: &Json, key: &str) -> Result<usize> {
    let n = j
        .get(key)?
        .as_f64()
        .ok_or_else(|| err!("{key} must be a number"))?;
    if n < 0.0 || n.fract() != 0.0 {
        bail!("{key} must be a non-negative integer, got {n}");
    }
    Ok(n as usize)
}

fn hex_field(j: &Json, key: &str) -> Result<u64> {
    let s = str_field(j, key)?;
    let digits = s
        .strip_prefix("0x")
        .ok_or_else(|| err!("{key} must be a 0x-prefixed hex string"))?;
    u64::from_str_radix(digits, 16)
        .map_err(|e| err!("{key}: bad hex {s:?}: {e}"))
}

fn design_to_json(d: &DesignPoint) -> Json {
    Json::Arr(d.values.iter().map(|&v| Json::Num(v as f64)).collect())
}

fn design_from_json(j: &Json) -> Result<DesignPoint> {
    let arr = j
        .as_arr()
        .ok_or_else(|| err!("design must be an array"))?;
    if arr.len() != N_PARAMS {
        bail!("design must have {N_PARAMS} values, got {}", arr.len());
    }
    let mut values = [0u32; N_PARAMS];
    for (slot, v) in values.iter_mut().zip(arr) {
        let n = v
            .as_f64()
            .ok_or_else(|| err!("design values must be numbers"))?;
        if n < 0.0 || n.fract() != 0.0 || n > u32::MAX as f64 {
            bail!("design value {n} is not a u32");
        }
        *slot = n as u32;
    }
    Ok(DesignPoint::new(values))
}

/// Metrics as a flat 12-number array:
/// `[ttft, tpot, area, s[0][0..3], s[1][0..3], e_prefill, e_token,
/// p_avg]`. Back-compat is **old-to-new only**: [`metrics_from_json`]
/// accepts the historical 9-value shape (power fields read as 0), but
/// a PR-3-era reader rejects 12-value arrays — don't expect new
/// checkpoints to load in old binaries.
fn metrics_to_json(m: &Metrics) -> Json {
    let mut out = vec![
        m.ttft_ms as f64,
        m.tpot_ms as f64,
        m.area_mm2 as f64,
    ];
    for phase in &m.stalls {
        out.extend(phase.iter().map(|&s| s as f64));
    }
    out.push(m.prefill_energy_mj as f64);
    out.push(m.energy_per_token_mj as f64);
    out.push(m.avg_power_w as f64);
    Json::Arr(out.into_iter().map(Json::Num).collect())
}

fn metrics_from_json(j: &Json) -> Result<Metrics> {
    let arr = j
        .as_arr()
        .ok_or_else(|| err!("metrics must be an array"))?;
    if arr.len() != 9 && arr.len() != 12 {
        bail!("metrics must have 9 or 12 values, got {}", arr.len());
    }
    let v = arr
        .iter()
        .map(|x| {
            x.as_f64()
                .map(|n| n as f32)
                .ok_or_else(|| err!("metrics values must be numbers"))
        })
        .collect::<Result<Vec<f32>>>()?;
    let (e_pf, e_dc, p_avg) = if v.len() == 12 {
        (v[9], v[10], v[11])
    } else {
        (0.0, 0.0, 0.0)
    };
    Ok(Metrics {
        ttft_ms: v[0],
        tpot_ms: v[1],
        area_mm2: v[2],
        energy_per_token_mj: e_dc,
        prefill_energy_mj: e_pf,
        avg_power_w: p_avg,
        stalls: [[v[3], v[4], v[5]], [v[6], v[7], v[8]]],
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    /// Build a raw object in one expression (for malformed documents).
    fn raw_obj(pairs: Vec<(&str, Json)>) -> BTreeMap<String, Json> {
        pairs
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect()
    }
    use crate::design::Param;
    use crate::eval::Evaluator;
    use crate::sim::RooflineSim;
    use crate::workload::GPT3_175B;

    fn state() -> SessionState {
        let mut sim = RooflineSim::new(GPT3_175B);
        let a = DesignPoint::a100();
        let b = a.with(Param::Cores, 64);
        SessionState {
            method: "lumina".to_string(),
            model: "qwen3".to_string(),
            seed: 0xdead_beef_cafe_f00d,
            budget: 40,
            spent: 2,
            evaluator: "roofline-rs".to_string(),
            workload_fp: u64::MAX,
            objectives: ObjectiveMode::Ppa,
            log: vec![
                (a, sim.eval(&a).unwrap()),
                (b, sim.eval(&b).unwrap()),
            ],
        }
    }

    #[test]
    fn json_roundtrip_is_bit_exact() {
        let st = state();
        let text = st.to_json().pretty();
        let again =
            SessionState::from_json(&Json::parse(&text).unwrap())
                .unwrap();
        assert_eq!(st, again);
        // f32 metric bits survive the f64 text roundtrip exactly —
        // including the new power fields and the objective mode.
        for ((_, a), (_, b)) in st.log.iter().zip(&again.log) {
            assert_eq!(a.ttft_ms.to_bits(), b.ttft_ms.to_bits());
            assert_eq!(a.stalls, b.stalls);
            assert_eq!(
                a.energy_per_token_mj.to_bits(),
                b.energy_per_token_mj.to_bits()
            );
            assert_eq!(
                a.prefill_energy_mj.to_bits(),
                b.prefill_energy_mj.to_bits()
            );
            assert_eq!(a.avg_power_w.to_bits(), b.avg_power_w.to_bits());
        }
        assert_eq!(again.objectives, ObjectiveMode::Ppa);
    }

    /// Pinned verbatim PR-3-era checkpoint document (no `objectives`
    /// field, 9-value metrics arrays): it must still load, with the
    /// power fields zeroed and the default latency-area mode, so an old
    /// checkpoint resumes bit-identically (default-mode session
    /// decisions never read the energy lanes).
    const OLD_FORMAT_FIXTURE: &str = r#"{
  "budget": 40,
  "evaluator": "roofline-rs",
  "method": "lumina",
  "model": "qwen3",
  "samples": [
    {
      "design": [12, 108, 4, 16, 32, 192, 40, 5],
      "metrics": [36.70556, 0.4424397, 833.9728, 26.794451,
                  3.6336124, 6.277494, 0, 0.42538139, 0.017058346]
    }
  ],
  "seed": "0xdeadbeefcafef00d",
  "spent": 1,
  "version": 1,
  "workload_fp": "0xffffffffffffffff"
}"#;

    #[test]
    fn pre_ppa_checkpoint_loads_with_default_mode_and_zero_energy() {
        let st = SessionState::from_json(
            &Json::parse(OLD_FORMAT_FIXTURE).unwrap(),
        )
        .unwrap();
        assert_eq!(st.objectives, ObjectiveMode::LatencyArea);
        assert_eq!(st.method, "lumina");
        assert_eq!(st.seed, 0xdead_beef_cafe_f00d);
        assert_eq!(st.log.len(), 1);
        let (d, m) = &st.log[0];
        assert_eq!(*d, DesignPoint::a100());
        assert_eq!(m.ttft_ms, 36.70556);
        assert_eq!(m.stalls[1][1], 0.42538139);
        assert_eq!(m.energy_per_token_mj, 0.0);
        assert_eq!(m.prefill_energy_mj, 0.0);
        assert_eq!(m.avg_power_w, 0.0);
        // And it re-saves in the new 12-value shape without loss of the
        // original timing bits.
        let again = SessionState::from_json(&st.to_json()).unwrap();
        assert_eq!(st, again);
    }

    #[test]
    fn u64_identities_survive_beyond_f64_precision() {
        let st = state();
        let again = SessionState::from_json(&st.to_json()).unwrap();
        assert_eq!(again.seed, 0xdead_beef_cafe_f00d);
        assert_eq!(again.workload_fp, u64::MAX);
    }

    #[test]
    fn file_roundtrip() {
        let st = state();
        let dir = std::env::temp_dir();
        let path = dir.join("lumina_state_test.json");
        st.save(&path).unwrap();
        let again = SessionState::load(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(st, again);
    }

    #[test]
    fn expect_identity_checks_every_lane() {
        let st = state();
        let seed = 0xdead_beef_cafe_f00d_u64;
        let check = |method: &str,
                     model: Option<&str>,
                     seed: u64,
                     budget: usize,
                     evaluator: Option<&str>,
                     fp: u64,
                     mode: ObjectiveMode| {
            st.expect_identity(
                "checkpoint",
                method,
                model,
                seed,
                budget,
                evaluator,
                fp,
                mode,
            )
        };
        let m = ObjectiveMode::Ppa;
        let ev = Some("roofline-rs");
        let qw = Some("qwen3");
        assert!(check("lumina", qw, seed, 40, ev, u64::MAX, m).is_ok());
        // `None` lanes are not checked.
        assert!(
            check("lumina", None, seed, 40, None, u64::MAX, m).is_ok()
        );
        // Every mismatching lane trips.
        assert!(check("genetic", qw, seed, 40, ev, u64::MAX, m).is_err());
        let other = Some("phi4");
        assert!(check("lumina", other, seed, 40, ev, u64::MAX, m)
            .is_err());
        assert!(check("lumina", qw, 1, 40, ev, u64::MAX, m).is_err());
        assert!(check("lumina", qw, seed, 41, ev, u64::MAX, m).is_err());
        let compass = Some("compass");
        assert!(check("lumina", qw, seed, 40, compass, u64::MAX, m)
            .is_err());
        assert!(check("lumina", qw, seed, 40, ev, 7, m).is_err());
        let la = ObjectiveMode::LatencyArea;
        assert!(check("lumina", qw, seed, 40, ev, u64::MAX, la)
            .is_err());
    }

    #[test]
    fn rejects_malformed_documents() {
        // Wrong version.
        let bad = Json::Obj(raw_obj(vec![(
            "version",
            Json::Num(99.0),
        )]));
        assert!(SessionState::from_json(&bad).is_err());
        // Truncated metrics array.
        let mut st = state().to_json();
        if let Json::Obj(o) = &mut st {
            o.insert(
                "samples".to_string(),
                Json::Arr(vec![Json::Obj(raw_obj(vec![
                    ("design", design_to_json(&DesignPoint::a100())),
                    ("metrics", Json::Arr(vec![Json::Num(1.0)])),
                ]))]),
            );
        }
        assert!(SessionState::from_json(&st).is_err());
    }
}
