//! Multi-process sharded races: N worker processes split the
//! (method x trial) cells of one fused race over a shared
//! coordination directory, and a merge pass folds the per-cell
//! checkpoints back into the exact single-process result.
//!
//! The protocol runs entirely through the `--cache-dir` directory —
//! the same directory the [`crate::eval::DiskStore`] memoizes
//! `explore` runs into — using the store's advisory-lock primitive
//! for coordination:
//!
//! 1. Every worker enumerates the race's cells in the canonical
//!    trial-outer / method-inner order of
//!    [`crate::figures::race::run_race_fused`]. Cell `j` belongs to
//!    shard `i` of `n` when `j % n == i` ([`ShardSpec::owns`]), and
//!    ownership is then *claimed* on disk via
//!    [`DirLock::try_claim`], so re-running a shard spec — or
//!    pointing two workers at the same spec — never double-runs a
//!    cell.
//! 2. Each worker fuses its owned cells into one [`FusedRace`] (the
//!    cells' `ask()` batches share `eval_batch` calls exactly as the
//!    in-process race does) and checkpoints every finished cell's
//!    `(design, metrics)` log to `DIR/cells/<method>-t<trial>.json`
//!    as an ordinary [`SessionState`] (staged rename: never torn).
//! 3. `lumina race --merge` ([`merge_race`]) loads every cell in
//!    canonical order, validates its identity lane against the race
//!    configuration, and rescores it with [`score_log`].
//!
//! Because every session draws all of its randomness in `ask` and
//! the evaluators are pure functions of the design, a cell's
//! trajectory does not depend on which process ran it or on what
//! else was fused alongside it — so the merged per-cell results,
//! and the global front folded by [`merged_front`], are bitwise
//! identical to running the whole fused race in one process (see
//! `tests/shard.rs`).

use std::fmt;
use std::path::{Path, PathBuf};

use crate::baselines::all_sessions_mode;
use crate::design::{DesignPoint, DesignSpace};
use crate::dse::{FusedRace, NullObserver, Observer, SessionState};
use crate::eval::DirLock;
use crate::figures::race::{
    score_log, trial_seed, RaceConfig, RaceResult,
};
use crate::pareto::{Objectives, ParetoArchive, PHV_REF};
use crate::{bail, err, Result};

/// `SessionState.model` marker for race cells. The race harness runs
/// every method under its default configuration — a cell is not an
/// `explore` run with a chosen LLM backbone — so cells carry this
/// fixed marker and [`merge_race`] validates it like any other
/// identity lane.
pub const RACE_MODEL: &str = "race";

/// Which slice of the race's (method x trial) cells this worker runs:
/// cell `j` (in canonical enumeration order) belongs to shard `index`
/// of `count` when `j % count == index`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSpec {
    pub index: usize,
    pub count: usize,
}

impl ShardSpec {
    /// The whole race as one shard (`0/1`).
    pub fn whole() -> ShardSpec {
        ShardSpec { index: 0, count: 1 }
    }

    /// Parse the CLI `--shard I/N` form: zero-based index `I` of `N`
    /// workers, `I < N`.
    pub fn parse(s: &str) -> Result<ShardSpec> {
        let (i, n) = s
            .split_once('/')
            .ok_or_else(|| err!("--shard must be I/N, got {s:?}"))?;
        let index: usize = i
            .trim()
            .parse()
            .map_err(|_| err!("bad shard index {i:?} in {s:?}"))?;
        let count: usize = n
            .trim()
            .parse()
            .map_err(|_| err!("bad shard count {n:?} in {s:?}"))?;
        if count == 0 {
            bail!("shard count must be >= 1, got {s:?}");
        }
        if index >= count {
            bail!(
                "shard index {index} out of range for {count} \
                 shards (indices are zero-based)"
            );
        }
        Ok(ShardSpec { index, count })
    }

    /// Does this shard own cell `j` of the canonical enumeration?
    pub fn owns(&self, cell: usize) -> bool {
        cell % self.count == self.index
    }
}

impl fmt::Display for ShardSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}/{}", self.index, self.count)
    }
}

/// Subdirectory of the coordination dir holding per-cell checkpoints
/// and claim locks (kept apart from the memo store's `*.lms`
/// segments and `LOCK`).
pub fn cells_dir(dir: &Path) -> PathBuf {
    dir.join("cells")
}

/// Checkpoint path of one (method, trial) cell.
pub fn cell_path(dir: &Path, method: &str, trial: usize) -> PathBuf {
    cells_dir(dir).join(format!("{method}-t{trial}.json"))
}

fn claim_name(method: &str, trial: usize) -> String {
    format!("claim-{method}-t{trial}")
}

/// What one worker's shard pass did with the cells it enumerated.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ShardOutcome {
    /// Owned cells this worker ran and checkpointed.
    pub ran: usize,
    /// Owned cells skipped: a checkpoint already existed.
    pub done: usize,
    /// Owned cells skipped: another worker holds the claim.
    pub contended: usize,
    /// Total cells in the race, across all shards.
    pub total: usize,
}

/// Run this worker's shard of the race (see the module docs for the
/// protocol). Returns what was run/skipped; safe to re-run after a
/// crash — finished cells are skipped, half-run cells were never
/// checkpointed (the staged rename is atomic) but stay claimed, so
/// recovering them means removing their `cells/claim-*` file first.
pub fn run_race_shard(
    cfg: &RaceConfig,
    shard: ShardSpec,
    dir: &Path,
) -> Result<ShardOutcome> {
    run_race_shard_observed(cfg, shard, dir, &mut NullObserver)
}

/// [`run_race_shard`] with observer hooks (live per-cell PHV progress
/// for `race --shard I/N --verbose`).
pub fn run_race_shard_observed(
    cfg: &RaceConfig,
    shard: ShardSpec,
    dir: &Path,
    observer: &mut dyn Observer,
) -> Result<ShardOutcome> {
    let cells = cells_dir(dir);
    std::fs::create_dir_all(&cells)?;
    let space = DesignSpace::table1();
    let mut ev = cfg.evaluator.make_for(&cfg.workload);
    // Same A100 reference the in-process race computes; the evaluator
    // is pure, so warming it with one extra eval changes nothing.
    let reference = ev.eval(&DesignPoint::a100())?;
    let mut race = FusedRace::new(&space);
    let mut outcome = ShardOutcome::default();
    for trial in 0..cfg.trials {
        let seed = trial_seed(cfg.seed, trial);
        for (name, session) in all_sessions_mode(seed, cfg.objectives)
        {
            let mine = shard.owns(outcome.total);
            outcome.total += 1;
            if !mine {
                continue;
            }
            if cell_path(dir, name, trial).exists() {
                outcome.done += 1;
                continue;
            }
            if !DirLock::try_claim(&cells, &claim_name(name, trial))? {
                outcome.contended += 1;
                continue;
            }
            race.add_cell(name, trial, session, cfg.samples);
        }
    }
    let results =
        race.run(ev.as_mut(), &reference, cfg.objectives, observer)?;
    for c in &results {
        let st = SessionState {
            method: c.method.to_string(),
            model: RACE_MODEL.to_string(),
            seed: trial_seed(cfg.seed, c.trial),
            budget: cfg.samples,
            spent: c.spent,
            evaluator: ev.name().to_string(),
            workload_fp: cfg.workload.fingerprint(),
            objectives: cfg.objectives,
            log: c.log.clone(),
        };
        st.save(&cell_path(dir, c.method, c.trial))?;
        outcome.ran += 1;
    }
    Ok(outcome)
}

/// Fold the per-cell checkpoints of a completed sharded race back
/// into the single-process result: load every cell in canonical
/// order, validate its identity lane against `cfg`, and rescore with
/// [`score_log`]. Errors if any cell is missing (a shard has not
/// finished or was never launched) or ran under a different
/// configuration.
pub fn merge_race(
    cfg: &RaceConfig,
    dir: &Path,
) -> Result<Vec<RaceResult>> {
    let mut ev = cfg.evaluator.make_for(&cfg.workload);
    let reference = ev.eval(&DesignPoint::a100())?;
    let ev_name = ev.name().to_string();
    let fp = cfg.workload.fingerprint();
    let mut out = Vec::new();
    let mut missing: Vec<String> = Vec::new();
    for trial in 0..cfg.trials {
        let seed = trial_seed(cfg.seed, trial);
        for (name, _) in all_sessions_mode(seed, cfg.objectives) {
            let path = cell_path(dir, name, trial);
            if !path.exists() {
                missing.push(format!("{name}-t{trial}"));
                continue;
            }
            let st = SessionState::load(&path)?;
            st.expect_identity(
                &format!("cell {name}-t{trial}"),
                name,
                Some(RACE_MODEL),
                seed,
                cfg.samples,
                Some(&ev_name),
                fp,
                cfg.objectives,
            )?;
            out.push(score_log(
                name,
                trial,
                &st.log,
                &reference,
                cfg.objectives,
            ));
        }
    }
    if !missing.is_empty() {
        bail!(
            "{} of {} race cells not checkpointed yet: {}",
            missing.len(),
            missing.len() + out.len(),
            missing.join(", ")
        );
    }
    Ok(out)
}

/// The race's global normalized Pareto front and its hypervolume:
/// every trajectory folded through one incremental [`ParetoArchive`]
/// in input order. [`merge_race`] and the in-process fused race
/// produce results in the same canonical cell order, so the two
/// fronts — points and PHV — compare bitwise.
pub fn merged_front(
    results: &[RaceResult],
    reference: &Objectives,
) -> (Vec<Objectives>, f64) {
    let mut archive = ParetoArchive::new(PHV_REF);
    for r in results {
        for (_, o) in &r.trajectory {
            archive.push([
                o[0] / reference[0],
                o[1] / reference[1],
                o[2] / reference[2],
            ]);
        }
    }
    (archive.front(), archive.hypervolume())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_spec_parses_and_rejects() {
        assert_eq!(
            ShardSpec::parse("0/2").unwrap(),
            ShardSpec { index: 0, count: 2 }
        );
        assert_eq!(
            ShardSpec::parse(" 3 / 8 ").unwrap(),
            ShardSpec { index: 3, count: 8 }
        );
        for bad in ["", "1", "a/2", "1/b", "2/2", "5/2", "1/0", "-1/2"]
        {
            assert!(ShardSpec::parse(bad).is_err(), "{bad:?}");
        }
        assert_eq!(ShardSpec::whole().to_string(), "0/1");
    }

    #[test]
    fn shards_partition_cells_exactly_once() {
        for count in 1..5usize {
            for cell in 0..30usize {
                let owners = (0..count)
                    .filter(|&index| {
                        ShardSpec { index, count }.owns(cell)
                    })
                    .count();
                assert_eq!(owners, 1, "cell {cell} of {count}");
            }
        }
    }

    #[test]
    fn cell_paths_are_stable() {
        let dir = Path::new("/tmp/race");
        assert_eq!(
            cell_path(dir, "genetic", 3),
            Path::new("/tmp/race/cells/genetic-t3.json")
        );
        assert_eq!(claim_name("genetic", 3), "claim-genetic-t3");
    }

    #[test]
    fn merged_front_normalizes_against_reference() {
        let traj = vec![
            (DesignPoint::a100(), [2.0, 2.0, 2.0]),
            (DesignPoint::a100(), [1.0, 1.0, 1.0]),
        ];
        let results = vec![score_like("a", traj)];
        let (front, phv) = merged_front(&results, &[2.0, 2.0, 2.0]);
        // [1,1,1] normalizes to [0.5; 3] and dominates [1.0; 3].
        assert_eq!(front, vec![[0.5, 0.5, 0.5]]);
        assert!((phv - 1.5f64.powi(3)).abs() < 1e-12);
    }

    fn score_like(
        method: &'static str,
        trajectory: Vec<(DesignPoint, Objectives)>,
    ) -> RaceResult {
        RaceResult {
            method,
            trial: 0,
            phv: 0.0,
            sample_efficiency: 0.0,
            superior: 0,
            trajectory,
        }
    }
}
