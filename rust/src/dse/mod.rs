//! Ask/tell DSE sessions: the pull-style optimizer API.
//!
//! The original optimizer surface was a blocking
//! `DseMethod::run(&mut BudgetedEvaluator)` monolith: each method owned
//! its own evaluate loop, pulled one design at a time, and the harness
//! could neither batch proposals across methods nor checkpoint nor
//! observe a run in flight. This module inverts that control flow, the
//! way agentic-DSE harnesses (gem5 Co-Pilot, AgentDSE) structure the
//! loop: the *driver* owns the evaluate step and an optimizer is a
//! resumable propose/observe agent.
//!
//! * [`DseSession`] — the agent: `ask()` proposes the next batch of
//!   designs, `tell()` observes their metrics. Population methods (GA,
//!   ACO) ask a whole generation/colony per step; point methods ask one
//!   design. A session never touches an evaluator.
//! * [`driver`] — the sequential driver: ask -> budgeted evaluate ->
//!   tell, with [`observer::Observer`] event hooks and optional
//!   checkpointing. `DseMethod::run` survives as a blanket impl over
//!   `DseSession` (see [`crate::baselines`]), so every pre-redesign
//!   `run()` call site works unchanged and produces bit-identical
//!   trajectories.
//! * [`state`] — serializable [`state::SessionState`]: checkpoint a
//!   mid-run session to JSON and resume it by deterministic replay of
//!   the recorded trajectory (the expensive simulator work is never
//!   redone; the cheap ask/tell bookkeeping is).
//! * [`race`] — the fused race driver: round-robins `ask()` across all
//!   live (method x trial) cells, fuses the proposals into one
//!   `eval_batch` against the shared parallel pipeline, and scatters
//!   the `tell()`s — so a 6-method x 5-trial race feeds the evaluator
//!   batches of dozens of designs instead of thousands of singletons.
//! * [`observer`] — `on_sample` / `on_phase` / `on_front_update` hooks
//!   for live progress (the CLI's `--verbose` PHV ticker).
//! * [`shard`] — multi-process sharding of a fused race: N workers
//!   claim disjoint (method x trial) cells through the disk store's
//!   advisory-lock protocol, checkpoint each cell as a
//!   [`state::SessionState`], and a merge pass reproduces the
//!   single-process race bit-for-bit.

pub mod driver;
pub mod observer;
pub mod race;
pub mod shard;
pub mod state;

#[cfg(test)]
mod golden;

pub use driver::{drive, replay, Driver, FrontTracker};
pub use observer::{NullObserver, Observer, ProgressObserver};
pub use race::{CellResult, FusedRace};
pub use shard::{
    merge_race, merged_front, run_race_shard, run_race_shard_observed,
    ShardOutcome, ShardSpec,
};
pub use state::SessionState;

use crate::design::{DesignPoint, DesignSpace};
use crate::eval::Metrics;

/// Read-only context the driver hands to [`DseSession::ask`].
///
/// Budget numbers mirror [`crate::eval::BudgetedEvaluator`]: `remaining`
/// counts simulator invocations still allowed (cache hits ride free),
/// `evaluations` counts trajectory entries (hits included).
pub struct AskCtx<'a> {
    /// The design space being explored.
    pub space: &'a DesignSpace,
    /// Total sample budget of this session's run.
    pub budget: usize,
    /// Budget units still unspent.
    pub remaining: usize,
    /// Evaluations observed so far (length of the trajectory log).
    pub evaluations: usize,
}

impl AskCtx<'_> {
    /// Budget units consumed so far.
    pub fn spent(&self) -> usize {
        self.budget - self.remaining
    }
}

/// A DSE optimizer as a resumable propose/observe agent.
///
/// Contract:
/// * `ask` returns the designs the session wants evaluated next — one
///   for point methods, a whole generation for population methods, or
///   an empty vec to declare convergence (the driver stops).
/// * `tell` delivers `(design, metrics)` results *in proposal order*.
///   Near budget exhaustion the driver may deliver only a prefix of the
///   asked batch; sessions must accept that.
/// * All design-space-dependent computation and every RNG draw happens
///   in `ask`; `tell` only records. This is what makes a session
///   replayable from its evaluated trajectory alone (see
///   [`state::SessionState`]).
///
/// A session instance represents *one* run: drive it to exhaustion,
/// then construct a fresh session for the next trial.
pub trait DseSession {
    /// Method name as reported in races and reports.
    fn name(&self) -> &'static str;

    /// Propose the next batch of designs to evaluate.
    fn ask(&mut self, ctx: &AskCtx) -> Vec<DesignPoint>;

    /// Observe evaluation results for (a prefix of) the last `ask`.
    fn tell(&mut self, results: &[(DesignPoint, Metrics)]);

    /// Current phase label for observers (e.g. LUMINA's
    /// reference / ahk-acquire / refine / expansion / shrink machine).
    fn phase(&self) -> &'static str {
        "search"
    }
}

/// Boxed sessions delegate (mirroring the `Box<E>: Evaluator` blanket
/// in [`crate::eval`]), so `Box<dyn DseSession>` is itself a session —
/// and, through the `DseMethod` blanket, a method.
impl<S: DseSession + ?Sized> DseSession for Box<S> {
    fn name(&self) -> &'static str {
        (**self).name()
    }

    fn ask(&mut self, ctx: &AskCtx) -> Vec<DesignPoint> {
        (**self).ask(ctx)
    }

    fn tell(&mut self, results: &[(DesignPoint, Metrics)]) {
        (**self).tell(results)
    }

    fn phase(&self) -> &'static str {
        (**self).phase()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::design::DesignSpace;

    struct Never;
    impl DseSession for Never {
        fn name(&self) -> &'static str {
            "never"
        }
        fn ask(&mut self, _ctx: &AskCtx) -> Vec<DesignPoint> {
            Vec::new()
        }
        fn tell(&mut self, _results: &[(DesignPoint, Metrics)]) {}
    }

    #[test]
    fn ask_ctx_spent_is_budget_minus_remaining() {
        let space = DesignSpace::table1();
        let ctx = AskCtx {
            space: &space,
            budget: 20,
            remaining: 15,
            evaluations: 7,
        };
        assert_eq!(ctx.spent(), 5);
    }

    #[test]
    fn default_phase_is_search() {
        assert_eq!(Never.phase(), "search");
    }
}
