//! The sequential session driver: ask -> budgeted evaluate -> tell,
//! with observer events, optional checkpointing, and deterministic
//! replay for resume.
//!
//! [`drive`] is the plain loop `DseMethod::run` blankets over (see
//! [`crate::baselines`]); [`Driver`] adds the observable/checkpointed
//! variant the CLI uses. Both preserve the exact budget semantics of
//! [`BudgetedEvaluator::eval_batch`], so a session driven here produces
//! the same trajectory as the pre-redesign blocking `run()` it
//! replaced.

use std::collections::HashSet;
use std::path::PathBuf;

use crate::design::{DesignPoint, DesignSpace};
use crate::eval::{BudgetedEvaluator, Metrics, HIT_LOG_FACTOR};
use crate::pareto::{
    phv_ref, ObjectiveMode, Objectives, ParetoArchive, PHV_REF,
};
use crate::{bail, Result};

use super::observer::{NullObserver, Observer};
use super::state::SessionState;
use super::{AskCtx, DseSession};

/// Run `session` against `eval` until the budget is exhausted or the
/// session converges — the sequential driver behind the blanket
/// `DseMethod::run` impl.
pub fn drive<S: DseSession + ?Sized>(
    session: &mut S,
    space: &DesignSpace,
    eval: &mut BudgetedEvaluator,
) -> Result<()> {
    let mut obs = NullObserver;
    Driver::new(space, &mut obs).run(session, eval)
}

/// Identity of a checkpointed run, validated on resume.
#[derive(Debug, Clone)]
pub struct CheckpointSink {
    /// File the state is written to.
    pub path: PathBuf,
    /// LLM backbone profile name of the run.
    pub model: String,
    /// Seed the session was constructed with.
    pub seed: u64,
    /// Evaluator name of the run.
    pub evaluator: String,
    /// Workload fingerprint of the run.
    pub workload_fp: u64,
    /// Objective mode of the run.
    pub objectives: ObjectiveMode,
    /// Write every `every`-th driver round (0 is treated as 1). Each
    /// write serializes the whole trajectory, so long cheap-evaluator
    /// runs can raise this to amortize the O(log) cost per write;
    /// [`Driver::run`] always flushes a final state regardless.
    pub every: usize,
}

/// Mode-dispatched normalized PHV front: the 3-D latency-area archive
/// or the 4-D ppa one, behind one `push` that normalizes a sample by
/// the reference and reports the updated hypervolume when the front
/// grew. Shared by [`Driver`] and the fused race cells so both drivers
/// report identical progress for identical trajectories.
pub enum FrontTracker {
    D3 { reference: Objectives, archive: ParetoArchive },
    /// The ppa tracker keeps the reference `Metrics` so every push can
    /// route through [`Metrics::objectives_ppa_vs`], which guards the
    /// energy lane against zero-energy pre-PPA data (no NaN fronts).
    D4 { reference: Metrics, archive: ParetoArchive<4> },
}

impl FrontTracker {
    /// Tracker for `mode`, normalizing by the reference metrics.
    pub fn new(mode: ObjectiveMode, reference: &Metrics) -> Self {
        match mode {
            ObjectiveMode::LatencyArea => FrontTracker::D3 {
                reference: reference.objectives(),
                archive: ParetoArchive::new(PHV_REF),
            },
            ObjectiveMode::Ppa => FrontTracker::D4 {
                reference: *reference,
                archive: ParetoArchive::new(phv_ref::<4>()),
            },
        }
    }

    /// Push one sample; `Some(phv)` when it joined the front.
    pub fn push(&mut self, m: &Metrics) -> Option<f64> {
        match self {
            FrontTracker::D3 { reference, archive } => {
                let o = m.objectives();
                archive
                    .push(std::array::from_fn(|i| o[i] / reference[i]))
                    .then(|| archive.hypervolume())
            }
            FrontTracker::D4 { reference, archive } => {
                let (o, r) = m.objectives_ppa_vs(reference);
                archive
                    .push(std::array::from_fn(|i| o[i] / r[i]))
                    .then(|| archive.hypervolume())
            }
        }
    }
}

/// The observable sequential driver. One [`Driver::step`] performs one
/// ask/evaluate/tell round; [`Driver::run`] loops until done.
pub struct Driver<'a> {
    space: &'a DesignSpace,
    observer: &'a mut dyn Observer,
    /// Trial index reported to the observer (0 for single runs).
    pub trial: usize,
    /// Normalized PHV front tracking (set via [`Driver::track`]);
    /// without it no `on_front_update` events fire.
    pub tracker: Option<FrontTracker>,
    /// When set, [`SessionState`] is written here after every round.
    pub checkpoint: Option<CheckpointSink>,
    last_phase: &'static str,
    rounds: usize,
}

impl<'a> Driver<'a> {
    pub fn new(
        space: &'a DesignSpace,
        observer: &'a mut dyn Observer,
    ) -> Self {
        Self {
            space,
            observer,
            trial: 0,
            tracker: None,
            checkpoint: None,
            last_phase: "",
            rounds: 0,
        }
    }

    /// Enable live front/PHV tracking against `reference` in `mode`.
    pub fn track(&mut self, mode: ObjectiveMode, reference: &Metrics) {
        self.tracker = Some(FrontTracker::new(mode, reference));
    }

    fn write_checkpoint<S: DseSession + ?Sized>(
        &self,
        session: &S,
        eval: &BudgetedEvaluator,
    ) -> Result<()> {
        let Some(sink) = &self.checkpoint else { return Ok(()) };
        SessionState {
            method: session.name().to_string(),
            model: sink.model.clone(),
            seed: sink.seed,
            budget: eval.budget,
            spent: eval.spent(),
            evaluator: sink.evaluator.clone(),
            workload_fp: sink.workload_fp,
            objectives: sink.objectives,
            log: eval.log.clone(),
        }
        .save(&sink.path)
    }

    fn emit_phase<S: DseSession + ?Sized>(&mut self, session: &S) {
        let phase = session.phase();
        if phase != self.last_phase {
            self.last_phase = phase;
            self.observer.on_phase(session.name(), self.trial, phase);
        }
    }

    /// One ask/evaluate/tell round. Returns false when the session is
    /// done (budget exhausted, converged, or nothing evaluable).
    pub fn step<S: DseSession + ?Sized>(
        &mut self,
        session: &mut S,
        eval: &mut BudgetedEvaluator,
    ) -> Result<bool> {
        if eval.exhausted() {
            return Ok(false);
        }
        self.emit_phase(&*session);
        let ctx = AskCtx {
            space: self.space,
            budget: eval.budget,
            remaining: eval.remaining(),
            evaluations: eval.evaluations(),
        };
        let proposals = session.ask(&ctx);
        self.emit_phase(&*session);
        if proposals.is_empty() {
            return Ok(false);
        }
        let results = eval.eval_batch(&proposals)?;
        if results.is_empty() {
            return Ok(false);
        }
        notify_samples(
            &mut *self.observer,
            session.name(),
            self.trial,
            eval.evaluations() - results.len(),
            &results,
            self.tracker.as_mut(),
        );
        session.tell(&results);
        self.emit_phase(&*session);
        self.rounds += 1;
        let cadence = self
            .checkpoint
            .as_ref()
            .map(|s| s.every.max(1))
            .unwrap_or(1);
        if self.rounds % cadence == 0 {
            self.write_checkpoint(&*session, eval)?;
        }
        Ok(true)
    }

    /// Drive to completion. Always flushes a final checkpoint when a
    /// sink is configured, whatever its round cadence.
    pub fn run<S: DseSession + ?Sized>(
        &mut self,
        session: &mut S,
        eval: &mut BudgetedEvaluator,
    ) -> Result<()> {
        while self.step(session, eval)? {}
        if self.rounds > 0 {
            self.write_checkpoint(&*session, eval)?;
        }
        Ok(())
    }
}

/// Deliver evaluated samples to an observer and fold them into the
/// mode-aware normalized PHV tracker (`on_front_update` fires on front
/// growth). `evals_before` is the trajectory length before these
/// results landed. Shared by [`Driver::step`] and the fused race
/// scatter so both drivers report identical progress for identical
/// trajectories.
pub(crate) fn notify_samples(
    observer: &mut dyn Observer,
    method: &str,
    trial: usize,
    evals_before: usize,
    results: &[(DesignPoint, Metrics)],
    mut tracker: Option<&mut FrontTracker>,
) {
    let mut evals = evals_before;
    for (d, m) in results {
        evals += 1;
        observer.on_sample(method, trial, evals, d, m);
        if let Some(t) = tracker.as_deref_mut() {
            if let Some(phv) = t.push(m) {
                observer.on_front_update(method, trial, evals, phv);
            }
        }
    }
}

/// Rebuild a session's internal state from a checkpointed trajectory by
/// replaying ask/tell against the recorded results — no simulator
/// invocations. Returns the budget spent, reconstructed under the memo
/// accounting of the `explore` path (a design charges on its first
/// appearance only; `prewarmed` designs were in the cache before the
/// budgeted run started — e.g. the reference evaluation — and never
/// charge).
///
/// Fails when the recorded trajectory diverges from what the session
/// proposes — a wrong seed, budget, workload, or a corrupt checkpoint.
pub fn replay<S: DseSession + ?Sized>(
    session: &mut S,
    space: &DesignSpace,
    budget: usize,
    log: &[(DesignPoint, Metrics)],
    prewarmed: &[DesignPoint],
) -> Result<usize> {
    let mut seen: HashSet<DesignPoint> =
        prewarmed.iter().copied().collect();
    let mut spent = 0usize;
    let mut i = 0usize;
    while i < log.len() {
        if spent >= budget
            || i >= budget.saturating_mul(HIT_LOG_FACTOR)
        {
            bail!(
                "checkpoint log has {} samples beyond the exhausted \
                 budget ({budget})",
                log.len() - i
            );
        }
        let ctx = AskCtx {
            space,
            budget,
            remaining: budget - spent,
            evaluations: i,
        };
        let proposals = session.ask(&ctx);
        if proposals.is_empty() {
            bail!(
                "session converged after {i} samples but the \
                 checkpoint holds {}",
                log.len()
            );
        }
        // Budget-limited prefix through the same estimator the live
        // path uses ([`crate::eval::budget_prefix`]), with the seen-set
        // standing in for the memo cache.
        let remaining = budget - spent;
        let (take, _) =
            crate::eval::budget_prefix(&proposals, remaining, true, |d| {
                seen.contains(d)
            });
        if take == 0 {
            bail!("checkpoint replay stalled at sample {i}");
        }
        let n = take.min(log.len() - i);
        let batch = &log[i..i + n];
        for (k, (d, _)) in batch.iter().enumerate() {
            if proposals[k] != *d {
                bail!(
                    "checkpoint diverges at sample {}: recorded {d}, \
                     session proposed {}",
                    i + k,
                    proposals[k]
                );
            }
        }
        for (d, _) in batch {
            if seen.insert(*d) {
                spent += 1;
            }
        }
        session.tell(batch);
        i += n;
    }
    Ok(spent)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::design::Param;
    use crate::eval::Evaluator;
    use crate::sim::RooflineSim;
    use crate::workload::GPT3_175B;

    /// Proposes a fixed walk along the cores axis, one design per ask.
    struct CoresWalk {
        at: usize,
        told: usize,
    }

    impl DseSession for CoresWalk {
        fn name(&self) -> &'static str {
            "cores-walk"
        }
        fn ask(&mut self, ctx: &AskCtx) -> Vec<DesignPoint> {
            let vals = ctx.space.values(Param::Cores);
            let d = DesignPoint::a100()
                .with(Param::Cores, vals[self.at % vals.len()]);
            self.at += 1;
            vec![d]
        }
        fn tell(&mut self, results: &[(DesignPoint, Metrics)]) {
            self.told += results.len();
        }
    }

    #[test]
    fn drive_spends_exactly_the_budget() {
        let space = DesignSpace::table1();
        let mut sim = RooflineSim::new(GPT3_175B);
        let mut be = BudgetedEvaluator::new(&mut sim, 9);
        let mut s = CoresWalk { at: 0, told: 0 };
        drive(&mut s, &space, &mut be).unwrap();
        assert_eq!(be.spent(), 9);
        assert_eq!(s.told, 9);
    }

    #[test]
    fn driver_emits_samples_and_front_updates() {
        use super::super::observer::tests::CountingObserver;
        let space = DesignSpace::table1();
        let mut sim = RooflineSim::new(GPT3_175B);
        let reference = sim.eval(&DesignPoint::a100()).unwrap();
        let mut be = BudgetedEvaluator::new(&mut sim, 6);
        let mut obs = CountingObserver::default();
        let mut driver = Driver::new(&space, &mut obs);
        driver.track(ObjectiveMode::LatencyArea, &reference);
        let mut s = CoresWalk { at: 0, told: 0 };
        driver.run(&mut s, &mut be).unwrap();
        assert_eq!(obs.samples, 6);
        assert!(obs.front_updates >= 1);
        assert_eq!(obs.phases, vec!["search"]);
    }

    #[test]
    fn ppa_tracker_emits_4d_front_updates() {
        use super::super::observer::tests::CountingObserver;
        let space = DesignSpace::table1();
        let mut sim = RooflineSim::new(GPT3_175B);
        let reference = sim.eval(&DesignPoint::a100()).unwrap();
        let mut be = BudgetedEvaluator::new(&mut sim, 6);
        let mut obs = CountingObserver::default();
        let mut driver = Driver::new(&space, &mut obs);
        driver.track(ObjectiveMode::Ppa, &reference);
        let mut s = CoresWalk { at: 0, told: 0 };
        driver.run(&mut s, &mut be).unwrap();
        assert_eq!(obs.samples, 6);
        assert!(obs.front_updates >= 1);
        assert!(obs.last_phv.is_finite() && obs.last_phv >= 0.0);
    }

    #[test]
    fn replay_reconstructs_spent_with_prewarmed_reference() {
        let space = DesignSpace::table1();
        // Record a run: 5 distinct designs.
        let log = {
            let mut sim = RooflineSim::new(GPT3_175B);
            let mut be = BudgetedEvaluator::new(&mut sim, 5);
            let mut s = CoresWalk { at: 0, told: 0 };
            drive(&mut s, &space, &mut be).unwrap();
            be.log
        };
        // Replay into a fresh session.
        let mut s = CoresWalk { at: 0, told: 0 };
        let spent = replay(&mut s, &space, 5, &log, &[]).unwrap();
        assert_eq!(spent, 5);
        assert_eq!(s.told, 5);
        // A prewarmed design does not charge on replay.
        let mut s = CoresWalk { at: 0, told: 0 };
        let spent =
            replay(&mut s, &space, 5, &log, &[log[0].0]).unwrap();
        assert_eq!(spent, 4);
    }

    #[test]
    fn replay_rejects_diverging_logs() {
        let space = DesignSpace::table1();
        let mut sim = RooflineSim::new(GPT3_175B);
        let mut be = BudgetedEvaluator::new(&mut sim, 4);
        let mut s = CoresWalk { at: 0, told: 0 };
        drive(&mut s, &space, &mut be).unwrap();
        let mut log = be.log.clone();
        log[2].0 = log[2].0.with(Param::Links, 24);
        let mut fresh = CoresWalk { at: 0, told: 0 };
        assert!(replay(&mut fresh, &space, 4, &log, &[]).is_err());
    }
}
