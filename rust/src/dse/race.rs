//! The fused race driver: the payoff of the ask/tell inversion.
//!
//! The serial race (`figures::race::run_race`) grinds every
//! (method x trial) cell to completion one evaluation at a time, so the
//! batch-parallel pipeline underneath sees batches of size 1 on the hot
//! path. [`FusedRace`] instead round-robins `ask()` across all live
//! cells, fuses the proposals into **one** `eval_batch` against the
//! shared evaluator, and scatters the results back through `tell()` —
//! a 6-method x 5-trial race feeds the pipeline fused batches (every
//! point method contributes 1, GA/ACO contribute whole generations)
//! instead of thousands of singleton calls.
//!
//! Budget identity: every cell carries its own ledger with the exact
//! accounting of an uncached [`crate::eval::BudgetedEvaluator`] (one
//! unit per evaluation, prefix-truncated at exhaustion), and the
//! evaluators on this path are pure functions of the design, so each
//! cell's trajectory — and therefore its PHV / sample efficiency — is
//! bit-identical to the serial race.
//!
//! Thread budget: the fused batches shard over the process-wide
//! [`crate::eval::WorkerPool`], which all (method x trial) cells share
//! through the one race evaluator — total evaluation threads are
//! capped at `available_parallelism` (pool workers + the driver
//! thread), where the PR-1 scoped-spawn sharder re-claimed every
//! hardware thread per `eval_batch` call (see
//! `tests/soa_pool.rs::fused_race_never_exceeds_the_worker_cap`).

use crate::design::{DesignPoint, DesignSpace};
use crate::eval::{Evaluator, Metrics, HIT_LOG_FACTOR};
use crate::pareto::ObjectiveMode;
use crate::Result;

use super::driver::{notify_samples, FrontTracker};
use super::observer::Observer;
use super::{AskCtx, DseSession};

/// Completed trajectory of one (method, trial) cell.
#[derive(Debug)]
pub struct CellResult {
    pub method: &'static str,
    pub trial: usize,
    /// Evaluated designs in order (the cell's trajectory log).
    pub log: Vec<(DesignPoint, Metrics)>,
    /// Budget units consumed.
    pub spent: usize,
}

struct Cell {
    method: &'static str,
    trial: usize,
    session: Box<dyn DseSession>,
    budget: usize,
    spent: usize,
    log: Vec<(DesignPoint, Metrics)>,
    tracker: Option<FrontTracker>,
    last_phase: &'static str,
    done: bool,
}

impl Cell {
    fn exhausted(&self) -> bool {
        self.spent >= self.budget
            || self.log.len()
                >= self.budget.saturating_mul(HIT_LOG_FACTOR)
    }
}

/// Round-robin ask/tell driver over many session cells sharing one
/// evaluator.
pub struct FusedRace<'a> {
    space: &'a DesignSpace,
    cells: Vec<Cell>,
}

impl<'a> FusedRace<'a> {
    pub fn new(space: &'a DesignSpace) -> Self {
        Self { space, cells: Vec::new() }
    }

    /// Register one (method, trial) cell with its own sample budget.
    pub fn add_cell(
        &mut self,
        method: &'static str,
        trial: usize,
        session: Box<dyn DseSession>,
        budget: usize,
    ) {
        self.cells.push(Cell {
            method,
            trial,
            session,
            budget,
            spent: 0,
            log: Vec::new(),
            tracker: None,
            last_phase: "",
            done: false,
        });
    }

    /// Live cells still asking.
    pub fn live(&self) -> usize {
        self.cells.iter().filter(|c| !c.done).count()
    }

    /// Drive every cell to completion, fusing proposals across cells
    /// into shared `eval_batch` calls. `reference` normalizes the
    /// per-cell PHV the observer sees, in the objective `mode`.
    pub fn run(
        &mut self,
        eval: &mut dyn Evaluator,
        reference: &Metrics,
        mode: ObjectiveMode,
        observer: &mut dyn Observer,
    ) -> Result<Vec<CellResult>> {
        for cell in &mut self.cells {
            cell.tracker = Some(FrontTracker::new(mode, reference));
        }
        loop {
            // ---- Gather: one ask per live cell, budget-truncated.
            let mut batch: Vec<DesignPoint> = Vec::new();
            let mut spans: Vec<(usize, usize)> = Vec::new();
            for (i, cell) in self.cells.iter_mut().enumerate() {
                if cell.done {
                    continue;
                }
                if cell.exhausted() {
                    cell.done = true;
                    continue;
                }
                emit_phase(cell, observer);
                let ctx = AskCtx {
                    space: self.space,
                    budget: cell.budget,
                    remaining: cell.budget - cell.spent,
                    evaluations: cell.log.len(),
                };
                let proposals = cell.session.ask(&ctx);
                emit_phase(cell, observer);
                // Uncached-path ledger: each evaluation charges one
                // unit, so only `remaining` proposals fit.
                let take =
                    (cell.budget - cell.spent).min(proposals.len());
                if take == 0 {
                    cell.done = true;
                    continue;
                }
                spans.push((i, take));
                batch.extend_from_slice(&proposals[..take]);
            }
            if batch.is_empty() {
                break;
            }

            // ---- Fuse: one shared evaluation of every proposal.
            let metrics = eval.eval_batch(&batch)?;

            // ---- Scatter: results back to their cells, in order.
            let mut off = 0usize;
            for (i, take) in spans {
                let cell = &mut self.cells[i];
                let results: Vec<(DesignPoint, Metrics)> = batch
                    [off..off + take]
                    .iter()
                    .copied()
                    .zip(metrics[off..off + take].iter().copied())
                    .collect();
                off += take;
                cell.spent += take;
                let evals_before = cell.log.len();
                cell.log.extend(results.iter().copied());
                notify_samples(
                    observer,
                    cell.method,
                    cell.trial,
                    evals_before,
                    &results,
                    cell.tracker.as_mut(),
                );
                cell.session.tell(&results);
                emit_phase(cell, observer);
            }
        }
        Ok(self
            .cells
            .drain(..)
            .map(|c| CellResult {
                method: c.method,
                trial: c.trial,
                log: c.log,
                spent: c.spent,
            })
            .collect())
    }
}

fn emit_phase(cell: &mut Cell, observer: &mut dyn Observer) {
    let phase = cell.session.phase();
    if phase != cell.last_phase {
        cell.last_phase = phase;
        observer.on_phase(cell.method, cell.trial, phase);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::NullObserver;
    use crate::eval::Evaluator;
    use crate::sim::RooflineSim;
    use crate::workload::GPT3_175B;

    #[test]
    fn fused_cells_spend_their_own_budgets() {
        let space = DesignSpace::table1();
        let mut ev = RooflineSim::new(GPT3_175B);
        let reference = ev.eval(&DesignPoint::a100()).unwrap();
        let mut race = FusedRace::new(&space);
        for (i, (name, session)) in
            crate::baselines::all_sessions(3).into_iter().enumerate()
        {
            race.add_cell(name, 0, session, 20 + i);
        }
        let cells = race
            .run(
                &mut ev,
                &reference,
                ObjectiveMode::LatencyArea,
                &mut NullObserver,
            )
            .unwrap();
        assert_eq!(cells.len(), 6);
        for (i, c) in cells.iter().enumerate() {
            assert_eq!(c.spent, 20 + i, "{}", c.method);
            assert_eq!(c.log.len(), 20 + i, "{}", c.method);
        }
    }

    #[test]
    fn fused_batches_are_genuinely_fused() {
        // The shared evaluator must see far fewer batch calls than
        // total evaluations: every round fuses all live cells.
        struct CountingBatches {
            inner: RooflineSim,
            calls: usize,
            evals: usize,
        }
        impl Evaluator for CountingBatches {
            fn eval_batch(
                &mut self,
                designs: &[DesignPoint],
            ) -> Result<Vec<Metrics>> {
                self.calls += 1;
                self.evals += designs.len();
                self.inner.eval_batch(designs)
            }
            fn name(&self) -> &'static str {
                "counting"
            }
        }
        let space = DesignSpace::table1();
        let mut ev = CountingBatches {
            inner: RooflineSim::new(GPT3_175B),
            calls: 0,
            evals: 0,
        };
        let reference = ev.eval(&DesignPoint::a100()).unwrap();
        let (calls0, evals0) = (ev.calls, ev.evals);
        let mut race = FusedRace::new(&space);
        for (name, session) in crate::baselines::all_sessions(5) {
            race.add_cell(name, 0, session, 40);
        }
        race.run(
            &mut ev,
            &reference,
            ObjectiveMode::LatencyArea,
            &mut NullObserver,
        )
        .unwrap();
        let calls = ev.calls - calls0;
        let evals = ev.evals - evals0;
        assert_eq!(evals, 6 * 40);
        assert!(
            calls * 2 < evals,
            "{calls} batch calls for {evals} evals — not fused"
        );
    }
}
