//! Pinned pre-redesign golden trajectories.
//!
//! Before the ask/tell inversion every optimizer was a blocking
//! `run(&mut BudgetedEvaluator)` loop. These are **verbatim copies** of
//! those loops (PR 2 state), kept as frozen oracles: the equivalence
//! tests below drive each new session through the sequential driver and
//! assert its `(design, metrics)` trajectory is bit-identical to the
//! golden loop under the same seed and budget. Do not "improve" this
//! file — its whole value is that it does not change with the sessions.

use crate::design::{sample, DesignPoint, DesignSpace, Param, N_PARAMS};
use crate::eval::{BudgetedEvaluator, Metrics};
use crate::llm::{LanguageModel, SimulatedAnalyst};
use crate::lumina::explore::ExplorationEngine;
use crate::lumina::memory::{FailedMove, TrajectoryMemory};
use crate::lumina::quale::InfluenceMap;
use crate::lumina::quane::Ahk;
use crate::lumina::strategy::StrategyEngine;
use crate::lumina::LuminaConfig;
use crate::pareto::{dominates, Objectives};
use crate::stats::rng::Pcg32;
use crate::Result;

// ------------------------------------------------------- grid search

pub fn golden_grid(
    offset: u64,
    space: &DesignSpace,
    eval: &mut BudgetedEvaluator,
) -> Result<()> {
    let total = space.size();
    let budget = eval.remaining() as u64;
    if budget == 0 {
        return Ok(());
    }
    let stride = (total / budget).max(1);
    let mut idx = offset % total;
    while !eval.exhausted() {
        let d = space
            .decode_index(idx % total)
            .expect("ring index reduced modulo size() decodes");
        eval.eval(&d)?;
        idx = idx.wrapping_add(stride);
    }
    Ok(())
}

// ----------------------------------------------------- random walker

pub fn golden_random_walk(
    seed: u64,
    space: &DesignSpace,
    eval: &mut BudgetedEvaluator,
) -> Result<()> {
    let mut rng = Pcg32::with_stream(seed, 0x3a);
    let restart_p = 0.05;
    let mut current = sample::uniform(space, &mut rng);
    while !eval.exhausted() {
        if eval.eval(&current)?.is_none() {
            break;
        }
        current = if rng.chance(restart_p) {
            sample::uniform(space, &mut rng)
        } else {
            let ns = space.neighbors(&current);
            *rng.choose(&ns)
        };
    }
    Ok(())
}

// --------------------------------------------------------------- bo

fn features(space: &DesignSpace, d: &DesignPoint) -> [f64; N_PARAMS] {
    let mut f = [0f64; N_PARAMS];
    for p in Param::ALL {
        let vals = space.values(p);
        let idx = space
            .index_of(p, d.get(p))
            .unwrap_or_else(|| space.nearest_index(p, d.get(p)));
        f[p.index()] = idx as f64 / (vals.len() - 1).max(1) as f64;
    }
    f
}

fn kernel(
    length_scale: f64,
    a: &[f64; N_PARAMS],
    b: &[f64; N_PARAMS],
) -> f64 {
    let mut d2 = 0.0;
    for i in 0..N_PARAMS {
        let d = a[i] - b[i];
        d2 += d * d;
    }
    (-d2 / (2.0 * length_scale * length_scale)).exp()
}

fn random_weights(rng: &mut Pcg32) -> [f64; 3] {
    let a = rng.f64();
    let b = rng.f64();
    let (lo, hi) = if a < b { (a, b) } else { (b, a) };
    [lo, hi - lo, 1.0 - hi]
}

fn cholesky(k: &mut [f64], n: usize) -> bool {
    for i in 0..n {
        for j in 0..=i {
            let mut s = k[i * n + j];
            for p in 0..j {
                s -= k[i * n + p] * k[j * n + p];
            }
            if i == j {
                if s <= 0.0 {
                    return false;
                }
                k[i * n + j] = s.sqrt();
            } else {
                k[i * n + j] = s / k[j * n + j];
            }
        }
        for j in i + 1..n {
            k[i * n + j] = 0.0;
        }
    }
    true
}

fn cho_solve(k: &[f64], n: usize, b: &[f64]) -> Vec<f64> {
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for j in 0..i {
            s -= k[i * n + j] * y[j];
        }
        y[i] = s / k[i * n + i];
    }
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for j in i + 1..n {
            s -= k[j * n + i] * x[j];
        }
        x[i] = s / k[i * n + i];
    }
    x
}

fn norm_pdf(z: f64) -> f64 {
    (-0.5 * z * z).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

fn norm_cdf(z: f64) -> f64 {
    let t = 1.0 / (1.0 + 0.2316419 * z.abs());
    let poly = t
        * (0.319381530
            + t * (-0.356563782
                + t * (1.781477937
                    + t * (-1.821255978 + t * 1.330274429))));
    let tail = norm_pdf(z) * poly;
    if z >= 0.0 {
        1.0 - tail
    } else {
        tail
    }
}

pub fn golden_bo(
    seed: u64,
    space: &DesignSpace,
    eval: &mut BudgetedEvaluator,
) -> Result<()> {
    let mut rng = Pcg32::with_stream(seed, 0xb0);
    let n_init = 12usize;
    let pool = 256usize;
    let max_train = 160usize;
    let length_scale = 0.35;
    let noise = 1e-4;

    let init =
        sample::stratified(space, &mut rng, n_init.min(eval.remaining()));
    eval.eval_batch(&init)?;

    while !eval.exhausted() {
        let all: Vec<(DesignPoint, Objectives)> = eval
            .log
            .iter()
            .map(|(d, m)| (*d, m.objectives()))
            .collect();
        let mut mean = [0f64; 3];
        for (_, o) in &all {
            for i in 0..3 {
                mean[i] += o[i];
            }
        }
        for m in &mut mean {
            *m /= all.len() as f64;
        }
        let w = random_weights(&mut rng);
        let scalar = |o: &Objectives| {
            (0..3).map(|i| w[i] * o[i] / mean[i]).sum::<f64>()
        };

        let mut idx: Vec<usize> = (0..all.len()).collect();
        if all.len() > max_train {
            idx.sort_by(|&a, &b| {
                scalar(&all[a].1)
                    .partial_cmp(&scalar(&all[b].1))
                    .unwrap()
            });
            let mut keep: Vec<usize> = idx[..max_train / 2].to_vec();
            keep.extend(all.len() - max_train / 2..all.len());
            keep.sort();
            keep.dedup();
            idx = keep;
        }

        let xs: Vec<[f64; N_PARAMS]> = idx
            .iter()
            .map(|&i| features(space, &all[i].0))
            .collect();
        let ys: Vec<f64> =
            idx.iter().map(|&i| scalar(&all[i].1)).collect();
        let y_mean = ys.iter().sum::<f64>() / ys.len() as f64;
        let yc: Vec<f64> = ys.iter().map(|y| y - y_mean).collect();

        let n = xs.len();
        let mut k = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                k[i * n + j] = kernel(length_scale, &xs[i], &xs[j])
                    + if i == j { noise } else { 0.0 };
            }
        }
        let chol = cholesky(&mut k, n);
        let alpha = if chol {
            cho_solve(&k, n, &yc)
        } else {
            let d = sample::uniform(space, &mut rng);
            eval.eval(&d)?;
            continue;
        };

        let best_y = ys.iter().cloned().fold(f64::INFINITY, f64::min);
        let incumbent = idx
            .iter()
            .min_by(|&&a, &&b| {
                scalar(&all[a].1)
                    .partial_cmp(&scalar(&all[b].1))
                    .unwrap()
            })
            .map(|&i| all[i].0)
            .unwrap_or_else(DesignPoint::a100);

        let mut best_cand: Option<(DesignPoint, f64)> = None;
        for c in 0..pool {
            let cand = if c % 4 == 0 {
                let ns = space.neighbors(&incumbent);
                *rng.choose(&ns)
            } else {
                sample::uniform(space, &mut rng)
            };
            let f = features(space, &cand);
            let kv: Vec<f64> = xs
                .iter()
                .map(|x| kernel(length_scale, x, &f))
                .collect();
            let mu = y_mean
                + kv.iter()
                    .zip(&alpha)
                    .map(|(a, b)| a * b)
                    .sum::<f64>();
            let v = cho_solve(&k, n, &kv);
            let var = (kernel(length_scale, &f, &f)
                - kv.iter()
                    .zip(&v)
                    .map(|(a, b)| a * b)
                    .sum::<f64>())
            .max(1e-12);
            let sigma = var.sqrt();
            let z = (best_y - mu) / sigma;
            let ei = sigma * (z * norm_cdf(z) + norm_pdf(z));
            if ei.is_finite()
                && best_cand.map(|(_, b)| ei > b).unwrap_or(true)
            {
                best_cand = Some((cand, ei));
            }
        }
        let next = best_cand
            .map(|(c, _)| c)
            .unwrap_or_else(|| sample::uniform(space, &mut rng));
        eval.eval(&next)?;
    }
    Ok(())
}

// --------------------------------------------------------------- ga

fn pareto_ranks(objs: &[Objectives]) -> Vec<usize> {
    let n = objs.len();
    let mut rank = vec![usize::MAX; n];
    let mut assigned = 0;
    let mut level = 0;
    while assigned < n {
        let mut this_level = Vec::new();
        for i in 0..n {
            if rank[i] != usize::MAX {
                continue;
            }
            let dominated = (0..n).any(|j| {
                j != i
                    && rank[j] == usize::MAX
                    && dominates(&objs[j], &objs[i])
            });
            if !dominated {
                this_level.push(i);
            }
        }
        for &i in &this_level {
            rank[i] = level;
        }
        let newly = this_level.len();
        if newly == 0 {
            for r in rank.iter_mut() {
                if *r == usize::MAX {
                    *r = level;
                }
            }
            break;
        }
        assigned += newly;
        level += 1;
    }
    rank
}

fn crowding(objs: &[Objectives]) -> Vec<f64> {
    let n = objs.len();
    let mut dist = vec![0.0f64; n];
    for k in 0..3 {
        let mut idx: Vec<usize> = (0..n).collect();
        idx.sort_by(|&a, &b| {
            objs[a][k].partial_cmp(&objs[b][k]).unwrap()
        });
        let span = (objs[idx[n - 1]][k] - objs[idx[0]][k]).max(1e-12);
        dist[idx[0]] = f64::INFINITY;
        dist[idx[n - 1]] = f64::INFINITY;
        for w in 1..n - 1 {
            dist[idx[w]] +=
                (objs[idx[w + 1]][k] - objs[idx[w - 1]][k]) / span;
        }
    }
    dist
}

fn ordered(x: f64) -> u64 {
    let bits = x.to_bits();
    if x >= 0.0 {
        bits ^ (1 << 63)
    } else {
        !bits
    }
}

pub fn golden_ga(
    seed: u64,
    space: &DesignSpace,
    eval: &mut BudgetedEvaluator,
) -> Result<()> {
    let mut rng = Pcg32::with_stream(seed, 0x6a);
    let pop_size = 24usize;
    let mutation_p = 0.25;

    let crossover =
        |rng: &mut Pcg32, a: &DesignPoint, b: &DesignPoint| {
            let mut child = *a;
            for p in Param::ALL {
                if rng.chance(0.5) {
                    child.set(p, b.get(p));
                }
            }
            child
        };
    let mutate = |rng: &mut Pcg32, d: &DesignPoint| {
        let mut out = *d;
        for p in Param::ALL {
            if rng.chance(mutation_p) {
                let delta = if rng.chance(0.5) { 1 } else { -1 };
                out = space.step(&out, p, delta);
            }
        }
        out
    };

    let n0 = pop_size.min(eval.remaining());
    if n0 == 0 {
        return Ok(());
    }
    let init = sample::stratified(space, &mut rng, n0);
    let mut pop: Vec<(DesignPoint, Objectives)> = eval
        .eval_batch(&init)?
        .into_iter()
        .map(|(d, m)| (d, m.objectives()))
        .collect();

    while !eval.exhausted() && pop.len() >= 2 {
        let objs: Vec<Objectives> =
            pop.iter().map(|(_, o)| *o).collect();
        let ranks = pareto_ranks(&objs);
        let crowd = crowding(&objs);
        let tournament = |rng: &mut Pcg32| {
            let a = rng.range_usize(0, pop.len());
            let b = rng.range_usize(0, pop.len());
            if (ranks[a], std::cmp::Reverse(ordered(crowd[a])))
                < (ranks[b], std::cmp::Reverse(ordered(crowd[b])))
            {
                a
            } else {
                b
            }
        };
        let pa = tournament(&mut rng);
        let pb = tournament(&mut rng);
        let child = {
            let x = crossover(&mut rng, &pop[pa].0.clone(), &pop[pb].0);
            mutate(&mut rng, &x)
        };
        let Some(m) = eval.eval(&child)? else { break };
        pop.push((child, m.objectives()));

        if pop.len() > pop_size {
            let objs: Vec<Objectives> =
                pop.iter().map(|(_, o)| *o).collect();
            let ranks = pareto_ranks(&objs);
            let crowd = crowding(&objs);
            let worst = (0..pop.len())
                .max_by(|&a, &b| {
                    (ranks[a], std::cmp::Reverse(ordered(crowd[a])))
                        .cmp(&(
                            ranks[b],
                            std::cmp::Reverse(ordered(crowd[b])),
                        ))
                })
                .unwrap();
            pop.swap_remove(worst);
        }
    }
    Ok(())
}

// -------------------------------------------------------------- aco

fn aco_sample_design(
    rng: &mut Pcg32,
    alpha: f64,
    space: &DesignSpace,
    pher: &[Vec<f64>; N_PARAMS],
) -> DesignPoint {
    let mut values = [0u32; N_PARAMS];
    for p in Param::ALL {
        let tr = &pher[p.index()];
        let weights: Vec<f64> =
            tr.iter().map(|t| t.powf(alpha)).collect();
        let total: f64 = weights.iter().sum();
        let mut pick = rng.f64() * total;
        let mut idx = 0;
        for (i, w) in weights.iter().enumerate() {
            pick -= w;
            if pick <= 0.0 {
                idx = i;
                break;
            }
        }
        values[p.index()] = space.values(p)[idx];
    }
    DesignPoint::new(values)
}

pub fn golden_aco(
    seed: u64,
    space: &DesignSpace,
    eval: &mut BudgetedEvaluator,
) -> Result<()> {
    let mut rng = Pcg32::with_stream(seed, 0xac0);
    let alpha = 0.7;
    let rho = 0.04;
    let ants = 20usize;
    let elite = 1usize;

    let mut pher: [Vec<f64>; N_PARAMS] = std::array::from_fn(|i| {
        vec![1.0; space.values(Param::from_index(i)).len()]
    });
    let mut mean: Objectives = [0.0; 3];
    let mut seen = 0usize;

    while !eval.exhausted() {
        let n = ants.min(eval.remaining());
        let designs: Vec<DesignPoint> = (0..n)
            .map(|_| aco_sample_design(&mut rng, alpha, space, &pher))
            .collect();
        let results = eval.eval_batch(&designs)?;
        if results.is_empty() {
            break;
        }
        for (_, m) in &results {
            let o = m.objectives();
            seen += 1;
            for i in 0..3 {
                mean[i] += (o[i] - mean[i]) / seen as f64;
            }
        }
        let mut scored: Vec<(f64, &DesignPoint)> = results
            .iter()
            .map(|(d, m)| {
                let o = m.objectives();
                let s: f64 = (0..3)
                    .map(|i| o[i] / mean[i].max(1e-30))
                    .sum();
                (1.0 / s.max(1e-9), d)
            })
            .collect();
        scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());

        for tr in pher.iter_mut() {
            for t in tr.iter_mut() {
                *t = (*t * (1.0 - rho)).max(0.05);
            }
        }
        for (q, d) in scored.iter().take(elite) {
            for p in Param::ALL {
                if let Some(i) = space.index_of(p, d.get(p)) {
                    pher[p.index()][i] += q;
                }
            }
        }
    }
    Ok(())
}

// ----------------------------------------------------------- lumina

fn lum_score(m: &Metrics, reference: &Metrics, expansion: bool) -> f64 {
    let nt = (m.ttft_ms / reference.ttft_ms) as f64;
    let nd = (m.tpot_ms / reference.tpot_ms) as f64;
    let na = (m.area_mm2 / reference.area_mm2) as f64;
    if expansion {
        nt + nd + na
    } else {
        nt + nd + 0.5 * na.max(1.0) * 4.0 - 2.0
    }
}

fn lum_shrink_sweep(
    cfg: &LuminaConfig,
    space: &DesignSpace,
    eval: &mut BudgetedEvaluator,
    tm: &mut TrajectoryMemory,
    ahk: &Ahk,
    reference: &Metrics,
) -> Result<()> {
    let mut rng = Pcg32::with_stream(cfg.seed, 0x54);
    let mut ee = ExplorationEngine::new(cfg.seed ^ 0x54);
    let mut step = tm.len();
    let mut anchor = tm
        .best_weighted(&reference.objectives(), &[1.0, 1.0, 2.0])
        .map(|s| (s.design, s.metrics))
        .unwrap_or((DesignPoint::a100(), *reference));
    let mut current = anchor;
    while !eval.exhausted() {
        let mut cands: Vec<Param> = Param::ALL
            .iter()
            .copied()
            .filter(|&p| space.step(&current.0, p, -1) != current.0)
            .collect();
        cands.sort_by(|&a, &b| {
            let crit = |p: Param| {
                ahk.perf_influence(p, 0).abs()
                    + ahk.perf_influence(p, 1).abs()
            };
            crit(a).partial_cmp(&crit(b)).unwrap()
        });
        let Some(&p) = cands.first() else { break };
        let next = space.step(&current.0, p, -1);
        let proposal = if tm.contains(&next) {
            let q = *rng.choose(&cands);
            space.step(&next, q, -1)
        } else {
            next
        };
        if tm.contains(&proposal) {
            current = anchor;
            let q = *rng.choose(&Param::ALL);
            let nudged = space.step(&current.0, q, -1);
            if tm.contains(&nudged) {
                break;
            }
            if let Some(m) = ee.evaluate(eval, tm, nudged, step)? {
                step += 1;
                current = (nudged, m);
            }
            continue;
        }
        let Some(m) = ee.evaluate(eval, tm, proposal, step)? else {
            break;
        };
        step += 1;
        let in_box = m.ttft_ms < 2.0 * reference.ttft_ms
            && m.tpot_ms < 2.0 * reference.tpot_ms;
        if in_box {
            current = (proposal, m);
            if m.area_mm2 < anchor.1.area_mm2 {
                anchor = current;
            }
        } else {
            current = anchor;
        }
    }
    Ok(())
}

pub fn golden_lumina(
    cfg: LuminaConfig,
    use_default_prompts: bool,
    space: &DesignSpace,
    eval: &mut BudgetedEvaluator,
) -> Result<()> {
    let mut model = SimulatedAnalyst::new(cfg.model, cfg.seed ^ 0x5e5e);
    let mut ee = ExplorationEngine::new(cfg.seed ^ 0xe0e0);
    let mut tm = TrajectoryMemory::new();

    let reference_design = DesignPoint::a100();
    let Some(reference) = eval.eval(&reference_design)? else {
        return Ok(());
    };
    tm.record(reference_design, reference, 0);

    let qual = InfluenceMap::from_kernel();
    let mut ahk = if eval.budget >= cfg.full_quane_threshold {
        let a = Ahk::acquire_full(qual, space, &reference_design, eval)?;
        for (i, (d, m)) in eval.log.iter().skip(1).enumerate() {
            tm.record(*d, *m, 1 + i);
        }
        a
    } else {
        Ahk::acquire_cheap(qual, space, &reference_design)
    };

    let mut current = reference_design;
    let mut current_m = reference;
    let expansion_at = eval.budget * 3 / 5;
    let mut expansion = false;
    let mut best_score = lum_score(&reference, &reference, expansion);
    let mut stale = 0usize;
    let mut step = tm.len();
    let shrink_at = eval.budget * 4 / 5;

    while !eval.exhausted() {
        if eval.budget > 64 && eval.spent() >= shrink_at {
            lum_shrink_sweep(
                &cfg, space, eval, &mut tm, &ahk, &reference,
            )?;
            let mut rng = Pcg32::with_stream(cfg.seed, 0xf111);
            let mut fill_step = tm.len();
            while !eval.exhausted() {
                let anchor = tm
                    .best_weighted(
                        &reference.objectives(),
                        &[1.0, 1.0, 1.0 + rng.f64()],
                    )
                    .map(|s| s.design)
                    .unwrap_or(reference_design);
                let mut d = anchor;
                for _ in 0..1 + rng.range_usize(0, 3) {
                    let p = *rng.choose(&Param::ALL);
                    let delta = if rng.chance(0.5) { 1 } else { -1 };
                    d = space.step(&d, p, delta);
                }
                if tm.contains(&d) {
                    d = sample::uniform(space, &mut rng);
                }
                if ee.evaluate(eval, &mut tm, d, fill_step)?.is_some()
                {
                    fill_step += 1;
                }
            }
            break;
        }
        if !expansion
            && eval.spent() >= expansion_at
            && eval.budget > 64
        {
            expansion = true;
            best_score = f64::INFINITY;
        }
        let directive = {
            let mut se = StrategyEngine::new(
                &mut model as &mut dyn LanguageModel,
            );
            if use_default_prompts {
                se.system_prompt =
                    crate::llm::prompts::SYSTEM_DEFAULT.to_string();
                se.enforce_rules = false;
            }
            se.area_ceiling = if expansion {
                2.0 * cfg.area_ceiling
            } else {
                cfg.area_ceiling
            };
            se.propose(
                space, &current, &current_m, &reference, &ahk, &tm,
                None,
            )
        };
        let proposal = ee.materialize(space, &current, &directive, &tm);
        let Some(m) = ee.evaluate(eval, &mut tm, proposal, step)?
        else {
            break;
        };
        step += 1;

        let metric = directive.phase.index();
        let obs = |new: f32, old: f32| ((new - old) / old) as f64;
        let delta_metric = match metric {
            0 => obs(m.ttft_ms, current_m.ttft_ms),
            _ => obs(m.tpot_ms, current_m.tpot_ms),
        };
        let (boost, steps) = directive.boost;
        ahk.refine(boost, metric, delta_metric / steps as f64);

        if delta_metric > 0.01 {
            tm.record_failure(FailedMove {
                param: boost,
                direction: 1,
                metric,
            });
        }

        let s = lum_score(&m, &reference, expansion);
        if s < best_score - 1e-6 {
            best_score = s;
            current = proposal;
            current_m = m;
            stale = 0;
        } else {
            stale += 1;
            if stale >= cfg.patience {
                if let Some(best) = tm.best_weighted(
                    &reference.objectives(),
                    &[1.0, 1.0, 0.7],
                ) {
                    current = best.design;
                    current_m = best.metrics;
                }
                let mut rng =
                    Pcg32::new(cfg.seed ^ step as u64);
                let p = *rng.choose(&Param::ALL);
                let nudged = space.step(&current, p, 1);
                if !tm.contains(&nudged) {
                    if let Some(nm) =
                        ee.evaluate(eval, &mut tm, nudged, step)?
                    {
                        step += 1;
                        current = nudged;
                        current_m = nm;
                    }
                }
                stale = 0;
            }
        }
    }
    Ok(())
}

// ------------------------------------------------------------ tests

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::{
        AntColony, BayesOpt, DseMethod, Genetic, GridSearch,
        RandomWalker,
    };
    use crate::lumina::Lumina;
    use crate::sim::RooflineSim;
    use crate::workload::GPT3_175B;

    type Log = Vec<(DesignPoint, Metrics)>;

    fn with_eval(
        budget: usize,
        f: impl FnOnce(&DesignSpace, &mut BudgetedEvaluator),
    ) -> Log {
        let space = DesignSpace::table1();
        let mut sim = RooflineSim::new(GPT3_175B);
        let mut be = BudgetedEvaluator::new(&mut sim, budget);
        f(&space, &mut be);
        be.log
    }

    #[test]
    fn grid_session_matches_golden_trajectory() {
        let seed = 42u64.wrapping_mul(0x2545f4914f6cdd1d);
        let new = with_eval(50, |space, be| {
            GridSearch::with_offset(seed).run(space, be).unwrap();
        });
        let gold = with_eval(50, |space, be| {
            golden_grid(seed, space, be).unwrap();
        });
        assert_eq!(new, gold);
    }

    #[test]
    fn random_walker_session_matches_golden_trajectory() {
        let new = with_eval(60, |space, be| {
            RandomWalker::new(7).run(space, be).unwrap();
        });
        let gold = with_eval(60, |space, be| {
            golden_random_walk(7, space, be).unwrap();
        });
        assert_eq!(new, gold);
    }

    #[test]
    fn bayes_opt_session_matches_golden_trajectory() {
        let new = with_eval(60, |space, be| {
            BayesOpt::new(3).run(space, be).unwrap();
        });
        let gold = with_eval(60, |space, be| {
            golden_bo(3, space, be).unwrap();
        });
        assert_eq!(new, gold);
    }

    #[test]
    fn genetic_session_matches_golden_trajectory() {
        let new = with_eval(60, |space, be| {
            Genetic::new(11).run(space, be).unwrap();
        });
        let gold = with_eval(60, |space, be| {
            golden_ga(11, space, be).unwrap();
        });
        assert_eq!(new, gold);
    }

    #[test]
    fn ant_colony_session_matches_golden_trajectory() {
        let new = with_eval(55, |space, be| {
            AntColony::new(2).run(space, be).unwrap();
        });
        let gold = with_eval(55, |space, be| {
            golden_aco(2, space, be).unwrap();
        });
        assert_eq!(new, gold);
    }

    #[test]
    fn lumina_session_matches_golden_small_budget() {
        // Budget 40: cheap-QuanE path, no expansion/shrink phases.
        let new = with_eval(40, |space, be| {
            Lumina::with_seed(11).run(space, be).unwrap();
        });
        let gold = with_eval(40, |space, be| {
            golden_lumina(
                LuminaConfig { seed: 11, ..Default::default() },
                false,
                space,
                be,
            )
            .unwrap();
        });
        assert_eq!(new, gold);
    }

    #[test]
    fn lumina_session_matches_golden_full_phase_machine() {
        // Budget 150: full QuanE sweep, expansion at 90, shrink at
        // 120, fill to exhaustion — every phase of the state machine.
        let new = with_eval(150, |space, be| {
            Lumina::with_seed(4).run(space, be).unwrap();
        });
        let gold = with_eval(150, |space, be| {
            golden_lumina(
                LuminaConfig { seed: 4, ..Default::default() },
                false,
                space,
                be,
            )
            .unwrap();
        });
        assert_eq!(new.len(), gold.len());
        assert_eq!(new, gold);
    }

    #[test]
    fn lumina_ablation_matches_golden() {
        let new = with_eval(50, |space, be| {
            let mut lum = Lumina::with_seed(9);
            lum.use_default_prompts = true;
            lum.run(space, be).unwrap();
        });
        let gold = with_eval(50, |space, be| {
            golden_lumina(
                LuminaConfig { seed: 9, ..Default::default() },
                true,
                space,
                be,
            )
            .unwrap();
        });
        assert_eq!(new, gold);
    }
}
