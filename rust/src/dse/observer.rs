//! Observer hooks: live visibility into a running session without the
//! optimizer or the driver knowing who is watching. The drivers emit
//! three events — a sample landed, the session changed phase, the
//! Pareto front grew — and implementations render them (the CLI's
//! `--verbose` progress ticker) or ignore them ([`NullObserver`]).

use crate::design::DesignPoint;
use crate::eval::Metrics;

/// Event sink for session drivers. All methods default to no-ops so
/// implementations override only what they render.
pub trait Observer {
    /// The session entered a new phase (see
    /// [`crate::dse::DseSession::phase`]).
    fn on_phase(
        &mut self,
        _method: &str,
        _trial: usize,
        _phase: &'static str,
    ) {
    }

    /// One evaluated sample landed in the trajectory. `evals` is the
    /// trajectory length *including* this sample.
    fn on_sample(
        &mut self,
        _method: &str,
        _trial: usize,
        _evals: usize,
        _design: &DesignPoint,
        _metrics: &Metrics,
    ) {
    }

    /// The sample joined the Pareto front; `phv` is the updated
    /// hypervolume of the normalized front.
    fn on_front_update(
        &mut self,
        _method: &str,
        _trial: usize,
        _evals: usize,
        _phv: f64,
    ) {
    }
}

/// Discards every event (the default driver observer).
pub struct NullObserver;

impl Observer for NullObserver {}

/// Prints phase transitions and front growth to stdout — the
/// `explore --verbose` / `race --fused --verbose` live ticker.
pub struct ProgressObserver {
    /// Also print every `sample_every`-th plain sample (0 = never).
    pub sample_every: usize,
}

impl ProgressObserver {
    pub fn new() -> Self {
        Self { sample_every: 0 }
    }
}

impl Default for ProgressObserver {
    fn default() -> Self {
        Self::new()
    }
}

impl Observer for ProgressObserver {
    fn on_phase(
        &mut self,
        method: &str,
        trial: usize,
        phase: &'static str,
    ) {
        println!("[{method}#{trial}] phase -> {phase}");
    }

    fn on_sample(
        &mut self,
        method: &str,
        trial: usize,
        evals: usize,
        design: &DesignPoint,
        _metrics: &Metrics,
    ) {
        if self.sample_every > 0 && evals % self.sample_every == 0 {
            println!("[{method}#{trial}] {evals:>5} {design}");
        }
    }

    fn on_front_update(
        &mut self,
        method: &str,
        trial: usize,
        evals: usize,
        phv: f64,
    ) {
        println!("[{method}#{trial}] {evals:>5} PHV={phv:.4}");
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;

    /// Counts events — used by driver/race tests too.
    #[derive(Default)]
    pub struct CountingObserver {
        pub phases: Vec<&'static str>,
        pub samples: usize,
        pub front_updates: usize,
        pub last_phv: f64,
    }

    impl Observer for CountingObserver {
        fn on_phase(
            &mut self,
            _method: &str,
            _trial: usize,
            phase: &'static str,
        ) {
            self.phases.push(phase);
        }
        fn on_sample(
            &mut self,
            _method: &str,
            _trial: usize,
            _evals: usize,
            _design: &DesignPoint,
            _metrics: &Metrics,
        ) {
            self.samples += 1;
        }
        fn on_front_update(
            &mut self,
            _method: &str,
            _trial: usize,
            _evals: usize,
            phv: f64,
        ) {
            self.front_updates += 1;
            self.last_phv = phv;
        }
    }

    #[test]
    fn null_observer_accepts_all_events() {
        let mut o = NullObserver;
        o.on_phase("m", 0, "p");
        o.on_sample(
            "m",
            0,
            1,
            &DesignPoint::a100(),
            &Metrics {
                ttft_ms: 1.0,
                tpot_ms: 1.0,
                area_mm2: 1.0,
                stalls: [[1.0, 0.0, 0.0]; 2],
                ..Default::default()
            },
        );
        o.on_front_update("m", 0, 1, 0.5);
    }
}
