//! Design-point memoization: the Table-1 space is a discrete grid, so a
//! [`DesignPoint`] hashes exactly and revisits (BO re-probing incumbents,
//! GA elitism, ACO trail reinforcement, LUMINA restarts) can be served
//! from a map instead of re-running the simulator.
//!
//! Entries are keyed on **(workload fingerprint, design)** — the metrics
//! of a design are a function of the workload it was evaluated under, so
//! the same design under two different workloads (a suite sweep, an
//! evaluator whose workload is reconfigured) must never alias to one
//! entry. The fingerprint is read from the inner evaluator on every
//! batch via [`Evaluator::workload_fingerprint`].
//!
//! [`CachedEvaluator`] wraps any [`Evaluator`]; unique uncached designs
//! of a batch are forwarded to the inner evaluator in first-appearance
//! order (so inner results stay deterministic), then every requested
//! design — duplicates included — is assembled from the map in input
//! order. Hit/miss counters feed [`BudgetedEvaluator`]'s accounting:
//! hits never burn sample budget.
//!
//! [`BudgetedEvaluator`]: crate::eval::BudgetedEvaluator

use std::collections::{HashMap, HashSet};

use crate::design::DesignPoint;
use crate::eval::{CacheCounters, Evaluator, Metrics};
use crate::Result;

/// Memoizing adapter over any evaluator.
#[derive(Debug)]
pub struct CachedEvaluator<E> {
    inner: E,
    map: HashMap<(u64, DesignPoint), Metrics>,
    counters: CacheCounters,
}

impl<E: Evaluator> CachedEvaluator<E> {
    pub fn new(inner: E) -> Self {
        Self { inner, map: HashMap::new(), counters: CacheCounters::default() }
    }

    /// Lookup counters since construction.
    pub fn counters(&self) -> CacheCounters {
        self.counters
    }

    /// Distinct (workload, design) pairs memoized.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn inner(&self) -> &E {
        &self.inner
    }

    /// Mutable access to the wrapped evaluator (e.g. to reconfigure its
    /// workload; the cache re-reads the fingerprint on every batch, so
    /// existing entries stay correct under their original key).
    pub fn inner_mut(&mut self) -> &mut E {
        &mut self.inner
    }

    pub fn into_inner(self) -> E {
        self.inner
    }

    /// Drop all memoized entries (counters are kept).
    pub fn clear(&mut self) {
        self.map.clear();
    }

    /// Seed known results under the inner evaluator's *current*
    /// workload fingerprint without touching the hit/miss counters —
    /// the checkpoint-resume path replays a recorded trajectory into
    /// the cache so the resumed run charges budget exactly like the
    /// uninterrupted one. Existing entries win on conflict.
    pub fn warm(&mut self, pairs: &[(DesignPoint, Metrics)]) {
        let fp = self.inner.workload_fingerprint();
        for (d, m) in pairs {
            self.map.entry((fp, *d)).or_insert(*m);
        }
    }
}

impl<E: Evaluator> Evaluator for CachedEvaluator<E> {
    fn eval_batch(&mut self, designs: &[DesignPoint]) -> Result<Vec<Metrics>> {
        let fp = self.inner.workload_fingerprint();
        // Unique uncached designs, in first-appearance order.
        let mut fresh: Vec<DesignPoint> = Vec::new();
        let mut seen: HashSet<DesignPoint> = HashSet::new();
        for d in designs {
            if !self.map.contains_key(&(fp, *d)) && seen.insert(*d) {
                fresh.push(*d);
            }
        }
        if !fresh.is_empty() {
            let ms = self.inner.eval_batch(&fresh)?;
            debug_assert_eq!(ms.len(), fresh.len());
            for (d, m) in fresh.iter().zip(ms) {
                self.map.insert((fp, *d), m);
            }
        }
        self.counters.misses += fresh.len() as u64;
        self.counters.hits += (designs.len() - fresh.len()) as u64;
        Ok(designs.iter().map(|d| self.map[&(fp, *d)]).collect())
    }

    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn is_cached(&self, d: &DesignPoint) -> bool {
        self.map
            .contains_key(&(self.inner.workload_fingerprint(), *d))
    }

    fn cache_counters(&self) -> Option<CacheCounters> {
        Some(self.counters)
    }

    fn workload_fingerprint(&self) -> u64 {
        self.inner.workload_fingerprint()
    }

    fn preload(&mut self, pairs: &[(DesignPoint, Metrics)]) {
        self.warm(pairs);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::design::Param;

    /// Counts inner invocations per design to prove memoization.
    struct CountingEval {
        calls: usize,
    }

    impl Evaluator for CountingEval {
        fn eval_batch(
            &mut self,
            designs: &[DesignPoint],
        ) -> Result<Vec<Metrics>> {
            self.calls += designs.len();
            Ok(designs
                .iter()
                .map(|d| Metrics {
                    ttft_ms: d.get(Param::Cores) as f32,
                    tpot_ms: 0.5,
                    area_mm2: 100.0,
                    stalls: [[1.0, 0.0, 0.0], [0.0, 1.0, 0.0]],
                    ..Default::default()
                })
                .collect())
        }
        fn name(&self) -> &'static str {
            "counting"
        }
    }

    #[test]
    fn memoizes_and_counts() {
        let mut c = CachedEvaluator::new(CountingEval { calls: 0 });
        let a = DesignPoint::a100();
        let b = a.with(Param::Cores, 64);
        // Batch with an in-batch duplicate: inner sees each unique once.
        let got = c.eval_batch(&[a, b, a]).unwrap();
        assert_eq!(got.len(), 3);
        assert_eq!(got[0], got[2]);
        assert_eq!(c.inner().calls, 2);
        assert_eq!(c.counters(), CacheCounters { hits: 1, misses: 2 });
        assert!(c.is_cached(&a) && c.is_cached(&b));
        // Full revisit: zero inner calls.
        let again = c.eval_batch(&[b, a]).unwrap();
        assert_eq!(again, vec![got[1], got[0]]);
        assert_eq!(c.inner().calls, 2);
        assert_eq!(c.counters(), CacheCounters { hits: 3, misses: 2 });
        assert!((c.counters().hit_rate() - 0.6).abs() < 1e-12);
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn clear_forgets_entries_but_keeps_counters() {
        let mut c = CachedEvaluator::new(CountingEval { calls: 0 });
        let a = DesignPoint::a100();
        c.eval_batch(&[a]).unwrap();
        c.clear();
        assert!(c.is_empty());
        assert!(!c.is_cached(&a));
        c.eval_batch(&[a]).unwrap();
        assert_eq!(c.counters().misses, 2);
    }

    #[test]
    fn warm_seeds_entries_without_counting() {
        let mut c = CachedEvaluator::new(CountingEval { calls: 0 });
        let a = DesignPoint::a100();
        let truth = c.eval(&a).unwrap();
        // Warm a fresh cache from the recorded pair: served without an
        // inner call, counters untouched by the warm itself.
        let mut c2 = CachedEvaluator::new(CountingEval { calls: 0 });
        c2.warm(&[(a, truth)]);
        assert!(c2.is_cached(&a));
        assert_eq!(c2.counters(), CacheCounters::default());
        assert_eq!(c2.eval(&a).unwrap(), truth);
        assert_eq!(c2.inner().calls, 0);
        assert_eq!(c2.counters().hits, 1);
    }

    /// Same inner evaluator, but reporting a settable workload
    /// fingerprint — models an evaluator reconfigured between batches.
    struct TaggedEval {
        inner: CountingEval,
        tag: u64,
    }

    impl Evaluator for TaggedEval {
        fn eval_batch(
            &mut self,
            designs: &[DesignPoint],
        ) -> Result<Vec<Metrics>> {
            let mut ms = self.inner.eval_batch(designs)?;
            for m in &mut ms {
                m.tpot_ms = self.tag as f32;
            }
            Ok(ms)
        }
        fn name(&self) -> &'static str {
            "tagged"
        }
        fn workload_fingerprint(&self) -> u64 {
            self.tag
        }
    }

    #[test]
    fn entries_are_keyed_per_workload() {
        let mut c = CachedEvaluator::new(TaggedEval {
            inner: CountingEval { calls: 0 },
            tag: 1,
        });
        let d = DesignPoint::a100();
        let under_a = c.eval(&d).unwrap();
        assert!(c.is_cached(&d));
        // Same design under a different workload: a distinct entry, not
        // a stale hit.
        c.inner.tag = 2;
        assert!(!c.is_cached(&d));
        let under_b = c.eval(&d).unwrap();
        assert_ne!(under_a, under_b);
        assert_eq!(c.len(), 2);
        assert_eq!(c.counters(), CacheCounters { hits: 0, misses: 2 });
        // Back on the first workload: served from its own entry.
        c.inner.tag = 1;
        assert_eq!(c.eval(&d).unwrap(), under_a);
        assert_eq!(c.counters(), CacheCounters { hits: 1, misses: 2 });
    }
}
