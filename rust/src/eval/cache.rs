//! Design-point memoization: the Table-1 space is a discrete grid, so a
//! [`DesignPoint`] hashes exactly and revisits (BO re-probing incumbents,
//! GA elitism, ACO trail reinforcement, LUMINA restarts) can be served
//! from a map instead of re-running the simulator.
//!
//! [`CachedEvaluator`] wraps any [`Evaluator`]; unique uncached designs
//! of a batch are forwarded to the inner evaluator in first-appearance
//! order (so inner results stay deterministic), then every requested
//! design — duplicates included — is assembled from the map in input
//! order. Hit/miss counters feed [`BudgetedEvaluator`]'s accounting:
//! hits never burn sample budget.
//!
//! [`BudgetedEvaluator`]: crate::eval::BudgetedEvaluator

use std::collections::{HashMap, HashSet};

use crate::design::DesignPoint;
use crate::eval::{CacheCounters, Evaluator, Metrics};
use crate::Result;

/// Memoizing adapter over any evaluator.
#[derive(Debug)]
pub struct CachedEvaluator<E> {
    inner: E,
    map: HashMap<DesignPoint, Metrics>,
    counters: CacheCounters,
}

impl<E: Evaluator> CachedEvaluator<E> {
    pub fn new(inner: E) -> Self {
        Self { inner, map: HashMap::new(), counters: CacheCounters::default() }
    }

    /// Lookup counters since construction.
    pub fn counters(&self) -> CacheCounters {
        self.counters
    }

    /// Distinct design points memoized.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn inner(&self) -> &E {
        &self.inner
    }

    pub fn into_inner(self) -> E {
        self.inner
    }

    /// Drop all memoized entries (counters are kept).
    pub fn clear(&mut self) {
        self.map.clear();
    }
}

impl<E: Evaluator> Evaluator for CachedEvaluator<E> {
    fn eval_batch(&mut self, designs: &[DesignPoint]) -> Result<Vec<Metrics>> {
        // Unique uncached designs, in first-appearance order.
        let mut fresh: Vec<DesignPoint> = Vec::new();
        let mut seen: HashSet<DesignPoint> = HashSet::new();
        for d in designs {
            if !self.map.contains_key(d) && seen.insert(*d) {
                fresh.push(*d);
            }
        }
        if !fresh.is_empty() {
            let ms = self.inner.eval_batch(&fresh)?;
            debug_assert_eq!(ms.len(), fresh.len());
            for (d, m) in fresh.iter().zip(ms) {
                self.map.insert(*d, m);
            }
        }
        self.counters.misses += fresh.len() as u64;
        self.counters.hits += (designs.len() - fresh.len()) as u64;
        Ok(designs.iter().map(|d| self.map[d]).collect())
    }

    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn is_cached(&self, d: &DesignPoint) -> bool {
        self.map.contains_key(d)
    }

    fn cache_counters(&self) -> Option<CacheCounters> {
        Some(self.counters)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::design::Param;

    /// Counts inner invocations per design to prove memoization.
    struct CountingEval {
        calls: usize,
    }

    impl Evaluator for CountingEval {
        fn eval_batch(
            &mut self,
            designs: &[DesignPoint],
        ) -> Result<Vec<Metrics>> {
            self.calls += designs.len();
            Ok(designs
                .iter()
                .map(|d| Metrics {
                    ttft_ms: d.get(Param::Cores) as f32,
                    tpot_ms: 0.5,
                    area_mm2: 100.0,
                    stalls: [[1.0, 0.0, 0.0], [0.0, 1.0, 0.0]],
                })
                .collect())
        }
        fn name(&self) -> &'static str {
            "counting"
        }
    }

    #[test]
    fn memoizes_and_counts() {
        let mut c = CachedEvaluator::new(CountingEval { calls: 0 });
        let a = DesignPoint::a100();
        let b = a.with(Param::Cores, 64);
        // Batch with an in-batch duplicate: inner sees each unique once.
        let got = c.eval_batch(&[a, b, a]).unwrap();
        assert_eq!(got.len(), 3);
        assert_eq!(got[0], got[2]);
        assert_eq!(c.inner().calls, 2);
        assert_eq!(c.counters(), CacheCounters { hits: 1, misses: 2 });
        assert!(c.is_cached(&a) && c.is_cached(&b));
        // Full revisit: zero inner calls.
        let again = c.eval_batch(&[b, a]).unwrap();
        assert_eq!(again, vec![got[1], got[0]]);
        assert_eq!(c.inner().calls, 2);
        assert_eq!(c.counters(), CacheCounters { hits: 3, misses: 2 });
        assert!((c.counters().hit_rate() - 0.6).abs() < 1e-12);
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn clear_forgets_entries_but_keeps_counters() {
        let mut c = CachedEvaluator::new(CountingEval { calls: 0 });
        let a = DesignPoint::a100();
        c.eval_batch(&[a]).unwrap();
        c.clear();
        assert!(c.is_empty());
        assert!(!c.is_cached(&a));
        c.eval_batch(&[a]).unwrap();
        assert_eq!(c.counters().misses, 2);
    }
}
