//! Design-point memoization: the Table-1 space is a discrete grid, so a
//! [`DesignPoint`] hashes exactly and revisits (BO re-probing incumbents,
//! GA elitism, ACO trail reinforcement, LUMINA restarts) can be served
//! from a map instead of re-running the simulator.
//!
//! Entries are keyed on **(workload fingerprint, design)** — the metrics
//! of a design are a function of the workload it was evaluated under, so
//! the same design under two different workloads (a suite sweep, an
//! evaluator whose workload is reconfigured) must never alias to one
//! entry. The fingerprint is read from the inner evaluator on every
//! batch via [`Evaluator::workload_fingerprint`].
//!
//! The store itself is a [`SharedCache`]: a sharded-`RwLock` concurrent
//! map with atomic hit/miss counters. That makes the cache usable
//! through `&self` from pool worker threads, so memoization composes on
//! *either* side of the parallel layer:
//!
//! * `CachedEvaluator<ParallelEvaluator<_>>` — the historical
//!   composition; unique misses of a batch are forwarded as one inner
//!   batch.
//! * `ParallelEvaluator<CachedEvaluator<_>>` — the CLI `explore` stack:
//!   the parallel layer probes the memo store up front, serves hits on
//!   the caller thread **without touching the worker pool**, and
//!   evaluates only unique misses in parallel (each exactly once, so
//!   observable results *and* counters are deterministic and identical
//!   to the sequential caching path).
//!
//! `SharedCache` is `Arc`-cloneable, so several evaluators (or several
//! threads) can share one memo store; keys never alias across workloads
//! thanks to the fingerprint lane.
//!
//! Batch semantics (both compositions): unique uncached designs are
//! forwarded to the inner evaluator in first-appearance order (so inner
//! results stay deterministic), then every requested design —
//! duplicates included — is assembled from the map in input order.
//! Hit/miss counters feed [`BudgetedEvaluator`]'s accounting: hits
//! never burn sample budget.
//!
//! [`BudgetedEvaluator`]: crate::eval::BudgetedEvaluator

use std::collections::hash_map::DefaultHasher;
use std::collections::{HashMap, HashSet};
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::design::DesignPoint;
use crate::eval::scratch::EvalScratch;
use crate::eval::{CacheCounters, EvalOne, Evaluator, Metrics};
use crate::Result;

/// Shard count: enough to make write contention negligible at the
/// pool's lane counts, small enough that `len()`/`clear()` sweeps stay
/// trivial.
const N_SHARDS: usize = 16;

type Shard = RwLock<HashMap<(u64, DesignPoint), Metrics>>;

#[derive(Debug, Default)]
struct CacheInner {
    shards: [Shard; N_SHARDS],
    hits: AtomicU64,
    misses: AtomicU64,
}

/// Concurrent sharded memo store keyed on (workload fingerprint,
/// design). Cloning shares the underlying map and counters.
#[derive(Debug, Clone, Default)]
pub struct SharedCache {
    inner: Arc<CacheInner>,
}

impl SharedCache {
    pub fn new() -> Self {
        Self::default()
    }

    fn shard(&self, key: &(u64, DesignPoint)) -> &Shard {
        let mut h = DefaultHasher::new();
        key.hash(&mut h);
        &self.inner.shards[(h.finish() as usize) % N_SHARDS]
    }

    /// Silent lookup (no counter effects; see [`SharedCache::record`]).
    pub fn get(&self, fp: u64, d: &DesignPoint) -> Option<Metrics> {
        let key = (fp, *d);
        self.shard(&key)
            .read()
            // lumina: allow(P001) poison propagates a panic from a peer thread
            .expect("cache shard poisoned")
            .get(&key)
            .copied()
    }

    pub fn contains(&self, fp: u64, d: &DesignPoint) -> bool {
        self.get(fp, d).is_some()
    }

    /// Insert, overwriting any existing entry (evaluators are pure, so
    /// a racing double-insert writes the same bits).
    pub fn insert(&self, fp: u64, d: &DesignPoint, m: Metrics) {
        let key = (fp, *d);
        self.shard(&key)
            .write()
            // lumina: allow(P001) poison propagates a panic from a peer thread
            .expect("cache shard poisoned")
            .insert(key, m);
    }

    /// Insert unless present (warm path: existing entries win).
    pub fn insert_if_absent(&self, fp: u64, d: &DesignPoint, m: Metrics) {
        let key = (fp, *d);
        self.shard(&key)
            .write()
            // lumina: allow(P001) poison propagates a panic from a peer thread
            .expect("cache shard poisoned")
            .entry(key)
            .or_insert(m);
    }

    /// Bump the lookup counters.
    pub fn record(&self, hits: u64, misses: u64) {
        if hits > 0 {
            self.inner.hits.fetch_add(hits, Ordering::Relaxed);
        }
        if misses > 0 {
            self.inner.misses.fetch_add(misses, Ordering::Relaxed);
        }
    }

    pub fn counters(&self) -> CacheCounters {
        CacheCounters {
            hits: self.inner.hits.load(Ordering::Relaxed),
            misses: self.inner.misses.load(Ordering::Relaxed),
        }
    }

    /// Distinct (workload, design) pairs memoized.
    pub fn len(&self) -> usize {
        self.inner
            .shards
            .iter()
            // lumina: allow(P001) poison propagates a panic from a peer thread
            .map(|s| s.read().expect("cache shard poisoned").len())
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop all memoized entries (counters are kept).
    pub fn clear(&self) {
        for s in &self.inner.shards {
            // lumina: allow(P001) poison propagates a panic from a peer thread
            s.write().expect("cache shard poisoned").clear();
        }
    }
}

/// Memoizing adapter over any evaluator (see module docs).
#[derive(Debug)]
pub struct CachedEvaluator<E> {
    inner: E,
    cache: SharedCache,
}

impl<E> CachedEvaluator<E> {
    pub fn new(inner: E) -> Self {
        Self { inner, cache: SharedCache::new() }
    }

    /// Wrap `inner` over an existing (possibly shared) memo store.
    pub fn with_cache(inner: E, cache: SharedCache) -> Self {
        Self { inner, cache }
    }

    /// Handle to the memo store (clone to share it).
    pub fn cache(&self) -> &SharedCache {
        &self.cache
    }

    /// Lookup counters since the store's construction.
    pub fn counters(&self) -> CacheCounters {
        self.cache.counters()
    }

    /// Distinct (workload, design) pairs memoized.
    pub fn len(&self) -> usize {
        self.cache.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cache.is_empty()
    }

    pub fn inner(&self) -> &E {
        &self.inner
    }

    /// Mutable access to the wrapped evaluator (e.g. to reconfigure its
    /// workload; the cache re-reads the fingerprint on every batch, so
    /// existing entries stay correct under their original key).
    pub fn inner_mut(&mut self) -> &mut E {
        &mut self.inner
    }

    pub fn into_inner(self) -> E {
        self.inner
    }

    /// Drop all memoized entries (counters are kept).
    pub fn clear(&mut self) {
        self.cache.clear();
    }

    /// Seed known results under `fp` without touching the hit/miss
    /// counters; existing entries win on conflict.
    fn warm_with_fp(&self, fp: u64, pairs: &[(DesignPoint, Metrics)]) {
        for (d, m) in pairs {
            self.cache.insert_if_absent(fp, d, *m);
        }
    }
}

/// Tier-generic core of the shared batch algorithm: probe every design
/// through `lookup`, forward unique misses (first-appearance order)
/// through `run_fresh`, commit the fresh results, assemble every
/// requested slot in input order, and `record(hits, misses)` with
/// `hits = designs - fresh`, `misses = fresh`. Closure-shaped so the
/// same algorithm serves both the in-memory [`SharedCache`] tier and
/// the mem+disk read-through stack (`crate::eval::store`), and so
/// `Evaluator::eval_batch` can pass a `run_fresh` that mutably borrows
/// the inner evaluator while the store is borrowed shared.
pub(crate) fn batch_via_tiers(
    lookup: impl Fn(&DesignPoint) -> Option<Metrics>,
    commit: impl Fn(&DesignPoint, Metrics),
    record: impl Fn(u64, u64),
    designs: &[DesignPoint],
    run_fresh: impl FnOnce(&[DesignPoint]) -> Result<Vec<Metrics>>,
) -> Result<Vec<Metrics>> {
    // One probe per design; the pure-hit path never touches the tiers
    // again (fresh results are assembled from the local vec, not
    // re-read through the shard locks).
    let mut slots: Vec<Option<Metrics>> =
        Vec::with_capacity(designs.len());
    let mut fresh: Vec<DesignPoint> = Vec::new();
    let mut seen: HashSet<DesignPoint> = HashSet::new();
    for d in designs {
        let hit = lookup(d);
        if hit.is_none() && seen.insert(*d) {
            fresh.push(*d);
        }
        slots.push(hit);
    }
    let fresh_ms = if fresh.is_empty() {
        Vec::new()
    } else {
        run_fresh(&fresh)?
    };
    debug_assert_eq!(fresh_ms.len(), fresh.len());
    for (d, m) in fresh.iter().zip(&fresh_ms) {
        commit(d, *m);
    }
    record((designs.len() - fresh.len()) as u64, fresh.len() as u64);
    let by_design: HashMap<DesignPoint, Metrics> =
        fresh.into_iter().zip(fresh_ms).collect();
    Ok(designs
        .iter()
        .zip(slots)
        .map(|(d, slot)| match slot {
            Some(m) => m,
            None => by_design[d],
        })
        .collect())
}

/// Shared batch algorithm of both trait impls, specialized to the
/// single in-memory tier (see [`batch_via_tiers`]).
fn batch_via(
    cache: &SharedCache,
    fp: u64,
    designs: &[DesignPoint],
    run_fresh: impl FnOnce(&[DesignPoint]) -> Result<Vec<Metrics>>,
) -> Result<Vec<Metrics>> {
    batch_via_tiers(
        |d| cache.get(fp, d),
        |d, m| cache.insert(fp, d, m),
        |hits, misses| cache.record(hits, misses),
        designs,
        run_fresh,
    )
}

impl<E: Evaluator> CachedEvaluator<E> {
    /// Seed known results under the inner evaluator's *current*
    /// workload fingerprint without touching the hit/miss counters —
    /// the checkpoint-resume path replays a recorded trajectory into
    /// the cache so the resumed run charges budget exactly like the
    /// uninterrupted one. Existing entries win on conflict.
    pub fn warm(&mut self, pairs: &[(DesignPoint, Metrics)]) {
        self.warm_with_fp(self.inner.workload_fingerprint(), pairs);
    }
}

impl<E: Evaluator> Evaluator for CachedEvaluator<E> {
    fn eval_batch(&mut self, designs: &[DesignPoint]) -> Result<Vec<Metrics>> {
        let fp = self.inner.workload_fingerprint();
        // Split borrow: the store is borrowed shared while the closure
        // mutates the inner evaluator.
        let inner = &mut self.inner;
        batch_via(&self.cache, fp, designs, |fresh| {
            inner.eval_batch(fresh)
        })
    }

    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn is_cached(&self, d: &DesignPoint) -> bool {
        self.cache
            .contains(self.inner.workload_fingerprint(), d)
    }

    fn cache_counters(&self) -> Option<CacheCounters> {
        Some(self.cache.counters())
    }

    fn disk_counters(&self) -> Option<super::DiskCounters> {
        // A disk tier lower in the stack (e.g. a memoized suite whose
        // members probe the store) still reports its warm-restart
        // telemetry through this wrapper.
        self.inner.disk_counters()
    }

    fn workload_fingerprint(&self) -> u64 {
        self.inner.workload_fingerprint()
    }

    fn preload(&mut self, pairs: &[(DesignPoint, Metrics)]) {
        self.warm_with_fp(self.inner.workload_fingerprint(), pairs);
    }
}

/// The thread-safe face: a memoizing *pure* evaluator, usable inside
/// [`crate::eval::ParallelEvaluator`] — pool workers evaluate misses
/// through `&self`, the parallel batch layer serves hits without
/// dispatching, and the memo hooks keep counters deterministic.
impl<E: EvalOne> EvalOne for CachedEvaluator<E> {
    fn eval_one(&self, d: &DesignPoint) -> Metrics {
        let fp = EvalOne::workload_fingerprint(&self.inner);
        if let Some(m) = self.cache.get(fp, d) {
            self.cache.record(1, 0);
            return m;
        }
        let m = self.inner.eval_one(d);
        self.cache.insert(fp, d, m);
        self.cache.record(0, 1);
        m
    }

    fn label(&self) -> &'static str {
        self.inner.label()
    }

    fn workload_fingerprint(&self) -> u64 {
        EvalOne::workload_fingerprint(&self.inner)
    }

    fn eval_chunk(
        &self,
        designs: &[DesignPoint],
        out: &mut [Metrics],
        scratch: &mut EvalScratch,
    ) {
        // Same dedup/assemble algorithm as the batch path, with the
        // misses evaluated through the inner SoA chunk kernel. When
        // called from the parallel layer's memo-aware path the chunk is
        // all-fresh (the orchestrator deduplicated), so this records
        // misses only.
        let fp = EvalOne::workload_fingerprint(&self.inner);
        let ms = batch_via(&self.cache, fp, designs, |fresh| {
            let mut fresh_ms = vec![Metrics::default(); fresh.len()];
            self.inner.eval_chunk(fresh, &mut fresh_ms, scratch);
            Ok(fresh_ms)
        })
        // lumina: allow(P001) the closure is Ok-returning; batch_via cannot fail
        .expect("infallible inner chunk");
        out.copy_from_slice(&ms);
    }

    fn probe(&self, d: &DesignPoint) -> Option<Metrics> {
        self.cache
            .get(EvalOne::workload_fingerprint(&self.inner), d)
    }

    fn memoizes(&self) -> bool {
        true
    }

    fn count_hits(&self, n: u64) {
        self.cache.record(n, 0);
    }

    fn memo_counters(&self) -> Option<CacheCounters> {
        Some(self.cache.counters())
    }

    fn memo_warm(&self, pairs: &[(DesignPoint, Metrics)]) {
        self.warm_with_fp(
            EvalOne::workload_fingerprint(&self.inner),
            pairs,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::design::Param;

    /// Counts inner invocations per design to prove memoization.
    struct CountingEval {
        calls: usize,
    }

    impl Evaluator for CountingEval {
        fn eval_batch(
            &mut self,
            designs: &[DesignPoint],
        ) -> Result<Vec<Metrics>> {
            self.calls += designs.len();
            Ok(designs
                .iter()
                .map(|d| Metrics {
                    ttft_ms: d.get(Param::Cores) as f32,
                    tpot_ms: 0.5,
                    area_mm2: 100.0,
                    stalls: [[1.0, 0.0, 0.0], [0.0, 1.0, 0.0]],
                    ..Default::default()
                })
                .collect())
        }
        fn name(&self) -> &'static str {
            "counting"
        }
    }

    #[test]
    fn memoizes_and_counts() {
        let mut c = CachedEvaluator::new(CountingEval { calls: 0 });
        let a = DesignPoint::a100();
        let b = a.with(Param::Cores, 64);
        // Batch with an in-batch duplicate: inner sees each unique once.
        let got = c.eval_batch(&[a, b, a]).unwrap();
        assert_eq!(got.len(), 3);
        assert_eq!(got[0], got[2]);
        assert_eq!(c.inner().calls, 2);
        assert_eq!(c.counters(), CacheCounters { hits: 1, misses: 2 });
        assert!(c.is_cached(&a) && c.is_cached(&b));
        // Full revisit: zero inner calls.
        let again = c.eval_batch(&[b, a]).unwrap();
        assert_eq!(again, vec![got[1], got[0]]);
        assert_eq!(c.inner().calls, 2);
        assert_eq!(c.counters(), CacheCounters { hits: 3, misses: 2 });
        assert!((c.counters().hit_rate() - 0.6).abs() < 1e-12);
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn clear_forgets_entries_but_keeps_counters() {
        let mut c = CachedEvaluator::new(CountingEval { calls: 0 });
        let a = DesignPoint::a100();
        c.eval_batch(&[a]).unwrap();
        c.clear();
        assert!(c.is_empty());
        assert!(!c.is_cached(&a));
        c.eval_batch(&[a]).unwrap();
        assert_eq!(c.counters().misses, 2);
    }

    #[test]
    fn warm_seeds_entries_without_counting() {
        let mut c = CachedEvaluator::new(CountingEval { calls: 0 });
        let a = DesignPoint::a100();
        let truth = c.eval(&a).unwrap();
        // Warm a fresh cache from the recorded pair: served without an
        // inner call, counters untouched by the warm itself.
        let mut c2 = CachedEvaluator::new(CountingEval { calls: 0 });
        c2.warm(&[(a, truth)]);
        assert!(c2.is_cached(&a));
        assert_eq!(c2.counters(), CacheCounters::default());
        assert_eq!(c2.eval(&a).unwrap(), truth);
        assert_eq!(c2.inner().calls, 0);
        assert_eq!(c2.counters().hits, 1);
    }

    /// Same inner evaluator, but reporting a settable workload
    /// fingerprint — models an evaluator reconfigured between batches.
    struct TaggedEval {
        inner: CountingEval,
        tag: u64,
    }

    impl Evaluator for TaggedEval {
        fn eval_batch(
            &mut self,
            designs: &[DesignPoint],
        ) -> Result<Vec<Metrics>> {
            let mut ms = self.inner.eval_batch(designs)?;
            for m in &mut ms {
                m.tpot_ms = self.tag as f32;
            }
            Ok(ms)
        }
        fn name(&self) -> &'static str {
            "tagged"
        }
        fn workload_fingerprint(&self) -> u64 {
            self.tag
        }
    }

    #[test]
    fn entries_are_keyed_per_workload() {
        let mut c = CachedEvaluator::new(TaggedEval {
            inner: CountingEval { calls: 0 },
            tag: 1,
        });
        let d = DesignPoint::a100();
        let under_a = c.eval(&d).unwrap();
        assert!(c.is_cached(&d));
        // Same design under a different workload: a distinct entry, not
        // a stale hit.
        c.inner.tag = 2;
        assert!(!c.is_cached(&d));
        let under_b = c.eval(&d).unwrap();
        assert_ne!(under_a, under_b);
        assert_eq!(c.len(), 2);
        assert_eq!(c.counters(), CacheCounters { hits: 0, misses: 2 });
        // Back on the first workload: served from its own entry.
        c.inner.tag = 1;
        assert_eq!(c.eval(&d).unwrap(), under_a);
        assert_eq!(c.counters(), CacheCounters { hits: 1, misses: 2 });
    }

    #[test]
    fn shared_cache_is_shared_across_evaluators() {
        let store = SharedCache::new();
        let mut c1 = CachedEvaluator::with_cache(
            CountingEval { calls: 0 },
            store.clone(),
        );
        let a = DesignPoint::a100();
        let truth = c1.eval(&a).unwrap();
        assert_eq!(c1.inner().calls, 1);
        // A second evaluator over the same store: pure hit.
        let mut c2 = CachedEvaluator::with_cache(
            CountingEval { calls: 0 },
            store.clone(),
        );
        assert!(c2.is_cached(&a));
        assert_eq!(c2.eval(&a).unwrap(), truth);
        assert_eq!(c2.inner().calls, 0);
        // Counters are shared too: 1 miss (c1) + 1 hit (c2).
        assert_eq!(
            store.counters(),
            CacheCounters { hits: 1, misses: 1 }
        );
        assert_eq!(store.len(), 1);
    }

    #[test]
    fn eval_one_face_memoizes_with_the_same_counters() {
        use crate::sim::RooflineSim;
        use crate::workload::GPT3_175B;
        let c = CachedEvaluator::new(RooflineSim::new(GPT3_175B));
        let a = DesignPoint::a100();
        let m1 = EvalOne::eval_one(&c, &a);
        let m2 = EvalOne::eval_one(&c, &a);
        assert_eq!(m1, m2);
        assert_eq!(c.counters(), CacheCounters { hits: 1, misses: 1 });
        assert_eq!(EvalOne::probe(&c, &a), Some(m1));
        assert!(EvalOne::memoizes(&c));
        assert_eq!(
            EvalOne::memo_counters(&c),
            Some(c.counters())
        );
    }
}
