//! On-disk memo store: warm evaluations that survive restarts and are
//! shareable across a fleet of worker processes.
//!
//! A store is a directory of append-only binary **segment files** plus
//! a sidecar `stats.json`. Each segment starts with a magic/version
//! header and then holds fixed-size records, one memoized evaluation
//! each, keyed exactly like [`SharedCache`] on **(workload
//! fingerprint, design)**:
//!
//! ```text
//! header  : "LMNMEMO1" (8)  | version u32 LE (4)            = 12 B
//! record  : fp u64 LE   (8) | design 8 x u32 LE       (32)
//!         | metrics 12 x f32-bits LE (48) | fnv1a64    (8)  = 96 B
//! ```
//!
//! Floats travel as raw IEEE-754 bit patterns (`util::bin`), so a
//! record read back is **bitwise** the metrics that were written — the
//! store can sit under the evaluation stack without perturbing the
//! repo's bit-identity guarantees. Every record carries an FNV-1a-64
//! checksum over its first 88 bytes; on open, the whole directory is
//! scanned into an in-memory `BTreeMap` index and a torn or corrupt
//! tail (a crashed writer's partial record, a bit flip) is *skipped
//! with a stderr note*, never an error — crash recovery is "reopen and
//! keep going with every intact record".
//!
//! Multi-process safety needs no byte-range locks: each writer appends
//! to its own `wip-<pid>-<k>.lms` file (claimed via `create_new`, so
//! two processes can never share one) and **seals** it by rename to
//! `seg-<pid>-<k>.lms` — rename is atomic, so readers see either the
//! old name or the complete sealed segment. The advisory [`DirLock`]
//! (`create_new` lock file, pid inside) serializes the one operation
//! that deletes files — [`DiskStore::compact`] — and doubles as the
//! claim protocol `dse::shard` uses to partition race cells. Appends
//! are best-effort: an I/O error logs once and disables the writer
//! (evaluation must not fail because a disk filled up).
//!
//! [`DiskBackedCache`] layers the store *under* a [`SharedCache`] as a
//! read-through / write-behind tier and implements both evaluator
//! traits exactly like [`CachedEvaluator`], so the CLI stack becomes
//! `ParallelEvaluator<DiskBackedCache<Sim>>`: probes hit memory first,
//! then disk (promoting into memory), and only true misses reach the
//! worker pool; fresh results are written behind to both tiers.
//!
//! [`CachedEvaluator`]: crate::eval::CachedEvaluator

use std::collections::BTreeMap;
use std::fs::{self, File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::design::{DesignPoint, N_PARAMS};
use crate::error::Context;
use crate::eval::cache::batch_via_tiers;
use crate::eval::scratch::EvalScratch;
use crate::eval::{
    CacheCounters, EvalOne, Evaluator, Metrics, SharedCache,
};
use crate::util::{bin, json::Json};
use crate::{err, Result};

/// Segment-file magic: "LuMiNa MEMO format 1".
pub const MAGIC: [u8; 8] = *b"LMNMEMO1";
/// On-disk format version (bump on any layout change).
pub const FORMAT_VERSION: u32 = 1;
/// Header length: magic + version.
pub const HEADER_LEN: usize = 12;
/// Fixed record length (see module docs for the layout).
pub const RECORD_LEN: usize = 96;
/// f32 lanes per record: the full [`Metrics`] struct.
const N_METRIC_LANES: usize = 12;
/// Segment rotation threshold: seal the write-in-progress file once it
/// crosses this many bytes (~10.9k records/segment).
const ROTATE_BYTES: u64 = 1 << 20;

/// Filename of a write-in-progress segment owned by `pid`.
fn wip_name(pid: u32, k: u64) -> String {
    format!("wip-{pid:010}-{k:06}.lms")
}

/// Sealed name of the same segment (rename target).
fn seg_name(pid: u32, k: u64) -> String {
    format!("seg-{pid:010}-{k:06}.lms")
}

/// The 12 metric lanes in record order (struct declaration order:
/// timing, area, energy, then the two stall stacks).
fn metric_lanes(m: &Metrics) -> [f32; N_METRIC_LANES] {
    [
        m.ttft_ms,
        m.tpot_ms,
        m.area_mm2,
        m.energy_per_token_mj,
        m.prefill_energy_mj,
        m.avg_power_w,
        m.stalls[0][0],
        m.stalls[0][1],
        m.stalls[0][2],
        m.stalls[1][0],
        m.stalls[1][1],
        m.stalls[1][2],
    ]
}

fn lanes_to_metrics(l: [f32; N_METRIC_LANES]) -> Metrics {
    Metrics {
        ttft_ms: l[0],
        tpot_ms: l[1],
        area_mm2: l[2],
        energy_per_token_mj: l[3],
        prefill_energy_mj: l[4],
        avg_power_w: l[5],
        stalls: [[l[6], l[7], l[8]], [l[9], l[10], l[11]]],
    }
}

/// Serialize one record (checksum included).
fn encode_record(fp: u64, d: &DesignPoint, m: &Metrics) -> Vec<u8> {
    let mut out = Vec::with_capacity(RECORD_LEN);
    bin::put_u64(&mut out, fp);
    for v in d.values {
        bin::put_u32(&mut out, v);
    }
    for v in metric_lanes(m) {
        bin::put_f32(&mut out, v);
    }
    let sum = bin::fnv1a64(&out);
    bin::put_u64(&mut out, sum);
    debug_assert_eq!(out.len(), RECORD_LEN);
    out
}

/// Parse + checksum-validate one record; `None` on any damage.
fn decode_record(rec: &[u8]) -> Option<((u64, DesignPoint), Metrics)> {
    if rec.len() != RECORD_LEN {
        return None;
    }
    let body = &rec[..RECORD_LEN - 8];
    if bin::read_u64(rec, RECORD_LEN - 8)? != bin::fnv1a64(body) {
        return None;
    }
    let fp = bin::read_u64(rec, 0)?;
    let mut values = [0u32; N_PARAMS];
    for (i, v) in values.iter_mut().enumerate() {
        *v = bin::read_u32(rec, 8 + i * 4)?;
    }
    let mut lanes = [0f32; N_METRIC_LANES];
    for (i, v) in lanes.iter_mut().enumerate() {
        *v = bin::read_f32(rec, 40 + i * 4)?;
    }
    Some(((fp, DesignPoint::new(values)), lanes_to_metrics(lanes)))
}

/// Advisory directory lock: a `create_new` lock file holding the
/// owner's pid. Guards compaction (the only file-deleting operation)
/// and provides the claim primitive `dse::shard` partitions race
/// cells with. Dropping releases; [`DirLock::persist`] instead leaves
/// the file on disk as a durable claim marker.
#[derive(Debug)]
pub struct DirLock {
    path: PathBuf,
    held: bool,
}

impl DirLock {
    /// `create_new` race: `Ok(None)` means some process already holds
    /// the file; real I/O trouble is an error.
    fn create(path: PathBuf) -> Result<Option<DirLock>> {
        match OpenOptions::new()
            .write(true)
            .create_new(true)
            .open(&path)
        {
            Ok(mut f) => {
                // Holder pid, purely diagnostic; claim is the file.
                let _ = writeln!(f, "{}", std::process::id());
                Ok(Some(DirLock { path, held: true }))
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::AlreadyExists =>
            {
                Ok(None)
            }
            Err(e) => Err(e).context(format!(
                "acquiring lock {}",
                path.display()
            )),
        }
    }

    /// Acquire `dir/<name>`; fails fast (no blocking/retry) when
    /// another process holds it, reporting the holder's pid.
    pub fn acquire(dir: &Path, name: &str) -> Result<DirLock> {
        let path = dir.join(name);
        match DirLock::create(path.clone())? {
            Some(lock) => Ok(lock),
            None => {
                let holder = fs::read_to_string(&path)
                    .unwrap_or_default()
                    .trim()
                    .to_string();
                Err(err!(
                    "lock {} held (pid {})",
                    path.display(),
                    if holder.is_empty() { "?" } else { &holder }
                ))
            }
        }
    }

    /// Non-erroring claim: `Ok(true)` when this call won the file,
    /// `Ok(false)` when some process (possibly us, earlier) already
    /// holds it. The won claim is persistent (survives the process).
    pub fn try_claim(dir: &Path, name: &str) -> Result<bool> {
        Ok(match DirLock::create(dir.join(name))? {
            Some(lock) => {
                lock.persist();
                true
            }
            None => false,
        })
    }

    /// Keep the lock file on disk permanently (claim-marker mode).
    pub fn persist(mut self) {
        self.held = false;
    }
}

impl Drop for DirLock {
    fn drop(&mut self) {
        if self.held {
            let _ = fs::remove_file(&self.path);
        }
    }
}

/// One write-in-progress segment file.
#[derive(Debug)]
struct SegWriter {
    file: File,
    pid: u32,
    k: u64,
    written: u64,
}

/// Per-session disk-tier counters (cumulative session totals are
/// additionally folded into the store's `stats.json` on drop).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DiskCounters {
    /// Lookups served from disk (first touch per entry; later probes
    /// hit the promoted in-memory copy).
    pub hits: u64,
    /// Records appended this session.
    pub appended: u64,
    /// Records recovered from disk when the store was opened.
    pub entries_on_open: u64,
}

/// Aggregate shape of a store directory (the `cache stats` report).
#[derive(Debug, Clone, Default)]
pub struct StoreStats {
    pub sealed_segments: usize,
    pub wip_segments: usize,
    pub bytes: u64,
    pub entries: usize,
    /// Records skipped on open (torn tails, checksum failures).
    pub skipped: usize,
    /// Distinct entries per workload fingerprint.
    pub per_workload: BTreeMap<u64, usize>,
    /// Lifetime counters from `stats.json` (0 when absent).
    pub lifetime_hits: u64,
    pub lifetime_appended: u64,
}

/// The on-disk memo store (see module docs for format + protocol).
/// All methods take `&self`; the store is shared across threads via
/// `Arc` and across processes via the directory itself.
#[derive(Debug)]
pub struct DiskStore {
    dir: PathBuf,
    index: RwLock<BTreeMap<(u64, DesignPoint), Metrics>>,
    writer: Mutex<Option<SegWriter>>,
    /// Next wip-file ordinal to probe for this process.
    next_k: AtomicU64,
    /// Set after the first append failure: stop writing, keep serving.
    broken: AtomicBool,
    /// Session counters already folded into `stats.json`.
    persisted: AtomicBool,
    hits: AtomicU64,
    appended: AtomicU64,
    entries_on_open: u64,
    skipped_on_open: usize,
}

impl DiskStore {
    /// Open (creating if absent) the store at `dir`, scanning every
    /// segment into the in-memory index. Damaged tails are skipped
    /// with a stderr note; only directory-level I/O errors fail.
    pub fn open(dir: &Path) -> Result<DiskStore> {
        fs::create_dir_all(dir).with_context(|| {
            format!("creating store dir {}", dir.display())
        })?;
        let mut index = BTreeMap::new();
        let mut skipped = 0usize;
        for name in segment_names(dir)? {
            let path = dir.join(&name);
            let bytes = match fs::read(&path) {
                Ok(b) => b,
                // A concurrent compact may remove segments under us;
                // whatever replaced them holds the same records.
                Err(e) => {
                    eprintln!(
                        "store: skipping unreadable segment {name}: {e}"
                    );
                    continue;
                }
            };
            skipped += scan_segment(&name, &bytes, &mut index);
        }
        let entries_on_open = index.len() as u64;
        Ok(DiskStore {
            dir: dir.to_path_buf(),
            index: RwLock::new(index),
            writer: Mutex::new(None),
            next_k: AtomicU64::new(0),
            broken: AtomicBool::new(false),
            persisted: AtomicBool::new(false),
            hits: AtomicU64::new(0),
            appended: AtomicU64::new(0),
            entries_on_open,
            skipped_on_open: skipped,
        })
    }

    /// Open wrapped in `Arc` (the shape evaluator stacks want).
    pub fn open_shared(dir: &Path) -> Result<Arc<DiskStore>> {
        Ok(Arc::new(DiskStore::open(dir)?))
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Silent index lookup (no counter effects; promotion layers call
    /// [`DiskStore::note_hit`] when they serve a result from here).
    pub fn get(&self, fp: u64, d: &DesignPoint) -> Option<Metrics> {
        self.index
            .read()
            // lumina: allow(P001) poison propagates a panic from a peer thread
            .expect("store index poisoned")
            .get(&(fp, *d))
            .copied()
    }

    pub fn contains(&self, fp: u64, d: &DesignPoint) -> bool {
        self.get(fp, d).is_some()
    }

    /// Count one lookup served from the disk tier.
    pub fn note_hit(&self) {
        self.hits.fetch_add(1, Ordering::Relaxed);
    }

    /// Append one record (write-behind; best-effort). The entry is
    /// always visible in the in-memory index; if the disk write fails
    /// the store logs once and stops writing for this session.
    pub fn append(&self, fp: u64, d: &DesignPoint, m: &Metrics) {
        self.index
            .write()
            // lumina: allow(P001) poison propagates a panic from a peer thread
            .expect("store index poisoned")
            .insert((fp, *d), *m);
        if self.broken.load(Ordering::Relaxed) {
            return;
        }
        if let Err(e) = self.append_bytes(&encode_record(fp, d, m)) {
            self.broken.store(true, Ordering::Relaxed);
            eprintln!("store: append failed, writes disabled: {e}");
        } else {
            self.appended.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn append_bytes(&self, rec: &[u8]) -> Result<()> {
        let mut guard = self
            .writer
            .lock()
            // lumina: allow(P001) poison propagates a panic from a peer thread
            .expect("store writer poisoned");
        if guard.is_none() {
            *guard = Some(self.open_writer()?);
        }
        // lumina: allow(P001) just assigned above when it was None
        let w = guard.as_mut().expect("writer present");
        w.file.write_all(rec)?;
        w.written += rec.len() as u64;
        if w.written >= ROTATE_BYTES {
            // lumina: allow(P001) checked Some on the line above
            let full = guard.take().expect("writer present");
            seal_writer(&self.dir, full)?;
        }
        Ok(())
    }

    /// Claim a fresh `wip-<pid>-<k>.lms` via `create_new` (collisions
    /// — a previous incarnation's leftover — just advance `k`).
    fn open_writer(&self) -> Result<SegWriter> {
        let pid = std::process::id();
        loop {
            let k = self.next_k.fetch_add(1, Ordering::Relaxed);
            let path = self.dir.join(wip_name(pid, k));
            match OpenOptions::new()
                .write(true)
                .create_new(true)
                .open(&path)
            {
                Ok(mut file) => {
                    let mut hdr = Vec::with_capacity(HEADER_LEN);
                    hdr.extend_from_slice(&MAGIC);
                    bin::put_u32(&mut hdr, FORMAT_VERSION);
                    file.write_all(&hdr)?;
                    return Ok(SegWriter {
                        file,
                        pid,
                        k,
                        written: HEADER_LEN as u64,
                    });
                }
                Err(e)
                    if e.kind()
                        == std::io::ErrorKind::AlreadyExists =>
                {
                    continue;
                }
                Err(e) => {
                    return Err(e).context(format!(
                        "creating segment {}",
                        path.display()
                    ))
                }
            }
        }
    }

    /// Seal the write-in-progress segment (flush + atomic rename to
    /// `seg-*`), making it immutable and compaction-eligible. No-op
    /// without an open writer. Also folds the session counters into
    /// `stats.json`.
    pub fn seal(&self) -> Result<()> {
        let taken = self
            .writer
            .lock()
            // lumina: allow(P001) poison propagates a panic from a peer thread
            .expect("store writer poisoned")
            .take();
        if let Some(w) = taken {
            seal_writer(&self.dir, w)?;
        }
        self.persist_stats();
        Ok(())
    }

    /// Distinct (workload, design) records currently indexed.
    pub fn len(&self) -> usize {
        self.index
            .read()
            // lumina: allow(P001) poison propagates a panic from a peer thread
            .expect("store index poisoned")
            .len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Session counters (see [`DiskCounters`]).
    pub fn counters(&self) -> DiskCounters {
        DiskCounters {
            hits: self.hits.load(Ordering::Relaxed),
            appended: self.appended.load(Ordering::Relaxed),
            entries_on_open: self.entries_on_open,
        }
    }

    /// Records skipped while scanning on open.
    pub fn skipped_on_open(&self) -> usize {
        self.skipped_on_open
    }

    /// Directory-level aggregate for `cache stats`.
    pub fn stats(&self) -> Result<StoreStats> {
        let mut s = StoreStats::default();
        for name in segment_names(&self.dir)? {
            if name.starts_with("wip-") {
                s.wip_segments += 1;
            } else {
                s.sealed_segments += 1;
            }
            if let Ok(meta) = fs::metadata(self.dir.join(&name)) {
                s.bytes += meta.len();
            }
        }
        let index = self
            .index
            .read()
            // lumina: allow(P001) poison propagates a panic from a peer thread
            .expect("store index poisoned");
        s.entries = index.len();
        for (fp, _) in index.keys() {
            *s.per_workload.entry(*fp).or_insert(0) += 1;
        }
        s.skipped = self.skipped_on_open;
        let (h, a) = self.lifetime_counters();
        s.lifetime_hits = h + self.hits.load(Ordering::Relaxed);
        s.lifetime_appended =
            a + self.appended.load(Ordering::Relaxed);
        Ok(s)
    }

    /// Rewrite every live index record into one fresh sealed segment
    /// and delete the sealed segments it supersedes. Serialized by the
    /// advisory [`DirLock`]; write-in-progress files of live writers
    /// are left alone (their later sealing can at worst duplicate
    /// records, and duplicates are benign — evaluators are pure, so
    /// the bits agree). Returns (records written, segments removed).
    pub fn compact(&self) -> Result<(usize, usize)> {
        let _lock = DirLock::acquire(&self.dir, "LOCK")?;
        // Seal our own writer first so our records are on disk and no
        // wip file of ours lingers.
        self.seal()?;
        let old: Vec<String> = segment_names(&self.dir)?
            .into_iter()
            .filter(|n| n.starts_with("seg-"))
            .collect();
        let snapshot: Vec<((u64, DesignPoint), Metrics)> = {
            let index = self
                .index
                .read()
                // lumina: allow(P001) poison propagates a panic from a peer thread
                .expect("store index poisoned");
            index.iter().map(|(k, v)| (*k, *v)).collect()
        };
        let mut w = self.open_writer()?;
        for ((fp, d), m) in &snapshot {
            let rec = encode_record(*fp, d, m);
            w.file.write_all(&rec)?;
            w.written += rec.len() as u64;
        }
        seal_writer(&self.dir, w)?;
        // Old segments go only after the replacement is sealed, so a
        // crash mid-compact can duplicate records but never lose any.
        let mut removed = 0usize;
        for name in &old {
            match fs::remove_file(self.dir.join(name)) {
                Ok(()) => removed += 1,
                Err(e) => eprintln!(
                    "store: compact could not remove {name}: {e}"
                ),
            }
        }
        Ok((snapshot.len(), removed))
    }

    /// Delete every segment file (and the `stats.json` sidecar) in
    /// `dir` without opening the store — the `cache clear`
    /// maintenance verb. Serialized by the advisory [`DirLock`] like
    /// [`Self::compact`]. Returns (files removed, bytes freed).
    pub fn clear(dir: &Path) -> Result<(usize, u64)> {
        let _lock = DirLock::acquire(dir, "LOCK")?;
        let mut files = 0usize;
        let mut bytes = 0u64;
        for name in segment_names(dir)? {
            let path = dir.join(&name);
            if let Ok(meta) = fs::metadata(&path) {
                bytes += meta.len();
            }
            fs::remove_file(&path)?;
            files += 1;
        }
        let stats = dir.join("stats.json");
        if stats.exists() {
            fs::remove_file(&stats)?;
        }
        Ok((files, bytes))
    }

    /// Lifetime counters recorded by previous sessions (from
    /// `stats.json`; zeros when absent/unreadable).
    fn lifetime_counters(&self) -> (u64, u64) {
        let raw = match fs::read_to_string(self.stats_path()) {
            Ok(s) => s,
            Err(_) => return (0, 0),
        };
        let Ok(j) = Json::parse(&raw) else { return (0, 0) };
        let get = |k: &str| {
            j.get(k)
                .ok()
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0) as u64
        };
        (get("hits"), get("appended"))
    }

    fn stats_path(&self) -> PathBuf {
        self.dir.join("stats.json")
    }

    /// Fold this session's counters into `stats.json` (best-effort,
    /// once; tmp + rename like every other artifact writer). The file
    /// is advisory telemetry — concurrent sessions may interleave and
    /// lose an update; the segment data never depends on it.
    pub fn persist_stats(&self) {
        if self.persisted.swap(true, Ordering::Relaxed) {
            return;
        }
        let (h, a) = self.lifetime_counters();
        let mut obj = BTreeMap::new();
        obj.insert(
            "hits".to_string(),
            Json::Num((h + self.hits.load(Ordering::Relaxed)) as f64),
        );
        obj.insert(
            "appended".to_string(),
            Json::Num(
                (a + self.appended.load(Ordering::Relaxed)) as f64,
            ),
        );
        let body = Json::Obj(obj).pretty();
        let tmp = self
            .dir
            .join(format!("stats.json.tmp-{}", std::process::id()));
        let ok = fs::write(&tmp, body)
            .and_then(|()| fs::rename(&tmp, self.stats_path()));
        if let Err(e) = ok {
            eprintln!("store: could not persist stats.json: {e}");
        }
    }
}

impl Drop for DiskStore {
    fn drop(&mut self) {
        if let Err(e) = self.seal() {
            eprintln!("store: seal on drop failed: {e}");
        }
    }
}

/// Flush + fsync + rename `wip-*` to its sealed `seg-*` name.
fn seal_writer(dir: &Path, mut w: SegWriter) -> Result<()> {
    w.file.flush()?;
    w.file.sync_all()?;
    let from = dir.join(wip_name(w.pid, w.k));
    let to = dir.join(seg_name(w.pid, w.k));
    fs::rename(&from, &to).with_context(|| {
        format!("sealing segment {}", from.display())
    })
}

/// Segment filenames under `dir`, sorted for a deterministic scan
/// order (`read_dir` order is filesystem-dependent).
fn segment_names(dir: &Path) -> Result<Vec<String>> {
    let mut names = Vec::new();
    for entry in fs::read_dir(dir).with_context(|| {
        format!("listing store dir {}", dir.display())
    })? {
        let name = entry?.file_name().to_string_lossy().into_owned();
        let is_seg = name.starts_with("seg-")
            || name.starts_with("wip-");
        if is_seg && name.ends_with(".lms") {
            names.push(name);
        }
    }
    names.sort();
    Ok(names)
}

/// Fold one segment's intact records into `index`; returns how many
/// records were skipped (bad header counts the whole file's records).
fn scan_segment(
    name: &str,
    bytes: &[u8],
    index: &mut BTreeMap<(u64, DesignPoint), Metrics>,
) -> usize {
    if bytes.len() < HEADER_LEN
        || bytes[..8] != MAGIC
        || bin::read_u32(bytes, 8) != Some(FORMAT_VERSION)
    {
        eprintln!("store: {name}: bad header, segment skipped");
        return bytes.len().saturating_sub(HEADER_LEN) / RECORD_LEN;
    }
    let mut skipped = 0usize;
    let body = &bytes[HEADER_LEN..];
    let whole = body.len() / RECORD_LEN;
    for (i, rec) in body.chunks(RECORD_LEN).enumerate() {
        match decode_record(rec) {
            Some((key, m)) => {
                index.insert(key, m);
            }
            None if rec.len() < RECORD_LEN => {
                // Torn tail: a writer crashed mid-record. Everything
                // before it was intact; carry on.
                eprintln!(
                    "store: {name}: torn tail ({} bytes) skipped",
                    rec.len()
                );
                skipped += 1;
            }
            None => {
                // Checksum failure: nothing after this offset can be
                // trusted (lengths are only implicit in the framing).
                let rest = whole - i;
                eprintln!(
                    "store: {name}: bad checksum at record {i}, \
                     {rest} record(s) skipped"
                );
                skipped += rest;
                break;
            }
        }
    }
    skipped
}

/// An in-memory [`SharedCache`] front with an *optional* [`DiskStore`]
/// behind it: the reusable two-tier (workload-fingerprint, design)
/// memo probe. [`DiskBackedCache`] is the evaluator-shaped wrapper of
/// the same tiering; the suite evaluator threads one `MemoTiers`
/// through all of its members instead — every member probes and
/// write-behinds under its **own** workload fingerprint (the same key
/// a single-workload run over that scenario uses, so entries
/// interchange between suite and single-workload runs). Cloning
/// shares both tiers.
#[derive(Debug, Clone, Default)]
pub struct MemoTiers {
    mem: SharedCache,
    disk: Option<Arc<DiskStore>>,
}

impl MemoTiers {
    pub fn new(disk: Option<Arc<DiskStore>>) -> Self {
        Self { mem: SharedCache::new(), disk }
    }

    /// The in-memory front tier.
    pub fn mem(&self) -> &SharedCache {
        &self.mem
    }

    /// The disk tier, when one is attached.
    pub fn disk(&self) -> Option<&Arc<DiskStore>> {
        self.disk.as_ref()
    }

    /// Two-tier probe: memory first, then disk with promotion into
    /// the memory tier (the promotion is counted in
    /// [`DiskCounters::hits`], mirroring [`DiskBackedCache`]).
    pub fn get(&self, fp: u64, d: &DesignPoint) -> Option<Metrics> {
        if let Some(m) = self.mem.get(fp, d) {
            return Some(m);
        }
        let disk = self.disk.as_ref()?;
        let m = disk.get(fp, d)?;
        self.mem.insert_if_absent(fp, d, m);
        disk.note_hit();
        Some(m)
    }

    /// True when either tier knows `(fp, d)`; no promotion, no
    /// counter effects.
    pub fn contains(&self, fp: u64, d: &DesignPoint) -> bool {
        self.mem.contains(fp, d)
            || self
                .disk
                .as_ref()
                .is_some_and(|dk| dk.contains(fp, d))
    }

    /// Write-behind commit to both tiers.
    pub fn put(&self, fp: u64, d: &DesignPoint, m: Metrics) {
        self.mem.insert(fp, d, m);
        if let Some(dk) = &self.disk {
            dk.append(fp, d, &m);
        }
    }
}

/// Read-through / write-behind two-tier memo cache: an in-memory
/// [`SharedCache`] in front of a [`DiskStore`]. Implements both
/// evaluator traits exactly like [`CachedEvaluator`], so it composes
/// with [`ParallelEvaluator`] identically — disk- and memory-resident
/// designs are served on the caller thread without touching the pool,
/// and only true misses are dispatched.
///
/// Counter semantics: the [`SharedCache`] hit/miss counters treat a
/// disk-served lookup as a *hit* (it costs no simulator work, so
/// [`BudgetedEvaluator`] lets it ride budget-free); the promotion
/// itself is additionally counted in [`DiskCounters::hits`].
///
/// [`CachedEvaluator`]: crate::eval::CachedEvaluator
/// [`ParallelEvaluator`]: crate::eval::ParallelEvaluator
/// [`BudgetedEvaluator`]: crate::eval::BudgetedEvaluator
#[derive(Debug)]
pub struct DiskBackedCache<E> {
    inner: E,
    mem: SharedCache,
    disk: Arc<DiskStore>,
}

impl<E> DiskBackedCache<E> {
    pub fn new(inner: E, disk: Arc<DiskStore>) -> Self {
        Self { inner, mem: SharedCache::new(), disk }
    }

    /// Wrap over existing (possibly shared) tiers.
    pub fn with_tiers(
        inner: E,
        mem: SharedCache,
        disk: Arc<DiskStore>,
    ) -> Self {
        Self { inner, mem, disk }
    }

    pub fn inner(&self) -> &E {
        &self.inner
    }

    pub fn mem(&self) -> &SharedCache {
        &self.mem
    }

    pub fn disk(&self) -> &Arc<DiskStore> {
        &self.disk
    }

    /// In-memory tier lookup counters.
    pub fn counters(&self) -> CacheCounters {
        self.mem.counters()
    }

    /// Two-tier probe: memory first, then disk with promotion.
    fn tier_get(&self, fp: u64, d: &DesignPoint) -> Option<Metrics> {
        if let Some(m) = self.mem.get(fp, d) {
            return Some(m);
        }
        let m = self.disk.get(fp, d)?;
        self.mem.insert_if_absent(fp, d, m);
        self.disk.note_hit();
        Some(m)
    }

    /// Write-behind commit to both tiers.
    fn tier_put(&self, fp: u64, d: &DesignPoint, m: Metrics) {
        self.mem.insert(fp, d, m);
        self.disk.append(fp, d, &m);
    }

    /// Seed known results without counter effects (resume path); new
    /// pairs are persisted, already-stored ones are not re-appended.
    fn warm_with_fp(&self, fp: u64, pairs: &[(DesignPoint, Metrics)]) {
        for (d, m) in pairs {
            self.mem.insert_if_absent(fp, d, *m);
            if !self.disk.contains(fp, d) {
                self.disk.append(fp, d, m);
            }
        }
    }

    fn batch_with_fp(
        &self,
        fp: u64,
        designs: &[DesignPoint],
        run_fresh: impl FnOnce(&[DesignPoint]) -> Result<Vec<Metrics>>,
    ) -> Result<Vec<Metrics>> {
        batch_via_tiers(
            |d| self.tier_get(fp, d),
            |d, m| self.tier_put(fp, d, m),
            |hits, misses| self.mem.record(hits, misses),
            designs,
            run_fresh,
        )
    }
}

impl<E: Evaluator> Evaluator for DiskBackedCache<E> {
    fn eval_batch(&mut self, designs: &[DesignPoint]) -> Result<Vec<Metrics>> {
        let fp = self.inner.workload_fingerprint();
        // Split borrow: tiers shared, inner evaluator mutable.
        let (mem, disk) = (&self.mem, &self.disk);
        let inner = &mut self.inner;
        batch_via_tiers(
            |d| {
                if let Some(m) = mem.get(fp, d) {
                    return Some(m);
                }
                let m = disk.get(fp, d)?;
                mem.insert_if_absent(fp, d, m);
                disk.note_hit();
                Some(m)
            },
            |d, m| {
                mem.insert(fp, d, m);
                disk.append(fp, d, &m);
            },
            |hits, misses| mem.record(hits, misses),
            designs,
            |fresh| inner.eval_batch(fresh),
        )
    }

    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn is_cached(&self, d: &DesignPoint) -> bool {
        let fp = self.inner.workload_fingerprint();
        self.mem.contains(fp, d) || self.disk.contains(fp, d)
    }

    fn cache_counters(&self) -> Option<CacheCounters> {
        Some(self.mem.counters())
    }

    fn disk_counters(&self) -> Option<DiskCounters> {
        Some(self.disk.counters())
    }

    fn workload_fingerprint(&self) -> u64 {
        self.inner.workload_fingerprint()
    }

    fn preload(&mut self, pairs: &[(DesignPoint, Metrics)]) {
        self.warm_with_fp(self.inner.workload_fingerprint(), pairs);
    }
}

impl<E: EvalOne> EvalOne for DiskBackedCache<E> {
    fn eval_one(&self, d: &DesignPoint) -> Metrics {
        let fp = EvalOne::workload_fingerprint(&self.inner);
        if let Some(m) = self.tier_get(fp, d) {
            self.mem.record(1, 0);
            return m;
        }
        let m = self.inner.eval_one(d);
        self.tier_put(fp, d, m);
        self.mem.record(0, 1);
        m
    }

    fn label(&self) -> &'static str {
        self.inner.label()
    }

    fn workload_fingerprint(&self) -> u64 {
        EvalOne::workload_fingerprint(&self.inner)
    }

    fn eval_chunk(
        &self,
        designs: &[DesignPoint],
        out: &mut [Metrics],
        scratch: &mut EvalScratch,
    ) {
        let fp = EvalOne::workload_fingerprint(&self.inner);
        let ms = self
            .batch_with_fp(fp, designs, |fresh| {
                let mut fresh_ms =
                    vec![Metrics::default(); fresh.len()];
                self.inner.eval_chunk(fresh, &mut fresh_ms, scratch);
                Ok(fresh_ms)
            })
            // lumina: allow(P001) the closure is Ok-returning; cannot fail
            .expect("infallible inner chunk");
        out.copy_from_slice(&ms);
    }

    fn probe(&self, d: &DesignPoint) -> Option<Metrics> {
        self.tier_get(EvalOne::workload_fingerprint(&self.inner), d)
    }

    fn memoizes(&self) -> bool {
        true
    }

    fn count_hits(&self, n: u64) {
        self.mem.record(n, 0);
    }

    fn memo_counters(&self) -> Option<CacheCounters> {
        Some(self.mem.counters())
    }

    fn memo_disk_counters(&self) -> Option<DiskCounters> {
        Some(self.disk.counters())
    }

    fn memo_warm(&self, pairs: &[(DesignPoint, Metrics)]) {
        self.warm_with_fp(
            EvalOne::workload_fingerprint(&self.inner),
            pairs,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_metrics(tag: f32) -> Metrics {
        Metrics {
            ttft_ms: 30.0 + tag,
            tpot_ms: 0.5,
            area_mm2: 800.0,
            energy_per_token_mj: 40.0,
            prefill_energy_mj: 8000.0,
            avg_power_w: 263.6,
            stalls: [[20.0, 4.0, 6.0], [0.01, 0.4, 0.09]],
        }
    }

    #[test]
    fn record_round_trips_bitwise() {
        let d = DesignPoint::a100();
        let mut m = sample_metrics(0.0);
        // Exercise payloads a text round-trip would mangle.
        m.tpot_ms = f32::from_bits(0x0000_0001);
        m.stalls[1][2] = -0.0;
        let rec = encode_record(0xfeed_beef, &d, &m);
        assert_eq!(rec.len(), RECORD_LEN);
        let ((fp, d2), m2) = decode_record(&rec).unwrap();
        assert_eq!(fp, 0xfeed_beef);
        assert_eq!(d2, d);
        let (a, b) = (metric_lanes(&m), metric_lanes(&m2));
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn decode_rejects_damage() {
        let d = DesignPoint::a100();
        let m = sample_metrics(1.0);
        let rec = encode_record(7, &d, &m);
        // Any single-byte flip must fail the checksum.
        for i in [0usize, 11, 40, RECORD_LEN - 1] {
            let mut bad = rec.clone();
            bad[i] ^= 0x40;
            assert!(decode_record(&bad).is_none(), "flip at {i}");
        }
        // Short (torn) records never decode.
        assert!(decode_record(&rec[..RECORD_LEN - 1]).is_none());
        assert!(decode_record(&[]).is_none());
    }

    #[test]
    fn scan_segment_skips_from_first_bad_checksum() {
        let d = DesignPoint::a100();
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MAGIC);
        bin::put_u32(&mut bytes, FORMAT_VERSION);
        for i in 0..4 {
            let m = sample_metrics(i as f32);
            let dd = d.with(crate::design::Param::Cores, 32 + i);
            bytes.extend_from_slice(&encode_record(9, &dd, &m));
        }
        // Corrupt record 2: records 0..2 survive, 2..4 are dropped.
        bytes[HEADER_LEN + 2 * RECORD_LEN + 5] ^= 0xff;
        let mut index = BTreeMap::new();
        let skipped = scan_segment("t.lms", &bytes, &mut index);
        assert_eq!(index.len(), 2);
        assert_eq!(skipped, 2);
    }

    #[test]
    fn scan_segment_rejects_bad_header() {
        let mut index = BTreeMap::new();
        let skipped = scan_segment("t.lms", b"NOTMAGIC", &mut index);
        assert_eq!(skipped, 0);
        assert!(index.is_empty());
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MAGIC);
        bin::put_u32(&mut bytes, FORMAT_VERSION + 1);
        bytes.extend_from_slice(&[0u8; RECORD_LEN]);
        let skipped = scan_segment("t.lms", &bytes, &mut index);
        assert_eq!(skipped, 1, "future version: all records skipped");
        assert!(index.is_empty());
    }
}
