//! Batch-parallel evaluation: shard `eval_batch` across the persistent
//! [`WorkerPool`] with deterministic, input-order result assembly.
//!
//! Lanes split the input into contiguous chunks; chunk `i` of the
//! output is written only by the lane that ran chunk `i`, so assembly
//! order never depends on thread scheduling and results are
//! **bit-identical** to the sequential path (each design is evaluated
//! by the same pure [`EvalOne`] evaluation either way — see
//! `tests/eval_pipeline.rs::parallel_matches_sequential_bitwise`).
//! Chunks run through [`EvalOne::eval_chunk`], which the simulators
//! override with their SoA batch kernels, so pool parallelism and SoA
//! vectorization compose.
//!
//! When the inner evaluator memoizes ([`EvalOne::memoizes`], see
//! [`crate::eval::CachedEvaluator`]), `eval_batch` takes the memo-aware
//! path: probe every design on the caller thread, serve hits **without
//! touching the pool**, and dispatch only the unique uncached designs —
//! each evaluated exactly once, so observable results and hit/miss
//! counters are deterministic and identical to the sequential caching
//! path.
//!
//! The PR-1 scoped-spawn sharder survives as
//! [`eval_batch_parallel`] — the benchmark baseline (`perf_hotpath`
//! compares pool dispatch against spawn-per-batch) and a second test
//! oracle; the adapter itself always dispatches to the shared pool.

use std::collections::{HashMap, HashSet};

use crate::design::DesignPoint;
use crate::eval::scratch::{with_caller_scratch, EvalScratch};
use crate::eval::{
    CacheCounters, DiskCounters, EvalOne, Evaluator, Metrics,
    WorkerPool,
};
use crate::Result;

/// Batches smaller than this run sequentially on the caller: even pool
/// dispatch (a queue push + condvar wake per lane) would dominate
/// sub-microsecond chunks.
pub(crate) const MIN_PARALLEL_BATCH: usize = 8;

/// Worker count used by [`ParallelEvaluator::new`]: every available
/// hardware thread (the caller lane plus the global pool's workers).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Adapter that evaluates batches of a pure [`EvalOne`] evaluator in
/// parallel on the process-wide [`WorkerPool`]. Single-design calls
/// stay on the caller's thread.
#[derive(Debug, Clone)]
pub struct ParallelEvaluator<E> {
    inner: E,
    threads: usize,
}

impl<E: EvalOne> ParallelEvaluator<E> {
    /// Wrap `inner`, using every available hardware thread.
    pub fn new(inner: E) -> Self {
        Self::with_threads(inner, default_threads())
    }

    /// Wrap `inner` with an explicit lane count (1 = sequential).
    pub fn with_threads(inner: E, threads: usize) -> Self {
        Self { inner, threads: threads.max(1) }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn inner(&self) -> &E {
        &self.inner
    }

    pub fn into_inner(self) -> E {
        self.inner
    }
}

impl<E: EvalOne> EvalOne for ParallelEvaluator<E> {
    fn eval_one(&self, d: &DesignPoint) -> Metrics {
        self.inner.eval_one(d)
    }

    fn label(&self) -> &'static str {
        self.inner.label()
    }

    fn workload_fingerprint(&self) -> u64 {
        self.inner.workload_fingerprint()
    }

    fn eval_chunk(
        &self,
        designs: &[DesignPoint],
        out: &mut [Metrics],
        scratch: &mut EvalScratch,
    ) {
        self.inner.eval_chunk(designs, out, scratch);
    }

    fn probe(&self, d: &DesignPoint) -> Option<Metrics> {
        self.inner.probe(d)
    }

    fn memoizes(&self) -> bool {
        self.inner.memoizes()
    }

    fn count_hits(&self, n: u64) {
        self.inner.count_hits(n);
    }

    fn memo_counters(&self) -> Option<CacheCounters> {
        self.inner.memo_counters()
    }

    fn memo_disk_counters(&self) -> Option<DiskCounters> {
        self.inner.memo_disk_counters()
    }

    fn memo_warm(&self, pairs: &[(DesignPoint, Metrics)]) {
        self.inner.memo_warm(pairs);
    }
}

impl<E: EvalOne> Evaluator for ParallelEvaluator<E> {
    fn eval_batch(&mut self, designs: &[DesignPoint]) -> Result<Vec<Metrics>> {
        Ok(eval_batch_pooled(&self.inner, designs, self.threads))
    }

    fn name(&self) -> &'static str {
        self.inner.label()
    }

    fn is_cached(&self, d: &DesignPoint) -> bool {
        self.inner.probe(d).is_some()
    }

    fn cache_counters(&self) -> Option<CacheCounters> {
        self.inner.memo_counters()
    }

    fn disk_counters(&self) -> Option<DiskCounters> {
        self.inner.memo_disk_counters()
    }

    fn workload_fingerprint(&self) -> u64 {
        EvalOne::workload_fingerprint(&self.inner)
    }

    fn preload(&mut self, pairs: &[(DesignPoint, Metrics)]) {
        self.inner.memo_warm(pairs);
    }
}

/// Evaluate `designs` on the global [`WorkerPool`] across up to
/// `threads` lanes, returning results in input order. Memoizing inner
/// evaluators get the dedup/hit-bypass path (see module docs). The
/// free-function form lets callers shard over a shared `&E` without
/// the adapter.
pub fn eval_batch_pooled<E: EvalOne + ?Sized>(
    ev: &E,
    designs: &[DesignPoint],
    threads: usize,
) -> Vec<Metrics> {
    let n = designs.len();
    if !ev.memoizes() {
        let mut out = vec![Metrics::default(); n];
        dispatch(ev, designs, &mut out, threads);
        return out;
    }
    // Memo-aware path: hits resolve on this thread, only unique
    // uncached designs are dispatched (each exactly once, so the
    // hit/miss counters match the sequential caching path: one miss
    // per unique fresh design, everything else a hit).
    let mut out: Vec<Option<Metrics>> = Vec::with_capacity(n);
    let mut fresh: Vec<DesignPoint> = Vec::new();
    let mut seen: HashSet<DesignPoint> = HashSet::new();
    for d in designs {
        match ev.probe(d) {
            Some(m) => out.push(Some(m)),
            None => {
                if seen.insert(*d) {
                    fresh.push(*d);
                }
                out.push(None);
            }
        }
    }
    let mut fresh_ms = vec![Metrics::default(); fresh.len()];
    // The memo layer's own `eval_chunk` runs on the pool lanes: it
    // misses on every (all-fresh) design, evaluates through the inner
    // SoA kernel and memoizes + counts the misses.
    dispatch(ev, &fresh, &mut fresh_ms, threads);
    ev.count_hits((n - fresh.len()) as u64);
    let by_design: HashMap<DesignPoint, Metrics> =
        fresh.iter().copied().zip(fresh_ms).collect();
    designs
        .iter()
        .zip(out)
        .map(|(d, slot)| match slot {
            Some(m) => m,
            None => by_design[d],
        })
        .collect()
}

/// Chunked pool dispatch (sequential below the batch-size floor).
fn dispatch<E: EvalOne + ?Sized>(
    ev: &E,
    designs: &[DesignPoint],
    out: &mut [Metrics],
    threads: usize,
) {
    if threads <= 1 || designs.len() < MIN_PARALLEL_BATCH {
        with_caller_scratch(|s| ev.eval_chunk(designs, out, s));
    } else {
        WorkerPool::global().eval_on(ev, designs, out, threads);
    }
}

/// Evaluate `designs` across up to `threads` *freshly spawned* scoped
/// workers, returning results in input order. This is the PR-1
/// implementation, kept as the spawn-per-batch baseline the
/// `perf_hotpath` pool rows are compared against and as an independent
/// oracle for the pool's assembly order.
pub fn eval_batch_parallel<E: EvalOne + ?Sized>(
    ev: &E,
    designs: &[DesignPoint],
    threads: usize,
) -> Vec<Metrics> {
    let n = designs.len();
    let threads = threads.clamp(1, n.max(1));
    if threads == 1 || n < MIN_PARALLEL_BATCH {
        return designs.iter().map(|d| ev.eval_one(d)).collect();
    }
    // Ceiling division so every worker gets at most `chunk` designs and
    // the chunk partition of input and output line up exactly.
    let chunk = n.div_ceil(threads);
    let mut out: Vec<Option<Metrics>> = vec![None; n];
    std::thread::scope(|s| {
        for (src, dst) in designs.chunks(chunk).zip(out.chunks_mut(chunk)) {
            s.spawn(move || {
                for (d, slot) in src.iter().zip(dst.iter_mut()) {
                    *slot = Some(ev.eval_one(d));
                }
            });
        }
    });
    out.into_iter()
        // lumina: allow(P001) chunking covers every index exactly once
        .map(|m| m.expect("every output slot is covered by one worker"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::design::{sample, DesignSpace};
    use crate::eval::CachedEvaluator;
    use crate::sim::RooflineSim;
    use crate::stats::rng::Pcg32;
    use crate::workload::GPT3_175B;

    #[test]
    fn matches_sequential_on_small_and_odd_sizes() {
        let space = DesignSpace::table1();
        let mut rng = Pcg32::new(17);
        let sim = RooflineSim::new(GPT3_175B);
        for n in [0usize, 1, 5, 8, 9, 31] {
            let ds = sample::uniform_batch(&space, &mut rng, n);
            let seq: Vec<_> = ds.iter().map(|d| sim.eval_one(d)).collect();
            for threads in [1usize, 2, 3, 7] {
                let par = eval_batch_parallel(&sim, &ds, threads);
                assert_eq!(par, seq, "spawn: n={n} threads={threads}");
                let pooled = eval_batch_pooled(&sim, &ds, threads);
                assert_eq!(pooled, seq, "pool: n={n} threads={threads}");
            }
        }
    }

    #[test]
    fn adapter_reports_inner_label_and_threads() {
        let p = ParallelEvaluator::with_threads(
            RooflineSim::new(GPT3_175B),
            4,
        );
        assert_eq!(p.threads(), 4);
        assert_eq!(p.label(), "roofline-rs");
        assert_eq!(Evaluator::name(&p), "roofline-rs");
        assert_eq!(ParallelEvaluator::with_threads(
            RooflineSim::new(GPT3_175B), 0).threads(), 1);
    }

    /// EvalOne wrapper counting how many designs reach the simulator —
    /// the memo-bypass proof (thread-safe: the pool may call it).
    struct CountingSim {
        sim: RooflineSim,
        evals: std::sync::atomic::AtomicUsize,
    }

    impl CountingSim {
        fn evals(&self) -> usize {
            self.evals.load(std::sync::atomic::Ordering::Relaxed)
        }
    }

    impl EvalOne for CountingSim {
        fn eval_one(&self, d: &DesignPoint) -> Metrics {
            self.evals
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            self.sim.eval_one(d)
        }
        fn label(&self) -> &'static str {
            "counting-sim"
        }
        fn workload_fingerprint(&self) -> u64 {
            EvalOne::workload_fingerprint(&self.sim)
        }
        fn eval_chunk(
            &self,
            designs: &[DesignPoint],
            out: &mut [Metrics],
            scratch: &mut EvalScratch,
        ) {
            self.evals.fetch_add(
                designs.len(),
                std::sync::atomic::Ordering::Relaxed,
            );
            self.sim.eval_chunk(designs, out, scratch);
        }
    }

    #[test]
    fn memo_aware_batch_serves_hits_without_dispatch() {
        let space = DesignSpace::table1();
        let mut rng = Pcg32::new(23);
        let ds = sample::uniform_batch(&space, &mut rng, 64);
        let mut plain = RooflineSim::new(GPT3_175B);
        let want = plain.eval_batch(&ds).unwrap();

        let mut stack = ParallelEvaluator::new(CachedEvaluator::new(
            CountingSim {
                sim: RooflineSim::new(GPT3_175B),
                evals: std::sync::atomic::AtomicUsize::new(0),
            },
        ));
        let cold = stack.eval_batch(&ds).unwrap();
        assert_eq!(cold, want);
        let unique = stack.inner().len();
        assert_eq!(
            stack.inner().inner().evals(),
            unique,
            "each unique design simulated exactly once"
        );
        let c = Evaluator::cache_counters(&stack).unwrap();
        assert_eq!(c.misses, unique as u64);
        assert_eq!(c.hits, ds.len() as u64 - unique as u64);
        // Warm revisit: bit-identical, served entirely from the memo
        // store — the simulator (and therefore the pool) sees nothing.
        let warm = stack.eval_batch(&ds).unwrap();
        assert_eq!(warm, want);
        assert_eq!(
            stack.inner().inner().evals(),
            unique,
            "hit path must bypass evaluation entirely"
        );
        let c = Evaluator::cache_counters(&stack).unwrap();
        assert_eq!(c.misses, unique as u64);
        assert_eq!(c.hits, 2 * ds.len() as u64 - unique as u64);
    }
}
