//! Batch-parallel evaluation: shard `eval_batch` across scoped worker
//! threads with deterministic, input-order result assembly.
//!
//! Workers split the input into contiguous chunks; chunk `i` of the
//! output is written only by worker `i`, so assembly order never depends
//! on thread scheduling and results are **bit-identical** to the
//! sequential path (each design is evaluated by the same pure
//! [`EvalOne::eval_one`] either way — see
//! `tests/eval_pipeline.rs::parallel_matches_sequential_bitwise`).

use crate::design::DesignPoint;
use crate::eval::{EvalOne, Evaluator, Metrics};
use crate::Result;

/// Batches smaller than this run sequentially: scoped-thread spawn
/// overhead (~10us/worker) would dominate sub-millisecond batches.
const MIN_PARALLEL_BATCH: usize = 8;

/// Worker count used by [`ParallelEvaluator::new`]: every available
/// hardware thread.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Adapter that evaluates batches of a pure [`EvalOne`] evaluator in
/// parallel. Single-design calls stay on the caller's thread.
#[derive(Debug, Clone)]
pub struct ParallelEvaluator<E> {
    inner: E,
    threads: usize,
}

impl<E: EvalOne> ParallelEvaluator<E> {
    /// Wrap `inner`, using every available hardware thread.
    pub fn new(inner: E) -> Self {
        Self::with_threads(inner, default_threads())
    }

    /// Wrap `inner` with an explicit worker count (1 = sequential).
    pub fn with_threads(inner: E, threads: usize) -> Self {
        Self { inner, threads: threads.max(1) }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn inner(&self) -> &E {
        &self.inner
    }

    pub fn into_inner(self) -> E {
        self.inner
    }
}

impl<E: EvalOne> EvalOne for ParallelEvaluator<E> {
    fn eval_one(&self, d: &DesignPoint) -> Metrics {
        self.inner.eval_one(d)
    }

    fn label(&self) -> &'static str {
        self.inner.label()
    }

    fn workload_fingerprint(&self) -> u64 {
        self.inner.workload_fingerprint()
    }
}

impl<E: EvalOne> Evaluator for ParallelEvaluator<E> {
    fn eval_batch(&mut self, designs: &[DesignPoint]) -> Result<Vec<Metrics>> {
        Ok(eval_batch_parallel(&self.inner, designs, self.threads))
    }

    fn name(&self) -> &'static str {
        self.inner.label()
    }

    fn workload_fingerprint(&self) -> u64 {
        EvalOne::workload_fingerprint(&self.inner)
    }
}

/// Evaluate `designs` across up to `threads` scoped workers, returning
/// results in input order. The free-function form lets callers shard
/// over a shared `&E` without the adapter.
pub fn eval_batch_parallel<E: EvalOne + ?Sized>(
    ev: &E,
    designs: &[DesignPoint],
    threads: usize,
) -> Vec<Metrics> {
    let n = designs.len();
    let threads = threads.clamp(1, n.max(1));
    if threads == 1 || n < MIN_PARALLEL_BATCH {
        return designs.iter().map(|d| ev.eval_one(d)).collect();
    }
    // Ceiling division so every worker gets at most `chunk` designs and
    // the chunk partition of input and output line up exactly.
    let chunk = n.div_ceil(threads);
    let mut out: Vec<Option<Metrics>> = vec![None; n];
    std::thread::scope(|s| {
        for (src, dst) in designs.chunks(chunk).zip(out.chunks_mut(chunk)) {
            s.spawn(move || {
                for (d, slot) in src.iter().zip(dst.iter_mut()) {
                    *slot = Some(ev.eval_one(d));
                }
            });
        }
    });
    out.into_iter()
        .map(|m| m.expect("every output slot is covered by one worker"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::design::{sample, DesignSpace};
    use crate::sim::RooflineSim;
    use crate::stats::rng::Pcg32;
    use crate::workload::GPT3_175B;

    #[test]
    fn matches_sequential_on_small_and_odd_sizes() {
        let space = DesignSpace::table1();
        let mut rng = Pcg32::new(17);
        let sim = RooflineSim::new(GPT3_175B);
        for n in [0usize, 1, 5, 8, 9, 31] {
            let ds = sample::uniform_batch(&space, &mut rng, n);
            let seq: Vec<_> = ds.iter().map(|d| sim.eval_one(d)).collect();
            for threads in [1usize, 2, 3, 7] {
                let par = eval_batch_parallel(&sim, &ds, threads);
                assert_eq!(par, seq, "n={n} threads={threads}");
            }
        }
    }

    #[test]
    fn adapter_reports_inner_label_and_threads() {
        let p = ParallelEvaluator::with_threads(
            RooflineSim::new(GPT3_175B),
            4,
        );
        assert_eq!(p.threads(), 4);
        assert_eq!(p.label(), "roofline-rs");
        assert_eq!(Evaluator::name(&p), "roofline-rs");
        assert_eq!(ParallelEvaluator::with_threads(
            RooflineSim::new(GPT3_175B), 0).threads(), 1);
    }
}
