//! Multi-scenario suite evaluation: one design, every registered
//! workload scenario, one weighted composite objective.
//!
//! [`SuiteEvaluator`] owns one backend per scenario (built by a
//! caller-supplied factory). Pure per-design backends
//! ([`SuiteBackend::Fused`]) join a **single fused cross-scenario
//! dispatch**: every (member × design-chunk) task of one ask batch is
//! enqueued under one [`super::WorkerPool`] batch latch
//! ([`super::pool::PoolJob`]), each member writing its own pre-sized
//! output lane — one barrier per batch instead of one per member, and
//! small ask batches still keep every worker busy because the chunk
//! size is derived from the fused total. Stateful batch backends
//! ([`SuiteBackend::Sequential`], e.g. a PJRT artifact) keep their own
//! `eval_batch` and run member-at-a-time, exactly like the historical
//! member path.
//!
//! Memoization is two-layered. A **composite memo** (keyed on the
//! combined suite fingerprint) dedups duplicate designs once before
//! any fan-out, so revisits and intra-batch duplicates are served on
//! the caller thread. Below it, every fused member probes and
//! write-behinds a shared [`super::store::MemoTiers`] under its
//! **own** workload fingerprint — with a `--cache-dir` disk store
//! attached, a design evaluated in a single-workload run is a free
//! disk hit inside a suite run, and vice versa.
//!
//! `eval_batch` returns a **composite** [`Metrics`] per design:
//! TTFT/TPOT are the weighted means of the per-scenario values
//! normalized by that scenario's A100 reference (so the A100 scores
//! exactly 1.0 on both axes and DSE methods optimize a dimensionless
//! multi-scenario objective); stall stacks are normalized the same way,
//! preserving the "stalls sum to phase time" invariant; area is
//! workload-independent and taken from the first scenario. Per-scenario
//! TTFT/TPOT reporting goes through [`SuiteEvaluator::eval_scenarios`].
//!
//! Composition order is fixed (registry order, f32 accumulation) and
//! composes straight from the transposed per-member lanes (no
//! per-design row is built), so suite results are bit-deterministic
//! and independent of whether the members are fused, parallel, cached,
//! or plain — covered by
//! `tests/eval_pipeline.rs::suite_fused_matches_sequential_bitwise_256`
//! and
//! `tests/eval_pipeline.rs::suite_composite_is_deterministic_across_pipelines`.

use std::collections::{HashMap, HashSet};
use std::sync::Arc;

use crate::design::DesignPoint;
use crate::eval::parallel::{default_threads, MIN_PARALLEL_BATCH};
use crate::eval::pool::PoolJob;
use crate::eval::{
    CacheCounters, DiskCounters, DiskStore, EvalOne, Evaluator,
    MemoTiers, Metrics, SharedCache, WorkerPool,
};
use crate::workload::{Scenario, WorkloadSpec};
use crate::{bail, Result};

/// One design's metrics under one named scenario.
#[derive(Debug, Clone, Copy)]
pub struct ScenarioMetrics {
    pub name: &'static str,
    pub weight: f64,
    /// Per-layer metrics of the evaluated design under this scenario.
    pub metrics: Metrics,
    /// Per-layer A100 reference metrics under this scenario.
    pub reference: Metrics,
    /// Full-model depth for report-level scaling.
    pub n_layers: u64,
}

impl ScenarioMetrics {
    /// Full-model TTFT (all layers), milliseconds.
    pub fn full_ttft_ms(&self) -> f32 {
        self.metrics.ttft_ms * self.n_layers as f32
    }

    /// Full-model TPOT (all layers), milliseconds.
    pub fn full_tpot_ms(&self) -> f32 {
        self.metrics.tpot_ms * self.n_layers as f32
    }
}

/// How one suite member evaluates (see module docs): pure per-design
/// backends join the fused cross-scenario pool dispatch and the
/// per-member memo tiers; stateful batch backends keep their own
/// `eval_batch` and run member-at-a-time.
pub enum SuiteBackend {
    Fused(Box<dyn EvalOne>),
    Sequential(Box<dyn Evaluator>),
}

struct SuiteMember {
    scenario: Scenario,
    backend: SuiteBackend,
    reference: Metrics,
    /// This member's own workload fingerprint — the per-member memo
    /// tier key, shared with single-workload runs of the same spec.
    fp: u64,
}

/// Weighted multi-scenario evaluator (see module docs).
pub struct SuiteEvaluator {
    members: Vec<SuiteMember>,
    weight_total: f32,
    fingerprint: u64,
    threads: usize,
    /// Composite memo keyed on (combined suite fingerprint, design);
    /// its counters drive budget accounting (a design counts as a
    /// miss only when some member actually simulated it).
    composite: SharedCache,
    /// Per-member memo tier keyed on (member fingerprint, design) —
    /// one shared two-tier store serves every fused member, since the
    /// keys embed each member's own fingerprint.
    tiers: MemoTiers,
}

impl SuiteEvaluator {
    /// Build one inner evaluator per scenario via `factory` and pin
    /// each scenario's A100 reference. Scenario weights must sum
    /// positive. Members built this way run the sequential member
    /// path; [`SuiteEvaluator::with_backends`] builds fused members.
    pub fn new(
        scenarios: &[&Scenario],
        factory: &mut dyn FnMut(&WorkloadSpec) -> Box<dyn Evaluator>,
    ) -> Result<Self> {
        Self::with_backends(
            scenarios,
            &mut |spec| SuiteBackend::Sequential(factory(spec)),
            None,
        )
    }

    /// Build one backend per scenario via `factory`, attach an
    /// optional disk tier under the per-member memo, and pin each
    /// scenario's A100 reference through one fused startup batch.
    pub fn with_backends(
        scenarios: &[&Scenario],
        factory: &mut dyn FnMut(&WorkloadSpec) -> SuiteBackend,
        disk: Option<Arc<DiskStore>>,
    ) -> Result<Self> {
        if scenarios.is_empty() {
            bail!("suite needs at least one scenario");
        }
        let weight_total: f32 =
            scenarios.iter().map(|s| s.weight as f32).sum();
        if weight_total <= 0.0 {
            bail!("suite scenario weights must sum positive");
        }
        let mut members = Vec::with_capacity(scenarios.len());
        let mut fingerprint: u64 = 0xcbf29ce484222325;
        for s in scenarios {
            let backend = factory(&s.spec);
            let fp = match &backend {
                SuiteBackend::Fused(ev) => ev.workload_fingerprint(),
                SuiteBackend::Sequential(ev) => {
                    ev.workload_fingerprint()
                }
            };
            fingerprint ^= s.spec.fingerprint();
            fingerprint = fingerprint.wrapping_mul(0x100000001b3);
            fingerprint ^= s.weight.to_bits();
            fingerprint = fingerprint.wrapping_mul(0x100000001b3);
            members.push(SuiteMember {
                scenario: **s,
                backend,
                reference: Metrics::default(),
                fp,
            });
        }
        let mut suite = Self {
            members,
            weight_total,
            fingerprint,
            threads: default_threads(),
            composite: SharedCache::new(),
            tiers: MemoTiers::new(disk),
        };
        suite.pin_references()?;
        Ok(suite)
    }

    /// Pin each member's A100 reference through [`Self::eval_members`]:
    /// fused members resolve in **one** shared pool dispatch (suite
    /// startup rides the pool instead of one sequential `eval` per
    /// member), and a warm disk store serves references without
    /// simulating at all.
    fn pin_references(&mut self) -> Result<()> {
        let a100 = DesignPoint::a100();
        let (per_member, _simulated) =
            self.eval_members(std::slice::from_ref(&a100))?;
        for (m, lane) in self.members.iter_mut().zip(&per_member) {
            m.reference = lane[0];
        }
        Ok(())
    }

    /// The scenarios of this suite, in evaluation order.
    pub fn scenarios(&self) -> Vec<&Scenario> {
        self.members.iter().map(|m| &m.scenario).collect()
    }

    /// Drop every memoized entry (the composite memo and the
    /// in-memory member tier; a disk tier is untouched) while keeping
    /// the counters. The perf bench re-evaluates one batch repeatedly
    /// and must re-dispatch it each iteration.
    pub fn clear_memo(&mut self) {
        self.composite.clear();
        self.tiers.mem().clear();
    }

    /// Per-scenario metrics of one design (report path; the
    /// [`Evaluator`] impl returns the composite instead). Fused
    /// members resolve through the member tiers, so a report on an
    /// already-explored design never re-simulates.
    pub fn eval_scenarios(
        &mut self,
        d: &DesignPoint,
    ) -> Result<Vec<ScenarioMetrics>> {
        let tiers = &self.tiers;
        let mut out = Vec::with_capacity(self.members.len());
        for m in &mut self.members {
            let SuiteMember { scenario, backend, reference, fp } = m;
            let metrics = match backend {
                SuiteBackend::Fused(ev) => match tiers.get(*fp, d) {
                    Some(hit) => hit,
                    None => {
                        let v = ev.eval_one(d);
                        tiers.put(*fp, d, v);
                        v
                    }
                },
                SuiteBackend::Sequential(ev) => ev.eval(d)?,
            };
            out.push(ScenarioMetrics {
                name: scenario.name,
                weight: scenario.weight,
                metrics,
                reference: *reference,
                n_layers: scenario.spec.n_layers,
            });
        }
        Ok(out)
    }

    /// Resolve `fresh` (unique designs) under every member. Fused
    /// members are tier-probed on the caller thread, then every
    /// still-missing (member × chunk) task runs under **one** fused
    /// pool dispatch, with write-behind into the member tiers.
    /// Sequential members run their own `eval_batch` over the full
    /// list, unchanged. Returns the member-major metrics grid and how
    /// many of the designs required at least one member simulation.
    fn eval_members(
        &mut self,
        fresh: &[DesignPoint],
    ) -> Result<(Vec<Vec<Metrics>>, usize)> {
        struct PendingLane<'a> {
            member: usize,
            ev: &'a dyn EvalOne,
            need: Vec<DesignPoint>,
            lane: Vec<Metrics>,
        }

        let nm = self.members.len();
        let n = fresh.len();
        let mut resolved: Vec<Vec<Option<Metrics>>> =
            vec![vec![None; n]; nm];
        let mut needs_sim = vec![false; n];
        let mut pending: Vec<PendingLane<'_>> = Vec::new();
        for (k, m) in self.members.iter().enumerate() {
            match &m.backend {
                SuiteBackend::Fused(ev) => {
                    let mut need = Vec::new();
                    for (i, d) in fresh.iter().enumerate() {
                        match self.tiers.get(m.fp, d) {
                            Some(hit) => resolved[k][i] = Some(hit),
                            None => {
                                need.push(*d);
                                needs_sim[i] = true;
                            }
                        }
                    }
                    if !need.is_empty() {
                        let lane =
                            vec![Metrics::default(); need.len()];
                        pending.push(PendingLane {
                            member: k,
                            ev: ev.as_ref(),
                            need,
                            lane,
                        });
                    }
                }
                SuiteBackend::Sequential(_) => {
                    // Stateful members can be neither tier-served nor
                    // fused: every design reaches their simulator.
                    needs_sim.fill(true);
                }
            }
        }
        if !pending.is_empty() {
            // The tentpole: all (member × chunk) tasks share a single
            // batch latch — one barrier for the whole suite batch.
            let total: usize =
                pending.iter().map(|p| p.need.len()).sum();
            let threads = if total < MIN_PARALLEL_BATCH {
                1
            } else {
                self.threads
            };
            let mut jobs: Vec<PoolJob<'_, dyn EvalOne>> = pending
                .iter_mut()
                .map(|p| PoolJob {
                    ev: p.ev,
                    designs: p.need.as_slice(),
                    out: p.lane.as_mut_slice(),
                })
                .collect();
            WorkerPool::global().eval_on_multi(&mut jobs, threads);
        }
        // Write-behind + scatter: `need` was collected in probe
        // order, so its results fill this member's unresolved slots
        // in order.
        for p in &pending {
            let fp = self.members[p.member].fp;
            let mut j = 0;
            for slot in resolved[p.member].iter_mut() {
                if slot.is_none() {
                    self.tiers.put(fp, &p.need[j], p.lane[j]);
                    *slot = Some(p.lane[j]);
                    j += 1;
                }
            }
            debug_assert_eq!(j, p.need.len());
        }
        drop(pending);
        for (k, m) in self.members.iter_mut().enumerate() {
            let SuiteMember { scenario, backend, .. } = m;
            if let SuiteBackend::Sequential(ev) = backend {
                let ms = ev.eval_batch(fresh)?;
                if ms.len() != n {
                    bail!(
                        "suite member {} returned {} results for {} \
                         designs",
                        scenario.name,
                        ms.len(),
                        n
                    );
                }
                for (i, v) in ms.into_iter().enumerate() {
                    resolved[k][i] = Some(v);
                }
            }
        }
        let simulated = needs_sim.iter().filter(|f| **f).count();
        let per_member = resolved
            .into_iter()
            .map(|lane| {
                lane.into_iter()
                    .map(|slot| {
                        // lumina: allow(P001) every slot is filled by the probe, the fused dispatch, or the sequential member pass above
                        slot.expect("unresolved suite member slot")
                    })
                    .collect()
            })
            .collect();
        Ok((per_member, simulated))
    }

    /// Compose design `i` of the transposed member-major metrics grid
    /// (member order matches `self.members`) into the suite
    /// objective. Reads straight from the member lanes — no
    /// per-design row is allocated, so steady-state composition is
    /// allocation-free.
    fn composite_at(
        &self,
        per_member: &[Vec<Metrics>],
        i: usize,
    ) -> Metrics {
        debug_assert_eq!(per_member.len(), self.members.len());
        let mut ttft = 0.0f32;
        let mut tpot = 0.0f32;
        let mut e_pf = 0.0f32;
        let mut e_dc = 0.0f32;
        let mut stalls = [[0.0f32; 3]; 2];
        for (mem, ms) in self.members.iter().zip(per_member) {
            let m = &ms[i];
            let wn = mem.scenario.weight as f32 / self.weight_total;
            let r = &mem.reference;
            ttft += wn * (m.ttft_ms / r.ttft_ms);
            tpot += wn * (m.tpot_ms / r.tpot_ms);
            // Energy composes like the latencies: weighted means of the
            // per-scenario values normalized by that scenario's A100
            // reference, so the A100 composite is exactly 1.0 per phase.
            // A member whose reference energy is zero (a pre-PPA PJRT
            // artifact deliberately loads with zero energy lanes)
            // contributes the neutral 1.0 — not NaN, and not a
            // partial weight that would deflate the energy lane in a
            // mixed artifact/mirror suite.
            e_pf += wn
                * crate::arch::power::norm_or_neutral(
                    m.prefill_energy_mj,
                    r.prefill_energy_mj,
                );
            e_dc += wn
                * crate::arch::power::norm_or_neutral(
                    m.energy_per_token_mj,
                    r.energy_per_token_mj,
                );
            let phase_refs = [r.ttft_ms, r.tpot_ms];
            for (p, phase_ref) in phase_refs.into_iter().enumerate() {
                for c in 0..3 {
                    stalls[p][c] += wn * (m.stalls[p][c] / phase_ref);
                }
            }
        }
        Metrics {
            ttft_ms: ttft,
            tpot_ms: tpot,
            // Die area does not depend on the workload; every member
            // reports the same value for a given design.
            area_mm2: per_member[0][i].area_mm2,
            energy_per_token_mj: e_dc,
            prefill_energy_mj: e_pf,
            // On normalized lanes the helper yields a dimensionless
            // "normalized power"; A100 scores exactly 1.0.
            avg_power_w: crate::arch::power::avg_power_w(
                e_pf, e_dc, ttft, tpot,
            ),
            stalls,
        }
    }
}

impl Evaluator for SuiteEvaluator {
    fn eval_batch(&mut self, designs: &[DesignPoint]) -> Result<Vec<Metrics>> {
        let fp = self.fingerprint;
        // Composite-memo probe + one dedup before any fan-out:
        // duplicate designs inside the ask batch and revisits across
        // batches are served on the caller thread.
        let mut slots: Vec<Option<Metrics>> =
            Vec::with_capacity(designs.len());
        let mut fresh: Vec<DesignPoint> = Vec::new();
        let mut seen: HashSet<DesignPoint> = HashSet::new();
        for d in designs {
            let hit = self.composite.get(fp, d);
            if hit.is_none() && seen.insert(*d) {
                fresh.push(*d);
            }
            slots.push(hit);
        }
        let (per_member, simulated) = if fresh.is_empty() {
            (Vec::new(), 0)
        } else {
            self.eval_members(&fresh)?
        };
        // Compose in input order from the transposed per-member lanes
        // directly — no per-design row allocation.
        let mut fresh_ms: HashMap<DesignPoint, Metrics> =
            HashMap::with_capacity(fresh.len());
        for (i, d) in fresh.iter().enumerate() {
            let m = self.composite_at(&per_member, i);
            self.composite.insert(fp, d, m);
            fresh_ms.insert(*d, m);
        }
        // A design counts as a miss only when some member actually
        // simulated it: composite-memo hits, intra-batch duplicates
        // and designs fully served by the member tiers (a warm disk
        // store) all ride as hits — so under `BudgetedEvaluator`
        // they stay budget-free, exactly like the single-workload
        // disk-backed stack.
        let misses = simulated as u64;
        self.composite.record(designs.len() as u64 - misses, misses);
        Ok(designs
            .iter()
            .zip(slots)
            .map(|(d, s)| s.unwrap_or_else(|| fresh_ms[d]))
            .collect())
    }

    fn name(&self) -> &'static str {
        "suite"
    }

    fn is_cached(&self, d: &DesignPoint) -> bool {
        if self.composite.contains(self.fingerprint, d) {
            return true;
        }
        // Served without simulating iff *every* member can be
        // tier-served; sequential members never can.
        self.members.iter().all(|m| {
            matches!(m.backend, SuiteBackend::Fused(_))
                && self.tiers.contains(m.fp, d)
        })
    }

    fn cache_counters(&self) -> Option<CacheCounters> {
        Some(self.composite.counters())
    }

    fn disk_counters(&self) -> Option<DiskCounters> {
        self.tiers.disk().map(|d| d.counters())
    }

    fn workload_fingerprint(&self) -> u64 {
        self.fingerprint
    }

    fn preload(&mut self, pairs: &[(DesignPoint, Metrics)]) {
        // Resume path: a checkpointed trajectory holds *composite*
        // metrics, so it warms the composite memo (the member tiers
        // refill from disk or fresh evaluation).
        for (d, m) in pairs {
            self.composite.insert_if_absent(self.fingerprint, d, *m);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::design::{sample, DesignSpace};
    use crate::eval::{Bottleneck, Phase};
    use crate::sim::RooflineSim;
    use crate::stats::rng::Pcg32;
    use crate::workload::{scenario_by_name, suite_scenarios};

    fn suite() -> SuiteEvaluator {
        SuiteEvaluator::new(
            &suite_scenarios(),
            &mut |spec: &WorkloadSpec| -> Box<dyn Evaluator> {
                Box::new(RooflineSim::new(*spec))
            },
        )
        .unwrap()
    }

    fn fused_suite() -> SuiteEvaluator {
        SuiteEvaluator::with_backends(
            &suite_scenarios(),
            &mut |spec: &WorkloadSpec| {
                SuiteBackend::Fused(Box::new(RooflineSim::new(*spec)))
            },
            None,
        )
        .unwrap()
    }

    #[test]
    fn a100_composite_is_unity() {
        let mut s = suite();
        let m = s.eval(&DesignPoint::a100()).unwrap();
        assert!((m.ttft_ms - 1.0).abs() < 1e-5, "{m:?}");
        assert!((m.tpot_ms - 1.0).abs() < 1e-5, "{m:?}");
        // Energy lanes are reference-normalized the same way.
        assert!((m.prefill_energy_mj - 1.0).abs() < 1e-5, "{m:?}");
        assert!((m.energy_per_token_mj - 1.0).abs() < 1e-5, "{m:?}");
        assert!((m.avg_power_w - 1.0).abs() < 1e-5, "{m:?}");
        // Stall stacks keep the sum-to-phase-time invariant.
        let pf: f32 = m.stalls[0].iter().sum();
        let dc: f32 = m.stalls[1].iter().sum();
        assert!((pf - m.ttft_ms).abs() < 1e-4);
        assert!((dc - m.tpot_ms).abs() < 1e-4);
    }

    #[test]
    fn composite_ranks_paper_designs_below_reference() {
        let mut s = suite();
        let a100 = s.eval(&DesignPoint::a100()).unwrap();
        let a = s.eval(&DesignPoint::paper_design_a()).unwrap();
        assert!(a.ttft_ms < a100.ttft_ms);
        assert!(a.area_mm2 < a100.area_mm2);
    }

    #[test]
    fn per_scenario_report_covers_all_members() {
        let mut s = suite();
        let rows = s.eval_scenarios(&DesignPoint::a100()).unwrap();
        assert_eq!(rows.len(), suite_scenarios().len());
        for r in &rows {
            assert!(r.metrics.ttft_ms > 0.0);
            assert!((r.metrics.ttft_ms - r.reference.ttft_ms).abs() < 1e-9);
            assert!(r.full_ttft_ms() > r.metrics.ttft_ms);
        }
        // The long-context scenario must be prefill-dominated relative
        // to the latency-decode one.
        let by_name = |n: &str| {
            rows.iter().find(|r| r.name == n).unwrap().metrics
        };
        let lc = by_name("long-context");
        let ld = by_name("latency-decode");
        assert!(lc.ttft_ms > ld.ttft_ms);
        assert!(
            lc.ttft_ms / lc.tpot_ms > ld.ttft_ms / ld.tpot_ms,
            "long-context should skew toward prefill"
        );
    }

    #[test]
    fn scenario_regimes_flip_bottlenecks() {
        // The suite exists to exercise different bottleneck structures;
        // check the A100 actually sees different dominant stalls across
        // scenarios in at least one phase.
        let mut s = suite();
        let rows = s.eval_scenarios(&DesignPoint::a100()).unwrap();
        let decode_stalls: Vec<Bottleneck> = rows
            .iter()
            .map(|r| r.metrics.dominant_bottleneck(Phase::Decode))
            .collect();
        let prefill_stalls: Vec<Bottleneck> = rows
            .iter()
            .map(|r| r.metrics.dominant_bottleneck(Phase::Prefill))
            .collect();
        let distinct = |v: &[Bottleneck]| {
            v.iter().any(|b| *b != v[0])
        };
        assert!(
            distinct(&decode_stalls) || distinct(&prefill_stalls),
            "all scenarios share one bottleneck profile: \
             prefill {prefill_stalls:?} decode {decode_stalls:?}"
        );
    }

    #[test]
    fn weights_shift_the_composite() {
        let heavy_decode = [*scenario_by_name("latency-decode").unwrap()];
        let heavy_prefill = [*scenario_by_name("long-context").unwrap()];
        let build = |ss: &[Scenario]| {
            let refs: Vec<&Scenario> = ss.iter().collect();
            SuiteEvaluator::new(
                &refs,
                &mut |spec: &WorkloadSpec| -> Box<dyn Evaluator> {
                    Box::new(RooflineSim::new(*spec))
                },
            )
            .unwrap()
        };
        // More memory channels: helps the decode-heavy suite composite
        // TPOT more than the prefill-heavy one helps its TTFT.
        use crate::design::Param;
        let d = DesignPoint::a100().with(Param::MemChannels, 10);
        let mut sd = build(&heavy_decode);
        let mut sp = build(&heavy_prefill);
        let md = sd.eval(&d).unwrap();
        let mp = sp.eval(&d).unwrap();
        assert!(md.tpot_ms < 1.0);
        assert!(md.tpot_ms < mp.ttft_ms);
    }

    #[test]
    fn zero_energy_references_compose_without_nan() {
        // Pre-PPA PJRT artifacts load with zero energy lanes; the
        // composite must stay finite (and serializable) rather than
        // propagate 0/0 NaN into checkpoints.
        struct ZeroEnergy(RooflineSim);
        impl Evaluator for ZeroEnergy {
            fn eval_batch(
                &mut self,
                designs: &[DesignPoint],
            ) -> crate::Result<Vec<Metrics>> {
                let mut ms = self.0.eval_batch(designs)?;
                for m in &mut ms {
                    m.energy_per_token_mj = 0.0;
                    m.prefill_energy_mj = 0.0;
                    m.avg_power_w = 0.0;
                }
                Ok(ms)
            }
            fn name(&self) -> &'static str {
                "zero-energy"
            }
            fn workload_fingerprint(&self) -> u64 {
                Evaluator::workload_fingerprint(&self.0)
            }
        }
        let mut s = SuiteEvaluator::new(
            &suite_scenarios(),
            &mut |spec: &WorkloadSpec| -> Box<dyn Evaluator> {
                Box::new(ZeroEnergy(RooflineSim::new(*spec)))
            },
        )
        .unwrap();
        let m = s.eval(&DesignPoint::a100()).unwrap();
        assert!(m.ttft_ms.is_finite() && (m.ttft_ms - 1.0).abs() < 1e-5);
        // Zero-energy members contribute the neutral 1.0, so the A100
        // composite invariant holds even without energy data.
        assert!((m.prefill_energy_mj - 1.0).abs() < 1e-5, "{m:?}");
        assert!((m.energy_per_token_mj - 1.0).abs() < 1e-5, "{m:?}");
        assert!((m.avg_power_w - 1.0).abs() < 1e-5, "{m:?}");
    }

    #[test]
    fn mixed_energy_suite_keeps_the_unity_invariant() {
        // One real member + zero-energy members (the mixed
        // artifact/mirror case): the A100 energy composite must stay
        // exactly 1.0, not a partial weighted sum.
        struct MaybeZero(RooflineSim, bool);
        impl Evaluator for MaybeZero {
            fn eval_batch(
                &mut self,
                designs: &[DesignPoint],
            ) -> crate::Result<Vec<Metrics>> {
                let mut ms = self.0.eval_batch(designs)?;
                if self.1 {
                    for m in &mut ms {
                        m.energy_per_token_mj = 0.0;
                        m.prefill_energy_mj = 0.0;
                        m.avg_power_w = 0.0;
                    }
                }
                Ok(ms)
            }
            fn name(&self) -> &'static str {
                "maybe-zero"
            }
            fn workload_fingerprint(&self) -> u64 {
                Evaluator::workload_fingerprint(&self.0)
            }
        }
        let mut first = true;
        let mut s = SuiteEvaluator::new(
            &suite_scenarios(),
            &mut |spec: &WorkloadSpec| -> Box<dyn Evaluator> {
                let zero = !first;
                first = false;
                Box::new(MaybeZero(RooflineSim::new(*spec), zero))
            },
        )
        .unwrap();
        let m = s.eval(&DesignPoint::a100()).unwrap();
        assert!((m.prefill_energy_mj - 1.0).abs() < 1e-5, "{m:?}");
        assert!((m.energy_per_token_mj - 1.0).abs() < 1e-5, "{m:?}");
    }

    #[test]
    fn empty_and_zero_weight_suites_are_rejected() {
        let none: [&Scenario; 0] = [];
        let mut factory = |spec: &WorkloadSpec| -> Box<dyn Evaluator> {
            Box::new(RooflineSim::new(*spec))
        };
        assert!(SuiteEvaluator::new(&none, &mut factory).is_err());
        let tiny = [scenario_by_name("gpt3-tiny").unwrap()];
        assert!(SuiteEvaluator::new(&tiny, &mut factory).is_err());
    }

    #[test]
    fn fused_suite_matches_sequential_bitwise() {
        let space = DesignSpace::table1();
        let mut rng = Pcg32::new(1009);
        let ds = sample::uniform_batch(&space, &mut rng, 32);
        let mut seq = suite();
        let mut fused = fused_suite();
        let a = seq.eval_batch(&ds).unwrap();
        let b = fused.eval_batch(&ds).unwrap();
        assert_eq!(a, b, "fused dispatch must be bitwise-identical");
        // References must agree bitwise too.
        let ra = seq.eval_scenarios(&DesignPoint::a100()).unwrap();
        let rb = fused.eval_scenarios(&DesignPoint::a100()).unwrap();
        for (x, y) in ra.iter().zip(&rb) {
            assert_eq!(x.reference, y.reference);
            assert_eq!(x.metrics, y.metrics);
        }
    }

    #[test]
    fn dedup_changes_counters_not_results() {
        let space = DesignSpace::table1();
        let mut rng = Pcg32::new(2027);
        let uniq = sample::uniform_batch(&space, &mut rng, 6);
        let dup: Vec<DesignPoint> =
            (0..24).map(|i| uniq[i % uniq.len()]).collect();
        let mut seq = suite();
        let mut fused = fused_suite();
        let a = seq.eval_batch(&dup).unwrap();
        let b = fused.eval_batch(&dup).unwrap();
        assert_eq!(a, b, "dedup must not change results");
        // Both stacks simulate only the unique designs; the 18
        // duplicate occurrences ride as caller-thread hits.
        for s in [&seq, &fused] {
            let c = s.cache_counters().unwrap();
            assert_eq!(c.misses, 6, "{}", s.name());
            assert_eq!(c.hits, 18, "{}", s.name());
        }
    }

    #[test]
    fn composite_memo_serves_repeat_batches() {
        let space = DesignSpace::table1();
        let mut rng = Pcg32::new(4099);
        let ds = sample::uniform_batch(&space, &mut rng, 10);
        let mut s = fused_suite();
        let first = s.eval_batch(&ds).unwrap();
        let again = s.eval_batch(&ds).unwrap();
        assert_eq!(first, again);
        let c = s.cache_counters().unwrap();
        assert_eq!(c.misses, 10);
        assert_eq!(c.hits, 10);
        for d in &ds {
            assert!(s.is_cached(d));
        }
        // clear_memo drops the memo but keeps the counters; the next
        // batch re-simulates.
        s.clear_memo();
        assert!(!s.is_cached(&ds[0]));
        let third = s.eval_batch(&ds).unwrap();
        assert_eq!(first, third);
        assert_eq!(s.cache_counters().unwrap().misses, 20);
    }
}
